"""Roofline report: reads the dry-run artifacts (runs/dryrun/*.json) and
formats the §Roofline table per (arch × shape × mesh).

Terms (per-device seconds, TPU v5e constants):
  compute_s    = HLO dot/conv FLOPs / 197 TFLOP/s
  memory_s     = HBM-boundary traffic proxy / 819 GB/s
  collective_s = trip-scaled collective bytes / 50 GB/s per link

Interpretation notes printed with the table:
  * train/prefill cells: roofline_mfu = useful-FLOPs time ÷ bound time —
    the fraction of the dominant roofline actually doing model math.
  * decode cells are *correctly* memory-bound (one token against a full
    cache); their figure of merit is bandwidth efficiency = ideal bytes
    (params read once + cache read once) ÷ achieved traffic proxy.
"""
from __future__ import annotations

import glob
import json
import math
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9

_SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


# ---------------------------------------------------------------------------
# Edge/FPGA roofline (ISSUE 10: the modeled-vs-measured profiler's bound)
# ---------------------------------------------------------------------------


def edge_ideal_cycles(macs: int, dma_bytes: int, *, d_total: int,
                      elem_bits: int = 8) -> int:
    """The roofline cycle bound for one scheduled group on the edge
    target: the larger of the compute bound (all of the device's DSPs
    multiplying every cycle, integer-packing-aware via
    :func:`repro.core.resource_model.dsp_per_mult`) and the bandwidth
    bound (boundary-DMA bytes at the derated
    :data:`~repro.core.resource_model.DRAM_BYTES_PER_CYCLE`).  A group
    whose *modeled* cycles sit at this bound is as good as the fabric
    allows; modeled/ideal is the profiler's ``roofline_util`` column.
    """
    from repro.core.resource_model import (
        DRAM_BYTES_PER_CYCLE,
        dsp_per_mult,
    )

    if d_total <= 0:
        raise ValueError(f"d_total must be > 0, got {d_total}")
    peak_macs_per_cycle = d_total / dsp_per_mult(elem_bits)
    compute = math.ceil(macs / peak_macs_per_cycle) if macs else 0
    memory = (math.ceil(dma_bytes / DRAM_BYTES_PER_CYCLE)
              if dma_bytes else 0)
    return max(compute, memory)


def load_records(out_dir: str = "runs/dryrun") -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def decode_bw_efficiency(rec: dict) -> float | None:
    """ideal bytes / achieved traffic for decode cells."""
    if rec.get("entry") != "decode_step" or rec.get("skipped"):
        return None
    # params (active) in bf16 + the KV/state cache, each read once,
    # divided across chips
    param_bytes = rec["params_active"] * 2
    cache_bytes = rec.get("cache_bytes", 0)
    ideal = (param_bytes + cache_bytes) / rec["chips"]
    achieved = rec["hlo_bytes_per_device"]
    return ideal / achieved if achieved else None


def kernel_substituted_memory(rec: dict) -> dict | None:
    """Memory term with Pallas-kernel-true traffic substituted.

    The XLA-level streaming attention / SSD scan bounce kernel-internal
    tensors (score tiles, softmax carries, chunk gates, state slices)
    through HBM at fusion boundaries; the validated Pallas kernels
    (``repro.kernels``, interpret-mode-tested vs ref.py) hold exactly
    these in VMEM scratch.  Method:

      removed = measured traffic of internal shapes (trailing dims drawn
                from the kernel's block geometry; from traffic_by_shape)
      added   = analytic kernel HBM traffic (Q/O once + K/V per q-block
                sweep for attention; x/dt/b/c/y once for SSD)

    Returns {"memory_s_pallas", "removed_s", "added_s"} or None if the
    record lacks traffic attribution / the arch has no applicable kernel.
    """
    if rec.get("skipped") or not rec.get("ok"):
        return None
    tbs = rec.get("traffic_by_shape")
    if not tbs:
        return None
    from repro.configs.base import SHAPES
    from repro.configs.registry import get_config

    cfg = get_config(rec["arch"])
    shape = SHAPES[rec["shape"]]
    chips = rec["chips"]
    mesh_shape = rec.get("mesh_shape", [16, 16])
    tp = mesh_shape[-1]
    dp = chips // tp

    import re as _re

    def trailing(key):
        m = _re.match(r"(\w+)\[([\d,]+)\]", key)
        if not m:
            return None, ()
        dims = [int(x) for x in m.group(2).split(",")]
        return m.group(1), tuple(dims[-2:]) if len(dims) >= 2 else tuple(dims)

    removed = 0.0
    added = 0.0
    exclude = {cfg.d_model, cfg.d_ff, cfg.padded_vocab, shape.seq_len}

    if cfg.num_heads > 0:  # attention kernel applies
        bq = min(cfg.attn_block_q, shape.seq_len)
        bk = min(cfg.attn_block_k, shape.seq_len)
        hd = cfg.resolved_head_dim

        def is_attn_internal(d):
            def ok(x):
                if x in exclude or x == 0:
                    return False
                return (x % bq == 0 or x % bk == 0 or x in (hd, 16, 8, 1))
            return len(d) == 2 and ok(d[0]) and ok(d[1]) and not (
                d[0] == shape.seq_len or d[1] == shape.seq_len
            )

        for key, b in tbs.items():
            dt_, d = trailing(key)
            if dt_ == "f32" and is_attn_internal(d):
                removed += b
        # analytic kernel traffic per device (bf16 HBM residency)
        s = shape.seq_len
        b_loc = max(shape.global_batch // dp, 1)
        # heads that don't divide TP are REPLICATED per device (the
        # head-aware sharding rule), not sliced
        hq_loc = (cfg.num_heads // tp if cfg.num_heads % tp == 0
                  else cfg.num_heads)
        hkv_loc = (cfg.num_kv_heads // tp if cfg.num_kv_heads % tp == 0
                   else cfg.num_kv_heads)
        layers = cfg.num_layers if cfg.family != "hybrid" else (
            cfg.num_layers // cfg.attn_period
        )
        nq = max(s // bq, 1)
        passes = 3.5 if shape.kind == "train" else 1.0  # fwd + flash bwd
        per_layer = (
            2 * b_loc * hq_loc * s * hd * 2          # Q read + O write
            + b_loc * hkv_loc * 2 * s * hd * 2 * nq  # K/V re-read per q-blk
        )
        added += passes * layers * per_layer

    if cfg.ssm is not None:  # SSD kernel applies
        q = cfg.ssm.chunk
        n = cfg.ssm.state_dim
        p = cfg.ssm.head_dim
        hs = max(cfg.ssm.num_heads(cfg.d_model) // tp, 1)
        magic = {q, 2 * q, 4 * q, n, p, hs, 2 * hs, 4}

        def is_ssd_internal(d):
            return (len(d) == 2 and d[0] in magic and d[1] in magic
                    and d[0] not in exclude and d[1] not in exclude)

        for key, b in tbs.items():
            dt_, d = trailing(key)
            if dt_ == "f32" and is_ssd_internal(d):
                removed += b
        s = shape.seq_len
        b_loc = max(shape.global_batch // dp, 1)
        di_loc = max(cfg.ssm.d_inner(cfg.d_model) // tp, 1)
        n_mamba = cfg.num_layers if cfg.family == "ssm" else (
            cfg.num_layers - cfg.num_layers // max(cfg.attn_period, 1)
        )
        passes = 3.5 if shape.kind == "train" else 1.0
        per_layer = b_loc * s * (2 * di_loc + 2 * n + hs) * 4  # x,y,dt,b,c
        added += passes * n_mamba * per_layer

    if removed == 0.0:
        return None
    mem_s = rec["memory_s"] - removed / HBM_BW + added / HBM_BW
    return {
        "memory_s_pallas": max(mem_s, 0.0),
        "removed_s": removed / HBM_BW,
        "added_s": added / HBM_BW,
    }


def sort_key(rec):
    return (
        rec["arch"],
        _SHAPE_ORDER.index(rec["shape"]) if rec["shape"] in _SHAPE_ORDER else 9,
        rec.get("mesh", ""),
    )


def table(out_dir: str = "runs/dryrun", emit=print, mesh: str | None = "single"):
    recs = [r for r in load_records(out_dir)
            if mesh is None or r.get("mesh") == mesh]
    if not recs:
        emit(f"# no dry-run artifacts under {out_dir} — run "
             "`python -m repro.launch.dryrun --all --mesh both` first")
        return []
    emit(f"# §Roofline — per (arch × shape), mesh={mesh}, per-device terms")
    emit("arch,shape,entry,compute_s,memory_s,collective_s,dominant,"
         "useful_ratio,roofline_mfu,decode_bw_eff,fits_hbm")
    rows = []
    for rec in sorted(recs, key=sort_key):
        if rec.get("skipped"):
            emit(f"{rec['arch']},{rec['shape']},SKIP,,,,,,,,")
            continue
        if not rec.get("ok"):
            emit(f"{rec['arch']},{rec['shape']},FAIL,,,,,,,,")
            continue
        eff = decode_bw_efficiency(rec)
        mem = rec.get("memory_analysis", {})
        temp = mem.get("temp_size_in_bytes", 0)
        args = mem.get("argument_size_in_bytes", 0)
        fits = (temp + args) <= 16 * (1 << 30)
        rows.append(rec)
        emit(
            f"{rec['arch']},{rec['shape']},{rec['entry']},"
            f"{rec['compute_s']:.4g},{rec['memory_s']:.4g},"
            f"{rec['collective_s']:.4g},{rec['dominant'][:-2]},"
            f"{rec['useful_flops_ratio']:.3f},{rec['roofline_mfu']:.4f},"
            f"{'' if eff is None else f'{eff:.3f}'},{fits}"
        )
    return rows


def pick_hillclimb_cells(out_dir: str = "runs/dryrun", emit=print):
    """The three §Perf cells: worst roofline fraction, most
    collective-bound, most representative of the paper's technique."""
    recs = [
        r for r in load_records(out_dir)
        if r.get("ok") and not r.get("skipped") and r.get("mesh") == "single"
    ]
    if not recs:
        return []
    trainish = [r for r in recs if r["entry"] != "decode_step"]
    worst = min(trainish, key=lambda r: r["roofline_mfu"])
    coll = max(recs, key=lambda r: r["collective_s"] /
               max(r["bound_s"], 1e-12))
    # paper-representative: the SSM arch (line-buffer streaming) at train
    rep = [r for r in recs
           if r["arch"] == "mamba2-1.3b" and r["shape"] == "train_4k"]
    cells = []
    for label, r in (("worst-mfu", worst), ("collective-bound", coll),
                     ("paper-representative", rep[0] if rep else worst)):
        cells.append((label, r["arch"], r["shape"]))
        emit(f"# hillclimb cell [{label}]: {r['arch']} × {r['shape']} "
             f"(mfu={r['roofline_mfu']:.4f}, dom={r['dominant']})")
    return cells


if __name__ == "__main__":
    table()
    print()
    table(mesh="multi")
    print()
    pick_hillclimb_cells()
