"""Markdown table generators for EXPERIMENTS.md (§Dry-run / §Roofline).

Usage: PYTHONPATH=src python -m benchmarks.report [--out runs/dryrun]
Prints markdown to stdout; EXPERIMENTS.md embeds the output.
"""
from __future__ import annotations

import argparse

from benchmarks.roofline import load_records, sort_key


def _gb(x) -> str:
    return f"{x / (1 << 30):.2f}"


def dryrun_table(recs, mesh: str) -> list[str]:
    out = [
        f"### Mesh `{mesh}`",
        "",
        "| arch | shape | entry | status | compile s | args GiB/dev | "
        "temp GiB/dev | collectives (count) |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted([r for r in recs if r.get("mesh") == mesh], key=sort_key):
        if r.get("skipped"):
            out.append(
                f"| {r['arch']} | {r['shape']} | — | **skip** (recorded) "
                f"| — | — | — | — |"
            )
            continue
        if not r.get("ok"):
            out.append(f"| {r['arch']} | {r['shape']} | — | **FAIL** | — | — | — | — |")
            continue
        mem = r.get("memory_analysis", {})
        colls = ", ".join(
            f"{k}×{v}" for k, v in sorted(r.get("collective_counts", {}).items())
        ) or "none"
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['entry']} | ok "
            f"| {r.get('compile_s', 0):.0f} "
            f"| {_gb(mem.get('argument_size_in_bytes', 0))} "
            f"| {_gb(mem.get('temp_size_in_bytes', 0))} "
            f"| {colls} |"
        )
    return out


def roofline_table(recs, mesh: str) -> list[str]:
    out = [
        f"### Mesh `{mesh}` (per-device seconds per step)",
        "",
        "| arch | shape | compute | memory | collective | bottleneck | "
        "MODEL/HLO FLOPs | roofline-MFU |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted([r for r in recs if r.get("mesh") == mesh], key=sort_key):
        if r.get("skipped") or not r.get("ok"):
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3g} "
            f"| {r['memory_s']:.3g} | {r['collective_s']:.3g} "
            f"| **{r['dominant'][:-2]}** | {r['useful_flops_ratio']:.2f} "
            f"| {r['roofline_mfu']:.4f} |"
        )
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="runs/dryrun")
    ap.add_argument("--section", choices=["dryrun", "roofline", "both"],
                    default="both")
    args = ap.parse_args()
    recs = load_records(args.out)
    lines: list[str] = []
    if args.section in ("dryrun", "both"):
        lines += dryrun_table(recs, "single") + [""]
        lines += dryrun_table(recs, "multi") + [""]
    if args.section in ("roofline", "both"):
        lines += roofline_table(recs, "single") + [""]
        lines += roofline_table(recs, "multi")
    print("\n".join(lines))


if __name__ == "__main__":
    main()
