"""Serving load-test benchmark → ``BENCH_serve.json`` (ISSUE 7).

For each zoo model × target, compile through the serving
:class:`~repro.serve.ArtifactCache`, warm every batch bucket the
dynamic batcher can land on (steady-state serving never recompiles, so
neither does the measured trajectory), then drive the
:class:`~repro.serve.ServeEngine` open-loop at a sweep of offered QPS
levels and record p50/p99 latency + achieved throughput per level.

The snapshot additionally carries a ``_speedup`` section measuring the
tentpole claim *in the same run*: lenet5 at batch 32, vmapped device
dispatch (``batch_mode="vmap"``) vs the per-sample loop
(``batch_mode="loop"``) — the acceptance gate is ≥5×.

Every row carries a provenance stamp (ISSUE 6); ``scripts/smoke_diff.py
--mode serve`` diffs the rows fail-soft across runs (only a >10% p99 or
throughput regression hard-fails, provenance stripped).  Each
model×target cell additionally carries the engine's **metrics
snapshot** (ISSUE 10: lifecycle-stage histograms, rejection causes,
batch occupancy — the full :meth:`ServeEngine.metrics` document,
diff-exempt like provenance); ``--metrics-out`` also writes the last
cell's snapshot standalone for the CI artifact.

Usage::

  PYTHONPATH=src python -m benchmarks.serve_bench            # full sweep
  PYTHONPATH=src python -m benchmarks.serve_bench \
      --models lenet5 --targets kv260 --qps 200 --requests 60  # CI smoke
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core.compile_driver import TARGETS, CompileOptions
from repro.frontends import zoo
from repro.instrument import provenance, validate_metrics_snapshot
from repro.kernels import ops
from repro.serve import ArtifactCache, ServeConfig, ServeEngine, run_load

#: the committed sweep: every zoo model on both targets, offered QPS
#: from comfortable to saturating (lenet5 vmapped capacity on one CPU
#: is a few thousand samples/s; the top level queues hard on purpose —
#: open-loop p99 under pressure is the number that matters).
DEFAULT_MODELS = ("lenet5", "tiny_vgg_32", "edge_residual_32")
DEFAULT_TARGETS = ("kv260", "zu3eg")
DEFAULT_QPS = (50.0, 200.0, 800.0)


def _warm_buckets(art, max_batch: int, seed: int) -> list[int]:
    """Execute one batched run per bucket ≤ ``max_batch`` so the serve
    sweep measures steady-state dispatch, not jit compiles."""
    src = art.design.source
    rng = np.random.default_rng(seed)
    x = {
        k: rng.integers(-4, 5, size=(max_batch,) + src.values[k].shape,
                        dtype=np.int32)
        for k in src.graph_inputs
    }
    warmed = []
    for b in ops.BATCH_BUCKETS:
        if b > max_batch:
            break
        art.run({k: v[:b] for k, v in x.items()})
        warmed.append(b)
    return warmed


def bench_speedup(cache: ArtifactCache, *, batch: int = 32,
                  reps: int = 3, seed: int = 0) -> dict:
    """The tentpole gate: lenet5@kv260 batch-``batch`` vmapped vs
    per-sample loop, min wall over ``reps`` after warming both paths."""
    options = CompileOptions(target=TARGETS["kv260"])
    art = cache.get_or_compile("lenet5", zoo.ZOO["lenet5"], options)
    src = art.design.source
    rng = np.random.default_rng(seed)
    x = {
        k: rng.integers(-4, 5, size=(batch,) + src.values[k].shape,
                        dtype=np.int32)
        for k in src.graph_inputs
    }
    y_loop = art.run(x, batch_mode="loop")
    y_vmap = art.run(x, batch_mode="vmap")
    exact = bool(np.array_equal(y_loop, y_vmap))
    loop_ms = min(
        _timed(lambda: art.run(x, batch_mode="loop")) for _ in range(reps)
    )
    vmap_ms = min(
        _timed(lambda: art.run(x, batch_mode="vmap")) for _ in range(reps)
    )
    return {
        "model": "lenet5",
        "target": "kv260",
        "batch": batch,
        "loop_ms": round(loop_ms, 3),
        "vmap_ms": round(vmap_ms, 3),
        "speedup": round(loop_ms / vmap_ms, 2) if vmap_ms else 0.0,
        "bit_exact": exact,
        "loop_throughput_sps": round(batch / loop_ms * 1e3, 1),
        "vmap_throughput_sps": round(batch / vmap_ms * 1e3, 1),
    }


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return (time.perf_counter() - t0) * 1e3


def bench_serve_json(path: str = "BENCH_serve.json", *,
                     models=DEFAULT_MODELS, targets=DEFAULT_TARGETS,
                     qps_levels=DEFAULT_QPS, requests: int = 120,
                     max_batch: int = 32, latency_budget_ms: float = 5.0,
                     seed: int = 0, speedup: bool = True,
                     metrics_out: str | None = None) -> dict:
    cache = ArtifactCache(capacity=2 * len(models))
    stamp = provenance()
    data: dict = {}
    last_snapshot: dict | None = None
    print("model,target,offered_qps,achieved_qps,p50_ms,p99_ms,mean_batch")
    for model in models:
        if model not in zoo.ZOO:
            raise KeyError(f"unknown zoo model {model!r} — {sorted(zoo.ZOO)}")
        data[model] = {}
        for tname in targets:
            options = CompileOptions(target=TARGETS[tname])
            t0 = time.perf_counter()
            art = cache.get_or_compile(model, zoo.ZOO[model], options)
            compile_s = time.perf_counter() - t0
            warmed = _warm_buckets(art, max_batch, seed)
            cfg = ServeConfig(max_batch=max_batch,
                              latency_budget_ms=latency_budget_ms)
            rows = []
            with ServeEngine(art, cfg, seed=seed) as eng:
                for q in qps_levels:
                    rep = run_load(eng, offered_qps=q, requests=requests,
                                   seed=seed)
                    row = rep.row()
                    rows.append(row)
                    print(f"{model},{tname},{row['offered_qps']},"
                          f"{row['achieved_qps']},{row['p50_ms']},"
                          f"{row['p99_ms']},{row['mean_batch']}")
                snapshot = validate_metrics_snapshot(eng.metrics())
            last_snapshot = snapshot
            data[model][tname] = {
                "loads": rows,
                "max_batch": max_batch,
                "latency_budget_ms": latency_budget_ms,
                "warmed_buckets": warmed,
                "metrics": snapshot,
                "provenance": dict(stamp, compile_s=round(compile_s, 4)),
            }
    if speedup:
        sp = bench_speedup(cache, batch=max_batch, seed=seed)
        sp["provenance"] = dict(stamp)
        data["_speedup"] = sp
        print(f"# speedup lenet5@kv260 b{sp['batch']}: "
              f"loop {sp['loop_ms']}ms vmap {sp['vmap_ms']}ms "
              f"= {sp['speedup']}x (bit_exact={sp['bit_exact']})")
    with open(path, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)
    print(f"# wrote {path}")
    if metrics_out and last_snapshot is not None:
        with open(metrics_out, "w") as f:
            json.dump(last_snapshot, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"# wrote {metrics_out}")
    return data


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--models", default=",".join(DEFAULT_MODELS))
    ap.add_argument("--targets", default=",".join(DEFAULT_TARGETS))
    ap.add_argument("--qps", default=",".join(str(q) for q in DEFAULT_QPS))
    ap.add_argument("--requests", type=int, default=120)
    ap.add_argument("--max-batch", type=int, default=32)
    ap.add_argument("--latency-budget-ms", type=float, default=5.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-speedup", action="store_true",
                    help="skip the lenet5 vmap-vs-loop gate section")
    ap.add_argument("--metrics-out", metavar="PATH", default=None,
                    help="also write the last cell's metrics snapshot "
                         "standalone (the CI artifact)")
    ap.add_argument("--min-speedup", type=float, default=5.0,
                    help="hard-fail when the vmap-vs-loop speedup is "
                         "below this; 0 makes the speedup informational "
                         "(CI uses 0: wall-clock on shared runners is "
                         "noisy-neighbor flaky). Bit-exactness always "
                         "hard-fails.")
    args = ap.parse_args(argv)
    data = bench_serve_json(
        args.out,
        models=tuple(m for m in args.models.split(",") if m),
        targets=tuple(t for t in args.targets.split(",") if t),
        qps_levels=tuple(float(q) for q in args.qps.split(",") if q),
        requests=args.requests,
        max_batch=args.max_batch,
        latency_budget_ms=args.latency_budget_ms,
        seed=args.seed,
        speedup=not args.no_speedup,
        metrics_out=args.metrics_out,
    )
    sp = data.get("_speedup")
    if sp and not sp["bit_exact"]:
        # correctness is never a soft gate
        print("# FAIL: vmap run is not bit-exact against the loop")
        return 1
    if sp and sp["speedup"] < args.min_speedup:
        print(f"# FAIL: batched speedup gate "
              f"(speedup={sp['speedup']}x < {args.min_speedup}x)")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
