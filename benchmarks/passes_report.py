"""Pass-pipeline benchmark report (ISSUE 1 acceptance artifacts).

Two sections:

  1. **Fusion report** — pre/post-fusion node count, stream-edge count,
     and modeled BRAM (solve_ilp under KV260 budgets) per suite kernel,
     plus the per-pass statistics trail.
  2. **Partition report** — ``deep_cascade`` at 32²/64²/224²: does the
     whole (fused) graph fit, and if not, the layer-group schedule that
     does — group count, per-group BRAM/DSP, DRAM spill bytes — with the
     cycle-balanced cut's max-group-cycles next to the PR 1 greedy cut's
     (the balancing win, ISSUE 2 tentpole).
"""
from __future__ import annotations

from repro.core import cnn_graphs
from repro.core.dse import solve_ilp
from repro.core.resource_model import KV260_BRAM18K, KV260_DSP
from repro.core.streaming import plan_streams
from repro.passes import partition_layer_groups, run_default_pipeline


def _internal_streams(plan) -> int:
    return sum(
        1 for s in plan.streams.values() if s.producer and s.consumer
    )


def fusion_report(emit=print) -> list[dict]:
    emit("# Pass pipeline — pre/post-fusion footprint per kernel")
    emit("kernel,nodes_pre,nodes_post,streams_pre,streams_post,"
         "bram_pre,bram_post,ops_fused")
    rows = []
    for name, make in cnn_graphs.PAPER_SUITE.items():
        dfg = make()
        result = run_default_pipeline(dfg)
        pre_plan, post_plan = plan_streams(dfg), plan_streams(result.dfg)
        pre = solve_ilp(pre_plan)
        post = solve_ilp(post_plan)
        row = {
            "kernel": name,
            "nodes_pre": len(dfg.nodes),
            "nodes_post": len(result.dfg.nodes),
            "streams_pre": _internal_streams(pre_plan),
            "streams_post": _internal_streams(post_plan),
            "bram_pre": pre.bram_used,
            "bram_post": post.bram_used,
            "ops_fused": result.stat("ops_fused"),
        }
        rows.append(row)
        emit(",".join(str(row[k]) for k in row))
    return rows


def partition_report(emit=print, sizes=(32, 64, 224)) -> list[dict]:
    emit("# Layer-group partitioning — deep_cascade (4×Conv3x3+ReLU, "
         f"c_mid=136) vs KV260 (BRAM {KV260_BRAM18K}, DSP {KV260_DSP})")
    emit("input_size,whole_graph_fits,groups,group_brams,group_dsps,"
         "spill_KiB,total_mcycles,max_group_mcycles,greedy_max_group_mcycles")
    rows = []
    for n in sizes:
        fused = run_default_pipeline(cnn_graphs.deep_cascade(n)).dfg
        pp = partition_layer_groups(fused)
        if pp.partitioned:
            greedy = partition_layer_groups(fused, strategy="greedy")
            greedy_max = round(greedy.max_group_cycles / 1e6, 3)
        else:
            greedy_max = ""
        row = {
            "input_size": n,
            "whole_graph_fits": pp.whole_graph_feasible,
            "groups": len(pp.groups),
            "group_brams": "|".join(str(g.bram) for g in pp.groups),
            "group_dsps": "|".join(str(g.dsp) for g in pp.groups),
            "spill_KiB": round(sum(s.bytes for s in pp.spills()) / 1024, 1),
            "total_mcycles": round(pp.total_cycles / 1e6, 3),
            "max_group_mcycles": round(pp.max_group_cycles / 1e6, 3),
            "greedy_max_group_mcycles": greedy_max,
        }
        rows.append(row)
        emit(",".join(str(row[k]) for k in row))
        assert pp.feasible, f"deep_cascade({n}) has an over-budget group"
    return rows


def pass_statistics(emit=print) -> None:
    emit("# Per-pass statistics (cascade_conv_32)")
    emit(run_default_pipeline(cnn_graphs.cascade_conv(32)).report())


def run_all(emit=print) -> None:
    fusion_report(emit)
    emit("")
    partition_report(emit)
    emit("")
    pass_statistics(emit)


if __name__ == "__main__":
    run_all()
