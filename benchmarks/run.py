"""Benchmark orchestrator: ``PYTHONPATH=src python -m benchmarks.run``.

Sections:
  1. Paper tables (Table II, Fig. 3, Table IV) from the calibrated
     FPGA resource model — one harness per paper artifact.
  2. Pass-pipeline report: pre/post-fusion footprint + layer-group
     partitioning of deep_cascade at 32²/64²/224².
  3. Kernel micro-validation: every Pallas kernel vs its ref.py oracle
     (interpret mode) with wall-times (CPU emulation — correctness
     gates, not TPU performance).
  4. MING DSE micro-bench: ILP solve times + explored nodes (the paper's
     "lightweight DSE" claim).
  5. Roofline summary from dry-run artifacts (if present) + the three
     hillclimb cells.

``--smoke`` runs the model-only sections (1, 2, 4) as a fast CI sanity
gate — no Pallas interpret-mode execution, no roofline artifacts.  Both
modes additionally compile every suite graph through the unified driver
(``repro.core.compile_driver``) and write ``BENCH_smoke.json`` (cycles,
peak BRAM, group count, spill bytes per graph) so the perf trajectory is
tracked across PRs.

Writes everything it prints; exit code 0 iff all validations pass.
"""
from __future__ import annotations

import argparse
import sys
import time


def _section(title: str):
    print(f"\n{'=' * 72}\n== {title}\n{'=' * 72}", flush=True)


def paper_tables() -> bool:
    from benchmarks import paper_tables as pt

    _section("Paper tables (Table II / Fig. 3 / Table IV)")
    pt.run_all()
    return True


def passes_section() -> bool:
    from benchmarks import passes_report

    _section("Pass pipeline (fusion + layer-group partitioning)")
    passes_report.run_all()
    return True


def bench_smoke_json(path: str = "BENCH_smoke.json") -> bool:
    """Compile every suite graph through the public API — once per
    device preset (KV260, ZU3EG) — and write the perf-trajectory
    snapshot (cycles + BRAM per graph per target) that CI archives and
    diffs across runs (``scripts/smoke_diff.py``).  Rows come straight
    from ``CompiledArtifact.report()``.

    Every row additionally carries a ``provenance`` stamp (ISSUE 6):
    git sha, host, compile wall seconds, and per-pass wall times —
    measurements, not metrics, so ``smoke_diff`` excludes them from the
    regression gate (timing jitter must never trip the >10% gate)."""
    import json

    from benchmarks.paper_tables import compile_cached, sweep_suite
    from repro.core.compile_driver import TARGETS
    from repro.instrument import provenance

    _section(f"BENCH smoke snapshot → {path}")
    data = {}
    ok = True
    stamp = provenance()  # identity fields, resolved once per snapshot
    print("graph,target,total_cycles,max_group_cycles,max_bram,groups,"
          "spill_bytes,weight_streamed")
    for name, make in sweep_suite().items():
        data[name] = {}
        for tname, target in TARGETS.items():
            t0 = time.perf_counter()
            art = compile_cached(name, make, target)
            compile_s = time.perf_counter() - t0
            rep = art.report()
            pr = art.design.pass_result
            data[name][tname] = {
                "total_cycles": rep.total_cycles,
                "max_group_cycles": rep.max_group_cycles,
                "max_bram": rep.max_bram,
                "max_dsp": rep.max_dsp,
                "groups": len(rep.groups),
                "spill_bytes": rep.spill_bytes,
                "weight_streamed": art.design.weight_streamed,
                "feasible": rep.feasible,
                "provenance": dict(
                    stamp,
                    compile_s=round(compile_s, 4),
                    pass_ms={p.name: round(p.wall_ms, 3)
                             for p in pr.passes} if pr else {},
                ),
            }
            r = data[name][tname]
            print(f"{name},{tname},{r['total_cycles']},"
                  f"{r['max_group_cycles']},{r['max_bram']},{r['groups']},"
                  f"{r['spill_bytes']},{r['weight_streamed']}")
            if not r["feasible"]:
                print(f"# WARNING: {name} infeasible under {tname} budgets")
                ok = False
    # always write the snapshot — a regression run is exactly when the
    # trajectory artifact matters most (feasible:false rows included)
    with open(path, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)
    return ok


def kernel_validation() -> bool:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels import ops, ref

    _section("Kernel validation vs ref.py oracles (interpret mode)")
    ok = True
    print("kernel,case,us_per_call,max_abs_err,pass")

    def check(name, case, fn, oracle, atol):
        nonlocal ok
        t0 = time.perf_counter()
        out = jax.tree.map(np.asarray, fn())
        dt = (time.perf_counter() - t0) * 1e6
        exp = jax.tree.map(np.asarray, oracle())
        errs = [
            np.abs(np.asarray(a, np.float32) - np.asarray(b, np.float32)).max()
            for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(exp))
        ]
        err = max(errs)
        good = err <= atol
        ok = ok and good
        print(f"{name},{case},{dt:.0f},{err:.2e},{good}")

    key = jax.random.key(0)
    ks = jax.random.split(key, 8)

    x8 = jax.random.randint(ks[0], (1, 16, 16, 8), -8, 8, jnp.int8)
    w8 = jax.random.randint(ks[1], (3, 3, 8, 16), -4, 4, jnp.int8)
    check("conv2d_stream", "int8_3x3",
          lambda: ops.conv2d_stream(x8, w8, fuse_relu=True),
          lambda: ref.conv2d(x8, w8, fuse_relu=True), 0)

    q = jax.random.normal(ks[2], (2, 8, 128, 64), jnp.float32)
    k = jax.random.normal(ks[3], (2, 2, 128, 64), jnp.float32)
    v = jax.random.normal(ks[4], (2, 2, 128, 64), jnp.float32)
    check("flash_attention", "gqa_causal_128",
          lambda: ops.flash_attention(q, k, v, causal=True,
                                      block_q=64, block_k=64),
          lambda: ref.attention(q, k, v, causal=True), 5e-5)

    xm = jax.random.normal(ks[5], (64, 128), jnp.float32)
    wg = jax.random.normal(ks[6], (128, 256), jnp.float32) * 0.05
    wu = jax.random.normal(ks[7], (128, 256), jnp.float32) * 0.05
    wd = jax.random.normal(ks[0], (256, 128), jnp.float32) * 0.05
    check("fused_mlp", "gated_silu",
          lambda: ops.fused_mlp(xm, wg, wu, wd, block_m=32, block_f=64),
          lambda: ref.mlp(xm, wg, wu, wd), 1e-3)

    xs = jax.random.normal(ks[1], (2, 64, 4, 16), jnp.float32)
    dt_ = jax.nn.softplus(jax.random.normal(ks[2], (2, 64, 4)))
    a = -jnp.exp(jax.random.normal(ks[3], (4,)) * 0.3)
    bm = jax.random.normal(ks[4], (2, 64, 8)) * 0.5
    cm = jax.random.normal(ks[5], (2, 64, 8)) * 0.5
    check("mamba2_ssd", "chunk16",
          lambda: ops.mamba2_ssd(xs, dt_, a, bm, cm, chunk=16),
          lambda: ref.ssd(xs, dt_, a, bm, cm), 5e-3)
    return ok


def dse_bench() -> bool:
    from repro.core import cnn_graphs
    from repro.core.dse import solve_ilp
    from repro.core.streaming import plan_streams

    _section("DSE micro-bench (lightweight-ILP claim)")
    print("kernel,solve_ms,explored,objective_cycles,feasible")
    for name, make in cnn_graphs.PAPER_SUITE.items():
        plan = plan_streams(make())
        t0 = time.perf_counter()
        res = solve_ilp(plan)
        dt = (time.perf_counter() - t0) * 1e3
        print(f"{name},{dt:.1f},{res.explored},{res.objective_cycles},"
              f"{res.feasible}")
    return True


def roofline_summary() -> bool:
    import os

    from benchmarks import roofline

    for label, out in (("BASELINE (paper-faithful)", "runs/dryrun"),
                       ("OPTIMIZED (beyond-paper)", "runs/dryrun_opt")):
        _section(f"Roofline summary — {label} ({out})")
        if not os.path.isdir(out):
            print(f"# {out} not present — run the dry-run sweep first")
            continue
        roofline.table(out, mesh="single")
        print()
        roofline.table(out, mesh="multi")
    print()
    _section("Hillclimb cell selection (from baseline)")
    if os.path.isdir("runs/dryrun"):
        roofline.pick_hillclimb_cells("runs/dryrun")
    return True


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--skip-kernels", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="fast model-only sanity pass (CI gate)")
    args = ap.parse_args(argv)
    ok = True
    ok &= paper_tables()
    ok &= passes_section()
    if not (args.skip_kernels or args.smoke):
        ok &= kernel_validation()
    ok &= dse_bench()
    ok &= bench_smoke_json()
    if not args.smoke:
        ok &= roofline_summary()
    _section(f"RESULT: {'PASS' if ok else 'FAIL'}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
