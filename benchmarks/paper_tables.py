"""Paper-table harnesses (one per Table/Figure in MING's evaluation).

Four execution models reproduce the paper's comparison frameworks on our
calibrated static resource model (repro.core.resource_model):

  vanilla    — Vitis auto baseline: materialized tensors, no unroll.
  scalehls   — graph pipelining only: II=2 (WAR hazards, Sec. V), no
               unroll, arguments passed between nodes (no explicit BRAM
               for intermediates — HLS maps them to LUT/FF, unmodeled).
  streamhls  — dataflow + DSP-aware unroll DSE, materialized
               intermediates + reorder copies, II=2 (WAR), BRAM-blind.
  ming       — the reproduction: streaming + line buffers + Eq.(1) ILP.

Each table prints ours next to the paper's published numbers (where the
paper reports that cell) so calibration drift is visible.
"""
from __future__ import annotations

import dataclasses
import math
import os
from dataclasses import dataclass

from repro import api
from repro.core import cnn_graphs
from repro.core.compile_driver import KV260, TARGETS, compile_design
from repro.core.dse import DseResult, solve_ilp, solve_materialized
from repro.core.resource_model import (
    ExecMode,
    FpgaResourceModel,
    KV260_BRAM18K,
    KV260_DSP,
)
from repro.core.streaming import plan_streams


@dataclass
class Row:
    kernel: str
    mode: str
    mcycles: float
    bram: int
    dsp: int
    speedup: float
    e_dsp: float
    feasible: bool
    groups: int = 1
    spill_bytes: int = 0


@dataclass(frozen=True)
class ModeResult:
    """(cycles, bram, dsp, feasible) plus partition detail for ``ming``.

    Indexes/iterates like the historical 4-tuple so downstream
    consumers (tests) keep working positionally."""

    cycles: float
    bram: int
    dsp: int
    feasible: bool
    groups: int = 1
    spill_bytes: int = 0

    def _tuple(self):
        return (self.cycles, self.bram, self.dsp, self.feasible)

    def __getitem__(self, i):
        return self._tuple()[i]

    def __iter__(self):
        return iter(self._tuple())


#: process-level memo for suite compiles: table2, the multi-target
#: sweep, and benchmarks/run.bench_smoke_json all read the same
#: deterministic artifacts — one balanced-DP run per (graph, target)
#: instead of one per reporting section.
_ARTIFACT_CACHE: dict[tuple[str, str], api.CompiledArtifact] = {}


def compile_cached(name: str, make, target=KV260) -> api.CompiledArtifact:
    """``compile_graph(make(), target)`` as a :class:`CompiledArtifact`,
    memoized on ``(suite key, CompileOptions.cache_key())`` — the same
    digest the serving runtime's artifact LRU uses, so an options change
    (not just a target rename) invalidates the entry.

    With ``REPRO_BENCH_CACHE=<dir>`` set, artifacts additionally persist
    to disk via ``CompiledArtifact.save``/``load`` so repeated benchmark
    processes skip the balanced-DP solves entirely.  Opt-in only: a
    stale cache would mask cost-model changes, so CI never sets it."""
    options = api.CompileOptions(target=target)
    key = (name, options.cache_key())
    art = _ARTIFACT_CACHE.get(key)
    if art is None:
        cache_dir = os.environ.get("REPRO_BENCH_CACHE")
        path = (
            os.path.join(cache_dir,
                         f"{name}.{options.cache_key()}.artifact")
            if cache_dir else None
        )
        if path and os.path.exists(path):
            art = api.CompiledArtifact.load(path)
        else:
            art = api.compile_graph(make(), options)
            if path:
                art.save(path)
        _ARTIFACT_CACHE[key] = art
    return art


def _modes_for(dfg, artifact: api.CompiledArtifact | None = None) -> dict[str, ModeResult]:
    """Per-mode :class:`ModeResult`.

    The ``ming`` mode is the unified compile driver
    (``repro.core.compile_driver.compile_design``): pass rewrites, then
    whole-graph DSE with cycle-balanced layer-group partitioning (and
    single-node weight-streaming rescue) when over budget.  BRAM/DSP are
    peak *resident* figures (one group on the fabric at a time), cycles
    the sequential group schedule including DRAM spill traffic; group
    count and spill bytes are reported instead of silently collapsing a
    partitioned design into whole-graph numbers.
    """
    plan = plan_streams(dfg)
    model = FpgaResourceModel()

    vanilla = model.estimate(plan, ExecMode.VANILLA, {})
    scale = model.estimate(plan, ExecMode.MATERIALIZED_DATAFLOW, {})
    stream_dse = solve_materialized(plan, b_total=KV260_BRAM18K)
    if artifact is None:
        artifact = api.CompiledArtifact(compile_design(dfg))
    design = artifact.design

    return {
        "vanilla": ModeResult(
            vanilla.cycles, vanilla.bram, max(vanilla.dsp, 1), True
        ),
        "scalehls": ModeResult(
            scale.pipeline_cycles,
            # ScaleHLS passes intermediates as function args (LUT/FF):
            # charge only the weight/constant buffers
            sum(model.node_bram_streaming(n, 1, 1) for n in plan.node_order()),
            scale.dsp,
            True,
        ),
        "streamhls": ModeResult(
            stream_dse.estimate.pipeline_cycles,
            stream_dse.estimate.bram,
            stream_dse.estimate.dsp,
            stream_dse.estimate.bram <= KV260_BRAM18K
            and stream_dse.estimate.dsp <= KV260_DSP,
        ),
        "ming": ModeResult(
            design.total_cycles,
            design.max_bram,
            design.max_dsp,
            design.feasible,
            groups=len(design.groups),
            spill_bytes=sum(s.bytes for s in design.spills()),
        ),
    }


#: paper Table II (published values) for calibration display:
#: kernel → {mode: (MCycles|speedup, BRAM, DSP)}
PAPER_TABLE2 = {
    "conv_relu_32": {"vanilla": (0.53, 19, 5), "ming_speedup": 504,
                     "ming_bram": 16, "ming_dsp": 246,
                     "streamhls_speedup": 1.84, "streamhls_bram": 51},
    "conv_relu_224": {"vanilla": (29.2, 707, 8), "ming_speedup": 582,
                      "ming_bram": 16, "ming_dsp": 246,
                      "streamhls_speedup": 2.06, "streamhls_bram": 2016},
    "cascade_conv_32": {"vanilla": (1.45, 52, 10), "ming_speedup": 44.6,
                        "ming_bram": 32, "ming_dsp": 183,
                        "streamhls_speedup": 2.95, "streamhls_bram": 116},
    "cascade_conv_224": {"vanilla": (86.1, 2280, 18), "ming_speedup": 48.6,
                         "ming_bram": 32, "ming_dsp": 183,
                         "streamhls_speedup": 4.06, "streamhls_bram": 6664},
    "residual_block_32": {"vanilla": (1.56, 89, 19), "ming_speedup": 57.8,
                          "ming_bram": 48, "ming_dsp": 259,
                          "streamhls_speedup": 2.02, "streamhls_bram": 162},
    "residual_block_224": {"vanilla": (88.6, 3947, 35), "ming_speedup": 53.7,
                           "ming_bram": 48, "ming_dsp": 259,
                           "streamhls_speedup": 2.9, "streamhls_bram": 6152},
    "linear": {"vanilla": (17.0, 265, 5), "ming_speedup": 125,
               "ming_bram": 64, "ming_dsp": 256,
               "streamhls_speedup": 32319, "streamhls_bram": 6144},
    "feed_forward": {"vanilla": (33.9, 463, 10), "ming_speedup": 249,
                     "ming_bram": 96, "ming_dsp": 192,
                     "streamhls_speedup": None, "streamhls_bram": None},
}


def table2(emit=print) -> list[Row]:
    """Paper Table II: cycles/BRAM/DSP/speedup/E_DSP per kernel × mode,
    plus partitioning detail (group count, spill bytes) for ``ming``."""
    rows: list[Row] = []
    emit("# Table II — kernels × frameworks (ours | paper where published)")
    emit("kernel,mode,MCycles,BRAM,DSP,speedup,E_DSP,feasible,"
         "groups,spill_KiB,paper_speedup,paper_bram")
    for name, make in cnn_graphs.PAPER_SUITE.items():
        modes = _modes_for(make(), artifact=compile_cached(name, make))
        v_cyc, v_bram, v_dsp, _ = modes["vanilla"]
        paper = PAPER_TABLE2.get(name, {})
        for mode, r in modes.items():
            cyc, bram, dsp, feas = r
            speedup = v_cyc / max(cyc, 1)
            e_dsp = speedup / max(dsp / max(v_dsp, 1), 1e-9)
            rows.append(Row(name, mode, cyc / 1e6, bram, dsp, speedup, e_dsp,
                            feas, groups=r.groups, spill_bytes=r.spill_bytes))
            p_speed = paper.get(f"{mode}_speedup", "")
            p_bram = paper.get(f"{mode}_bram", "")
            if mode == "vanilla" and "vanilla" in paper:
                p_speed, p_bram = 1.0, paper["vanilla"][1]
            emit(
                f"{name},{mode},{cyc/1e6:.4f},{bram},{dsp},"
                f"{speedup:.1f},{e_dsp:.2f},{feas},"
                f"{r.groups},{r.spill_bytes / 1024:.1f},{p_speed},{p_bram}"
            )
    return rows


def fig3(emit=print, sizes=(32, 64, 96, 128, 160, 192, 224)) -> dict:
    """Fig. 3: single-layer BRAM vs input size, materialized vs streaming."""
    out = {"sizes": list(sizes), "materialized": [], "streaming": []}
    emit("# Fig. 3 — single-layer Conv+ReLU BRAM utilization vs input size")
    emit("input_size,materialized_bram,ming_bram")
    for n in sizes:
        plan = plan_streams(cnn_graphs.conv_relu(n))
        mat = solve_materialized(plan)
        ming = solve_ilp(plan)
        out["materialized"].append(mat.estimate.bram)
        out["streaming"].append(ming.bram_used)
        emit(f"{n},{mat.estimate.bram},{ming.bram_used}")
    return out


#: paper Table IV published rows: DSP budget → (speedup, DSP, E_DSP)
PAPER_TABLE4 = {1248: (504, 246, 10.24), 250: (19.1, 76, 2.25),
                50: (3.54, 21, 0.84)}


def table4(emit=print, budgets=(1248, 250, 50)) -> list[dict]:
    """Table IV: DSP budget sweep on single-layer 32×32."""
    plan = plan_streams(cnn_graphs.conv_relu(32))
    model = FpgaResourceModel()
    vanilla = model.estimate(plan, ExecMode.VANILLA, {})
    rows = []
    emit("# Table IV — DSP budget vs speedup (single-layer 32×32)")
    emit("dsp_budget,speedup,dsp_used,E_DSP,feasible,"
         "paper_speedup,paper_dsp,paper_edsp")
    for b in budgets:
        res = solve_ilp(plan, d_total=b)
        speed = vanilla.cycles / max(res.estimate.pipeline_cycles, 1)
        e_dsp = speed / max(res.dsp_used / max(vanilla.dsp, 1), 1e-9)
        p = PAPER_TABLE4.get(b, ("", "", ""))
        rows.append({"budget": b, "speedup": speed, "dsp": res.dsp_used,
                     "e_dsp": e_dsp, "feasible": res.feasible})
        emit(f"{b},{speed:.1f},{res.dsp_used},{e_dsp:.2f},{res.feasible},"
             f"{p[0]},{p[1]},{p[2]}")
    return rows


def sweep_suite():
    """PAPER_SUITE plus the fusion / weight-streaming showcases — the
    graphs the multi-target sweep and BENCH_smoke.json report per
    device.  One registry for the CLI, the benchmarks, and the tests:
    ``repro.api.suite()``."""
    return api.suite()


def table_targets(emit=print, targets=("kv260", "zu3eg")) -> list[dict]:
    """Multi-target sweep (beyond-paper): how the same graph maps onto
    different edge budgets.  The KV260 (BRAM-poor, DSP-rich) partitions
    or streams weights where the ZU3EG (BRAM-rich, DSP-poor) fits whole
    but unrolls ~3.5× narrower — cuts, streamed nodes, cycles and peak
    BRAM/DSP per part, per graph."""
    rows: list[dict] = []
    emit("# Multi-target sweep — cuts / streamed weights / cycles per part")
    emit("kernel,target,groups,streamed_nodes,max_group_Mcycles,"
         "total_Mcycles,spill_KiB,peak_bram,peak_dsp,feasible")
    for name, make in sweep_suite().items():
        for tname in targets:
            rep = compile_cached(name, make, TARGETS[tname]).report()
            row = {
                "kernel": name,
                "target": tname,
                "groups": len(rep.groups),
                "streamed_nodes": sum(
                    len(g.weight_streamed) for g in rep.groups
                ),
                "max_group_cycles": rep.max_group_cycles,
                "total_cycles": rep.total_cycles,
                "spill_bytes": rep.spill_bytes,
                "bram": rep.max_bram,
                "dsp": rep.max_dsp,
                "feasible": rep.feasible,
            }
            rows.append(row)
            emit(
                f"{name},{tname},{row['groups']},{row['streamed_nodes']},"
                f"{row['max_group_cycles']/1e6:.4f},"
                f"{row['total_cycles']/1e6:.4f},"
                f"{row['spill_bytes']/1024:.1f},{row['bram']},{row['dsp']},"
                f"{row['feasible']}"
            )
    return rows


def run_all(emit=print):
    table2(emit)
    emit("")
    fig3(emit)
    emit("")
    table4(emit)
    emit("")
    table_targets(emit)


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--targets", action="store_true",
                    help="only the multi-target sweep")
    args = ap.parse_args()
    table_targets() if args.targets else run_all()
