"""End-to-end training driver: a ~100M-param llama-family model trained
for a few hundred steps on CPU, with checkpointing, an injected mid-run
crash (auto-restart), and loss-curve verification.

This is the (b) "end-to-end driver" deliverable at the scale this
container can actually execute; the same ``repro.launch.train`` driver
runs the full configs on a TPU fleet (dry-run-validated).

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import tempfile

from repro.configs.base import count_params
from repro.configs.registry import get_config
from repro.launch.train import train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    args = ap.parse_args()

    # ~100M params: llama3.2-1b family, narrowed
    cfg = get_config("llama3.2-1b").with_(
        num_layers=4, d_model=512, num_heads=8, num_kv_heads=4, d_ff=1536,
        vocab_size=32768, attn_block_q=128, attn_block_k=128, loss_chunk=128,
        dtype="float32",
    )
    n = count_params(cfg)
    print(f"model: {n/1e6:.1f}M params "
          f"({cfg.num_layers}L d={cfg.d_model} ff={cfg.d_ff} v={cfg.vocab_size})")

    import repro.configs.registry as registry

    # register the custom config under a temp name for the CLI driver
    registry.ARCHS["_example100m"] = "llama3_2_1b"
    import repro.configs.llama3_2_1b as mod

    orig = mod.CONFIG
    mod.CONFIG = cfg
    try:
        with tempfile.TemporaryDirectory() as ckpt:
            out = train(
                arch="_example100m", smoke=False, steps=args.steps,
                batch=args.batch, seq=args.seq, ckpt_dir=ckpt,
                ckpt_every=50, lr=6e-4, fail_at=(args.steps // 2,),
                log_every=20,
            )
    finally:
        mod.CONFIG = orig
        registry.ARCHS.pop("_example100m")

    losses = out["losses"]
    first = sum(losses[:10]) / 10
    last = sum(losses[-10:]) / 10
    print(f"\nfirst-10 mean loss {first:.4f} -> last-10 mean loss {last:.4f}")
    print(f"survived injected crash at step {args.steps // 2}; "
          f"median step {out['median_step_s']*1e3:.0f} ms; "
          f"stragglers flagged: {len(out['straggler_flags'])}")
    assert last < first - 0.3, "model failed to learn"
    print("OK — loss decreased through a mid-run crash + restart")


if __name__ == "__main__":
    main()
