"""Quickstart: the whole MING pipeline on one CNN kernel, end to end.

  1. Build the paper's Conv+ReLU kernel as a linalg-style DFG.
  2. Classify every node (Alg. 1 + 2): sliding-window vs pure-parallel.
  3. Streaming transform: streams + line buffers (never materialize the
     intermediate tensor — contribution C1).
  4. ILP DSE under the Kria KV260 budgets (Eq. 1).
  5. Emit Vitis-style HLS C++ with the five pragma families.
  6. TPU path: run the line-buffer streaming conv as a Pallas kernel
     (interpret mode on CPU) and check it against the oracle.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    KV260_BRAM18K,
    KV260_DSP,
    classify_kernel,
    cnn_graphs,
    plan_streams,
    solve_ilp,
    solve_materialized,
)
from repro.core.emit_hls import emit_cpp
from repro.kernels import ops, ref


def main() -> None:
    # 1-2. build + classify ---------------------------------------------------
    dfg = cnn_graphs.conv_relu(32)
    print(f"DFG {dfg.name!r}: {len(dfg.nodes)} nodes, "
          f"{len(dfg.intermediate_values())} intermediate tensor(s)")
    for node in dfg.topo_order():
        info = classify_kernel(node)
        extra = (f" stride={info.stride} dilation={info.dilation}"
                 if info.kernel_class.value == "sliding_window" else "")
        print(f"  {node.name:8s} -> {info.kernel_class.value}{extra}")

    # 3. streaming transform ---------------------------------------------------
    plan = plan_streams(dfg)
    conv = plan.nodes["conv0"]
    print(f"\nstreaming plan: line buffer {conv.line_buffer_bits // 8} B "
          f"(vs {dfg.values['conv0_out'].total_bits // 8} B materialized), "
          f"{len(plan.streams)} streams, {len(plan.regions)} DATAFLOW region")

    # 4. DSE --------------------------------------------------------------------
    ming = solve_ilp(plan, d_total=KV260_DSP, b_total=KV260_BRAM18K)
    mat = solve_materialized(plan)
    speed = mat.estimate.pipeline_cycles / ming.estimate.pipeline_cycles
    print(f"\nDSE (KV260: {KV260_DSP} DSP, {KV260_BRAM18K} BRAM18K):")
    print(f"  MING      : {ming.estimate.pipeline_cycles:>9} cycles, "
          f"{ming.bram_used:>4} BRAM, {ming.dsp_used:>4} DSP "
          f"(explored {ming.explored} states)")
    print(f"  StreamHLS-like: {mat.estimate.pipeline_cycles:>9} cycles, "
          f"{mat.estimate.bram:>4} BRAM, {mat.estimate.dsp:>4} DSP")
    print(f"  -> {speed:.1f}x faster with "
          f"{mat.estimate.bram / max(ming.bram_used, 1):.1f}x less BRAM")

    # 5. HLS emission -------------------------------------------------------------
    cpp = emit_cpp(plan, ming)
    print(f"\nemitted {len(cpp.splitlines())} lines of Vitis HLS C++; head:")
    print("\n".join("  | " + l for l in cpp.splitlines()[:16]))

    # 6. TPU Pallas path ------------------------------------------------------------
    key = jax.random.key(0)
    x = jax.random.randint(key, (1, 32, 32, 3), -8, 8, jnp.int8)
    w = jax.random.randint(jax.random.key(1), (3, 3, 3, 16), -4, 4, jnp.int8)
    out = ops.conv2d_stream(x, w, fuse_relu=True)      # line-buffer kernel
    exp = ref.conv2d(x, w, fuse_relu=True)             # oracle
    np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))
    print(f"\nPallas line-buffer conv (interpret): {out.shape} int32 — "
          "matches oracle exactly")


if __name__ == "__main__":
    main()
