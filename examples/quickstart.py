"""Quickstart: the public API end to end — build → compile → report →
emit → run.

One front door (``repro.api``, re-exported at the package top level):

  1. Declare a CNN with the layer-builder frontend (``Sequential`` /
     ``Conv2D`` / ``ReLU`` / ``MaxPool`` …) — shapes are inferred and
     validated, no hand-assembled ``Value``/GenericOp bookkeeping.
  2. Compile it under one validated ``CompileOptions`` bundle (device
     preset, partition strategy, pass selection, weight-streaming
     policy) — pass pipeline → streaming transform → ILP DSE →
     cycle-balanced layer groups, all behind ``compile_graph``.
  3. Read the ``CompiledArtifact.report()`` table
     (cycles / BRAM / DSP / spills per group).
  4. ``emit_hls`` the Vitis-style C++ kernels + host schedule.
  5. ``run`` the same schedule on the Pallas path (interpret mode on
     CPU) and check it against the dense oracle.
  6. ``save``/``load`` the artifact — the benchmark-cache hook.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os
import tempfile

import numpy as np

import repro


def main() -> None:
    # 1. build ---------------------------------------------------------------
    net = repro.Sequential(
        [
            repro.Conv2D(16),
            repro.ReLU(),
            repro.Residual([repro.Conv2D(16), repro.ReLU(), repro.Conv2D(16)]),
            repro.ReLU(),
            repro.AvgPool(2),
        ],
        input_shape=(1, 32, 32, 16),
        name="quickstart_net",
    )
    dfg = net.build()
    print(f"built {dfg.name!r}: {len(dfg.nodes)} nodes, "
          f"{len(dfg.intermediate_values())} intermediate tensor(s)")

    # 2. compile -------------------------------------------------------------
    options = repro.CompileOptions(target="kv260", strategy="balanced")
    art = repro.compile_graph(net, options)

    # 3. report --------------------------------------------------------------
    print("\nreport:")
    print(art.report())

    # 4. emit HLS ------------------------------------------------------------
    outdir = tempfile.mkdtemp(prefix="quickstart_hls_")
    for path in art.emit_hls(outdir):
        print(f"emitted {path} ({os.path.getsize(path)} bytes)")

    # 5. run (Pallas interpret) + oracle check -------------------------------
    from repro.passes import interp

    env = interp.random_env(art.design.original, seed=0)
    want = interp.graph_outputs(art.design.original, env)
    got = art.run({"x": env["x"]}, params=env, interpret=True, seed=0)
    (want_arr,) = want.values()
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want_arr))
    print(f"\nran OK: output {tuple(got.shape)} {got.dtype} — "
          "bit-exact with the DFG interpreter")

    # 6. save / load ---------------------------------------------------------
    saved = art.save(os.path.join(outdir, "quickstart.artifact"))
    again = repro.CompiledArtifact.load(saved)
    assert again.report() == art.report()
    print(f"saved + reloaded {saved} — identical report")


if __name__ == "__main__":
    main()
