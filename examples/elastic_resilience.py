"""Large-scale-runnability demo on one host:

  1. train a small model on a (2,2)-device mesh with async checkpoints,
  2. kill it mid-run (injected node failure) — auto-restart resumes,
  3. *elastically re-mesh*: restore the same checkpoint onto a (4,)-mesh
     (pure-DP) and a (1,1) single device, continuing training each time,
  4. show the straggler watchdog flagging a slowed step.

Run:  XLA_FLAGS=--xla_force_host_platform_device_count=4 \\
      PYTHONPATH=src python examples/elastic_resilience.py
"""
import os

if "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=4"
    )

import tempfile
import time

import jax

from repro.checkpoint.manager import CheckpointManager
from repro.configs.registry import get_config
from repro.launch.mesh import make_host_mesh, single_device_mesh
from repro.launch.train import train
from repro.runtime.resilience import StragglerWatchdog


def main() -> None:
    assert len(jax.devices()) == 4, jax.devices()
    common = dict(arch="qwen2-0.5b", smoke=True, batch=4, seq=64, lr=1e-3,
                  ckpt_every=10, log_every=10, seed=0)

    with tempfile.TemporaryDirectory() as ckpt:
        # 1+2: mesh (2,2), crash at step 15, auto-restart
        print("== phase 1: (data=2, model=2) mesh, crash injected at 15 ==")
        out1 = train(steps=30, ckpt_dir=ckpt, fail_at=(15,),
                     mesh=make_host_mesh((2, 2), ("data", "model")), **common)
        assert out1["final_step"] == 30

        # 3a: elastic re-mesh to pure-DP (4,1)
        print("== phase 2: SAME checkpoint restored on a (data=4) mesh ==")
        out2 = train(steps=45, ckpt_dir=ckpt,
                     mesh=make_host_mesh((4, 1), ("data", "model")), **common)
        assert out2["final_step"] == 45
        assert len(out2["losses"]) == 15, "must resume at 30, not restart"

        # 3b: down to a single device
        print("== phase 3: same checkpoint on a single device ==")
        out3 = train(steps=50, ckpt_dir=ckpt, mesh=single_device_mesh(),
                     **common)
        assert out3["final_step"] == 50

    # 4: watchdog demo
    wd = StragglerWatchdog(window=16, threshold=2.5)
    for i in range(12):
        wd.start(); time.sleep(0.003); wd.stop(i)
    wd.start(); time.sleep(0.05); wd.stop(12)     # the straggler
    print(f"watchdog flagged steps: {[s for s, _ in wd.flagged]} "
          f"(median {wd.median*1e3:.1f} ms)")
    assert wd.flagged, "straggler not flagged"
    print("OK — crash-restart, 2 elastic re-meshes, straggler detection")


if __name__ == "__main__":
    main()
