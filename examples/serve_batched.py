"""Batched serving example on the artifact engine (ISSUE 7).

Compile a zoo classifier through the serving artifact cache, stand up a
dynamic-batching :class:`repro.serve.ServeEngine` over it, push an
open-loop burst of requests, and show the observability contract: the
batch coalescing, p50/p99 latency, and the serve counters landing in
the same Chrome trace as the compile spans.

Run:  PYTHONPATH=src python examples/serve_batched.py
"""
import numpy as np

from repro.core.compile_driver import CompileOptions
from repro.frontends import zoo
from repro.instrument import Tracer, use_tracer, validate_chrome_trace
from repro.serve import ArtifactCache, ServeConfig, ServeEngine, run_load


def main() -> None:
    tracer = Tracer()
    with use_tracer(tracer):
        # artifact LRU keyed (model, CompileOptions.cache_key()) — the
        # second lookup is a hit, no second balanced-DP solve
        cache = ArtifactCache(capacity=4)
        options = CompileOptions(target="kv260")
        art = cache.get_or_compile("lenet5", zoo.ZOO["lenet5"], options)
        assert cache.get_or_compile("lenet5", zoo.ZOO["lenet5"],
                                    options) is art
        print(f"artifact cache: {cache.stats}")

        src = art.source
        name = src.graph_inputs[0]
        rng = np.random.default_rng(0)

        cfg = ServeConfig(max_batch=16, latency_budget_ms=5.0)
        with ServeEngine(art, cfg) as engine:
            # single blocking request (warms the bucket-1 executable)
            x = rng.integers(-4, 5, src.values[name].shape, dtype=np.int32)
            y = engine(x)
            print(f"single request → logits {y.shape}")

            # a concurrent burst coalesces into vmapped batches
            futs = [
                engine.submit(
                    rng.integers(-4, 5, src.values[name].shape,
                                 dtype=np.int32)
                )
                for _ in range(32)
            ]
            outs = [f.result() for f in futs]
            print(f"burst of 32 → {engine.stats['batches']} batches "
                  f"(max batch seen {engine.stats['max_batch_seen']})")
            assert all(o.shape == outs[0].shape for o in outs)

            # open-loop load level: offered vs achieved QPS, p50/p99
            rep = run_load(engine, offered_qps=200, requests=100, seed=1)
            print(f"offered {rep.offered_qps:.0f} qps → achieved "
                  f"{rep.achieved_qps:.0f} qps, p50 {rep.p50_ms:.1f} ms, "
                  f"p99 {rep.p99_ms:.1f} ms, mean batch {rep.mean_batch:.1f}")

    # one trace, one tracer: compile spans (had we traced the compile),
    # vmapped run:<group> spans, and the serve counter series together
    obj = tracer.to_chrome()
    validate_chrome_trace(obj)
    serve_events = sorted({
        e["name"] for e in obj["traceEvents"]
        if e["name"].startswith(("serve", "artifact"))
    })
    print(f"chrome trace OK: {len(obj['traceEvents'])} events, "
          f"serve series {serve_events}")


if __name__ == "__main__":
    main()
