"""Batched serving example: prefill a wave of prompts, decode lock-step,
report tokens/s — then demonstrate the decode-cache contract by checking
the engine's greedy tokens against teacher-forced full forwards.

Run:  PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.launch.serve import ServeEngine
from repro.models import lm


def main() -> None:
    cfg = get_config("llama3.2-1b", smoke=True).with_(remat=False)
    engine = ServeEngine(cfg, max_len=160, seed=0)
    rng = np.random.default_rng(0)

    # wave 1: warmup/compile
    prompts = rng.integers(0, cfg.vocab_size, (8, 64), dtype=np.int32)
    engine.generate(prompts, max_new=8)

    # wave 2: measured
    out, stats = engine.generate(prompts, max_new=64)
    print(f"batch=8 prompt=64 new=64: prefill {stats.prefill_s*1e3:.0f} ms, "
          f"decode {stats.decode_s*1e3:.0f} ms, "
          f"{stats.tokens_per_s:.0f} tok/s (CPU)")

    # correctness: engine greedy == teacher-forced argmax
    small = rng.integers(0, cfg.vocab_size, (2, 16), dtype=np.int32)
    got, _ = engine.generate(small, max_new=4)
    seq = small.copy()
    for t in range(4):
        logits, _ = lm.lm_prefill(engine.params, cfg,
                                  {"tokens": jnp.asarray(seq)})
        nxt = np.asarray(jnp.argmax(logits, -1)).astype(np.int32)
        assert np.array_equal(nxt, got[:, t]), f"divergence at step {t}"
        seq = np.concatenate([seq, nxt[:, None]], axis=1)
    print("decode-cache contract verified: engine tokens == teacher-forced "
          "argmax for 4 steps")

    # temperature sampling determinism under a seed
    s1, _ = engine.generate(small, max_new=8, temperature=0.8, seed=42)
    s2, _ = engine.generate(small, max_new=8, temperature=0.8, seed=42)
    assert np.array_equal(s1, s2)
    print("seeded sampling is reproducible")


if __name__ == "__main__":
    main()
