"""ONNX importer (ISSUE 5): the vendored wire decoder against real
bytes, the checked-in LeNet-5 golden fixture end to end (import →
compile → emit → run, bit-exact with an independent NumPy NCHW oracle
on both device presets), and the unsupported-feature error paths.
"""
import os

import numpy as np
import pytest

import _onnx_fixture as fx
from repro.frontends import OnnxImportError, import_model, load_onnx
from repro.frontends.onnx_reader import decode_wire

GOLDEN = os.path.join(os.path.dirname(__file__), "golden", "lenet5.onnx")


class TestWireDecoder:
    def test_decodes_fixture_structure(self):
        og = decode_wire(fx.lenet5_model_bytes())
        assert og.name == "lenet5"
        assert [n.op_type for n in og.nodes] == [
            "Conv", "Relu", "MaxPool", "Conv", "Relu", "MaxPool",
            "Flatten", "Gemm", "Relu", "Gemm", "Relu", "Gemm",
        ]
        assert og.inputs == [("input", (1, 1, 32, 32))]
        assert og.outputs == ["logits"]
        w = fx.lenet5_weights(0)
        assert set(og.initializers) == set(w)
        for k in w:
            np.testing.assert_array_equal(og.initializers[k], w[k])
            assert og.initializers[k].dtype == w[k].dtype

    def test_attributes_decode(self):
        og = decode_wire(fx.lenet5_model_bytes())
        conv = og.nodes[0]
        assert conv.attrs["kernel_shape"] == [5, 5]
        assert conv.attrs["pads"] == [2, 2, 2, 2]
        gemm = og.nodes[7]
        assert gemm.attrs["transB"] == 1
        assert gemm.attrs["alpha"] == pytest.approx(1.0)

    def test_symbolic_output_dims_are_ignored(self):
        """Graph *outputs* only need names — a symbolic output shape
        (shape-inferred dynamic dim) must not fail the wire decoder
        when the onnx-package path would accept it."""
        g = fx.graph(
            "symout",
            [fx.node("Relu", ["x"], ["y"], "r")],
            [],
            [fx.value_info("x", (1, 8))],
            [fx.value_info("y", (), symbolic="N")],
        )
        m = load_onnx(fx.model(g))
        assert m.dfg.graph_outputs  # imported fine

    def test_symbolic_dims_rejected(self):
        g = fx.graph(
            "sym",
            [fx.node("Relu", ["x"], ["y"], "r")],
            [],
            [fx.value_info("x", (), symbolic="batch")],
            [fx.value_info("y", (1,))],
        )
        with pytest.raises(OnnxImportError, match="symbolic"):
            load_onnx(fx.model(g))

    def test_garbage_bytes_rejected(self):
        with pytest.raises(OnnxImportError):
            load_onnx(b"\xff\xff\xff\xff not a protobuf")


class TestLeNetGolden:
    """The checked-in fixture: regenerate with
    ``python tests/_onnx_fixture.py``."""

    def test_golden_bytes_are_the_seeded_fixture(self):
        with open(GOLDEN, "rb") as f:
            assert f.read() == fx.lenet5_model_bytes(seed=0)

    def test_import_shape_and_params(self):
        m = load_onnx(GOLDEN)
        assert m.name == "lenet5"
        assert m.source == "onnx"
        assert m.missing_params() == []
        # OIHW -> HWIO weight relayout happened
        assert m.params["conv1_w"].shape == (5, 5, 1, 6)
        assert m.params["fc1_w"].shape == (1024, 120)
        # the imported graph keeps the ONNX NCHW contract at the boundary
        assert m.dfg.values[m.dfg.graph_inputs[0]].shape == (1, 1, 32, 32)
        assert m.dfg.values[m.dfg.graph_outputs[0]].shape == (1, 10)

    @pytest.mark.parametrize("target", ["kv260", "zu3eg"])
    def test_bit_exact_against_numpy_oracle(self, target):
        """Acceptance: imported model compiles (layout pass active) and
        runs bit-exact with an executor-independent NumPy oracle."""
        from repro import api

        m = load_onnx(GOLDEN)
        art = api.compile_graph(m.dfg, api.CompileOptions(target=target))
        assert art.feasible
        x = np.random.default_rng(7).integers(
            -4, 5, (1, 1, 32, 32)
        ).astype(np.int32)
        got = np.asarray(
            art.run({m.dfg.graph_inputs[0]: x}, params=m.params,
                    interpret=True)
        )
        want = fx.lenet5_numpy(x.astype(np.int64), fx.lenet5_weights(0))
        np.testing.assert_array_equal(got.astype(np.int64), want)

    def test_run_matches_dfg_interpreter(self):
        from repro import api
        from repro.passes import interp

        m = load_onnx(GOLDEN)
        art = api.compile_graph(m.dfg)
        env = dict(m.params)
        x = np.random.default_rng(3).integers(
            -4, 5, (1, 1, 32, 32)
        ).astype(np.int32)
        env[m.dfg.graph_inputs[0]] = x
        want = interp.graph_outputs(
            m.dfg, {k: np.asarray(v) for k, v in env.items()}
        )
        got = art.run({m.dfg.graph_inputs[0]: x}, params=m.params,
                      interpret=True)
        np.testing.assert_array_equal(
            np.asarray(want[m.dfg.graph_outputs[0]]), np.asarray(got)
        )

    def test_layout_pass_leaves_single_boundary_transpose(self):
        from repro import api
        from repro.core.analysis import reorder_spec

        m = load_onnx(GOLDEN)
        art = api.compile_graph(m.dfg)
        specs = [reorder_spec(n) for n in art.design.source.nodes]
        transposes = [s for s in specs if s and s[0] == "transpose"]
        flattens = [s for s in specs if s and s[0] == "flatten"]
        assert len(transposes) == 1  # the NCHW graph-input bridge
        assert len(flattens) == 1
        # the flatten absorbed the NHWC->NCHW head transpose: its
        # linearization order is channels-major over the NHWC tensor
        assert flattens[0][1] == (3, 1, 2)

    def test_emit_hls_end_to_end(self, tmp_path):
        from repro import api

        m = load_onnx(GOLDEN)
        art = api.compile_graph(m.dfg)
        paths = art.emit_hls(str(tmp_path))
        names = {os.path.basename(p) for p in paths}
        assert "host_schedule.cpp" in names
        assert any(n.startswith("lenet5_g") for n in names)

    def test_cli_compile_onnx_runs(self, capsys):
        from repro.__main__ import main as cli_main

        assert cli_main(["compile", GOLDEN, "--run", "--quiet"]) == 0
        assert "ran OK" in capsys.readouterr().out

    def test_batched_validation_on_imported_classifier(self):
        """ISSUE 5 satellite meets the tentpole: a small input batch
        through the imported classifier, one oracle check per sample."""
        from repro import api

        m = load_onnx(GOLDEN)
        art = api.compile_graph(m.dfg)
        rng = np.random.default_rng(11)
        xs = rng.integers(-4, 5, (3, 1, 1, 32, 32)).astype(np.int32)
        got = np.asarray(
            art.run({m.dfg.graph_inputs[0]: xs}, params=m.params,
                    interpret=True)
        )
        assert got.shape == (3, 1, 10)
        w = fx.lenet5_weights(0)
        for i in range(3):
            np.testing.assert_array_equal(
                got[i].astype(np.int64),
                fx.lenet5_numpy(xs[i].astype(np.int64), w),
            )


class TestUnsupportedFeatures:
    def _conv_model(self, **overrides):
        """A one-conv model with attribute overrides for error paths."""
        attrs = {
            "kernel_shape": fx.attr_ints("kernel_shape", [3, 3]),
            "strides": fx.attr_ints("strides", [1, 1]),
            "pads": fx.attr_ints("pads", [1, 1, 1, 1]),
        }
        attrs.update(overrides)
        w = np.zeros((4, 2, 3, 3), np.int8)
        g = fx.graph(
            "one_conv",
            [fx.node("Conv", ["x", "w"], ["y"], "conv",
                     tuple(a for a in attrs.values() if a is not None))],
            [fx.tensor("w", w)],
            [fx.value_info("x", (1, 2, 8, 8))],
            [fx.value_info("y", (1, 4, 8, 8))],
        )
        return fx.model(g)

    def test_unsupported_op_named(self):
        g = fx.graph(
            "soft",
            [fx.node("Softmax", ["x"], ["y"], "sm")],
            [],
            [fx.value_info("x", (1, 8))],
            [fx.value_info("y", (1, 8))],
        )
        with pytest.raises(OnnxImportError, match="Softmax"):
            load_onnx(fx.model(g))

    def test_strided_conv_rejected(self):
        data = self._conv_model(
            strides=fx.attr_ints("strides", [2, 2]))
        with pytest.raises(OnnxImportError, match="stride"):
            load_onnx(data)

    def test_valid_padding_conv_rejected(self):
        data = self._conv_model(pads=fx.attr_ints("pads", [0, 0, 0, 0]))
        with pytest.raises(OnnxImportError, match="SAME"):
            load_onnx(data)

    def test_even_kernel_conv_rejected(self):
        """Even-kernel SAME padding is asymmetric — silently mapping it
        onto the symmetric-SAME streaming conv would corrupt numerics."""
        w = np.zeros((4, 2, 4, 4), np.int8)
        g = fx.graph(
            "even_k",
            [fx.node("Conv", ["x", "w"], ["y"], "conv",
                     (fx.attr_ints("pads", [1, 1, 1, 1]),))],
            [fx.tensor("w", w)],
            [fx.value_info("x", (1, 2, 8, 8))],
            [fx.value_info("y", (1, 4, 8, 8))],
        )
        with pytest.raises(OnnxImportError, match="even kernel"):
            load_onnx(fx.model(g))

    def test_grouped_conv_rejected(self):
        data = self._conv_model(group=fx.attr_int("group", 2))
        with pytest.raises(OnnxImportError, match="group"):
            load_onnx(data)

    def test_dilated_conv_rejected(self):
        data = self._conv_model(
            dilations=fx.attr_ints("dilations", [2, 2]))
        with pytest.raises(OnnxImportError, match="dilation"):
            load_onnx(data)

    def test_flatten_axis_2_rejected(self):
        g = fx.graph(
            "flat2",
            [fx.node("Flatten", ["x"], ["y"], "f",
                     (fx.attr_int("axis", 2),))],
            [],
            [fx.value_info("x", (1, 2, 4, 4))],
            [fx.value_info("y", (2, 16))],
        )
        with pytest.raises(OnnxImportError, match="axis=1"):
            load_onnx(fx.model(g))

    def test_non_initializer_weight_rejected(self):
        w = np.zeros((4, 2, 3, 3), np.int8)
        g = fx.graph(
            "dyn_w",
            [fx.node("Conv", ["x", "wdyn"], ["y"], "conv",
                     (fx.attr_ints("pads", [1, 1, 1, 1]),))],
            [fx.tensor("unused", w)],
            [fx.value_info("x", (1, 2, 8, 8)),
             fx.value_info("wdyn", (4, 2, 3, 3))],
            [fx.value_info("y", (1, 4, 8, 8))],
        )
        with pytest.raises(OnnxImportError, match="initializer"):
            load_onnx(fx.model(g))


class TestSmallModels:
    def test_gemm_bias_and_add_paths(self):
        """Gemm with transB + bias, then Add with an initializer: both
        constant-binding paths, checked against numpy."""
        rng = np.random.default_rng(5)
        w = rng.integers(-3, 4, (6, 8)).astype(np.int8)     # (units, d_in)
        b = rng.integers(-3, 4, (6,)).astype(np.int32)
        k = rng.integers(-3, 4, (1, 6)).astype(np.int32)
        g = fx.graph(
            "mlp",
            [
                fx.node("Gemm", ["x", "w", "b"], ["h"], "gemm",
                        (fx.attr_int("transB", 1),)),
                fx.node("Add", ["h", "k"], ["y"], "bias2"),
            ],
            [fx.tensor("w", w), fx.tensor("b", b), fx.tensor("k", k)],
            [fx.value_info("x", (1, 8))],
            [fx.value_info("y", (1, 6))],
        )
        m = load_onnx(fx.model(g))
        from repro import api

        art = api.compile_graph(m.dfg)
        x = rng.integers(-3, 4, (1, 8)).astype(np.int32)
        got = np.asarray(art.run(x, params=m.params, interpret=True))
        want = x.astype(np.int64) @ w.T.astype(np.int64) + b + k
        np.testing.assert_array_equal(got.astype(np.int64), want)

    def test_avgpool_model(self):
        g = fx.graph(
            "ap",
            [fx.node("AveragePool", ["x"], ["y"], "pool",
                     (fx.attr_ints("kernel_shape", [2, 2]),
                      fx.attr_ints("strides", [2, 2])))],
            [],
            [fx.value_info("x", (1, 2, 4, 4))],
            [fx.value_info("y", (1, 2, 2, 2))],
        )
        m = load_onnx(fx.model(g))
        from repro import api

        art = api.compile_graph(m.dfg)
        x = np.arange(32, dtype=np.int32).reshape(1, 2, 4, 4)
        got = np.asarray(art.run(x, interpret=True))
        want = x.reshape(1, 2, 2, 2, 2, 2).sum(axis=(3, 5)) // 4
        np.testing.assert_array_equal(got, want)

    def test_import_model_dispatches_onnx(self):
        m = import_model(GOLDEN)
        assert m.source == "onnx"
