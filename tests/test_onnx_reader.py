"""ONNX importer (ISSUE 5): the vendored wire decoder against real
bytes, the checked-in LeNet-5 golden fixture end to end (import →
compile → emit → run, bit-exact with an independent NumPy NCHW oracle
on both device presets), and the unsupported-feature error paths.
"""
import os

import numpy as np
import pytest

import _onnx_fixture as fx
from repro.frontends import OnnxImportError, import_model, load_onnx
from repro.frontends.onnx_reader import decode_wire

GOLDEN = os.path.join(os.path.dirname(__file__), "golden", "lenet5.onnx")
GOLDEN2 = os.path.join(os.path.dirname(__file__), "golden",
                       "resnet_tiny.onnx")


class TestWireDecoder:
    def test_decodes_fixture_structure(self):
        og = decode_wire(fx.lenet5_model_bytes())
        assert og.name == "lenet5"
        assert [n.op_type for n in og.nodes] == [
            "Conv", "Relu", "MaxPool", "Conv", "Relu", "MaxPool",
            "Flatten", "Gemm", "Relu", "Gemm", "Relu", "Gemm",
        ]
        assert og.inputs == [("input", (1, 1, 32, 32))]
        assert og.outputs == ["logits"]
        w = fx.lenet5_weights(0)
        assert set(og.initializers) == set(w)
        for k in w:
            np.testing.assert_array_equal(og.initializers[k], w[k])
            assert og.initializers[k].dtype == w[k].dtype

    def test_attributes_decode(self):
        og = decode_wire(fx.lenet5_model_bytes())
        conv = og.nodes[0]
        assert conv.attrs["kernel_shape"] == [5, 5]
        assert conv.attrs["pads"] == [2, 2, 2, 2]
        gemm = og.nodes[7]
        assert gemm.attrs["transB"] == 1
        assert gemm.attrs["alpha"] == pytest.approx(1.0)

    def test_symbolic_output_dims_are_ignored(self):
        """Graph *outputs* only need names — a symbolic output shape
        (shape-inferred dynamic dim) must not fail the wire decoder
        when the onnx-package path would accept it."""
        g = fx.graph(
            "symout",
            [fx.node("Relu", ["x"], ["y"], "r")],
            [],
            [fx.value_info("x", (1, 8))],
            [fx.value_info("y", (), symbolic="N")],
        )
        m = load_onnx(fx.model(g))
        assert m.dfg.graph_outputs  # imported fine

    def test_symbolic_dims_rejected(self):
        g = fx.graph(
            "sym",
            [fx.node("Relu", ["x"], ["y"], "r")],
            [],
            [fx.value_info("x", (), symbolic="batch")],
            [fx.value_info("y", (1,))],
        )
        with pytest.raises(OnnxImportError, match="symbolic"):
            load_onnx(fx.model(g))

    def test_garbage_bytes_rejected(self):
        with pytest.raises(OnnxImportError):
            load_onnx(b"\xff\xff\xff\xff not a protobuf")


class TestLeNetGolden:
    """The checked-in fixture: regenerate with
    ``python tests/_onnx_fixture.py``."""

    def test_golden_bytes_are_the_seeded_fixture(self):
        with open(GOLDEN, "rb") as f:
            assert f.read() == fx.lenet5_model_bytes(seed=0)

    def test_import_shape_and_params(self):
        m = load_onnx(GOLDEN)
        assert m.name == "lenet5"
        assert m.source == "onnx"
        assert m.missing_params() == []
        # OIHW -> HWIO weight relayout happened
        assert m.params["conv1_w"].shape == (5, 5, 1, 6)
        assert m.params["fc1_w"].shape == (1024, 120)
        # the imported graph keeps the ONNX NCHW contract at the boundary
        assert m.dfg.values[m.dfg.graph_inputs[0]].shape == (1, 1, 32, 32)
        assert m.dfg.values[m.dfg.graph_outputs[0]].shape == (1, 10)

    @pytest.mark.parametrize("target", ["kv260", "zu3eg"])
    def test_bit_exact_against_numpy_oracle(self, target):
        """Acceptance: imported model compiles (layout pass active) and
        runs bit-exact with an executor-independent NumPy oracle."""
        from repro import api

        m = load_onnx(GOLDEN)
        art = api.compile_graph(m.dfg, api.CompileOptions(target=target))
        assert art.feasible
        x = np.random.default_rng(7).integers(
            -4, 5, (1, 1, 32, 32)
        ).astype(np.int32)
        got = np.asarray(
            art.run({m.dfg.graph_inputs[0]: x}, params=m.params,
                    interpret=True)
        )
        want = fx.lenet5_numpy(x.astype(np.int64), fx.lenet5_weights(0))
        np.testing.assert_array_equal(got.astype(np.int64), want)

    def test_run_matches_dfg_interpreter(self):
        from repro import api
        from repro.passes import interp

        m = load_onnx(GOLDEN)
        art = api.compile_graph(m.dfg)
        env = dict(m.params)
        x = np.random.default_rng(3).integers(
            -4, 5, (1, 1, 32, 32)
        ).astype(np.int32)
        env[m.dfg.graph_inputs[0]] = x
        want = interp.graph_outputs(
            m.dfg, {k: np.asarray(v) for k, v in env.items()}
        )
        got = art.run({m.dfg.graph_inputs[0]: x}, params=m.params,
                      interpret=True)
        np.testing.assert_array_equal(
            np.asarray(want[m.dfg.graph_outputs[0]]), np.asarray(got)
        )

    def test_layout_pass_leaves_single_boundary_transpose(self):
        from repro import api
        from repro.core.analysis import reorder_spec

        m = load_onnx(GOLDEN)
        art = api.compile_graph(m.dfg)
        specs = [reorder_spec(n) for n in art.design.source.nodes]
        transposes = [s for s in specs if s and s[0] == "transpose"]
        flattens = [s for s in specs if s and s[0] == "flatten"]
        assert len(transposes) == 1  # the NCHW graph-input bridge
        assert len(flattens) == 1
        # the flatten absorbed the NHWC->NCHW head transpose: its
        # linearization order is channels-major over the NHWC tensor
        assert flattens[0][1] == (3, 1, 2)

    def test_emit_hls_end_to_end(self, tmp_path):
        from repro import api

        m = load_onnx(GOLDEN)
        art = api.compile_graph(m.dfg)
        paths = art.emit_hls(str(tmp_path))
        names = {os.path.basename(p) for p in paths}
        assert "host_schedule.cpp" in names
        assert any(n.startswith("lenet5_g") for n in names)

    def test_cli_compile_onnx_runs(self, capsys):
        from repro.__main__ import main as cli_main

        assert cli_main(["compile", GOLDEN, "--run", "--quiet"]) == 0
        assert "ran OK" in capsys.readouterr().out

    def test_batched_validation_on_imported_classifier(self):
        """ISSUE 5 satellite meets the tentpole: a small input batch
        through the imported classifier, one oracle check per sample."""
        from repro import api

        m = load_onnx(GOLDEN)
        art = api.compile_graph(m.dfg)
        rng = np.random.default_rng(11)
        xs = rng.integers(-4, 5, (3, 1, 1, 32, 32)).astype(np.int32)
        got = np.asarray(
            art.run({m.dfg.graph_inputs[0]: xs}, params=m.params,
                    interpret=True)
        )
        assert got.shape == (3, 1, 10)
        w = fx.lenet5_weights(0)
        for i in range(3):
            np.testing.assert_array_equal(
                got[i].astype(np.int64),
                fx.lenet5_numpy(xs[i].astype(np.int64), w),
            )


class TestUnsupportedFeatures:
    def _conv_model(self, **overrides):
        """A one-conv model with attribute overrides for error paths."""
        attrs = {
            "kernel_shape": fx.attr_ints("kernel_shape", [3, 3]),
            "strides": fx.attr_ints("strides", [1, 1]),
            "pads": fx.attr_ints("pads", [1, 1, 1, 1]),
        }
        attrs.update(overrides)
        w = np.zeros((4, 2, 3, 3), np.int8)
        g = fx.graph(
            "one_conv",
            [fx.node("Conv", ["x", "w"], ["y"], "conv",
                     tuple(a for a in attrs.values() if a is not None))],
            [fx.tensor("w", w)],
            [fx.value_info("x", (1, 2, 8, 8))],
            [fx.value_info("y", (1, 4, 8, 8))],
        )
        return fx.model(g)

    def test_unsupported_op_named(self):
        g = fx.graph(
            "soft",
            [fx.node("Softmax", ["x"], ["y"], "sm")],
            [],
            [fx.value_info("x", (1, 8))],
            [fx.value_info("y", (1, 8))],
        )
        with pytest.raises(OnnxImportError, match="Softmax"):
            load_onnx(fx.model(g))

    def test_grouped_conv_rejected(self):
        data = self._conv_model(group=fx.attr_int("group", 2))
        with pytest.raises(OnnxImportError, match="group"):
            load_onnx(data)

    def test_dilated_conv_rejected(self):
        data = self._conv_model(
            dilations=fx.attr_ints("dilations", [2, 2]))
        with pytest.raises(OnnxImportError, match="dilation"):
            load_onnx(data)

    def test_pool_missing_kernel_shape_named(self):
        """ISSUE 8 satellite: a pool node with no kernel_shape used to
        surface as a misleading non-square-[] error — it must name the
        missing attribute and the node."""
        g = fx.graph(
            "nop",
            [fx.node("MaxPool", ["x"], ["y"], "pool_k")],
            [],
            [fx.value_info("x", (1, 2, 4, 4))],
            [fx.value_info("y", (1, 2, 2, 2))],
        )
        with pytest.raises(OnnxImportError,
                           match=r"pool_k.*kernel_shape"):
            load_onnx(fx.model(g))

    def test_flatten_axis_2_rejected(self):
        g = fx.graph(
            "flat2",
            [fx.node("Flatten", ["x"], ["y"], "f",
                     (fx.attr_int("axis", 2),))],
            [],
            [fx.value_info("x", (1, 2, 4, 4))],
            [fx.value_info("y", (2, 16))],
        )
        with pytest.raises(OnnxImportError, match="axis=1"):
            load_onnx(fx.model(g))

    def test_non_initializer_weight_rejected(self):
        w = np.zeros((4, 2, 3, 3), np.int8)
        g = fx.graph(
            "dyn_w",
            [fx.node("Conv", ["x", "wdyn"], ["y"], "conv",
                     (fx.attr_ints("pads", [1, 1, 1, 1]),))],
            [fx.tensor("unused", w)],
            [fx.value_info("x", (1, 2, 8, 8)),
             fx.value_info("wdyn", (4, 2, 3, 3))],
            [fx.value_info("y", (1, 4, 8, 8))],
        )
        with pytest.raises(OnnxImportError, match="initializer"):
            load_onnx(fx.model(g))


def _conv_nchw(x, wgt, stride=1, pads=((0, 0), (0, 0))):
    """Independent NCHW conv oracle, int64 accumulation."""
    from numpy.lib.stride_tricks import sliding_window_view

    k = wgt.shape[2]
    (pt, pb), (pl, pr) = pads
    xp = np.pad(x, ((0, 0), (0, 0), (pt, pb), (pl, pr)))
    win = sliding_window_view(xp, (k, k), axis=(2, 3))
    win = win[:, :, ::stride, ::stride]
    return np.einsum("nchwij,ocij->nohw", win.astype(np.int64),
                     wgt.astype(np.int64))


class TestConvPaddingMatrix:
    """The tentpole import rules: (auto_pad, pads, kernel, stride) →
    SAME / VALID / named rejection, each accepted cell bit-exact
    against the NCHW oracle."""

    def _model(self, w, h_in, attrs):
        g = fx.graph(
            "pm",
            [fx.node("Conv", ["x", "w"], ["y"], "conv", tuple(attrs))],
            [fx.tensor("w", w)],
            [fx.value_info("x", (1, int(w.shape[1]), h_in, h_in))],
            [fx.value_info("y", (1,))],
        )
        return fx.model(g)

    def _run(self, data, h_in, c_in, seed=3):
        from repro import api

        m = load_onnx(data)
        art = api.compile_graph(m.dfg)
        x = np.random.default_rng(seed).integers(
            -4, 5, (1, c_in, h_in, h_in)
        ).astype(np.int32)
        got = np.asarray(
            art.run({m.dfg.graph_inputs[0]: x}, params=m.params,
                    interpret=True)
        )
        return x.astype(np.int64), got.astype(np.int64)

    def test_strided_conv_imports(self):
        """Flip of ISSUE 5's rejection: stride-2 with explicit
        SAME_UPPER-frame pads now streams."""
        w = np.random.default_rng(0).integers(
            -4, 5, (4, 2, 3, 3)).astype(np.int8)
        data = self._model(w, 8, [fx.attr_ints("kernel_shape", [3, 3]),
                                  fx.attr_ints("strides", [2, 2]),
                                  fx.attr_ints("pads", [0, 0, 1, 1])])
        x, got = self._run(data, 8, 2)
        want = _conv_nchw(x, w, stride=2, pads=((0, 1), (0, 1)))
        np.testing.assert_array_equal(got, want)

    def test_valid_conv_imports(self):
        """Flip of ISSUE 5's rejection: zero pads = VALID now streams
        (8×8, k3 → 6×6)."""
        w = np.random.default_rng(1).integers(
            -4, 5, (4, 2, 3, 3)).astype(np.int8)
        data = self._model(w, 8, [fx.attr_ints("pads", [0, 0, 0, 0])])
        x, got = self._run(data, 8, 2)
        assert got.shape == (1, 4, 6, 6)
        np.testing.assert_array_equal(got, _conv_nchw(x, w))

    def test_even_kernel_same_upper_end_heavy(self):
        """Satellite 1, the wrong-answer repro: an even-kernel
        SAME_UPPER conv pads end-heavy.  The begin-heavy (mirrored)
        placement the old early-return would have silently produced is
        a *different* array — assert both that the mis-placement is
        observable and that the import matches the correct one."""
        w = np.random.default_rng(2).integers(
            -4, 5, (4, 2, 4, 4)).astype(np.int8)
        data = self._model(w, 8, [fx.attr_string("auto_pad", "SAME_UPPER")])
        x, got = self._run(data, 8, 2)
        want = _conv_nchw(x, w, pads=((1, 2), (1, 2)))      # end-heavy
        wrong = _conv_nchw(x, w, pads=((2, 1), (2, 1)))     # begin-heavy
        assert not np.array_equal(want, wrong)
        np.testing.assert_array_equal(got, want)

    def test_even_kernel_same_lower_rejected(self):
        """Satellite 1: SAME_LOWER's begin-heavy split cannot ride the
        end-heavy streaming frame when the total pad is odd — named
        rejection, not a mirrored window."""
        w = np.zeros((4, 2, 4, 4), np.int8)
        data = self._model(w, 8, [fx.attr_string("auto_pad", "SAME_LOWER")])
        with pytest.raises(OnnxImportError, match="SAME_LOWER"):
            load_onnx(data)

    def test_same_lower_odd_kernel_imports(self):
        """SAME_LOWER with a symmetric split (odd kernel, stride 1) is
        identical to SAME_UPPER — accepted."""
        w = np.random.default_rng(4).integers(
            -4, 5, (4, 2, 3, 3)).astype(np.int8)
        data = self._model(w, 8, [fx.attr_string("auto_pad", "SAME_LOWER")])
        x, got = self._run(data, 8, 2)
        np.testing.assert_array_equal(
            got, _conv_nchw(x, w, pads=((1, 1), (1, 1))))

    def test_arbitrary_pads_rejected(self):
        """Symmetric [1,1,1,1] on an even kernel is neither VALID nor
        the SAME_UPPER frame [1,1,2,2] — named rejection."""
        w = np.zeros((4, 2, 4, 4), np.int8)
        data = self._model(w, 8, [fx.attr_ints("pads", [1, 1, 1, 1])])
        with pytest.raises(OnnxImportError, match="neither zero"):
            load_onnx(data)

    def test_auto_pad_with_explicit_pads_rejected(self):
        w = np.zeros((4, 2, 3, 3), np.int8)
        data = self._model(w, 8, [fx.attr_string("auto_pad", "SAME_UPPER"),
                                  fx.attr_ints("pads", [1, 1, 1, 1])])
        with pytest.raises(OnnxImportError, match="forbids"):
            load_onnx(data)

    def test_strided_valid_even_kernel_imports(self):
        """k2 s2 VALID — the classic learned-downsample shape."""
        w = np.random.default_rng(5).integers(
            -4, 5, (4, 2, 2, 2)).astype(np.int8)
        data = self._model(w, 8, [fx.attr_string("auto_pad", "VALID"),
                                  fx.attr_ints("strides", [2, 2])])
        x, got = self._run(data, 8, 2)
        assert got.shape == (1, 4, 4, 4)
        np.testing.assert_array_equal(got, _conv_nchw(x, w, stride=2))


class TestGemmAttributeMatrix:
    """Satellite 3: every (alpha, beta, transA, transB, bias-arity)
    cell of the Gemm attribute matrix pinned against the ONNX spec —
    Y = alpha·A'·B' + beta·C."""

    W = np.arange(-10, 14, dtype=np.int8).reshape(6, 4)   # (units, d_in)
    C = np.arange(1, 7, dtype=np.int32)                   # (units,)

    def _model(self, attrs, with_c=True, w=None):
        w = self.W if w is None else w
        ins = ["x", "w"] + (["c"] if with_c else [])
        inits = [fx.tensor("w", w)]
        if with_c:
            inits.append(fx.tensor("c", self.C))
        g = fx.graph(
            "gm",
            [fx.node("Gemm", ins, ["y"], "gemm", tuple(attrs))],
            inits,
            [fx.value_info("x", (1, 4))],
            [fx.value_info("y", (1, 6))],
        )
        return fx.model(g)

    def _run(self, data):
        from repro import api

        m = load_onnx(data)
        art = api.compile_graph(m.dfg)
        x = np.arange(2, 6, dtype=np.int32).reshape(1, 4)
        got = np.asarray(art.run(x, params=m.params, interpret=True))
        return x.astype(np.int64), got.astype(np.int64)

    def test_defaults_transb_bias(self):
        """alpha=1 beta=1 transB=1 with C: the torchvision export
        shape."""
        x, got = self._run(self._model((fx.attr_int("transB", 1),)))
        np.testing.assert_array_equal(
            got, x @ self.W.T.astype(np.int64) + self.C)

    def test_transb_0(self):
        """transB=0: B is already (d_in, units)."""
        w = np.ascontiguousarray(self.W.T)                # (4, 6)
        x, got = self._run(self._model((), with_c=False, w=w))
        np.testing.assert_array_equal(got, x @ w.astype(np.int64))

    def test_beta_0_drops_bias(self):
        """beta=0 with C present: the spec says the bias term vanishes."""
        x, got = self._run(self._model(
            (fx.attr_int("transB", 1), fx.attr_float("beta", 0.0))))
        np.testing.assert_array_equal(got, x @ self.W.T.astype(np.int64))

    def test_beta_nonunit_without_c_accepted(self):
        """beta=2 but no C input: beta multiplies nothing — accepted."""
        x, got = self._run(self._model(
            (fx.attr_int("transB", 1), fx.attr_float("beta", 2.0)),
            with_c=False))
        np.testing.assert_array_equal(got, x @ self.W.T.astype(np.int64))

    def test_beta_nonunit_with_c_rejected(self):
        data = self._model(
            (fx.attr_int("transB", 1), fx.attr_float("beta", 0.5)))
        with pytest.raises(OnnxImportError, match="beta"):
            load_onnx(data)

    def test_alpha_nonunit_rejected(self):
        data = self._model(
            (fx.attr_int("transB", 1), fx.attr_float("alpha", 2.0)))
        with pytest.raises(OnnxImportError, match="alpha"):
            load_onnx(data)

    def test_trans_a_rejected(self):
        data = self._model(
            (fx.attr_int("transB", 1), fx.attr_int("transA", 1)))
        with pytest.raises(OnnxImportError, match="transA"):
            load_onnx(data)

    def test_c_wrong_arity_rejected(self):
        """C must be the (units,) per-unit bias — a (d_in,)-sized C is
        rejected by name, not silently broadcast."""
        w = np.ascontiguousarray(self.W.T)                # units = 6
        ins = ["x", "w", "c"]
        g = fx.graph(
            "gm",
            [fx.node("Gemm", ins, ["y"], "gemm", ())],
            [fx.tensor("w", w),
             fx.tensor("c", np.arange(4, dtype=np.int32))],
            [fx.value_info("x", (1, 4))],
            [fx.value_info("y", (1, 6))],
        )
        with pytest.raises(OnnxImportError, match="elements"):
            load_onnx(fx.model(g))


class TestBatchNormFold:
    """BN folding error paths — fold *correctness* is pinned by the
    resnet_tiny golden (BN applied unfolded in the oracle)."""

    def _bn_stats(self, c, var=1.0):
        return [fx.tensor("s", np.full(c, 2.0, np.float32)),
                fx.tensor("B", np.zeros(c, np.float32)),
                fx.tensor("m", np.zeros(c, np.float32)),
                fx.tensor("v", np.full(c, var, np.float32))]

    def test_bn_not_after_conv_rejected(self):
        g = fx.graph(
            "bn_solo",
            [fx.node("Relu", ["x"], ["h"], "r"),
             fx.node("BatchNormalization", ["h", "s", "B", "m", "v"],
                     ["y"], "bn", (fx.attr_float("epsilon", 0.0),))],
            self._bn_stats(2),
            [fx.value_info("x", (1, 2, 4, 4))],
            [fx.value_info("y", (1, 2, 4, 4))],
        )
        with pytest.raises(OnnxImportError, match="not a Conv output"):
            load_onnx(fx.model(g))

    def test_bn_on_shared_conv_output_rejected(self):
        w = np.ones((2, 2, 3, 3), np.int8)
        g = fx.graph(
            "bn_shared",
            [fx.node("Conv", ["x", "w"], ["h"], "conv",
                     (fx.attr_string("auto_pad", "SAME_UPPER"),)),
             fx.node("BatchNormalization", ["h", "s", "B", "m", "v"],
                     ["y"], "bn", (fx.attr_float("epsilon", 0.0),))],
            [fx.tensor("w", w)] + self._bn_stats(2),
            [fx.value_info("x", (1, 2, 4, 4))],
            [fx.value_info("h", (1, 2, 4, 4)),
             fx.value_info("y", (1, 2, 4, 4))],
        )
        with pytest.raises(OnnxImportError, match="other consumers"):
            load_onnx(fx.model(g))

    def test_bn_fractional_fold_on_int_weights_rejected(self):
        """var=4, scale=2 → s=1 is exact; var=16, scale=2 → s=0.5 is
        not representable in int8 weights — named rejection instead of
        silent rounding."""
        w = np.ones((2, 2, 3, 3), np.int8)
        g = fx.graph(
            "bn_frac",
            [fx.node("Conv", ["x", "w"], ["h"], "conv",
                     (fx.attr_string("auto_pad", "SAME_UPPER"),)),
             fx.node("BatchNormalization", ["h", "s", "B", "m", "v"],
                     ["y"], "bn", (fx.attr_float("epsilon", 0.0),))],
            [fx.tensor("w", w)] + self._bn_stats(2, var=16.0),
            [fx.value_info("x", (1, 2, 4, 4))],
            [fx.value_info("y", (1, 2, 4, 4))],
        )
        with pytest.raises(OnnxImportError, match="requantization"):
            load_onnx(fx.model(g))


class TestResnetTinyGolden:
    """The ISSUE 8 golden: stride-2 downsamples under three padding
    spellings, BN folds, a GlobalAveragePool head.  Regenerate with
    ``python tests/_onnx_fixture.py``."""

    def test_golden_bytes_are_the_seeded_fixture(self):
        with open(GOLDEN2, "rb") as f:
            assert f.read() == fx.resnet_tiny_model_bytes(seed=0)

    def test_bn_nodes_fold_away(self):
        m = load_onnx(GOLDEN2)
        assert m.missing_params() == []
        # 3 convs survive; BN left no standalone nodes behind
        payloads = [op.name for op in m.dfg.nodes]
        assert not any("bn" in p for p in payloads)

    @pytest.mark.parametrize("target", ["kv260", "zu3eg"])
    def test_bit_exact_against_numpy_oracle(self, target):
        """Acceptance: the strided ResNet-style export compiles end to
        end and matches the independent un-folded NumPy oracle."""
        from repro import api

        m = load_onnx(GOLDEN2)
        art = api.compile_graph(m.dfg, api.CompileOptions(target=target))
        assert art.feasible
        x = np.random.default_rng(17).integers(
            -4, 5, (1, 3, 16, 16)
        ).astype(np.int32)
        got = np.asarray(
            art.run({m.dfg.graph_inputs[0]: x}, params=m.params,
                    interpret=True)
        )
        want = fx.resnet_tiny_numpy(x.astype(np.int64),
                                    fx.resnet_tiny_weights(0))
        np.testing.assert_array_equal(got.astype(np.int64), want)

    def test_global_average_pool_floor_division(self):
        """GAP rides the AVG epilogue's DIV exit: floor division for
        integers, including negative sums."""
        g = fx.graph(
            "gap",
            [fx.node("GlobalAveragePool", ["x"], ["y"], "gap")],
            [],
            [fx.value_info("x", (1, 2, 4, 4))],
            [fx.value_info("y", (1, 2, 1, 1))],
        )
        m = load_onnx(fx.model(g))
        from repro import api

        art = api.compile_graph(m.dfg)
        x = (np.arange(32, dtype=np.int32) - 19).reshape(1, 2, 4, 4)
        got = np.asarray(art.run(x, interpret=True))
        want = x.astype(np.int64).sum(axis=(2, 3), keepdims=True) // 16
        np.testing.assert_array_equal(got.astype(np.int64), want)


class TestBiasFootprint:
    def test_broadcast_bias_reduces_modeled_bram(self):
        """Acceptance: a rank-1 (C,) bias epilogue operand costs C
        resident elements; the old full-tensor materialization charged
        H·W·C — the modeled BRAM must drop.  On the DSP-poor ZU3EG the
        unroll (and hence the array partitioning) is small, so the
        full-tensor constant lands squarely in RAM18K blocks."""
        from repro import api
        from repro.api.builder import Graph

        def build(full):
            g = Graph("bias_full" if full else "bias_bcast")
            x = g.input((1, 64, 64, 8))
            h = g.conv2d(x, 32, kernel=3)
            if full:
                k = g.constant((1, 64, 64, 32), name="b")
            else:
                k = g.constant((32,), name="b")
            g.output(g.add(h, k))
            return g.build()

        opts = api.CompileOptions(target="zu3eg")
        art_full = api.compile_graph(build(True), opts)
        art_bcast = api.compile_graph(build(False), opts)
        # both fuse the bias into the conv epilogue; the plans differ
        # only in the resident constant footprint
        plan_bits = lambda a: next(  # noqa: E731
            iter(a.design.groups[0].plan.nodes.values())
        ).const_buffer_bits
        assert plan_bits(art_bcast) < plan_bits(art_full)
        assert art_bcast.report().max_bram < art_full.report().max_bram


class TestSmallModels:
    def test_gemm_bias_and_add_paths(self):
        """Gemm with transB + bias, then Add with an initializer: both
        constant-binding paths, checked against numpy."""
        rng = np.random.default_rng(5)
        w = rng.integers(-3, 4, (6, 8)).astype(np.int8)     # (units, d_in)
        b = rng.integers(-3, 4, (6,)).astype(np.int32)
        k = rng.integers(-3, 4, (1, 6)).astype(np.int32)
        g = fx.graph(
            "mlp",
            [
                fx.node("Gemm", ["x", "w", "b"], ["h"], "gemm",
                        (fx.attr_int("transB", 1),)),
                fx.node("Add", ["h", "k"], ["y"], "bias2"),
            ],
            [fx.tensor("w", w), fx.tensor("b", b), fx.tensor("k", k)],
            [fx.value_info("x", (1, 8))],
            [fx.value_info("y", (1, 6))],
        )
        m = load_onnx(fx.model(g))
        from repro import api

        art = api.compile_graph(m.dfg)
        x = rng.integers(-3, 4, (1, 8)).astype(np.int32)
        got = np.asarray(art.run(x, params=m.params, interpret=True))
        want = x.astype(np.int64) @ w.T.astype(np.int64) + b + k
        np.testing.assert_array_equal(got.astype(np.int64), want)

    def test_avgpool_model(self):
        g = fx.graph(
            "ap",
            [fx.node("AveragePool", ["x"], ["y"], "pool",
                     (fx.attr_ints("kernel_shape", [2, 2]),
                      fx.attr_ints("strides", [2, 2])))],
            [],
            [fx.value_info("x", (1, 2, 4, 4))],
            [fx.value_info("y", (1, 2, 2, 2))],
        )
        m = load_onnx(fx.model(g))
        from repro import api

        art = api.compile_graph(m.dfg)
        x = np.arange(32, dtype=np.int32).reshape(1, 2, 4, 4)
        got = np.asarray(art.run(x, interpret=True))
        want = x.reshape(1, 2, 2, 2, 2, 2).sum(axis=(3, 5)) // 4
        np.testing.assert_array_equal(got, want)

    def test_import_model_dispatches_onnx(self):
        m = import_model(GOLDEN)
        assert m.source == "onnx"
