"""Vocab padding (§Perf optimization): numerically exact vs unpadded."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeConfig, count_params
from repro.configs.registry import get_config
from repro.data.pipeline import DataConfig, batch_for_model
from repro.models import lm


def _pad_params(params, v_old, v_new):
    """Zero-pad the vocab rows/cols so padded params == unpadded math."""
    out = dict(params)
    if "embed" in out:
        out["embed"] = jnp.pad(out["embed"], ((0, v_new - v_old), (0, 0)))
    if "lm_head" in out:
        out["lm_head"] = jnp.pad(out["lm_head"], ((0, 0), (0, v_new - v_old)))
    return out


@pytest.fixture(scope="module")
def setup():
    cfg0 = get_config("mamba2-1.3b", smoke=True)     # vocab 256
    cfg1 = cfg0.with_(pad_vocab_to=96)               # → 288
    batch = batch_for_model(cfg0, ShapeConfig("t", 32, 2, "train"),
                            DataConfig(), 0)
    p0 = lm.init_params(jax.random.key(0), cfg0)
    p1 = _pad_params(p0, cfg0.vocab_size, cfg1.padded_vocab)
    return cfg0, cfg1, p0, p1, batch


class TestPaddedEquivalence:
    def test_padded_shapes(self, setup):
        cfg0, cfg1, p0, p1, _ = setup
        assert cfg1.padded_vocab == 288
        assert p1["embed"].shape[0] == 288
        assert count_params(cfg1) == sum(
            x.size for x in jax.tree.leaves(
                lm.init_params(jax.random.key(0), cfg1))
        )

    def test_loss_identical(self, setup):
        cfg0, cfg1, p0, p1, batch = setup
        l0 = float(lm.lm_loss(p0, cfg0, batch))
        l1 = float(lm.lm_loss(p1, cfg1, batch))
        assert l0 == pytest.approx(l1, rel=1e-6)

    def test_grads_identical_on_real_rows(self, setup):
        cfg0, cfg1, p0, p1, batch = setup
        g0 = jax.grad(lambda p: lm.lm_loss(p, cfg0, batch))(p0)
        g1 = jax.grad(lambda p: lm.lm_loss(p, cfg1, batch))(p1)
        v = cfg0.vocab_size
        np.testing.assert_allclose(
            np.asarray(g1["embed"][:v], np.float32),
            np.asarray(g0["embed"], np.float32), atol=1e-3, rtol=1e-2,
        )
        # padded embed rows get zero grad (never indexed, masked in loss)
        assert float(jnp.abs(g1["embed"][v:].astype(jnp.float32)).max()) == 0.0

    def test_prefill_decode_logits_sliced(self, setup):
        cfg0, cfg1, p0, p1, batch = setup
        logits0, _ = lm.lm_prefill(p0, cfg0, {"tokens": batch["tokens"]})
        logits1, _ = lm.lm_prefill(p1, cfg1, {"tokens": batch["tokens"]})
        assert logits1.shape == (2, cfg0.vocab_size)
        np.testing.assert_allclose(
            np.asarray(logits0), np.asarray(logits1), atol=1e-3, rtol=1e-3
        )

    def test_sharding_unlocked(self):
        """The point of the exercise: padded vocab divides the model axis."""
        cfg = get_config("mamba2-1.3b")             # 50280
        assert cfg.vocab_size % 16 != 0
        padded = cfg.with_(pad_vocab_to=256)
        assert padded.padded_vocab % 256 == 0       # 16 model × 16 sublanes
        assert padded.padded_vocab - cfg.vocab_size < 256
