"""Dry-run machinery smoke tests (reduced 8-device meshes in subprocesses).

The production 256/512-chip runs are executed by ``benchmarks`` /
EXPERIMENTS.md; these tests prove the *machinery* — lowering, sharding,
compile, artifact schema — on every step kind cheaply.
"""
import json
import os

import pytest

CELLS = [
    ("llama3.2-1b", "train_4k", "single"),
    ("qwen2-0.5b", "prefill_32k", "single"),
    ("qwen2-0.5b", "decode_32k", "single"),
    ("mamba2-1.3b", "long_500k", "single"),
    ("granite-moe-1b-a400m", "train_4k", "multi"),
    ("seamless-m4t-medium", "decode_32k", "single"),
]


@pytest.mark.parametrize("arch,shape,mesh", CELLS)
def test_cell_compiles(arch, shape, mesh, subproc, tmp_path):
    code = f"""
import sys
from repro.launch.dryrun import main
sys.exit(main(["--arch", {arch!r}, "--shape", {shape!r},
               "--mesh", {mesh!r}, "--out", {str(tmp_path)!r}]))
"""
    r = subproc(
        code, env={
            "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
            "REPRO_MESH_SHAPE": "4,2",
            "REPRO_MESH_SHAPE_MULTI": "2,2,2",
        }, timeout=1200,
    )
    assert r.returncode == 0, (r.stdout[-1000:], r.stderr[-2000:])
    safe = arch.replace(".", "_")
    rec = json.load(open(tmp_path / f"{safe}__{shape}__{mesh}.json"))
    assert rec["ok"], rec
    assert rec["entry"] in ("train_step", "prefill_step", "decode_step")
    # roofline terms present and positive
    for term in ("compute_s", "memory_s", "collective_s"):
        assert rec[term] >= 0
    assert rec["dominant"] in ("compute_s", "memory_s", "collective_s")
    assert rec["hlo_flops_per_device"] > 0
    if mesh == "multi":
        assert rec["chips"] == 8


def test_skip_recorded_for_full_attention_long(subproc, tmp_path):
    """long_500k on a full-attention arch must be a recorded skip."""
    code = f"""
import sys
from repro.launch.dryrun import main
sys.exit(main(["--arch", "yi-9b", "--shape", "long_500k",
               "--mesh", "single", "--out", {str(tmp_path)!r}]))
"""
    r = subproc(code, env={
        "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
        "REPRO_MESH_SHAPE": "4,2",
    })
    assert r.returncode == 0, r.stderr[-2000:]
    rec = json.load(open(tmp_path / "yi-9b__long_500k__single.json"))
    assert rec["skipped"] and "edge-infeasible" in rec["reason"]


def test_input_specs_match_real_batches():
    """A dry-run-validated cell must accept real pipeline data: the spec
    shapes/dtypes equal the generated batch's."""
    import jax

    from repro.configs.base import SHAPES, ShapeConfig
    from repro.configs.registry import get_config
    from repro.data.pipeline import DataConfig, batch_for_model
    from repro.launch import specs as S

    for arch in ("llama3.2-1b", "qwen2-vl-72b"):
        cfg = get_config(arch, smoke=True)
        shape = ShapeConfig("t", 64, 2, "train")
        spec = S.train_input_specs(cfg, shape)
        batch = batch_for_model(cfg, shape, DataConfig(), 0)
        spec_flat = jax.tree_util.tree_flatten_with_path(spec)[0]
        batch_flat = dict(
            (jax.tree_util.keystr(k), v)
            for k, v in jax.tree_util.tree_flatten_with_path(batch)[0]
        )
        for k, v in spec_flat:
            key = jax.tree_util.keystr(k)
            assert key in batch_flat, key
            got = batch_flat[key]
            assert tuple(got.shape) == tuple(v.shape), (key, got.shape, v.shape)
