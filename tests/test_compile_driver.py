"""Unified compile driver (ISSUE 2): schedule IR, cycle-balanced
partitioning, partial weight streaming, and single-schedule consumers."""
import numpy as np
import pytest

from repro.core import cnn_graphs
from repro.core.compile_driver import (
    KV260,
    TARGETS,
    ZU3EG,
    CompiledDesign,
    GroupSchedule,
    Target,
    compile_design,
)
from repro.core.dse import solve_ilp
from repro.core.emit_hls import emit_design
from repro.core.resource_model import (
    DRAM_BYTES_PER_CYCLE,
    KV260_BRAM18K,
    KV260_DSP,
    transition_cycles,
)
from repro.core.streaming import plan_streams
from repro.passes import (
    PartitionError,
    partition_layer_groups,
    run_default_pipeline,
)
from repro.passes import interp


@pytest.fixture()
def deep224_design(deep224_partition):
    """deep_cascade(224), balanced partition (session-shared IR — the
    same CompiledDesign compile() builds)."""
    return deep224_partition


@pytest.fixture(scope="module")
def deep224_greedy(deep224_fused):
    return partition_layer_groups(deep224_fused, strategy="greedy")


class TestCompiledDesign:
    """The one object every backend consumes."""

    def test_single_group_when_graph_fits(self):
        d = compile_design(cnn_graphs.conv_relu(32))
        assert isinstance(d, CompiledDesign)
        assert d.whole_graph_feasible and not d.partitioned
        assert len(d.groups) == 1 and isinstance(d.groups[0], GroupSchedule)
        assert d.target == KV260
        assert d.original is not None and d.pass_result is not None
        # pass pipeline ran: conv+relu fused into one node
        assert len(d.source.nodes) == 1

    def test_partition_returns_same_ir(self, deep224_design):
        """partition_layer_groups and compile() build the same IR — no
        second plan-derivation path left."""
        d = deep224_design
        assert isinstance(d, CompiledDesign)
        assert all(isinstance(g, GroupSchedule) for g in d.groups)
        assert d.partitioned and d.feasible
        assert d.max_bram <= d.b_total and d.max_dsp <= d.d_total

    def test_schedule_rows_carry_weight_streaming(self):
        d = compile_design(cnn_graphs.fat_conv())
        rows = d.schedule()
        assert any(r["weight_streamed"] for r in rows)

    def test_custom_target(self):
        tiny = Target(name="tiny", d_total=64, b_total=32)
        d = compile_design(cnn_graphs.conv_relu(8, c_out=4), tiny)
        assert d.d_total == 64 and d.b_total == 32
        assert d.feasible


class TestCycleAccounting:
    """Satellite: spill-buffer sizing and host-schedule cycle property."""

    @pytest.mark.parametrize("n,c_mid,b_total", [
        (8, 4, 2), (8, 4, KV260_BRAM18K),
        (16, 8, 2), (16, 8, 4), (16, 8, 8), (16, 4, 3),
        (32, 8, 16), (32, 16, 8),
    ])
    def test_total_cycles_identity(self, n, c_mid, b_total):
        """Property (swept over graph sizes × budgets): sum(group cycles)
        + overlapped boundary DMA == total_cycles, with each boundary
        recomputed independently from the adjacent groups' spill lists —
        and never above the PR 2 serial round-trip baseline."""
        fused = run_default_pipeline(cnn_graphs.cascade_conv(n, c_mid=c_mid)).dfg
        try:
            pp = partition_layer_groups(fused, b_total=b_total)
        except PartitionError:
            pytest.skip("unsplittable under this budget")
        for s in pp.spills():
            assert s.bits == fused.values[s.value].total_bits
            assert s.bytes == -(-s.bits // 8)
        expected_spill = 0
        for left, right in zip(pp.groups, pp.groups[1:]):
            w = sum(-(-fused.values[v].total_bits // 8) for v in left.spill_out)
            r = sum(-(-fused.values[v].total_bits // 8) for v in right.spill_in)
            expected_spill += transition_cycles(w, r)
        assert pp.spill_cycles == expected_spill
        assert pp.total_cycles == sum(g.cycles for g in pp.groups) + expected_spill
        # the overlapped model must never price a cut above PR 2's
        # serial write-then-read charge
        assert pp.spill_cycles <= pp.serial_spill_cycles

    def test_deep224_accounting(self, deep224_design):
        d = deep224_design
        assert d.total_cycles == sum(g.cycles for g in d.groups) + d.spill_cycles
        assert d.spill_cycles > 0
        assert d.max_group_cycles == max(g.cycles for g in d.groups)


class TestBalancedPartitioning:
    """Tentpole: DP min-max beats the greedy prefix cut on cycles."""

    def test_deep224_fits_and_improves_on_greedy(
        self, deep224_design, deep224_greedy
    ):
        bal, greedy = deep224_design, deep224_greedy
        assert bal.feasible and bal.max_bram <= KV260_BRAM18K
        assert bal.max_dsp <= KV260_DSP
        # regression: the balanced cut's slowest group is strictly faster
        assert bal.max_group_cycles < greedy.max_group_cycles
        # and not at the price of a slower end-to-end schedule
        assert bal.total_cycles <= greedy.total_cycles

    def test_balanced_never_worse_than_greedy_forced_cuts(self):
        """On a tiny forced partition the DP is at least as good."""
        fused = run_default_pipeline(cnn_graphs.cascade_conv(16, c_mid=8)).dfg
        bal = partition_layer_groups(fused, b_total=2)
        greedy = partition_layer_groups(fused, b_total=2, strategy="greedy")
        assert bal.max_group_cycles <= greedy.max_group_cycles

    def test_groups_cover_graph_in_topo_order(self, deep224_design):
        d = deep224_design
        covered = [n for g in d.groups for n in g.node_names]
        assert sorted(covered) == sorted(n.name for n in d.source.nodes)
        # every spill-out is a later group's spill-in
        outs = {v for g in d.groups for v in g.spill_out}
        ins = {v for g in d.groups for v in g.spill_in}
        assert outs == ins and outs

    def test_groupwise_semantics_preserved(self, deep224_design):
        """Interpreter-chained groups == whole graph, on a small clone
        of the same cut structure."""
        fused = run_default_pipeline(cnn_graphs.cascade_conv(16, c_mid=8)).dfg
        pp = partition_layer_groups(fused, b_total=2)
        assert pp.partitioned
        env = interp.random_env(fused, seed=11)
        whole = interp.graph_outputs(fused, env)
        chained = dict(env)
        for g in pp.groups:
            chained.update(interp.execute_dfg(g.dfg, chained))
        for k, v in whole.items():
            np.testing.assert_array_equal(np.asarray(v), np.asarray(chained[k]))


class TestWeightStreaming:
    """Tentpole: weight-dominated convs compile via DRAM-tiled weights."""

    def test_fat_conv_infeasible_without_streaming(self):
        fused = run_default_pipeline(cnn_graphs.fat_conv()).dfg
        whole = solve_ilp(plan_streams(fused))
        assert not whole.feasible

    def test_fat_conv_compiles_via_streaming(self):
        d = compile_design(cnn_graphs.fat_conv())
        assert d.feasible
        assert d.weight_streamed, "expected a weight-streamed node"
        (node, tiles), = d.weight_streamed.items()
        assert tiles > 1
        assert d.max_bram <= KV260_BRAM18K and d.max_dsp <= KV260_DSP

    def test_streaming_charges_dram_cycles(self):
        """The streamed design must be slower than a hypothetical
        resident-weight plan of the same unroll — the DRAM round trip is
        in the ledger, not hidden."""
        d = compile_design(cnn_graphs.fat_conv())
        g = d.groups[0]
        w_bits = sum(
            v.total_bits for v in g.dfg.values.values() if v.is_constant
        )
        dram_cycles = -(-2 * (w_bits // 8) // DRAM_BYTES_PER_CYCLE)
        assert g.cycles > dram_cycles  # round trip included in the total

    def test_solver_prefers_resident_weights_when_they_fit(self):
        """weight_streaming=True must not change designs that fit: the
        streamed variants are strictly slower, so the ILP ignores them."""
        plan = plan_streams(
            run_default_pipeline(cnn_graphs.conv_relu(32)).dfg
        )
        base = solve_ilp(plan)
        ws = solve_ilp(plan, weight_streaming=True)
        assert base.feasible and ws.feasible
        assert not ws.weight_tiles
        assert ws.objective_cycles == base.objective_cycles


class TestEmitConsumesDesign:
    def test_emit_design_weight_streamed_golden(self, golden_check):
        d = compile_design(cnn_graphs.fat_conv())
        files = emit_design(d)
        golden_check("fat_conv_16_g0.cpp", files["fat_conv_16_g0.cpp"])

    def test_double_buffered_kernel_structure(self):
        d = compile_design(cnn_graphs.fat_conv())
        files = emit_design(d)
        cpp = files["fat_conv_16_g0.cpp"]
        tiles = d.weight_streamed["conv0"]
        assert f"WT: for (int wt = 0; wt < {tiles}; ++wt)" in cpp
        assert "load_tile(wtile[0], dram_w0, 0);" in cpp     # preload
        assert (  # guarded prefetch — never reads past the last tile
            f"if (wt + 1 < {tiles}) load_tile(wtile[(wt + 1) & 1]" in cpp
        )
        assert "wtile[2][" in cpp
        assert "const elem_t *dram_w0" in cpp
        assert cpp.count("{") == cpp.count("}")
        host = files["host_schedule.cpp"]
        assert "wstream_w0" in host and "weights streamed" in host

    def test_single_group_design_emits(self):
        d = compile_design(cnn_graphs.conv_relu(32))
        files = emit_design(d)
        assert set(files) == {f"{d.groups[0].name}.cpp", "host_schedule.cpp"}
        assert "#pragma HLS DATAFLOW" in files[f"{d.groups[0].name}.cpp"]


class TestOverlappedSpills:
    """ISSUE 3 tentpole: spill writes of group k overlap group k+1's
    fill — max(spill, fill) + burst tail, not a serial round trip."""

    def test_deep224_beats_serial_spill_baseline(self, deep224_design):
        """The acceptance regression: modeled total cycles strictly
        below the PR 2 serial-spill baseline on deep_cascade_224."""
        d = deep224_design
        assert d.partitioned and d.spill_cycles > 0
        serial_total = sum(g.cycles for g in d.groups) + d.serial_spill_cycles
        assert d.spill_cycles < d.serial_spill_cycles
        assert d.total_cycles < serial_total

    def test_boundary_traffic_matches_spill_lists(self, deep224_design):
        d = deep224_design
        traffic = d.boundary_traffic()
        assert len(traffic) == len(d.groups) - 1
        for (w, r), left, right in zip(traffic, d.groups, d.groups[1:]):
            assert w == sum(
                -(-d.source.values[v].total_bits // 8) for v in left.spill_out
            )
            assert r == sum(
                -(-d.source.values[v].total_bits // 8) for v in right.spill_in
            )

    def test_transition_never_above_serial(self):
        """max(w, r) + capped tail degenerates to the serial sum for
        sub-burst transfers and beats it for long ones."""
        from repro.core.resource_model import DRAM_BURST_BYTES

        for w, r in [(0, 0), (0, 4096), (128, 128), (128, 4096),
                     (4096, 4096), (1 << 20, 1 << 20), (1 << 20, 64)]:
            serial = -(-w // DRAM_BYTES_PER_CYCLE) + -(-r // DRAM_BYTES_PER_CYCLE)
            assert transition_cycles(w, r) <= serial
        big = 1 << 20
        assert transition_cycles(big, big) < (
            -(-2 * big // DRAM_BYTES_PER_CYCLE)
        )
        assert transition_cycles(big, 0) == -(-big // DRAM_BYTES_PER_CYCLE)

    def test_host_schedule_issues_overlapped_transfers(self, deep224_design):
        files = emit_design(deep224_design)
        host = files["host_schedule.cpp"]
        assert "dma_write_async(" in host and "dma_read_async(" in host
        assert "dma_join();" in host
        assert host.count("// transition ") == len(deep224_design.groups) - 1


class TestCostAwareStreaming:
    """ISSUE 3 tentpole: weight streaming is a first-class DP choice —
    any slice may stream; the single-node rescue path is gone."""

    def test_fat_cascade_streams_every_conv(self):
        """Every layer's weights exceed the budget alone, so no resident
        cut exists: the DP must schedule streamed groups end to end."""
        d = compile_design(cnn_graphs.fat_cascade())
        assert d.feasible
        assert set(d.weight_streamed) == {"conv0", "conv1"}
        assert all(t > 1 for t in d.weight_streamed.values())
        assert d.max_bram <= KV260_BRAM18K and d.max_dsp <= KV260_DSP

    def test_multi_node_slices_can_stream(self):
        """The capability the PR 2 rescue lacked: a multi-node slice
        that is over budget resident gets a feasible weight-streamed
        plan, so the DP prices it against cutting instead of being
        forced to cut."""
        from repro.passes.partition import _GroupPlanner

        fused = run_default_pipeline(cnn_graphs.fat_cascade()).dfg
        planner = _GroupPlanner(
            fused, d_total=KV260_DSP, b_total=KV260_BRAM18K,
            model=None, max_unroll=4096,
        )
        # the probe reaches the whole graph only via streamed weights
        assert planner.max_feasible_end(0) == len(planner.order)
        merged = planner.group(0, 2)
        assert merged.dse.feasible and merged.dse.weight_tiles
        assert not planner.resident_feasible(0, 2)
        # the DP rejected the merged slice on modeled cycles, not by fiat
        d = compile_design(cnn_graphs.fat_cascade())
        assert d.max_group_cycles <= merged.cycles

    @pytest.mark.parametrize("strategy", ["balanced", "greedy"])
    def test_fat_graphs_compile_under_both_strategies(self, strategy):
        for make in (cnn_graphs.fat_conv, cnn_graphs.fat_cascade):
            d = compile_design(make(), strategy=strategy)
            assert d.feasible and d.weight_streamed


class TestMultiTarget:
    def test_targets_registry(self):
        assert set(TARGETS) >= {"kv260", "zu3eg"}
        assert TARGETS["kv260"] is KV260 and TARGETS["zu3eg"] is ZU3EG
        assert ZU3EG.b_total > KV260.b_total  # BRAM-richer
        assert ZU3EG.d_total < KV260.d_total  # DSP-poorer

    def test_zu3eg_flips_fat_conv_to_resident(self):
        """The same graph maps differently per part: streamed weight
        tiles on the BRAM-poor KV260, resident on the ZU3EG."""
        kv = compile_design(cnn_graphs.fat_conv())
        zu = compile_design(cnn_graphs.fat_conv(), ZU3EG)
        assert kv.weight_streamed and not zu.weight_streamed
        assert zu.whole_graph_feasible and zu.max_bram <= ZU3EG.b_total

    def test_zu3eg_fits_deep224_whole_but_slower(
        self, deep224_fused, deep224_partition
    ):
        zu = partition_layer_groups(
            deep224_fused, d_total=ZU3EG.d_total, b_total=ZU3EG.b_total
        )
        assert zu.whole_graph_feasible and len(zu.groups) == 1
        assert zu.max_dsp <= ZU3EG.d_total
        # no spills on the BRAM-richer part — but far fewer DSPs, so the
        # partitioned KV260 schedule is still the faster one
        assert zu.spill_cycles == 0
        assert zu.total_cycles > deep224_partition.total_cycles


class TestExecutableCache:
    """Satellite: lower_group caches jitted executables per group
    signature — repeated run_compiled calls stop re-jitting."""

    def test_lower_group_caches_jitted_executables(self, monkeypatch):
        from repro.kernels import ops

        d = compile_design(cnn_graphs.cascade_conv(8, c_mid=4))
        env = interp.random_env(d.source, seed=2)
        calls = {"n": 0}
        orig = ops._build_group_fn

        def probe(group, interpret, jit, batch=None):
            calls["n"] += 1
            return orig(group, interpret, jit, batch=batch)

        monkeypatch.setattr(ops, "_build_group_fn", probe)
        ops._EXEC_CACHE.clear()
        before_hits = ops.exec_cache_stats["hits"]
        first = ops.run_compiled(d, env, interpret=True)
        n_first = calls["n"]
        assert n_first == len(d.groups)  # one build per group
        second = ops.run_compiled(d, env, interpret=True)
        assert calls["n"] == n_first  # cache hit: no re-build, no re-jit
        assert ops.exec_cache_stats["hits"] == before_hits + len(d.groups)
        for k in first:
            np.testing.assert_array_equal(
                np.asarray(first[k]), np.asarray(second[k])
            )

    def test_recompiled_design_reuses_executables(self, monkeypatch):
        """Two separate compile() runs of the same graph share one
        executable (signature-keyed, not object-keyed)."""
        from repro.kernels import ops

        env = interp.random_env(compile_design(
            cnn_graphs.conv_relu(8, c_out=4)).source, seed=4)
        calls = {"n": 0}
        orig = ops._build_group_fn

        def probe(group, interpret, jit, batch=None):
            calls["n"] += 1
            return orig(group, interpret, jit, batch=batch)

        monkeypatch.setattr(ops, "_build_group_fn", probe)
        ops._EXEC_CACHE.clear()
        ops.run_compiled(compile_design(cnn_graphs.conv_relu(8, c_out=4)),
                         env, interpret=True)
        n_first = calls["n"]
        ops.run_compiled(compile_design(cnn_graphs.conv_relu(8, c_out=4)),
                         env, interpret=True)
        assert calls["n"] == n_first


class TestPallasConsumesDesign:
    """kernels/ops.run_compiled executes the identical schedule."""

    @pytest.mark.parametrize(
        "make",
        [
            lambda: cnn_graphs.conv_relu(8, c_out=4),
            lambda: cnn_graphs.cascade_conv(8, c_mid=4),
            lambda: cnn_graphs.conv_pool(8, c_out=4),
            lambda: cnn_graphs.residual_block(8, c=4),
            cnn_graphs.feed_forward,
        ],
        ids=["conv_relu", "cascade", "conv_pool", "residual", "feed_forward"],
    )
    def test_run_compiled_matches_interp(self, make):
        dfg = make()
        d = compile_design(dfg)
        env = interp.random_env(d.source, seed=7)
        want = interp.graph_outputs(d.source, env)
        got = ops_run(d, env)
        assert set(want) == set(got)
        for k in want:
            np.testing.assert_array_equal(np.asarray(want[k]), np.asarray(got[k]))

    def test_partitioned_design_chains_groups(self):
        fused = run_default_pipeline(cnn_graphs.cascade_conv(16, c_mid=8)).dfg
        pp = partition_layer_groups(fused, b_total=2)
        assert pp.partitioned
        env = interp.random_env(fused, seed=3)
        want = interp.graph_outputs(fused, env)
        got = ops_run(pp, env)
        for k in want:
            np.testing.assert_array_equal(np.asarray(want[k]), np.asarray(got[k]))

    @pytest.mark.parametrize("strategy", ["balanced", "greedy"])
    @pytest.mark.parametrize(
        "make",
        [cnn_graphs.fat_conv, cnn_graphs.fat_cascade],
        ids=["fat_conv", "fat_cascade"],
    )
    def test_streamed_groups_match_interp(self, make, strategy):
        """Satellite: run_compiled's weight-tiled lowering (the TPU dual
        of the emitter's wtile loop) is bit-exact with the reference
        interpreter for streamed-weight groups, both strategies."""
        d = compile_design(make(), strategy=strategy)
        assert d.weight_streamed, "expected a weight-streamed schedule"
        env = interp.random_env(d.source, seed=5)
        want = interp.graph_outputs(d.source, env)
        got = ops_run(d, env)
        assert set(want) == set(got)
        for k in want:
            np.testing.assert_array_equal(np.asarray(want[k]), np.asarray(got[k]))


def ops_run(design, env):
    from repro.kernels import ops

    return ops.run_compiled(design, env, interpret=True)
