"""Unified compile driver (ISSUE 2): schedule IR, cycle-balanced
partitioning, partial weight streaming, and single-schedule consumers."""
import numpy as np
import pytest

from repro.core import cnn_graphs
from repro.core.compile_driver import (
    KV260,
    CompiledDesign,
    GroupSchedule,
    Target,
    compile as compile_design,
)
from repro.core.dse import solve_ilp
from repro.core.emit_hls import emit_design
from repro.core.resource_model import (
    DRAM_BYTES_PER_CYCLE,
    KV260_BRAM18K,
    KV260_DSP,
)
from repro.core.streaming import plan_streams
from repro.passes import (
    PartitionError,
    partition_layer_groups,
    run_default_pipeline,
)
from repro.passes import interp


@pytest.fixture()
def deep224_design(deep224_partition):
    """deep_cascade(224), balanced partition (session-shared IR — the
    same CompiledDesign compile() builds)."""
    return deep224_partition


@pytest.fixture(scope="module")
def deep224_greedy(deep224_fused):
    return partition_layer_groups(deep224_fused, strategy="greedy")


class TestCompiledDesign:
    """The one object every backend consumes."""

    def test_single_group_when_graph_fits(self):
        d = compile_design(cnn_graphs.conv_relu(32))
        assert isinstance(d, CompiledDesign)
        assert d.whole_graph_feasible and not d.partitioned
        assert len(d.groups) == 1 and isinstance(d.groups[0], GroupSchedule)
        assert d.target == KV260
        assert d.original is not None and d.pass_result is not None
        # pass pipeline ran: conv+relu fused into one node
        assert len(d.source.nodes) == 1

    def test_partition_returns_same_ir(self, deep224_design):
        """partition_layer_groups and compile() build the same IR — no
        second plan-derivation path left."""
        d = deep224_design
        assert isinstance(d, CompiledDesign)
        assert all(isinstance(g, GroupSchedule) for g in d.groups)
        assert d.partitioned and d.feasible
        assert d.max_bram <= d.b_total and d.max_dsp <= d.d_total

    def test_schedule_rows_carry_weight_streaming(self):
        d = compile_design(cnn_graphs.fat_conv())
        rows = d.schedule()
        assert any(r["weight_streamed"] for r in rows)

    def test_custom_target(self):
        tiny = Target(name="tiny", d_total=64, b_total=32)
        d = compile_design(cnn_graphs.conv_relu(8, c_out=4), tiny)
        assert d.d_total == 64 and d.b_total == 32
        assert d.feasible


class TestCycleAccounting:
    """Satellite: spill-buffer sizing and host-schedule cycle property."""

    @pytest.mark.parametrize("n,c_mid,b_total", [
        (8, 4, 2), (8, 4, KV260_BRAM18K),
        (16, 8, 2), (16, 8, 4), (16, 8, 8), (16, 4, 3),
        (32, 8, 16), (32, 16, 8),
    ])
    def test_total_cycles_identity(self, n, c_mid, b_total):
        """Property (swept over graph sizes × budgets): sum(group cycles)
        + spill round-trips == total_cycles, with the spill round-trips
        recomputed independently from value bits."""
        fused = run_default_pipeline(cnn_graphs.cascade_conv(n, c_mid=c_mid)).dfg
        try:
            pp = partition_layer_groups(fused, b_total=b_total)
        except PartitionError:
            pytest.skip("unsplittable under this budget")
        expected_spill = 0
        for s in pp.spills():
            assert s.bits == fused.values[s.value].total_bits
            assert s.bytes == -(-s.bits // 8)
            expected_spill += -(-2 * s.bytes // DRAM_BYTES_PER_CYCLE)
        assert pp.spill_cycles == expected_spill
        assert pp.total_cycles == sum(g.cycles for g in pp.groups) + expected_spill

    def test_deep224_accounting(self, deep224_design):
        d = deep224_design
        assert d.total_cycles == sum(g.cycles for g in d.groups) + d.spill_cycles
        assert d.spill_cycles > 0
        assert d.max_group_cycles == max(g.cycles for g in d.groups)


class TestBalancedPartitioning:
    """Tentpole: DP min-max beats the greedy prefix cut on cycles."""

    def test_deep224_fits_and_improves_on_greedy(
        self, deep224_design, deep224_greedy
    ):
        bal, greedy = deep224_design, deep224_greedy
        assert bal.feasible and bal.max_bram <= KV260_BRAM18K
        assert bal.max_dsp <= KV260_DSP
        # regression: the balanced cut's slowest group is strictly faster
        assert bal.max_group_cycles < greedy.max_group_cycles
        # and not at the price of a slower end-to-end schedule
        assert bal.total_cycles <= greedy.total_cycles

    def test_balanced_never_worse_than_greedy_forced_cuts(self):
        """On a tiny forced partition the DP is at least as good."""
        fused = run_default_pipeline(cnn_graphs.cascade_conv(16, c_mid=8)).dfg
        bal = partition_layer_groups(fused, b_total=2)
        greedy = partition_layer_groups(fused, b_total=2, strategy="greedy")
        assert bal.max_group_cycles <= greedy.max_group_cycles

    def test_groups_cover_graph_in_topo_order(self, deep224_design):
        d = deep224_design
        covered = [n for g in d.groups for n in g.node_names]
        assert sorted(covered) == sorted(n.name for n in d.source.nodes)
        # every spill-out is a later group's spill-in
        outs = {v for g in d.groups for v in g.spill_out}
        ins = {v for g in d.groups for v in g.spill_in}
        assert outs == ins and outs

    def test_groupwise_semantics_preserved(self, deep224_design):
        """Interpreter-chained groups == whole graph, on a small clone
        of the same cut structure."""
        fused = run_default_pipeline(cnn_graphs.cascade_conv(16, c_mid=8)).dfg
        pp = partition_layer_groups(fused, b_total=2)
        assert pp.partitioned
        env = interp.random_env(fused, seed=11)
        whole = interp.graph_outputs(fused, env)
        chained = dict(env)
        for g in pp.groups:
            chained.update(interp.execute_dfg(g.dfg, chained))
        for k, v in whole.items():
            np.testing.assert_array_equal(np.asarray(v), np.asarray(chained[k]))


class TestWeightStreaming:
    """Tentpole: weight-dominated convs compile via DRAM-tiled weights."""

    def test_fat_conv_infeasible_without_streaming(self):
        fused = run_default_pipeline(cnn_graphs.fat_conv()).dfg
        whole = solve_ilp(plan_streams(fused))
        assert not whole.feasible

    def test_fat_conv_compiles_via_streaming(self):
        d = compile_design(cnn_graphs.fat_conv())
        assert d.feasible
        assert d.weight_streamed, "expected a weight-streamed node"
        (node, tiles), = d.weight_streamed.items()
        assert tiles > 1
        assert d.max_bram <= KV260_BRAM18K and d.max_dsp <= KV260_DSP

    def test_streaming_charges_dram_cycles(self):
        """The streamed design must be slower than a hypothetical
        resident-weight plan of the same unroll — the DRAM round trip is
        in the ledger, not hidden."""
        d = compile_design(cnn_graphs.fat_conv())
        g = d.groups[0]
        w_bits = sum(
            v.total_bits for v in g.dfg.values.values() if v.is_constant
        )
        dram_cycles = -(-2 * (w_bits // 8) // DRAM_BYTES_PER_CYCLE)
        assert g.cycles > dram_cycles  # round trip included in the total

    def test_solver_prefers_resident_weights_when_they_fit(self):
        """weight_streaming=True must not change designs that fit: the
        streamed variants are strictly slower, so the ILP ignores them."""
        plan = plan_streams(
            run_default_pipeline(cnn_graphs.conv_relu(32)).dfg
        )
        base = solve_ilp(plan)
        ws = solve_ilp(plan, weight_streaming=True)
        assert base.feasible and ws.feasible
        assert not ws.weight_tiles
        assert ws.objective_cycles == base.objective_cycles


class TestEmitConsumesDesign:
    def test_emit_design_weight_streamed_golden(self, tmp_path):
        import os

        d = compile_design(cnn_graphs.fat_conv())
        files = emit_design(d)
        golden = os.path.join(
            os.path.dirname(__file__), "golden", "fat_conv_16_g0.cpp"
        )
        with open(golden) as f:
            assert files["fat_conv_16_g0.cpp"] == f.read(), (
                "weight-streamed kernel drifted from golden — if "
                "intentional, regenerate tests/golden/ (this test shows "
                "the recipe)"
            )

    def test_double_buffered_kernel_structure(self):
        d = compile_design(cnn_graphs.fat_conv())
        files = emit_design(d)
        cpp = files["fat_conv_16_g0.cpp"]
        tiles = d.weight_streamed["conv0"]
        assert f"WT: for (int wt = 0; wt < {tiles}; ++wt)" in cpp
        assert "load_tile(wtile[0], dram_w0, 0);" in cpp     # preload
        assert (  # guarded prefetch — never reads past the last tile
            f"if (wt + 1 < {tiles}) load_tile(wtile[(wt + 1) & 1]" in cpp
        )
        assert "wtile[2][" in cpp
        assert "const elem_t *dram_w0" in cpp
        assert cpp.count("{") == cpp.count("}")
        host = files["host_schedule.cpp"]
        assert "wstream_w0" in host and "weights streamed" in host

    def test_single_group_design_emits(self):
        d = compile_design(cnn_graphs.conv_relu(32))
        files = emit_design(d)
        assert set(files) == {f"{d.groups[0].name}.cpp", "host_schedule.cpp"}
        assert "#pragma HLS DATAFLOW" in files[f"{d.groups[0].name}.cpp"]


class TestPallasConsumesDesign:
    """kernels/ops.run_compiled executes the identical schedule."""

    @pytest.mark.parametrize(
        "make",
        [
            lambda: cnn_graphs.conv_relu(8, c_out=4),
            lambda: cnn_graphs.cascade_conv(8, c_mid=4),
            lambda: cnn_graphs.conv_pool(8, c_out=4),
            lambda: cnn_graphs.residual_block(8, c=4),
            cnn_graphs.feed_forward,
        ],
        ids=["conv_relu", "cascade", "conv_pool", "residual", "feed_forward"],
    )
    def test_run_compiled_matches_interp(self, make):
        dfg = make()
        d = compile_design(dfg)
        env = interp.random_env(d.source, seed=7)
        want = interp.graph_outputs(d.source, env)
        got = ops_run(d, env)
        assert set(want) == set(got)
        for k in want:
            np.testing.assert_array_equal(np.asarray(want[k]), np.asarray(got[k]))

    def test_partitioned_design_chains_groups(self):
        fused = run_default_pipeline(cnn_graphs.cascade_conv(16, c_mid=8)).dfg
        pp = partition_layer_groups(fused, b_total=2)
        assert pp.partitioned
        env = interp.random_env(fused, seed=3)
        want = interp.graph_outputs(fused, env)
        got = ops_run(pp, env)
        for k in want:
            np.testing.assert_array_equal(np.asarray(want[k]), np.asarray(got[k]))


def ops_run(design, env):
    from repro.kernels import ops

    return ops.run_compiled(design, env, interpret=True)
