"""Batched device execution (ISSUE 7): vmapped group executables.

The acceptance contract: ``CompiledArtifact.run`` with the default
``batch_mode="vmap"`` is bit-exact against the per-sample loop
(``batch_mode="loop"``) on every zoo model on both targets, ragged
batches pad to buckets without leaking padding rows, each group
compiles at most once per batch bucket, and the exec cache is a real
LRU (bounded, evictions counted).
"""
import numpy as np
import pytest

from repro import api
from repro.core import cnn_graphs
from repro.core.compile_driver import KV260, ZU3EG
from repro.frontends import zoo
from repro.kernels import ops


def _batched_inputs(src, batch, seed=0):
    rng = np.random.default_rng(seed)
    return {
        k: rng.integers(-4, 5, size=(batch,) + src.values[k].shape,
                        dtype=np.int32)
        for k in src.graph_inputs
    }


def _assert_vmap_equals_loop(art, batch, seed=0):
    x = _batched_inputs(art.source, batch, seed)
    want = art.run(x, batch_mode="loop")
    got = art.run(x, batch_mode="vmap")
    if isinstance(want, dict):
        for k in want:
            np.testing.assert_array_equal(got[k], want[k])
    else:
        np.testing.assert_array_equal(got, want)
    assert art.last_run_stats["batch_mode"] == "vmap"
    assert art.last_run_stats["samples"] == batch


class TestBatchBuckets:
    def test_bucket_rounds_up(self):
        assert ops.batch_bucket(1) == 1
        assert ops.batch_bucket(3) == 4
        assert ops.batch_bucket(8) == 8
        assert ops.batch_bucket(17) == 32
        assert ops.batch_bucket(64) == 64

    def test_bucket_rejects_out_of_range(self):
        with pytest.raises(ValueError, match=">= 1"):
            ops.batch_bucket(0)
        with pytest.raises(ValueError, match="top bucket"):
            ops.batch_bucket(65)

    def test_chunks_cover_batch_exactly(self):
        chunks = list(ops._batch_chunks(70))
        assert chunks == [(0, 64, 64), (64, 6, 8)]
        assert list(ops._batch_chunks(5)) == [(0, 5, 8)]
        for batch in (1, 31, 64, 65, 200):
            spans = list(ops._batch_chunks(batch))
            assert sum(n for _, n, _ in spans) == batch
            assert all(n <= b for _, n, b in spans)


class TestVmapBitExact:
    @pytest.mark.parametrize("target", [KV260, ZU3EG], ids=["kv260", "zu3eg"])
    @pytest.mark.parametrize("model", sorted(zoo.ZOO))
    def test_zoo_models_both_targets(self, model, target):
        """The acceptance criterion, verbatim: every zoo model, both
        targets, batched run bit-exact vs the per-sample loop."""
        art = api.compile_graph(zoo.ZOO[model](),
                                api.CompileOptions(target=target))
        _assert_vmap_equals_loop(art, batch=3, seed=7)

    @pytest.mark.parametrize("make", [
        lambda: cnn_graphs.conv_relu(8, c_out=4),
        lambda: cnn_graphs.residual_block(8, c=4),
        lambda: cnn_graphs.feed_forward(batch=16, d_in=8, d_hidden=16),
    ], ids=["conv_relu", "residual", "feed_forward"])
    def test_builder_graphs(self, make):
        _assert_vmap_equals_loop(api.compile_graph(make()), batch=4)

    def test_random_builder_graphs_property(self):
        """Property-style sweep: random little Sequential stacks (seeded
        layer choices) must agree between the two batch modes."""
        rng = np.random.default_rng(42)
        for trial in range(3):
            layers = [api.Conv2D(int(rng.integers(2, 5)), kernel=3)]
            if rng.integers(2):
                layers.append(api.ReLU())
            if rng.integers(2):
                layers.append(api.MaxPool(2))
            layers += [api.Flatten(), api.Dense(int(rng.integers(3, 8)))]
            net = api.Sequential(
                layers, input_shape=(1, 8, 8, 2), name=f"rand{trial}"
            )
            target = (KV260, ZU3EG)[trial % 2]
            art = api.compile_graph(net, api.CompileOptions(target=target))
            _assert_vmap_equals_loop(art, batch=int(rng.integers(2, 6)),
                                     seed=trial)

    def test_multi_input_graph(self):
        g = api.Graph("two_in")
        a = g.input((1, 4, 4, 2), name="a")
        b = g.input((1, 4, 4, 2), name="b")
        g.output(g.add(a, b))
        art = api.compile_graph(g.build())
        _assert_vmap_equals_loop(art, batch=5)

    def test_bad_mode_rejected(self):
        art = api.compile_graph(cnn_graphs.conv_relu(8, c_out=4))
        with pytest.raises(ValueError, match="batch_mode"):
            art.run(_batched_inputs(art.source, 2), batch_mode="turbo")


class TestStridedConvProperty:
    """ISSUE 8 satellite: the generalized stride-s / VALID streaming
    path against ``jax.lax.conv_general_dilated``, random geometry,
    both targets, and vmap == loop on every config."""

    @staticmethod
    def _same_pads(n, k, s):
        out = -(-n // s)
        total = max(0, s * (out - 1) + k - n)
        return total // 2, total - total // 2

    def test_random_strided_valid_convs(self):
        import jax
        import jax.numpy as jnp

        rng = np.random.default_rng(1234)
        for trial in range(8):
            k = int(rng.integers(1, 6))
            s = int(rng.integers(1, 4))
            h = int(rng.integers(max(k, 6), 15))
            c_in = int(rng.integers(1, 4))
            c_out = int(rng.integers(2, 6))
            padding = "SAME" if trial % 2 == 0 else "VALID"
            target = (KV260, ZU3EG)[trial % 2]

            g = api.Graph(f"pconv{trial}")
            x_ref = g.input((1, h, h, c_in), name="x")
            g.output(g.conv2d(x_ref, c_out, kernel=k, stride=s,
                              padding=padding, weight="w"))
            art = api.compile_graph(g.build(),
                                    api.CompileOptions(target=target))
            assert art.feasible

            w = rng.integers(-4, 5, (k, k, c_in, c_out)).astype(np.int8)
            x = rng.integers(-4, 5, (1, h, h, c_in)).astype(np.int32)
            got = np.asarray(
                art.run({"x": x}, params={"w": w}, interpret=True)
            )
            pads = ((0, 0), (0, 0)) if padding == "VALID" else (
                self._same_pads(h, k, s), self._same_pads(h, k, s))
            want = jax.lax.conv_general_dilated(
                jnp.asarray(x, jnp.int32),
                jnp.asarray(w, jnp.int32),
                window_strides=(s, s),
                padding=pads,
                dimension_numbers=("NHWC", "HWIO", "NHWC"),
            )
            np.testing.assert_array_equal(
                got, np.asarray(want),
                err_msg=f"trial {trial}: k={k} s={s} h={h} "
                        f"c={c_in}->{c_out} {padding} @ {target.name}",
            )
            _assert_vmap_equals_loop(art, batch=3, seed=trial)


class TestIntegerAccumulators:
    """The fast batched integer-conv lowering (``conv2d_same_mm``) must
    return the same int32 accumulators as the streaming Pallas kernel:
    int8/int16 inputs previously accumulated (and wrapped) in the input
    dtype, silently changing batched-run results on sub-int32 models."""

    @pytest.mark.parametrize(
        "dtype", [np.int8, np.uint8, np.int16, np.int32],
        ids=["int8", "uint8", "int16", "int32"])
    def test_mm_matches_stream_dtype_and_values(self, dtype):
        import jax.numpy as jnp

        rng = np.random.default_rng(0)
        lo, hi = (0, 6) if dtype == np.uint8 else (-6, 6)
        x = rng.integers(lo, hi, size=(2, 8, 8, 5)).astype(dtype)
        w = rng.integers(lo, hi, size=(3, 3, 5, 4)).astype(dtype)
        a = ops.conv2d_stream(jnp.asarray(x), jnp.asarray(w),
                              interpret=True)
        b = ops.conv2d_same_mm(jnp.asarray(x), jnp.asarray(w))
        assert a.dtype == jnp.int32 and b.dtype == jnp.int32
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_int8_accumulation_exceeds_input_width(self):
        import jax.numpy as jnp

        # 3*3*16 taps of ~100*100 products: the accumulator is far
        # outside int8 (and int16) range, so wrapping would show
        rng = np.random.default_rng(1)
        x = rng.integers(50, 101, size=(1, 6, 6, 16)).astype(np.int8)
        w = rng.integers(50, 101, size=(3, 3, 16, 2)).astype(np.int8)
        a = ops.conv2d_stream(jnp.asarray(x), jnp.asarray(w),
                              interpret=True)
        b = ops.conv2d_same_mm(jnp.asarray(x), jnp.asarray(w))
        assert b.dtype == jnp.int32
        assert int(np.max(np.asarray(b))) > np.iinfo(np.int16).max
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_vmap_loop_bit_exact_int8_end_to_end(self):
        """An all-int8 batched run (inputs *and* weights — the PTQ
        regime the importer admits) must match the per-sample loop in
        dtype and bits through the artifact surface."""
        art = api.compile_graph(cnn_graphs.conv_relu(8, c_out=4))
        src = art.source
        rng = np.random.default_rng(2)
        x = {
            k: rng.integers(-4, 5,
                            size=(5,) + src.values[k].shape).astype(np.int8)
            for k in src.graph_inputs
        }
        params = {
            n: rng.integers(-4, 5, size=v.shape).astype(np.int8)
            for n, v in src.values.items() if v.is_constant
        }
        want = art.run(x, params, batch_mode="loop")
        got = art.run(x, params, batch_mode="vmap")
        assert want.dtype == got.dtype == np.int32
        np.testing.assert_array_equal(got, want)


class TestRaggedBatches:
    """Padding to a bucket must never leak into outputs."""

    @pytest.mark.parametrize("batch", [3, 5, 17])
    def test_ragged_equals_loop(self, batch):
        art = api.compile_graph(zoo.lenet5())
        _assert_vmap_equals_loop(art, batch=batch)

    def test_prefix_consistency_across_buckets(self):
        """Samples keep their identity whatever bucket the batch pads
        to: row i of a ragged batch equals row i of the full batch."""
        art = api.compile_graph(cnn_graphs.conv_relu(8, c_out=4))
        x = _batched_inputs(art.source, 8, seed=3)
        full = art.run(x)
        for n in (1, 3, 5):
            got = art.run({k: v[:n] for k, v in x.items()})
            np.testing.assert_array_equal(got, full[:n])

    def test_chunked_batch_over_top_bucket(self):
        """A batch above the top bucket splits into chunks and
        concatenates — still exact, still one stacked output."""
        art = api.compile_graph(cnn_graphs.conv_relu(8, c_out=4))
        x = _batched_inputs(art.source, 70, seed=5)
        got = art.run(x)
        assert got.shape[0] == 70
        want = art.run({k: v[:8] for k, v in x.items()})
        np.testing.assert_array_equal(got[:8], want)
        assert art.last_run_stats is not None


class TestCompileCounts:
    """≤1 compile per group per batch bucket (acceptance probe)."""

    def test_recompiles_bounded_by_buckets(self, monkeypatch):
        art = api.compile_graph(zoo.lenet5())
        n_groups = len(art.design.groups)
        builds = []
        real_build = ops._build_group_fn

        def probe(group, interpret, jit, batch=None):
            builds.append((group.name, batch))
            return real_build(group, interpret, jit, batch=batch)

        monkeypatch.setattr(ops, "_build_group_fn", probe)
        ops._EXEC_CACHE.clear()
        x = _batched_inputs(art.source, 8, seed=1)
        for batch in (3, 4, 2, 8, 3):  # buckets {4, 2, 8}
            art.run({k: v[:batch] for k, v in x.items()})
        batched_builds = [b for b in builds if b[1] is not None]
        assert len(batched_builds) == len(set(batched_builds))
        assert len(batched_builds) <= 3 * n_groups
        # same buckets again: zero new builds
        before = len(builds)
        for batch in (3, 4, 2, 8):
            art.run({k: v[:batch] for k, v in x.items()})
        assert len(builds) == before

    def test_exec_cache_delta_reports_hits(self):
        art = api.compile_graph(cnn_graphs.conv_relu(8, c_out=4))
        x = _batched_inputs(art.source, 4, seed=2)
        art.run(x)
        art.run(x)
        delta = art.last_run_stats["exec_cache"]
        assert delta["misses"] == 0 and delta["hits"] >= 1


class TestExecCacheLRU:
    """Satellite: the exec cache is bounded with counted evictions."""

    def test_eviction_at_cap(self, monkeypatch):
        monkeypatch.setattr(ops, "_EXEC_CACHE_CAP", 2)
        ops._EXEC_CACHE.clear()
        ev0 = ops.exec_cache_stats["evictions"]
        arts = [
            api.compile_graph(cnn_graphs.conv_relu(8, c_out=c))
            for c in (2, 3, 4)
        ]
        for art in arts:
            art.run(interpret=True)
        assert len(ops._EXEC_CACHE) <= 2
        assert ops.exec_cache_stats["evictions"] > ev0

    def test_lru_order_keeps_hot_entry(self, monkeypatch):
        monkeypatch.setattr(ops, "_EXEC_CACHE_CAP", 2)
        ops._EXEC_CACHE.clear()
        a = api.compile_graph(cnn_graphs.conv_relu(8, c_out=2))
        b = api.compile_graph(cnn_graphs.conv_relu(8, c_out=3))
        c = api.compile_graph(cnn_graphs.conv_relu(8, c_out=4))
        a.run(interpret=True)
        b.run(interpret=True)
        a.run(interpret=True)  # refresh a: b is now LRU
        h0 = ops.exec_cache_stats["hits"]
        c.run(interpret=True)  # evicts b, not a
        a.run(interpret=True)
        assert ops.exec_cache_stats["hits"] > h0
