"""Per-arch smoke tests (reduced configs, one forward/train step on CPU,
shape + finiteness assertions) and prefill↔decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeConfig, count_params
from repro.configs.registry import all_archs, get_config
from repro.data.pipeline import DataConfig, batch_for_model
from repro.launch import steps as ST
from repro.models import encdec, lm

SMOKE_SHAPE = ShapeConfig("smoke", 32, 2, "train")


def _smoke_batch(cfg):
    if cfg.family == "encdec":
        return {
            "frames": jnp.ones((2, 32, cfg.d_model), jnp.float32),
            "tokens": jnp.zeros((2, 16), jnp.int32),
            "labels": jnp.ones((2, 16), jnp.int32),
        }
    return batch_for_model(cfg, SMOKE_SHAPE, DataConfig(seed=0), 0)


@pytest.mark.parametrize("arch", all_archs())
class TestArchSmoke:
    def test_forward_loss(self, arch):
        cfg = get_config(arch, smoke=True)
        params = ST.model_init(jax.random.key(0), cfg)
        loss = ST.model_loss(params, cfg, _smoke_batch(cfg))
        assert loss.shape == ()
        assert bool(jnp.isfinite(loss)), arch
        assert float(loss) > 0

    def test_train_step_no_nans(self, arch):
        from repro.optim import adamw

        cfg = get_config(arch, smoke=True)
        params = ST.model_init(jax.random.key(0), cfg)
        opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
        opt = adamw.init(params, opt_cfg)
        step = ST.make_train_step(cfg, opt_cfg)
        params, opt, metrics = jax.jit(step)(params, opt, _smoke_batch(cfg))
        assert bool(jnp.isfinite(metrics["loss"]))
        assert bool(jnp.isfinite(metrics["grad_norm"]))
        for leaf in jax.tree.leaves(params):
            assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32)))), arch

    def test_prefill_decode_shapes(self, arch):
        cfg = get_config(arch, smoke=True)
        params = ST.model_init(jax.random.key(0), cfg)
        b = _smoke_batch(cfg)
        b.pop("labels", None)
        if cfg.family == "encdec":
            b.pop("tokens", None)
        logits, caches = ST.model_prefill(params, cfg, b)
        assert logits.shape == (2, cfg.vocab_size)
        assert bool(jnp.all(jnp.isfinite(logits)))

    def test_param_count_matches_analytic(self, arch):
        """count_params (used for MODEL_FLOPS) must equal the real pytree."""
        cfg = get_config(arch, smoke=True)
        params = ST.model_init(jax.random.key(0), cfg)
        actual = sum(x.size for x in jax.tree.leaves(params))
        expected = count_params(cfg)
        assert actual == expected, (arch, actual, expected)


class TestPrefillDecodeConsistency:
    """Decoding from a prefilled cache must reproduce teacher-forced
    full-sequence logits (the KV-cache correctness contract)."""

    @pytest.mark.parametrize("arch", ["llama3.2-1b", "qwen2-0.5b",
                                      "mamba2-1.3b", "olmoe-1b-7b",
                                      "jamba-1.5-large-398b"])
    def test_decode_matches_full_forward(self, arch):
        import dataclasses

        cfg = get_config(arch, smoke=True).with_(remat=False)
        if cfg.moe is not None:
            # capacity-dropped tokens legitimately differ between a 15- and
            # 16-token forward; the cache contract is exact modulo drops —
            # test it drop-free (capacity ≫ tokens)
            cfg = cfg.with_(
                moe=dataclasses.replace(cfg.moe, capacity_factor=8.0)
            )
        params = ST.model_init(jax.random.key(1), cfg)
        tokens = jax.random.randint(jax.random.key(2), (2, 16), 0,
                                    cfg.vocab_size)

        # full forward logits at the last position
        logits_full, caches = lm.lm_prefill(params, cfg, {"tokens": tokens})

        # prefill on the prefix, then decode the last token
        prefix = tokens[:, :-1]
        _, pcaches = lm.lm_prefill(params, cfg, {"tokens": prefix})
        cache = lm.init_cache(cfg, 2, 16)
        cache = _load_cache(cache, pcaches, 15)
        logits_dec, _ = lm.lm_decode(
            params, cfg, cache, tokens[:, -1], jnp.asarray(15, jnp.int32)
        )
        np.testing.assert_allclose(
            np.asarray(logits_dec), np.asarray(logits_full),
            atol=3e-2, rtol=3e-2,
        )

    def test_encdec_decode_matches_teacher_forced(self):
        cfg = get_config("seamless-m4t-medium", smoke=True).with_(remat=False)
        params = ST.model_init(jax.random.key(1), cfg)
        frames = jax.random.normal(jax.random.key(2), (2, 16, cfg.d_model))
        tokens = jax.random.randint(jax.random.key(3), (2, 8), 0,
                                    cfg.vocab_size)

        memory = encdec.encode(params, cfg, frames)
        h = encdec.decode_train(params, cfg, memory, tokens)
        logits_full = (h[:, -1] @ params["lm_head"]).astype(jnp.float32)

        # decode token-by-token
        cache = encdec.init_cache(cfg, 2, mem_len=16, max_len=8)
        ck, cv = jax.vmap(
            lambda p: encdec._cross_kv(p["cross_attn"], cfg, memory)
        )(params["decoder"]["blocks"])
        cache["ck"], cache["cv"] = ck, cv
        for t in range(8):
            logits_dec, cache = encdec.encdec_decode(
                params, cfg, cache, tokens[:, t], jnp.asarray(t, jnp.int32)
            )
        np.testing.assert_allclose(
            np.asarray(logits_dec), np.asarray(logits_full),
            atol=3e-2, rtol=3e-2,
        )


def _load_cache(zeroed, prefill_caches, plen):
    """Copy tight prefill caches into the bounded decode cache layout."""

    def merge(path, dst):
        src = prefill_caches
        for k in path:
            src = src[getattr(k, "key", k)]
        if src.shape != dst.shape:
            pad = [(0, d - s) for d, s in zip(dst.shape, src.shape)]
            return jnp.pad(src.astype(dst.dtype), pad)
        return src.astype(dst.dtype)

    return jax.tree_util.tree_map_with_path(merge, zeroed)


class TestModelInvariants:
    def test_mamba_decode_matches_full_scan(self):
        from repro.models import mamba2 as M

        cfg = get_config("mamba2-1.3b", smoke=True)
        p = M.init_mamba(jax.random.key(0), cfg)
        x = jax.random.normal(jax.random.key(1), (2, 9, cfg.d_model),
                              jnp.float32).astype(cfg.param_dtype)
        full = M.mamba_layer(p, cfg, x)

        # streaming decode over the same sequence
        s = cfg.ssm
        conv = jnp.zeros((2, s.conv_kernel - 1, s.conv_dim(cfg.d_model)),
                         cfg.param_dtype)
        ssm = jnp.zeros(
            (2, s.num_heads(cfg.d_model), s.head_dim, s.state_dim), jnp.float32
        )
        outs = []
        for t in range(9):
            y, conv, ssm = M.mamba_decode(p, cfg, x[:, t : t + 1], conv, ssm)
            outs.append(y)
        stream = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(
            np.asarray(stream, np.float32), np.asarray(full, np.float32),
            atol=5e-2, rtol=5e-2,
        )

    def test_moe_capacity_drops_bounded(self):
        """Dropped-token fraction stays small at capacity_factor=1.25."""
        from repro.models import moe as MOE

        cfg = get_config("olmoe-1b-7b", smoke=True)
        p = MOE.init_moe(jax.random.key(0), cfg)
        x = jax.random.normal(jax.random.key(1), (4, 32, cfg.d_model),
                              jnp.float32).astype(cfg.param_dtype)
        y = MOE.moe_layer(p, cfg, x)
        assert y.shape == x.shape
        assert bool(jnp.all(jnp.isfinite(y.astype(jnp.float32))))
        # a zero output row would mean the token lost all k experts
        row_norms = jnp.linalg.norm(
            y.reshape(-1, cfg.d_model).astype(jnp.float32), axis=-1
        )
        assert float(jnp.mean(row_norms == 0)) < 0.05

    def test_mrope_differs_from_rope(self):
        from repro.models import layers as L

        cfg = get_config("qwen2-vl-72b", smoke=True)
        p = L.init_attention(jax.random.key(0), cfg)
        x = jax.random.normal(jax.random.key(1), (1, 8, cfg.d_model),
                              jnp.float32).astype(cfg.param_dtype)
        pos = jnp.arange(8, dtype=jnp.int32)[None]
        text_stream = jnp.broadcast_to(pos, (3, 1, 8))
        img_stream = text_stream.at[1].set(pos * 2).at[2].set(pos * 3)
        o1, _ = L.attention_layer(p, cfg, x, pos, mrope_positions=text_stream)
        o2, _ = L.attention_layer(p, cfg, x, pos, mrope_positions=img_stream)
        assert not np.allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32))

    def test_hybrid_superblock_pattern(self):
        cfg = get_config("jamba-1.5-large-398b")
        pat = lm.superblock_pattern(cfg)
        assert len(pat) == 8
        assert sum(1 for s in pat if s.mixer == "attn") == 1   # 1-in-8
        assert sum(1 for s in pat if s.ffn == "moe") == 4      # alternate MoE
        assert cfg.num_layers % len(pat) == 0
