"""Model-card format (ISSUE 5): schema validation, weight embedding,
and the load-bearing contract — ``import_card(export_card(g))`` is
node-for-node identical to ``g`` for any builder graph (reusing the
PR 4 equality pins via dataclass equality).
"""
import json

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_fallback import given, settings, st

from repro.api.builder import (
    AvgPool,
    Conv2D,
    Dense,
    Flatten,
    Graph,
    MaxPool,
    ReLU,
    Residual,
    Sequential,
)
from repro.core import cnn_graphs
from repro.frontends import (
    ModelCardError,
    ZOO,
    export_card,
    import_card,
    import_model,
)
from repro.frontends.modelcard import FORMAT, SCHEMA_VERSION
from test_frontend import assert_dfg_equal


def roundtrip(dfg):
    """export → JSON text → import (the on-disk path, not just dicts)."""
    card = json.loads(json.dumps(export_card(dfg)))
    return import_card(card)


class TestRoundTrip:
    @pytest.mark.parametrize("name", sorted(cnn_graphs.PAPER_SUITE))
    def test_paper_suite_round_trips(self, name):
        dfg = cnn_graphs.PAPER_SUITE[name]()
        assert_dfg_equal(roundtrip(dfg).dfg, dfg)

    @pytest.mark.parametrize("name", sorted(ZOO))
    def test_zoo_round_trips(self, name):
        dfg = ZOO[name]()
        assert_dfg_equal(roundtrip(dfg).dfg, dfg)

    def test_showcases_round_trip(self):
        for make in (cnn_graphs.conv_pool, cnn_graphs.conv_avgpool,
                     cnn_graphs.fat_conv):
            dfg = make()
            assert_dfg_equal(roundtrip(dfg).dfg, dfg)

    def test_reorder_ops_round_trip(self):
        g = Graph("r")
        x = g.input((1, 2, 6, 6))
        h = g.transpose(x, (0, 2, 3, 1))
        h = g.conv2d(h, 4)
        h = g.transpose(h, (0, 3, 1, 2))
        h = g.flatten(h)
        g.output(g.dense(h, 5))
        dfg = g.build()
        assert_dfg_equal(roundtrip(dfg).dfg, dfg)

    def test_non_default_flatten_order_round_trips(self):
        g = Graph("r")
        x = g.input((1, 4, 6, 2))
        g.output(g.flatten(x, order=(3, 1, 2)))
        dfg = g.build()
        assert_dfg_equal(roundtrip(dfg).dfg, dfg)

    def test_bare_constant_add_round_trips(self):
        g = Graph("bias")
        x = g.input((1, 8))
        k = g.constant((1, 8), name="bias0")
        g.output(g.add(x, k))
        dfg = g.build()
        assert_dfg_equal(roundtrip(dfg).dfg, dfg)


class TestRoundTripProperty:
    @given(st.integers(4, 16), st.integers(1, 6), st.integers(1, 3),
           st.integers(0, 2))
    @settings(max_examples=20, deadline=None)
    def test_random_builder_graphs_round_trip(self, n, c, layers, head):
        """Random conv cascades with optional pool/residual/dense heads
        — every one must survive export → import node-for-node."""
        specs = []
        for _ in range(layers):
            specs += [Conv2D(c), ReLU()]
        if head == 1:
            specs += [MaxPool(2) if n % 2 == 0 else ReLU()]
        elif head == 2:
            specs += [Residual([Conv2D(c), ReLU(), Conv2D(c)]),
                      Flatten(), Dense(4)]
        net = Sequential(specs, input_shape=(1, n, n, c), name="rand")
        dfg = net.build()
        assert_dfg_equal(roundtrip(dfg).dfg, dfg)


class TestWeights:
    def test_params_embed_and_decode(self):
        dfg = ZOO["lenet5"]()
        rng = np.random.default_rng(0)
        params = {
            name: rng.integers(-4, 5, v.shape).astype(np.int8)
            for name, v in dfg.values.items() if v.is_constant
        }
        m = import_card(export_card(dfg, params=params))
        assert m.missing_params() == []
        for k, v in params.items():
            np.testing.assert_array_equal(m.params[k], v)

    def test_partial_params_are_reported_missing(self):
        dfg = cnn_graphs.conv_relu(8, c_out=4)
        (wname,) = [n for n, v in dfg.values.items() if v.is_constant]
        m = import_card(export_card(dfg))
        assert m.missing_params() == [wname]

    def test_param_shape_mismatch_rejected(self):
        dfg = cnn_graphs.conv_relu(8, c_out=4)
        with pytest.raises(ModelCardError, match="shape"):
            export_card(dfg, params={"w0": np.zeros((2, 2), np.int8)})

    def test_param_unknown_name_rejected(self):
        dfg = cnn_graphs.conv_relu(8, c_out=4)
        with pytest.raises(ModelCardError, match="not a constant"):
            export_card(dfg, params={"nope": np.zeros((1,), np.int8)})

    def test_imported_weights_flow_into_run(self):
        from repro import api

        dfg = cnn_graphs.conv_relu(8, c_out=4)
        rng = np.random.default_rng(1)
        params = {"w0": rng.integers(-3, 4, (3, 3, 3, 4)).astype(np.int8)}
        m = import_card(export_card(dfg, params=params))
        art = api.compile_graph(m.dfg)
        x = rng.integers(-3, 4, (1, 8, 8, 3)).astype(np.int32)
        got = np.asarray(art.run(x, params=m.params, interpret=True))
        from repro.kernels import ref

        want = np.maximum(
            np.asarray(ref.conv2d(x, params["w0"].astype(np.int32))), 0
        )
        np.testing.assert_array_equal(got, want)


class TestValidation:
    def test_format_and_version_checked(self):
        card = export_card(cnn_graphs.conv_relu(8, c_out=4))
        bad = dict(card, format="something-else")
        with pytest.raises(ModelCardError, match="not a ming-modelcard"):
            import_card(bad)
        bad = dict(card, version=99)
        with pytest.raises(ModelCardError, match="version"):
            import_card(bad)

    def test_unknown_op_rejected(self):
        card = export_card(cnn_graphs.conv_relu(8, c_out=4))
        bad = dict(card, layers=card["layers"] + [{"op": "softmax"}])
        with pytest.raises(ModelCardError, match="unknown op"):
            import_card(bad)

    def test_dangling_reference_rejected(self):
        card = export_card(cnn_graphs.conv_relu(8, c_out=4))
        bad = json.loads(json.dumps(card))
        bad["layers"][0]["input"] = "ghost"
        with pytest.raises(ModelCardError, match="ghost"):
            import_card(bad)

    def test_missing_sections_rejected(self):
        for drop in ("inputs", "layers", "outputs", "name"):
            card = export_card(cnn_graphs.conv_relu(8, c_out=4))
            del card[drop]
            with pytest.raises(ModelCardError):
                import_card(card)

    def test_invalid_json_text_rejected(self):
        with pytest.raises(ModelCardError, match="JSON"):
            import_card("{not json")

    def test_missing_file_is_file_not_found(self, capsys):
        """A typo'd path must surface as file-not-found, not as
        'invalid JSON' (the inline-document fallback only engages for
        strings that look like JSON)."""
        with pytest.raises(FileNotFoundError):
            import_card("examples/lent5.json")
        from repro.__main__ import main as cli_main

        assert cli_main(["compile", "examples/lent5.json"]) == 2
        assert "No such file" in capsys.readouterr().err

    def test_fused_graphs_not_exportable(self):
        from repro.passes import run_default_pipeline

        fused = run_default_pipeline(cnn_graphs.conv_relu(8, c_out=4)).dfg
        with pytest.raises(ModelCardError, match="pre-pass"):
            export_card(fused)


class TestFilesAndDispatch:
    def test_card_file_import_and_dispatch(self, tmp_path):
        from repro.frontends import zoo

        path = tmp_path / "lenet5.json"
        path.write_text(zoo.card_json("lenet5"))
        m = import_model(str(path))
        assert_dfg_equal(m.dfg, zoo.lenet5())

    def test_examples_lenet5_card_matches_zoo(self):
        import os

        from repro.frontends import zoo

        path = os.path.join(os.path.dirname(__file__), "..", "examples",
                            "lenet5.json")
        m = import_card(path)
        assert_dfg_equal(m.dfg, zoo.lenet5())

    def test_unknown_extension_rejected(self):
        with pytest.raises(ValueError, match="unknown model extension"):
            import_model("model.yaml")

    def test_card_constants(self):
        card = export_card(ZOO["lenet5"]())
        assert card["format"] == FORMAT
        assert card["version"] == SCHEMA_VERSION
