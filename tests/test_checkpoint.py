"""Checkpointing: atomicity, GC, async, elastic re-mesh restore."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager


def _tree(x=0.0):
    return {
        "params": {"w": jnp.full((4, 4), 1.0 + x), "b": jnp.zeros(4)},
        "opt": {"mu": jnp.full((4, 4), 2.0 + x)},
    }


class TestRoundtrip:
    def test_save_restore_bitexact(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        tree = _tree(0.5)
        mgr.save(7, tree, extra={"note": "x"})
        restored, extra = mgr.restore(7, tree)
        assert extra == {"note": "x"}
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_latest_step(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        assert mgr.latest_step() is None
        mgr.save(1, _tree())
        mgr.save(5, _tree())
        assert mgr.latest_step() == 5

    def test_structure_mismatch_caught(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, _tree())
        with pytest.raises(AssertionError):
            mgr.restore(1, {"params": {"w": jnp.zeros((4, 4))}})


class TestAtomicity:
    def test_tmp_dirs_invisible(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(3, _tree())
        # simulate a crash mid-write: stray .tmp with garbage
        os.makedirs(tmp_path / "step_000000009.tmp")
        assert mgr.latest_step() == 3

    def test_manifest_required(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        os.makedirs(tmp_path / "step_000000004")  # no manifest → not committed
        assert mgr.latest_step() is None

    def test_gc_keeps_newest(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        for s in (1, 2, 3, 4):
            mgr.save(s, _tree())
        steps = sorted(
            int(d.split("_")[1]) for d in os.listdir(tmp_path)
            if d.startswith("step_")
        )
        assert steps == [3, 4]


class TestAsync:
    def test_async_write_then_wait(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save_async(11, _tree(1.0))
        mgr.wait()
        restored, _ = mgr.restore(11, _tree())
        assert float(jax.tree.leaves(restored)[0][0, 0]) == pytest.approx(3.0)

    def test_async_snapshot_semantics(self, tmp_path):
        """Mutating the live tree after save_async must not corrupt the
        checkpoint (snapshot is taken synchronously)."""
        mgr = CheckpointManager(str(tmp_path))
        import numpy as onp

        live = {"w": onp.ones(4)}
        mgr.save_async(1, live)
        live["w"][:] = 99.0
        mgr.wait()
        restored, _ = mgr.restore(1, {"w": jnp.zeros(4)})
        np.testing.assert_array_equal(np.asarray(restored["w"]), np.ones(4))


class TestElasticRemesh:
    def test_restore_onto_different_mesh(self, subproc, tmp_path):
        """Save on a (4,2) mesh, restore onto (2,2,2) and a single device —
        checkpoints are mesh-agnostic logical arrays."""
        code = f"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint.manager import CheckpointManager
from repro.launch.mesh import make_host_mesh

mgr = CheckpointManager({str(tmp_path)!r})
mesh1 = make_host_mesh((4, 2), ("data", "model"))
w = jnp.arange(64.0).reshape(8, 8)
sharded = jax.device_put(w, NamedSharding(mesh1, P("data", "model")))
mgr.save(1, {{"w": sharded}})

mesh2 = make_host_mesh((2, 2, 2), ("pod", "data", "model"))
tgt = NamedSharding(mesh2, P(("pod", "data"), "model"))
restored, _ = mgr.restore(1, {{"w": w}}, {{"w": tgt}})
np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(w))
assert restored["w"].sharding == tgt
single, _ = mgr.restore(1, {{"w": w}})
np.testing.assert_array_equal(np.asarray(single["w"]), np.asarray(w))
print("OK")
"""
        r = subproc(code, devices=8)
        assert r.returncode == 0, r.stderr[-2000:]
        assert "OK" in r.stdout
