"""Multi-group HLS emission: golden files + structural invariants."""
import os

import pytest

from repro.core import cnn_graphs
from repro.core.emit_hls import emit_partitioned
from repro.passes import partition_layer_groups, run_default_pipeline

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")


@pytest.fixture(scope="module")
def emitted():
    """The deterministic forced-partition scenario behind the goldens."""
    fused = run_default_pipeline(cnn_graphs.cascade_conv(16, c_mid=8)).dfg
    pp = partition_layer_groups(fused, b_total=2)
    assert pp.partitioned
    return pp, emit_partitioned(pp)


class TestGolden:
    @pytest.mark.parametrize(
        "fname",
        [
            "cascade_conv_16_g0.cpp",
            "cascade_conv_16_g1.cpp",
            "host_schedule.cpp",
        ],
    )
    def test_matches_golden(self, emitted, fname, golden_check):
        _, files = emitted
        golden_check(f"cascade16_{fname}", files[fname])

    def test_zu3eg_emission_golden(self, golden_check):
        """The ZU3EG budget flips fat_conv from weight-streamed (KV260)
        to resident weights: the emitted kernel must carry no wtile
        ping/pong loop and no m_axi weight pointer."""
        from repro.core.compile_driver import ZU3EG, compile_design

        d = compile_design(cnn_graphs.fat_conv(), ZU3EG)
        assert not d.weight_streamed and len(d.groups) == 1
        files = emit_partitioned(d)
        cpp = files["fat_conv_16_g0.cpp"]
        assert "wtile" not in cpp and "dram_w0" not in cpp
        golden_check("fat_conv_16_zu3eg_g0.cpp", cpp)


class TestStructure:
    def test_one_file_per_group_plus_schedule(self, emitted):
        pp, files = emitted
        assert set(files) == {f"{g.name}.cpp" for g in pp.groups} | {
            "host_schedule.cpp"
        }

    def test_group_kernels_are_complete_dataflow_designs(self, emitted):
        pp, files = emitted
        for g in pp.groups:
            cpp = files[f"{g.name}.cpp"]
            assert "#pragma HLS DATAFLOW" in cpp
            assert f"void {g.name}(" in cpp
            # the DDR-pointer entry the host schedule links against
            assert f'extern "C" void {g.name}_m_axi(' in cpp
            assert cpp.count("{") == cpp.count("}")
            for node in g.dfg.nodes:
                assert f"void {node.name}(" in cpp

    def test_fused_epilogue_emitted(self, emitted):
        pp, files = emitted
        assert any(
            "// fused relu" in files[f"{g.name}.cpp"] for g in pp.groups
        )

    def test_host_schedule_threads_spills(self, emitted):
        pp, files = emitted
        host = files["host_schedule.cpp"]
        for s in pp.spills():
            assert f"static elem_t spill_{s.value}[{s.bytes}];" in host
        # groups invoked in order, spill buffers threaded between them
        last = -1
        for g in pp.groups:
            pos = host.index(f"  {g.name}_m_axi(")
            assert pos > last
            last = pos

    def test_deep_cascade_224_emits(self, deep224_fused, deep224_partition):
        """The acceptance graph's partitioned artifact is well-formed."""
        fused, pp = deep224_fused, deep224_partition
        files = emit_partitioned(pp)
        host = files["host_schedule.cpp"]
        assert f"void run_{fused.name}(" in host
        assert len(files) == len(pp.groups) + 1
        for g in pp.groups:
            assert files[f"{g.name}.cpp"].count("{") == files[
                f"{g.name}.cpp"
            ].count("}")
