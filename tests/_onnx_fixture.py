"""Minimal protobuf *encoder* for ONNX test fixtures.

The mirror image of the vendored decoder in
``repro.frontends.onnx_reader``: enough of the ModelProto wire format to
synthesize small CNN checkpoints in-memory, so the reader's no-``onnx``
path is exercised against real bytes (and so ``tests/golden/lenet5.onnx``
can be regenerated deterministically — run this module as a script).

Encoder and decoder are developed against the same field tables but
share no code, which is the point: a decoder bug cannot cancel out in
the round trip.
"""
from __future__ import annotations

import struct

import numpy as np

# TensorProto.DataType
FLOAT, UINT8, INT8, INT32, INT64 = 1, 2, 3, 6, 7

_NP_CODES = {
    np.dtype(np.float32): FLOAT,
    np.dtype(np.uint8): UINT8,
    np.dtype(np.int8): INT8,
    np.dtype(np.int32): INT32,
    np.dtype(np.int64): INT64,
}

# AttributeProto.AttributeType
_AT_FLOAT, _AT_INT, _AT_STRING, _AT_INTS = 1, 2, 3, 7


def _varint(v: int) -> bytes:
    if v < 0:
        v += 1 << 64
    out = bytearray()
    while True:
        b = v & 0x7F
        v >>= 7
        if v:
            out.append(b | 0x80)
        else:
            out.append(b)
            return bytes(out)


def _tag(fno: int, wt: int) -> bytes:
    return _varint((fno << 3) | wt)


def _int_field(fno: int, v: int) -> bytes:
    return _tag(fno, 0) + _varint(v)


def _bytes_field(fno: int, payload: bytes) -> bytes:
    return _tag(fno, 2) + _varint(len(payload)) + payload


def _str_field(fno: int, s: str) -> bytes:
    return _bytes_field(fno, s.encode())


def _float_field(fno: int, f: float) -> bytes:
    return _tag(fno, 5) + struct.pack("<f", f)


def tensor(name: str, arr: np.ndarray) -> bytes:
    """TensorProto with raw_data."""
    a = np.ascontiguousarray(arr)
    code = _NP_CODES[a.dtype]
    out = b"".join(_int_field(1, int(d)) for d in a.shape)
    out += _int_field(2, code)
    out += _str_field(8, name)
    out += _bytes_field(9, a.tobytes())
    return out


def value_info(name: str, shape, elem_type: int = INT8,
               symbolic: str | None = None) -> bytes:
    dims = b""
    for d in shape:
        dims += _bytes_field(1, _int_field(1, int(d)))
    if symbolic is not None:
        dims += _bytes_field(1, _str_field(2, symbolic))
    shape_msg = _bytes_field(2, dims)
    tensor_type = _bytes_field(1, _int_field(1, elem_type) + shape_msg)
    return _str_field(1, name) + _bytes_field(2, tensor_type)


def attr_int(name: str, v: int) -> bytes:
    return (_str_field(1, name) + _int_field(3, v)
            + _int_field(20, _AT_INT))


def attr_ints(name: str, vals) -> bytes:
    out = _str_field(1, name)
    for v in vals:
        out += _int_field(8, int(v))
    return out + _int_field(20, _AT_INTS)


def attr_float(name: str, f: float) -> bytes:
    return (_str_field(1, name) + _float_field(2, f)
            + _int_field(20, _AT_FLOAT))


def attr_string(name: str, s: str) -> bytes:
    return (_str_field(1, name) + _bytes_field(4, s.encode())
            + _int_field(20, _AT_STRING))


def node(op_type: str, inputs, outputs, name: str = "",
         attrs=()) -> bytes:
    out = b"".join(_str_field(1, i) for i in inputs)
    out += b"".join(_str_field(2, o) for o in outputs)
    out += _str_field(3, name)
    out += _str_field(4, op_type)
    out += b"".join(_bytes_field(5, a) for a in attrs)
    return out


def graph(name: str, nodes, initializers, inputs, outputs) -> bytes:
    out = b"".join(_bytes_field(1, n) for n in nodes)
    out += _str_field(2, name)
    out += b"".join(_bytes_field(5, t) for t in initializers)
    out += b"".join(_bytes_field(11, vi) for vi in inputs)
    out += b"".join(_bytes_field(12, vi) for vi in outputs)
    return out


def model(graph_bytes: bytes, ir_version: int = 8,
          opset: int = 13) -> bytes:
    opset_import = _str_field(1, "") + _int_field(2, opset)
    return (
        _int_field(1, ir_version)
        + _str_field(2, "ming-repro-fixture")
        + _bytes_field(7, graph_bytes)
        + _bytes_field(8, opset_import)
    )


# ---------------------------------------------------------------------------
# The LeNet-5 fixture (int8 weights, int32 biases — integer-exact)
# ---------------------------------------------------------------------------


def lenet5_weights(seed: int = 0) -> dict[str, np.ndarray]:
    rng = np.random.default_rng(seed)

    def w8(*shape):
        return rng.integers(-4, 5, shape).astype(np.int8)

    def b32(n):
        return rng.integers(-8, 9, (n,)).astype(np.int32)

    return {
        "conv1_w": w8(6, 1, 5, 5), "conv1_b": b32(6),
        "conv2_w": w8(16, 6, 5, 5), "conv2_b": b32(16),
        "fc1_w": w8(120, 1024), "fc1_b": b32(120),
        "fc2_w": w8(84, 120), "fc2_b": b32(84),
        "fc3_w": w8(10, 84), "fc3_b": b32(10),
    }


def lenet5_model_bytes(seed: int = 0) -> bytes:
    """LeNet-5 (SAME-padding variant) as NCHW ONNX bytes: the golden
    fixture ``tests/golden/lenet5.onnx`` is exactly this with seed 0."""
    w = lenet5_weights(seed)
    conv_attrs = lambda k: (attr_ints("kernel_shape", [k, k]),  # noqa: E731
                            attr_ints("strides", [1, 1]),
                            attr_ints("pads", [(k - 1) // 2] * 4))
    pool_attrs = (attr_ints("kernel_shape", [2, 2]),
                  attr_ints("strides", [2, 2]))
    gemm_attrs = (attr_int("transB", 1), attr_float("alpha", 1.0),
                  attr_float("beta", 1.0))
    nodes = [
        node("Conv", ["input", "conv1_w", "conv1_b"], ["c1"], "conv1",
             conv_attrs(5)),
        node("Relu", ["c1"], ["r1"], "relu1"),
        node("MaxPool", ["r1"], ["p1"], "pool1", pool_attrs),
        node("Conv", ["p1", "conv2_w", "conv2_b"], ["c2"], "conv2",
             conv_attrs(5)),
        node("Relu", ["c2"], ["r2"], "relu2"),
        node("MaxPool", ["r2"], ["p2"], "pool2", pool_attrs),
        node("Flatten", ["p2"], ["flat"], "flatten", (attr_int("axis", 1),)),
        node("Gemm", ["flat", "fc1_w", "fc1_b"], ["f1"], "fc1", gemm_attrs),
        node("Relu", ["f1"], ["fr1"], "relu3"),
        node("Gemm", ["fr1", "fc2_w", "fc2_b"], ["f2"], "fc2", gemm_attrs),
        node("Relu", ["f2"], ["fr2"], "relu4"),
        node("Gemm", ["fr2", "fc3_w", "fc3_b"], ["logits"], "fc3",
             gemm_attrs),
    ]
    g = graph(
        "lenet5",
        nodes,
        [tensor(k, v) for k, v in w.items()],
        [value_info("input", (1, 1, 32, 32), INT8)],
        [value_info("logits", (1, 10), INT32)],
    )
    return model(g)


# ---------------------------------------------------------------------------
# NumPy NCHW oracle (independent of the repo's executors)
# ---------------------------------------------------------------------------


def lenet5_numpy(x: np.ndarray, w: dict[str, np.ndarray]) -> np.ndarray:
    """Reference forward pass on NCHW int inputs, int64 accumulation."""
    from numpy.lib.stride_tricks import sliding_window_view

    def conv(x, wgt, b):  # x (1,C,H,W), wgt (O,C,k,k)
        k = wgt.shape[2]
        p = (k - 1) // 2
        xp = np.pad(x, ((0, 0), (0, 0), (p, p), (p, p)))
        win = sliding_window_view(xp, (k, k), axis=(2, 3))
        out = np.einsum("nchwij,ocij->nohw", win.astype(np.int64),
                        wgt.astype(np.int64))
        return out + b[None, :, None, None]

    def pool(x):
        n, c, h, wdt = x.shape
        return x.reshape(n, c, h // 2, 2, wdt // 2, 2).max(axis=(3, 5))

    relu = lambda v: np.maximum(v, 0)  # noqa: E731
    h = relu(conv(x, w["conv1_w"], w["conv1_b"]))
    h = pool(h)
    h = relu(conv(h, w["conv2_w"], w["conv2_b"]))
    h = pool(h)
    h = h.reshape(1, -1)
    h = relu(h @ w["fc1_w"].T.astype(np.int64) + w["fc1_b"])
    h = relu(h @ w["fc2_w"].T.astype(np.int64) + w["fc2_b"])
    return h @ w["fc3_w"].T.astype(np.int64) + w["fc3_b"]


# ---------------------------------------------------------------------------
# The strided ResNet-style fixture (ISSUE 8): stride-2 downsample convs
# under three padding spellings (auto_pad SAME_UPPER, explicit
# SAME-frame pads, auto_pad VALID with an even kernel), inference-mode
# BatchNormalization after the first two convs, and a
# GlobalAveragePool head.  BN statistics are float32 but integral with
# var=1 and epsilon=0, so the importer's conv fold is integer-exact.
# ---------------------------------------------------------------------------


def same4(n: int, k: int, s: int) -> tuple[int, int]:
    """End-heavy (begin, end) SAME_UPPER split for one spatial axis."""
    out = -(-n // s)
    total = max(0, s * (out - 1) + k - n)
    return total // 2, total - total // 2


def resnet_tiny_weights(seed: int = 0) -> dict[str, np.ndarray]:
    rng = np.random.default_rng(seed)

    def w8(*shape):
        return rng.integers(-4, 5, shape).astype(np.int8)

    def b32(n):
        return rng.integers(-8, 9, (n,)).astype(np.int32)

    def bn(prefix, c):
        return {
            f"{prefix}_scale": rng.integers(1, 3, (c,)).astype(np.float32),
            f"{prefix}_B": rng.integers(-8, 9, (c,)).astype(np.float32),
            f"{prefix}_mean": rng.integers(-8, 9, (c,)).astype(np.float32),
            f"{prefix}_var": np.ones(c, np.float32),
        }

    out = {
        "c1_w": w8(8, 3, 3, 3), "c1_b": b32(8),
        "c2_w": w8(16, 8, 3, 3),
        "c3_w": w8(16, 16, 2, 2), "c3_b": b32(16),
        "fc_w": w8(10, 16), "fc_b": b32(10),
    }
    out.update(bn("bn1", 8))
    out.update(bn("bn2", 16))
    return out


def resnet_tiny_model_bytes(seed: int = 0) -> bytes:
    """The strided golden fixture ``tests/golden/resnet_tiny.onnx`` is
    exactly this with seed 0.  Topology (NCHW):

        input (1,3,16,16)
          Conv k3 s2 auto_pad=SAME_UPPER (+bias) → BN → Relu   (1,8,8,8)
          Conv k3 s2 explicit pads [0,0,1,1]     → BN → Relu   (1,16,4,4)
          Conv k2 s2 auto_pad=VALID (+bias)           → Relu   (1,16,2,2)
          GlobalAveragePool                                    (1,16,1,1)
          Flatten → Gemm(transB) (+bias)                       (1,10)
    """
    w = resnet_tiny_weights(seed)
    bn_attrs = (attr_float("epsilon", 0.0),)
    bn_ins = lambda p: [f"{p}_scale", f"{p}_B", f"{p}_mean",  # noqa: E731
                        f"{p}_var"]
    nodes = [
        node("Conv", ["input", "c1_w", "c1_b"], ["c1"], "conv1",
             (attr_ints("kernel_shape", [3, 3]),
              attr_ints("strides", [2, 2]),
              attr_string("auto_pad", "SAME_UPPER"))),
        node("BatchNormalization", ["c1"] + bn_ins("bn1"), ["n1"], "bn1",
             bn_attrs),
        node("Relu", ["n1"], ["r1"], "relu1"),
        node("Conv", ["r1", "c2_w"], ["c2"], "conv2",
             (attr_ints("kernel_shape", [3, 3]),
              attr_ints("strides", [2, 2]),
              attr_ints("pads", [0, 0, 1, 1]))),
        node("BatchNormalization", ["c2"] + bn_ins("bn2"), ["n2"], "bn2",
             bn_attrs),
        node("Relu", ["n2"], ["r2"], "relu2"),
        node("Conv", ["r2", "c3_w", "c3_b"], ["c3"], "conv3",
             (attr_ints("kernel_shape", [2, 2]),
              attr_ints("strides", [2, 2]),
              attr_string("auto_pad", "VALID"))),
        node("Relu", ["c3"], ["r3"], "relu3"),
        node("GlobalAveragePool", ["r3"], ["gap"], "gap"),
        node("Flatten", ["gap"], ["flat"], "flatten", (attr_int("axis", 1),)),
        node("Gemm", ["flat", "fc_w", "fc_b"], ["logits"], "fc",
             (attr_int("transB", 1), attr_float("alpha", 1.0),
              attr_float("beta", 1.0))),
    ]
    g = graph(
        "resnet_tiny",
        nodes,
        [tensor(k, v) for k, v in w.items()],
        [value_info("input", (1, 3, 16, 16), INT8)],
        [value_info("logits", (1, 10), INT32)],
    )
    return model(g)


def resnet_tiny_numpy(x: np.ndarray, w: dict[str, np.ndarray]) -> np.ndarray:
    """Reference forward pass on NCHW int inputs, int64 accumulation.
    BN is applied directly (not folded) — an independent check of the
    importer's fold.  GlobalAveragePool floor-divides like the DIV exit
    path."""
    from numpy.lib.stride_tricks import sliding_window_view

    def conv(x, wgt, b, stride, pads):  # pads ((t, b), (l, r))
        k = wgt.shape[2]
        (pt, pb), (pl, pr) = pads
        xp = np.pad(x, ((0, 0), (0, 0), (pt, pb), (pl, pr)))
        win = sliding_window_view(xp, (k, k), axis=(2, 3))
        win = win[:, :, ::stride, ::stride]
        out = np.einsum("nchwij,ocij->nohw", win.astype(np.int64),
                        wgt.astype(np.int64))
        return out + (0 if b is None else b[None, :, None, None])

    def bn(x, p):
        s = (w[f"{p}_scale"] / np.sqrt(w[f"{p}_var"])).astype(np.int64)
        return ((x - w[f"{p}_mean"].astype(np.int64)[None, :, None, None])
                * s[None, :, None, None]
                + w[f"{p}_B"].astype(np.int64)[None, :, None, None])

    relu = lambda v: np.maximum(v, 0)  # noqa: E731
    h = conv(x, w["c1_w"], w["c1_b"], 2, (same4(16, 3, 2), same4(16, 3, 2)))
    h = relu(bn(h, "bn1"))
    h = conv(h, w["c2_w"], None, 2, (same4(8, 3, 2), same4(8, 3, 2)))
    h = relu(bn(h, "bn2"))
    h = relu(conv(h, w["c3_w"], w["c3_b"], 2, ((0, 0), (0, 0))))
    h = h.sum(axis=(2, 3), keepdims=True) // (h.shape[2] * h.shape[3])
    h = h.reshape(1, -1)
    return h @ w["fc_w"].T.astype(np.int64) + w["fc_b"]


if __name__ == "__main__":  # pragma: no cover - fixture regeneration
    import os

    for fname, data in (("lenet5.onnx", lenet5_model_bytes()),
                        ("resnet_tiny.onnx", resnet_tiny_model_bytes())):
        path = os.path.join(os.path.dirname(__file__), "golden", fname)
        with open(path, "wb") as f:
            f.write(data)
        print(f"wrote {path} ({os.path.getsize(path)} bytes)")
