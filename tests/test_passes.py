"""Pass pipeline: verifier, DCE, canonicalize, fusion — unit + semantic
tests (semantics via the DFG interpreter backed by kernels/ref.py)."""
import numpy as np
import pytest

from repro.core import cnn_graphs
from repro.core.dse import solve_ilp
from repro.core.ir import (
    DFG,
    FusedEpilogue,
    GenericOp,
    PayloadKind,
    Value,
    make_elementwise_op,
)
from repro.core.streaming import plan_streams
from repro.passes import (
    Canonicalize,
    CommonSubexprElimination,
    ConvActivationFusion,
    ConvPoolFusion,
    DeadCodeElimination,
    ElementwiseChainFusion,
    Pass,
    PassManager,
    VerificationError,
    default_pipeline,
    run_default_pipeline,
    verify_dfg,
)
from repro.passes import interp


def _relu_chain(n=8, c=4):
    """conv → relu → mul(scale const) → relu (chain fodder)."""
    dfg = cnn_graphs.conv_relu(n, c_in=3, c_out=c)
    shape = (1, n, n, c)
    dfg.add_value(Value("scale", shape, 8, is_constant=True))
    dfg.add_value(Value("scaled", shape, 8))
    dfg.add_node(
        make_elementwise_op("scale0", ["relu0_out", "scale"], "scaled",
                            shape, PayloadKind.MUL)
    )
    dfg.add_value(Value("relu9_out", shape, 8))
    dfg.add_node(
        make_elementwise_op("relu9", ["scaled"], "relu9_out", shape,
                            PayloadKind.RELU)
    )
    dfg.graph_outputs = ["relu9_out"]
    return dfg


class TestVerifier:
    def test_suite_graphs_verify(self):
        for make in cnn_graphs.PAPER_SUITE.values():
            verify_dfg(make())

    def test_duplicate_producer_rejected(self):
        dfg = cnn_graphs.conv_relu(8)
        dup = make_elementwise_op(
            "dup", ["conv0_out"], "relu0_out", (1, 8, 8, 16), PayloadKind.RELU
        )
        dfg.nodes.append(dup)
        with pytest.raises(VerificationError, match=r"\[V2\]"):
            verify_dfg(dfg)

    def test_unregistered_value_rejected(self):
        dfg = cnn_graphs.conv_relu(8)
        dfg.nodes[0].inputs = ("ghost", dfg.nodes[0].inputs[1])
        with pytest.raises(VerificationError, match=r"\[V1\]"):
            verify_dfg(dfg)

    def test_cycle_rejected(self):
        dfg = cnn_graphs.conv_relu(8)
        # relu feeds the conv that feeds it
        dfg.nodes[0].inputs = ("relu0_out", dfg.nodes[0].inputs[1])
        dfg.graph_inputs = []
        with pytest.raises(VerificationError):
            verify_dfg(dfg)

    def test_stream_epilogue_operand_rejected(self):
        dfg = cnn_graphs.cascade_conv(8)
        dfg.nodes[0].epilogue = (FusedEpilogue(PayloadKind.ADD, "relu1_out"),)
        with pytest.raises(VerificationError, match=r"\[V6\]"):
            verify_dfg(dfg)

    def test_shape_mismatch_rejected(self):
        dfg = cnn_graphs.conv_relu(8)
        dfg.values["relu0_out"].shape = (1, 9, 9, 16)
        with pytest.raises(VerificationError, match=r"\[V8\]"):
            verify_dfg(dfg)


class _BrokenPass(Pass):
    name = "broken"

    def run_on(self, dfg: DFG) -> dict[str, int]:
        dfg.nodes[0].inputs = ("nonexistent",) + dfg.nodes[0].inputs[1:]
        return {"damage": 1}


class TestPassManager:
    def test_broken_rewrite_caught_and_named(self):
        with pytest.raises(VerificationError, match="broken"):
            PassManager([_BrokenPass()]).run(cnn_graphs.conv_relu(8))

    def test_input_graph_not_mutated(self):
        dfg = cnn_graphs.cascade_conv(8)
        n_nodes = len(dfg.nodes)
        run_default_pipeline(dfg)
        assert len(dfg.nodes) == n_nodes
        assert all(not n.epilogue for n in dfg.nodes)

    def test_report_lists_every_pass(self):
        res = run_default_pipeline(cnn_graphs.cascade_conv(8))
        report = res.report()
        for p in default_pipeline():
            assert p.name in report


class TestDce:
    def test_dead_branch_removed(self):
        dfg = cnn_graphs.conv_relu(8)
        shape = (1, 8, 8, 16)
        dfg.add_value(Value("dead_out", shape, 8))
        dfg.add_node(
            make_elementwise_op("dead", ["conv0_out"], "dead_out", shape,
                                PayloadKind.EXP)
        )
        dfg.add_value(Value("orphan", (4,), 8))
        stats = DeadCodeElimination().run_on(dfg)
        assert stats["nodes_removed"] == 1
        assert stats["values_removed"] == 2  # dead_out + orphan
        assert "dead" not in [n.name for n in dfg.nodes]
        verify_dfg(dfg)

    def test_live_graph_untouched(self):
        dfg = cnn_graphs.residual_block(8)
        stats = DeadCodeElimination().run_on(dfg)
        assert stats["nodes_removed"] == 0 and stats["values_removed"] == 0


class TestCanonicalize:
    def test_identity_removed(self):
        dfg = cnn_graphs.conv_relu(8)
        shape = (1, 8, 8, 16)
        # splice an identity between conv and relu
        dfg.add_value(Value("id_out", shape, 8))
        dfg.add_node(
            make_elementwise_op("id0", ["conv0_out"], "id_out", shape,
                                PayloadKind.IDENTITY)
        )
        dfg.node("relu0").inputs = ("id_out",)
        stats = Canonicalize().run_on(dfg)
        assert stats["identities_removed"] == 1
        assert dfg.node("relu0").inputs == ("conv0_out",)
        verify_dfg(dfg)

    def test_shape_propagation(self):
        dfg = cnn_graphs.conv_relu(8)
        dfg.values["conv0_out"].shape = (1, 99, 99, 16)  # stale
        stats = Canonicalize().run_on(dfg)
        assert stats["shapes_fixed"] >= 1
        assert dfg.values["conv0_out"].shape == (1, 8, 8, 16)

    def test_deterministic_order(self):
        dfg = cnn_graphs.residual_block(8)
        dfg.nodes.reverse()
        Canonicalize().run_on(dfg)
        order = [n.name for n in dfg.nodes]
        dfg2 = cnn_graphs.residual_block(8)
        Canonicalize().run_on(dfg2)
        assert order == [n.name for n in dfg2.nodes]


class TestFusion:
    @pytest.mark.parametrize(
        "make",
        [
            lambda: cnn_graphs.conv_relu(8),
            lambda: cnn_graphs.cascade_conv(8, c_mid=4),
            lambda: cnn_graphs.residual_block(8, c=4),
            cnn_graphs.feed_forward,
            _relu_chain,
        ],
        ids=["conv_relu", "cascade", "residual", "feed_forward", "chain"],
    )
    def test_semantics_preserved(self, make):
        """Fused graph computes bit-identical outputs (int32 math)."""
        dfg = make()
        env = interp.random_env(dfg, seed=7)
        before = interp.graph_outputs(dfg, env)
        after = interp.graph_outputs(run_default_pipeline(dfg).dfg, env)
        assert set(before) == set(after)
        for k in before:
            np.testing.assert_array_equal(
                np.asarray(before[k]), np.asarray(after[k])
            )

    def test_conv_activation_fuses_relu(self):
        res = run_default_pipeline(cnn_graphs.conv_relu(8))
        (conv,) = res.dfg.nodes
        assert conv.name == "conv0"
        assert [e.kind for e in conv.epilogue] == [PayloadKind.RELU]
        assert res.dfg.graph_outputs == ["relu0_out"]

    def test_elementwise_chain_collapses(self):
        res = run_default_pipeline(_relu_chain())
        # conv absorbs relu -> mul(scale) -> relu: single node remains
        assert len(res.dfg.nodes) == 1
        kinds = [e.kind for e in res.dfg.nodes[0].epilogue]
        assert kinds == [PayloadKind.RELU, PayloadKind.MUL, PayloadKind.RELU]
        assert res.dfg.nodes[0].epilogue[1].operand == "scale"

    def test_multi_consumer_not_fused(self):
        """Residual: conv1's output feeds add_skip with a second stream
        input — add_skip must survive."""
        res = run_default_pipeline(cnn_graphs.residual_block(8))
        names = {n.name for n in res.dfg.nodes}
        assert "add_skip" in names
        assert len(res.dfg.nodes) == 3  # conv0(+relu), conv1, add(+relu)

    def test_graph_output_value_name_preserved(self):
        res = run_default_pipeline(cnn_graphs.cascade_conv(8))
        assert res.dfg.graph_outputs == ["relu1_out"]
        assert res.dfg.nodes[-1].output == "relu1_out"


class TestConvPoolFusion:
    """Satellite (ISSUE 2): 2×2 pool folds into the conv's epilogue."""

    def test_pool_fuses_into_conv(self):
        res = run_default_pipeline(cnn_graphs.conv_pool(16, c_out=8))
        (conv,) = res.dfg.nodes
        assert conv.name == "conv0"
        kinds = [(e.kind, e.window) for e in conv.epilogue]
        assert kinds == [
            (PayloadKind.RELU, ()),
            (PayloadKind.MAX, (1, 2, 2, 1)),
        ]
        assert res.dfg.graph_outputs == ["pool0_out"]
        assert res.dfg.values["pool0_out"].shape == (1, 8, 8, 8)
        assert res.stat("pools_fused") == 1

    def test_fused_vs_unfused_bit_exact(self):
        """Legality + semantics: fused pool computes the identical
        max-pooled result (int32 math, exact)."""
        dfg = cnn_graphs.conv_pool(16, c_out=8)
        env = interp.random_env(dfg, seed=13)
        before = interp.graph_outputs(dfg, env)
        after = interp.graph_outputs(run_default_pipeline(dfg).dfg, env)
        assert set(before) == set(after)
        for k in before:
            np.testing.assert_array_equal(
                np.asarray(before[k]), np.asarray(after[k])
            )

    def test_multi_consumer_pool_not_fused(self):
        """F-legality: a conv output with a second consumer keeps its
        pool as a standalone node."""
        dfg = cnn_graphs.conv_pool(16, c_out=8)
        # second consumer of the conv output
        shape = (1, 16, 16, 8)
        dfg.add_value(Value("tap_out", shape, 8))
        dfg.add_node(
            make_elementwise_op("tap", ["conv0_out"], "tap_out", shape,
                                PayloadKind.RELU)
        )
        dfg.graph_outputs.append("tap_out")
        res = run_default_pipeline(dfg)
        assert "pool0" in {n.name for n in res.dfg.nodes}
        assert res.stat("pools_fused") == 0

    def test_overlapping_pool_not_fused(self):
        """Stride-aligned only: a 3×3 stride-1 pool must stay a node."""
        from repro.core.ir import make_pool2d_op

        dfg = cnn_graphs.conv_relu(16, c_out=8)
        dfg.add_value(Value("pool_out", (1, 16, 16, 8), 8))
        dfg.add_node(
            make_pool2d_op("pool0", "relu0_out", "pool_out",
                           n=1, h_out=16, w_out=16, c=8, kh=3, kw=3, stride=1)
        )
        dfg.graph_outputs = ["pool_out"]
        res = run_default_pipeline(dfg)
        assert "pool0" in {n.name for n in res.dfg.nodes}
        assert res.stat("pools_fused") == 0

    def test_fused_plan_shrinks_footprint(self):
        """One fewer process + FIFO: modeled BRAM must not grow."""
        dfg = cnn_graphs.conv_pool(32)
        fused = run_default_pipeline(dfg).dfg
        pre = solve_ilp(plan_streams(dfg))
        post = solve_ilp(plan_streams(fused))
        assert pre.feasible and post.feasible
        assert post.bram_used < pre.bram_used


def _diamond_with_duplicates(n=8, c=4):
    """x → {conv0, conv9 (identical)} → relus → add: CSE fodder."""
    from repro.core.ir import make_conv2d_op

    dfg = cnn_graphs.conv_relu(n, c_out=c)
    shape = (1, n, n, c)
    dfg.add_value(Value("conv9_out", shape, 8))
    dfg.add_node(
        make_conv2d_op("conv9", "x", "w0", "conv9_out",
                       n=1, h_out=n, w_out=n, c_out=c, kh=3, kw=3, c_in=3)
    )
    dfg.add_value(Value("relu9_out", shape, 8))
    dfg.add_node(
        make_elementwise_op("relu9", ["conv9_out"], "relu9_out", shape,
                            PayloadKind.RELU)
    )
    dfg.add_value(Value("sum_out", shape, 8))
    dfg.add_node(
        make_elementwise_op("sum", ["relu0_out", "relu9_out"], "sum_out",
                            shape, PayloadKind.ADD)
    )
    dfg.graph_outputs = ["sum_out"]
    return dfg


class TestCse:
    """Satellite (ISSUE 2): CSE across branches."""

    def test_duplicate_chain_collapses(self):
        dfg = _diamond_with_duplicates()
        stats = CommonSubexprElimination().run_on(dfg)
        assert stats["subexprs_eliminated"] == 2  # conv9 then relu9
        names = {n.name for n in dfg.nodes}
        assert "conv9" not in names and "relu9" not in names
        assert dfg.node("sum").inputs == ("relu0_out", "relu0_out")
        verify_dfg(dfg)

    def test_semantics_preserved(self):
        dfg = _diamond_with_duplicates()
        env = interp.random_env(dfg, seed=9)
        before = interp.graph_outputs(dfg, env)
        after = interp.graph_outputs(run_default_pipeline(dfg).dfg, env)
        for k in before:
            np.testing.assert_array_equal(
                np.asarray(before[k]), np.asarray(after[k])
            )

    def test_distinct_nodes_untouched(self):
        dfg = cnn_graphs.residual_block(8)
        stats = CommonSubexprElimination().run_on(dfg)
        assert stats["subexprs_eliminated"] == 0

    def test_graph_output_duplicate_kept(self):
        """A duplicate whose output is itself a graph output stays."""
        dfg = _diamond_with_duplicates()
        dfg.graph_outputs.append("relu9_out")
        stats = CommonSubexprElimination().run_on(dfg)
        # conv9 dedups, but relu9 (a graph output) must survive
        assert stats["subexprs_eliminated"] == 1
        assert "relu9" in {n.name for n in dfg.nodes}
        verify_dfg(dfg)


class TestAcceptance:
    def test_fusion_shrinks_streams_and_bram_cascade32(self):
        """ISSUE 1 acceptance: default pipeline reduces stream-edge count
        and modeled BRAM on cascade_conv(32) vs the unfused plan."""
        dfg = cnn_graphs.cascade_conv(32)
        fused = run_default_pipeline(dfg).dfg
        plan_pre, plan_post = plan_streams(dfg), plan_streams(fused)
        edges = lambda p: sum(
            1 for s in p.streams.values() if s.producer and s.consumer
        )
        assert edges(plan_post) < edges(plan_pre)
        pre, post = solve_ilp(plan_pre), solve_ilp(plan_post)
        assert pre.feasible and post.feasible
        assert post.bram_used < pre.bram_used


class TestConvEpiloguePallas:
    """kernels/ops.py fused-epilogue flag (TPU dual of the fusion pass)."""

    @pytest.mark.parametrize("epilogue", [None, "relu", "squared_relu"])
    def test_epilogue_matches_oracle(self, epilogue):
        import jax
        import jax.numpy as jnp

        from repro.kernels import ops, ref

        ks = jax.random.split(jax.random.key(3), 2)
        x = jax.random.randint(ks[0], (1, 12, 12, 4), -8, 8, jnp.int8)
        w = jax.random.randint(ks[1], (3, 3, 4, 8), -4, 4, jnp.int8)
        out = ops.conv2d_stream(x, w, epilogue=epilogue, interpret=True)
        exp = ref.conv2d(x, w, epilogue=epilogue)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))

    def test_fuse_relu_alias(self):
        import jax
        import jax.numpy as jnp

        from repro.kernels import ops

        ks = jax.random.split(jax.random.key(4), 2)
        x = jax.random.randint(ks[0], (1, 8, 8, 3), -8, 8, jnp.int8)
        w = jax.random.randint(ks[1], (3, 3, 3, 4), -4, 4, jnp.int8)
        a = ops.conv2d_stream(x, w, fuse_relu=True, interpret=True)
        b = ops.conv2d_stream(x, w, epilogue="relu", interpret=True)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
