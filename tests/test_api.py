"""Public API (ISSUE 4): CompileOptions validation, the
CompiledArtifact session handle, the retired ``compile`` alias
(ISSUE 5), batched runs, and the ``python -m repro`` CLI.
"""
import os

import numpy as np
import pytest

from repro import api
from repro.__main__ import main as cli_main
from repro.core import cnn_graphs
from repro.core.compile_driver import (
    KV260,
    ZU3EG,
    CompileOptions,
    Target,
    compile_design,
)
from repro.passes import PartitionError, interp


class TestCompileOptions:
    def test_preset_name_resolves_to_target(self):
        o = CompileOptions(target="zu3eg")
        assert o.target is ZU3EG
        assert CompileOptions().target is KV260

    def test_custom_target_passes_through(self):
        tiny = Target(name="tiny", d_total=64, b_total=32)
        assert CompileOptions(target=tiny).target is tiny

    @pytest.mark.parametrize("bad,match", [
        (dict(target="nope"), "unknown target preset"),
        (dict(target=42), "Target or preset name"),
        (dict(strategy="zigzag"), "unknown partition strategy"),
        (dict(weight_streaming="sometimes"), "weight_streaming"),
        (dict(max_unroll=0), "max_unroll"),
        (dict(passes=("dce", "zap")), "unknown pass name"),
    ])
    def test_validation_happens_at_construction(self, bad, match):
        with pytest.raises(ValueError, match=match):
            CompileOptions(**bad)

    def test_max_unroll_defers_to_target(self):
        assert CompileOptions().resolved_max_unroll == KV260.max_unroll
        assert CompileOptions(max_unroll=8).resolved_max_unroll == 8

    def test_frozen(self):
        o = CompileOptions()
        with pytest.raises(Exception):
            o.strategy = "greedy"

    def test_pass_selection_runs_exactly_those_passes(self):
        o = CompileOptions(passes=("canonicalize", "dce"))
        res = o.run_pipeline(cnn_graphs.conv_relu(8, c_out=4))
        assert [p.name for p in res.passes] == ["canonicalize", "dce"]
        # no fusion selected: both nodes survive
        assert len(res.dfg.nodes) == 2

    def test_empty_passes_skip_pipeline(self):
        d = compile_design(cnn_graphs.conv_relu(8, c_out=4),
                           options=CompileOptions(passes=()))
        assert d.pass_result is None
        assert len(d.source.nodes) == 2

    def test_options_and_legacy_kwargs_are_exclusive(self):
        with pytest.raises(ValueError, match="not both"):
            compile_design(cnn_graphs.conv_relu(8, c_out=4),
                           KV260, options=CompileOptions())

    def test_weight_streaming_off_rejects_fat_conv(self):
        with pytest.raises(PartitionError, match="weight_streaming"):
            compile_design(cnn_graphs.fat_conv(),
                           options=CompileOptions(weight_streaming="off"))

    def test_options_recorded_on_design(self):
        o = CompileOptions(strategy="greedy")
        d = compile_design(cnn_graphs.conv_relu(8, c_out=4), options=o)
        assert d.options is o

    def test_partitioner_and_ilp_reject_mixed_options_and_kwargs(self):
        """No silent override anywhere in the stack: options and loose
        kwargs are mutually exclusive at every layer."""
        from repro.core.dse import solve_ilp
        from repro.core.streaming import plan_streams
        from repro.passes import partition_layer_groups

        dfg = cnn_graphs.conv_relu(8, c_out=4)
        with pytest.raises(ValueError, match="not both"):
            partition_layer_groups(dfg, options=CompileOptions(), b_total=50)
        with pytest.raises(ValueError, match="not both"):
            solve_ilp(plan_streams(dfg), options=CompileOptions(), d_total=50)
        # options alone still works end to end at both layers
        d = partition_layer_groups(dfg, options=CompileOptions())
        assert d.feasible
        assert solve_ilp(plan_streams(dfg), options=CompileOptions()).feasible


class TestRetiredCompileAlias:
    """ISSUE 5 satellite: the deprecating ``compile`` alias is gone —
    a clear AttributeError points at ``compile_design``."""

    def test_attribute_access_raises_with_pointer(self):
        from repro.core import compile_driver

        with pytest.raises(AttributeError, match="compile_design"):
            compile_driver.compile  # noqa: B018

    def test_from_import_fails_too(self):
        with pytest.raises(ImportError, match="compile"):
            from repro.core.compile_driver import compile  # noqa: F401

    def test_other_attributes_error_normally(self):
        from repro.core import compile_driver

        with pytest.raises(AttributeError, match="no_such_thing"):
            compile_driver.no_such_thing  # noqa: B018


class TestCompiledArtifact:
    def test_compile_graph_accepts_builders_and_dfgs(self):
        net = api.Sequential([api.Conv2D(4), api.ReLU()],
                             input_shape=(1, 8, 8, 3), name="t")
        a1 = api.compile_graph(net)
        a2 = api.compile_graph(net.build())
        assert a1.report() == a2.report()
        with pytest.raises(TypeError, match="DFG or a builder"):
            api.compile_graph(42)

    def test_kwarg_sugar(self):
        a = api.compile_graph(cnn_graphs.conv_relu(8, c_out=4),
                              target="zu3eg")
        assert a.target_name == "zu3eg"
        with pytest.raises(ValueError, match="not both"):
            api.compile_graph(cnn_graphs.conv_relu(8, c_out=4),
                              api.CompileOptions(), target="zu3eg")

    @pytest.mark.parametrize("target", [KV260, ZU3EG], ids=["kv260", "zu3eg"])
    @pytest.mark.parametrize("make", [
        lambda: cnn_graphs.conv_relu(8, c_out=4),
        lambda: cnn_graphs.residual_block(8, c=4),
        lambda: cnn_graphs.conv_avgpool(8, c_out=4),
        lambda: cnn_graphs.feed_forward(batch=16, d_in=8, d_hidden=16),
    ], ids=["conv_relu", "residual", "conv_avgpool", "feed_forward"])
    def test_run_bit_exact_with_interp_on_both_targets(self, make, target):
        """Acceptance: builder graph → CompileOptions → artifact.run is
        bit-exact with the DFG interpreter on every device preset."""
        dfg = make()
        art = api.compile_graph(dfg, api.CompileOptions(target=target))
        env = interp.random_env(art.design.source, seed=3)
        want = interp.graph_outputs(art.design.source, env)
        inputs = {k: env[k] for k in art.design.source.graph_inputs}
        got = art.run(inputs, params=env, interpret=True, seed=3)
        outs = got if isinstance(got, dict) else {
            art.design.source.graph_outputs[0]: got
        }
        for k, arr in want.items():
            np.testing.assert_array_equal(np.asarray(arr),
                                          np.asarray(outs[k]))

    def test_run_accepts_bare_array_for_single_input(self):
        art = api.compile_graph(cnn_graphs.conv_relu(8, c_out=4))
        env = interp.random_env(art.design.source, seed=5)
        got_map = art.run({"x": env["x"]}, params=env, interpret=True)
        got_bare = art.run(env["x"], params=env, interpret=True)
        np.testing.assert_array_equal(np.asarray(got_map),
                                      np.asarray(got_bare))

    def test_run_rejects_unknown_bindings(self):
        art = api.compile_graph(cnn_graphs.conv_relu(8, c_out=4))
        with pytest.raises(KeyError, match="not a constant"):
            art.run(params={"nonsense": 1}, interpret=True)
        with pytest.raises(KeyError, match="not a graph input"):
            art.run({"nonsense": 1}, interpret=True)

    def test_run_rejects_non_constant_param(self):
        """A param naming a surviving intermediate would be silently
        recomputed over — reject it instead."""
        art = api.compile_graph(cnn_graphs.cascade_conv(8, c_mid=4),
                                api.CompileOptions(passes=()))
        inter = art.design.source.nodes[0].output
        with pytest.raises(KeyError, match="not a constant"):
            art.run(params={inter: 1}, interpret=True)

    def test_run_rejects_partially_bound_inputs(self):
        g = api.Graph("two_in")
        a = g.input((1, 4, 4, 2), name="a")
        b = g.input((1, 4, 4, 2), name="b")
        g.output(g.add(a, b))
        art = api.compile_graph(g.build())
        env = interp.random_env(art.design.source, seed=1)
        with pytest.raises(ValueError, match="missing graph input"):
            art.run({"a": env["a"]}, interpret=True)
        # all inputs bound, or none (smoke run), both work
        art.run({"a": env["a"], "b": env["b"]}, interpret=True)
        art.run(interpret=True)

    def test_batched_run_stacks_per_sample_outputs(self):
        """ISSUE 5 satellite: one extra leading dim on every input =>
        per-sample execution, outputs stacked along a new batch axis."""
        art = api.compile_graph(cnn_graphs.conv_relu(8, c_out=4))
        src = art.design.source
        env = interp.random_env(src, seed=11)
        xs = np.stack([
            np.asarray(env["x"]),
            np.asarray(env["x"]) + 1,
            np.asarray(env["x"]) - 2,
        ])
        got = art.run({"x": xs}, params=env, interpret=True)
        assert got.shape[0] == 3
        for i in range(3):
            want = art.run({"x": xs[i]}, params=env, interpret=True)
            np.testing.assert_array_equal(np.asarray(got[i]),
                                          np.asarray(want))

    def test_batched_run_multi_input_consistency(self):
        g = api.Graph("two_in")
        a = g.input((1, 4, 4, 2), name="a")
        b = g.input((1, 4, 4, 2), name="b")
        g.output(g.add(a, b))
        art = api.compile_graph(g.build())
        rng = np.random.default_rng(0)
        xa = rng.integers(-4, 5, (2, 1, 4, 4, 2)).astype(np.int32)
        xb = rng.integers(-4, 5, (2, 1, 4, 4, 2)).astype(np.int32)
        got = art.run({"a": xa, "b": xb}, interpret=True)
        np.testing.assert_array_equal(np.asarray(got), xa + xb)
        # mixed batched/unbatched inputs fail loudly
        with pytest.raises(ValueError, match="leading batch extent"):
            art.run({"a": xa, "b": xb[0]}, interpret=True)
        # wrong ranks fail loudly
        with pytest.raises(ValueError, match="expected"):
            art.run({"a": xa[None], "b": xb[None]}, interpret=True)
        # batch extent 0 is a clear error, not a numpy stack crash
        with pytest.raises(ValueError, match="batch extent 0"):
            art.run({"a": xa[:0], "b": xb[:0]}, interpret=True)

    def test_batched_run_on_zoo_classifier(self):
        """Imported classifiers validate on small input batches."""
        from repro.frontends import zoo

        art = api.compile_graph(zoo.lenet5())
        src = art.design.source
        env = interp.random_env(src, seed=2)
        xs = np.random.default_rng(3).integers(
            -4, 5, (2,) + src.values["x"].shape
        ).astype(np.int32)
        got = art.run(xs, params=env, interpret=True)
        assert got.shape == (2, 1, 10)
        for i in range(2):
            np.testing.assert_array_equal(
                np.asarray(art.run(xs[i], params=env, interpret=True)),
                np.asarray(got[i]),
            )

    def test_report_table(self):
        art = api.compile_graph(cnn_graphs.deep_cascade(32))
        rep = art.report()
        assert rep.graph == "deep_cascade_32" and rep.target == "kv260"
        assert rep.total_cycles == art.design.total_cycles
        assert rep.max_bram == art.design.max_bram
        assert len(rep.groups) == len(art.design.groups)
        text = str(rep)
        assert "deep_cascade_32 @ kv260" in text
        assert "group,nodes,cycles" in text

    def test_report_shows_streamed_weights(self):
        rep = api.compile_graph(cnn_graphs.fat_conv()).report()
        assert any(g.weight_streamed for g in rep.groups)
        assert "conv0/" in str(rep)

    def test_emit_hls_writes_kernels_and_host_schedule(self, tmp_path):
        art = api.compile_graph(cnn_graphs.conv_relu(8, c_out=4))
        paths = art.emit_hls(str(tmp_path / "out"))
        names = sorted(os.path.basename(p) for p in paths)
        assert names == ["conv_relu_8_g0.cpp", "host_schedule.cpp"]
        for p in paths:
            assert os.path.getsize(p) > 0

    def test_save_load_roundtrip(self, tmp_path):
        art = api.compile_graph(cnn_graphs.conv_avgpool(8, c_out=4))
        path = art.save(str(tmp_path / "cache" / "a.artifact"))
        loaded = api.CompiledArtifact.load(path)
        assert loaded.report() == art.report()
        env = interp.random_env(art.design.source, seed=7)
        inputs = {k: env[k] for k in art.design.source.graph_inputs}
        a = art.run(inputs, params=env, interpret=True)
        b = loaded.run(inputs, params=env, interpret=True)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_load_rejects_garbage(self, tmp_path):
        p = tmp_path / "bad.artifact"
        import pickle

        p.write_bytes(pickle.dumps({"not": "an artifact"}))
        with pytest.raises(ValueError, match="not a CompiledArtifact"):
            api.CompiledArtifact.load(str(p))

    def test_suite_registry_covers_paper_suite(self):
        s = api.suite()
        assert set(cnn_graphs.PAPER_SUITE) <= set(s)
        for extra in ("conv_pool_32", "conv_avgpool_32", "fat_conv_16",
                      "fat_cascade_16"):
            assert extra in s
        # and the model zoo rides along (ISSUE 5)
        from repro.frontends import zoo

        assert set(zoo.ZOO) <= set(s)

    def test_every_small_suite_graph_compiles_on_both_targets(self):
        """Acceptance (model level): every suite graph is expressible
        via the builder and compiles under CompileOptions on both
        presets.  224² variants are covered by the benchmark smoke
        (BENCH_smoke.json) — too slow to re-solve here."""
        small = [n for n in api.suite() if "224" not in n]
        for name in small:
            dfg = api.suite()[name]()
            for tname in ("kv260", "zu3eg"):
                art = api.compile_graph(
                    dfg, api.CompileOptions(target=tname)
                )
                assert art.feasible, (name, tname)


class TestTopLevelExports:
    def test_lazy_package_surface(self):
        import repro

        assert repro.CompileOptions is CompileOptions
        assert repro.Sequential is api.Sequential
        assert callable(repro.compile_graph)
        assert "compile_graph" in dir(repro)
        with pytest.raises(AttributeError):
            repro.no_such_symbol


class TestCli:
    def test_list(self, capsys):
        assert cli_main(["list"]) == 0
        out = capsys.readouterr().out
        assert "conv_relu_32" in out and "kv260" in out

    def test_compile_report_and_emit(self, tmp_path, capsys):
        rc = cli_main([
            "compile", "conv_relu_32", "--target", "zu3eg",
            "--emit", str(tmp_path / "hls"),
        ])
        assert rc == 0
        out = capsys.readouterr().out
        assert "conv_relu_32 @ zu3eg" in out
        assert (tmp_path / "hls" / "host_schedule.cpp").exists()

    def test_unknown_graph_fails_with_hint(self, capsys):
        assert cli_main(["compile", "resnet152"]) == 2
        assert "python -m repro list" in capsys.readouterr().err

    def test_compile_model_card_file_and_run(self, capsys):
        """ISSUE 5 acceptance: `python -m repro compile examples/
        lenet5.json --run` compiles and executes the imported model."""
        card = os.path.join(os.path.dirname(__file__), "..", "examples",
                            "lenet5.json")
        assert cli_main(["compile", card, "--run", "--quiet"]) == 0
        out = capsys.readouterr().out
        assert "ran OK" in out

    def test_zoo_lists_and_exports_cards(self, tmp_path, capsys):
        assert cli_main(["zoo", "--export", str(tmp_path)]) == 0
        out = capsys.readouterr().out
        assert "lenet5" in out
        for name in ("lenet5", "tiny_vgg_32", "edge_residual_32"):
            assert (tmp_path / f"{name}.json").exists()

    def test_compile_unknown_extension_fails_cleanly(self, tmp_path, capsys):
        bad = tmp_path / "model.txt"
        bad.write_text("nope")
        assert cli_main(["compile", str(bad)]) == 2
        assert "unknown model extension" in capsys.readouterr().err

    def test_suite_name_wins_over_cwd_entry(self, tmp_path, monkeypatch,
                                            capsys):
        """A stray file/dir named like a suite graph must not shadow
        the registry (regression: os.path.exists checked first)."""
        (tmp_path / "conv_relu_32").mkdir()
        monkeypatch.chdir(tmp_path)
        assert cli_main(["compile", "conv_relu_32", "--quiet"]) == 0

    def test_compile_directory_path_exits_two(self, tmp_path, capsys):
        """IsADirectoryError (and friends) are bad arguments (exit 2),
        never raw tracebacks."""
        d = tmp_path / "model.json"
        d.mkdir()
        assert cli_main(["compile", str(d)]) == 2
        assert "error:" in capsys.readouterr().err

    def test_bad_option_fails_cleanly(self, capsys):
        assert cli_main(["compile", "conv_relu_32", "--target", "vu9p"]) == 2
        assert "unknown target preset" in capsys.readouterr().err

    def test_infeasible_design_exits_one_not_two(self, capsys):
        """PartitionError on a valid command line is exit 1 (infeasible
        design), reserving 2 for bad arguments."""
        rc = cli_main(["compile", "fat_conv_16", "--weight-streaming",
                       "off", "--quiet"])
        assert rc == 1
        assert "infeasible" in capsys.readouterr().err
