"""Serving engine: wave generation, determinism, prefill/decode parity."""
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.launch.serve import ServeEngine


@pytest.fixture(scope="module")
def engine():
    cfg = get_config("qwen2-0.5b", smoke=True)
    return ServeEngine(cfg, max_len=96, seed=0)


class TestGenerate:
    def test_shapes_and_range(self, engine):
        rng = np.random.default_rng(0)
        prompts = rng.integers(0, engine.cfg.vocab_size, (3, 32),
                               dtype=np.int32)
        out, stats = engine.generate(prompts, max_new=8)
        assert out.shape == (3, 8)
        assert out.min() >= 0 and out.max() < engine.cfg.vocab_size
        assert stats.tokens_out == 24
        assert stats.tokens_per_s > 0

    def test_greedy_deterministic(self, engine):
        rng = np.random.default_rng(1)
        prompts = rng.integers(0, engine.cfg.vocab_size, (2, 16),
                               dtype=np.int32)
        o1, _ = engine.generate(prompts, max_new=6)
        o2, _ = engine.generate(prompts, max_new=6)
        np.testing.assert_array_equal(o1, o2)

    def test_sampling_seeded(self, engine):
        rng = np.random.default_rng(2)
        prompts = rng.integers(0, engine.cfg.vocab_size, (2, 16),
                               dtype=np.int32)
        o1, _ = engine.generate(prompts, max_new=6, temperature=1.0, seed=5)
        o2, _ = engine.generate(prompts, max_new=6, temperature=1.0, seed=5)
        o3, _ = engine.generate(prompts, max_new=6, temperature=1.0, seed=6)
        np.testing.assert_array_equal(o1, o2)
        assert not np.array_equal(o1, o3)

    def test_prompt_conditioning(self, engine):
        """Different prompts must produce different continuations."""
        rng = np.random.default_rng(3)
        p1 = rng.integers(0, engine.cfg.vocab_size, (1, 24), dtype=np.int32)
        p2 = rng.integers(0, engine.cfg.vocab_size, (1, 24), dtype=np.int32)
        o1, _ = engine.generate(p1, max_new=8)
        o2, _ = engine.generate(p2, max_new=8)
        assert not np.array_equal(o1, o2)


class TestEngineParity:
    def test_generate_matches_full_forward(self):
        """Greedy engine tokens == argmax over teacher-forced logits from
        the full forward at each step (cache correctness end-to-end)."""
        import jax
        import jax.numpy as jnp

        from repro.models import lm

        cfg = get_config("llama3.2-1b", smoke=True).with_(remat=False)
        eng = ServeEngine(cfg, max_len=48, seed=0)
        rng = np.random.default_rng(4)
        prompts = rng.integers(0, cfg.vocab_size, (2, 16), dtype=np.int32)
        out, _ = eng.generate(prompts, max_new=4)

        # replay with teacher forcing through lm_prefill
        seq = prompts.copy()
        for t in range(4):
            logits, _ = lm.lm_prefill(
                eng.params, cfg, {"tokens": jnp.asarray(seq)}
            )
            nxt = np.asarray(jnp.argmax(logits, -1)).astype(np.int32)
            np.testing.assert_array_equal(nxt, out[:, t], err_msg=f"step {t}")
            seq = np.concatenate([seq, nxt[:, None]], axis=1)
