"""A vendored minimal property-testing engine for when ``hypothesis``
is not installed.

Historically this module stubbed ``given``/``settings``/``st`` with
no-ops that *skipped* every property test — so CI environments without
the optional dep never fuzzed at all (ROADMAP follow-up).  It is now a
tiny real engine:

* ``st.integers`` / ``st.sampled_from`` / ``st.lists`` /
  ``st.composite`` draw actual values from a deterministic RNG (seeded
  per test, so CI runs are reproducible);
* ``@given(...)`` runs ``max_examples`` drawn examples through the test
  body;
* on failure, a greedy **shrinker** minimizes the counterexample —
  integers walk toward their lower bound (binary steps, then -1), lists
  drop elements toward ``min_size`` and shrink element-wise — and the
  minimal failing example is printed before the original failure
  re-raises.

Only the strategy surface this repo's tests use is implemented.  The
real thing (``pip install -r requirements-dev.txt``) is strictly
better — richer strategies, database replay, targeted shrinking — and
takes over automatically when importable.
"""
from __future__ import annotations

import functools
import random

#: shrink-phase budget: total extra test invocations per failure
_SHRINK_BUDGET = 200
#: default examples when no @settings decorates the test
_DEFAULT_MAX_EXAMPLES = 50


# ---------------------------------------------------------------------------
# Strategies
# ---------------------------------------------------------------------------


class _Strategy:
    def example(self, rng: random.Random):
        raise NotImplementedError

    def shrink_candidates(self, value):
        """Smaller candidates to try, most aggressive first."""
        return []


class _Integers(_Strategy):
    def __init__(self, min_value=None, max_value=None) -> None:
        self.min_value = -(2 ** 16) if min_value is None else min_value
        self.max_value = 2 ** 16 if max_value is None else max_value
        if self.min_value > self.max_value:
            raise ValueError(f"empty integer range [{min_value}, {max_value}]")

    def example(self, rng: random.Random) -> int:
        return rng.randint(self.min_value, self.max_value)

    def shrink_candidates(self, value: int):
        # shrink toward the smallest-magnitude legal value (hypothesis
        # shrinks toward 0 when in range, else toward the bound)
        target = min(max(0, self.min_value), self.max_value)
        out = []
        if value != target:
            out.append(target)
            mid = target + (value - target) // 2
            if mid not in (value, target):
                out.append(mid)
            step = value - 1 if value > target else value + 1
            if step != target:
                out.append(step)
        return out


class _SampledFrom(_Strategy):
    def __init__(self, elements) -> None:
        self.elements = list(elements)
        if not self.elements:
            raise ValueError("sampled_from needs at least one element")

    def example(self, rng: random.Random):
        return rng.choice(self.elements)

    def shrink_candidates(self, value):
        # earlier elements are "simpler" (hypothesis convention)
        try:
            i = self.elements.index(value)
        except ValueError:
            return []
        return [self.elements[0]] if i > 0 else []


class _Lists(_Strategy):
    def __init__(self, elements: _Strategy, min_size=0, max_size=None) -> None:
        self.elements = elements
        self.min_size = min_size
        self.max_size = max_size if max_size is not None else min_size + 10

    def example(self, rng: random.Random) -> list:
        n = rng.randint(self.min_size, self.max_size)
        return [self.elements.example(rng) for _ in range(n)]

    def shrink_candidates(self, value: list):
        out = []
        if len(value) > self.min_size:
            out.append(value[: self.min_size])
            out.append(value[:-1])
        for i, v in enumerate(value):
            for cand in self.elements.shrink_candidates(v):
                out.append(value[:i] + [cand] + value[i + 1:])
                break  # one element-wise step per position is plenty
        return out


class _CompositeStrategy(_Strategy):
    """Re-runs the @st.composite builder with a fresh draw function.

    Composite draws do NOT shrink (that needs choice-sequence
    navigation, which real hypothesis provides); a composite
    counterexample is reported as drawn."""

    def __init__(self, fn, args, kwargs) -> None:
        self.fn = fn
        self.args = args
        self.kwargs = kwargs

    def example(self, rng: random.Random):
        return self.fn(lambda s: s.example(rng), *self.args, **self.kwargs)


class _Strategies:
    @staticmethod
    def integers(min_value=None, max_value=None) -> _Integers:
        return _Integers(min_value, max_value)

    @staticmethod
    def sampled_from(elements) -> _SampledFrom:
        return _SampledFrom(elements)

    @staticmethod
    def lists(elements, min_size=0, max_size=None) -> _Lists:
        return _Lists(elements, min_size, max_size)

    @staticmethod
    def composite(fn):
        def make(*args, **kwargs):
            return _CompositeStrategy(fn, args, kwargs)

        return functools.wraps(fn)(make)


st = _Strategies()


# ---------------------------------------------------------------------------
# settings / given
# ---------------------------------------------------------------------------


class settings:  # noqa: N801 - mirrors hypothesis.settings
    """Records max_examples; every other knob is accepted and ignored."""

    def __init__(self, *_args, max_examples: int = _DEFAULT_MAX_EXAMPLES,
                 **_kwargs) -> None:
        self.max_examples = max_examples

    def __call__(self, fn):
        # works in either decorator order: attribute travels with the
        # function object @given wraps (or with the wrapper itself)
        fn._fb_settings = self
        return fn


def _fails_like(fn, args, kwargs, vals, exc_type) -> bool:
    """True iff the call raises the *same exception type* the original
    draw did — a candidate that blows up differently (e.g. a shrunk
    input tripping validation instead of the assertion under test) must
    not be latched onto as the 'minimal' counterexample."""
    try:
        fn(*args, *vals, **kwargs)
        return False
    except exc_type:
        return True
    except Exception:
        return False


def _shrink(fn, args, kwargs, strategies, vals: list, exc_type) -> list:
    """Greedy minimization: keep applying the first candidate that still
    fails with the original exception type, within the shrink budget."""
    budget = _SHRINK_BUDGET
    improved = True
    while improved and budget > 0:
        improved = False
        for i, strat in enumerate(strategies):
            for cand in strat.shrink_candidates(vals[i]):
                if budget <= 0:
                    break
                budget -= 1
                trial = list(vals)
                trial[i] = cand
                if _fails_like(fn, args, kwargs, trial, exc_type):
                    vals = trial
                    improved = True
                    break
    return vals


def given(*strategies):
    def deco(fn):
        # NOT functools.wraps: copying __wrapped__ would make pytest
        # inspect the original signature and demand the drawn params as
        # fixtures; the wrapper must present a bare (*args) signature.
        def wrapper(*args, **kwargs):
            cfg = getattr(wrapper, "_fb_settings", None) or getattr(
                fn, "_fb_settings", None
            )
            max_examples = cfg.max_examples if cfg else _DEFAULT_MAX_EXAMPLES
            rng = random.Random(f"{fn.__module__}.{fn.__qualname__}")
            for _ in range(max_examples):
                vals = [s.example(rng) for s in strategies]
                try:
                    fn(*args, *vals, **kwargs)
                except Exception as e:
                    minimal = _shrink(fn, args, kwargs, strategies,
                                      list(vals), type(e))
                    how = (
                        "shrunk by the vendored engine"
                        if minimal != vals else "as drawn, not shrunk"
                    )
                    print(
                        f"\nFalsifying example ({fn.__qualname__}, {how}): "
                        f"{minimal!r}"
                    )
                    fn(*args, *minimal, **kwargs)  # re-raise minimally
                    raise  # pragma: no cover - minimal example passed?!

        for attr in ("__name__", "__qualname__", "__module__", "__doc__"):
            setattr(wrapper, attr, getattr(fn, attr, None))
        wrapper._fb_settings = getattr(fn, "_fb_settings", None)
        wrapper._fb_property = True
        return wrapper

    return deco
