"""Stand-ins for ``hypothesis`` when the optional dep is not installed.

The property-based tests import ``given``/``settings``/``st`` at module
scope; a bare ``pytest.importorskip`` would skip *every* test in those
modules, including the ~60 plain unit tests.  Instead the test modules
fall back to these no-ops: ``@given(...)`` marks just the property tests
as skipped, strategies become inert placeholders, and the rest of the
module runs normally.  Install the real thing via ``requirements-dev.txt``
to run the property tests too.
"""
from __future__ import annotations

import pytest

_SKIP = pytest.mark.skip(reason="hypothesis not installed "
                                "(pip install -r requirements-dev.txt)")


def given(*_args, **_kwargs):
    def deco(fn):
        return _SKIP(fn)

    return deco


class settings:  # noqa: N801 - mirrors hypothesis.settings
    def __init__(self, *_args, **_kwargs) -> None:
        pass

    def __call__(self, fn):
        return fn


class _Strategy:
    """Inert placeholder: callable, chainable, never drawn from."""

    def __call__(self, *_args, **_kwargs) -> "_Strategy":
        return self

    def __getattr__(self, _name) -> "_Strategy":
        return self


class _Strategies:
    def composite(self, fn):
        # the decorated builder is never executed; calling it must just
        # return a strategy placeholder for @given(...)
        return _Strategy()

    def __getattr__(self, _name) -> _Strategy:
        return _Strategy()


st = _Strategies()
