"""Data pipeline: determinism, host sharding, restart semantics."""
import dataclasses

import numpy as np
import pytest

from repro.configs.base import SHAPES, ShapeConfig
from repro.configs.registry import get_config
from repro.data.pipeline import DataConfig, LmDataIterator, batch_for_model, lm_batch


class TestDeterminism:
    def test_same_step_same_batch(self):
        cfg = DataConfig(seed=7, vocab_size=100, seq_len=32, global_batch=4)
        b1, b2 = lm_batch(cfg, 13), lm_batch(cfg, 13)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])

    def test_steps_differ(self):
        cfg = DataConfig(seed=7, vocab_size=100, seq_len=32, global_batch=4)
        b1, b2 = lm_batch(cfg, 0), lm_batch(cfg, 1)
        assert not np.array_equal(b1["tokens"], b2["tokens"])

    def test_labels_are_shifted_tokens(self):
        cfg = DataConfig(seed=0, vocab_size=50, seq_len=16, global_batch=2)
        b = lm_batch(cfg, 0)
        # labels[t] is the next token after tokens[t] in the same stream
        assert b["tokens"].shape == b["labels"].shape == (2, 16)
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])

    def test_tokens_in_vocab(self):
        cfg = DataConfig(seed=0, vocab_size=64, seq_len=128, global_batch=4)
        b = lm_batch(cfg, 5)
        assert b["tokens"].min() >= 0 and b["tokens"].max() < 64


class TestHostSharding:
    def test_shards_disjoint_rows_deterministic(self):
        """Two hosts generating their own row ranges see consistent data
        with the full-batch generation? (each host's block is keyed by its
        row range — restart-stable per host)."""
        full = DataConfig(seed=3, vocab_size=100, seq_len=16, global_batch=8)
        h0 = dataclasses.replace(full, host_row_start=0, host_row_end=4)
        h1 = dataclasses.replace(full, host_row_start=4, host_row_end=8)
        b0, b1 = lm_batch(h0, 2), lm_batch(h1, 2)
        assert b0["tokens"].shape == (4, 16)
        assert b1["tokens"].shape == (4, 16)
        # different streams (host key differs)
        assert not np.array_equal(b0["tokens"], b1["tokens"])
        # re-generation is stable
        np.testing.assert_array_equal(lm_batch(h0, 2)["tokens"], b0["tokens"])


class TestIterator:
    def test_checkpointable_cursor(self):
        cfg = DataConfig(seed=1, vocab_size=50, seq_len=8, global_batch=2)
        it = LmDataIterator(cfg)
        batches = [next(it) for _ in range(3)]
        state = it.state()
        more = [next(it) for _ in range(2)]
        it2 = LmDataIterator(cfg)
        it2.restore(state)
        replay = [next(it2) for _ in range(2)]
        for a, b in zip(more, replay):
            np.testing.assert_array_equal(a["tokens"], b["tokens"])


class TestModelBatches:
    def test_token_arch(self):
        cfg = get_config("llama3.2-1b", smoke=True)
        shape = ShapeConfig("t", 32, 2, "train")
        b = batch_for_model(cfg, shape, DataConfig(), 0)
        assert set(b) == {"tokens", "labels"}
        assert b["tokens"].shape == (2, 32)

    def test_vlm_arch_gets_embeds_and_mrope(self):
        cfg = get_config("qwen2-vl-72b", smoke=True)
        shape = ShapeConfig("t", 32, 2, "train")
        b = batch_for_model(cfg, shape, DataConfig(), 0)
        assert set(b) == {"labels", "embeds", "mrope_positions"}
        assert b["embeds"].shape == (2, 32, cfg.d_model)
        assert b["mrope_positions"].shape == (3, 2, 32)

    def test_vocab_respected(self):
        cfg = get_config("mamba2-1.3b", smoke=True)
        shape = ShapeConfig("t", 16, 2, "train")
        b = batch_for_model(cfg, shape, DataConfig(), 0)
        assert int(b["labels"].max()) < cfg.vocab_size
