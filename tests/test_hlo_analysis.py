"""HLO-text analysis: trip-count recovery, FLOP counting, collectives."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import analyze_hlo, split_computations


class TestTripScaledFlops:
    def test_scanned_matmul_flops_exact(self):
        """A matmul scanned N times must count N× the dot FLOPs — the
        exact undercount cost_analysis() exhibits."""
        n_steps, m = 24, 64

        def f(x, w):
            def body(h, _):
                return jnp.tanh(h @ w), None
            h, _ = jax.lax.scan(body, x, None, length=n_steps)
            return h

        x = jnp.ones((m, m))
        w = jnp.ones((m, m))
        compiled = jax.jit(f).lower(x, w).compile()
        stats = analyze_hlo(compiled.as_text())
        analytic = 2.0 * m * m * m * n_steps
        assert stats.dot_flops == pytest.approx(analytic, rel=0.01)

    def test_unscanned_matmul(self):
        m = 32
        f = lambda a, b: a @ b
        compiled = jax.jit(f).lower(jnp.ones((m, m)), jnp.ones((m, m))).compile()
        stats = analyze_hlo(compiled.as_text())
        assert stats.dot_flops == pytest.approx(2.0 * m ** 3, rel=0.01)

    def test_nested_scans_multiply(self):
        inner, outer, m = 4, 6, 16

        def f(x, w):
            def outer_body(h, _):
                def inner_body(hh, _):
                    return hh @ w, None
                h2, _ = jax.lax.scan(inner_body, h, None, length=inner)
                return h2, None
            h, _ = jax.lax.scan(outer_body, x, None, length=outer)
            return h

        compiled = jax.jit(f).lower(jnp.ones((m, m)), jnp.ones((m, m))).compile()
        stats = analyze_hlo(compiled.as_text())
        analytic = 2.0 * m ** 3 * inner * outer
        assert stats.dot_flops == pytest.approx(analytic, rel=0.05)


class TestCollectives:
    def test_psum_counted(self, subproc):
        code = """
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.launch.mesh import make_host_mesh
from repro.launch.hlo_analysis import analyze_hlo

mesh = make_host_mesh((4,), ("data",))
f = shard_map(lambda x: jax.lax.psum(x, "data"), mesh=mesh,
              in_specs=P("data"), out_specs=P())
x = jnp.ones((16, 256), jnp.float32)
compiled = jax.jit(f).lower(x).compile()
stats = analyze_hlo(compiled.as_text())
# per-device operand: (4, 256) f32 = 4096 B; ring all-reduce ≈ 2× size
assert stats.collective_counts.get("all-reduce", 0) >= 1, stats.summary()
assert abs(stats.collective_bytes["all-reduce"] - 2 * 4 * 256 * 4) < 1e-6, \\
    stats.summary()
print("OK")
"""
        r = subproc(code, devices=4)
        assert r.returncode == 0, r.stderr[-2000:]
        assert "OK" in r.stdout

    def test_all_gather_counted(self, subproc):
        code = """
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.mesh import make_host_mesh
from repro.launch.hlo_analysis import analyze_hlo

mesh = make_host_mesh((4,), ("data",))
x = jnp.ones((16, 64), jnp.float32)
xs = jax.device_put(x, NamedSharding(mesh, P("data", None)))
f = jax.jit(lambda v: v * 2.0, in_shardings=(NamedSharding(mesh, P("data", None)),),
            out_shardings=NamedSharding(mesh, P()))
compiled = f.lower(xs).compile()
stats = analyze_hlo(compiled.as_text())
assert stats.collective_counts.get("all-gather", 0) >= 1, stats.summary()
print("OK")
"""
        r = subproc(code, devices=4)
        assert r.returncode == 0, r.stderr[-2000:]
        assert "OK" in r.stdout


class TestMemoryProxy:
    def test_dot_traffic(self):
        m = 128
        compiled = jax.jit(lambda a, b: a @ b).lower(
            jnp.ones((m, m), jnp.float32), jnp.ones((m, m), jnp.float32)
        ).compile()
        stats = analyze_hlo(compiled.as_text())
        # ≥ operands + result of the dot; ≤ a few× (copies/layout)
        lo = 3 * m * m * 4
        assert lo <= stats.memory_bytes <= 4 * lo

    def test_in_place_cache_update_not_overcharged(self):
        """dynamic-update-slice into a big buffer must charge ~the update
        size, not the buffer size."""
        big = jnp.zeros((4096, 128), jnp.float32)     # 2 MiB
        upd = jnp.ones((1, 128), jnp.float32)         # 512 B

        def f(b, u):
            return jax.lax.dynamic_update_slice(b, u, (17, 0))

        compiled = jax.jit(f, donate_argnums=(0,)).lower(big, upd).compile()
        stats = analyze_hlo(compiled.as_text())
        assert stats.memory_bytes < 64 * 1024, stats.memory_bytes


class TestTrafficAttribution:
    def test_by_shape_sums_to_total(self):
        m = 64

        def f(a, b, c):
            return (a @ b) @ c

        compiled = jax.jit(f).lower(
            jnp.ones((m, m)), jnp.ones((m, m)), jnp.ones((m, m))
        ).compile()
        stats = analyze_hlo(compiled.as_text())
        assert stats.memory_bytes > 0
        assert sum(stats.traffic_by_shape.values()) == pytest.approx(
            stats.memory_bytes
        )

    def test_collective_by_shape_sums(self, subproc):
        code = """
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.launch.mesh import make_host_mesh
from repro.launch.hlo_analysis import analyze_hlo
mesh = make_host_mesh((4,), ("data",))
f = shard_map(lambda x: jax.lax.psum(x, "data"), mesh=mesh,
              in_specs=P("data"), out_specs=P())
compiled = jax.jit(f).lower(jnp.ones((16, 64))).compile()
s = analyze_hlo(compiled.as_text())
assert abs(sum(s.collective_by_shape.values())
           - sum(s.collective_bytes.values())) < 1e-6
print("OK")
"""
        r = subproc(code, devices=4)
        assert r.returncode == 0, r.stderr[-1500:]
        assert "OK" in r.stdout


class TestKernelSubstitution:
    def test_attention_internals_identified(self):
        """The roofline substitution must remove score/carry shapes but
        keep activation-shaped traffic."""
        from benchmarks.roofline import kernel_substituted_memory

        rec = {
            "ok": True, "skipped": False,
            "arch": "llama3.2-1b", "shape": "train_4k",
            "chips": 256, "mesh_shape": [16, 16],
            "memory_s": 10.0,
            "traffic_by_shape": {
                "f32[512,512]": 819e9 * 4.0,     # score tiles → removed
                "f32[512,64]": 819e9 * 2.0,      # carries → removed
                "f32[4096,2048]": 819e9 * 3.0,   # (S, D) activations → kept
            },
        }
        adj = kernel_substituted_memory(rec)
        assert adj is not None
        assert adj["removed_s"] == pytest.approx(6.0)
        # memory falls by removed minus the (small) analytic kernel bytes
        assert 3.0 <= adj["memory_s_pallas"] <= 4.6

    def test_no_attention_no_substitution(self):
        from benchmarks.roofline import kernel_substituted_memory

        rec = {
            "ok": True, "skipped": False,
            "arch": "mamba2-1.3b", "shape": "train_4k",
            "chips": 256, "mesh_shape": [16, 16],
            "memory_s": 5.0,
            "traffic_by_shape": {"f32[4096,2048]": 819e9},  # nothing internal
        }
        assert kernel_substituted_memory(rec) is None


class TestParserRobustness:
    def test_split_finds_entry(self):
        compiled = jax.jit(lambda x: x + 1).lower(jnp.ones(8)).compile()
        comps = split_computations(compiled.as_text())
        assert any(n.startswith("main") for n in comps)

    def test_scan_trip_recovered(self):
        def f(x):
            return jax.lax.scan(lambda c, _: (c * 2, None), x, None,
                                length=13)[0]
        compiled = jax.jit(f).lower(jnp.ones(4)).compile()
        stats = analyze_hlo(compiled.as_text())
        assert 13 in stats.loop_trips.values()
