"""Gradient compression: quantization error bound + error feedback."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.compress import (
    compress_with_feedback,
    dequantize_int8,
    init_error_feedback,
    quantize_int8,
)


class TestQuantization:
    def test_roundtrip_error_bound(self):
        x = jax.random.normal(jax.random.key(0), (1024,))
        q, scale = quantize_int8(x)
        err = np.abs(np.asarray(dequantize_int8(q, scale) - x))
        assert err.max() <= float(scale) / 2 + 1e-7

    def test_extremes_preserved(self):
        x = jnp.asarray([-3.0, 0.0, 3.0])
        q, scale = quantize_int8(x)
        y = dequantize_int8(q, scale)
        np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=0.02)

    def test_zero_input(self):
        q, scale = quantize_int8(jnp.zeros(8))
        assert np.all(np.asarray(q) == 0)


class TestErrorFeedback:
    def test_error_accumulates_to_zero_bias(self):
        """With error feedback, the long-run mean of the compressed signal
        equals the true gradient (Seide et al. property)."""
        g = jax.random.normal(jax.random.key(1), (256,)) * 0.01
        err = jnp.zeros_like(g)
        total = jnp.zeros_like(g)
        n = 200
        for _ in range(n):
            q, scale, err = compress_with_feedback(g, err)
            total = total + dequantize_int8(q, scale)
        np.testing.assert_allclose(
            np.asarray(total / n), np.asarray(g), atol=1e-4
        )

    def test_residual_bounded(self):
        g = jax.random.normal(jax.random.key(2), (128,))
        err = jnp.zeros_like(g)
        for _ in range(50):
            _, scale, err = compress_with_feedback(g, err)
            assert float(jnp.max(jnp.abs(err))) <= float(scale) / 2 + 1e-6

    def test_init_congruent(self):
        grads = {"a": jnp.ones((2, 3)), "b": {"c": jnp.ones(4)}}
        st = init_error_feedback(grads)
        assert jax.tree.structure(st.err) == jax.tree.structure(grads)


class TestPodAllReduce:
    def test_compressed_psum_two_pods(self, subproc):
        """int8 cross-pod all-reduce ≈ fp32 all-reduce (within quant err)."""
        code = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.launch.mesh import make_host_mesh
from repro.optim.compress import compressed_psum_pod, init_error_feedback
mesh = make_host_mesh((2, 2, 1), ("pod", "data", "model"))
g = {"w": jax.random.normal(jax.random.key(0), (16,)) * 0.1}
st = init_error_feedback(g)
with mesh:
    out, st2 = compressed_psum_pod(g, st, mesh)
# expected: mean over 2 pods of identical replicas = g itself
np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(g["w"]) * 2 / 2,
                           atol=2e-3)
print("OK")
"""
        r = subproc(code, devices=4)
        assert r.returncode == 0, r.stderr[-2000:]
        assert "OK" in r.stdout
