"""ILP DSE (paper Eq. (1)): constraint satisfaction, optimality, duals."""
import math

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep: property tests skip, unit tests run
    from _hypothesis_fallback import given, settings, st

from repro.core import cnn_graphs
from repro.core.dse import (
    divisors,
    node_candidates,
    plan_attention_blocks,
    plan_conv_rows,
    plan_matmul_blocks,
    solve_ilp,
    solve_materialized,
)
from repro.core.resource_model import (
    FpgaResourceModel,
    KV260_BRAM18K,
    KV260_DSP,
    TPU_V5E,
)
from repro.core.streaming import plan_streams


class TestDivisors:
    @given(st.integers(1, 10_000))
    @settings(max_examples=100, deadline=None)
    def test_divisors_exact(self, n):
        ds = divisors(n)
        assert ds == sorted(d for d in range(1, n + 1) if n % d == 0)


class TestConstraints:
    @pytest.mark.parametrize("name", ["conv_relu_32", "linear", "residual_block_32"])
    def test_budgets_respected(self, name):
        plan = plan_streams(cnn_graphs.PAPER_SUITE[name]())
        res = solve_ilp(plan)
        assert res.feasible
        assert res.dsp_used <= KV260_DSP
        assert res.bram_used <= KV260_BRAM18K

    def test_unroll_divides_trip(self):
        plan = plan_streams(cnn_graphs.conv_relu(32))
        res = solve_ilp(plan)
        for node in plan.node_order():
            u = res.unrolls[node.name]
            assert node.loops.total_trip % u == 0, (node.name, u)

    def test_stream_width_consistency(self):
        """Eq. (1) stream constraint: κ_src == κ_dst on every edge."""
        plan = plan_streams(cnn_graphs.residual_block(32))
        res = solve_ilp(plan)
        for s in plan.streams.values():
            if s.producer and s.consumer:
                assert (
                    res.stream_widths[s.producer]
                    == res.stream_widths[s.consumer]
                ), s.name

    @pytest.mark.parametrize("d_total", [1248, 250, 50])
    def test_dsp_sweep_table4(self, d_total):
        """Paper Table IV: tighter DSP budgets still yield feasible
        designs, with monotonically lower DSP usage."""
        plan = plan_streams(cnn_graphs.conv_relu(32))
        res = solve_ilp(plan, d_total=d_total)
        assert res.feasible
        assert res.dsp_used <= d_total

    def test_dsp_speedup_monotone(self):
        plan = plan_streams(cnn_graphs.conv_relu(32))
        cycles = [
            solve_ilp(plan, d_total=d).estimate.pipeline_cycles
            for d in (1248, 250, 50)
        ]
        assert cycles[0] <= cycles[1] <= cycles[2]

    def test_infeasible_budget_reported(self):
        plan = plan_streams(cnn_graphs.conv_relu(224))
        res = solve_ilp(plan, b_total=0)   # no BRAM at all: line buffers fail
        assert not res.feasible


class TestOptimality:
    def test_bnb_matches_bruteforce_small(self):
        """Exact solver vs exhaustive enumeration on a small graph."""
        plan = plan_streams(cnn_graphs.linear(batch=8, d_in=8, d_out=8))
        model = FpgaResourceModel()
        d_total, b_total = 64, 32
        res = solve_ilp(plan, d_total=d_total, b_total=b_total, model=model)
        nodes = plan.node_order()
        cands = {n.name: node_candidates(n, model, d_total) for n in nodes}
        best = math.inf
        import itertools

        names = [n.name for n in nodes]
        prods = {n.name: [] for n in nodes}
        for s in plan.streams.values():
            if s.producer and s.consumer:
                prods[s.consumer].append(s.producer)
        for combo in itertools.product(*(cands[n] for n in names)):
            assign = dict(zip(names, combo))
            if sum(c.dsp for c in combo) > d_total:
                continue
            if sum(c.bram for c in combo) > b_total:
                continue
            if any(
                assign[p].stream_width != assign[n].stream_width
                for n in names
                for p in prods[n]
            ):
                continue
            best = min(best, sum(c.cycles for c in combo))
        assert res.objective_cycles == best


class TestMaterializedBaseline:
    def test_streaming_beats_materialized_on_bram(self):
        """The paper's headline: streaming BRAM ≪ materialized BRAM, and
        the gap grows with input size (Fig. 3)."""
        for n, min_ratio in ((32, 2), (224, 50)):
            plan = plan_streams(cnn_graphs.conv_relu(n))
            stream = solve_ilp(plan)
            mat = solve_materialized(plan)
            assert stream.bram_used * min_ratio <= max(mat.estimate.bram, 1)

    def test_streaming_faster_than_materialized(self):
        plan = plan_streams(cnn_graphs.conv_relu(32))
        stream = solve_ilp(plan)
        mat = solve_materialized(plan)
        assert (
            stream.estimate.pipeline_cycles < mat.estimate.pipeline_cycles
        )


class TestTpuDual:
    def test_attention_blocks_fit_vmem(self):
        plan = plan_attention_blocks(seq_q=4096, seq_k=4096, head_dim=128)
        assert plan.vmem_bytes <= TPU_V5E.vmem_bytes
        assert plan.blocks["block_q"] % 128 == 0
        assert plan.blocks["block_k"] % 128 == 0

    def test_matmul_blocks_fit_vmem(self):
        plan = plan_matmul_blocks(m=8192, k=4096, n=14336)
        assert plan.vmem_bytes <= TPU_V5E.vmem_bytes
        assert plan.mxu_util == 1.0

    def test_conv_rows_line_buffer_constraint(self):
        plan = plan_conv_rows(h=226, w=226, c_in=3, c_out=16, kh=3, kw=3)
        assert plan.vmem_bytes <= TPU_V5E.vmem_bytes
        assert plan.blocks["rows"] >= 1

    def test_vmem_budget_binds(self):
        """Tiny budget → smaller tiles chosen."""
        big = plan_attention_blocks(seq_q=4096, seq_k=4096, head_dim=128)
        small = plan_attention_blocks(
            seq_q=4096, seq_k=4096, head_dim=128,
            vmem_budget=TPU_V5E.vmem_bytes // 16,
        )
        assert small.vmem_bytes <= TPU_V5E.vmem_bytes // 16
        assert (
            small.blocks["block_q"] * small.blocks["block_k"]
            <= big.blocks["block_q"] * big.blocks["block_k"]
        )
