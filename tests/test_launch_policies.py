"""Launcher policies: TP selection, grad-accum budget, head-aware specs."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import SHAPES, ShapeConfig
from repro.configs.registry import get_config


class TestPickTp:
    def _pick(self, arch, shape_name):
        from repro.launch.dryrun import pick_tp

        return pick_tp(get_config(arch), SHAPES[shape_name], 256)

    def test_qwen_train_keeps_tp2(self):
        # batch 256 % (256/2=128) == 0 → the arch preference stands
        assert self._pick("qwen2-0.5b", "train_4k") == 2

    def test_qwen_prefill_widens(self):
        # batch 32: dp must be ≤32 → tp widens 2→8
        assert self._pick("qwen2-0.5b", "prefill_32k") == 8

    def test_default_archs_stay_16(self):
        assert self._pick("llama3.2-1b", "train_4k") == 16

    def test_granite_preference(self):
        assert self._pick("granite-moe-1b-a400m", "train_4k") == 8


class TestGradAccumBudget:
    def _ga(self, arch, dp=16):
        from repro.launch.dryrun import pick_grad_accum

        return pick_grad_accum(get_config(arch), SHAPES["train_4k"], dp)

    def test_shallow_small_model_low_accum(self):
        assert self._ga("llama3.2-1b") <= 4

    def test_deep_model_accumulates(self):
        # yi-9b: 48L × 16 rows × 4096 × 4096 × 2B = 25.8 GiB saved at ga=1
        assert self._ga("yi-9b") >= 4

    def test_budget_counts_layers(self):
        from repro.launch.dryrun import pick_grad_accum

        shallow = get_config("yi-9b").with_(num_layers=4)
        deep = get_config("yi-9b")
        ga_s = pick_grad_accum(shallow, SHAPES["train_4k"], 16)
        ga_d = pick_grad_accum(deep, SHAPES["train_4k"], 16)
        assert ga_d > ga_s

    def test_moe_buffers_counted(self):
        from repro.launch.dryrun import pick_grad_accum

        moe = get_config("olmoe-1b-7b")
        dense_like = moe.with_(moe=None, family="dense")
        ga_moe = pick_grad_accum(moe, SHAPES["train_4k"], 16)
        ga_dense = pick_grad_accum(dense_like, SHAPES["train_4k"], 16)
        assert ga_moe >= ga_dense

    def test_never_exceeds_rows(self):
        from repro.launch.dryrun import pick_grad_accum

        ga = pick_grad_accum(get_config("jamba-1.5-large-398b"),
                             SHAPES["train_4k"], 16)
        assert ga <= 16  # rows per device


class TestHeadAwareSharding:
    def test_indivisible_heads_replicate(self, subproc):
        code = """
import jax
from jax.sharding import PartitionSpec as P
from repro.configs.registry import get_config
from repro.distributed import sharding as shd
from repro.launch import steps as ST
from repro.launch.mesh import make_host_mesh

mesh = make_host_mesh((2, 4), ("data", "model"))
# qwen2-0.5b: 14 q heads, 2 kv heads — neither divides model=4
cfg = get_config("qwen2-0.5b")
shape = jax.eval_shape(lambda: ST.model_init(jax.random.key(0), cfg))
sh = shd.make_param_shardings(mesh, shape, cfg)
flat = {jax.tree_util.keystr(k): v.spec
        for k, v in jax.tree_util.tree_flatten_with_path(sh)[0]}
wq = [v for k, v in flat.items() if "'wq'" in k][0]
wk = [v for k, v in flat.items() if "'wk'" in k][0]
wo = [v for k, v in flat.items() if "'wo'" in k][0]
assert "model" not in str(wq), wq
assert "model" not in str(wk), wk
assert "model" not in str(wo), wo
# MLP still TP-shards (d_ff 4864 % 4 == 0)
wu = [v for k, v in flat.items() if "'wu'" in k][0]
assert "model" in str(wu), wu
print("OK")
"""
        r = subproc(code, devices=8)
        assert r.returncode == 0, r.stderr[-2000:]
        assert "OK" in r.stdout

    def test_divisible_heads_shard(self, subproc):
        code = """
import jax
from repro.configs.registry import get_config
from repro.distributed import sharding as shd
from repro.launch import steps as ST
from repro.launch.mesh import make_host_mesh

mesh = make_host_mesh((2, 4), ("data", "model"))
# llama: 32 q heads % 4 == 0 → shard; 8 kv heads % 4 == 0 → shard
cfg = get_config("llama3.2-1b")
shape = jax.eval_shape(lambda: ST.model_init(jax.random.key(0), cfg))
sh = shd.make_param_shardings(mesh, shape, cfg)
flat = {jax.tree_util.keystr(k): v.spec
        for k, v in jax.tree_util.tree_flatten_with_path(sh)[0]}
assert "model" in str([v for k, v in flat.items() if "'wq'" in k][0])
assert "model" in str([v for k, v in flat.items() if "'wk'" in k][0])
print("OK")
"""
        r = subproc(code, devices=8)
        assert r.returncode == 0, r.stderr[-2000:]
        assert "OK" in r.stdout


class TestMeshTpOverride:
    def test_tp_reshape_preserves_chips(self, subproc):
        code = """
import math
from repro.launch.mesh import make_production_mesh
import os
os.environ.pop("REPRO_MESH_SHAPE", None)
m = make_production_mesh(tp=2)
assert m.devices.shape == (128, 2), m.devices.shape
assert m.axis_names == ("data", "model")
m2 = make_production_mesh(multi_pod=True, tp=4)
assert m2.devices.shape == (2, 64, 4), m2.devices.shape
print("OK")
"""
        r = subproc(code, devices=512)  # the multi-pod mesh needs 2·64·4
        assert r.returncode == 0, r.stderr[-2000:]
        assert "OK" in r.stdout
