"""Instrumentation layer (ISSUE 6): tracer span model, Chrome trace
export/validation, null-tracer no-op guarantees (byte-identical
schedules + emitted HLS with tracing off), DP search statistics,
runtime counters, Report telemetry, and the ``--trace`` CLI path.
"""
import json
import os
import pickle

import pytest

from repro import instrument
from repro.core import cnn_graphs
from repro.core.compile_driver import CompileOptions, compile_design
from repro.core.emit_hls import emit_design
from repro.instrument import (
    NULL_TRACER,
    Tracer,
    diff_snapshots,
    provenance,
    snapshot_dfg,
    use_tracer,
    validate_chrome_trace,
)


class TestTracer:
    def test_spans_nest_and_export_chrome_complete_events(self):
        t = Tracer()
        with t.span("outer", cat="compile", args={"k": 1}):
            with t.span("inner", cat="passes") as sargs:
                sargs["extra"] = "v"
        obj = t.to_chrome()
        ev = {e["name"]: e for e in obj["traceEvents"]}
        assert ev["outer"]["ph"] == "X" and ev["inner"]["ph"] == "X"
        assert ev["outer"]["args"] == {"k": 1}
        assert ev["inner"]["args"] == {"extra": "v"}
        # inner is temporally contained in outer (ts/dur in microseconds)
        o, i = ev["outer"], ev["inner"]
        assert o["ts"] <= i["ts"]
        assert i["ts"] + i["dur"] <= o["ts"] + o["dur"] + 1e-3
        validate_chrome_trace(obj)

    def test_span_args_mutable_mid_span(self):
        t = Tracer()
        with t.span("s") as sargs:
            sargs.update({"found": 3})
        (e,) = t.to_chrome()["traceEvents"]
        assert e["args"]["found"] == 3

    def test_instant_and_counter_events(self):
        t = Tracer()
        t.instant("mark", cat="partition", args={"reason": "BRAM"})
        t.counter("dma_bytes", {"write": 128, "read": 64})
        ev = t.to_chrome()["traceEvents"]
        phases = sorted(e["ph"] for e in ev)
        assert phases == ["C", "i"]
        validate_chrome_trace(t.to_chrome())

    def test_write_stamps_provenance(self, tmp_path):
        t = Tracer()
        with t.span("s"):
            pass
        p = tmp_path / "trace.json"
        t.write(str(p), provenance={"graph": "g"})
        obj = json.loads(p.read_text())
        validate_chrome_trace(obj)
        assert obj["otherData"]["provenance"]["graph"] == "g"
        assert obj["displayTimeUnit"] == "ms"

    def test_null_tracer_records_nothing_and_discards_args(self):
        assert not NULL_TRACER.enabled
        with NULL_TRACER.span("s", args={"a": 1}) as sargs:
            sargs["b"] = 2       # discarded, not an error
            sargs.update(c=3)
        NULL_TRACER.instant("i")
        NULL_TRACER.counter("c", {"v": 1.0})
        assert NULL_TRACER.to_chrome()["traceEvents"] == []

    def test_contextvar_threading(self):
        assert instrument.current() is NULL_TRACER
        assert not instrument.tracing_active()
        t = Tracer()
        with use_tracer(t):
            assert instrument.current() is t
            assert instrument.tracing_active()
            with instrument.span("ambient"):
                pass
        assert instrument.current() is NULL_TRACER
        assert [e["name"] for e in t.to_chrome()["traceEvents"]] == \
            ["ambient"]

    def test_use_tracer_none_is_noop_scope(self):
        with use_tracer(None):
            assert instrument.current() is NULL_TRACER
            # module-level helpers stay safe no-ops
            with instrument.span("x") as sargs:
                sargs["k"] = 1
            instrument.instant("y")


class TestValidator:
    def _base(self, **kw):
        e = {"name": "n", "ph": "X", "ts": 1.0, "dur": 2.0,
             "pid": 1, "tid": 1, "cat": "c", "args": {}}
        e.update(kw)
        return {"traceEvents": [e]}

    def test_accepts_well_formed(self):
        validate_chrome_trace(self._base())

    @pytest.mark.parametrize("bad", [
        {"ph": "Z"},                    # unknown phase
        {"ts": -1.0},                   # negative timestamp
        {"dur": -5.0},                  # negative duration
        {"pid": "zero"},                # non-int pid
        {"args": "notadict"},           # non-dict args
        {"name": 42},                   # non-string name
    ])
    def test_rejects_malformed_events(self, bad):
        with pytest.raises(ValueError):
            validate_chrome_trace(self._base(**bad))

    def test_rejects_non_list_traceevents(self):
        with pytest.raises(ValueError):
            validate_chrome_trace({"traceEvents": {}})

    def test_counter_args_must_be_numeric(self):
        with pytest.raises(ValueError):
            validate_chrome_trace(
                self._base(ph="C", args={"v": "high"}))


class TestSnapshots:
    def test_diff_detects_structural_change(self):
        a = cnn_graphs.conv_relu(8, c_out=4)
        before = snapshot_dfg(a)
        opts = CompileOptions()
        fused = opts.run_pipeline(a).dfg
        d = diff_snapshots(before, snapshot_dfg(fused))
        assert not instrument.diff_is_empty(d)
        assert d["nodes_removed"] or d["nodes_changed"]

    def test_identical_graphs_diff_empty(self):
        s = snapshot_dfg(cnn_graphs.conv_relu(8, c_out=4))
        assert instrument.diff_is_empty(diff_snapshots(s, s))


class TestNoOpByteIdentity:
    """The acceptance contract: tracing off == tracing never existed."""

    def test_schedule_and_hls_bit_identical_traced_vs_untraced(self):
        dfg = cnn_graphs.deep_cascade(64)
        plain = compile_design(dfg, options=CompileOptions())
        traced = compile_design(cnn_graphs.deep_cascade(64),
                                options=CompileOptions(trace=True))
        assert plain.schedule() == traced.schedule()
        assert emit_design(plain) == emit_design(traced)
        assert plain.tracer is None
        assert traced.tracer is not None and traced.tracer.enabled

    def test_untraced_compile_leaves_no_ambient_tracer(self):
        compile_design(cnn_graphs.conv_relu(8, c_out=4),
                       options=CompileOptions())
        assert instrument.current() is NULL_TRACER

    def test_tracer_never_pickled(self):
        d = compile_design(cnn_graphs.conv_relu(8, c_out=4),
                           options=CompileOptions(trace=True))
        assert d.tracer is not None
        d2 = pickle.loads(pickle.dumps(d))
        assert d2.tracer is None
        assert d2.schedule() == d.schedule()


class TestCompileTrace:
    @pytest.fixture(scope="class")
    def traced_224(self):
        """Acceptance graph: deep_cascade_224 compiled with tracing on."""
        return compile_design(cnn_graphs.deep_cascade(224),
                              options=CompileOptions(trace=True))

    def test_pass_spans_present_with_wall_times(self, traced_224):
        ev = traced_224.tracer.to_chrome()["traceEvents"]
        passes = [e for e in ev if e["name"].startswith("pass:")]
        assert passes, "no pass spans recorded"
        assert all(e["ph"] == "X" and e["dur"] >= 0 for e in passes)
        # PassStats carries wall_ms regardless of tracing
        assert all(p.wall_ms >= 0
                   for p in traced_224.pass_result.passes)

    def test_dp_stats_event_with_rejected_cut_reasons(self, traced_224):
        ev = traced_224.tracer.to_chrome()["traceEvents"]
        dp = [e for e in ev if e["name"].startswith("dp_stats:")]
        assert len(dp) == 1, "expected exactly one DP statistics event"
        stats = dp[0]["args"]
        assert stats["dp_states"] > 0
        assert stats["ilp_solves"] > 0
        # 224² cascade cannot fit whole-graph: cuts were rejected
        assert stats["rejected_cuts"], "no rejected cuts recorded"
        reasons = {c["reason"] for c in stats["rejected_cuts"]}
        assert reasons <= {"BRAM", "DSP", "BRAM+DSP", "infeasible"}
        assert stats["rejected_by_reason"]
        assert sum(stats["rejected_by_reason"].values()) == \
            len(stats["rejected_cuts"])
        # the kept frontier mirrors the final grouping
        assert len(stats["frontier"]) == len(traced_224.groups)

    def test_dp_stats_attached_even_untraced(self):
        d = compile_design(cnn_graphs.deep_cascade(64),
                           options=CompileOptions())
        assert d.dp_stats is not None
        assert d.dp_stats["dp_states"] >= 0

    def test_whole_trace_validates(self, traced_224):
        validate_chrome_trace(traced_224.tracer.to_chrome())

    def test_ir_after_instants_carry_diffs(self, traced_224):
        ev = traced_224.tracer.to_chrome()["traceEvents"]
        ir = [e for e in ev if e["name"].startswith("ir_after:")]
        assert ir, "no ir_after instants"
        assert all("diff" in e["args"] for e in ir)

    def test_emit_spans_recorded_under_artifact_scope(self, traced_224,
                                                      tmp_path):
        from repro.api import CompiledArtifact

        CompiledArtifact(traced_224).emit_hls(str(tmp_path))
        ev = traced_224.tracer.to_chrome()["traceEvents"]
        emits = [e for e in ev if e["name"].startswith("emit:")]
        assert emits, "no emit spans"
        assert any(e["name"].endswith(".cpp") for e in emits)

    def test_trace_option_validation(self):
        with pytest.raises(ValueError):
            CompileOptions(trace="")
        with pytest.raises(ValueError):
            CompileOptions(trace=3.14)
        assert CompileOptions(trace="/tmp/t.json").trace_path == \
            "/tmp/t.json"
        assert CompileOptions(trace=True).trace_path is None


class TestRuntimeCounters:
    @pytest.fixture(scope="class")
    def ran(self):
        from repro import api

        art = api.compile_graph(cnn_graphs.deep_cascade(64),
                                api.CompileOptions(trace=True))
        out = art.run(interpret=True)
        return art, out

    def test_last_run_stats_per_group(self, ran):
        art, _ = ran
        st = art.last_run_stats
        assert st is not None and st["samples"] == 1
        assert st["wall_ms"] > 0
        names = {g.name for g in art.design.groups}
        assert {row["group"] for row in st["groups"]} == names
        for row in st["groups"]:
            assert row["wall_ms"] >= 0
            assert row["jit_cache"] in ("hit", "miss", "unjitted")

    def test_runtime_spans_and_jit_cache_events(self, ran):
        art, _ = ran
        ev = art.tracer.to_chrome()["traceEvents"]
        runs = [e for e in ev if e["name"].startswith("run:")]
        assert runs, "no runtime spans"
        group_spans = [e for e in runs
                       if any(e["name"] == f"run:{g.name}"
                              for g in art.design.groups)]
        assert len(group_spans) == len(art.design.groups)
        assert any(e["name"] == "jit_cache" for e in ev)

    def test_exec_cache_stats_surface_in_run_stats(self, ran):
        art, _ = ran
        from repro.kernels import ops

        st = art.last_run_stats
        assert set(st["exec_cache"]) == {"hits", "misses"}
        total = st["exec_cache_total"]
        assert total["hits"] <= ops.exec_cache_stats["hits"]
        assert total["misses"] <= ops.exec_cache_stats["misses"]

    def test_write_trace(self, ran, tmp_path):
        art, _ = ran
        p = tmp_path / "t.json"
        art.write_trace(str(p))
        obj = validate_chrome_trace(json.loads(p.read_text()))
        prov = obj["otherData"]["provenance"]
        assert prov["graph"] == art.design.source.name
        assert "git_sha" in prov and "host" in prov

    def test_write_trace_without_tracer_raises(self):
        from repro import api

        art = api.compile_graph(cnn_graphs.conv_relu(8, c_out=4),
                                api.CompileOptions())
        with pytest.raises(ValueError, match="trace"):
            art.write_trace("/tmp/never.json")


class TestReportTelemetry:
    def test_report_shows_dma_transitions_for_partitioned(self):
        from repro import api

        art = api.compile_graph(cnn_graphs.deep_cascade(224),
                                api.CompileOptions())
        rep = art.report()
        assert len(rep.groups) > 1
        assert len(rep.transitions) == len(rep.groups) - 1
        s = str(rep)
        assert "-- dma" in s and "overlapped" in s
        for tr in rep.transitions:
            assert tr.cycles >= 0

    def test_single_group_report_has_no_transitions(self):
        from repro import api

        art = api.compile_graph(cnn_graphs.conv_relu(8, c_out=4),
                                api.CompileOptions())
        rep = art.report()
        assert rep.transitions == ()
        assert "-- dma" not in str(rep)

    def test_telemetry_present_but_excluded_from_equality(self):
        from repro import api

        a1 = api.compile_graph(cnn_graphs.deep_cascade(64),
                               api.CompileOptions())
        a2 = api.compile_graph(cnn_graphs.deep_cascade(64),
                               api.CompileOptions())
        r1, r2 = a1.report(), a2.report()
        assert r1.telemetry and r1.telemetry["passes"]
        assert r1 == r2   # wall-time jitter must not break equality
        assert "telemetry" in str(r1)


class TestProvenance:
    def test_fields(self):
        p = provenance(extra={"k": "v"})
        for key in ("git_sha", "host", "platform", "python", "time_unix"):
            assert key in p
        assert p["k"] == "v"

    def test_env_override(self, monkeypatch):
        import importlib

        # the package re-exports the provenance *function* under the
        # submodule's name, so resolve the module via importlib
        pm = importlib.import_module("repro.instrument.provenance")
        monkeypatch.setenv("REPRO_GIT_SHA", "deadbeef")
        monkeypatch.setattr(pm, "_GIT_SHA", None)  # drop process cache
        assert provenance()["git_sha"] == "deadbeef"
        monkeypatch.setattr(pm, "_GIT_SHA", None)


class TestSmokeDiffIgnoresProvenance:
    def test_provenance_only_change_is_not_a_delta(self, capsys):
        import importlib.util

        spec = importlib.util.spec_from_file_location(
            "smoke_diff",
            os.path.join(os.path.dirname(__file__), "..", "scripts",
                         "smoke_diff.py"))
        sd = importlib.util.module_from_spec(spec)
        spec.loader.exec_module(sd)
        row = {"total_cycles": 100, "max_group_cycles": 60, "max_bram": 10,
               "groups": 2, "spill_bytes": 0,
               "provenance": {"git_sha": "aaa", "compile_s": 1.0}}
        prev = {"g": {"kv260": dict(row)}}
        cur = {"g": {"kv260": dict(row,
                                   provenance={"git_sha": "bbb",
                                               "compile_s": 9.9})}}
        lines = []
        assert sd.diff(prev, cur, 0.10, emit=lines.append) == 0
        assert lines == ["graph,target,metric,previous,current,delta_pct"]

    def test_metric_regression_still_caught(self):
        import importlib.util

        spec = importlib.util.spec_from_file_location(
            "smoke_diff2",
            os.path.join(os.path.dirname(__file__), "..", "scripts",
                         "smoke_diff.py"))
        sd = importlib.util.module_from_spec(spec)
        spec.loader.exec_module(sd)
        prev = {"g": {"kv260": {"total_cycles": 100}}}
        cur = {"g": {"kv260": {"total_cycles": 150}}}
        lines = []
        assert sd.diff(prev, cur, 0.10, emit=lines.append) == 1


class TestCLITrace:
    def test_compile_trace_flag_writes_valid_trace(self, tmp_path, capsys):
        from repro.__main__ import main as cli_main

        p = tmp_path / "trace.json"
        rc = cli_main(["compile", "conv_relu_32", "--trace", str(p),
                       "--quiet"])
        assert rc == 0
        out = capsys.readouterr().out
        assert "trace written" in out
        obj = validate_chrome_trace(json.loads(p.read_text()))
        names = [e["name"] for e in obj["traceEvents"]]
        assert any(n.startswith("pass:") for n in names)
        assert any(n.startswith("partition:") for n in names)
        assert "provenance" in obj["otherData"]
