"""Layer-level: attention impl parity, streaming-backward VJPs, RoPE."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.models import layers as L
from repro.models.lm import chunked_ce_loss


def _qkv(key, b=2, hq=8, hkv=2, s=64, d=16):
    ks = jax.random.split(key, 3)
    return (
        jax.random.normal(ks[0], (b, hq, s, d), jnp.float32),
        jax.random.normal(ks[1], (b, hkv, s, d), jnp.float32),
        jax.random.normal(ks[2], (b, hkv, s, d), jnp.float32),
    )


class TestAttentionParity:
    @pytest.mark.parametrize("causal", [True, False])
    def test_blockwise_vs_reference(self, causal):
        q, k, v = _qkv(jax.random.key(0))
        out = L.blockwise_attention(q, k, v, causal=causal,
                                    block_q=16, block_k=16)
        exp = ref.attention(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   atol=2e-5, rtol=2e-5)

    def test_pallas_vs_reference(self):
        q, k, v = _qkv(jax.random.key(1))
        out = L.attention_pallas(q, k, v, causal=True, block_q=16, block_k=16)
        exp = ref.attention(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   atol=2e-5, rtol=2e-5)

    def test_decode_attention_matches_masked_full(self):
        q, k, v = _qkv(jax.random.key(2), s=32)
        q1 = q[:, :, -1:, :]
        out = L.decode_attention(q1, k, v, jnp.asarray(32))
        exp = ref.attention(q1, k, v, causal=True, q_offset=31)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   atol=2e-5, rtol=2e-5)

    def test_block_size_invariance(self):
        q, k, v = _qkv(jax.random.key(3))
        o1 = L.blockwise_attention(q, k, v, block_q=16, block_k=16)
        o2 = L.blockwise_attention(q, k, v, block_q=64, block_k=32)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                                   atol=2e-5, rtol=2e-5)


class TestStreamingBackward:
    """The custom VJPs must be gradient-exact vs the default scan VJP."""

    @pytest.mark.parametrize("causal", [True, False])
    def test_attention_grads_match(self, causal):
        q, k, v = _qkv(jax.random.key(4))

        def loss(impl):
            def f(q, k, v):
                o = L.blockwise_attention(
                    q, k, v, causal=causal, block_q=16, block_k=16,
                    streaming_bwd=impl,
                )
                return jnp.sum(jnp.sin(o))
            return f

        g1 = jax.grad(loss(True), argnums=(0, 1, 2))(q, k, v)
        g2 = jax.grad(loss(False), argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(g1, g2):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=2e-4, rtol=2e-4)

    def test_attention_grads_vs_dense(self):
        q, k, v = _qkv(jax.random.key(5), s=32)
        g1 = jax.grad(
            lambda *a: jnp.sum(L.blockwise_attention(
                *a, causal=True, block_q=16, block_k=16) ** 2),
            argnums=(0, 1, 2),
        )(q, k, v)
        g2 = jax.grad(
            lambda *a: jnp.sum(ref.attention(*a, causal=True) ** 2),
            argnums=(0, 1, 2),
        )(q, k, v)
        for a, b in zip(g1, g2):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=2e-4, rtol=2e-4)

    def test_ce_loss_grads_match(self):
        ks = jax.random.split(jax.random.key(6), 3)
        h = jax.random.normal(ks[0], (2, 32, 16))
        w = jax.random.normal(ks[1], (16, 50)) * 0.3
        labels = jax.random.randint(ks[2], (2, 32), 0, 50)
        for chunk in (8, 16, 32):
            l1, g1 = jax.value_and_grad(
                lambda h, w: chunked_ce_loss(h, w, labels, chunk, True),
                argnums=(0, 1),
            )(h, w)
            l2, g2 = jax.value_and_grad(
                lambda h, w: chunked_ce_loss(h, w, labels, chunk, False),
                argnums=(0, 1),
            )(h, w)
            np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)
            for a, b in zip(g1, g2):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           atol=1e-5, rtol=1e-5)

    def test_ce_loss_vs_dense_softmax(self):
        ks = jax.random.split(jax.random.key(7), 3)
        h = jax.random.normal(ks[0], (2, 16, 8))
        w = jax.random.normal(ks[1], (8, 20)) * 0.5
        labels = jax.random.randint(ks[2], (2, 16), 0, 20)

        def dense(h, w):
            logits = (h @ w).astype(jnp.float32)
            lp = jax.nn.log_softmax(logits)
            gold = jnp.take_along_axis(lp, labels[..., None], -1)[..., 0]
            return -jnp.mean(gold)

        l1, g1 = jax.value_and_grad(
            lambda h, w: chunked_ce_loss(h, w, labels, 8), argnums=(0, 1)
        )(h, w)
        l2, g2 = jax.value_and_grad(dense, argnums=(0, 1))(h, w)
        np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
        for a, b in zip(g1, g2):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-5, rtol=1e-5)


class TestRope:
    def test_rope_rotation_preserves_norm(self):
        pos = jnp.arange(16, dtype=jnp.int32)[None]
        cos, sin = L.rope_cos_sin(pos, 32, 10_000.0)
        x = jax.random.normal(jax.random.key(0), (1, 2, 16, 32))
        y = L.apply_rope(x, cos, sin)
        np.testing.assert_allclose(
            np.linalg.norm(np.asarray(y), axis=-1),
            np.linalg.norm(np.asarray(x), axis=-1),
            rtol=1e-5,
        )

    def test_rope_relative_property(self):
        """q·k after RoPE depends only on relative distance."""
        hd = 32
        q = jax.random.normal(jax.random.key(1), (1, 1, 1, hd))
        k = jax.random.normal(jax.random.key(2), (1, 1, 1, hd))

        def dot_at(pq, pk):
            cq, sq_ = L.rope_cos_sin(jnp.asarray([[pq]], jnp.int32), hd, 1e4)
            ck, sk_ = L.rope_cos_sin(jnp.asarray([[pk]], jnp.int32), hd, 1e4)
            qr = L.apply_rope(q, cq, sq_)
            kr = L.apply_rope(k, ck, sk_)
            return float(jnp.sum(qr * kr))

        assert dot_at(5, 3) == pytest.approx(dot_at(105, 103), rel=1e-4)

    def test_mrope_text_equals_rope(self):
        """Identical (t,h,w) streams == plain RoPE (text tokens)."""
        hd = 32
        pos = jnp.arange(8, dtype=jnp.int32)[None]
        c1, s1 = L.rope_cos_sin(pos, hd, 1e4)
        streams = jnp.broadcast_to(pos, (3, 1, 8))
        c2, s2 = L.rope_cos_sin(
            pos, hd, 1e4, mrope_sections=(8, 4, 4), mrope_positions=streams
        )
        np.testing.assert_allclose(np.asarray(c1), np.asarray(c2), rtol=1e-6)
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-6)


class TestMlp:
    def test_streamed_matches_dense(self):
        from repro.configs.registry import get_config

        cfg = get_config("llama3.2-1b", smoke=True)
        p = L.init_mlp(jax.random.key(0), cfg)
        x = jax.random.normal(jax.random.key(1), (2, 8, cfg.d_model),
                              jnp.float32).astype(cfg.param_dtype)
        dense = L.mlp_layer(p, cfg.with_(mlp_impl="dense"), x)
        streamed = L.mlp_layer(p, cfg.with_(mlp_impl="streamed"), x)
        np.testing.assert_allclose(
            np.asarray(dense, np.float32), np.asarray(streamed, np.float32),
            atol=2e-2, rtol=2e-2,
        )

    def test_rmsnorm(self):
        x = jax.random.normal(jax.random.key(0), (2, 8)) * 10
        w = jnp.ones((8,))
        y = L.rmsnorm(x, w)
        rms = np.sqrt(np.mean(np.asarray(y) ** 2, -1))
        np.testing.assert_allclose(rms, 1.0, rtol=1e-3)
