"""Streaming transform (paper Sec. IV-B): buffers, streams, regions."""
import math

import pytest

from repro.core import cnn_graphs
from repro.core.analysis import KernelClass
from repro.core.streaming import plan_streams


class TestLineBuffers:
    def test_conv_line_buffer_size(self):
        """Paper: (K-1)×N line buffer for an N×N input, K×K kernel —
        here ×C_in channels ×8 bits, with N the padded input extent."""
        dfg = cnn_graphs.conv_relu(32, c_in=3, c_out=16)
        plan = plan_streams(dfg)
        conv = plan.nodes["conv0"]
        assert conv.kernel_class == KernelClass.SLIDING_WINDOW
        # (K-1)=2 lines × padded width 34 × 3 channels × 8 bits
        assert conv.line_buffer_bits == 2 * 34 * 3 * 8
        # window buffer: 3×3×3 × 8 bits
        assert conv.window_buffer_bits == 3 * 3 * 3 * 8

    def test_line_buffer_scales_with_input_width_not_area(self):
        small = plan_streams(cnn_graphs.conv_relu(32)).nodes["conv0"]
        large = plan_streams(cnn_graphs.conv_relu(224)).nodes["conv0"]
        ratio = large.line_buffer_bits / small.line_buffer_bits
        # linear in N (226/34), not quadratic
        assert ratio == pytest.approx(226 / 34)

    def test_relu_has_no_buffers(self):
        plan = plan_streams(cnn_graphs.conv_relu(32))
        relu = plan.nodes["relu0"]
        assert relu.kernel_class == KernelClass.PURE_PARALLEL
        assert relu.buffer_bits() == 0

    def test_matmul_data_line_buffer(self):
        plan = plan_streams(cnn_graphs.linear())
        mm = plan.nodes["linear0"]
        assert mm.kernel_class == KernelClass.REGULAR_REDUCTION
        # current data line = reduction extent (k=128) × 8 bits
        assert mm.line_buffer_bits == 128 * 8


class TestStreams:
    def test_intermediates_become_streams_not_arrays(self):
        """C1: every inter-node tensor is a stream; no intermediate value
        contributes array storage to the plan."""
        dfg = cnn_graphs.cascade_conv(32)
        plan = plan_streams(dfg)
        inter = {v.name for v in dfg.intermediate_values()}
        assert len(inter) == 3  # conv0_out, relu0_out, conv1_out
        # one stream per producer→consumer edge
        edges = {(p.name, c.name) for p, c, _ in dfg.edges()}
        internal = {
            (s.producer, s.consumer)
            for s in plan.streams.values()
            if s.producer and s.consumer
        }
        assert internal == edges
        # stream buffer bits are tiny vs the tensors they replace
        stream_bits = sum(s.buffer_bits for s in plan.streams.values())
        tensor_bits = sum(dfg.values[v].total_bits for v in inter)
        assert stream_bits < tensor_bits / 100

    def test_host_boundary_streams(self):
        plan = plan_streams(cnn_graphs.conv_relu(32))
        b_in = [s for s in plan.streams.values() if s.producer is None]
        b_out = [s for s in plan.streams.values() if s.consumer is None]
        assert len(b_in) == 1 and len(b_out) == 1
        assert b_in[0].consumer == "conv0"
        assert b_out[0].producer == "relu0"


class TestDiamond:
    def test_residual_fifo_sized_for_skip_path(self):
        """Sec. IV-C last ¶: the skip edge of a diamond must absorb the
        long path's fill latency or the pipeline deadlocks."""
        dfg = cnn_graphs.residual_block(32)
        plan = plan_streams(dfg)
        skip = plan.streams["s_conv0_to_relu0"]  # short internal edge
        # the skip edge feeding add directly from the graph input does not
        # exist (x is a graph input); instead conv1->add vs relu0->conv1:
        # check the *add* node's deeper input got depth > default
        add_inputs = [
            plan.streams[s] for s in plan.nodes["add_skip"].input_streams
        ]
        depths = sorted(s.depth for s in add_inputs)
        assert depths[-1] >= 2  # at least double-buffered
        # the graph-input edge to add (host boundary) exists
        assert any(s.producer is None for s in add_inputs) is False or True

    def test_single_region_for_connected_graph(self):
        plan = plan_streams(cnn_graphs.residual_block(32))
        assert len(plan.regions) == 1
        assert set(plan.regions[0].node_names) == {n.name for n in plan.dfg.nodes}


class TestPaperSuite:
    @pytest.mark.parametrize("name", list(cnn_graphs.PAPER_SUITE))
    def test_all_kernels_plan(self, name):
        dfg = cnn_graphs.PAPER_SUITE[name]()
        plan = plan_streams(dfg)
        assert plan.total_buffer_bits() > 0
        for node in plan.node_order():
            assert node.loops.total_trip >= 1
