"""Compile-time dataflow analyzer (ISSUE 9): interval arithmetic, the
four rule families, compile_design integration (lint=warn/error/off),
Report/trace surfacing, the `python -m repro lint` CLI, and the
overflow-safe ⇒ bit-exact property sweep.

Acceptance pins:

* the range analyzer flags the pre-fix PR 7 int8 accumulator
  (``acc_bits="input"``) as ERROR naming the node, while the fixed
  int32 path and every zoo model lint clean at ERROR on both targets;
* ``compile_design(lint="error")`` rejects a deliberately
  under-buffered reconvergent graph with a stream-skew (SK1)
  diagnostic.
"""
import json

import numpy as np
import pytest

from repro import api
from repro.analyze import (
    ACC_INPUT_DTYPE,
    RULES,
    Diagnostic,
    Interval,
    LintError,
    Severity,
    analyze_hygiene,
    analyze_ranges,
    analyze_schedule,
    analyze_stream_skew,
    at_or_above,
    diagnostics_to_json,
    dtype_interval,
    max_severity,
    overflow_safe,
    severity_counts,
    value_intervals,
)
from repro.core import cnn_graphs
from repro.core.ir import FusedEpilogue, PayloadKind, Value
from repro.core.streaming import fifo_slack, plan_streams
from repro.frontends import zoo
from repro.passes import interp, partition_layer_groups, run_default_pipeline

TARGETS = ("kv260", "zu3eg")


def _residual():
    return zoo.ZOO["edge_residual_32"]()


# ---------------------------------------------------------------------------
# Interval arithmetic
# ---------------------------------------------------------------------------


class TestInterval:
    def test_dtype_interval(self):
        assert dtype_interval(8) == Interval(-128, 127)
        assert dtype_interval(16) == Interval(-32768, 32767)

    def test_bits_round_trip(self):
        assert Interval(-128, 127).bits == 8
        assert Interval(-129, 0).bits == 9
        assert Interval(0, 255).bits == 9  # signed carrier needs the sign bit
        assert Interval(0, 0).bits == 1
        assert dtype_interval(32).bits == 32

    def test_mul_four_corners(self):
        a, b = Interval(-3, 2), Interval(-5, 7)
        assert a.mul(b) == Interval(-21, 15)

    def test_scale_models_k_term_sum(self):
        assert Interval(-2, 3).scale(10) == Interval(-20, 30)

    def test_relu_and_join(self):
        assert Interval(-5, 3).relu() == Interval(0, 3)
        assert Interval(-5, 3).join_max(Interval(-1, 1)) == Interval(-1, 3)

    def test_empty_interval_rejected(self):
        with pytest.raises(ValueError, match="empty"):
            Interval(2, 1)

    def test_fits(self):
        assert Interval(-128, 127).fits(8)
        assert not Interval(-129, 0).fits(8)


# ---------------------------------------------------------------------------
# Range analysis — the PR 7 regression, statically
# ---------------------------------------------------------------------------


class TestRanges:
    def test_prefix_int8_accumulator_flagged(self):
        """The pre-fix PR 7 lowering (accumulate in the stream dtype)
        must be flagged ERROR, naming the offending conv node."""
        diags = analyze_ranges(zoo.ZOO["lenet5"](), acc_bits=ACC_INPUT_DTYPE)
        r1 = [d for d in diags if d.rule == "R1"]
        assert r1, "int8 accumulator wrap not detected"
        assert all(d.severity is Severity.ERROR for d in r1)
        assert any(d.node == "conv0" for d in r1)
        first = next(d for d in r1 if d.node == "conv0")
        assert "8 bits" in first.message and "accumulator" in first.message
        assert "int32" in first.hint

    def test_fixed_int32_path_clean(self):
        """The shipped conv2d_same_mm lowering (int32 accumulators) is
        overflow-safe on every zoo model."""
        for name, make in zoo.ZOO.items():
            assert overflow_safe(make()), name

    def test_custom_acc_width_threshold(self):
        dfg = cnn_graphs.conv_relu(8)  # 3x3x3 = 27-tap int8 MACs
        # 27 * [-16256, 16384] needs 20 bits
        assert not overflow_safe(dfg, acc_bits=16)
        assert overflow_safe(dfg, acc_bits=20)

    def test_int16_conv_not_declared_safe(self):
        """Full-range int16 operands genuinely can wrap an int32
        accumulator — the analyzer must refuse to declare them safe."""
        dfg = cnn_graphs.conv_relu(8)
        for v in dfg.values.values():
            v.elem_bits = 16
        for n in dfg.nodes:
            n.elem_bits = 16
        assert not overflow_safe(dfg)

    def test_intervals_clamped_to_stream_dtype(self):
        """Propagated intervals never exceed what the stream carries —
        the soundness clamp that keeps deep graphs analyzable."""
        dfg = zoo.ZOO["tiny_vgg_32"]()
        env = value_intervals(dfg)
        for name, iv in env.items():
            bits = dfg.values[name].elem_bits
            carrier = dtype_interval(bits)
            assert iv.lo >= carrier.lo and iv.hi <= carrier.hi, name

    def test_requant_clamp_is_reported(self):
        diags = analyze_ranges(zoo.ZOO["lenet5"]())
        r2 = [d for d in diags if d.rule == "R2"]
        assert r2 and all(d.severity is Severity.INFO for d in r2)


# ---------------------------------------------------------------------------
# Stream skew / deadlock
# ---------------------------------------------------------------------------


class TestStreamSkew:
    def test_sized_plan_reports_joins_not_errors(self):
        plan = plan_streams(run_default_pipeline(_residual()).dfg)
        slack = fifo_slack(plan)
        assert slack, "residual model must have reconvergent skew"
        diags = analyze_stream_skew(plan)
        assert {d.rule for d in diags} == {"SK2"}
        assert len(diags) == len(slack)

    def test_underbuffered_fifo_is_deadlock_error(self):
        plan = plan_streams(run_default_pipeline(_residual()).dfg)
        name, need = next(iter(sorted(fifo_slack(plan).items())))
        plan.streams[name].depth = need - 1
        diags = analyze_stream_skew(plan, group="g0")
        sk1 = [d for d in diags if d.rule == "SK1"]
        assert len(sk1) == 1
        d = sk1[0]
        assert d.severity is Severity.ERROR and d.node == name
        assert d.group == "g0"
        assert f">= {need}" in d.hint

    def test_sizing_pass_and_analyzer_share_slack(self):
        """fifo_slack is the single source of truth: every sized skip
        FIFO's depth equals (at least) the slack the analyzer checks."""
        plan = plan_streams(run_default_pipeline(_residual()).dfg)
        for name, need in fifo_slack(plan).items():
            assert plan.streams[name].depth >= need


# ---------------------------------------------------------------------------
# Schedule hazards
# ---------------------------------------------------------------------------


def _two_group_design():
    fused = run_default_pipeline(cnn_graphs.cascade_conv(16, c_mid=8)).dfg
    pp = partition_layer_groups(fused, b_total=2)
    assert len(pp.groups) == 2
    return pp


class TestHazards:
    def test_clean_schedule_small_boundary_warns_sh3(self):
        pp = _two_group_design()
        diags = analyze_schedule(pp)
        assert not [d for d in diags if d.severity is Severity.ERROR]
        # the 2 KiB boundary is smaller than one 4 KiB DRAM burst
        sh3 = [d for d in diags if d.rule == "SH3"]
        assert sh3 and "DRAM burst" in sh3[0].message

    def test_budget_overcommit_sh1(self):
        pp = _two_group_design()
        pp.b_total = pp.groups[0].bram - 1
        diags = analyze_schedule(pp)
        sh1 = [d for d in diags if d.rule == "SH1"]
        assert sh1 and sh1[0].severity is Severity.ERROR
        assert "BRAM" in sh1[0].message
        assert sh1[0].group == pp.groups[0].name

    def test_read_before_write_sh2(self):
        pp = _two_group_design()
        # tamper: group 0 no longer spills what group 1 fills
        spilled = pp.groups[0].spill_out.pop()
        diags = analyze_schedule(pp)
        sh2 = [d for d in diags if d.rule == "SH2"]
        assert len(sh2) == 1
        assert sh2[0].severity is Severity.ERROR
        assert sh2[0].node == spilled
        assert "unwritten" in sh2[0].message


# ---------------------------------------------------------------------------
# Hygiene lints
# ---------------------------------------------------------------------------


class TestHygiene:
    def test_clean_graph_is_silent(self):
        assert analyze_hygiene(cnn_graphs.conv_relu(8)) == []

    def test_h1_unused_constant(self):
        dfg = cnn_graphs.conv_relu(8)
        dfg.add_value(Value("dead_w", (3, 3, 3, 16), 8, is_constant=True))
        d = analyze_hygiene(dfg)
        assert [x.rule for x in d] == ["H1"]
        assert d[0].node == "dead_w" and "no node" in d[0].message

    def test_h2_dtype_inconsistent_epilogue_operand(self):
        dfg = cnn_graphs.conv_relu(8)
        dfg.add_value(Value("bias", (16,), 16, is_constant=True))
        dfg.nodes[0].epilogue = (FusedEpilogue(PayloadKind.ADD, "bias"),)
        d = [x for x in analyze_hygiene(dfg) if x.rule == "H2"]
        assert len(d) == 1 and d[0].node == "conv0"
        assert "16-bit" in d[0].message

    def test_h3_dead_output(self):
        dfg = cnn_graphs.cascade_conv(8)
        dfg.graph_outputs = ["relu0_out"]  # conv1/relu1 now dead
        d = [x for x in analyze_hygiene(dfg) if x.rule == "H3"]
        assert d and any(x.node == "relu1" for x in d)

    def test_h4_narrowing_stream(self):
        dfg = cnn_graphs.conv_relu(8)
        dfg.values["conv0_out"].elem_bits = 16
        d = [x for x in analyze_hygiene(dfg) if x.rule == "H4"]
        assert len(d) == 1 and d[0].node == "relu0"
        assert "truncation" in d[0].message


# ---------------------------------------------------------------------------
# Diagnostic model + rule catalog
# ---------------------------------------------------------------------------


class TestDiagnosticModel:
    def test_format(self):
        d = Diagnostic(rule="R1", severity=Severity.ERROR, graph="g",
                       node="conv0", message="m", hint="h")
        assert d.format() == "error[R1] g/conv0: m (hint: h)"
        assert d.location == "g/conv0"

    def test_severity_order_and_parse(self):
        assert Severity.INFO.rank < Severity.WARNING.rank < Severity.ERROR.rank
        assert Severity.parse("ERROR") is Severity.ERROR
        with pytest.raises(ValueError, match="unknown severity"):
            Severity.parse("fatal")

    def test_helpers(self):
        mk = lambda s: Diagnostic(rule="X", severity=s, graph="g", message="m")
        diags = [mk(Severity.INFO), mk(Severity.ERROR), mk(Severity.INFO)]
        assert max_severity(diags) is Severity.ERROR
        assert max_severity([]) is None
        assert severity_counts(diags) == {"info": 2, "warning": 0, "error": 1}
        assert len(at_or_above(diags, "warning")) == 1

    def test_json_envelope(self):
        d = Diagnostic(rule="SK1", severity=Severity.ERROR, graph="g",
                       group="g0", node="s", message="m", hint="h")
        doc = diagnostics_to_json([d], meta={"targets": ["kv260"]})
        assert doc["version"] == 1
        assert doc["counts"]["error"] == 1
        assert doc["diagnostics"][0] == {
            "rule": "SK1", "severity": "error", "message": "m",
            "graph": "g", "node": "s", "group": "g0", "hint": "h",
        }
        assert doc["meta"] == {"targets": ["kv260"]}
        json.dumps(doc)  # serializable

    def test_rule_catalog_complete(self):
        assert set(RULES) == {"SK1", "SK2", "R1", "R2",
                              "SH1", "SH2", "SH3", "H1", "H2", "H3", "H4"}
        for rid, r in RULES.items():
            assert r.id == rid and r.summary
            assert r.scope in ("dfg", "plan", "design")

    def test_lint_error_carries_diagnostics(self):
        d = Diagnostic(rule="R1", severity=Severity.ERROR, graph="g",
                       node="n", message="m")
        i = Diagnostic(rule="R2", severity=Severity.INFO, graph="g",
                       message="m2")
        e = LintError([d, i], graph="g")
        assert e.diagnostics == (d, i)
        assert "1 ERROR-severity" in str(e) and "error[R1]" in str(e)


# ---------------------------------------------------------------------------
# compile_design integration
# ---------------------------------------------------------------------------


class TestCompileIntegration:
    @pytest.mark.parametrize("target", TARGETS)
    def test_zoo_error_clean_on_both_targets(self, target):
        """Acceptance: every zoo model compiles under lint="error" on
        both device presets — zero ERROR-severity diagnostics."""
        for name, make in zoo.ZOO.items():
            design = api.compile_design(
                make(), options=api.CompileOptions(target=target,
                                                   lint="error"))
            errs = [d for d in design.diagnostics
                    if d.severity is Severity.ERROR]
            assert not errs, f"{name} @ {target}: {errs}"

    def test_warn_mode_stores_diagnostics(self):
        design = api.compile_design(zoo.ZOO["lenet5"]())  # default: warn
        assert design.diagnostics
        assert max_severity(design.diagnostics) is Severity.INFO

    def test_off_mode_skips_analysis(self):
        design = api.compile_design(
            zoo.ZOO["lenet5"](), options=api.CompileOptions(lint="off"))
        assert design.diagnostics == []

    def test_invalid_lint_value_rejected(self):
        with pytest.raises(ValueError, match="lint"):
            api.CompileOptions(lint="loud")

    def test_lint_excluded_from_cache_key(self):
        keys = {api.CompileOptions(lint=m).cache_key()
                for m in ("warn", "error", "off")}
        assert len(keys) == 1

    def test_underbuffered_reconvergent_rejected(self, monkeypatch):
        """Acceptance: with FIFO sizing disabled, the residual model's
        skip FIFOs cannot absorb the line-buffer skew and lint="error"
        must reject the compile with a stream-skew diagnostic."""
        import repro.core.streaming as streaming

        monkeypatch.setattr(streaming, "_size_diamond_fifos",
                            lambda plan: None)
        with pytest.raises(LintError, match=r"error\[SK1\].*deadlock") as ei:
            api.compile_design(_residual(),
                               options=api.CompileOptions(lint="error"))
        assert any(d.rule == "SK1" for d in ei.value.diagnostics)

    def test_underbuffered_reconvergent_warn_mode_compiles(self,
                                                           monkeypatch):
        import repro.core.streaming as streaming

        monkeypatch.setattr(streaming, "_size_diamond_fifos",
                            lambda plan: None)
        design = api.compile_design(_residual(),
                                    options=api.CompileOptions(lint="warn"))
        assert any(d.rule == "SK1" for d in design.diagnostics)


# ---------------------------------------------------------------------------
# Report / telemetry / trace surfacing
# ---------------------------------------------------------------------------


class TestSurfacing:
    @pytest.fixture(scope="class")
    def lenet_traced(self):
        return api.compile_graph(zoo.ZOO["lenet5"](),
                                 api.CompileOptions(trace=True))

    def test_report_lint_line(self, lenet_traced):
        rep = str(lenet_traced.report())
        assert "lint: 0 error(s), 0 warning(s)" in rep

    def test_telemetry_carries_diagnostics(self, lenet_traced):
        tel = lenet_traced._telemetry()
        assert tel["diagnostics"]["counts"]["error"] == 0
        assert tel["diagnostics"]["items"]
        assert all("rule" in it for it in tel["diagnostics"]["items"])

    def test_artifact_diagnostics_property(self, lenet_traced):
        diags = lenet_traced.diagnostics
        assert diags and all(isinstance(d, Diagnostic) for d in diags)

    def test_analyze_spans_in_trace(self, lenet_traced):
        ev = lenet_traced.design.tracer.to_chrome()["traceEvents"]
        spans = [e for e in ev if e["name"].startswith("analyze:")]
        assert spans, "no analyze spans recorded"
        assert all(e["cat"] == "analyze" for e in spans)
        # the root span counts its findings
        root = [e for e in spans if e["name"] == "analyze:lenet5"]
        assert len(root) == 1
        assert root[0]["args"]["diagnostics"] == 5
        assert root[0]["args"]["errors"] == 0


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def _cli(subproc, *argv):
    args = ", ".join(repr(a) for a in argv)
    return subproc(
        "from repro.__main__ import main\n"
        f"raise SystemExit(main([{args}]))\n",
        env={"JAX_PLATFORMS": "cpu"},
    )


class TestLintCli:
    def test_clean_model_exits_zero(self, subproc, tmp_path):
        out = tmp_path / "diag.json"
        r = _cli(subproc, "lint", "lenet5", "--json", str(out))
        assert r.returncode == 0, r.stderr
        assert "lenet5 @ kv260" in r.stdout
        doc = json.loads(out.read_text())
        assert doc["version"] == 1 and doc["counts"]["error"] == 0
        assert doc["meta"]["graphs"][0]["graph"] == "lenet5"

    def test_fail_on_info_exits_one(self, subproc):
        r = _cli(subproc, "lint", "lenet5", "--fail-on", "info", "--quiet")
        assert r.returncode == 1
        assert "at/above 'info'" in r.stderr

    def test_unknown_graph_exits_two(self, subproc):
        r = _cli(subproc, "lint", "no_such_model")
        assert r.returncode == 2
        assert "unknown graph" in r.stderr

    def test_no_graphs_exits_two(self, subproc):
        r = _cli(subproc, "lint")
        assert r.returncode == 2
        assert "--all" in r.stderr

    def test_multi_target(self, subproc):
        r = _cli(subproc, "lint", "conv_relu_32", "--target", "kv260",
                 "--target", "zu3eg")
        assert r.returncode == 0, r.stderr
        assert "conv_relu_32 @ kv260" in r.stdout
        assert "conv_relu_32 @ zu3eg" in r.stdout


# ---------------------------------------------------------------------------
# Property sweep: overflow-safe ⇒ vmap/loop bit-exact (satellite)
# ---------------------------------------------------------------------------

N_SEEDS = 4

_SAFE_GRAPHS = {
    "conv_relu_8": lambda: cnn_graphs.conv_relu(8),
    "conv_pool_8": lambda: cnn_graphs.conv_pool(8),
    "conv_avgpool_8": lambda: cnn_graphs.conv_avgpool(8),
    "cascade_conv_8": lambda: cnn_graphs.cascade_conv(8, c_mid=8),
}


class TestOverflowSafeBitExact:
    """The analyzer's safety claim, checked dynamically: every graph it
    declares overflow-safe executes bit-identically under the vmapped
    batched path and the per-sample loop on full-range random int8
    inputs — the exact scenario the pre-fix PR 7 lowering corrupted."""

    @pytest.mark.parametrize("name", sorted(_SAFE_GRAPHS))
    def test_declared_safe_runs_bit_exact(self, name):
        dfg = _SAFE_GRAPHS[name]()
        assert overflow_safe(dfg), f"{name} unexpectedly unsafe"
        art = api.compile_graph(dfg, api.CompileOptions())
        src = art.design.source
        gi = src.graph_inputs[0]
        shape = tuple(src.values[gi].shape)
        for seed in range(N_SEEDS):
            rng = np.random.default_rng(seed)
            x = rng.integers(-128, 128, size=(3,) + shape, dtype=np.int32)
            params = {k: np.asarray(v)
                      for k, v in interp.random_env(src, seed=seed).items()
                      if src.values[k].is_constant}
            a = art.run({gi: x}, params=params, interpret=True,
                        batch_mode="vmap")
            b = art.run({gi: x}, params=params, interpret=True,
                        batch_mode="loop")
            np.testing.assert_array_equal(a, b, err_msg=f"{name} seed {seed}")
