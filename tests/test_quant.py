"""Weight-only int8 PTQ (the paper's inference regime, LM path)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.launch.serve import ServeEngine
from repro.models import lm
from repro.quant import dequantize_params, quantize_params
from repro.quant.ptq import QTensor, quantization_error


class TestQTensor:
    def test_matrices_quantized_vectors_kept(self):
        params = {"w": jnp.ones((8, 16)) * 0.5, "ln": jnp.ones(16),
                  "step": jnp.zeros((), jnp.int32)}
        q = quantize_params(params)
        assert isinstance(q["w"], QTensor)
        assert q["w"].q.dtype == jnp.int8
        assert not isinstance(q["ln"], QTensor)
        assert not isinstance(q["step"], QTensor)

    def test_roundtrip_error_bounded(self):
        w = jax.random.normal(jax.random.key(0), (64, 128)) * 0.1
        q = quantize_params({"w": w})
        d = dequantize_params(q, jnp.float32)["w"]
        # absmax per channel → error ≤ scale/2 = amax/254 per channel
        amax = np.abs(np.asarray(w)).max(axis=0, keepdims=True)
        assert (np.abs(np.asarray(d) - np.asarray(w)) <= amax / 254 + 1e-7).all()

    def test_per_channel_scales(self):
        # one huge column must not destroy the precision of others
        w = jnp.ones((16, 4)) * 0.01
        w = w.at[:, 0].set(100.0)
        d = dequantize_params(quantize_params({"w": w}), jnp.float32)["w"]
        np.testing.assert_allclose(np.asarray(d[:, 1:]), 0.01, rtol=0.01)

    def test_halves_weight_bytes(self):
        params = lm.init_params(jax.random.key(0),
                                get_config("llama3.2-1b", smoke=True))
        q = quantize_params(params)

        def nbytes(t):
            return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(t))

        assert nbytes(q) < nbytes(params) * 0.65  # int8 + f32 scales vs bf16

    def test_error_report(self):
        params = lm.init_params(jax.random.key(0),
                                get_config("qwen2-0.5b", smoke=True))
        errs = quantization_error(params, quantize_params(params))
        assert errs and max(errs.values()) < 0.01


class TestInt8Model:
    def test_quantized_forward_close(self):
        cfg = get_config("llama3.2-1b", smoke=True).with_(remat=False)
        params = lm.init_params(jax.random.key(0), cfg)
        tokens = jax.random.randint(jax.random.key(1), (2, 16), 0,
                                    cfg.vocab_size)
        ref_logits, _ = lm.lm_prefill(params, cfg, {"tokens": tokens})
        qp = quantize_params(params)
        q_logits, _ = lm.lm_prefill(
            dequantize_params(qp, cfg.param_dtype), cfg, {"tokens": tokens}
        )
        # int8 weight noise: logits agree to ~1e-1 absolute on a unit-scale
        # random model, and top-1 rarely flips
        ref, got = np.asarray(ref_logits), np.asarray(q_logits)
        assert np.mean(np.abs(ref - got)) < 0.15
        agree = (ref.argmax(-1) == got.argmax(-1)).mean()
        assert agree >= 0.5

    def test_engine_int8_generates(self):
        cfg = get_config("qwen2-0.5b", smoke=True)
        eng = ServeEngine(cfg, max_len=64, int8_weights=True)
        rng = np.random.default_rng(0)
        prompts = rng.integers(0, cfg.vocab_size, (2, 16), dtype=np.int32)
        out, stats = eng.generate(prompts, max_new=6)
        assert out.shape == (2, 6)
        assert out.min() >= 0 and out.max() < cfg.vocab_size
        # deterministic
        out2, _ = eng.generate(prompts, max_new=6)
        np.testing.assert_array_equal(out, out2)

    def test_engine_int8_close_to_fp(self):
        cfg = get_config("llama3.2-1b", smoke=True).with_(remat=False)
        fp = ServeEngine(cfg, max_len=48, seed=0)
        q8 = ServeEngine(cfg, max_len=48, seed=0, int8_weights=True)
        rng = np.random.default_rng(1)
        prompts = rng.integers(0, cfg.vocab_size, (2, 16), dtype=np.int32)
        o_fp, _ = fp.generate(prompts, max_new=4)
        o_q8, _ = q8.generate(prompts, max_new=4)
        # same-seed init → greedy tokens mostly agree under int8 noise
        assert (o_fp == o_q8).mean() >= 0.5
