"""Layout machinery (ISSUE 5 tentpole): transpose/flatten IR ops, the
NCHW↔NHWC canonicalization pass, and the V10 verifier invariant.

The load-bearing property: the layout pass may move and cancel
transposes however it likes, but the rewritten graph must stay
*bit-exact* with the original on random integer inputs — checked here
on importer-shaped graphs (sandwiched convs/pools, residual diamonds,
NCHW classifier heads).
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_fallback import given, settings, st

from repro.api.builder import Flatten, FrontendError, Graph, Sequential
from repro.core.analysis import reorder_spec
from repro.core.ir import (
    PayloadKind,
    Value,
    make_flatten_op,
    make_transpose_op,
)
from repro.passes import (
    LayoutCanonicalize,
    PASS_REGISTRY,
    VerificationError,
    interp,
    run_default_pipeline,
    verify_dfg,
)

NCHW2NHWC = (0, 2, 3, 1)
NHWC2NCHW = (0, 3, 1, 2)


def _exact(dfg_a, dfg_b, seed=0):
    env = interp.random_env(dfg_a, seed=seed)
    a = interp.graph_outputs(dfg_a, env)
    b = interp.graph_outputs(dfg_b, env)
    assert set(a) == set(b)
    for k in a:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))


def _imported_chain(with_residual=False):
    """An importer-shaped graph: NCHW boundary, transpose sandwiches."""
    g = Graph("imported")
    x = g.input((1, 3, 8, 8))
    h = g.transpose(x, NCHW2NHWC)
    h = g.conv2d(h, 8)
    h = g.transpose(h, NHWC2NCHW)
    h = g.relu(h)
    if with_residual:
        skip = h
        h = g.transpose(h, NCHW2NHWC)
        h = g.conv2d(h, 8)
        h = g.transpose(h, NHWC2NCHW)
        h = g.add(h, skip)
    h = g.transpose(h, NCHW2NHWC)
    h = g.conv2d(h, 4)
    h = g.transpose(h, NHWC2NCHW)
    h = g.flatten(h)
    h = g.dense(h, 10)
    g.output(h)
    return g.build()


class TestReorderOps:
    def test_transpose_semantics(self):
        g = Graph("t")
        x = g.input((1, 5, 4, 3))
        g.output(g.transpose(x, NHWC2NCHW))
        dfg = g.build()
        verify_dfg(dfg)
        env = interp.random_env(dfg, seed=0)
        out = interp.graph_outputs(dfg, env)
        np.testing.assert_array_equal(
            np.asarray(out[dfg.graph_outputs[0]]),
            np.transpose(np.asarray(env["x"]), NHWC2NCHW),
        )

    def test_flatten_semantics_with_order(self):
        g = Graph("t")
        x = g.input((1, 4, 3, 2))
        g.output(g.flatten(x, order=(3, 1, 2)))  # channels-major
        dfg = g.build()
        verify_dfg(dfg)
        env = interp.random_env(dfg, seed=1)
        out = np.asarray(
            interp.graph_outputs(dfg, env)[dfg.graph_outputs[0]]
        )
        want = np.transpose(np.asarray(env["x"]), (0, 3, 1, 2)).reshape(1, -1)
        np.testing.assert_array_equal(out, want)

    def test_reorder_spec_recovers_structure(self):
        t = make_transpose_op("t", "a", "b", in_shape=(1, 2, 3, 4),
                              perm=NCHW2NHWC)
        assert reorder_spec(t) == ("transpose", NCHW2NHWC)
        f = make_flatten_op("f", "a", "b", in_shape=(1, 2, 3, 4),
                            order=(3, 1, 2))
        assert reorder_spec(f) == ("flatten", (3, 1, 2))

    def test_reorder_spec_handles_extent_one_stride_ties(self):
        """Extent-1 axes tie on stride with their neighbour; recovery
        must still accept every order the builder can produce (the
        recovered order may swap tied extent-1 axes — the op is
        identical either way)."""
        import itertools

        for shape in ((1, 4, 1, 3), (1, 1, 5, 1), (1, 2, 1, 1)):
            for order in itertools.permutations((1, 2, 3)):
                f = make_flatten_op("f", "a", "b", in_shape=shape,
                                    order=order)
                spec = reorder_spec(f)
                assert spec is not None and spec[0] == "flatten", \
                    (shape, order)
                # rebuilding from the recovered order gives the same op
                g = make_flatten_op("f", "a", "b", in_shape=shape,
                                    order=spec[1])
                assert g == f, (shape, order, spec)

    def test_extent_one_flatten_compiles_end_to_end(self):
        """Regression: V10 once rejected builder-legal flattens whose
        extent-1 axis tied strides with a neighbour."""
        from repro import api

        g = Graph("t")
        x = g.input((1, 4, 1, 3))
        g.output(g.flatten(x, order=(1, 3, 2)))
        dfg = g.build()
        verify_dfg(dfg)
        art = api.compile_graph(dfg)
        env = interp.random_env(dfg, seed=0)
        got = np.asarray(art.run({"x": env["x"]}, params=env,
                                 interpret=True))
        want = np.transpose(np.asarray(env["x"]),
                            (0, 1, 3, 2)).reshape(1, -1)
        np.testing.assert_array_equal(got, want)

    def test_builder_validates_perm_and_rank(self):
        g = Graph("t")
        x = g.input((1, 4, 4, 2))
        with pytest.raises(FrontendError, match="not a permutation"):
            g.transpose(x, (0, 1, 2, 2))
        with pytest.raises(FrontendError, match="not a permutation"):
            g.flatten(x, order=(1, 1, 2))
        y = g.input((4,), name="vec")
        with pytest.raises(FrontendError, match="rank >= 2"):
            g.flatten(y)

    def test_canonicalize_keeps_reorder_ops(self):
        """Identity-payload data movers must survive identity removal."""
        dfg = _imported_chain()
        n_before = sum(
            1 for n in dfg.nodes if reorder_spec(n) is not None
        )
        from repro.passes import Canonicalize

        Canonicalize().run_on(dfg)
        n_after = sum(
            1 for n in dfg.nodes if reorder_spec(n) is not None
        )
        assert n_before == n_after
        verify_dfg(dfg)

    def test_verifier_v10_rejects_malformed_reorder(self):
        g = Graph("t")
        x = g.input((1, 4, 4, 2))
        g.output(g.transpose(x, NHWC2NCHW))
        dfg = g.build()
        # corrupt the output shape: V10 must fire
        dfg.values[dfg.graph_outputs[0]].shape = (1, 4, 4, 2)
        with pytest.raises(VerificationError, match="V8|V10"):
            verify_dfg(dfg)

    def test_verifier_v10_rejects_epilogue_on_reorder(self):
        g = Graph("t")
        x = g.input((1, 4, 4, 2))
        g.output(g.transpose(x, NHWC2NCHW))
        dfg = g.build()
        from repro.core.ir import FusedEpilogue

        dfg.nodes[0].epilogue = (FusedEpilogue(PayloadKind.RELU),)
        with pytest.raises(VerificationError, match="V10"):
            verify_dfg(dfg)


class TestLayoutPass:
    def test_registered(self):
        assert "layout" in PASS_REGISTRY
        assert PASS_REGISTRY["layout"] is LayoutCanonicalize

    def test_cancels_adjacent_inverse_pair(self):
        g = Graph("t")
        x = g.input((1, 2, 4, 4))
        h = g.transpose(x, NCHW2NHWC)
        h = g.transpose(h, NHWC2NCHW)
        h = g.relu(h)
        g.output(h)
        dfg = g.build()
        stats = LayoutCanonicalize().run_on(dfg)
        assert stats["transposes_cancelled"] == 1
        verify_dfg(dfg)
        assert not any(reorder_spec(n) for n in dfg.nodes)

    def test_composes_non_inverse_pair(self):
        g = Graph("t")
        x = g.input((1, 2, 3, 4))
        h = g.transpose(x, (0, 2, 3, 1))
        h = g.transpose(h, (0, 2, 3, 1))
        g.output(h)
        dfg = g.build()
        ref = dfg.clone()
        stats = LayoutCanonicalize().run_on(dfg)
        assert stats["transposes_composed"] == 1
        verify_dfg(dfg)
        assert sum(1 for n in dfg.nodes if reorder_spec(n)) == 1
        _exact(ref, dfg)

    def test_sinks_relu_and_cancels_sandwich(self):
        dfg = _imported_chain()
        ref = dfg.clone()
        stats = LayoutCanonicalize().run_on(dfg)
        verify_dfg(dfg)
        assert stats["elementwise_sunk"] >= 1
        assert stats["transposes_cancelled"] >= 1
        assert stats["flatten_folds"] == 1
        _exact(ref, dfg)

    def test_residual_add_sinks_below_matching_transposes(self):
        dfg = _imported_chain(with_residual=True)
        ref = dfg.clone()
        LayoutCanonicalize().run_on(dfg)
        verify_dfg(dfg)
        _exact(ref, dfg)
        # after the full pipeline only the boundary transpose survives
        res = run_default_pipeline(_imported_chain(with_residual=True))
        live = [n for n in res.dfg.nodes
                if (reorder_spec(n) or ("", 0))[0] == "transpose"]
        assert len(live) == 1

    def test_input_to_output_round_trip_is_not_cancelled(self):
        """A cancelling pair that spans graph input → graph output has
        nothing to rewire into — cancelling it would alias the output
        to the input and empty the graph (which the emitter rejects)."""
        from repro import api
        from repro.core.emit_hls import emit_design

        g = Graph("t")
        x = g.input((1, 2, 4, 4))
        h = g.transpose(x, NCHW2NHWC)
        g.output(g.transpose(h, NHWC2NCHW))
        dfg = g.build()
        ref = dfg.clone()
        LayoutCanonicalize().run_on(dfg)
        verify_dfg(dfg)
        assert dfg.nodes, "pass must not empty the graph"
        _exact(ref, dfg)
        # and the whole front door still emits
        art = api.compile_graph(ref)
        files = emit_design(art.design)
        assert "host_schedule.cpp" in files

    def test_shared_transpose_output_is_left_alone(self):
        """A transpose with two consumers must not be repurposed."""
        g = Graph("t")
        x = g.input((1, 2, 4, 4))
        h = g.transpose(x, NCHW2NHWC)
        a = g.relu(h)
        b = g.relu(h, name="relu_b")
        g.output(g.add(a, b))
        dfg = g.build()
        ref = dfg.clone()
        LayoutCanonicalize().run_on(dfg)
        verify_dfg(dfg)
        _exact(ref, dfg)

    def test_pipeline_keeps_fusion_wins_on_imported_graphs(self):
        """After layout canonicalization the imported chain fuses like
        a native one: conv+relu collapse, interior reorders disappear."""
        res = run_default_pipeline(_imported_chain())
        kinds = [reorder_spec(n) for n in res.dfg.nodes]
        transposes = [s for s in kinds if s and s[0] == "transpose"]
        assert len(transposes) == 1  # only the NCHW boundary
        convs = [n for n in res.dfg.nodes
                 if n.payload == PayloadKind.MAC and n.n_dims == 7]
        assert any(n.epilogue for n in convs)  # relu fused in

    def test_default_pipeline_bit_exact_on_imported_shapes(self):
        for make in (lambda: _imported_chain(False),
                     lambda: _imported_chain(True)):
            dfg = make()
            res = run_default_pipeline(dfg)
            _exact(dfg, res.dfg, seed=4)


class TestDeepImports:
    def test_deep_sandwich_chain_reaches_fixpoint(self):
        """A VGG-16-scale import (~40 sandwiched layers) must fully
        canonicalize — no silent iteration-cap stall leaving interior
        transposes (regression for the old fixed 100-rewrite cap)."""
        import warnings

        g = Graph("deep")
        h = g.input((1, 2, 4, 4))
        for _ in range(40):
            h = g.transpose(h, NCHW2NHWC)
            h = g.conv2d(h, 2)
            h = g.transpose(h, NHWC2NCHW)
            h = g.relu(h)
        h = g.flatten(h)
        g.output(g.dense(h, 3))
        dfg = g.build()
        with warnings.catch_warnings():
            warnings.simplefilter("error")  # the stall warning is fatal
            res = run_default_pipeline(dfg)
        transposes = [n for n in res.dfg.nodes
                      if (reorder_spec(n) or ("",))[0] == "transpose"]
        assert len(transposes) == 1


class TestLayoutProperty:
    @given(st.integers(2, 5), st.integers(1, 4), st.integers(0, 1))
    @settings(max_examples=10, deadline=None)
    def test_random_sandwich_depths_stay_exact(self, hw, c, residual):
        n = 2 * hw
        g = Graph("p")
        x = g.input((1, c, n, n))
        h = g.transpose(x, NCHW2NHWC)
        h = g.conv2d(h, 4)
        h = g.transpose(h, NHWC2NCHW)
        h = g.relu(h)
        if residual:
            skip = h
            h = g.transpose(h, NCHW2NHWC)
            h = g.conv2d(h, 4)
            h = g.transpose(h, NHWC2NCHW)
            h = g.add(h, skip)
        h = g.flatten(h)
        g.output(g.dense(h, 3))
        dfg = g.build()
        res = run_default_pipeline(dfg)
        _exact(dfg, res.dfg, seed=hw * 7 + c)


class TestReorderThroughBackends:
    def test_sequential_flatten_layer(self):
        net = Sequential(
            [Flatten()], input_shape=(1, 3, 4, 2), name="flat",
        )
        dfg = net.build()
        assert dfg.values[dfg.graph_outputs[0]].shape == (1, 24)

    def test_compiled_artifact_runs_reorders_bit_exact(self):
        from repro import api

        dfg = _imported_chain(with_residual=True)
        env = interp.random_env(dfg, seed=9)
        want = interp.graph_outputs(dfg, env)
        for t in ("kv260", "zu3eg"):
            art = api.compile_graph(dfg, api.CompileOptions(target=t))
            assert art.feasible
            got = art.run({k: env[k] for k in dfg.graph_inputs},
                          params=env, interpret=True)
            np.testing.assert_array_equal(
                np.asarray(want[dfg.graph_outputs[0]]), np.asarray(got)
            )

    def test_emitter_handles_reorder_nodes(self):
        from repro.core.compile_driver import CompileOptions, compile_design
        from repro.core.emit_hls import emit_design

        # no passes: the transposes are still in the emitted design
        d = compile_design(_imported_chain(),
                           options=CompileOptions(passes=()))
        files = emit_design(d)
        cpp = "".join(files.values())
        assert "transpose0" in cpp and "flatten0" in cpp

    def test_streaming_charges_reorder_buffer(self):
        from repro.core.streaming import plan_streams

        g = Graph("t")
        x = g.input((1, 8, 8, 4))
        g.output(g.transpose(x, NHWC2NCHW))
        plan = plan_streams(g.build())
        node = plan.nodes["transpose0"]
        assert node.line_buffer_bits == 8 * 8 * 4 * 8  # full tensor

        # an in-order flatten is a pure wire: no buffer
        g2 = Graph("t2")
        y = g2.input((1, 8, 8, 4))
        g2.output(g2.flatten(y))
        plan2 = plan_streams(g2.build())
        assert plan2.nodes["flatten0"].line_buffer_bits == 0
