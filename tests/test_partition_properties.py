"""Property-test suite for the partition DP (ISSUE 3 satellite).

Random conv/relu/residual DAGs, swept over budgets, pin the partitioner
contract:

* (a) the balanced DP's slowest group is never slower than the greedy
  prefix cut's — the min-max primary objective, provable because every
  greedy cut is inside the DP's candidate space;
* (b) every scheduled group fits the target budget, either with
  resident weights or carrying a streamed-weight (tile) plan;
* (c) the DP result is invariant under node/value relabeling — the cut
  is a function of graph structure, not of names;
* plus the ISSUE 3 cost-model invariants: groups cover the topo order
  contiguously, spill-outs match spill-ins, the total-cycle identity
  holds, and the overlapped boundary DMA never exceeds the PR 2 serial
  round-trip charge.

Each property runs twice: a deterministic seed sweep (always on — the
tier-1 gate) and a hypothesis-driven version when the optional dep is
installed (see tests/_hypothesis_fallback.py).
"""
import random

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_fallback import given, settings, st

from repro.core.ir import (
    DFG,
    PayloadKind,
    Value,
    make_conv2d_op,
    make_elementwise_op,
)
from repro.passes import PartitionError, partition_layer_groups
from repro.core.resource_model import transition_cycles

INT8 = 8
N_SEEDS = 10  # deterministic tier-1 sweep


# ---------------------------------------------------------------------------
# Random DAG builder
# ---------------------------------------------------------------------------


def random_dag(seed: int, rename=None) -> DFG:
    """A random conv/relu chain with occasional residual diamonds.

    ``rename`` maps every canonical node/value label to an alternate
    spelling — the relabeling property builds the *same structure* twice
    with different names (insertion order, and therefore the structural
    topological order, is identical by construction).
    """
    rename = rename or (lambda s: s)
    rng = random.Random(seed)
    n = rng.choice([4, 6, 8])
    c = rng.choice([2, 4, 8])
    layers = rng.randint(2, 5)
    shape = (1, n, n, c)

    dfg = DFG(rename(f"rand{seed}"))
    x = rename("x")
    dfg.add_value(Value(x, shape, INT8))
    dfg.graph_inputs.append(x)
    cur, skip = x, None
    for i in range(layers):
        k = rng.choice([1, 3, 3])
        w, o = rename(f"w{i}"), rename(f"conv{i}_out")
        dfg.add_value(Value(w, (k, k, c, c), INT8, is_constant=True))
        dfg.add_value(Value(o, shape, INT8))
        dfg.add_node(
            make_conv2d_op(
                rename(f"conv{i}"), cur, w, o,
                n=1, h_out=n, w_out=n, c_out=c, kh=k, kw=k, c_in=c,
            )
        )
        cur = o
        if rng.random() < 0.5:
            r = rename(f"relu{i}_out")
            dfg.add_value(Value(r, shape, INT8))
            dfg.add_node(
                make_elementwise_op(
                    rename(f"relu{i}"), [cur], r, shape, PayloadKind.RELU
                )
            )
            cur = r
        if skip is not None and rng.random() < 0.4:
            a = rename(f"add{i}_out")
            dfg.add_value(Value(a, shape, INT8))
            dfg.add_node(
                make_elementwise_op(
                    rename(f"add{i}"), [cur, skip], a, shape, PayloadKind.ADD
                )
            )
            cur, skip = a, None
        if skip is None and rng.random() < 0.4:
            skip = cur
    dfg.graph_outputs.append(cur)
    return dfg


def random_budgets(seed: int) -> tuple[int, int]:
    """(d_total, b_total) drawn independently of the DAG shape so the
    same seed reproduces them for the relabeled twin."""
    rng = random.Random(seed ^ 0x5EED)
    return rng.choice([64, 256, 1248]), rng.choice([2, 3, 4, 8, 288])


def _partition(dfg: DFG, seed: int, strategy: str = "balanced"):
    d_total, b_total = random_budgets(seed)
    return partition_layer_groups(
        dfg, d_total=d_total, b_total=b_total, strategy=strategy
    )


# ---------------------------------------------------------------------------
# The properties (shared by the seed sweep and the hypothesis drivers)
# ---------------------------------------------------------------------------


def check_balanced_not_worse_than_greedy(seed: int) -> None:
    dfg = random_dag(seed)
    try:
        bal = _partition(dfg, seed)
        greedy = _partition(dfg, seed, strategy="greedy")
    except PartitionError:
        return  # un-schedulable under this budget draw — vacuous
    assert bal.max_group_cycles <= greedy.max_group_cycles


def check_groups_fit_or_stream(seed: int) -> None:
    dfg = random_dag(seed)
    d_total, b_total = random_budgets(seed)
    try:
        pp = _partition(dfg, seed)
    except PartitionError:
        return
    for g in pp.groups:
        assert g.dse.feasible, g.name
        assert g.bram <= b_total, g.name
        assert g.dsp <= d_total, g.name
        # resident fit, or an explicit streamed-weight plan — never a
        # silently over-budget group
        assert not g.dse.weight_tiles or all(
            t > 1 for t in g.dse.weight_tiles.values()
        )


def check_relabel_invariance(seed: int) -> None:
    plain = random_dag(seed)
    exotic = random_dag(seed, rename=lambda s: f"zz_{s[::-1]}")
    try:
        a = _partition(plain, seed)
        b = _partition(exotic, seed)
    except PartitionError:
        try:
            _partition(plain, seed)
            raise AssertionError("only one naming raised PartitionError")
        except PartitionError:
            return
    assert [len(g.node_names) for g in a.groups] == [
        len(g.node_names) for g in b.groups
    ]
    assert [g.cycles for g in a.groups] == [g.cycles for g in b.groups]
    assert a.max_group_cycles == b.max_group_cycles
    assert a.total_cycles == b.total_cycles
    assert a.spill_bits == b.spill_bits
    assert sorted(a.weight_streamed.values()) == sorted(
        b.weight_streamed.values()
    )


def check_schedule_invariants(seed: int) -> None:
    dfg = random_dag(seed)
    try:
        pp = _partition(dfg, seed)
    except PartitionError:
        return
    # groups cover the topological order contiguously
    covered = [n for g in pp.groups for n in g.node_names]
    assert covered == [n.name for n in dfg.topo_order()]
    # every spill-out is some later group's spill-in and vice versa
    outs = {v for g in pp.groups for v in g.spill_out}
    ins = {v for g in pp.groups for v in g.spill_in}
    assert outs == ins
    # cost-model identities (ISSUE 3 overlap model)
    assert pp.total_cycles == sum(g.cycles for g in pp.groups) + pp.spill_cycles
    assert pp.spill_cycles <= pp.serial_spill_cycles
    for w, r in pp.boundary_traffic():
        assert transition_cycles(w, r) <= (
            transition_cycles(w, 0) + transition_cycles(0, r)
        )


# ---------------------------------------------------------------------------
# Deterministic tier-1 sweep
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(N_SEEDS))
def test_balanced_not_worse_than_greedy(seed):
    check_balanced_not_worse_than_greedy(seed)


@pytest.mark.parametrize("seed", range(N_SEEDS))
def test_groups_fit_or_stream(seed):
    check_groups_fit_or_stream(seed)


@pytest.mark.parametrize("seed", range(N_SEEDS))
def test_relabel_invariance(seed):
    check_relabel_invariance(seed)


@pytest.mark.parametrize("seed", range(N_SEEDS))
def test_schedule_invariants(seed):
    check_schedule_invariants(seed)


# ---------------------------------------------------------------------------
# Hypothesis drivers (skipped when hypothesis is not installed)
# ---------------------------------------------------------------------------

_SEEDS = st.integers(min_value=0, max_value=10_000)


@given(_SEEDS)
@settings(max_examples=25, deadline=None)
def test_hyp_balanced_not_worse_than_greedy(seed):
    check_balanced_not_worse_than_greedy(seed)


@given(_SEEDS)
@settings(max_examples=25, deadline=None)
def test_hyp_groups_fit_or_stream(seed):
    check_groups_fit_or_stream(seed)


@given(_SEEDS)
@settings(max_examples=15, deadline=None)
def test_hyp_relabel_invariance(seed):
    check_relabel_invariance(seed)


@given(_SEEDS)
@settings(max_examples=25, deadline=None)
def test_hyp_schedule_invariants(seed):
    check_schedule_invariants(seed)
