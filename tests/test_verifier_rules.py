"""Per-rule verifier coverage (ISSUE 9 satellite): one minimal broken
DFG per structural invariant V1–V10, each asserting the rule id lands
in the message, plus the collect-all reporting mode."""
import pytest

from repro.api import Graph
from repro.core import cnn_graphs
from repro.core.ir import FusedEpilogue, PayloadKind, Value, make_elementwise_op
from repro.passes import VerificationError, verify_dfg

NHWC2NCHW = (0, 3, 1, 2)


def _conv_relu():
    return cnn_graphs.conv_relu(8)


class TestRuleTriggers:
    def test_v1_unregistered_value(self):
        dfg = _conv_relu()
        dfg.nodes[0].inputs = ("ghost", dfg.nodes[0].inputs[1])
        with pytest.raises(VerificationError, match=r"\[V1\].*ghost"):
            verify_dfg(dfg)

    def test_v1_duplicate_node_name(self):
        dfg = _conv_relu()
        dfg.nodes[1].name = dfg.nodes[0].name
        with pytest.raises(VerificationError, match=r"\[V1\].*duplicate"):
            verify_dfg(dfg)

    def test_v2_duplicate_producer(self):
        dfg = _conv_relu()
        dfg.nodes.append(make_elementwise_op(
            "dup", ["conv0_out"], "relu0_out", (1, 8, 8, 16), PayloadKind.RELU
        ))
        with pytest.raises(VerificationError, match=r"\[V2\]"):
            verify_dfg(dfg)

    def test_v3_output_without_producer(self):
        dfg = _conv_relu()
        dfg.add_value(Value("phantom", (1, 8, 8, 16)))
        dfg.graph_outputs = ["phantom"]
        with pytest.raises(VerificationError, match=r"\[V3\].*phantom"):
            verify_dfg(dfg)

    def test_v3_input_with_producer(self):
        dfg = _conv_relu()
        dfg.graph_inputs = list(dfg.graph_inputs) + ["conv0_out"]
        with pytest.raises(VerificationError, match=r"\[V3\].*conv0_out"):
            verify_dfg(dfg)

    def test_v4_cycle(self):
        dfg = _conv_relu()
        dfg.nodes[0].inputs = ("relu0_out", dfg.nodes[0].inputs[1])
        dfg.graph_inputs = []
        with pytest.raises(VerificationError, match=r"\[V4\]"):
            verify_dfg(dfg)

    def test_v5_arity_mismatch(self):
        dfg = _conv_relu()
        dfg.nodes[1].dim_sizes = dfg.nodes[1].dim_sizes + (2,)
        with pytest.raises(VerificationError, match=r"\[V5\]"):
            verify_dfg(dfg)

    def test_v6_stream_epilogue_operand(self):
        dfg = _conv_relu()
        dfg.nodes[0].epilogue = (FusedEpilogue(PayloadKind.ADD, "relu0_out"),)
        with pytest.raises(VerificationError, match=r"\[V6\]"):
            verify_dfg(dfg)

    def test_v7_unfed_input(self):
        # an unfed input also stalls Kahn's algorithm, so fail-fast
        # reports V4 first; collect-all surfaces the precise V7 line too
        dfg = _conv_relu()
        dfg.add_value(Value("orphan", (1, 8, 8, 16)))
        dfg.nodes[1].inputs = ("orphan",)
        with pytest.raises(VerificationError, match=r"\[V4\]"):
            verify_dfg(dfg)
        with pytest.raises(
            VerificationError, match=r"(?s)\[V4\].*\[V7\].*orphan"
        ):
            verify_dfg(dfg, collect_all=True)

    def test_v8_shape_mismatch(self):
        dfg = _conv_relu()
        dfg.values["relu0_out"].shape = (1, 9, 9, 16)
        with pytest.raises(VerificationError, match=r"\[V8\]"):
            verify_dfg(dfg)

    def test_v9_window_does_not_tile(self):
        dfg = _conv_relu()
        # V8 must pass first: give the output the floor-div shape so the
        # only problem left is the 3x3 window not tiling the 8x8 extent
        dfg.nodes[0].epilogue = (
            FusedEpilogue(PayloadKind.MAX, window=(1, 3, 3, 1)),
        )
        dfg.values["conv0_out"].shape = (1, 2, 2, 16)
        dfg.values["relu0_out"].shape = (1, 2, 2, 16)
        dfg.nodes[1].inputs = ("conv0_out",)
        dfg.nodes[1].indexing_maps = dfg.nodes[1].indexing_maps[-2:]
        dfg.nodes[1].dim_sizes = (1, 2, 2, 16)
        with pytest.raises(VerificationError, match=r"\[V9\].*tile"):
            verify_dfg(dfg)

    def test_v10_epilogue_on_reorder(self):
        g = Graph("t")
        x = g.input((1, 4, 4, 2))
        g.output(g.transpose(x, NHWC2NCHW))
        dfg = g.build()
        dfg.nodes[0].epilogue = (FusedEpilogue(PayloadKind.RELU),)
        with pytest.raises(VerificationError, match=r"\[V10\]"):
            verify_dfg(dfg)


class TestCollectAll:
    def _multi_broken(self):
        """V2 (duplicate producer) + V6 (stream epilogue operand) + V8
        (shape mismatch) in one graph."""
        dfg = _conv_relu()
        dfg.nodes.append(make_elementwise_op(
            "dup", ["conv0_out"], "relu0_out", (1, 8, 8, 16), PayloadKind.RELU
        ))
        dfg.nodes[0].epilogue = (FusedEpilogue(PayloadKind.ADD, "relu0_out"),)
        dfg.values["relu0_out"].shape = (1, 9, 9, 16)
        return dfg

    def test_fail_fast_reports_first_only(self):
        with pytest.raises(VerificationError, match=r"\[V2\]") as ei:
            verify_dfg(self._multi_broken())
        assert len(ei.value.violations) == 1
        assert ei.value.violations[0].startswith("[V2]")

    def test_collect_all_gathers_every_rule(self):
        with pytest.raises(VerificationError) as ei:
            verify_dfg(self._multi_broken(), collect_all=True)
        rules = {v.split("]")[0] + "]" for v in ei.value.violations}
        assert {"[V2]", "[V6]", "[V8]"} <= rules
        # the message carries one line per violation
        msg = str(ei.value)
        assert "[V2]" in msg and "[V6]" in msg and "[V8]" in msg
        assert "structural violation(s)" in msg

    def test_collect_all_clean_graph_is_silent(self):
        verify_dfg(_conv_relu(), collect_all=True)

    def test_collect_all_survives_cascading_damage(self):
        # an unregistered value (V1) makes later value lookups crash;
        # collect mode must still raise the V1 report, not a KeyError
        dfg = _conv_relu()
        dfg.nodes[0].inputs = ("ghost", dfg.nodes[0].inputs[1])
        del dfg.values["conv0_out"]
        with pytest.raises(VerificationError, match=r"\[V1\]"):
            verify_dfg(dfg, collect_all=True)
