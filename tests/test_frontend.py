"""Layer-builder frontend (ISSUE 4 tentpole): shape inference,
validating errors, and — the load-bearing contract — *node-for-node
equality* with the historical hand-built graphs.

The legacy constructors below are verbatim copies of the pre-ISSUE-4
``cnn_graphs`` bodies (hand-assembled ``Value`` + ``make_*_op``).  The
shipped constructors are now thin wrappers over
``repro.api.builder.Sequential``; every suite graph must compare equal
(values, nodes, maps, iterator types, boundary lists — dataclass
equality covers all of it) so nothing downstream (goldens, BENCH rows,
partition cuts) can move.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_fallback import given, settings, st

from repro.api.builder import (
    AvgPool,
    Conv2D,
    Dense,
    FrontendError,
    Graph,
    MaxPool,
    ReLU,
    Residual,
    Sequential,
)
from repro.core import cnn_graphs
from repro.core.ir import (
    DFG,
    PayloadKind,
    Value,
    make_conv2d_op,
    make_elementwise_op,
    make_matmul_op,
    make_pool2d_op,
)

INT8 = 8


# ---------------------------------------------------------------------------
# The legacy hand-built constructors (pre-ISSUE-4 cnn_graphs, verbatim)
# ---------------------------------------------------------------------------


def _conv(dfg, idx, in_name, n, h, w, c_in, c_out, k=3):
    wname = f"w{idx}"
    oname = f"conv{idx}_out"
    dfg.add_value(Value(wname, (k, k, c_in, c_out), INT8, is_constant=True))
    dfg.add_value(Value(oname, (n, h, w, c_out), INT8))
    dfg.add_node(
        make_conv2d_op(
            f"conv{idx}", in_name, wname, oname,
            n=n, h_out=h, w_out=w, c_out=c_out, kh=k, kw=k, c_in=c_in,
        )
    )
    return oname


def _relu(dfg, idx, in_name, shape):
    oname = f"relu{idx}_out"
    dfg.add_value(Value(oname, shape, INT8))
    dfg.add_node(
        make_elementwise_op(f"relu{idx}", [in_name], oname, shape,
                            PayloadKind.RELU)
    )
    return oname


def legacy_conv_relu(n_size=32, c_in=3, c_out=16):
    dfg = DFG(f"conv_relu_{n_size}")
    shape = (1, n_size, n_size, c_in)
    dfg.add_value(Value("x", shape, INT8))
    dfg.graph_inputs.append("x")
    c1 = _conv(dfg, 0, "x", 1, n_size, n_size, c_in, c_out)
    r1 = _relu(dfg, 0, c1, (1, n_size, n_size, c_out))
    dfg.graph_outputs.append(r1)
    return dfg


def legacy_cascade_conv(n_size=32, c_in=3, c_mid=16):
    dfg = DFG(f"cascade_conv_{n_size}")
    dfg.add_value(Value("x", (1, n_size, n_size, c_in), INT8))
    dfg.graph_inputs.append("x")
    c1 = _conv(dfg, 0, "x", 1, n_size, n_size, c_in, c_mid)
    r1 = _relu(dfg, 0, c1, (1, n_size, n_size, c_mid))
    c2 = _conv(dfg, 1, r1, 1, n_size, n_size, c_mid, c_mid)
    r2 = _relu(dfg, 1, c2, (1, n_size, n_size, c_mid))
    dfg.graph_outputs.append(r2)
    return dfg


def legacy_residual_block(n_size=32, c=16):
    dfg = DFG(f"residual_block_{n_size}")
    shape = (1, n_size, n_size, c)
    dfg.add_value(Value("x", shape, INT8))
    dfg.graph_inputs.append("x")
    c1 = _conv(dfg, 0, "x", 1, n_size, n_size, c, c)
    r1 = _relu(dfg, 0, c1, shape)
    c2 = _conv(dfg, 1, r1, 1, n_size, n_size, c, c)
    dfg.add_value(Value("add_out", shape, INT8))
    dfg.add_node(
        make_elementwise_op("add_skip", [c2, "x"], "add_out", shape,
                            PayloadKind.ADD)
    )
    r2 = _relu(dfg, 1, "add_out", shape)
    dfg.graph_outputs.append(r2)
    return dfg


def legacy_linear(batch=512, d_in=128, d_out=256):
    dfg = DFG("linear")
    dfg.add_value(Value("x", (batch, d_in), INT8))
    dfg.add_value(Value("w0", (d_in, d_out), INT8, is_constant=True))
    dfg.add_value(Value("y", (batch, d_out), INT8))
    dfg.graph_inputs.append("x")
    dfg.add_node(
        make_matmul_op("linear0", "x", "w0", "y", m=batch, k=d_in,
                       n_out=d_out)
    )
    dfg.graph_outputs.append("y")
    return dfg


def legacy_feed_forward(batch=512, d_in=128, d_hidden=256):
    dfg = DFG("feed_forward")
    dfg.add_value(Value("x", (batch, d_in), INT8))
    dfg.add_value(Value("w0", (d_in, d_hidden), INT8, is_constant=True))
    dfg.add_value(Value("h", (batch, d_hidden), INT8))
    dfg.graph_inputs.append("x")
    dfg.add_node(
        make_matmul_op("linear0", "x", "w0", "h", m=batch, k=d_in,
                       n_out=d_hidden)
    )
    hr = _relu(dfg, 0, "h", (batch, d_hidden))
    dfg.add_value(Value("w1", (d_hidden, d_in), INT8, is_constant=True))
    dfg.add_value(Value("y", (batch, d_in), INT8))
    dfg.add_node(
        make_matmul_op("linear1", hr, "w1", "y", m=batch, k=d_hidden,
                       n_out=d_in)
    )
    dfg.graph_outputs.append("y")
    return dfg


def legacy_deep_cascade(n_size=32, c_in=3, c_mid=136, n_layers=4):
    dfg = DFG(f"deep_cascade_{n_size}")
    dfg.add_value(Value("x", (1, n_size, n_size, c_in), INT8))
    dfg.graph_inputs.append("x")
    cur, c_prev = "x", c_in
    for i in range(n_layers):
        cur = _conv(dfg, i, cur, 1, n_size, n_size, c_prev, c_mid)
        cur = _relu(dfg, i, cur, (1, n_size, n_size, c_mid))
        c_prev = c_mid
    dfg.graph_outputs.append(cur)
    return dfg


def legacy_conv_pool(n_size=32, c_in=3, c_out=16):
    assert n_size % 2 == 0
    dfg = DFG(f"conv_pool_{n_size}")
    dfg.add_value(Value("x", (1, n_size, n_size, c_in), INT8))
    dfg.graph_inputs.append("x")
    c1 = _conv(dfg, 0, "x", 1, n_size, n_size, c_in, c_out)
    r1 = _relu(dfg, 0, c1, (1, n_size, n_size, c_out))
    h = n_size // 2
    dfg.add_value(Value("pool0_out", (1, h, h, c_out), INT8))
    dfg.add_node(
        make_pool2d_op(
            "pool0", r1, "pool0_out",
            n=1, h_out=h, w_out=h, c=c_out, kh=2, kw=2, stride=2,
        )
    )
    dfg.graph_outputs.append("pool0_out")
    return dfg


def legacy_fat_conv(n_size=16, c=288):
    dfg = DFG(f"fat_conv_{n_size}")
    dfg.add_value(Value("x", (1, n_size, n_size, c), INT8))
    dfg.graph_inputs.append("x")
    c1 = _conv(dfg, 0, "x", 1, n_size, n_size, c, c)
    r1 = _relu(dfg, 0, c1, (1, n_size, n_size, c))
    dfg.graph_outputs.append(r1)
    return dfg


def legacy_fat_cascade(n_size=16, c=288, n_layers=2):
    dfg = DFG(f"fat_cascade_{n_size}")
    dfg.add_value(Value("x", (1, n_size, n_size, c), INT8))
    dfg.graph_inputs.append("x")
    cur = "x"
    for i in range(n_layers):
        cur = _conv(dfg, i, cur, 1, n_size, n_size, c, c)
        cur = _relu(dfg, i, cur, (1, n_size, n_size, c))
    dfg.graph_outputs.append(cur)
    return dfg


LEGACY = {
    "conv_relu_32": legacy_conv_relu,
    "conv_relu_224": lambda: legacy_conv_relu(224),
    "cascade_conv_32": legacy_cascade_conv,
    "cascade_conv_224": lambda: legacy_cascade_conv(224),
    "residual_block_32": legacy_residual_block,
    "residual_block_224": lambda: legacy_residual_block(224),
    "linear": legacy_linear,
    "feed_forward": legacy_feed_forward,
    "deep_cascade_32": legacy_deep_cascade,
    "deep_cascade_224": lambda: legacy_deep_cascade(224),
    "conv_pool_32": legacy_conv_pool,
    "fat_conv_16": legacy_fat_conv,
    "fat_cascade_16": legacy_fat_cascade,
}

BUILT = {
    "conv_relu_32": cnn_graphs.conv_relu,
    "conv_relu_224": lambda: cnn_graphs.conv_relu(224),
    "cascade_conv_32": cnn_graphs.cascade_conv,
    "cascade_conv_224": lambda: cnn_graphs.cascade_conv(224),
    "residual_block_32": cnn_graphs.residual_block,
    "residual_block_224": lambda: cnn_graphs.residual_block(224),
    "linear": cnn_graphs.linear,
    "feed_forward": cnn_graphs.feed_forward,
    "deep_cascade_32": cnn_graphs.deep_cascade,
    "deep_cascade_224": lambda: cnn_graphs.deep_cascade(224),
    "conv_pool_32": cnn_graphs.conv_pool,
    "fat_conv_16": cnn_graphs.fat_conv,
    "fat_cascade_16": cnn_graphs.fat_cascade,
}


def assert_dfg_equal(a: DFG, b: DFG) -> None:
    """Node-for-node, value-for-value equality with readable diffs."""
    assert a.name == b.name
    assert a.graph_inputs == b.graph_inputs
    assert a.graph_outputs == b.graph_outputs
    assert sorted(a.values) == sorted(b.values)
    for k in a.values:
        assert a.values[k] == b.values[k], k
    assert len(a.nodes) == len(b.nodes)
    for na, nb in zip(a.nodes, b.nodes):
        assert na == nb, na.name
    assert a == b  # and the whole-dataclass check agrees


class TestSuiteEquality:
    """Every suite graph: builder wrapper == legacy hand-built."""

    @pytest.mark.parametrize("name", sorted(LEGACY))
    def test_builder_equals_legacy(self, name):
        assert_dfg_equal(BUILT[name](), LEGACY[name]())

    def test_paper_suite_is_builder_built(self):
        for name, make in cnn_graphs.PAPER_SUITE.items():
            g = make()
            assert g.name.startswith(name.rsplit("_", 1)[0]) or g.name == name
            g.topo_order()  # well-formed


class TestPropertyEquality:
    """Random conv/relu cascades built both ways stay identical."""

    @given(st.integers(4, 32), st.integers(1, 8), st.integers(1, 4))
    @settings(max_examples=25, deadline=None)
    def test_random_cascades_equal(self, n, c, layers):
        built = cnn_graphs.deep_cascade(n, c_in=3, c_mid=c, n_layers=layers)
        legacy = legacy_deep_cascade(n, c_in=3, c_mid=c, n_layers=layers)
        assert_dfg_equal(built, legacy)

    @given(st.integers(2, 16), st.integers(1, 8))
    @settings(max_examples=15, deadline=None)
    def test_random_even_conv_pools_equal(self, half, c_out):
        n = 2 * half
        assert_dfg_equal(
            cnn_graphs.conv_pool(n, c_out=c_out),
            legacy_conv_pool(n, c_out=c_out),
        )


class TestShapeInference:
    def test_conv_infers_same_padding_shape(self):
        g = Graph("t")
        x = g.input((1, 9, 9, 3))
        y = g.conv2d(x, 5, kernel=3, stride=2)
        assert y.shape == (1, 5, 5, 5)

    def test_pool_infers_valid_shape(self):
        g = Graph("t")
        x = g.input((1, 10, 10, 2))
        y = g.max_pool(x, window=2)
        assert y.shape == (1, 5, 5, 2)

    def test_dense_infers_units(self):
        g = Graph("t")
        x = g.input((4, 8))
        y = g.dense(x, 16)
        assert y.shape == (4, 16)

    def test_wrong_rank_input_to_conv(self):
        g = Graph("t")
        x = g.input((4, 8))
        with pytest.raises(FrontendError, match="rank-4 NHWC"):
            g.conv2d(x, 16)

    def test_wrong_rank_input_to_dense(self):
        g = Graph("t")
        x = g.input((1, 8, 8, 3))
        with pytest.raises(FrontendError, match="rank-2"):
            g.dense(x, 16)

    def test_channel_mismatch_in_residual(self):
        net = Sequential(
            [Residual([Conv2D(8)])],  # body changes 4 -> 8 channels
            input_shape=(1, 8, 8, 4), name="bad",
        )
        with pytest.raises(FrontendError, match="shapes differ"):
            net.build()

    def test_illegal_pool_window(self):
        g = Graph("t")
        x = g.input((1, 9, 9, 2))
        with pytest.raises(FrontendError, match="illegal pool window"):
            g.max_pool(x, window=2)  # (9-2) % 2 != 0

    def test_pool_window_larger_than_input(self):
        g = Graph("t")
        x = g.input((1, 4, 4, 2))
        with pytest.raises(FrontendError, match="exceeds the spatial"):
            g.avg_pool(x, window=8)

    def test_empty_residual_body(self):
        net = Sequential([Residual([])], input_shape=(1, 4, 4, 2),
                         name="bad")
        with pytest.raises(FrontendError, match="at least one body layer"):
            net.build()

    def test_weight_streaming_policy_is_a_string_not_a_bool(self):
        from repro.passes import partition_layer_groups

        with pytest.raises(ValueError, match="weight_streaming"):
            partition_layer_groups(cnn_graphs.conv_relu(8, c_out=4),
                                   weight_streaming=False)

    def test_unknown_layer_object(self):
        with pytest.raises(FrontendError, match="not a layer"):
            Sequential(["relu"], input_shape=(1, 4, 4, 1), name="bad").build()

    def test_graph_without_outputs(self):
        g = Graph("t")
        g.input((1, 4, 4, 1))
        with pytest.raises(FrontendError, match="no outputs"):
            g.build()

    def test_foreign_tensor_ref_rejected(self):
        g1, g2 = Graph("a"), Graph("b")
        x = g1.input((1, 4, 4, 1))
        g2.input((1, 4, 4, 1), name="other")
        with pytest.raises(FrontendError, match="not a value of graph"):
            g2.relu(x)


class TestAvgPool:
    """ISSUE 4 satellite: AvgPool through builder, fusion, both
    executors, the emitter, and the resource model."""

    def test_builder_emits_avg_payload(self):
        dfg = cnn_graphs.conv_avgpool(8, c_out=4)
        pool = dfg.node("pool0")
        assert pool.payload == PayloadKind.AVG

    def test_fusion_folds_avg_pool_as_windowed_epilogue(self):
        from repro.passes import run_default_pipeline

        res = run_default_pipeline(cnn_graphs.conv_avgpool(8, c_out=4))
        (node,) = res.dfg.nodes
        kinds = [e.kind for e in node.epilogue]
        assert PayloadKind.AVG in kinds
        assert any(e.window for e in node.epilogue if e.kind == PayloadKind.AVG)

    def test_fused_equals_unfused_interp(self):
        import numpy as np

        from repro.passes import interp, run_default_pipeline

        dfg = cnn_graphs.conv_avgpool(8, c_out=4)
        env = interp.random_env(dfg, seed=1)
        want = interp.graph_outputs(dfg, env)["pool0_out"]
        fused = run_default_pipeline(dfg).dfg
        got = interp.graph_outputs(fused, env)["pool0_out"]
        np.testing.assert_array_equal(np.asarray(want), np.asarray(got))

    def test_run_compiled_matches_interp(self):
        import numpy as np

        from repro.core.compile_driver import compile_design
        from repro.kernels import ops
        from repro.passes import interp

        d = compile_design(cnn_graphs.conv_avgpool(8, c_out=4))
        env = interp.random_env(d.source, seed=2)
        want = interp.graph_outputs(d.source, env)
        got = ops.run_compiled(d, env, interpret=True)
        for k in want:
            np.testing.assert_array_equal(np.asarray(want[k]),
                                          np.asarray(got[k]))

    def test_emitter_charges_div_exit_path(self):
        from repro.core.compile_driver import compile_design
        from repro.core.emit_hls import emit_design

        # fused: DIV rides the conv's windowed epilogue
        d = compile_design(cnn_graphs.conv_avgpool(8, c_out=4))
        cpp = emit_design(d)[f"{d.groups[0].name}.cpp"]
        assert "DIV exit path" in cpp
        # unfused: the standalone AVG node accumulates then divides
        d2 = compile_design(cnn_graphs.conv_avgpool(8, c_out=4),
                            run_passes=False)
        cpp2 = emit_design(d2)[f"{d2.groups[0].name}.cpp"]
        assert "avg-pool accumulate" in cpp2
        assert "DIV exit path" in cpp2

    def test_resource_model_charges_divider(self):
        """The fused avg pool costs (at least) one more DSP than the max
        pool — the constant-reciprocal divider on the exit datapath."""
        from repro.core.compile_driver import compile_design

        avg = compile_design(cnn_graphs.conv_avgpool(8, c_out=4))
        mx = compile_design(cnn_graphs.conv_pool(8, c_out=4))
        assert avg.max_dsp > mx.max_dsp

    def test_pool_reduce_avg_is_floor_division_once(self):
        import jax.numpy as jnp
        import numpy as np

        from repro.kernels import ref

        x = jnp.arange(16, dtype=jnp.int32).reshape(1, 4, 4, 1)
        out = ref.pool_reduce("avg", x, (1, 2, 2, 1))
        want = np.array([[[[2], [4]], [[10], [12]]]])  # floor(sum/4)
        np.testing.assert_array_equal(np.asarray(out), want)
        # float path divides exactly
        xf = x.astype(jnp.float32)
        outf = ref.pool_reduce("avg", xf, (1, 2, 2, 1))
        np.testing.assert_allclose(np.asarray(outf),
                                   want.astype(np.float32) + 0.5)
