"""IR layer: affine algebra, GenericOp validation, DFG topology."""
import pytest

try:
    from hypothesis import given, strategies as st
except ImportError:  # optional dep: property tests skip, unit tests run
    from _hypothesis_fallback import given, st

from repro.core.ir import (
    DFG,
    AffineExpr,
    AffineMap,
    GenericOp,
    IteratorType,
    PayloadKind,
    Value,
    make_conv2d_op,
    make_elementwise_op,
    make_matmul_op,
)


class TestAffineExpr:
    def test_single_dim(self):
        e = AffineExpr.dim(2)
        assert e.is_single_dim()
        assert e.dims() == (2,)
        assert e.coeff(2) == 1 and e.coeff(0) == 0

    def test_combination_not_single(self):
        e = AffineExpr.dim(0, 2) + AffineExpr.dim(1, 3)
        assert not e.is_single_dim()
        assert e.coeff(0) == 2 and e.coeff(1) == 3

    def test_scaled_dim_not_single(self):
        assert not AffineExpr.dim(0, 2).is_single_dim()

    def test_offset_not_single(self):
        assert not (AffineExpr.dim(0) + AffineExpr.constant(1)).is_single_dim()

    def test_add_cancels(self):
        e = AffineExpr.dim(0) + AffineExpr.dim(0, -1)
        assert e.terms == () and e.const == 0

    def test_mul(self):
        e = AffineExpr.dim(1) * 3
        assert e.coeff(1) == 3
        assert (e * 0).terms == ()

    @given(st.integers(-5, 5), st.integers(-5, 5), st.integers(0, 3),
           st.integers(0, 3))
    def test_evaluate_linear(self, c0, c1, d0, d1):
        e = AffineExpr.dim(0, c0) + AffineExpr.dim(1, c1) + AffineExpr.constant(7)
        point = [d0, d1]
        assert e.evaluate(point) == c0 * d0 + c1 * d1 + 7


class TestAffineMap:
    def test_identity(self):
        m = AffineMap.identity(3)
        assert m.is_identity()
        assert all(e.is_single_dim() for e in m.results)

    def test_non_identity(self):
        m = AffineMap.of(2, [AffineExpr.dim(1), AffineExpr.dim(0)])
        assert not m.is_identity()


class TestGenericOp:
    def test_conv_builder_shape(self):
        op = make_conv2d_op(
            "c", "x", "w", "y", n=1, h_out=8, w_out=8, c_out=4, kh=3, kw=3,
            c_in=2,
        )
        assert op.n_dims == 7
        assert op.parallel_dims == (0, 1, 2, 3)
        assert op.reduction_dims == (4, 5, 6)
        assert op.total_trip_count == 8 * 8 * 4 * 3 * 3 * 2

    def test_map_arity_validated(self):
        with pytest.raises(ValueError):
            GenericOp(
                name="bad", inputs=("a",), output="b",
                indexing_maps=(AffineMap.identity(2),),  # needs 2
                iterator_types=(IteratorType.PARALLEL,) * 2,
                dim_sizes=(2, 2),
            )

    def test_dim_size_mismatch(self):
        with pytest.raises(ValueError):
            GenericOp(
                name="bad", inputs=(), output="b",
                indexing_maps=(AffineMap.identity(2),),
                iterator_types=(IteratorType.PARALLEL,) * 2,
                dim_sizes=(2,),
            )

    def test_macs(self):
        op = make_matmul_op("m", "a", "b", "c", m=4, k=8, n_out=2)
        assert op.macs() == 4 * 8 * 2


class TestDFG:
    def _simple(self) -> DFG:
        dfg = DFG("g")
        dfg.add_value(Value("x", (4, 4)))
        dfg.add_value(Value("w", (4, 4), is_constant=True))
        dfg.add_value(Value("y", (4, 4)))
        dfg.add_value(Value("z", (4, 4)))
        dfg.graph_inputs.append("x")
        dfg.add_node(make_matmul_op("mm", "x", "w", "y", m=4, k=4, n_out=4))
        dfg.add_node(
            make_elementwise_op("relu", ["y"], "z", (4, 4), PayloadKind.RELU)
        )
        dfg.graph_outputs.append("z")
        return dfg

    def test_topo_order(self):
        dfg = self._simple()
        order = [n.name for n in dfg.topo_order()]
        assert order == ["mm", "relu"]

    def test_producer_consumer(self):
        dfg = self._simple()
        assert dfg.producer_of("y").name == "mm"
        assert [n.name for n in dfg.consumers_of("y")] == ["relu"]

    def test_intermediates(self):
        dfg = self._simple()
        assert [v.name for v in dfg.intermediate_values()] == ["y"]

    def test_duplicate_value_rejected(self):
        dfg = self._simple()
        with pytest.raises(ValueError):
            dfg.add_value(Value("x", (1,)))

    def test_unknown_value_rejected(self):
        dfg = self._simple()
        with pytest.raises(ValueError):
            dfg.add_node(make_matmul_op("m2", "nope", "w", "y", m=4, k=4, n_out=4))

    def test_cycle_detected(self):
        dfg = DFG("cyc")
        dfg.add_value(Value("a", (2,)))
        dfg.add_value(Value("b", (2,)))
        dfg.add_node(
            make_elementwise_op("n1", ["a"], "b", (2,), PayloadKind.IDENTITY)
        )
        dfg.add_node(
            make_elementwise_op("n2", ["b"], "a", (2,), PayloadKind.IDENTITY)
        )
        with pytest.raises(ValueError, match="cycle"):
            dfg.topo_order()
