"""Distribution layer: sharding rules, multi-device parity (subprocess)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.registry import get_config
from repro.distributed import sharding as shd
from repro.launch import steps as ST
from repro.launch.mesh import make_host_mesh, single_device_mesh


class TestParamRules:
    def test_llama_specs(self):
        mesh = single_device_mesh()
        cfg = get_config("llama3.2-1b", smoke=True)
        shape = jax.eval_shape(lambda: ST.model_init(jax.random.key(0), cfg))
        sh = shd.make_param_shardings(mesh, shape)
        flat = {
            jax.tree_util.keystr(k): v.spec
            for k, v in jax.tree_util.tree_flatten_with_path(sh)[0]
        }
        # stacked block leaves replicate the layer axis and shard TP/FSDP
        wq = [v for k, v in flat.items() if "wq" in k][0]
        assert wq[0] is None            # layer-stack axis never sharded
        assert "model" in wq            # TP somewhere
        embed = [v for k, v in flat.items() if "embed" in k][0]
        assert "model" in embed

    def test_divisibility_fallback(self):
        """mamba2's vocab (50280) does not divide model=16 → replicated."""
        mesh = make_host_mesh((1, 1), ("data", "model"))  # trivially divides
        # emulate a 16-way model axis by asking the spec logic directly
        import numpy as np
        from jax.sharding import Mesh

        devs = np.array(jax.devices() * 1)
        cfg = get_config("mamba2-1.3b")
        shape = jax.eval_shape(lambda: ST.model_init(jax.random.key(0), cfg))
        # fake mesh with 16 model "devices" is impossible with 1 real device;
        # check the predicate directly instead
        assert cfg.vocab_size % 16 != 0

    def test_batch_fallback_b1(self):
        mesh = make_host_mesh((1, 1), ("data", "model"))
        b = {"tokens": jax.ShapeDtypeStruct((1, 64), jnp.int32)}
        sh = shd.make_batch_shardings(mesh, b)
        assert sh["tokens"].spec == P(None, None) or sh["tokens"].spec == P("data", None)


class TestMultiDeviceParity:
    def test_train_step_matches_single_device(self, subproc):
        """One train step on a (2,2) mesh must equal the single-device
        result bit-for-bit-ish (fp32 tolerance) — proves the sharding
        rules don't change the math."""
        code = """
import jax, jax.numpy as jnp, numpy as np
from repro.configs.registry import get_config
from repro.distributed import sharding as shd
from repro.distributed.ctx import activation_sharding
from repro.launch import steps as ST
from repro.launch.mesh import make_host_mesh, single_device_mesh
from repro.optim import adamw
from repro.data.pipeline import DataConfig, batch_for_model
from repro.configs.base import ShapeConfig

cfg = get_config("llama3.2-1b", smoke=True).with_(dtype="float32")
opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
shape = ShapeConfig("t", 32, 4, "train")
batch = batch_for_model(cfg, shape, DataConfig(seed=0), 0)

def run(mesh):
    hook = shd.activation_hook(mesh)
    with mesh, activation_sharding(hook):
        params = ST.model_init(jax.random.key(0), cfg)
        p_sh = shd.make_param_shardings(mesh, jax.eval_shape(lambda: params))
        params = jax.device_put(params, p_sh)
        opt = adamw.init(params, opt_cfg)
        step = jax.jit(ST.make_train_step(cfg, opt_cfg),
                       in_shardings=(p_sh, None, None))
        new_p, _, m = step(params, opt, batch)
        return float(m["loss"]), np.asarray(jax.tree.leaves(new_p)[0],
                                            np.float32)

l1, p1 = run(make_host_mesh((2, 2), ("data", "model")))
l2, p2 = run(single_device_mesh())
np.testing.assert_allclose(l1, l2, rtol=1e-5)
# params pass through Adam's rsqrt: fp32 reduction-order noise ~1e-4
np.testing.assert_allclose(p1, p2, atol=3e-4, rtol=1e-3)
print("OK", l1)
"""
        r = subproc(code, devices=4)
        assert r.returncode == 0, r.stderr[-2500:]
        assert "OK" in r.stdout

    def test_grad_accum_invariance(self, subproc):
        """grad_accum=2 must produce the same update as grad_accum=1."""
        code = """
import jax, numpy as np
from repro.configs.registry import get_config
from repro.launch import steps as ST
from repro.optim import adamw
from repro.data.pipeline import DataConfig, batch_for_model
from repro.configs.base import ShapeConfig

cfg = get_config("qwen2-0.5b", smoke=True).with_(dtype="float32")
opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
batch = batch_for_model(cfg, ShapeConfig("t", 32, 4, "train"),
                        DataConfig(seed=1), 0)
params = ST.model_init(jax.random.key(0), cfg)
opt = adamw.init(params, opt_cfg)
outs = {}
for ga in (1, 2):
    step = jax.jit(ST.make_train_step(cfg, opt_cfg, grad_accum=ga))
    new_p, _, m = step(params, opt, batch)
    outs[ga] = (float(m["loss"]), np.asarray(jax.tree.leaves(new_p)[0],
                                             np.float32))
np.testing.assert_allclose(outs[1][0], outs[2][0], rtol=1e-5)
np.testing.assert_allclose(outs[1][1], outs[2][1], atol=2e-5, rtol=2e-5)
print("OK")
"""
        r = subproc(code, devices=1)
        assert r.returncode == 0, r.stderr[-2500:]
        assert "OK" in r.stdout

    def test_cache_sharding_adapts(self, subproc):
        """Hkv=2 cannot shard over model=4 → seq axis takes it."""
        code = """
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.distributed import sharding as shd
from repro.launch.mesh import make_host_mesh

mesh = make_host_mesh((2, 4), ("data", "model"))
cache = {"b0": {"k": jax.ShapeDtypeStruct((2, 4, 2, 64, 16), jnp.bfloat16),
                "v": jax.ShapeDtypeStruct((2, 4, 2, 64, 16), jnp.bfloat16)}}
sh = shd.make_cache_shardings(mesh, cache)
spec = sh["b0"]["k"].spec
assert spec == P(None, "data", None, "model", None), spec
# Hkv divisible: heads take it
cache2 = {"b0": {"k": jax.ShapeDtypeStruct((2, 4, 8, 64, 16), jnp.bfloat16)}}
spec2 = shd.make_cache_shardings(mesh, cache2)["b0"]["k"].spec
assert spec2 == P(None, "data", "model", None, None), spec2
print("OK")
"""
        r = subproc(code, devices=8)
        assert r.returncode == 0, r.stderr[-2000:]
        assert "OK" in r.stdout
