"""Fault tolerance: crash-restart continuity, straggler watchdog."""
import time

import numpy as np
import pytest

from repro.launch.train import train
from repro.runtime.resilience import (
    FailureInjector,
    SimulatedFailure,
    StragglerWatchdog,
    run_resilient,
)


class TestWatchdog:
    def test_flags_slow_steps(self):
        wd = StragglerWatchdog(window=16, threshold=2.0)
        for i in range(12):
            wd.start()
            time.sleep(0.002)
            wd.stop(i)
        wd.start()
        time.sleep(0.05)  # 25× median
        wd.stop(99)
        assert any(step == 99 for step, _ in wd.flagged)

    def test_no_false_positives_uniform(self):
        wd = StragglerWatchdog(window=16, threshold=3.0)
        for i in range(20):
            wd.start()
            time.sleep(0.002)
            wd.stop(i)
        assert wd.flagged == []


class TestFailureInjector:
    def test_fires_once(self):
        inj = FailureInjector(fail_at_steps=(3,))
        inj.check(2)
        with pytest.raises(SimulatedFailure):
            inj.check(3)
        inj.check(3)  # second pass after restart: no re-fire


class TestRunResilient:
    def test_restart_resumes_from_checkpoint(self):
        saved = {}
        log = []

        def make_state():
            return 0, {"x": 0}

        def restore_state():
            if not saved:
                return None
            step = max(saved)
            return step, dict(saved[step])

        inj = FailureInjector(fail_at_steps=(7,))

        def run_step(step, state):
            inj.check(step)
            log.append(step)
            return {"x": state["x"] + 1}, {}

        def save_state(step, state):
            saved[step] = dict(state)

        final_step, state = run_resilient(
            total_steps=10, make_state=make_state,
            restore_state=restore_state, run_step=run_step,
            save_state=save_state, checkpoint_every=5,
        )
        assert final_step == 10 and state["x"] == 10
        # steps 5..6 replayed after the crash at 7
        assert log == [0, 1, 2, 3, 4, 5, 6, 5, 6, 7, 8, 9]

    def test_gives_up_after_max_restarts(self):
        def run_step(step, state):
            raise SimulatedFailure("always")

        with pytest.raises(SimulatedFailure):
            run_resilient(
                total_steps=2, make_state=lambda: (0, {}),
                restore_state=lambda: None, run_step=run_step,
                save_state=lambda s, st: None, max_restarts=2,
            )


class TestEndToEndRestart:
    def test_bit_exact_loss_continuity(self, tmp_path):
        """A run crashed at step 12 and restarted must produce exactly the
        same losses as an uninterrupted run (determinism contract)."""
        common = dict(
            arch="qwen2-0.5b", smoke=True, steps=20, batch=2, seq=32,
            ckpt_every=5, lr=1e-3, log_every=0, seed=3,
        )
        clean = train(ckpt_dir=str(tmp_path / "clean"), **common)
        crashy = train(
            ckpt_dir=str(tmp_path / "crashy"), fail_at=(12,), **common
        )
        assert crashy["final_step"] == 20
        # the crashy run replays steps 10,11 — compare the last losses
        np.testing.assert_allclose(
            clean["losses"][-5:], crashy["losses"][-5:], rtol=1e-6
        )

    def test_resume_from_existing_dir(self, tmp_path):
        """Train 10 steps, stop; re-invoke for 20 → resumes at 10."""
        common = dict(arch="qwen2-0.5b", smoke=True, batch=2, seq=32,
                      ckpt_every=5, lr=1e-3, log_every=0, seed=3,
                      ckpt_dir=str(tmp_path))
        first = train(steps=10, **common)
        second = train(steps=20, **common)
        assert second["final_step"] == 20
        # resumed run executed only steps 10..19
        assert len(second["losses"]) == 10
