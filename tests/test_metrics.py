"""Metrics registry, request-lifecycle observability, and the
modeled-vs-measured profiler (ISSUE 10).

The two contracts under test, in the tracer's image:

* **disabled path is free and invisible** — with ``NULL_REGISTRY`` (the
  ambient default) every instrument is a shared no-op and instrumented
  code produces byte-identical output;
* **enabled path is consistent** — snapshots are schema-valid,
  histogram buckets are cumulative ``le`` semantics exactly, counters
  are thread-safe under contention, and the serve engine's lifecycle
  series add up.
"""
import json
import queue
import threading
from concurrent.futures import Future
from types import SimpleNamespace

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_fallback import given, settings, st

from repro import api
from repro.frontends import zoo
from repro.instrument import (
    NULL_REGISTRY,
    MetricsRegistry,
    profile_artifact,
    use_metrics,
    validate_metrics_snapshot,
)
from repro.instrument import metrics as metrics_mod
from repro.instrument.metrics import LATENCY_BUCKETS_MS, quantile
from repro.serve import ServeConfig, ServeEngine, run_load
from repro.serve.loadgen import _percentile


@pytest.fixture(scope="module")
def lenet_art():
    return api.compile_graph(zoo.lenet5())


def _sample_inputs(src, n, seed=0):
    rng = np.random.default_rng(seed)
    return [
        {k: rng.integers(-4, 5, size=src.values[k].shape, dtype=np.int32)
         for k in src.graph_inputs}
        for _ in range(n)
    ]


# ---------------------------------------------------------------------------
# registry basics
# ---------------------------------------------------------------------------


class TestCounter:
    def test_inc_value_total(self):
        r = MetricsRegistry()
        c = r.counter("reqs", "requests", labels=("cause",))
        c.inc(cause="a")
        c.inc(2.5, cause="b")
        assert c.value(cause="a") == 1
        assert c.value(cause="b") == 2.5
        assert c.value(cause="never") == 0
        assert c.total() == 3.5

    def test_counters_only_go_up(self):
        c = MetricsRegistry().counter("n")
        with pytest.raises(ValueError, match="only go up"):
            c.inc(-1)

    def test_label_names_enforced(self):
        c = MetricsRegistry().counter("n", labels=("cause",))
        with pytest.raises(ValueError, match="label"):
            c.inc()  # missing the declared label
        with pytest.raises(ValueError, match="label"):
            c.inc(cause="x", extra="y")

    def test_redeclare_get_or_create(self):
        r = MetricsRegistry()
        assert r.counter("n", labels=("a",)) is r.counter("n", labels=("a",))
        with pytest.raises(ValueError, match="already declared"):
            r.counter("n", labels=("b",))  # different labels
        with pytest.raises(ValueError, match="already declared"):
            r.gauge("n")  # different kind


class TestGauge:
    def test_set_inc_dec(self):
        g = MetricsRegistry().gauge("depth")
        g.inc()
        g.inc(3)
        g.dec()
        assert g.value() == 3
        g.set(-7.5)
        assert g.value() == -7.5


class TestHistogram:
    def test_sum_count_min_max(self):
        h = MetricsRegistry().histogram("lat", buckets=(1.0, 10.0))
        for v in (0.5, 5.0, 500.0):
            h.observe(v)
        row = h._export_child(h._children[()])
        assert row["count"] == 3
        assert row["sum"] == pytest.approx(505.5)
        assert row["min"] == 0.5 and row["max"] == 500.0

    def test_bucket_bounds_validated(self):
        r = MetricsRegistry()
        with pytest.raises(ValueError, match="at least one"):
            r.histogram("a", buckets=())
        with pytest.raises(ValueError, match="strictly increase"):
            r.histogram("b", buckets=(1.0, 1.0))
        with pytest.raises(ValueError, match="finite"):
            r.histogram("c", buckets=(1.0, float("inf")))

    def test_default_buckets_are_the_latency_ladder(self):
        h = MetricsRegistry().histogram("lat")
        assert h.buckets == LATENCY_BUCKETS_MS
        assert all(b2 == 2 * b1 for b1, b2 in
                   zip(LATENCY_BUCKETS_MS, LATENCY_BUCKETS_MS[1:]))

    def test_boundary_value_lands_in_its_bucket(self):
        """``le`` semantics: an observation exactly at a bound counts in
        that bound's bucket, not the next one."""
        bounds = (1.0, 2.0, 4.0)
        r = MetricsRegistry()
        h = r.histogram("lat", buckets=bounds)
        for b in bounds:
            h.observe(b)
        row = r.snapshot()["histograms"]["lat"]["values"][0]
        cum = {b["le"]: b["count"] for b in row["buckets"]}
        assert cum[1.0] == 1 and cum[2.0] == 2 and cum[4.0] == 3
        assert cum["+Inf"] == 3

    @settings(max_examples=40)
    @given(st.lists(st.integers(min_value=-(10 ** 4), max_value=10 ** 7),
                    min_size=0, max_size=50))
    def test_bucket_counts_match_direct_computation(self, raw):
        """Property sweep: for arbitrary observations the exported
        cumulative counts equal a direct ``v <= bound`` count, the +Inf
        bucket equals the total, and counts never decrease."""
        values = [v / 97.0 for v in raw]  # cover sub-bucket fractions
        r = MetricsRegistry()
        h = r.histogram("lat", buckets=LATENCY_BUCKETS_MS)
        for v in values:
            h.observe(v)
        snap = validate_metrics_snapshot(r.snapshot())
        rows = snap["histograms"]["lat"]["values"]
        if not values:
            assert rows == []
            return
        buckets = rows[0]["buckets"]
        for b in buckets[:-1]:
            assert b["count"] == sum(1 for v in values if v <= b["le"])
        assert buckets[-1]["le"] == "+Inf"
        assert buckets[-1]["count"] == len(values)
        counts = [b["count"] for b in buckets]
        assert counts == sorted(counts)
        assert rows[0]["sum"] == pytest.approx(sum(values), abs=1e-4)

    def test_quantile_estimator(self):
        r = MetricsRegistry()
        h = r.histogram("lat", buckets=(1.0, 2.0, 4.0, 8.0))
        for v in (0.5, 1.5, 3.0, 6.0):
            h.observe(v)
        row = r.snapshot()["histograms"]["lat"]["values"][0]
        assert 0 < quantile(row, 50) <= 4.0
        assert quantile(row, 100) <= 8.0
        assert quantile({"count": 0, "buckets": []}, 50) == 0.0
        with pytest.raises(ValueError, match="quantile"):
            quantile(row, 101)


class TestThreadSafety:
    def test_concurrent_updates_are_exact(self):
        r = MetricsRegistry()
        c = r.counter("n", labels=("worker",))
        h = r.histogram("lat", buckets=(1.0, 10.0, 100.0))
        g = r.gauge("depth")
        N, K = 8, 500

        def work(w):
            for i in range(K):
                c.inc(worker=str(w % 2))
                h.observe(float(i % 7))
                g.inc()
                g.dec()

        threads = [threading.Thread(target=work, args=(w,))
                   for w in range(N)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert c.total() == N * K
        snap = validate_metrics_snapshot(r.snapshot())
        row = snap["histograms"]["lat"]["values"][0]
        assert row["count"] == N * K
        assert row["buckets"][-1]["count"] == N * K
        assert g.value() == 0


# ---------------------------------------------------------------------------
# snapshot schema + exposition
# ---------------------------------------------------------------------------


class TestSnapshot:
    def _registry(self):
        r = MetricsRegistry()
        r.counter("reqs", "requests", labels=("cause",)).inc(cause="full")
        r.gauge("depth", "queue depth").set(3)
        r.histogram("lat", "latency", buckets=(1.0, 10.0)).observe(0.4)
        return r

    def test_snapshot_is_json_and_valid(self):
        snap = self._registry().snapshot()
        validate_metrics_snapshot(json.loads(json.dumps(snap)))
        assert snap["version"] == 1
        assert set(snap) == {"version", "counters", "gauges", "histograms"}

    def test_validator_rejects_tampering(self):
        snap = self._registry().snapshot()
        bad = json.loads(json.dumps(snap))
        bad["histograms"]["lat"]["values"][0]["buckets"][-1]["le"] = 10.0
        with pytest.raises(ValueError, match="\\+Inf"):
            validate_metrics_snapshot(bad)
        bad = json.loads(json.dumps(snap))
        bad["histograms"]["lat"]["values"][0]["buckets"][0]["count"] = 99
        with pytest.raises(ValueError, match="cumulative|count"):
            validate_metrics_snapshot(bad)
        bad = json.loads(json.dumps(snap))
        bad["counters"]["reqs"]["values"][0]["labels"] = {"other": "x"}
        with pytest.raises(ValueError, match="labels"):
            validate_metrics_snapshot(bad)
        with pytest.raises(ValueError, match="version"):
            validate_metrics_snapshot({"version": 2})
        with pytest.raises(ValueError, match="dict"):
            validate_metrics_snapshot([])

    def test_prometheus_exposition(self):
        text = self._registry().to_prometheus()
        assert "# TYPE reqs counter" in text
        assert 'reqs{cause="full"} 1.0' in text
        assert "# TYPE depth gauge" in text
        assert "# TYPE lat histogram" in text
        assert 'lat_bucket{le="+Inf"} 1' in text
        assert "lat_sum 0.4" in text
        assert "lat_count 1" in text
        assert text.endswith("\n")

    def test_prometheus_escapes_label_values(self):
        r = MetricsRegistry()
        r.counter("n", labels=("msg",)).inc(msg='he said "hi"\n')
        assert r'\"hi\"' in r.to_prometheus()


class TestNullRegistry:
    def test_disabled_and_noop(self):
        assert NULL_REGISTRY.enabled is False
        c = NULL_REGISTRY.counter("n", labels=("x",))
        c.inc()          # no label check, no state, no error
        c.inc(5, x="y")
        assert c.value() == 0.0
        NULL_REGISTRY.gauge("g").set(9)
        NULL_REGISTRY.histogram("h").observe(1.0)
        snap = NULL_REGISTRY.snapshot()
        validate_metrics_snapshot(snap)
        assert snap["counters"] == {}
        assert NULL_REGISTRY.to_prometheus() == ""

    def test_ambient_default_and_scope(self):
        assert metrics_mod.current() is NULL_REGISTRY
        r = MetricsRegistry()
        with use_metrics(r):
            assert metrics_mod.current() is r
            with use_metrics(None):  # no-op scope
                assert metrics_mod.current() is r
            with use_metrics(r):     # already installed: no-op
                assert metrics_mod.current() is r
        assert metrics_mod.current() is NULL_REGISTRY


# ---------------------------------------------------------------------------
# loadgen: _percentile edge cases + saturation handling
# ---------------------------------------------------------------------------


class TestPercentile:
    """Satellite: nearest-rank edge cases for the loadgen estimator."""

    def test_empty(self):
        assert _percentile([], 50) == 0.0

    def test_single_sample_all_quantiles(self):
        for q in (0, 1, 50, 99, 100):
            assert _percentile([7.5], q) == 7.5

    def test_q0_and_q100_hit_the_ends(self):
        xs = [1.0, 2.0, 3.0, 4.0, 5.0]
        assert _percentile(xs, 0) == 1.0
        assert _percentile(xs, 100) == 5.0

    def test_ties(self):
        xs = [3.0, 3.0, 3.0, 3.0]
        for q in (0, 25, 50, 75, 100):
            assert _percentile(xs, q) == 3.0

    def test_nearest_rank_rounding(self):
        xs = [10.0, 20.0]
        assert _percentile(xs, 49) == 10.0   # rounds to index 0
        assert _percentile(xs, 51) == 20.0   # rounds to index 1
        # exactly .5 hits Python's round-half-to-even: index 0
        assert _percentile(xs, 50) == 10.0

    def test_never_out_of_range(self):
        xs = sorted([5.0, 1.0, 9.0])
        for q in range(0, 101, 7):
            assert _percentile(xs, q) in xs


class _SaturatingEngine:
    """Deterministic stand-in: rejects every other submit with
    ``queue.Full`` (what a saturated admission queue does), resolves
    accepted futures immediately."""

    def __init__(self):
        self._stats = {"requests": 0, "batches": 0, "rejected": 0,
                       "max_batch_seen": 1}
        self.artifact = SimpleNamespace(
            source=SimpleNamespace(graph_inputs=["x"], values={}))
        self._n = 0

    @property
    def stats(self):  # point-in-time copy, the engine's contract
        return dict(self._stats)

    def submit(self, inputs):
        self._n += 1
        if self._n % 2 == 0:
            self._stats["rejected"] += 1
            raise queue.Full("admission queue full")
        fut = Future()
        fut.set_result(np.zeros(1))
        self._stats["requests"] += 1
        self._stats["batches"] += 1
        return fut


class TestLoadgenSaturation:
    """Satellite: ``run_load`` must survive admission rejection, count
    it, and keep rejected arrivals out of the latency distribution."""

    def test_queue_full_is_counted_not_raised(self):
        eng = _SaturatingEngine()
        rep = run_load(eng, offered_qps=50000, requests=10,
                       inputs=[{"x": np.zeros(1)}])
        assert rep.rejected == 5
        assert rep.requests == 5          # served only
        assert rep.batches == 5
        assert rep.mean_batch == 1.0
        assert rep.p99_ms >= 0            # computed over served only

    def test_all_rejected_yields_empty_distribution(self):
        eng = _SaturatingEngine()
        eng.submit = lambda inputs: (_ for _ in ()).throw(
            queue.Full("full"))
        rep = run_load(eng, offered_qps=50000, requests=4,
                       inputs=[{"x": np.zeros(1)}])
        assert rep.requests == 0
        assert rep.rejected == 4
        assert rep.p50_ms == 0.0 and rep.mean_ms == 0.0


# ---------------------------------------------------------------------------
# serve engine lifecycle metrics
# ---------------------------------------------------------------------------


class TestEngineMetrics:
    def test_lifecycle_series_add_up(self, lenet_art):
        samples = _sample_inputs(lenet_art.source, 6, seed=4)
        with ServeEngine(lenet_art, ServeConfig(max_batch=4)) as eng:
            futs = [eng.submit(s) for s in samples]
            for f in futs:
                f.result()
            snap = validate_metrics_snapshot(eng.metrics())
        served = snap["counters"]["serve_requests_total"]["values"][0]
        assert served["value"] == 6
        batches = snap["counters"]["serve_batches_total"]["values"][0]
        assert 2 <= batches["value"] <= 6  # max_batch=4 forces >= 2
        stages = {row["labels"]["stage"]: row["count"]
                  for row in snap["histograms"]["serve_stage_ms"]["values"]}
        assert set(stages) == {"queue_wait", "batch_form", "execute",
                               "respond"}
        assert stages["queue_wait"] == 6          # one per request
        assert stages["execute"] == batches["value"]   # one per batch
        occ = snap["histograms"]["serve_batch_occupancy"]["values"][0]
        assert occ["count"] == batches["value"]
        assert occ["sum"] == 6                    # occupancies sum to reqs
        lat = snap["histograms"]["serve_request_latency_ms"]["values"][0]
        assert lat["count"] == 6
        # nothing left in flight after the context exits
        depth = snap["gauges"]["serve_queue_depth"]["values"][0]
        assert depth["value"] == 0
        inflight = snap["gauges"]["serve_inflight_batches"]["values"][0]
        assert inflight["value"] == 0

    def test_invalid_request_counted_by_cause(self, lenet_art):
        with ServeEngine(lenet_art) as eng:
            with pytest.raises(ValueError):
                eng.submit({"nope": np.zeros((1, 8, 8))})
            snap = eng.metrics()
        rej = {row["labels"]["cause"]: row["value"]
               for row in snap["counters"]["serve_rejected_total"]["values"]}
        assert rej == {"invalid": 1}

    def test_request_ids_and_flight_recorder(self, lenet_art):
        samples = _sample_inputs(lenet_art.source, 5, seed=5)
        cfg = ServeConfig(max_batch=2, flight_records=2)
        with ServeEngine(lenet_art, cfg) as eng:
            for s in samples:
                eng.submit(s).result()
            recs = eng.flight_records()
        assert len(recs) == 2  # ring bounded by config
        ids = [i for r in recs for i in r["request_ids"]]
        assert ids == sorted(ids)  # monotone request ids
        for r in recs:
            assert r["outcome"] == "ok"
            assert r["n"] == len(r["request_ids"])
            for k in ("queue_wait_ms", "batch_form_ms", "execute_ms",
                      "respond_ms"):
                assert r[k] >= 0

    def test_flight_recorder_records_failures(self, lenet_art):
        samples = _sample_inputs(lenet_art.source, 1, seed=6)
        with ServeEngine(lenet_art) as eng:
            eng.artifact = _Exploding(lenet_art)
            fut = eng.submit(samples[0])
            with pytest.raises(RuntimeError, match="boom"):
                fut.result()
            recs = eng.flight_records()
            snap = eng.metrics()
            eng.artifact = lenet_art
        assert recs and recs[-1]["outcome"] == "error:RuntimeError"
        rej = {row["labels"]["cause"]: row["value"]
               for row in snap["counters"]["serve_rejected_total"]["values"]}
        assert rej.get("execute_error") == 1

    def test_flight_recorder_disabled_by_config(self, lenet_art):
        samples = _sample_inputs(lenet_art.source, 2, seed=7)
        with ServeEngine(lenet_art,
                         ServeConfig(flight_records=0)) as eng:
            for s in samples:
                eng.submit(s).result()
            assert eng.flight_records() == []

    def test_stats_property_is_a_safe_copy(self, lenet_art):
        """Satellite: ``stats`` is a point-in-time snapshot — mutating
        the returned dict never corrupts the engine's accounting."""
        samples = _sample_inputs(lenet_art.source, 2, seed=8)
        with ServeEngine(lenet_art) as eng:
            for s in samples:
                eng.submit(s).result()
            seen = eng.stats
            seen["requests"] = -999
            assert eng.stats["requests"] == 2
        assert eng.stats["requests"] == 2

    def test_null_registry_engine(self, lenet_art):
        samples = _sample_inputs(lenet_art.source, 2, seed=9)
        with ServeEngine(lenet_art, registry=NULL_REGISTRY) as eng:
            outs = [eng.submit(s).result() for s in samples]
            snap = validate_metrics_snapshot(eng.metrics())
        assert snap["counters"] == {}
        assert len(outs) == 2
        assert eng.stats["requests"] == 2  # legacy counters still work

    def test_shared_registry_aggregates_engines(self, lenet_art):
        shared = MetricsRegistry()
        samples = _sample_inputs(lenet_art.source, 2, seed=10)
        for _ in range(2):
            with ServeEngine(lenet_art, registry=shared) as eng:
                for s in samples:
                    eng.submit(s).result()
        snap = shared.snapshot()
        assert (snap["counters"]["serve_requests_total"]["values"][0]
                ["value"]) == 4


class _Exploding:
    """Artifact proxy whose run() always raises."""

    def __init__(self, art):
        self.source = art.source
        self.tracer = art.tracer

    def run(self, *a, **k):
        raise RuntimeError("boom")


# ---------------------------------------------------------------------------
# byte-identity with metrics disabled (acceptance criterion)
# ---------------------------------------------------------------------------


class TestByteIdentity:
    def test_run_outputs_identical_with_and_without_registry(self,
                                                             lenet_art):
        x = _sample_inputs(lenet_art.source, 1, seed=11)[0]
        name = lenet_art.source.graph_inputs[0]
        y_plain = lenet_art.run({name: x[name]}, seed=0)
        with use_metrics(MetricsRegistry()) as reg:
            y_metered = lenet_art.run({name: x[name]}, seed=0)
            assert reg.snapshot()["histograms"]  # it did record
        assert np.asarray(y_plain).tobytes() == \
            np.asarray(y_metered).tobytes()

    def test_serve_outputs_identical_with_and_without_registry(
            self, lenet_art):
        samples = _sample_inputs(lenet_art.source, 3, seed=12)
        with ServeEngine(lenet_art, registry=NULL_REGISTRY) as eng:
            null_out = [eng.submit(s).result() for s in samples]
        with ServeEngine(lenet_art) as eng:
            live_out = [eng.submit(s).result() for s in samples]
        for a, b in zip(null_out, live_out):
            assert np.asarray(a).tobytes() == np.asarray(b).tobytes()

    def test_ambient_registry_records_run_series(self, lenet_art):
        x = _sample_inputs(lenet_art.source, 1, seed=13)[0]
        name = lenet_art.source.graph_inputs[0]
        reg = MetricsRegistry()
        with use_metrics(reg):
            lenet_art.run({name: x[name]}, seed=0)
        snap = validate_metrics_snapshot(reg.snapshot())
        walls = snap["histograms"]["run_group_wall_ms"]["values"]
        assert walls and all(row["count"] >= 1 for row in walls)

    def test_report_telemetry_gains_metrics_section(self, lenet_art):
        x = _sample_inputs(lenet_art.source, 1, seed=14)[0]
        name = lenet_art.source.graph_inputs[0]
        reg = MetricsRegistry()
        with use_metrics(reg):
            lenet_art.run({name: x[name]}, seed=0)
            rep = lenet_art.report()
        assert rep.telemetry is not None
        validate_metrics_snapshot(rep.telemetry["metrics"])
        assert "metrics:" in str(rep)
        # without an ambient registry the section is absent
        rep_plain = lenet_art.report()
        assert "metrics" not in (rep_plain.telemetry or {})


# ---------------------------------------------------------------------------
# profiler
# ---------------------------------------------------------------------------


class TestProfiler:
    def test_profile_lenet5(self, lenet_art):
        rep = profile_artifact(lenet_art, reps=1, warmup=0)
        assert rep.model == "lenet5"
        assert rep.groups and rep.layers
        for g in rep.groups:
            assert g["modeled_cycles"] > 0
            assert g["measured_ms"] > 0
            assert g["implied_clock_mhz"] > 0
            assert g["ratio"] == pytest.approx(
                g["measured_ms"] / g["modeled_ms"], rel=1e-3)
            assert g["roofline_util"] is None or 0 <= g["roofline_util"] <= 1
        # layer attribution partitions each group's measured wall
        for g in rep.groups:
            attributed = sum(n["attributed_ms"] for n in rep.layers
                             if n["group"] == g["group"])
            assert attributed == pytest.approx(g["measured_ms"], abs=0.05)
        doc = json.loads(json.dumps(rep.to_json()))
        assert doc["version"] == 1 and doc["groups"]
        table = rep.format_table()
        assert "modeled_cyc" in table and rep.groups[0]["group"] in table

    def test_profile_all_zoo_models_both_targets(self):
        """Acceptance: a per-group table (and JSON) for every zoo model
        on both device presets."""
        for model, make in sorted(zoo.ZOO.items()):
            for target in ("kv260", "zu3eg"):
                art = api.compile_graph(make(), target=target)
                rep = profile_artifact(art, reps=1, warmup=0)
                assert rep.target == target
                assert rep.groups, f"{model}@{target}: no group rows"
                assert rep.layers, f"{model}@{target}: no layer rows"
                json.dumps(rep.to_json())
                assert model in rep.format_table()

    def test_drift_flagging_is_median_relative(self, lenet_art):
        rep = profile_artifact(lenet_art, reps=1, warmup=0,
                               threshold=1000.0)
        # an absurd threshold flags nothing
        assert rep.flagged == []
        assert all(not g["drift"] for g in rep.groups)

    def test_argument_validation(self, lenet_art):
        with pytest.raises(ValueError, match="reps"):
            profile_artifact(lenet_art, reps=0)
        with pytest.raises(ValueError, match="threshold"):
            profile_artifact(lenet_art, threshold=1.0)
        with pytest.raises(ValueError, match="clock"):
            profile_artifact(lenet_art, clock_mhz=0)

    def test_edge_roofline_helper(self):
        from benchmarks.roofline import edge_ideal_cycles

        # compute-bound: 1248 DSPs at 0.5 DSP/mult = 2496 MACs/cycle
        assert edge_ideal_cycles(249600, 0, d_total=1248) == 100
        # memory-bound: 16 B/cycle
        assert edge_ideal_cycles(0, 1600, d_total=1248) == 100
        # max of the two
        assert edge_ideal_cycles(249600, 160000, d_total=1248) == 10000
        with pytest.raises(ValueError, match="d_total"):
            edge_ideal_cycles(1, 1, d_total=0)


# ---------------------------------------------------------------------------
# smoke_diff blindness to the metrics fields (satellite)
# ---------------------------------------------------------------------------


class TestSmokeDiffMetricsBlind:
    @staticmethod
    def _sd():
        import importlib.util
        import os

        spec = importlib.util.spec_from_file_location(
            "smoke_diff_metrics",
            os.path.join(os.path.dirname(__file__), "..", "scripts",
                         "smoke_diff.py"))
        sd = importlib.util.module_from_spec(spec)
        spec.loader.exec_module(sd)
        return sd

    def test_compile_mode_ignores_metrics(self):
        sd = self._sd()
        assert "metrics" in sd.IGNORED_KEYS

        def snap(n):
            return {"lenet5": {"kv260": {
                "total_cycles": 100, "max_group_cycles": 100,
                "max_bram": 1, "groups": 1, "spill_bytes": 0,
                "metrics": {"version": 1, "counters": {"c": n}},
            }}}

        lines = []
        assert sd.diff(snap(1), snap(2), 0.10, emit=lines.append) == 0
        assert lines == ["graph,target,metric,previous,current,delta_pct"]

    def test_serve_mode_ignores_cell_metrics(self):
        sd = self._sd()

        def snap(n):
            return {"lenet5": {"kv260": {
                "loads": [{"offered_qps": 100.0, "achieved_qps": 50.0,
                           "p50_ms": 5.0, "p99_ms": 9.0, "mean_ms": 6.0,
                           "mean_batch": 2.0, "batches": 10,
                           "rejected": 0}],
                "metrics": {"version": 1, "counters": {"c": n}},
            }}}

        lines = []
        assert sd.diff_serve(snap(1), snap(2), 0.10,
                             emit=lines.append) == 0
        assert lines == [
            "model,target,offered_qps,metric,previous,current,delta_pct"
        ]
