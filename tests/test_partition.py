"""Resource-aware layer-group partitioning (repro.passes.partition)."""
import numpy as np
import pytest

from repro.core import cnn_graphs
from repro.core.dse import solve_ilp
from repro.core.resource_model import KV260_BRAM18K, KV260_DSP
from repro.core.streaming import plan_streams
from repro.passes import (
    PartitionError,
    partition_layer_groups,
    run_default_pipeline,
)
from repro.passes import interp


@pytest.fixture()
def deep224(deep224_fused, deep224_partition):
    """Fused deep_cascade(224) + its partition plan (session-shared —
    see conftest.py)."""
    return deep224_fused, deep224_partition


class TestAcceptance:
    """ISSUE 1: deep_cascade(224) only fits the KV260 via partitioning."""

    def test_whole_graph_provably_infeasible(self, deep224):
        fused, pp = deep224
        whole = solve_ilp(plan_streams(fused))
        assert not whole.feasible
        assert not pp.whole_graph_feasible

    def test_every_group_fits_budgets(self, deep224):
        _, pp = deep224
        assert pp.partitioned and len(pp.groups) >= 2
        assert pp.feasible
        for g in pp.groups:
            assert g.dse.feasible, g.name
            assert g.bram <= KV260_BRAM18K, g.name
            assert g.dsp <= KV260_DSP, g.name

    def test_deep_cascade_32_fits_whole(self):
        fused = run_default_pipeline(cnn_graphs.deep_cascade(32)).dfg
        pp = partition_layer_groups(fused)
        assert pp.whole_graph_feasible and len(pp.groups) == 1


class TestSpills:
    def test_boundary_values_spill_to_dram(self, deep224):
        fused, pp = deep224
        spills = pp.spills()
        assert spills, "a cut must spill at least one value"
        for s in spills:
            assert s.bits == fused.values[s.value].total_bits
            assert s.bytes == -(-s.bits // 8)
        # every spill-out of group i is a spill-in of a later group
        outs = {v for g in pp.groups for v in g.spill_out}
        ins = {v for g in pp.groups for v in g.spill_in}
        assert outs == ins

    def test_total_cycles_include_spill_traffic(self, deep224):
        _, pp = deep224
        assert pp.total_cycles == sum(g.cycles for g in pp.groups) + pp.spill_cycles
        assert pp.spill_cycles > 0

    def test_schedule_rows(self, deep224):
        fused, pp = deep224
        rows = pp.schedule()
        assert [r["group"] for r in rows] == [g.name for g in pp.groups]
        covered = [n for r in rows for n in r["nodes"]]
        assert sorted(covered) == sorted(n.name for n in fused.nodes)


class TestSemantics:
    def test_groupwise_execution_matches_whole_graph(self):
        """Chaining group subgraphs through the interpreter (the host
        schedule, with dict entries standing in for DRAM buffers) must
        reproduce the unpartitioned result exactly."""
        fused = run_default_pipeline(
            cnn_graphs.cascade_conv(16, c_mid=8)
        ).dfg
        # tiny BRAM budget forces a cut between the two convs
        pp = partition_layer_groups(fused, b_total=2)
        assert pp.partitioned
        env = interp.random_env(fused, seed=11)
        whole = interp.graph_outputs(fused, env)
        chained = dict(env)
        for g in pp.groups:
            chained.update(interp.execute_dfg(g.dfg, chained))
        for k, v in whole.items():
            np.testing.assert_array_equal(np.asarray(v), np.asarray(chained[k]))


class TestEdgeCases:
    def test_unsplittable_node_raises(self):
        dfg = cnn_graphs.conv_relu(32)
        with pytest.raises(PartitionError, match="alone exceeds"):
            partition_layer_groups(dfg, b_total=0)

    def test_budgets_recorded(self, deep224):
        _, pp = deep224
        assert pp.b_total == KV260_BRAM18K and pp.d_total == KV260_DSP
        assert pp.max_bram <= pp.b_total and pp.max_dsp <= pp.d_total
