"""Serving runtime (ISSUE 7): cache_key, ArtifactCache, ServeEngine,
load generator, and the single-tracer observability contract.
"""
import queue
import time

import numpy as np
import pytest

from repro import api
from repro.core import cnn_graphs
from repro.core.compile_driver import CompileOptions, KV260, ZU3EG
from repro.frontends import zoo
from repro.instrument import Tracer, use_tracer, validate_chrome_trace
from repro.serve import (
    ArtifactCache,
    LoadReport,
    ServeConfig,
    ServeEngine,
    run_load,
)


class TestCacheKey:
    def test_stable_across_instances(self):
        assert (CompileOptions(target="kv260").cache_key()
                == CompileOptions(target="kv260").cache_key())
        assert CompileOptions().cache_key() == CompileOptions().cache_key()

    def test_distinct_per_target_and_options(self):
        keys = {
            CompileOptions(target="kv260").cache_key(),
            CompileOptions(target="zu3eg").cache_key(),
            CompileOptions(strategy="greedy").cache_key(),
            CompileOptions(max_unroll=8).cache_key(),
            CompileOptions(weight_streaming="off").cache_key(),
            CompileOptions(passes=("dce",)).cache_key(),
        }
        assert len(keys) == 6

    def test_trace_does_not_change_identity(self):
        """Instrumentation never changes what gets compiled — a traced
        and an untraced compile must share a cache entry."""
        assert (CompileOptions(trace=True).cache_key()
                == CompileOptions().cache_key())

    def test_key_is_short_hashable_digest(self):
        k = CompileOptions().cache_key()
        assert isinstance(k, str) and len(k) == 16
        hash(k)


class TestArtifactCache:
    def _make(self, c_out):
        return lambda: cnn_graphs.conv_relu(8, c_out=c_out)

    def test_hit_returns_same_artifact(self):
        cache = ArtifactCache(capacity=4)
        a1 = cache.get_or_compile("m", self._make(4), CompileOptions())
        a2 = cache.get_or_compile("m", self._make(4), CompileOptions())
        assert a1 is a2
        assert cache.stats == {"hits": 1, "misses": 1, "evictions": 0}

    def test_distinct_options_distinct_entries(self):
        cache = ArtifactCache(capacity=4)
        a = cache.get_or_compile("m", self._make(4),
                                 CompileOptions(target="kv260"))
        b = cache.get_or_compile("m", self._make(4),
                                 CompileOptions(target="zu3eg"))
        assert a is not b and len(cache) == 2

    def test_lru_eviction_bounded(self):
        cache = ArtifactCache(capacity=2)
        for name in ("a", "b", "c"):
            cache.get_or_compile(name, self._make(4), CompileOptions())
        assert len(cache) == 2
        assert cache.stats["evictions"] == 1
        # "a" was evicted; "c" (and "b") still resident
        assert cache.get("a", CompileOptions()) is None
        assert cache.get("c", CompileOptions()) is not None

    def test_lru_refresh_on_hit(self):
        cache = ArtifactCache(capacity=2)
        cache.get_or_compile("a", self._make(4), CompileOptions())
        cache.get_or_compile("b", self._make(5), CompileOptions())
        cache.get_or_compile("a", self._make(4), CompileOptions())  # hot
        cache.get_or_compile("c", self._make(6), CompileOptions())
        assert cache.get("a", CompileOptions()) is not None
        assert cache.get("b", CompileOptions()) is None

    def test_capacity_validated(self):
        with pytest.raises(ValueError, match="capacity"):
            ArtifactCache(capacity=0)


@pytest.fixture(scope="module")
def lenet_art():
    return api.compile_graph(zoo.lenet5())


def _sample_inputs(src, n, seed=0):
    rng = np.random.default_rng(seed)
    return [
        {k: rng.integers(-4, 5, size=src.values[k].shape, dtype=np.int32)
         for k in src.graph_inputs}
        for _ in range(n)
    ]


class TestServeEngine:
    def test_results_match_direct_run(self, lenet_art):
        samples = _sample_inputs(lenet_art.source, 5, seed=1)
        with ServeEngine(lenet_art, ServeConfig(max_batch=4)) as eng:
            futs = [eng.submit(s) for s in samples]
            got = [f.result(timeout=60) for f in futs]
        name = lenet_art.source.graph_inputs[0]
        stacked = np.stack([s[name] for s in samples])
        want = lenet_art.run({name: stacked})
        for i in range(5):
            np.testing.assert_array_equal(got[i], want[i])

    def test_batches_respect_max_batch(self, lenet_art):
        samples = _sample_inputs(lenet_art.source, 6, seed=2)
        with ServeEngine(lenet_art,
                         ServeConfig(max_batch=2,
                                     latency_budget_ms=50.0)) as eng:
            futs = [eng.submit(s) for s in samples]
            for f in futs:
                f.result(timeout=60)
        assert eng.stats["max_batch_seen"] <= 2
        assert eng.stats["requests"] == 6
        assert eng.stats["batches"] >= 3

    def test_dynamic_batching_coalesces(self, lenet_art):
        """A generous budget coalesces queued singles into one batch."""
        samples = _sample_inputs(lenet_art.source, 4, seed=3)
        with ServeEngine(lenet_art,
                         ServeConfig(max_batch=8,
                                     latency_budget_ms=500.0)) as eng:
            futs = [eng.submit(s) for s in samples]
            for f in futs:
                f.result(timeout=60)
        assert eng.stats["batches"] < 4

    def test_bare_array_single_input(self, lenet_art):
        x = _sample_inputs(lenet_art.source, 1, seed=4)[0]
        name = lenet_art.source.graph_inputs[0]
        with ServeEngine(lenet_art) as eng:
            got = eng(x[name])
        np.testing.assert_array_equal(got,
                                      lenet_art.run({name: x[name][None]})[0])

    def test_malformed_requests_rejected_at_admission(self, lenet_art):
        """Bad requests fail their *own* caller at submit(), before
        they can poison the innocent requests they would have
        co-batched with at np.stack time."""
        src = lenet_art.source
        name = src.graph_inputs[0]
        good = _sample_inputs(src, 1, seed=5)[0]
        with ServeEngine(lenet_art) as eng:
            with pytest.raises(ValueError, match="per-sample shape"):
                eng.submit(np.zeros((3, 3), np.int32))  # wrong shape
            with pytest.raises(ValueError, match="missing"):
                eng.submit({})  # dict missing the graph input
            with pytest.raises(ValueError, match="unknown"):
                eng.submit(dict(good, bogus=good[name]))
            with pytest.raises(ValueError, match="per-sample shape"):
                eng.submit({name: good[name][None]})  # stray batch dim
            # engine keeps serving well-formed requests
            eng(good)
        assert eng.stats["requests"] == 1

    def test_execute_errors_propagate_to_future(self, lenet_art):
        """A failure *inside* the batch execute still resolves every
        future with the exception — no hung callers."""
        x = _sample_inputs(lenet_art.source, 1, seed=5)[0]

        def boom(*a, **k):
            raise RuntimeError("kaboom")

        with ServeEngine(lenet_art) as eng:
            lenet_art.run = boom  # instance shadow over the method
            try:
                fut = eng.submit(x)
                with pytest.raises(RuntimeError, match="kaboom"):
                    fut.result(timeout=60)
            finally:
                del lenet_art.run
            # engine keeps serving after a poisoned batch
            eng(x)

    def test_stop_drains_queued_requests(self, lenet_art):
        """Requests stuck in the queue behind the stop signal fail
        loudly with RuntimeError instead of blocking their callers on
        fut.result() forever."""
        import threading
        from concurrent.futures import Future

        from repro.serve import engine as engine_mod

        x = _sample_inputs(lenet_art.source, 1, seed=11)[0]
        started, gate = threading.Event(), threading.Event()
        real_run = type(lenet_art).run

        def slow_run(*a, **k):
            started.set()
            assert gate.wait(timeout=30)
            return real_run(lenet_art, *a, **k)

        lenet_art.run = slow_run  # instance shadow over the method
        try:
            eng = ServeEngine(lenet_art,
                              ServeConfig(latency_budget_ms=0.0)).start()
            fut = eng.submit(x)
            assert started.wait(timeout=30)  # worker busy in _execute
            # jam a request behind a stop signal — the shape admission
            # racing shutdown would take
            eng._queue.put(engine_mod._STOP)
            orphan = engine_mod._Request(
                -1, {k: np.asarray(v) for k, v in x.items()},
                Future(), time.perf_counter())
            eng._queue.put(orphan)
            gate.set()
            eng.stop()
        finally:
            del lenet_art.run
        fut.result(timeout=60)  # the in-flight batch still completed
        with pytest.raises(RuntimeError, match="engine stopped"):
            orphan.future.result(timeout=60)
        assert eng.stats["rejected"] == 1
        with pytest.raises(RuntimeError, match="not started"):
            eng.submit(x)  # a stopped engine rejects new work

    def test_submit_requires_start(self, lenet_art):
        eng = ServeEngine(lenet_art)
        with pytest.raises(RuntimeError, match="not started"):
            eng.submit(np.zeros((1,), np.int32))

    def test_queue_depth_rejects(self, lenet_art):
        eng = ServeEngine(lenet_art, ServeConfig(queue_depth=1))
        # fill the queue without a worker draining it
        eng._worker = object()  # type: ignore[assignment]
        x = _sample_inputs(lenet_art.source, 1, seed=6)[0]
        eng._params_resolved = {}
        eng.submit(x)
        with pytest.raises(queue.Full):
            eng.submit(x)
        assert eng.stats["rejected"] == 1

    def test_config_validation(self):
        with pytest.raises(ValueError, match="max_batch"):
            ServeConfig(max_batch=0)
        with pytest.raises(ValueError, match="latency_budget_ms"):
            ServeConfig(latency_budget_ms=-1)
        with pytest.raises(ValueError, match="queue_depth"):
            ServeConfig(queue_depth=0)


class TestServeTracing:
    """Acceptance: serve counters land in the PR 6 Chrome trace — the
    same tracer, not a second telemetry path."""

    def test_serve_counters_in_chrome_trace(self, lenet_art):
        tracer = Tracer()
        samples = _sample_inputs(lenet_art.source, 4, seed=7)
        with use_tracer(tracer):
            cache = ArtifactCache(capacity=2)
            cache.put("lenet5", CompileOptions(), lenet_art)
            art = cache.get_or_compile("lenet5", zoo.lenet5,
                                       CompileOptions())
            assert art is lenet_art
            with ServeEngine(art, ServeConfig(max_batch=4)) as eng:
                futs = [eng.submit(s) for s in samples]
                for f in futs:
                    f.result(timeout=60)
        obj = tracer.to_chrome()
        validate_chrome_trace(obj)
        names = {e["name"] for e in obj["traceEvents"]}
        assert {"serve:batch", "serve_batch", "serve_latency_ms",
                "serve_qps", "artifact_cache"} <= names
        # counter args are numeric (validate_chrome_trace-compatible)
        for ev in obj["traceEvents"]:
            if ev["ph"] == "C":
                assert all(isinstance(v, (int, float))
                           for v in ev["args"].values())

    def test_worker_thread_sees_artifact_tracer(self):
        """No ambient tracer: the worker installs the artifact's
        compile-time tracer across the thread boundary."""
        art = api.compile_graph(cnn_graphs.conv_relu(8, c_out=4),
                                api.CompileOptions(trace=True))
        x = _sample_inputs(art.source, 2, seed=8)
        with ServeEngine(art, ServeConfig(max_batch=2)) as eng:
            futs = [eng.submit(s) for s in x]
            for f in futs:
                f.result(timeout=60)
        names = {e["name"] for e in art.tracer.events}
        assert "serve:batch" in names and "serve_qps" in names


class TestLoadGenerator:
    def test_report_shape_and_totals(self, lenet_art):
        with ServeEngine(lenet_art, ServeConfig(max_batch=8)) as eng:
            rep = run_load(eng, offered_qps=500, requests=20, seed=9)
        assert isinstance(rep, LoadReport)
        assert rep.requests == 20
        assert rep.achieved_qps > 0
        assert 0 < rep.p50_ms <= rep.p99_ms
        assert rep.mean_batch >= 1
        row = rep.row()
        assert set(row) == {"offered_qps", "achieved_qps", "requests",
                            "duration_s", "p50_ms", "p99_ms", "mean_ms",
                            "mean_batch", "batches", "rejected"}

    def test_validates_arguments(self, lenet_art):
        with ServeEngine(lenet_art) as eng:
            with pytest.raises(ValueError, match="offered_qps"):
                run_load(eng, offered_qps=0, requests=1)
            with pytest.raises(ValueError, match="requests"):
                run_load(eng, offered_qps=1, requests=0)


class TestServeDiff:
    """scripts/smoke_diff.py --mode serve: fail-soft row diffs, hard
    fail only on >threshold p99/throughput regressions, provenance
    stripped."""

    @staticmethod
    def _sd():
        import importlib.util
        import os

        spec = importlib.util.spec_from_file_location(
            "smoke_diff_serve",
            os.path.join(os.path.dirname(__file__), "..", "scripts",
                         "smoke_diff.py"))
        sd = importlib.util.module_from_spec(spec)
        spec.loader.exec_module(sd)
        return sd

    @staticmethod
    def _snap(p99=10.0, qps=200.0, sha="aaa"):
        return {
            "lenet5": {"kv260": {
                "loads": [{"offered_qps": 200.0, "achieved_qps": qps,
                           "p50_ms": 7.0, "p99_ms": p99, "mean_ms": 7.5,
                           "mean_batch": 2.0, "requests": 60,
                           "duration_s": 0.3, "batches": 30, "rejected": 0,
                           "provenance": {"git_sha": sha}}],
                "provenance": {"git_sha": sha},
            }},
            "_speedup": {"speedup": 10.0, "provenance": {"git_sha": sha}},
        }

    def test_provenance_only_change_is_soft(self):
        sd = self._sd()
        lines = []
        assert sd.diff_serve(self._snap(sha="aaa"), self._snap(sha="bbb"),
                             0.10, emit=lines.append) == 0
        assert lines == [
            "model,target,offered_qps,metric,previous,current,delta_pct"
        ]

    def test_small_drift_is_soft(self):
        sd = self._sd()
        assert sd.diff_serve(self._snap(p99=10.0), self._snap(p99=10.5),
                             0.10, emit=lambda *_: None) == 0

    def test_p99_and_throughput_regressions_hard_fail(self):
        sd = self._sd()
        assert sd.diff_serve(self._snap(p99=10.0), self._snap(p99=12.0),
                             0.10, emit=lambda *_: None) == 1
        assert sd.diff_serve(self._snap(qps=200.0), self._snap(qps=150.0),
                             0.10, emit=lambda *_: None) == 1
        # improvements never fail
        assert sd.diff_serve(self._snap(p99=12.0, qps=150.0),
                             self._snap(p99=10.0, qps=200.0),
                             0.10, emit=lambda *_: None) == 0
