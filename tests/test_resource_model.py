"""Resource model (paper C3): BRAM granularity, int8 DSP packing, modes."""
import pytest

from repro.core import cnn_graphs
from repro.core.resource_model import (
    BRAM18K_BITS,
    ExecMode,
    FpgaResourceModel,
    LUTRAM_THRESHOLD_BITS,
    TpuResourceModel,
    TPU_V5E,
    bram_blocks,
    dsp_per_mult,
)
from repro.core.streaming import plan_streams


class TestBramBlocks:
    def test_zero(self):
        assert bram_blocks(0) == 0

    def test_lutram_threshold(self):
        assert bram_blocks(LUTRAM_THRESHOLD_BITS) == 0
        assert bram_blocks(LUTRAM_THRESHOLD_BITS + 1) == 1

    def test_rounding(self):
        assert bram_blocks(BRAM18K_BITS) == 1
        assert bram_blocks(BRAM18K_BITS + 1) == 2

    def test_partition_granularity_loss(self):
        """Partitioning a 2-block array into 4 slices costs 4 blocks when
        slices exceed the LUTRAM threshold — the paper's explanation of
        StreamHLS's unroll-driven BRAM growth."""
        bits = 2 * BRAM18K_BITS
        assert bram_blocks(bits, partitions=1) == 2
        assert bram_blocks(bits, partitions=4) == 4

    def test_partition_into_lutram(self):
        bits = 4 * LUTRAM_THRESHOLD_BITS
        assert bram_blocks(bits, partitions=4) == 0


class TestDspPacking:
    def test_int8_packs_two_per_dsp(self):
        assert dsp_per_mult(8) == 0.5

    def test_int16_one(self):
        assert dsp_per_mult(16) == 1.0

    def test_wide_cascades(self):
        assert dsp_per_mult(27) == 2.0
        assert dsp_per_mult(32) == 4.0


class TestModes:
    def _plans(self, n=32):
        plan = plan_streams(cnn_graphs.conv_relu(n))
        model = FpgaResourceModel()
        return plan, model

    def test_streaming_bram_constant_in_input_size(self):
        """MING's BRAM is line-buffer-only: grows ~linearly in N (line
        length), not N² (tensor area)."""
        model = FpgaResourceModel()
        brams = []
        for n in (32, 224):
            plan = plan_streams(cnn_graphs.conv_relu(n))
            est = model.estimate(plan, ExecMode.STREAMING, {})
            brams.append(est.bram)
        assert brams[1] <= brams[0] * (224 / 32) * 1.5

    def test_vanilla_bram_grows_quadratically(self):
        """Fig. 3: materialized BRAM scales with tensor area."""
        model = FpgaResourceModel()
        brams = []
        for n in (32, 224):
            plan = plan_streams(cnn_graphs.conv_relu(n))
            est = model.estimate(plan, ExecMode.VANILLA, {})
            brams.append(est.bram)
        assert brams[1] >= brams[0] * 20  # paper: 19 → 707 (~37×)

    def test_war_ii_slows_materialized(self):
        plan, model = self._plans()
        s = model.estimate(plan, ExecMode.STREAMING, {})
        m = model.estimate(plan, ExecMode.MATERIALIZED_DATAFLOW, {})
        assert m.cycles > s.cycles  # II=2 vs II=1 at equal unroll

    def test_pipeline_cycles_less_than_sum(self):
        plan, model = self._plans()
        est = model.estimate(plan, ExecMode.STREAMING, {})
        assert est.pipeline_cycles <= est.cycles

    def test_relu_contributes_no_dsp(self):
        plan, model = self._plans()
        est = model.estimate(plan, ExecMode.STREAMING, {})
        relu = [n for n in est.nodes if n.name == "relu0"][0]
        assert relu.dsp == 0


class TestTpuModel:
    def test_matmul_aligned_full_util(self):
        m = TpuResourceModel()
        e = m.matmul_block(128, 512, 128)
        assert e.mxu_util == 1.0
        assert e.cycles == pytest.approx(512.0)

    def test_matmul_misaligned_wastes_lanes(self):
        m = TpuResourceModel()
        e = m.matmul_block(64, 512, 128)
        assert e.mxu_util == pytest.approx(0.5)

    def test_attention_vmem_scales_with_blocks(self):
        m = TpuResourceModel()
        small = m.attention_blocks(block_q=128, block_k=128, head_dim=128)
        big = m.attention_blocks(block_q=512, block_k=512, head_dim=128)
        assert big.vmem_bytes > small.vmem_bytes

    def test_roofline_time(self):
        m = TpuResourceModel()
        c, h = m.roofline_time(197e12, 819e9, chips=1)
        assert c == pytest.approx(1.0)
        assert h == pytest.approx(1.0)
