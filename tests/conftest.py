"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests must see the
single real CPU device; multi-device tests spawn subprocesses that set
their own --xla_force_host_platform_device_count."""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")
GOLDEN_DIR = os.path.join(REPO, "tests", "golden")


def pytest_addoption(parser):
    parser.addoption(
        "--update-golden", action="store_true", default=False,
        help="rewrite tests/golden/ from the current emission instead of "
             "diffing against it (one-command golden refresh)",
    )


@pytest.fixture(scope="session")
def golden_check(request):
    """Compare emitted text against ``tests/golden/<name>``; with
    ``pytest --update-golden`` the golden file is (re)written instead."""
    update = request.config.getoption("--update-golden")

    def check(name: str, content: str):
        path = os.path.join(GOLDEN_DIR, name)
        if update:
            with open(path, "w") as f:
                f.write(content)
            return
        assert os.path.exists(path), (
            f"missing golden {name} — run `pytest --update-golden` to "
            "create it"
        )
        with open(path) as f:
            expected = f.read()
        assert content == expected, (
            f"{name} drifted from golden — if intentional, refresh with "
            "`pytest --update-golden`"
        )

    return check


def run_subprocess(code: str, *, devices: int = 0, env: dict | None = None,
                   timeout: int = 600) -> subprocess.CompletedProcess:
    """Run python code in a subprocess with its own device count."""
    e = dict(os.environ)
    e["PYTHONPATH"] = SRC + os.pathsep + e.get("PYTHONPATH", "")
    if devices:
        e["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    e.update(env or {})
    return subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, env=e, timeout=timeout,
    )


@pytest.fixture(scope="session")
def subproc():
    return run_subprocess


@pytest.fixture(scope="session")
def deep224_fused():
    """deep_cascade(224) through the default pass pipeline — shared
    across test modules (the pipeline + per-group ILP solves are the
    priciest model-side fixtures in the suite)."""
    from repro.core import cnn_graphs
    from repro.passes import run_default_pipeline

    return run_default_pipeline(cnn_graphs.deep_cascade(224)).dfg


@pytest.fixture(scope="session")
def deep224_partition(deep224_fused):
    """Cycle-balanced partition of deep_cascade(224) (CompiledDesign)."""
    from repro.passes import partition_layer_groups

    return partition_layer_groups(deep224_fused)
