"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests must see the
single real CPU device; multi-device tests spawn subprocesses that set
their own --xla_force_host_platform_device_count."""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_subprocess(code: str, *, devices: int = 0, env: dict | None = None,
                   timeout: int = 600) -> subprocess.CompletedProcess:
    """Run python code in a subprocess with its own device count."""
    e = dict(os.environ)
    e["PYTHONPATH"] = SRC + os.pathsep + e.get("PYTHONPATH", "")
    if devices:
        e["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    e.update(env or {})
    return subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, env=e, timeout=timeout,
    )


@pytest.fixture(scope="session")
def subproc():
    return run_subprocess


@pytest.fixture(scope="session")
def deep224_fused():
    """deep_cascade(224) through the default pass pipeline — shared
    across test modules (the pipeline + per-group ILP solves are the
    priciest model-side fixtures in the suite)."""
    from repro.core import cnn_graphs
    from repro.passes import run_default_pipeline

    return run_default_pipeline(cnn_graphs.deep_cascade(224)).dfg


@pytest.fixture(scope="session")
def deep224_partition(deep224_fused):
    """Cycle-balanced partition of deep_cascade(224) (CompiledDesign)."""
    from repro.passes import partition_layer_groups

    return partition_layer_groups(deep224_fused)
