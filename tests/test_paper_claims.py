"""Regression tests pinning the paper's qualitative claims to the
calibrated models (EXPERIMENTS.md §Paper-validation)."""
import pytest

from benchmarks.paper_tables import PAPER_TABLE2, _modes_for, fig3, table4
from repro.core import cnn_graphs
from repro.core.dse import solve_ilp
from repro.core.resource_model import KV260_BRAM18K, KV260_DSP
from repro.core.streaming import plan_streams


class TestTable2Claims:
    @pytest.fixture(scope="class")
    def modes(self):
        return {
            name: _modes_for(make())
            for name, make in cnn_graphs.PAPER_SUITE.items()
        }

    def test_ming_fastest_everywhere(self, modes):
        """Fastest among designs that actually fit the device — cycle
        counts of infeasible designs (StreamHLS at 224², Table II DNF
        rows) are fantasy numbers the paper excludes too."""
        for name, m in modes.items():
            cycles = {k: v[0] for k, v in m.items() if v[3]}
            assert m["ming"][3], name
            assert cycles["ming"] == min(cycles.values()), name

    def test_ming_bram_constant_in_input_size(self, modes):
        """Table II: MING BRAM identical for 32² and 224² inputs."""
        for a, b in (("conv_relu_32", "conv_relu_224"),
                     ("cascade_conv_32", "cascade_conv_224"),
                     ("residual_block_32", "residual_block_224")):
            assert modes[a]["ming"][1] == modes[b]["ming"][1]

    def test_ming_single_conv_bram_matches_paper_exactly(self, modes):
        assert modes["conv_relu_32"]["ming"][1] == 16  # paper: 16

    def test_streamhls_infeasible_at_224(self, modes):
        """Paper: StreamHLS exceeds the KV260 BRAM at 224² inputs."""
        for name in ("conv_relu_224", "cascade_conv_224",
                     "residual_block_224"):
            feasible = modes[name]["streamhls"][3]
            assert not feasible, name

    def test_ming_always_feasible(self, modes):
        for name, m in modes.items():
            assert m["ming"][3], name

    def test_ming_speedup_order_of_magnitude(self, modes):
        """Paper: single-layer ≈ 504-582×; ours must land in [100, 2000]."""
        for name in ("conv_relu_32", "conv_relu_224"):
            v = modes[name]["vanilla"][0]
            g = modes[name]["ming"][0]
            assert 100 <= v / g <= 2000, (name, v / g)

    def test_ming_best_dsp_efficiency(self, modes):
        """Paper: MING has the highest E_DSP on every kernel (among
        designs that fit the device; infeasible rows are excluded as in
        test_ming_fastest_everywhere)."""
        for name, m in modes.items():
            v_cyc, _, v_dsp, _ = m["vanilla"]

            def edsp(mode):
                cyc, _, dsp, _ = m[mode]
                return (v_cyc / max(cyc, 1)) / max(dsp / max(v_dsp, 1), 1e-9)

            scores = {mode: edsp(mode) for mode in m if m[mode][3]}
            assert scores["ming"] == max(scores.values()), (name, scores)


class TestFig3Claim:
    def test_materialized_grows_streaming_flat(self):
        data = fig3(emit=lambda *_: None, sizes=(32, 128, 224))
        mat, stream = data["materialized"], data["streaming"]
        assert mat[-1] > mat[0] * 10          # ~N² growth
        assert stream[-1] == stream[0]        # constant


class TestTable4Claim:
    def test_feasible_under_extreme_dsp_scarcity(self):
        rows = table4(emit=lambda *_: None, budgets=(1248, 250, 50))
        assert all(r["feasible"] for r in rows)
        # monotone: less DSP -> no more speedup
        speeds = [r["speedup"] for r in rows]
        assert speeds[0] >= speeds[1] >= speeds[2]
        assert all(r["dsp"] <= r["budget"] for r in rows)
