"""Per-kernel validation: sweep shapes/dtypes, assert allclose against the
ref.py pure-jnp oracles (Pallas kernels run in interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep: property tests skip, unit tests run
    from _hypothesis_fallback import given, settings, st

from repro.kernels import ops, ref


def _rand(key, shape, dtype):
    if jnp.issubdtype(dtype, jnp.integer):
        return jax.random.randint(key, shape, -8, 8, dtype)
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


# ---------------------------------------------------------------------------
# conv2d_stream (the paper's centerpiece kernel)
# ---------------------------------------------------------------------------


class TestConv2dStream:
    @pytest.mark.parametrize("dtype", [jnp.int8, jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("k", [1, 3, 5])
    def test_kernel_sizes_dtypes(self, dtype, k):
        kx, kw = jax.random.split(jax.random.key(0))
        x = _rand(kx, (2, 12, 12, 4), dtype)
        w = _rand(kw, (k, k, 4, 8), dtype)
        out = ops.conv2d_stream(x, w)
        exp = ref.conv2d(x, w)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(exp, np.float32),
            atol=1e-2 if dtype == jnp.bfloat16 else 1e-4, rtol=1e-2,
        )

    @pytest.mark.parametrize("hw", [(8, 8), (16, 8), (9, 13), (32, 32)])
    def test_shapes(self, hw):
        h, w_ = hw
        kx, kw = jax.random.split(jax.random.key(1))
        x = _rand(kx, (1, h, w_, 3), jnp.int8)
        w = _rand(kw, (3, 3, 3, 16), jnp.int8)
        out = ops.conv2d_stream(x, w)
        exp = ref.conv2d(x, w)
        assert out.shape == exp.shape == (1, h, w_, 16)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))

    def test_fused_relu(self):
        kx, kw = jax.random.split(jax.random.key(2))
        x = _rand(kx, (1, 8, 8, 2), jnp.int8)
        w = _rand(kw, (3, 3, 2, 4), jnp.int8)
        out = ops.conv2d_stream(x, w, fuse_relu=True)
        exp = ref.conv2d(x, w, fuse_relu=True)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))
        assert (np.asarray(out) >= 0).all()

    @pytest.mark.parametrize("rows", [1, 2, 4])
    def test_rows_per_block_invariant(self, rows):
        """The DSE's row-tiling choice must not change results."""
        kx, kw = jax.random.split(jax.random.key(3))
        x = _rand(kx, (1, 10, 10, 3), jnp.int8)
        w = _rand(kw, (3, 3, 3, 4), jnp.int8)
        out = ops.conv2d_stream(x, w, rows_per_block=rows)
        exp = ref.conv2d(x, w)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))

    def test_int8_accumulates_int32(self):
        kx, kw = jax.random.split(jax.random.key(4))
        x = jnp.full((1, 8, 8, 64), 127, jnp.int8)
        w = jnp.full((3, 3, 64, 4), 127, jnp.int8)
        out = ops.conv2d_stream(x, w)
        assert out.dtype == jnp.int32
        exp = ref.conv2d(x, w)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


class TestFlashAttention:
    @pytest.mark.parametrize("causal", [True, False])
    @pytest.mark.parametrize("group", [1, 4])
    def test_gqa_causal(self, causal, group):
        ks = jax.random.split(jax.random.key(0), 3)
        hkv = 2
        q = _rand(ks[0], (2, hkv * group, 32, 16), jnp.float32)
        k = _rand(ks[1], (2, hkv, 32, 16), jnp.float32)
        v = _rand(ks[2], (2, hkv, 32, 16), jnp.float32)
        out = ops.flash_attention(q, k, v, causal=causal, block_q=16, block_k=16)
        exp = ref.attention(q, k, v, causal=causal)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(exp), atol=2e-5, rtol=2e-5
        )

    @pytest.mark.parametrize("sq,sk,bq,bk", [
        (16, 16, 16, 16), (64, 64, 16, 32), (32, 64, 32, 16), (128, 128, 64, 64),
    ])
    def test_block_shapes(self, sq, sk, bq, bk):
        ks = jax.random.split(jax.random.key(1), 3)
        q = _rand(ks[0], (1, 4, sq, 32), jnp.float32)
        k = _rand(ks[1], (1, 4, sk, 32), jnp.float32)
        v = _rand(ks[2], (1, 4, sk, 32), jnp.float32)
        out = ops.flash_attention(q, k, v, causal=False, block_q=bq, block_k=bk)
        exp = ref.attention(q, k, v, causal=False)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(exp), atol=2e-5, rtol=2e-5
        )

    def test_decode_q_offset(self):
        """Decode semantics: q at absolute position q_offset attends to the
        full prefix."""
        ks = jax.random.split(jax.random.key(2), 3)
        q = _rand(ks[0], (1, 2, 8, 16), jnp.float32)
        k = _rand(ks[1], (1, 2, 32, 16), jnp.float32)
        v = _rand(ks[2], (1, 2, 32, 16), jnp.float32)
        out = ops.flash_attention(q, k, v, causal=True, q_offset=24,
                                  block_q=8, block_k=16)
        exp = ref.attention(q, k, v, causal=True, q_offset=24)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(exp), atol=2e-5, rtol=2e-5
        )

    def test_bfloat16(self):
        ks = jax.random.split(jax.random.key(3), 3)
        q = _rand(ks[0], (1, 2, 32, 32), jnp.bfloat16)
        k = _rand(ks[1], (1, 2, 32, 32), jnp.bfloat16)
        v = _rand(ks[2], (1, 2, 32, 32), jnp.bfloat16)
        out = ops.flash_attention(q, k, v, block_q=16, block_k=16)
        exp = ref.attention(q, k, v)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(exp, np.float32),
            atol=3e-2, rtol=3e-2,
        )


# ---------------------------------------------------------------------------
# fused MLP
# ---------------------------------------------------------------------------


class TestFusedMlp:
    @pytest.mark.parametrize("act", ["silu", "gelu", "relu", "squared_relu"])
    @pytest.mark.parametrize("gated", [True, False])
    def test_acts_gating(self, act, gated):
        ks = jax.random.split(jax.random.key(0), 4)
        x = _rand(ks[0], (32, 64), jnp.float32)
        wg = _rand(ks[1], (64, 128), jnp.float32) * 0.1 if gated else None
        wu = _rand(ks[2], (64, 128), jnp.float32) * 0.1
        wd = _rand(ks[3], (128, 64), jnp.float32) * 0.1
        out = ops.fused_mlp(x, wg, wu, wd, act=act, block_m=16, block_f=32)
        exp = ref.mlp(x, wg, wu, wd, act=act)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(exp), atol=5e-4, rtol=5e-4
        )

    @pytest.mark.parametrize("m,f,bm,bf", [
        (8, 32, 8, 32), (64, 256, 16, 64), (128, 512, 128, 128),
    ])
    def test_tilings(self, m, f, bm, bf):
        ks = jax.random.split(jax.random.key(1), 4)
        x = _rand(ks[0], (m, 32), jnp.float32)
        wg = _rand(ks[1], (32, f), jnp.float32) * 0.1
        wu = _rand(ks[2], (32, f), jnp.float32) * 0.1
        wd = _rand(ks[3], (f, 32), jnp.float32) * 0.1
        out = ops.fused_mlp(x, wg, wu, wd, block_m=bm, block_f=bf)
        exp = ref.mlp(x, wg, wu, wd)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(exp), atol=5e-4, rtol=5e-4
        )

    def test_leading_dims(self):
        ks = jax.random.split(jax.random.key(2), 4)
        x = _rand(ks[0], (2, 8, 32), jnp.float32)
        wg = _rand(ks[1], (32, 64), jnp.float32) * 0.1
        wu = _rand(ks[2], (32, 64), jnp.float32) * 0.1
        wd = _rand(ks[3], (64, 32), jnp.float32) * 0.1
        out = ops.fused_mlp(x, wg, wu, wd, block_m=8, block_f=32)
        assert out.shape == x.shape
        exp = ref.mlp(x.reshape(16, 32), wg, wu, wd).reshape(2, 8, 32)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(exp), atol=5e-4, rtol=5e-4
        )


# ---------------------------------------------------------------------------
# Mamba-2 SSD
# ---------------------------------------------------------------------------


class TestMamba2Ssd:
    def _inputs(self, key, b=2, l=32, h=4, p=8, n=8):
        ks = jax.random.split(key, 5)
        x = _rand(ks[0], (b, l, h, p), jnp.float32)
        dt = jax.nn.softplus(_rand(ks[1], (b, l, h), jnp.float32))
        a = -jnp.exp(_rand(ks[2], (h,), jnp.float32) * 0.3)
        bm = _rand(ks[3], (b, l, n), jnp.float32) * 0.5
        cm = _rand(ks[4], (b, l, n), jnp.float32) * 0.5
        return x, dt, a, bm, cm

    @pytest.mark.parametrize("chunk", [4, 8, 16, 32])
    def test_chunk_sizes_vs_sequential(self, chunk):
        x, dt, a, bm, cm = self._inputs(jax.random.key(0))
        y, sf = ops.mamba2_ssd(x, dt, a, bm, cm, chunk=chunk)
        ye, se = ref.ssd(x, dt, a, bm, cm)
        np.testing.assert_allclose(np.asarray(y), np.asarray(ye),
                                   atol=1e-3, rtol=1e-3)
        np.testing.assert_allclose(np.asarray(sf), np.asarray(se),
                                   atol=1e-3, rtol=1e-3)

    def test_chunked_oracle_matches_sequential(self):
        """ref.ssd_chunked (the algorithm the kernel implements) must be
        exactly equivalent to the sequential recurrence."""
        x, dt, a, bm, cm = self._inputs(jax.random.key(1))
        y1, s1 = ref.ssd_chunked(x, dt, a, bm, cm, chunk=8)
        y2, s2 = ref.ssd(x, dt, a, bm, cm)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   atol=1e-4, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                                   atol=1e-4, rtol=1e-4)

    def test_init_state_carried(self):
        """Splitting a sequence across two kernel calls with state carry
        must equal one full-length call (the decode/prefill contract)."""
        x, dt, a, bm, cm = self._inputs(jax.random.key(2), l=32)
        y_full, s_full = ops.mamba2_ssd(x, dt, a, bm, cm, chunk=8)
        y1, s1 = ops.mamba2_ssd(
            x[:, :16], dt[:, :16], a, bm[:, :16], cm[:, :16], chunk=8
        )
        y2, s2 = ops.mamba2_ssd(
            x[:, 16:], dt[:, 16:], a, bm[:, 16:], cm[:, 16:],
            init_state=s1, chunk=8,
        )
        np.testing.assert_allclose(
            np.asarray(jnp.concatenate([y1, y2], axis=1)),
            np.asarray(y_full), atol=1e-3, rtol=1e-3,
        )
        np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full),
                                   atol=1e-3, rtol=1e-3)

    def test_decode_step_matches_scan(self):
        """O(1) recurrent decode step == one step of the full scan."""
        x, dt, a, bm, cm = self._inputs(jax.random.key(3), l=8)
        _, state = ref.ssd(x[:, :7], dt[:, :7], a, bm[:, :7], cm[:, :7])
        y_step, s_step = ref.ssd_decode_step(
            state, x[:, 7], dt[:, 7], a, bm[:, 7], cm[:, 7]
        )
        y_full, s_full = ref.ssd(x, dt, a, bm, cm)
        np.testing.assert_allclose(np.asarray(y_step), np.asarray(y_full[:, 7]),
                                   atol=1e-4, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(s_step), np.asarray(s_full),
                                   atol=1e-4, rtol=1e-4)

    @given(st.integers(1, 4), st.integers(1, 3))
    @settings(max_examples=10, deadline=None)
    def test_property_random_shapes(self, b, h):
        x, dt, a, bm, cm = self._inputs(jax.random.key(4), b=b, l=16, h=h)
        y, sf = ops.mamba2_ssd(x, dt, a, bm, cm, chunk=8)
        ye, se = ref.ssd(x, dt, a, bm, cm)
        np.testing.assert_allclose(np.asarray(y), np.asarray(ye),
                                   atol=1e-3, rtol=1e-3)
