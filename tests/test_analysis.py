"""Paper Algorithms 1 & 2 (kernel classification) — unit + property tests."""
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep: property tests skip, unit tests run
    from _hypothesis_fallback import given, settings, st

from repro.core.analysis import (
    KernelClass,
    classify_iterators,
    classify_kernel,
    detect_sliding_window,
    window_geometry,
)
from repro.core.ir import (
    AffineExpr,
    AffineMap,
    GenericOp,
    IteratorType,
    PayloadKind,
    make_conv2d_op,
    make_elementwise_op,
    make_matmul_op,
    make_pool2d_op,
)

P, R = IteratorType.PARALLEL, IteratorType.REDUCTION


class TestAlgorithm1:
    """Sliding-window detection: E = s·i_p + δ·i_r."""

    def test_conv_detected(self):
        op = make_conv2d_op("c", "x", "w", "y", n=1, h_out=8, w_out=8,
                            c_out=4, kh=3, kw=3, c_in=2)
        info = detect_sliding_window(op)
        assert info.is_sliding_window
        assert info.stride == 1 and info.dilation == 1

    @pytest.mark.parametrize("stride,dilation", [(1, 1), (2, 1), (1, 2), (2, 3)])
    def test_stride_dilation_extracted(self, stride, dilation):
        op = make_conv2d_op("c", "x", "w", "y", n=1, h_out=4, w_out=4,
                            c_out=2, kh=3, kw=3, c_in=2,
                            stride=stride, dilation=dilation)
        info = detect_sliding_window(op)
        assert (info.is_sliding_window, info.stride, info.dilation) == (
            True, stride, dilation)

    def test_matmul_not_sliding(self):
        op = make_matmul_op("m", "a", "b", "c", m=4, k=4, n_out=4)
        assert not detect_sliding_window(op).is_sliding_window

    def test_elementwise_not_sliding(self):
        op = make_elementwise_op("e", ["a"], "b", (4, 4), PayloadKind.RELU)
        assert not detect_sliding_window(op).is_sliding_window

    def test_pool_detected(self):
        op = make_pool2d_op("p", "x", "y", n=1, h_out=4, w_out=4, c=2,
                            kh=2, kw=2, stride=2)
        info = detect_sliding_window(op)
        assert info.is_sliding_window and info.stride == 2

    def test_all_parallel_early_exit(self):
        """Line 1 of Alg. 1: pure-parallel ops return immediately even if
        an input map had a composite expression."""
        m = AffineMap.of(2, [AffineExpr.dim(0) + AffineExpr.dim(1)])
        op = GenericOp(
            name="odd", inputs=("a",), output="b",
            indexing_maps=(m, AffineMap.of(2, [AffineExpr.dim(0),
                                               AffineExpr.dim(1)])),
            iterator_types=(P, P), dim_sizes=(4, 4),
        )
        assert not detect_sliding_window(op).is_sliding_window

    def test_two_reduction_terms_not_sliding(self):
        """i_r1 + i_r2 (no parallel term) must not match."""
        imap = AffineMap.of(3, [AffineExpr.dim(1) + AffineExpr.dim(2)])
        omap = AffineMap.of(3, [AffineExpr.dim(0)])
        op = GenericOp(
            name="rr", inputs=("a",), output="b",
            indexing_maps=(imap, omap),
            iterator_types=(P, R, R), dim_sizes=(4, 2, 2),
        )
        assert not detect_sliding_window(op).is_sliding_window


class TestAlgorithm2:
    def test_conv_classes(self):
        op = make_conv2d_op("c", "x", "w", "y", n=1, h_out=8, w_out=8,
                            c_out=4, kh=3, kw=3, c_in=2)
        cls = classify_iterators(op)
        # parallel single-dim input subscripts: n (d0), c_out (d3)
        assert set(cls.parallel) == {0, 3}
        # reduction single-dim subscripts: r (d4), s (d5), c_in (d6)
        assert set(cls.reduction) == {4, 5, 6}
        # composite exprs: the two sliding spatial subscripts
        assert len(cls.original_input) == 2
        # window dims: output parallel dims not already in P: h (d1), w (d2)
        assert set(cls.window) == {1, 2}

    def test_matmul_classes(self):
        op = make_matmul_op("m", "a", "b", "c", m=4, k=8, n_out=2)
        cls = classify_iterators(op)
        assert set(cls.parallel) == {0, 1}
        assert set(cls.reduction) == {2}
        assert cls.original_input == () and cls.window == ()

    def test_elementwise_classes(self):
        op = make_elementwise_op("e", ["a", "b"], "c", (4, 4), PayloadKind.ADD)
        cls = classify_iterators(op)
        assert set(cls.parallel) == {0, 1}
        assert cls.reduction == () and cls.window == ()


class TestClassification:
    def test_three_way(self):
        conv = make_conv2d_op("c", "x", "w", "y", n=1, h_out=8, w_out=8,
                              c_out=4, kh=3, kw=3, c_in=2)
        mm = make_matmul_op("m", "a", "b", "c", m=4, k=8, n_out=2)
        ew = make_elementwise_op("e", ["a"], "b", (4,), PayloadKind.RELU)
        assert classify_kernel(conv).kernel_class == KernelClass.SLIDING_WINDOW
        assert classify_kernel(mm).kernel_class == KernelClass.REGULAR_REDUCTION
        assert classify_kernel(ew).kernel_class == KernelClass.PURE_PARALLEL

    def test_window_geometry_conv(self):
        op = make_conv2d_op("c", "x", "w", "y", n=1, h_out=32, w_out=32,
                            c_out=4, kh=3, kw=3, c_in=2)
        geo = window_geometry(op)
        assert geo.window_dims == (1, 2)
        assert geo.window_extents == (3, 3)
        # input extent: s*(P-1) + δ*(R-1) + 1 = 31 + 2 + 1 = 34 (padded frame)
        assert geo.input_extents == (34, 34)

    def test_window_geometry_rejects_non_sliding(self):
        mm = make_matmul_op("m", "a", "b", "c", m=4, k=8, n_out=2)
        with pytest.raises(ValueError):
            window_geometry(mm)


# ---------------------------------------------------------------------------
# property tests: classification is total, deterministic, and O(|maps|)
# ---------------------------------------------------------------------------

@st.composite
def generic_ops(draw):
    n_dims = draw(st.integers(1, 5))
    its = draw(
        st.lists(st.sampled_from([P, R]), min_size=n_dims, max_size=n_dims)
    )
    dim_sizes = tuple(
        draw(st.lists(st.integers(1, 8), min_size=n_dims, max_size=n_dims))
    )

    def expr():
        kind = draw(st.integers(0, 2))
        d0 = draw(st.integers(0, n_dims - 1))
        if kind == 0:
            return AffineExpr.dim(d0)
        if kind == 1:
            return AffineExpr.dim(d0, draw(st.integers(1, 3)))
        d1 = draw(st.integers(0, n_dims - 1))
        if d1 == d0:
            d1 = (d0 + 1) % n_dims
        if n_dims == 1:
            return AffineExpr.dim(d0)
        return AffineExpr.dim(d0, draw(st.integers(1, 3))) + AffineExpr.dim(
            d1, draw(st.integers(1, 3))
        )

    n_in = draw(st.integers(1, 3))
    n_res = draw(st.integers(1, 3))
    maps = tuple(
        AffineMap.of(n_dims, [expr() for _ in range(n_res)])
        for _ in range(n_in + 1)
    )
    return GenericOp(
        name="rand", inputs=tuple(f"i{j}" for j in range(n_in)), output="o",
        indexing_maps=maps, iterator_types=tuple(its), dim_sizes=dim_sizes,
    )


class TestProperties:
    @given(generic_ops())
    @settings(max_examples=200, deadline=None)
    def test_classification_total_and_consistent(self, op):
        info = classify_kernel(op)
        sw = detect_sliding_window(op)
        # invariant 1: sliding-window implies a reduction iterator exists
        if sw.is_sliding_window:
            assert any(t == R for t in op.iterator_types)
            assert sw.stride > 0 and sw.dilation > 0
            assert info.kernel_class == KernelClass.SLIDING_WINDOW
        # invariant 2: no reduction iterators → pure parallel
        if all(t == P for t in op.iterator_types):
            assert info.kernel_class == KernelClass.PURE_PARALLEL
        # invariant 3: the four sets partition cleanly
        cls = info.classes
        assert set(cls.parallel).isdisjoint(cls.reduction)
        for d in cls.parallel:
            assert op.is_parallel_dim(d)
        for d in cls.reduction:
            assert op.is_reduction_dim(d)
        for d in cls.window:
            assert op.is_parallel_dim(d) and d not in cls.parallel

    @given(generic_ops())
    @settings(max_examples=100, deadline=None)
    def test_deterministic(self, op):
        assert classify_kernel(op) == classify_kernel(op)
