"""AdamW optimizer: convergence, clipping, schedule, moment quantization."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import adamw


def _quadratic_target():
    target = jnp.asarray([1.0, -2.0, 3.0])

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    return {"w": jnp.zeros(3)}, loss, target


class TestAdamW:
    def test_converges_on_quadratic(self):
        params, loss, target = _quadratic_target()
        cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=5,
                                total_steps=300, grad_clip=100.0)
        state = adamw.init(params, cfg)
        for _ in range(300):
            g = jax.grad(loss)(params)
            params, state, _ = adamw.apply(params, g, state, cfg)
        np.testing.assert_allclose(np.asarray(params["w"]),
                                   np.asarray(target), atol=1e-2)

    def test_grad_clip_bounds_update(self):
        params = {"w": jnp.zeros(4)}
        cfg = adamw.AdamWConfig(lr=1.0, grad_clip=1.0, warmup_steps=0,
                                total_steps=10, weight_decay=0.0)
        state = adamw.init(params, cfg)
        huge = {"w": jnp.full(4, 1e9)}
        _, _, metrics = adamw.apply(params, huge, state, cfg)
        assert float(metrics["grad_norm"]) == pytest.approx(2e9, rel=1e-3)
        # after clipping, the effective grad norm is 1.0 → m is bounded

    def test_schedule_warmup_and_decay(self):
        cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=100, total_steps=1000,
                                min_lr_frac=0.1)
        lr0 = float(adamw.lr_schedule(cfg, jnp.asarray(0)))
        lr_half_warm = float(adamw.lr_schedule(cfg, jnp.asarray(50)))
        lr_peak = float(adamw.lr_schedule(cfg, jnp.asarray(100)))
        lr_end = float(adamw.lr_schedule(cfg, jnp.asarray(1000)))
        assert lr0 == 0.0
        assert lr_half_warm == pytest.approx(5e-4)
        assert lr_peak == pytest.approx(1e-3)
        assert lr_end == pytest.approx(1e-4, rel=1e-2)

    def test_weight_decay_shrinks(self):
        params = {"w": jnp.full(4, 10.0)}
        cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.1, warmup_steps=0,
                                total_steps=10)
        state = adamw.init(params, cfg)
        zero_g = {"w": jnp.zeros(4)}
        new_p, _, _ = adamw.apply(params, zero_g, state, cfg)
        assert float(new_p["w"][0]) < 10.0

    def test_quantized_moments_track_fp32(self):
        params, loss, target = _quadratic_target()
        runs = {}
        for quant in (False, True):
            p = dict(params)
            cfg = adamw.AdamWConfig(lr=0.05, weight_decay=0.0, warmup_steps=0,
                                    total_steps=200, quantize_moments=quant)
            state = adamw.init(p, cfg)
            for _ in range(200):
                g = jax.grad(loss)(p)
                p, state, _ = adamw.apply(p, g, state, cfg)
            runs[quant] = np.asarray(p["w"])
        # int8 nu is a lossy estimate but must land in the same basin
        np.testing.assert_allclose(runs[True], runs[False], atol=0.15)

    def test_step_counter(self):
        params = {"w": jnp.zeros(2)}
        cfg = adamw.AdamWConfig()
        state = adamw.init(params, cfg)
        for i in range(3):
            params, state, _ = adamw.apply(params, {"w": jnp.ones(2)}, state,
                                           cfg)
        assert int(state.step) == 3
