"""Vitis C++ emission: every pragma family the paper highlights appears,
structure is well-formed, and the DSE results parameterize it."""
import re

import pytest

from repro.core import cnn_graphs
from repro.core.dse import solve_ilp
from repro.core.emit_hls import emit_cpp
from repro.core.streaming import plan_streams


@pytest.fixture(scope="module")
def conv_cpp():
    plan = plan_streams(cnn_graphs.conv_relu(32))
    dse = solve_ilp(plan)
    return emit_cpp(plan, dse), plan, dse


class TestPragmas:
    def test_dataflow_region(self, conv_cpp):
        cpp, _, _ = conv_cpp
        assert "#pragma HLS DATAFLOW" in cpp

    def test_stream_decls_with_depth(self, conv_cpp):
        cpp, plan, _ = conv_cpp
        for s in plan.streams.values():
            if s.producer and s.consumer:
                assert f"#pragma HLS STREAM variable={s.name} depth={s.depth}" in cpp

    def test_pipeline_ii_1(self, conv_cpp):
        cpp, _, _ = conv_cpp
        assert "#pragma HLS PIPELINE II=1" in cpp

    def test_unroll_factors_from_dse(self, conv_cpp):
        cpp, _, dse = conv_cpp
        factors = [u for u in dse.unrolls.values() if u > 1]
        if factors:
            assert re.search(r"#pragma HLS UNROLL factor=\d+", cpp)

    def test_line_buffer_bound_to_bram(self, conv_cpp):
        cpp, _, _ = conv_cpp
        assert "BIND_STORAGE variable=line_buf" in cpp
        assert "impl=bram" in cpp

    def test_array_partition(self, conv_cpp):
        cpp, _, _ = conv_cpp
        assert "#pragma HLS ARRAY_PARTITION" in cpp


class TestStructure:
    def test_one_function_per_node(self, conv_cpp):
        cpp, plan, _ = conv_cpp
        for node in plan.node_order():
            assert f"void {node.op.name}(" in cpp

    def test_top_function_calls_all_nodes(self, conv_cpp):
        cpp, plan, _ = conv_cpp
        top = cpp[cpp.rindex("#pragma HLS DATAFLOW"):]
        for node in plan.node_order():
            assert f"{node.op.name}(" in top

    def test_braces_balanced(self, conv_cpp):
        cpp, _, _ = conv_cpp
        assert cpp.count("{") == cpp.count("}")

    def test_int8_types(self, conv_cpp):
        cpp, _, _ = conv_cpp
        assert "typedef ap_int<8> elem_t;" in cpp
        assert "typedef ap_int<32> accum_t;" in cpp


@pytest.mark.parametrize("name", list(cnn_graphs.PAPER_SUITE))
def test_whole_suite_emits(name):
    plan = plan_streams(cnn_graphs.PAPER_SUITE[name]())
    dse = solve_ilp(plan)
    cpp = emit_cpp(plan, dse)
    assert cpp.count("{") == cpp.count("}")
    assert "#pragma HLS DATAFLOW" in cpp
