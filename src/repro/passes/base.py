"""Pass infrastructure: MLIR's PassManager, minus MLIR.

A :class:`Pass` is a named, statistics-reporting rewrite over a
:class:`~repro.core.ir.DFG`.  The :class:`PassManager` clones the input
graph (callers keep the original for before/after comparison), runs the
pipeline in order, verifies the graph after every pass, and collects the
per-pass statistics the MLIR ``-pass-statistics`` flag would print.

Every future rewrite lands as a Pass: implement ``run_on(dfg) -> dict``
(mutate in place, return {stat: count}) and append it to a pipeline.
"""
from __future__ import annotations

import abc
import time
from dataclasses import dataclass, field

import repro.instrument as instrument
from repro.core.ir import DFG

from .verifier import VerificationError, verify_dfg


class Pass(abc.ABC):
    """One rewrite.  ``name`` identifies it in reports and errors."""

    name: str = "pass"

    @abc.abstractmethod
    def run_on(self, dfg: DFG) -> dict[str, int]:
        """Mutate ``dfg`` in place; return statistics (counts of what the
        pass did).  An all-zero dict means the pass made no change."""

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"<{type(self).__name__} {self.name!r}>"


@dataclass(frozen=True)
class PassStats:
    """Outcome of one pass application.

    ``wall_ms`` is the pass's measured wall time (the ``-mlir-timing``
    datum); it rides along in telemetry/provenance but never enters any
    schedule, emission, or BENCH metric — outputs stay deterministic.
    """

    name: str
    changed: bool
    stats: dict[str, int]
    wall_ms: float = 0.0


@dataclass
class PipelineResult:
    """The rewritten graph plus the statistics trail."""

    dfg: DFG
    passes: list[PassStats] = field(default_factory=list)

    @property
    def changed(self) -> bool:
        return any(p.changed for p in self.passes)

    def stat(self, key: str) -> int:
        """Sum one statistic across every pass that reported it."""
        return sum(p.stats.get(key, 0) for p in self.passes)

    def report(self) -> str:
        """MLIR ``-pass-statistics``-style summary."""
        lines = [f"pass pipeline on {self.dfg.name}:"]
        for p in self.passes:
            stats = ", ".join(f"{k}={v}" for k, v in sorted(p.stats.items()) if v)
            lines.append(f"  {p.name:<28} {stats or '(no change)'}")
        return "\n".join(lines)


class PassManager:
    """Runs a pipeline of passes with inter-pass verification.

    ``verify=True`` (default) runs the structural verifier after every
    pass and re-raises :class:`VerificationError` naming the pass that
    broke the graph — the MLIR contract that makes rewrites composable.
    """

    def __init__(self, passes: list[Pass], *, verify: bool = True) -> None:
        self.passes = list(passes)
        self.verify = verify

    def run(self, dfg: DFG, *, clone: bool = True) -> PipelineResult:
        tracer = instrument.current()
        g = dfg.clone() if clone else dfg
        if self.verify:
            verify_dfg(g)  # reject malformed inputs before rewriting
        result = PipelineResult(dfg=g)
        snap = instrument.snapshot_dfg(g) if tracer.enabled else None
        with tracer.span(f"pipeline:{g.name}", cat="passes") as pipe_args:
            for p in self.passes:
                with tracer.span(f"pass:{p.name}", cat="passes") as sargs:
                    t0 = time.perf_counter()
                    stats = p.run_on(g) or {}
                    wall_ms = (time.perf_counter() - t0) * 1e3
                    sargs.update(stats)
                if self.verify:
                    with tracer.span(f"verify:{p.name}", cat="passes"):
                        try:
                            verify_dfg(g)
                        except VerificationError as e:
                            raise VerificationError(
                                f"pass {p.name!r} produced a malformed "
                                f"DFG: {e}"
                            ) from e
                result.passes.append(PassStats(
                    p.name, any(v for v in stats.values()), dict(stats),
                    wall_ms=wall_ms,
                ))
                if tracer.enabled:
                    # -print-ir-after-all: structural diff per pass, the
                    # full textual IR only on request (ir_snapshots)
                    after = instrument.snapshot_dfg(g)
                    args: dict = {
                        "diff": instrument.diff_snapshots(snap, after)
                    }
                    if tracer.ir_snapshots:
                        args["ir"] = instrument.format_dfg(g)
                    tracer.instant(f"ir_after:{p.name}", cat="passes",
                                   args=args)
                    snap = after
            pipe_args["passes"] = len(self.passes)
        return result
