"""Common-subexpression elimination across branches.

Two nodes compute the same value when they agree on *everything but
their names*: operand ids (inputs in order), indexing maps, iterator
types, dim sizes, payload, element width, and epilogue chain.  All of
those are hashable by construction (frozen dataclasses / tuples), so the
pass is a single dictionary sweep in topological order: the first node
with a given key is kept, later duplicates are removed and their uses
rewired to the keeper's output.

Sweeps repeat to a fixpoint so chains of duplicates collapse (deduping
two convs makes their downstream ReLUs textually identical, which the
next sweep catches).  A duplicate whose output is a graph output is left
alone — rewiring it would alias two external buffers to one value.

Semantics are verified bit-exactly through ``repro.passes.interp``
(tests/test_passes.py): the deduped graph must compute what the original
did.
"""
from __future__ import annotations

from repro.core.ir import DFG, GenericOp

from .base import Pass


def _node_key(node: GenericOp):
    """Everything that determines the node's value, minus its identity."""
    return (
        node.inputs,
        node.indexing_maps,
        node.iterator_types,
        node.dim_sizes,
        node.payload,
        node.elem_bits,
        node.epilogue,
    )


class CommonSubexprElimination(Pass):
    name = "cse"

    def run_on(self, dfg: DFG) -> dict[str, int]:
        removed = 0
        changed = True
        while changed:
            changed = False
            seen: dict[tuple, GenericOp] = {}
            for node in dfg.topo_order():
                key = _node_key(node)
                keep = seen.get(key)
                if keep is None:
                    seen[key] = node
                    continue
                if node.output in dfg.graph_outputs:
                    continue
                dfg.remove_node(node.name)
                dfg.replace_value_uses(node.output, keep.output)
                if node.output not in dfg.referenced_values():
                    del dfg.values[node.output]
                removed += 1
                changed = True
        return {"subexprs_eliminated": removed}
