"""Operator fusion: fold elementwise consumers into producer payloads.

Two passes share one legality core:

* :class:`ElementwiseChainFusion` — chains of pure-parallel elementwise
  ops (ReLU / add / mul / …) collapse into a single node carrying the
  rest of the chain as :class:`~repro.core.ir.FusedEpilogue` entries.
* :class:`ConvActivationFusion` — a trailing activation (or constant
  bias/scale) folds into the MAC node (conv / matmul) that feeds it, the
  classic epilogue fusion.

Either way the fused consumer's process function and its FIFO disappear
from the streaming plan: one fewer dataflow node, one fewer stream edge,
one fewer BRAM-bound FIFO — the footprint reduction the pass pipeline
exists to deliver.

Legality (checked per candidate pair producer P → consumer C):

  F1. C is pure-parallel with identity indexing maps (true elementwise);
  F2. C reads exactly one non-constant value, exactly once: P's output;
  F3. P's output has no other consumer and is not a graph output;
  F4. C's iteration space equals the shape of P's output value;
  F5. C's payload is a supported epilogue kind (unary: relu,
      squared_relu, identity, exp; binary with a constant operand:
      add, mul, max).
"""
from __future__ import annotations

from repro.core.analysis import KernelClass, classify_kernel
from repro.core.ir import DFG, FusedEpilogue, GenericOp, PayloadKind

from .base import Pass

FUSIBLE_UNARY = {
    PayloadKind.RELU,
    PayloadKind.SQUARED_RELU,
    PayloadKind.IDENTITY,
    PayloadKind.EXP,
}
FUSIBLE_BINARY = {PayloadKind.ADD, PayloadKind.MUL, PayloadKind.MAX}


def _epilogue_operand(dfg: DFG, op: GenericOp) -> tuple[bool, str | None]:
    """(is_fusible_payload, constant_operand_name) for consumer ``op``."""
    const_inputs = [i for i in op.inputs if dfg.values[i].is_constant]
    stream_inputs = [i for i in op.inputs if not dfg.values[i].is_constant]
    if op.payload in FUSIBLE_UNARY:
        return (len(stream_inputs) == 1 and not const_inputs, None)
    if op.payload in FUSIBLE_BINARY:
        if len(stream_inputs) == 1 and len(const_inputs) == 1:
            return True, const_inputs[0]
    return False, None


def _identity_or_broadcast_const(dfg: DFG, op: GenericOp) -> bool:
    """F1's map condition: the output map and every *streamed* operand
    map must be the identity; a constant operand may instead broadcast
    along the last axis (a single ``d_{n-1}`` result — the per-channel
    bias of ``make_broadcast_binary_op``).  The flat output index is
    channel-fastest, so the epilogue reads such an operand at
    ``o % len`` — still one element per output point."""
    if not op.output_map.is_identity():
        return False
    for i, name in enumerate(op.inputs):
        m = op.input_maps[i]
        if m.is_identity():
            continue
        if not dfg.values[name].is_constant:
            return False
        if len(m.results) != 1:
            return False
        e = m.results[0]
        if not (e.is_single_dim() and e.terms[0] == (op.n_dims - 1, 1)):
            return False
    return True


def can_fuse(dfg: DFG, producer: GenericOp, consumer: GenericOp) -> bool:
    """All of F1-F5, for ``producer → consumer``."""
    info = classify_kernel(consumer)
    if info.kernel_class != KernelClass.PURE_PARALLEL:          # F1
        return False
    if not _identity_or_broadcast_const(dfg, consumer):          # F1
        return False
    out = producer.output
    if consumer.inputs.count(out) != 1:                          # F2
        return False
    stream_inputs = [i for i in consumer.inputs if not dfg.values[i].is_constant]
    if stream_inputs != [out]:                                   # F2
        return False
    if out in dfg.graph_outputs or len(dfg.consumers_of(out)) != 1:  # F3
        return False
    if consumer.dim_sizes != dfg.values[out].shape:              # F4
        return False
    fusible, _ = _epilogue_operand(dfg, consumer)                # F5
    return fusible


def fuse(dfg: DFG, producer: GenericOp, consumer: GenericOp) -> None:
    """Fold ``consumer`` into ``producer.epilogue`` (caller checked
    :func:`can_fuse`).  The intermediate value disappears."""
    _, operand = _epilogue_operand(dfg, consumer)
    old_out = producer.output
    dfg.remove_node(consumer.name)
    producer.epilogue = producer.epilogue + (
        FusedEpilogue(consumer.payload, operand),
    ) + consumer.epilogue
    producer.output = consumer.output
    if old_out not in dfg.referenced_values():
        del dfg.values[old_out]


class _FusionBase(Pass):
    """Fixpoint driver; subclasses pick which producers qualify."""

    def producer_ok(self, dfg: DFG, producer: GenericOp) -> bool:
        raise NotImplementedError

    def run_on(self, dfg: DFG) -> dict[str, int]:
        fused = 0
        changed = True
        while changed:
            changed = False
            for consumer in list(dfg.nodes):
                # locate the single stream producer, if any
                producers = [
                    p for i in consumer.inputs
                    if not dfg.values[i].is_constant
                    and (p := dfg.producer_of(i)) is not None
                ]
                if len(producers) != 1:
                    continue
                producer = producers[0]
                if not self.producer_ok(dfg, producer):
                    continue
                if can_fuse(dfg, producer, consumer):
                    fuse(dfg, producer, consumer)
                    fused += 1
                    changed = True
        return {"ops_fused": fused, "streams_eliminated": fused}


class ElementwiseChainFusion(_FusionBase):
    """ReLU/add/mul chains collapse into their elementwise producer."""

    name = "elementwise-chain-fusion"

    def producer_ok(self, dfg: DFG, producer: GenericOp) -> bool:
        return classify_kernel(producer).kernel_class == KernelClass.PURE_PARALLEL


class ConvActivationFusion(_FusionBase):
    """Trailing activation folds into the MAC node (conv / matmul)."""

    name = "conv-activation-fusion"

    def producer_ok(self, dfg: DFG, producer: GenericOp) -> bool:
        if producer.payload != PayloadKind.MAC:
            return False
        return classify_kernel(producer).kernel_class in (
            KernelClass.SLIDING_WINDOW,
            KernelClass.REGULAR_REDUCTION,
        )


# ---------------------------------------------------------------------------
# conv + pool fusion: a non-overlapping pool consumer folds into the
# producing conv's epilogue as a windowed FusedEpilogue
# ---------------------------------------------------------------------------


def pool_window_factors(dfg: DFG, pool: GenericOp) -> tuple[int, ...] | None:
    """Per-output-axis pool factors for a *fusible* pool op, else None.

    Legality (beyond what :func:`can_fuse_pool` checks on the producer
    side): the op is a single-input sliding-window MAX or AVG reduction
    whose stride equals every window extent (non-overlapping — "stride
    aligned"), and whose input extents divide exactly.
    """
    if pool.payload not in (PayloadKind.MAX, PayloadKind.AVG):
        return None
    if len(pool.inputs) != 1:
        return None
    info = classify_kernel(pool)
    if info.kernel_class != KernelClass.SLIDING_WINDOW:
        return None
    out_results = list(pool.output_map.results)
    if not all(e.is_single_dim() for e in out_results):
        return None
    axis_of = {e.terms[0][0]: i for i, e in enumerate(out_results)}
    factors = [1] * len(out_results)
    for expr in info.classes.original_input:
        par = red = None
        for d, c in expr.terms:
            if pool.is_parallel_dim(d):
                par = (d, c)
            else:
                red = (d, c)
        if par is None or red is None:
            return None
        (pd, stride), (rd, dil) = par, red
        k = pool.dim_extent(rd)
        if dil != 1 or stride != k or pd not in axis_of:   # overlapping
            return None
        factors[axis_of[pd]] = k
    if all(f == 1 for f in factors):
        return None
    return tuple(factors)


def can_fuse_pool(dfg: DFG, producer: GenericOp, pool: GenericOp) -> bool:
    """Legality for ``producer → pool`` window fusion: the producer is a
    MAC sliding-window node (conv) whose output feeds *only* this
    stride-aligned pool, and the pooled axes divide exactly."""
    if producer.payload != PayloadKind.MAC:
        return False
    if classify_kernel(producer).kernel_class != KernelClass.SLIDING_WINDOW:
        return False
    out = producer.output
    if pool.inputs != (out,):
        return False
    if out in dfg.graph_outputs or len(dfg.consumers_of(out)) != 1:
        return False
    factors = pool_window_factors(dfg, pool)
    if factors is None:
        return False
    shape = dfg.values[out].shape
    if len(shape) != len(factors):
        return False
    return all(s % f == 0 for s, f in zip(shape, factors))


def fuse_pool(dfg: DFG, producer: GenericOp, pool: GenericOp) -> None:
    """Fold ``pool`` into ``producer.epilogue`` as a windowed entry
    (caller checked :func:`can_fuse_pool`)."""
    factors = pool_window_factors(dfg, pool)
    assert factors is not None
    old_out = producer.output
    dfg.remove_node(pool.name)
    producer.epilogue = producer.epilogue + (
        FusedEpilogue(pool.payload, None, window=factors),
    ) + pool.epilogue
    producer.output = pool.output
    if old_out not in dfg.referenced_values():
        del dfg.values[old_out]


class ConvPoolFusion(Pass):
    """A 2×2 (or any non-overlapping) max or average pool folds into the
    producing conv's epilogue: one fewer process, one fewer BRAM-bound
    FIFO, and the group's output stream shrinks by the pool factor.
    Average pools additionally carry the DIV exit path (one divide per
    pooled output point, charged by the resource model)."""

    name = "conv-pool-fusion"

    def run_on(self, dfg: DFG) -> dict[str, int]:
        fused = 0
        changed = True
        while changed:
            changed = False
            for pool in list(dfg.nodes):
                if pool_window_factors(dfg, pool) is None:
                    continue
                producer = dfg.producer_of(pool.inputs[0])
                if producer is None:
                    continue
                if can_fuse_pool(dfg, producer, pool):
                    fuse_pool(dfg, producer, pool)
                    fused += 1
                    changed = True
        return {"pools_fused": fused, "streams_eliminated": fused}
