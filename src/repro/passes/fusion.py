"""Operator fusion: fold elementwise consumers into producer payloads.

Two passes share one legality core:

* :class:`ElementwiseChainFusion` — chains of pure-parallel elementwise
  ops (ReLU / add / mul / …) collapse into a single node carrying the
  rest of the chain as :class:`~repro.core.ir.FusedEpilogue` entries.
* :class:`ConvActivationFusion` — a trailing activation (or constant
  bias/scale) folds into the MAC node (conv / matmul) that feeds it, the
  classic epilogue fusion.

Either way the fused consumer's process function and its FIFO disappear
from the streaming plan: one fewer dataflow node, one fewer stream edge,
one fewer BRAM-bound FIFO — the footprint reduction the pass pipeline
exists to deliver.

Legality (checked per candidate pair producer P → consumer C):

  F1. C is pure-parallel with identity indexing maps (true elementwise);
  F2. C reads exactly one non-constant value, exactly once: P's output;
  F3. P's output has no other consumer and is not a graph output;
  F4. C's iteration space equals the shape of P's output value;
  F5. C's payload is a supported epilogue kind (unary: relu,
      squared_relu, identity, exp; binary with a constant operand:
      add, mul, max).
"""
from __future__ import annotations

from repro.core.analysis import KernelClass, classify_kernel
from repro.core.ir import DFG, FusedEpilogue, GenericOp, PayloadKind

from .base import Pass

FUSIBLE_UNARY = {
    PayloadKind.RELU,
    PayloadKind.SQUARED_RELU,
    PayloadKind.IDENTITY,
    PayloadKind.EXP,
}
FUSIBLE_BINARY = {PayloadKind.ADD, PayloadKind.MUL, PayloadKind.MAX}


def _epilogue_operand(dfg: DFG, op: GenericOp) -> tuple[bool, str | None]:
    """(is_fusible_payload, constant_operand_name) for consumer ``op``."""
    const_inputs = [i for i in op.inputs if dfg.values[i].is_constant]
    stream_inputs = [i for i in op.inputs if not dfg.values[i].is_constant]
    if op.payload in FUSIBLE_UNARY:
        return (len(stream_inputs) == 1 and not const_inputs, None)
    if op.payload in FUSIBLE_BINARY:
        if len(stream_inputs) == 1 and len(const_inputs) == 1:
            return True, const_inputs[0]
    return False, None


def can_fuse(dfg: DFG, producer: GenericOp, consumer: GenericOp) -> bool:
    """All of F1-F5, for ``producer → consumer``."""
    info = classify_kernel(consumer)
    if info.kernel_class != KernelClass.PURE_PARALLEL:          # F1
        return False
    if not all(m.is_identity() for m in consumer.indexing_maps):  # F1
        return False
    out = producer.output
    if consumer.inputs.count(out) != 1:                          # F2
        return False
    stream_inputs = [i for i in consumer.inputs if not dfg.values[i].is_constant]
    if stream_inputs != [out]:                                   # F2
        return False
    if out in dfg.graph_outputs or len(dfg.consumers_of(out)) != 1:  # F3
        return False
    if consumer.dim_sizes != dfg.values[out].shape:              # F4
        return False
    fusible, _ = _epilogue_operand(dfg, consumer)                # F5
    return fusible


def fuse(dfg: DFG, producer: GenericOp, consumer: GenericOp) -> None:
    """Fold ``consumer`` into ``producer.epilogue`` (caller checked
    :func:`can_fuse`).  The intermediate value disappears."""
    _, operand = _epilogue_operand(dfg, consumer)
    old_out = producer.output
    dfg.remove_node(consumer.name)
    producer.epilogue = producer.epilogue + (
        FusedEpilogue(consumer.payload, operand),
    ) + consumer.epilogue
    producer.output = consumer.output
    if old_out not in dfg.referenced_values():
        del dfg.values[old_out]


class _FusionBase(Pass):
    """Fixpoint driver; subclasses pick which producers qualify."""

    def producer_ok(self, dfg: DFG, producer: GenericOp) -> bool:
        raise NotImplementedError

    def run_on(self, dfg: DFG) -> dict[str, int]:
        fused = 0
        changed = True
        while changed:
            changed = False
            for consumer in list(dfg.nodes):
                # locate the single stream producer, if any
                producers = [
                    p for i in consumer.inputs
                    if not dfg.values[i].is_constant
                    and (p := dfg.producer_of(i)) is not None
                ]
                if len(producers) != 1:
                    continue
                producer = producers[0]
                if not self.producer_ok(dfg, producer):
                    continue
                if can_fuse(dfg, producer, consumer):
                    fuse(dfg, producer, consumer)
                    fused += 1
                    changed = True
        return {"ops_fused": fused, "streams_eliminated": fused}


class ElementwiseChainFusion(_FusionBase):
    """ReLU/add/mul chains collapse into their elementwise producer."""

    name = "elementwise-chain-fusion"

    def producer_ok(self, dfg: DFG, producer: GenericOp) -> bool:
        return classify_kernel(producer).kernel_class == KernelClass.PURE_PARALLEL


class ConvActivationFusion(_FusionBase):
    """Trailing activation folds into the MAC node (conv / matmul)."""

    name = "conv-activation-fusion"

    def producer_ok(self, dfg: DFG, producer: GenericOp) -> bool:
        if producer.payload != PayloadKind.MAC:
            return False
        return classify_kernel(producer).kernel_class in (
            KernelClass.SLIDING_WINDOW,
            KernelClass.REGULAR_REDUCTION,
        )
