"""Layout canonicalization: NCHW↔NHWC transpose motion and cancellation.

ONNX models arrive in NCHW; the streaming conv/pool kernels (and every
builder graph) are NHWC.  The importer (``repro.frontends.onnx_reader``)
keeps each imported op *faithful* to its ONNX semantics by sandwiching
every layout-sensitive node between explicit transposes::

    x(NCHW) → T(→NHWC) → conv → T(→NCHW) → relu → T(→NHWC) → conv → …

which is correct but buffers a full feature map at every arrow.  This
pass cancels the interior pairs so only the graph-boundary transposes
(the external NCHW contract) survive:

* **compose/cancel** — a transpose fed by a transpose composes into one
  (identity compositions rewire the consumer straight through);
* **sink elementwise** — a unary elementwise op fed by a transpose is
  layout-agnostic: it commutes below the transpose so the transpose can
  meet (and cancel against) the next layer's inverse.  Binary
  elementwise ops (residual adds) sink when *both* operands come off
  transposes with the same permutation;
* **fold into flatten** — a transpose feeding a flatten disappears into
  the flatten's linearization order (the mixed-radix output map absorbs
  the permutation), so NCHW classifier heads cost no reorder buffer.

Every rewrite preserves per-element semantics exactly (the verifier's
V10 invariant is checked after each pass application by PassManager);
``tests/test_layout.py`` pins bit-exactness against the unrewritten
graph on random inputs.  Ops with epilogues are never touched — this
pass runs *before* fusion in the default pipeline, so that never fires
in practice.
"""
from __future__ import annotations

from repro.core.analysis import reorder_spec
from repro.core.ir import (
    DFG,
    GenericOp,
    Value,
    make_flatten_op,
    make_transpose_op,
)

from .base import Pass


def _as_transpose(dfg: DFG, value_name: str) -> tuple[GenericOp, tuple[int, ...]] | None:
    """(producer node, perm) when ``value_name`` is a transpose output."""
    prod = dfg.producer_of(value_name)
    if prod is None or prod.epilogue:
        return None
    spec = reorder_spec(prod)
    if spec is None or spec[0] != "transpose":
        return None
    return prod, spec[1]


def _sole_interior_consumer(dfg: DFG, value_name: str, consumer: GenericOp) -> bool:
    """True when ``consumer`` is the only reader and the value never
    escapes through the graph boundary — the condition for repurposing
    its producer in place."""
    if value_name in dfg.graph_outputs or value_name in dfg.graph_inputs:
        return False
    cons = dfg.consumers_of(value_name)
    if len(cons) != 1 or cons[0] is not consumer:
        return False
    if any(
        any(e.operand == value_name for e in n.epilogue) for n in dfg.nodes
    ):
        return False
    return True


class LayoutCanonicalize(Pass):
    """Cancel interior layout transposes (see module docstring)."""

    name = "layout"

    def run_on(self, dfg: DFG) -> dict[str, int]:
        stats = {
            "transposes_composed": 0,
            "transposes_cancelled": 0,
            "elementwise_sunk": 0,
            "flatten_folds": 0,
        }
        # one rewrite per iteration; every rewrite either removes a
        # node or moves a transpose strictly downward, so a generous
        # size-proportional cap is only a runaway backstop — hitting it
        # would leave interior transposes (full-tensor reorder buffers)
        # behind, so it warns instead of failing silently
        limit = 50 * max(len(dfg.nodes), 1)
        for i in range(limit + 1):
            changed = (
                self._compose_or_cancel(dfg, stats)
                or self._sink_elementwise(dfg, stats)
                or self._fold_into_flatten(dfg, stats)
            )
            if not changed:
                break
            self._drop_dead_reorders(dfg)
        else:  # pragma: no cover - backstop, not a reachable rewrite path
            import warnings

            warnings.warn(
                f"{dfg.name}: layout canonicalization stopped after "
                f"{limit} rewrites without reaching a fixpoint — "
                "interior transposes may remain (full-tensor reorder "
                "buffers)",
                RuntimeWarning,
                stacklevel=2,
            )
        return stats

    @staticmethod
    def _drop_dead_reorders(dfg: DFG) -> None:
        """Remove reorder nodes whose output nothing reads (rewrites
        strand them); full DCE is a separate pass, but leaving a chain
        of dead transposes here would block further composition."""
        changed = True
        while changed:
            changed = False
            for node in list(dfg.nodes):
                if reorder_spec(node) is None:
                    continue
                out = node.output
                if out in dfg.graph_outputs or dfg.consumers_of(out):
                    continue
                if any(
                    any(e.operand == out for e in n.epilogue)
                    for n in dfg.nodes
                ):
                    continue
                dfg.remove_node(node.name)
                if out in dfg.values and out not in dfg.referenced_values():
                    del dfg.values[out]
                changed = True

    # -- rule 1: transpose(transpose(x)) -------------------------------------

    def _compose_or_cancel(self, dfg: DFG, stats: dict[str, int]) -> bool:
        for node in list(dfg.nodes):
            spec = reorder_spec(node)
            if spec is None or spec[0] != "transpose" or node.epilogue:
                continue
            upstream = _as_transpose(dfg, node.inputs[0])
            if upstream is None:
                continue
            t1, p1 = upstream
            p2 = spec[1]
            composed = tuple(p1[i] for i in p2)
            src = t1.inputs[0]
            if composed == tuple(range(len(composed))):
                # a graph-input → graph-output round trip has nothing to
                # rewire into: cancelling would alias the output to the
                # input (and can empty the graph entirely, which the
                # emitter rejects) — same passthrough rule as
                # canonicalize's identity removal
                if (node.output in dfg.graph_outputs
                        and src in dfg.graph_inputs):
                    continue
                # identity round trip: consumers of node.output read the
                # pre-transpose value directly
                out = node.output
                dfg.remove_node(node.name)
                dfg.replace_value_uses(out, src)
                if out in dfg.values and out not in dfg.referenced_values():
                    del dfg.values[out]
                stats["transposes_cancelled"] += 1
            else:
                replacement = make_transpose_op(
                    node.name, src, node.output,
                    in_shape=dfg.values[src].shape, perm=composed,
                    elem_bits=node.elem_bits,
                )
                dfg.nodes[dfg.nodes.index(node)] = replacement
                stats["transposes_composed"] += 1
            return True
        return False

    # -- rule 2/3: elementwise ops commute below transposes ------------------

    def _sink_elementwise(self, dfg: DFG, stats: dict[str, int]) -> bool:
        for node in list(dfg.nodes):
            if node.epilogue or reorder_spec(node) is not None:
                continue
            if not all(m.is_identity() for m in node.indexing_maps):
                continue
            if len(node.inputs) == 1:
                hit = self._sink_unary(dfg, node)
            elif len(node.inputs) == 2:
                hit = self._sink_binary(dfg, node)
            else:
                hit = False
            if hit:
                stats["elementwise_sunk"] += 1
                return True
        return False

    def _retarget(self, dfg: DFG, node: GenericOp, new_inputs: tuple[str, ...],
                  transpose: GenericOp) -> None:
        """Move ``node`` above ``transpose``: the elementwise op now
        computes on the pre-transpose layout into a fresh ``mid`` value,
        and the transpose maps ``mid`` onto the op's original output."""
        src_shape = dfg.values[new_inputs[0]].shape
        mid = f"{node.name}_pre_{transpose.name}"
        if mid in dfg.values:  # paranoid: keep names unique
            i = 0
            while f"{mid}_{i}" in dfg.values:
                i += 1
            mid = f"{mid}_{i}"
        dfg.add_value(Value(mid, src_shape, node.elem_bits))
        old_outs = [transpose.output] + list(node.inputs)
        node.inputs = new_inputs
        node.dim_sizes = src_shape
        out = node.output
        node.output = mid
        transpose.inputs = (mid,)
        transpose.output = out
        for v in old_outs:
            if v in dfg.values and v not in dfg.referenced_values():
                del dfg.values[v]

    def _sink_unary(self, dfg: DFG, node: GenericOp) -> bool:
        upstream = _as_transpose(dfg, node.inputs[0])
        if upstream is None:
            return False
        t, _ = upstream
        if not _sole_interior_consumer(dfg, t.output, node):
            return False
        self._retarget(dfg, node, (t.inputs[0],), t)
        return True

    def _sink_binary(self, dfg: DFG, node: GenericOp) -> bool:
        a, b = node.inputs
        ta = _as_transpose(dfg, a)
        tb = _as_transpose(dfg, b)
        if ta is None or tb is None or ta[1] != tb[1]:
            return False
        (t1, _), (t2, _) = ta, tb
        if t1 is t2:
            # add(t_out, t_out): one transpose feeds both operands
            if not _sole_interior_consumer(dfg, t1.output, node):
                return False
            self._retarget(dfg, node, (t1.inputs[0], t1.inputs[0]), t1)
            return True
        if not (
            _sole_interior_consumer(dfg, t1.output, node)
            and _sole_interior_consumer(dfg, t2.output, node)
        ):
            return False
        self._retarget(dfg, node, (t1.inputs[0], t2.inputs[0]), t1)
        # t2 is now dead: nothing reads its output
        dfg.remove_node(t2.name)
        if t2.output in dfg.values and t2.output not in dfg.referenced_values():
            del dfg.values[t2.output]
        return True

    # -- rule 4: transpose → flatten folds into the linearization ------------

    def _fold_into_flatten(self, dfg: DFG, stats: dict[str, int]) -> bool:
        for node in list(dfg.nodes):
            spec = reorder_spec(node)
            if spec is None or spec[0] != "flatten" or node.epilogue:
                continue
            upstream = _as_transpose(dfg, node.inputs[0])
            if upstream is None:
                continue
            t, perm = upstream
            if perm[0] != 0:
                continue  # batch axis must survive the fold
            if not _sole_interior_consumer(dfg, t.output, node):
                continue
            order = spec[1]
            # flatten axis j of transpose(x, perm) is axis perm[j] of x
            new_order = tuple(perm[j] for j in order)
            src = t.inputs[0]
            replacement = make_flatten_op(
                node.name, src, node.output,
                in_shape=dfg.values[src].shape, order=new_order,
                elem_bits=node.elem_bits,
            )
            dfg.nodes[dfg.nodes.index(node)] = replacement
            if t.output in dfg.values and t.output not in dfg.referenced_values():
                dfg.remove_node(t.name)
                del dfg.values[t.output]
            stats["flatten_folds"] += 1
            return True
        return False
