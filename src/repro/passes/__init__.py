"""MING pass pipeline: verified, statistics-reporting DFG rewrites.

The compiler-infrastructure layer between the frontends
(``repro.core.cnn_graphs``) and the unified compile driver
(``repro.core.compile_driver``, paper Fig. 4 extended):

    cnn_graphs → [canonicalize → dce → cse → fusion → dce] → compile_design
                                                               │
                     ┌─────────────────────────────────────────┘
                     ▼
            whole-graph streaming + ILP
                     │ (over budget resident?)
                     └→ cost-aware layer-group partition
                        (streamed weight tiles priced against
                         overlapped spill boundaries, any slice)
                              │
                              ▼
                     CompiledDesign — consumed by emit_hls.emit_design
                     and kernels/ops.run_compiled alike

``run_default_pipeline`` applies the standard rewrite pipeline;
``partition_layer_groups`` builds the group schedule for graphs whose
whole-graph plan exceeds the FPGA budgets.  See DESIGN.md §1 and §3.
"""
from .base import Pass, PassManager, PassStats, PipelineResult
from .canonicalize import Canonicalize
from .cse import CommonSubexprElimination
from .dce import DeadCodeElimination
from .fusion import (
    ConvActivationFusion,
    ConvPoolFusion,
    ElementwiseChainFusion,
    can_fuse,
    can_fuse_pool,
    fuse,
    fuse_pool,
)
from .layout import LayoutCanonicalize
from .partition import (
    LayerGroup,
    PartitionError,
    PartitionPlan,
    SpillBuffer,
    partition_layer_groups,
)
from .verifier import VerificationError, verify_dfg
from repro.core.resource_model import DRAM_BYTES_PER_CYCLE


#: registered rewrites, keyed by their Pass.name — the vocabulary
#: ``repro.core.CompileOptions.passes`` selects pipelines from
PASS_REGISTRY: dict[str, type[Pass]] = {
    cls.name: cls
    for cls in (
        Canonicalize,
        DeadCodeElimination,
        CommonSubexprElimination,
        LayoutCanonicalize,
        ElementwiseChainFusion,
        ConvActivationFusion,
        ConvPoolFusion,
    )
}


def validate_pass_names(names) -> None:
    """Reject unknown registry names — the one error message both
    ``CompileOptions`` (at construction) and :func:`pipeline_from_names`
    (at instantiation) raise."""
    unknown = [n for n in names if n not in PASS_REGISTRY]
    if unknown:
        raise ValueError(
            f"unknown pass name(s) {unknown} — available: "
            f"{sorted(PASS_REGISTRY)}"
        )


def pipeline_from_names(names) -> list[Pass]:
    """Instantiate a pipeline from registry names, in the given order."""
    validate_pass_names(names)
    return [PASS_REGISTRY[n]() for n in names]


def default_pipeline() -> list[Pass]:
    """Canonicalize, strip dead code, dedup, cancel layout transposes
    (before fusion, so imported NCHW graphs fuse like native ones),
    fuse, clean up, re-canonicalize."""
    return [
        Canonicalize(),
        DeadCodeElimination(),
        CommonSubexprElimination(),
        LayoutCanonicalize(),
        ElementwiseChainFusion(),
        ConvActivationFusion(),
        ConvPoolFusion(),
        DeadCodeElimination(),
        Canonicalize(),
    ]


def run_default_pipeline(dfg, *, verify: bool = True) -> PipelineResult:
    """Clone ``dfg`` and run the default pipeline over the clone."""
    return PassManager(default_pipeline(), verify=verify).run(dfg)


__all__ = [
    "Pass",
    "PassManager",
    "PassStats",
    "PipelineResult",
    "Canonicalize",
    "CommonSubexprElimination",
    "DeadCodeElimination",
    "ElementwiseChainFusion",
    "ConvActivationFusion",
    "ConvPoolFusion",
    "LayoutCanonicalize",
    "can_fuse",
    "can_fuse_pool",
    "fuse",
    "fuse_pool",
    "DRAM_BYTES_PER_CYCLE",
    "PASS_REGISTRY",
    "pipeline_from_names",
    "validate_pass_names",
    "LayerGroup",
    "PartitionError",
    "PartitionPlan",
    "SpillBuffer",
    "partition_layer_groups",
    "VerificationError",
    "verify_dfg",
    "default_pipeline",
    "run_default_pipeline",
]
