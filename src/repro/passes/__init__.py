"""MING pass pipeline: verified, statistics-reporting DFG rewrites.

The compiler-infrastructure layer between the frontends
(``repro.core.cnn_graphs``) and the streaming/DSE/emit backends
(paper Fig. 4, extended):

    cnn_graphs → [canonicalize → dce → fusion → dce] → streaming → dse
                                 │ (whole plan over budget?)
                                 └→ layer-group partition → per-group
                                    streaming+dse → multi-kernel emit

``run_default_pipeline`` applies the standard rewrite pipeline;
``partition_layer_groups`` handles graphs whose whole-graph plan
exceeds the FPGA budgets.  See DESIGN.md §"Pass pipeline".
"""
from .base import Pass, PassManager, PassStats, PipelineResult
from .canonicalize import Canonicalize
from .dce import DeadCodeElimination
from .fusion import (
    ConvActivationFusion,
    ElementwiseChainFusion,
    can_fuse,
    fuse,
)
from .partition import (
    DRAM_BYTES_PER_CYCLE,
    LayerGroup,
    PartitionError,
    PartitionPlan,
    SpillBuffer,
    partition_layer_groups,
)
from .verifier import VerificationError, verify_dfg


def default_pipeline() -> list[Pass]:
    """Canonicalize, strip dead code, fuse, clean up, re-canonicalize."""
    return [
        Canonicalize(),
        DeadCodeElimination(),
        ElementwiseChainFusion(),
        ConvActivationFusion(),
        DeadCodeElimination(),
        Canonicalize(),
    ]


def run_default_pipeline(dfg, *, verify: bool = True) -> PipelineResult:
    """Clone ``dfg`` and run the default pipeline over the clone."""
    return PassManager(default_pipeline(), verify=verify).run(dfg)


__all__ = [
    "Pass",
    "PassManager",
    "PassStats",
    "PipelineResult",
    "Canonicalize",
    "DeadCodeElimination",
    "ElementwiseChainFusion",
    "ConvActivationFusion",
    "can_fuse",
    "fuse",
    "DRAM_BYTES_PER_CYCLE",
    "LayerGroup",
    "PartitionError",
    "PartitionPlan",
    "SpillBuffer",
    "partition_layer_groups",
    "VerificationError",
    "verify_dfg",
    "default_pipeline",
    "run_default_pipeline",
]
