"""Dead-node / dead-value elimination.

A node is dead when its output transitively feeds no graph output; a
value is dead when nothing references it (no node input/output/epilogue
operand, not a graph input/output).  On the FPGA a dead node is a whole
process function plus its FIFOs; eliminating it before the streaming
transform keeps them out of the BRAM/DSP ledger entirely.
"""
from __future__ import annotations

from repro.core.ir import DFG

from .base import Pass


class DeadCodeElimination(Pass):
    name = "dce"

    def run_on(self, dfg: DFG) -> dict[str, int]:
        nodes_removed = 0
        # liveness: fixpoint over "output feeds a live consumer or exit"
        live_values = set(dfg.graph_outputs)
        changed = True
        live_nodes: set[str] = set()
        while changed:
            changed = False
            for n in dfg.nodes:
                if n.name in live_nodes:
                    continue
                if n.output in live_values:
                    live_nodes.add(n.name)
                    live_values.update(n.inputs)
                    changed = True
        for n in [n for n in dfg.nodes if n.name not in live_nodes]:
            dfg.remove_node(n.name)
            nodes_removed += 1

        values_removed = 0
        refs = dfg.referenced_values()
        for v in [v for v in dfg.values if v not in refs]:
            del dfg.values[v]
            values_removed += 1
        return {"nodes_removed": nodes_removed, "values_removed": values_removed}
