"""Resource-aware, cycle-balanced layer-group partitioning.

When :func:`~repro.core.dse.solve_ilp` proves the whole-graph streaming
plan exceeds the BRAM/DSP budgets even at unroll=1, we split the DFG at
stream cut-points into **layer groups**: contiguous topological slices
that each fit the budget on their own.  Groups execute sequentially on
the fabric (separate HLS kernels, one resident at a time); values
crossing a group boundary spill to DRAM buffers that the host-side
schedule allocates and threads between kernel invocations.

Two strategies over the (canonicalized, fused) topological order:

* ``"balanced"`` (default) — exact min-max search: a memoized DP over
  the cut positions that minimizes the *slowest group's* modeled cycles
  subject to per-group feasibility.  Feasibility is monotone in group
  extent (a superset group needs at least its subset's resources), so
  each start position probes forward only until the first infeasible
  end — PR 1's suffix-bound fast infeasibility keeps every probe cheap.
* ``"greedy"`` — the PR 1 prefix cut (grow until the budget breaks),
  optimal in group *count* but free to leave one group far slower than
  the rest; kept for regression comparison.

Either way a single node that exceeds the budgets on its own is retried
with **partial weight streaming** (``solve_ilp(weight_streaming=True)``)
before :class:`PartitionError` is raised — the rescue that makes
weight-dominated convs schedulable at the cost of DRAM tile traffic.

The result is the schedule IR of :mod:`repro.core.compile_driver`:
``partition_layer_groups`` returns a :class:`CompiledDesign` (exported
here under its historical name ``PartitionPlan``), whose groups are
:class:`GroupSchedule`s (historically ``LayerGroup``).
"""
from __future__ import annotations

from typing import Optional

from repro.core.compile_driver import CompiledDesign, GroupSchedule, SpillBuffer
from repro.core.dse import solve_ilp
from repro.core.ir import DFG
from repro.core.resource_model import (
    FpgaResourceModel,
    KV260_BRAM18K,
    KV260_DSP,
)
from repro.core.streaming import plan_streams

#: historical names (PR 1 API) for the schedule IR classes
LayerGroup = GroupSchedule
PartitionPlan = CompiledDesign


class PartitionError(ValueError):
    """A single node exceeds the budgets on its own — no cut can help,
    not even with partial weight streaming."""


class _GroupPlanner:
    """Plans (and caches) contiguous slices ``order[i:j]`` as groups."""

    def __init__(self, dfg: DFG, *, d_total: int, b_total: int,
                 model: Optional[FpgaResourceModel], max_unroll: int) -> None:
        self.dfg = dfg
        self.order = [n.name for n in dfg.topo_order()]
        self.d_total = d_total
        self.b_total = b_total
        self.model = model
        self.max_unroll = max_unroll
        self._cache: dict[tuple[int, int], GroupSchedule] = {}

    def group(self, i: int, j: int, index: int = 0) -> GroupSchedule:
        """Plan ``order[i:j]`` (cached; ``index`` only names the group)."""
        key = (i, j)
        g = self._cache.get(key)
        if g is None:
            names = self.order[i:j]
            sub = self.dfg.subgraph(names, name=f"{self.dfg.name}_g{index}")
            plan = plan_streams(sub)
            dse = solve_ilp(
                plan, d_total=self.d_total, b_total=self.b_total,
                model=self.model, max_unroll=self.max_unroll,
            )
            if not dse.feasible and j - i == 1:
                # last resort for a node no cut can shrink: stream its
                # weights from DRAM in double-buffered tiles
                rescued = solve_ilp(
                    plan, d_total=self.d_total, b_total=self.b_total,
                    model=self.model, max_unroll=self.max_unroll,
                    weight_streaming=True,
                )
                if rescued.feasible:
                    dse = rescued
            spill_in = [v for v in sub.graph_inputs
                        if v not in self.dfg.graph_inputs]
            spill_out = [v for v in sub.graph_outputs
                         if v not in self.dfg.graph_outputs]
            g = GroupSchedule(sub.name, sub, plan, dse, spill_in, spill_out)
            self._cache[key] = g
        return g

    def renamed(self, i: int, j: int, index: int) -> GroupSchedule:
        """The cached group, re-labelled with its final schedule index."""
        g = self.group(i, j)
        name = f"{self.dfg.name}_g{index}"
        if g.name != name:
            sub = self.dfg.subgraph(self.order[i:j], name=name)
            g = GroupSchedule(name, sub, g.plan, g.dse,
                              list(g.spill_in), list(g.spill_out))
            self._cache[(i, j)] = g
        return g

    def max_feasible_end(self, i: int) -> int:
        """Largest ``j`` with ``order[i:j]`` feasible (monotone probe).

        Raises :class:`PartitionError` when even ``order[i:i+1]`` (with
        the weight-streaming rescue) cannot fit.
        """
        if not self.group(i, i + 1).dse.feasible:
            raise PartitionError(
                f"{self.dfg.name}: node {self.order[i]} alone exceeds the "
                f"budgets (DSP={self.d_total}, BRAM={self.b_total}) — "
                "partitioning cannot help"
            )
        j = i + 1
        while j < len(self.order) and self.group(i, j + 1).dse.feasible:
            j += 1
        return j


def _balanced_cuts(planner: _GroupPlanner) -> list[tuple[int, int]]:
    """Min-max DP over cut positions: minimize the slowest group's
    modeled cycles, tie-breaking on fewer groups then lower total."""
    n = len(planner.order)
    memo: dict[int, tuple[tuple[int, int, int], list[tuple[int, int]]]] = {
        n: ((0, 0, 0), [])
    }

    def best(i: int) -> tuple[tuple[int, int, int], list[tuple[int, int]]]:
        hit = memo.get(i)
        if hit is not None:
            return hit
        end = planner.max_feasible_end(i)
        best_key: tuple[int, int, int] | None = None
        best_cuts: list[tuple[int, int]] = []
        for j in range(i + 1, end + 1):
            cyc = planner.group(i, j).cycles
            (rest_max, rest_groups, rest_total), rest_cuts = best(j)
            key = (max(cyc, rest_max), 1 + rest_groups, cyc + rest_total)
            if best_key is None or key < best_key:
                best_key = key
                best_cuts = [(i, j)] + rest_cuts
        assert best_key is not None  # end >= i+1 guarantees one candidate
        memo[i] = (best_key, best_cuts)
        return memo[i]

    return best(0)[1]


def _greedy_cuts(planner: _GroupPlanner) -> list[tuple[int, int]]:
    """PR 1 behaviour: grow each group until the next node breaks it."""
    cuts: list[tuple[int, int]] = []
    i = 0
    n = len(planner.order)
    while i < n:
        j = planner.max_feasible_end(i)
        cuts.append((i, j))
        i = j
    return cuts


def partition_layer_groups(
    dfg: DFG,
    *,
    d_total: int = KV260_DSP,
    b_total: int = KV260_BRAM18K,
    model: Optional[FpgaResourceModel] = None,
    max_unroll: int = 4096,
    strategy: str = "balanced",
) -> CompiledDesign:
    """Whole graph if it fits; cycle-balanced topological layer groups
    (or the greedy PR 1 cut, ``strategy="greedy"``) if not."""
    if strategy not in ("balanced", "greedy"):
        raise ValueError(f"unknown partition strategy {strategy!r}")
    planner = _GroupPlanner(
        dfg, d_total=d_total, b_total=b_total, model=model,
        max_unroll=max_unroll,
    )
    n = len(planner.order)
    whole = planner.group(0, n)
    if whole.dse.feasible:
        return CompiledDesign(dfg, [planner.renamed(0, n, 0)],
                              d_total, b_total, whole_graph_feasible=True)

    cuts = (_balanced_cuts if strategy == "balanced" else _greedy_cuts)(planner)
    groups = [planner.renamed(i, j, idx) for idx, (i, j) in enumerate(cuts)]
    return CompiledDesign(dfg, groups, d_total, b_total,
                          whole_graph_feasible=False)
