"""Resource-aware layer-group partitioning (the pass pipeline's answer
to "the whole graph does not fit").

When :func:`~repro.core.dse.solve_ilp` proves the whole-graph streaming
plan exceeds the BRAM/DSP budgets even at unroll=1, we split the DFG at
stream cut-points into **layer groups**: contiguous topological slices
that each fit the budget on their own.  Groups execute sequentially on
the fabric (separate HLS kernels, one resident at a time); values
crossing a group boundary spill to DRAM buffers that the host-side
schedule allocates and threads between kernel invocations.

The partitioner is greedy over the (canonicalized, fused) topological
order: grow the current group while its independent streaming+DSE plan
stays feasible, cut when the next node would break the budget.  Greedy
is optimal in group *count* for chain graphs (every cut point it skips,
a later plan must also skip), and safe for diamonds because groups are
topological prefixes — a producer is always in the same or an earlier
group than its consumers.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Optional

from repro.core.dse import DseResult, solve_ilp
from repro.core.ir import DFG
from repro.core.resource_model import (
    FpgaResourceModel,
    KV260_BRAM18K,
    KV260_DSP,
)
from repro.core.streaming import StreamingPlan, plan_streams

#: DRAM spill bandwidth in bytes per fabric cycle (KV260 DDR4 ≈ 19 GB/s
#: at a 300 MHz fabric clock ⇒ ~64 B/cycle; we derate to a conservative
#: streaming-access figure).
DRAM_BYTES_PER_CYCLE = 16


class PartitionError(ValueError):
    """A single node exceeds the budgets on its own — no cut can help."""


@dataclass
class SpillBuffer:
    """A DRAM buffer carrying one value across a group boundary."""

    value: str
    bits: int

    @property
    def bytes(self) -> int:
        return math.ceil(self.bits / 8)


@dataclass
class LayerGroup:
    """One sequentially-executed slice of the graph, independently
    planned through streaming + DSE."""

    name: str
    dfg: DFG
    plan: StreamingPlan
    dse: DseResult
    spill_in: list[str] = field(default_factory=list)
    spill_out: list[str] = field(default_factory=list)

    @property
    def bram(self) -> int:
        return self.dse.bram_used

    @property
    def dsp(self) -> int:
        return self.dse.dsp_used

    @property
    def cycles(self) -> int:
        return self.dse.estimate.pipeline_cycles


@dataclass
class PartitionPlan:
    """The group schedule: groups in execution order + spill ledger."""

    source: DFG
    groups: list[LayerGroup]
    d_total: int
    b_total: int
    whole_graph_feasible: bool

    @property
    def partitioned(self) -> bool:
        return len(self.groups) > 1

    @property
    def feasible(self) -> bool:
        return all(g.dse.feasible for g in self.groups)

    @property
    def max_bram(self) -> int:
        """Peak resident BRAM — one group occupies the fabric at a time."""
        return max(g.bram for g in self.groups)

    @property
    def max_dsp(self) -> int:
        return max(g.dsp for g in self.groups)

    def spills(self) -> list[SpillBuffer]:
        seen: dict[str, SpillBuffer] = {}
        for g in self.groups:
            for v in g.spill_out:
                val = self.source.values[v]
                seen.setdefault(v, SpillBuffer(v, val.total_bits))
        return list(seen.values())

    @property
    def spill_bits(self) -> int:
        return sum(s.bits for s in self.spills())

    @property
    def spill_cycles(self) -> int:
        """DRAM round-trip (write at the producer cut, read at the
        consumer cut) for every spilled value."""
        return sum(
            math.ceil(2 * s.bytes / DRAM_BYTES_PER_CYCLE) for s in self.spills()
        )

    @property
    def total_cycles(self) -> int:
        """Sequential schedule: groups back-to-back plus spill traffic."""
        return sum(g.cycles for g in self.groups) + self.spill_cycles

    def schedule(self) -> list[dict]:
        """Host-visible schedule rows (consumed by the emitter and the
        benchmark report)."""
        return [
            {
                "group": g.name,
                "nodes": [n.name for n in g.dfg.nodes],
                "bram": g.bram,
                "dsp": g.dsp,
                "cycles": g.cycles,
                "spill_in": list(g.spill_in),
                "spill_out": list(g.spill_out),
            }
            for g in self.groups
        ]


def _plan_group(
    dfg: DFG,
    names: list[str],
    index: int,
    *,
    d_total: int,
    b_total: int,
    model: Optional[FpgaResourceModel],
    max_unroll: int,
) -> LayerGroup:
    sub = dfg.subgraph(names, name=f"{dfg.name}_g{index}")
    plan = plan_streams(sub)
    dse = solve_ilp(
        plan, d_total=d_total, b_total=b_total, model=model, max_unroll=max_unroll
    )
    spill_in = [v for v in sub.graph_inputs if v not in dfg.graph_inputs]
    spill_out = [v for v in sub.graph_outputs if v not in dfg.graph_outputs]
    return LayerGroup(sub.name, sub, plan, dse, spill_in, spill_out)


def partition_layer_groups(
    dfg: DFG,
    *,
    d_total: int = KV260_DSP,
    b_total: int = KV260_BRAM18K,
    model: Optional[FpgaResourceModel] = None,
    max_unroll: int = 4096,
) -> PartitionPlan:
    """Whole graph if it fits; greedy topological layer groups if not."""
    whole = _plan_group(
        dfg, [n.name for n in dfg.topo_order()], 0,
        d_total=d_total, b_total=b_total, model=model, max_unroll=max_unroll,
    )
    if whole.dse.feasible:
        return PartitionPlan(dfg, [whole], d_total, b_total,
                             whole_graph_feasible=True)

    order = [n.name for n in dfg.topo_order()]
    groups: list[LayerGroup] = []
    current: list[str] = []
    planned: Optional[LayerGroup] = None
    for name in order:
        candidate = current + [name]
        trial = _plan_group(
            dfg, candidate, len(groups),
            d_total=d_total, b_total=b_total, model=model, max_unroll=max_unroll,
        )
        if trial.dse.feasible:
            current, planned = candidate, trial
            continue
        if not current:
            raise PartitionError(
                f"{dfg.name}: node {name} alone exceeds the budgets "
                f"(DSP={d_total}, BRAM={b_total}) — partitioning cannot help"
            )
        groups.append(planned)
        current = [name]
        planned = _plan_group(
            dfg, current, len(groups),
            d_total=d_total, b_total=b_total, model=model, max_unroll=max_unroll,
        )
        if not planned.dse.feasible:
            raise PartitionError(
                f"{dfg.name}: node {name} alone exceeds the budgets "
                f"(DSP={d_total}, BRAM={b_total}) — partitioning cannot help"
            )
    if current:
        groups.append(planned)
    return PartitionPlan(dfg, groups, d_total, b_total,
                         whole_graph_feasible=False)
