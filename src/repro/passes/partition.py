"""Resource-aware, cost-aware layer-group partitioning.

When :func:`~repro.core.dse.solve_ilp` proves the whole-graph streaming
plan exceeds the BRAM/DSP budgets even at unroll=1, we split the DFG at
stream cut-points into **layer groups**: contiguous topological slices
that each fit the budget on their own.  Groups execute sequentially on
the fabric (separate HLS kernels, one resident at a time); values
crossing a group boundary spill to DRAM buffers that the host-side
schedule allocates and threads between kernel invocations.

Two strategies over the (canonicalized, fused) topological order:

* ``"balanced"`` (default) — exact min-max search: a memoized DP over
  the cut positions that minimizes the *slowest group's* modeled cycles
  subject to per-group feasibility, tie-breaking on the **total host
  schedule** (group cycles plus overlapped boundary DMA, see
  :func:`~repro.core.resource_model.transition_cycles`) and then on
  fewer groups.  Since ISSUE 3 every candidate slice — not just single
  nodes — may be planned with **partial weight streaming** when its
  resident-weight plan is over budget, so the DP prices a group
  boundary (spill round-trip) against streamed weight tiles (DRAM tile
  traffic) and keeps whichever is modeled cheaper.
* ``"greedy"`` — the PR 1 prefix cut (grow until the budget breaks),
  optimal in group *count* but free to leave one group far slower than
  the rest; kept for regression comparison with its historical
  semantics (weight streaming only as the single-node rescue).

Feasibility is monotone in group extent (a superset group needs at
least its subset's line buffers, FIFOs, and — streamed or not — weight
storage), so each start position probes forward only until the first
infeasible end; PR 1's suffix-bound fast infeasibility keeps every
probe cheap.

The result is the schedule IR of :mod:`repro.core.compile_driver`:
``partition_layer_groups`` returns a :class:`CompiledDesign` (exported
here under its historical name ``PartitionPlan``), whose groups are
:class:`GroupSchedule`s (historically ``LayerGroup``).
"""
from __future__ import annotations

from typing import Optional

import repro.instrument as instrument
from repro.core.compile_driver import (
    _STRATEGIES,
    _WEIGHT_STREAMING,
    CompiledDesign,
    CompileOptions,
    GroupSchedule,
    SpillBuffer,
    boundary_bytes,
)
from repro.core.dse import solve_ilp
from repro.core.ir import DFG
from repro.core.resource_model import (
    FpgaResourceModel,
    KV260_BRAM18K,
    KV260_DSP,
    transition_cycles,
)
from repro.core.streaming import plan_streams

#: historical names (PR 1 API) for the schedule IR classes
LayerGroup = GroupSchedule
PartitionPlan = CompiledDesign


class PartitionError(ValueError):
    """A single node exceeds the budgets on its own — no cut can help,
    not even with partial weight streaming."""


class _GroupPlanner:
    """Plans (and caches) contiguous slices ``order[i:j]`` as groups.

    Every slice is planned resident-weights first; if that is over
    budget the slice is re-solved with ``weight_streaming=True`` — the
    first-class streaming choice the balanced DP prices against a cut.
    (The streamed candidate set is a superset of the resident one, so a
    slice that fits resident never silently picks up weight tiles.)
    """

    def __init__(self, dfg: DFG, *, d_total: int, b_total: int,
                 model: Optional[FpgaResourceModel], max_unroll: int,
                 weight_streaming: str = "auto") -> None:
        self.dfg = dfg
        self.order = [n.name for n in dfg.topo_order()]
        self.d_total = d_total
        self.b_total = b_total
        self.model = model
        self.max_unroll = max_unroll
        self.weight_streaming = weight_streaming
        self._resident: dict[tuple[int, int], tuple] = {}
        self._cache: dict[tuple[int, int], GroupSchedule] = {}
        #: search statistics the DP trace and ``CompiledDesign.dp_stats``
        #: surface — counts only, never consulted by the search itself
        self.stats: dict = {
            "nodes": len(self.order),
            "ilp_solves": 0,
            "streamed_resolves": 0,
            "slices_planned": 0,
            "slice_cache_hits": 0,
            "dp_states": 0,
            "dp_memo_hits": 0,
            "rejected_cuts": [],
        }

    def _reject_reason(self, dse) -> str:
        """Why an infeasible slice was rejected, from its unroll=1
        estimate vs the budgets: over BRAM, over DSP, both, or
        infeasible for another reason (e.g. no legal candidates)."""
        over = []
        if dse.bram_used > self.b_total:
            over.append("BRAM")
        if dse.dsp_used > self.d_total:
            over.append("DSP")
        return "+".join(over) or "infeasible"

    def _record_reject(self, i: int, j: int, dse, *, streamed: bool,
                       rescued: bool = False) -> None:
        """Log ``order[i:j]`` as a rejected cut candidate.  ``rescued``
        marks a slice that was infeasible with resident weights but
        kept after a streamed re-solve — rejected *as a resident cut*,
        which is the reason (BRAM/DSP) the trace surfaces."""
        self.stats["rejected_cuts"].append({
            "i": i, "j": j,
            "first": self.order[i], "last": self.order[j - 1],
            "reason": self._reject_reason(dse),
            "bram": dse.bram_used, "dsp": dse.dsp_used,
            "streamed_tried": streamed,
            "streamed_rescued": rescued,
        })

    def _solve(self, plan, *, weight_streaming: bool):
        with instrument.span(
            f"ilp:{plan.dfg.name}", cat="dse",
            args={"nodes": len(plan.node_order()),
                  "weight_streaming": weight_streaming},
        ) as sargs:
            dse = solve_ilp(
                plan, d_total=self.d_total, b_total=self.b_total,
                model=self.model, max_unroll=self.max_unroll,
                weight_streaming=weight_streaming,
            )
            sargs.update({"explored": dse.explored,
                          "feasible": dse.feasible,
                          "objective_cycles": dse.objective_cycles})
        return dse

    def _resident_plan(self, i: int, j: int):
        """(subgraph, streaming plan, resident-weights DSE) for
        ``order[i:j]`` — cached separately from :meth:`group` so pure
        resident-feasibility probes (the greedy strategy, the
        whole-graph fast path) never pay the streamed re-solve."""
        key = (i, j)
        hit = self._resident.get(key)
        if hit is None:
            names = self.order[i:j]
            sub = self.dfg.subgraph(names, name=f"{self.dfg.name}_g0")
            plan = plan_streams(sub)
            self.stats["ilp_solves"] += 1
            hit = (sub, plan, self._solve(plan, weight_streaming=False))
            self._resident[key] = hit
        return hit

    def group(self, i: int, j: int) -> GroupSchedule:
        """Plan ``order[i:j]``: resident if it fits, else re-solved with
        partial weight streaming (double-buffered DRAM tiles) — any
        slice length, not the PR 2 single-node rescue.  Cached."""
        key = (i, j)
        g = self._cache.get(key)
        if g is None:
            self.stats["slices_planned"] += 1
            sub, plan, dse = self._resident_plan(i, j)
            resident = dse
            tried_stream = False
            if not dse.feasible and self.weight_streaming != "off":
                tried_stream = True
                self.stats["ilp_solves"] += 1
                self.stats["streamed_resolves"] += 1
                streamed = self._solve(plan, weight_streaming=True)
                if streamed.feasible:
                    dse = streamed
            if not resident.feasible:
                # a resident-infeasible slice is a rejected cut
                # candidate either way: when the streamed re-solve
                # rescues it the slice survives *streamed*, but the
                # resident rejection (and its BRAM/DSP reason) is what
                # explains the schedule in the trace
                self._record_reject(i, j, resident, streamed=tried_stream,
                                    rescued=dse.feasible)
            spill_in = [v for v in sub.graph_inputs
                        if v not in self.dfg.graph_inputs]
            spill_out = [v for v in sub.graph_outputs
                         if v not in self.dfg.graph_outputs]
            g = GroupSchedule(sub.name, sub, plan, dse, spill_in, spill_out)
            self._cache[key] = g
        else:
            self.stats["slice_cache_hits"] += 1
        return g

    def renamed(self, i: int, j: int, index: int) -> GroupSchedule:
        """The cached group, re-labelled with its final schedule index."""
        g = self.group(i, j)
        name = f"{self.dfg.name}_g{index}"
        if g.name != name:
            sub = self.dfg.subgraph(self.order[i:j], name=name)
            g = GroupSchedule(name, sub, g.plan, g.dse,
                              list(g.spill_in), list(g.spill_out))
            self._cache[(i, j)] = g
        return g

    def resident_feasible(self, i: int, j: int) -> bool:
        """``order[i:j]`` fits with all weights on-chip (no tiles)."""
        return self._resident_plan(i, j)[2].feasible

    def transition(self, left: GroupSchedule, right: GroupSchedule) -> int:
        """Overlapped boundary DMA between two adjacent groups — the
        same ``boundary_bytes`` the compiled design reports."""
        return transition_cycles(*boundary_bytes(self.dfg, left, right))

    def _check_first(self, i: int) -> None:
        if not self.group(i, i + 1).dse.feasible:
            how = (
                "even with streamed weights"
                if self.weight_streaming != "off"
                else "with resident weights (weight_streaming='off')"
            )
            raise PartitionError(
                f"{self.dfg.name}: node {self.order[i]} alone exceeds the "
                f"budgets (DSP={self.d_total}, BRAM={self.b_total}) {how} "
                "— partitioning cannot help"
            )

    def max_feasible_end(self, i: int) -> int:
        """Largest ``j`` with ``order[i:j]`` feasible — resident *or*
        weight-streamed (monotone probe).

        Raises :class:`PartitionError` when even ``order[i:i+1]`` cannot
        fit with streamed weights.
        """
        self._check_first(i)
        j = i + 1
        while j < len(self.order) and self.group(i, j + 1).dse.feasible:
            j += 1
        return j

    def max_resident_end(self, i: int) -> int:
        """The PR 1/PR 2 greedy probe: grow while the slice fits with
        resident weights; a lone infeasible node falls back to the
        streamed single-node group (the historical rescue)."""
        self._check_first(i)
        j = i + 1
        while j < len(self.order) and self.resident_feasible(i, j + 1):
            j += 1
        return j


def _balanced_cuts(planner: _GroupPlanner) -> list[tuple[int, int]]:
    """Cost-aware min-max DP over cut positions.

    Primary objective: minimize the slowest group's modeled cycles —
    exact (every greedy cut is in the candidate space, so the balanced
    result is never worse than greedy on the max, a property pinned by
    tests/test_partition_properties.py).  Tie-breaks: the total host
    schedule (group cycles + overlapped boundary DMA — this is where a
    spill round-trip is traded against a streamed slice's weight-tile
    traffic), then fewer groups.

    The tie-break total is exact for linear chains (every boundary's
    traffic depends only on the cut position).  For diamonds the bridge
    added when combining ``group(i, j)`` with the memoized suffix uses
    the suffix's already-chosen first group, whose ``spill_in`` can vary
    with its extent — an exact total there would need two-dimensional
    DP state; we accept the approximation on the secondary key only.
    """
    n = len(planner.order)
    # memo[i] = ((max_cycles, total_cycles, n_groups), cuts-for-suffix)
    memo: dict[int, tuple[tuple[int, int, int], list[tuple[int, int]]]] = {
        n: ((0, 0, 0), [])
    }

    def best(i: int) -> tuple[tuple[int, int, int], list[tuple[int, int]]]:
        hit = memo.get(i)
        if hit is not None:
            planner.stats["dp_memo_hits"] += 1
            return hit
        planner.stats["dp_states"] += 1
        end = planner.max_feasible_end(i)
        best_key: tuple[int, int, int] | None = None
        best_cuts: list[tuple[int, int]] = []
        for j in range(i + 1, end + 1):
            g = planner.group(i, j)
            (rest_max, rest_total, rest_groups), rest_cuts = best(j)
            bridge = (
                planner.transition(g, planner.group(*rest_cuts[0]))
                if rest_cuts else 0
            )
            key = (
                max(g.cycles, rest_max),
                g.cycles + bridge + rest_total,
                1 + rest_groups,
            )
            if best_key is None or key < best_key:
                best_key = key
                best_cuts = [(i, j)] + rest_cuts
        assert best_key is not None  # end >= i+1 guarantees one candidate
        memo[i] = (best_key, best_cuts)
        return memo[i]

    return best(0)[1]


def _greedy_cuts(planner: _GroupPlanner) -> list[tuple[int, int]]:
    """PR 1 behaviour: grow each group until the next node breaks it."""
    cuts: list[tuple[int, int]] = []
    i = 0
    n = len(planner.order)
    while i < n:
        j = planner.max_resident_end(i)
        cuts.append((i, j))
        i = j
    return cuts


def partition_layer_groups(
    dfg: DFG,
    *,
    options: Optional[CompileOptions] = None,
    d_total: Optional[int] = None,
    b_total: Optional[int] = None,
    model: Optional[FpgaResourceModel] = None,
    max_unroll: Optional[int] = None,
    strategy: Optional[str] = None,
    weight_streaming: Optional[str] = None,
) -> CompiledDesign:
    """Whole graph if it fits resident; otherwise cost-aware balanced
    topological layer groups (or the greedy PR 1 cut,
    ``strategy="greedy"``) — where the balanced DP may keep a slice
    whole with streamed weight tiles instead of cutting it (disable
    with ``weight_streaming="off"``).

    An ``options`` bundle (:class:`repro.core.CompileOptions`) is the
    single source of truth ``compile_design`` threads through the whole
    stack: budgets and the resource model come from its target, the
    strategy, unroll cap, and streaming policy from its fields.  Mixing
    it with loose kwargs is an error (never a silent override)."""
    if options is not None:
        loose = (d_total, b_total, model, max_unroll, strategy,
                 weight_streaming)
        if any(v is not None for v in loose):
            raise ValueError(
                "pass either options=CompileOptions(...) or the loose "
                "d_total/b_total/model/max_unroll/strategy/"
                "weight_streaming kwargs, not both"
            )
        tgt = options.target
        d_total, b_total = tgt.d_total, tgt.b_total
        model = tgt.model()
        max_unroll = options.resolved_max_unroll
        strategy = options.strategy
        weight_streaming = options.weight_streaming
    else:
        d_total = KV260_DSP if d_total is None else d_total
        b_total = KV260_BRAM18K if b_total is None else b_total
        max_unroll = 4096 if max_unroll is None else max_unroll
        strategy = "balanced" if strategy is None else strategy
        weight_streaming = (
            "auto" if weight_streaming is None else weight_streaming
        )
    if strategy not in _STRATEGIES:
        raise ValueError(
            f"unknown partition strategy {strategy!r} — one of {_STRATEGIES}"
        )
    if weight_streaming not in _WEIGHT_STREAMING:
        # a policy string, NOT solve_ilp's per-solve bool — catch e.g.
        # weight_streaming=False before it silently behaves as "auto"
        raise ValueError(
            f"weight_streaming must be one of {_WEIGHT_STREAMING}, got "
            f"{weight_streaming!r}"
        )
    planner = _GroupPlanner(
        dfg, d_total=d_total, b_total=b_total, model=model,
        max_unroll=max_unroll, weight_streaming=weight_streaming,
    )
    tracer = instrument.current()
    n = len(planner.order)
    with tracer.span(f"partition:{dfg.name}", cat="partition") as pargs:
        if planner.resident_feasible(0, n):
            # fits whole with weights on-chip: never cut a feasible graph
            # (the ROADMAP reconfiguration-cost item gates that trade)
            cuts = [(0, n)]
            whole = True
        else:
            whole = False
            cuts = (_balanced_cuts if strategy == "balanced"
                    else _greedy_cuts)(planner)
        groups = [planner.renamed(i, j, idx)
                  for idx, (i, j) in enumerate(cuts)]
        design = CompiledDesign(dfg, groups, d_total, b_total,
                                whole_graph_feasible=whole, options=options)
        design.dp_stats = _finish_stats(planner, strategy, design, cuts)
        pargs.update({"groups": len(groups), "whole_graph_feasible": whole})
    if tracer.enabled:
        tracer.instant(f"dp_stats:{dfg.name}", cat="partition",
                       args=design.dp_stats)
    return design


def _finish_stats(planner: _GroupPlanner, strategy: str,
                  design: CompiledDesign, cuts: list[tuple[int, int]]) -> dict:
    """The search-statistics record attached to every design: planner
    counters, a rejected-cut reason histogram, and the final frontier
    (the kept cuts with their modeled cost)."""
    stats = dict(planner.stats)
    stats["rejected_cuts"] = list(stats["rejected_cuts"])
    stats["strategy"] = strategy
    stats["whole_graph_feasible"] = design.whole_graph_feasible
    reasons: dict[str, int] = {}
    for rc in stats["rejected_cuts"]:
        reasons[rc["reason"]] = reasons.get(rc["reason"], 0) + 1
    stats["rejected_by_reason"] = reasons
    stats["frontier"] = [
        {
            "group": g.name, "i": i, "j": j,
            "cycles": g.cycles, "bram": g.bram, "dsp": g.dsp,
            "weight_tiles": g.weight_streamed,
        }
        for (i, j), g in zip(cuts, design.groups)
    ]
    return stats
