"""Reference DFG interpreter — the semantic oracle for pass testing.

Executes a :class:`~repro.core.ir.DFG` numerically (dense jnp math, no
tiling, no streams) so tests can assert that a rewritten graph computes
*exactly* what the original did: fusion (elementwise, conv+activation,
conv+pool), CSE, DCE, canonicalization, and the layer-group partitioner
are all checked against this executor, which in turn leans on
``repro.kernels.ref`` for the conv/pool/elementwise primitives — the
same primitives ``repro.kernels.ops.lower_group`` lowers groups with, so
the interpreter, the Pallas path, and the HLS emitter all share one
semantic definition.

Supported node shapes (everything ``cnn_graphs`` builds):

* pure-parallel elementwise ops (identity maps) for every PayloadKind;
* regular reductions whose map results are all single dims (matmul and
  friends) via einsum built from the indexing maps;
* NHWC sliding-window MAC (conv2d) via ``ref.conv2d`` (SAME padding —
  the convention the graph builders use when sizing output values);
* NHWC sliding-window MAX (max pool, non-overlapping or not) via
  ``ref.maxpool2d`` (VALID padding);
* fused epilogues, including windowed pooling entries.

Integer graphs execute in int32 (the paper's int8 PTQ regime accumulates
in int32); float graphs in float32.
"""
from __future__ import annotations

from typing import Mapping

import jax
import jax.numpy as jnp

from repro.core.analysis import (
    KernelClass,
    classify_kernel,
    conv_spatial_pads,
    einsum_spec,
    reorder_spec,
    window_geometry,
)
from repro.core.ir import DFG, GenericOp, PayloadKind
from repro.kernels import ref


def _apply_epilogue(op: GenericOp, out, env: Mapping[str, jax.Array]):
    return ref.apply_epilogue(out, op.epilogue, env)


def _einsum_from_maps(op: GenericOp, operands):
    """Regular reduction with single-dim map results → jnp.einsum."""
    return jnp.einsum(einsum_spec(op), *operands)


def _conv2d(op: GenericOp, dfg: DFG, env: Mapping[str, jax.Array]):
    info = classify_kernel(op)
    if op.n_dims != 7 or len(op.inputs) != 2 or info.dilation != 1:
        raise NotImplementedError(f"{op.name}: unsupported sliding-window shape")
    stream = [i for i in op.inputs if not dfg.values[i].is_constant]
    const = [i for i in op.inputs if dfg.values[i].is_constant]
    if len(stream) != 1 or len(const) != 1:
        raise NotImplementedError(f"{op.name}: conv needs 1 stream + 1 const input")
    x = env[stream[0]]
    pads = conv_spatial_pads(op, tuple(x.shape))
    return ref.conv2d(x, env[const[0]], stride=info.stride,
                      padding=(pads[1], pads[2]))


def _pool2d(op: GenericOp, env: Mapping[str, jax.Array]):
    info = classify_kernel(op)
    geo = window_geometry(op, info)
    if op.n_dims != 6 or len(geo.window_extents) != 2 or info.dilation != 1:
        raise NotImplementedError(f"{op.name}: unsupported pool shape")
    kh, kw = geo.window_extents
    pool = ref.maxpool2d if op.payload == PayloadKind.MAX else ref.avgpool2d
    return pool(env[op.inputs[0]], kh, kw, info.stride)


def execute_reorder(op: GenericOp, x: jax.Array) -> jax.Array:
    """Transpose / flatten data-movement ops (shared with the Pallas
    lowering so both executors agree on reorder semantics)."""
    spec = reorder_spec(op)
    assert spec is not None, op.name
    kind, arg = spec
    if kind == "transpose":
        return jnp.transpose(x, arg)
    # flatten: bring the non-batch axes into linearization order, then
    # collapse them row-major
    return jnp.transpose(x, (0,) + arg).reshape(x.shape[0], -1)


def execute_node(op: GenericOp, dfg: DFG, env: Mapping[str, jax.Array]):
    info = classify_kernel(op)
    if info.kernel_class == KernelClass.PURE_PARALLEL:
        if reorder_spec(op) is not None:
            return _apply_epilogue(op, execute_reorder(op, env[op.inputs[0]]),
                                   env)
        args = [env[i] for i in op.inputs]
        if len(args) == 1:
            out = ref.unary(op.payload, args[0])
        elif len(args) == 2:
            out = ref.binary(op.payload, args[0], args[1])
        else:
            raise NotImplementedError(f"{op.name}: {len(args)}-ary elementwise")
    elif info.kernel_class == KernelClass.REGULAR_REDUCTION:
        if op.payload != PayloadKind.MAC:
            raise NotImplementedError(f"{op.name}: non-MAC reduction")
        out = _einsum_from_maps(op, [env[i] for i in op.inputs])
    else:  # SLIDING_WINDOW
        if op.payload == PayloadKind.MAC:
            out = _conv2d(op, dfg, env)
        elif (
            op.payload in (PayloadKind.MAX, PayloadKind.AVG)
            and len(op.inputs) == 1
        ):
            out = _pool2d(op, env)
        else:
            raise NotImplementedError(f"{op.name}: unsupported sliding window")
    return _apply_epilogue(op, out, env)


def execute_dfg(
    dfg: DFG, env: Mapping[str, jax.Array]
) -> dict[str, jax.Array]:
    """Run the graph; ``env`` must bind every graph input and constant.
    Returns the full value environment (inputs + all produced values),
    so layer groups can be chained by feeding one group's result env
    into the next — exactly what the host schedule does via DRAM."""
    out_env = dict(env)
    for op in dfg.topo_order():
        out_env[op.output] = execute_node(op, dfg, out_env)
    return out_env


def graph_outputs(dfg: DFG, env: Mapping[str, jax.Array]) -> dict[str, jax.Array]:
    full = execute_dfg(dfg, env)
    return {v: full[v] for v in dfg.graph_outputs}


def random_env(dfg: DFG, seed: int = 0) -> dict[str, jax.Array]:
    """Small-integer int32 bindings for every graph input and constant —
    integer math keeps fused-vs-unfused comparisons exact."""
    key = jax.random.key(seed)
    env: dict[str, jax.Array] = {}
    names = list(dfg.graph_inputs) + [
        v for v, val in dfg.values.items() if val.is_constant
    ]
    for name in names:
        key, sub = jax.random.split(key)
        env[name] = jax.random.randint(
            sub, dfg.values[name].shape, -4, 5, jnp.int32
        )
    return env
