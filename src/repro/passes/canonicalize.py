"""Canonicalization: identity removal, shape propagation, ordering.

Three rewrites that keep every later pass simple:

* **identity elimination** — ``IDENTITY``-payload pure-parallel ops with
  an empty epilogue are wires; uses of their output are rewired to their
  input and the node is dropped.
* **constant-shape propagation** — each produced Value's shape is
  recomputed from its producer's output map (loop extents are the
  static source of truth); stale shapes from hand-built or rewritten
  graphs are overwritten so the verifier's V8 invariant holds.
* **deterministic node ordering** — ``dfg.nodes`` is rewritten into
  topological order with lexicographic tie-break, so pass pipelines,
  emission, and golden files are reproducible regardless of builder
  insertion order.
"""
from __future__ import annotations

from repro.core.analysis import KernelClass, classify_kernel
from repro.core.ir import DFG, GenericOp

from .base import Pass


def _inferred_output_shape(op: GenericOp) -> tuple[int, ...] | None:
    """Output extents when every output-map result is a single dim
    (shrunk by any fused pooling epilogue — the value the op produces)."""
    omap = op.output_map
    if not all(e.is_single_dim() for e in omap.results):
        return None
    extents = tuple(op.dim_extent(e.terms[0][0]) for e in omap.results)
    return op.epilogue_shape(extents)


class Canonicalize(Pass):
    name = "canonicalize"

    def run_on(self, dfg: DFG) -> dict[str, int]:
        identities_removed = self._remove_identities(dfg)
        shapes_fixed = self._propagate_shapes(dfg)
        reordered = self._sort_nodes(dfg)
        return {
            "identities_removed": identities_removed,
            "shapes_fixed": shapes_fixed,
            "nodes_reordered": reordered,
        }

    # -- identity elimination ------------------------------------------------

    def _remove_identities(self, dfg: DFG) -> int:
        removed = 0
        for node in list(dfg.nodes):
            if node.payload.value != "identity" or node.epilogue:
                continue
            if len(node.inputs) != 1:
                continue
            info = classify_kernel(node)
            if info.kernel_class != KernelClass.PURE_PARALLEL:
                continue
            # a transpose/flatten is IDENTITY-payload but *moves* data —
            # only a true wire (identity maps end to end) is removable
            if not all(m.is_identity() for m in node.indexing_maps):
                continue
            src, out = node.inputs[0], node.output
            # pure pass-through from a graph input to a graph output has
            # nothing to rewire into — keep the node as the sole producer.
            if src in dfg.graph_inputs and out in dfg.graph_outputs:
                continue
            dfg.remove_node(node.name)
            dfg.replace_value_uses(out, src)
            if out in dfg.values and out not in dfg.referenced_values():
                del dfg.values[out]
            removed += 1
        return removed

    # -- constant-shape propagation ------------------------------------------

    def _propagate_shapes(self, dfg: DFG) -> int:
        fixed = 0
        for node in dfg.topo_order():
            shape = _inferred_output_shape(node)
            if shape is None:
                continue
            val = dfg.values[node.output]
            if val.shape != shape:
                val.shape = shape
                fixed += 1
        return fixed

    # -- deterministic ordering ----------------------------------------------

    def _sort_nodes(self, dfg: DFG) -> int:
        """Stable topological sort with name tie-break (Kahn's, sorted
        ready set).  Returns 1 when the order actually changed."""
        produced = set(dfg.graph_inputs) | {
            v for v, val in dfg.values.items() if val.is_constant
        }
        pending = {n.name: n for n in dfg.nodes}
        order: list[GenericOp] = []
        while pending:
            ready = sorted(
                name for name, n in pending.items()
                if all(i in produced for i in n.inputs)
            )
            if not ready:
                raise ValueError(f"{dfg.name}: cycle during canonicalization")
            for name in ready:
                node = pending.pop(name)
                order.append(node)
                produced.add(node.output)
        changed = [n.name for n in order] != [n.name for n in dfg.nodes]
        dfg.nodes = order
        return int(changed)
