"""Structural DFG verifier — the pass pipeline's safety net.

MLIR runs its verifier between passes so a broken rewrite is caught at
the pass that produced it, not three passes later in a backend crash.
We mirror that: :class:`~repro.passes.base.PassManager` calls
:func:`verify_dfg` after every pass and raises
:class:`VerificationError` naming the offending pass.

Checks (all structural — payload semantics are the interpreter's job):

  V1. unique node names; every node input/output/epilogue operand is a
      registered value;
  V2. single static assignment: at most one producer per value;
  V3. graph inputs are not produced by nodes; graph outputs are produced
      by a node or are graph inputs (pass-through);
  V4. the graph is acyclic (Kahn's algorithm completes);
  V5. op arity: |indexing_maps| == |inputs|+1, every map matches n_dims,
      |dim_sizes| == |iterator_types|;
  V6. epilogue operands are constant values (fusion may only fold
      on-chip constants, never streams);
  V7. every non-constant node input is a graph input or has a producer;
  V8. output shape agreement: when every output-map result is a single
      dim, the produced Value's shape equals the mapped extents — shrunk
      by any fused pooling epilogue (the canonicalizer's shape
      propagation maintains this invariant);
  V9. pooling epilogues are well-formed: window rank matches the output
      rank and every factor tiles its axis exactly.
  V10. data-movement (reorder) ops preserve elements: an IDENTITY
      pure-parallel op with non-identity maps must be a recognizable
      transpose/flatten (``repro.core.analysis.reorder_spec``), carry no
      epilogue, and produce a value with exactly the input's element
      count and the shape its maps imply — the layout pass's rewrites
      are checked against this after every application.

Two reporting modes.  The default (the PassManager's mode) is
fail-fast: the first violated invariant raises, naming the rule —
``lenet5: [V2] value x produced by both a and b``.  With
``collect_all=True`` every rule still runs after a violation and the
single raised :class:`VerificationError` lists them all (one ``[Vk]``
line each, also on ``.violations``) — the mode ``python -m repro lint``
and hand-written graph debugging want, where the second and third
breakages are usually more informative than the first.
"""
from __future__ import annotations

from repro.core.analysis import reorder_spec
from repro.core.ir import DFG, IteratorType, PayloadKind


class VerificationError(ValueError):
    """A rewrite left the DFG structurally malformed.

    ``violations`` holds one ``[Vk] message`` string per violated
    invariant — a single entry in fail-fast mode, every violation found
    when ``verify_dfg(..., collect_all=True)`` raised.
    """

    def __init__(self, message: str, violations: tuple = ()):
        super().__init__(message)
        self.violations = tuple(violations)


def _check_names(dfg: DFG, fail) -> None:
    # V1 — names and registration
    seen_nodes: set[str] = set()
    for n in dfg.nodes:
        if n.name in seen_nodes:
            fail("V1", f"duplicate node name {n.name}")
        seen_nodes.add(n.name)
        for v in n.inputs + (n.output,):
            if v not in dfg.values:
                fail("V1", f"{n.name}: unregistered value {v}")
        for e in n.epilogue:
            if e.operand is not None and e.operand not in dfg.values:
                fail("V1", f"{n.name}: unregistered epilogue operand {e.operand}")


def _check_ssa(dfg: DFG, fail) -> None:
    # V2 — single producer per value
    producers: dict[str, str] = {}
    for n in dfg.nodes:
        if n.output in producers:
            fail("V2", f"value {n.output} produced by both "
                       f"{producers[n.output]} and {n.name}")
        producers[n.output] = n.name


def _check_boundary(dfg: DFG, fail) -> None:
    # V3 — graph boundary
    producers = {n.output: n.name for n in dfg.nodes}
    for gi in dfg.graph_inputs:
        if gi not in dfg.values:
            fail("V3", f"graph input {gi} not registered")
        if gi in producers:
            fail("V3", f"graph input {gi} is produced by {producers[gi]}")
    for go in dfg.graph_outputs:
        if go not in dfg.values:
            fail("V3", f"graph output {go} not registered")
        if go not in producers and go not in dfg.graph_inputs:
            fail("V3", f"graph output {go} has no producer")


def _check_acyclic(dfg: DFG, fail) -> None:
    # V4 — acyclicity
    try:
        dfg.topo_order()
    except ValueError as e:
        fail("V4", str(e))


def _check_arity(dfg: DFG, fail) -> None:
    # V5 — op arity (rewrites mutate past __post_init__)
    for n in dfg.nodes:
        if len(n.indexing_maps) != len(n.inputs) + 1:
            fail("V5", f"{n.name}: {len(n.indexing_maps)} maps for "
                       f"{len(n.inputs)} inputs")
        if len(n.dim_sizes) != len(n.iterator_types):
            fail("V5", f"{n.name}: dim_sizes/iterator_types mismatch")
        for m in n.indexing_maps:
            if m.n_dims != n.n_dims:
                fail("V5", f"{n.name}: map arity {m.n_dims} != {n.n_dims}")


def _check_epilogue_consts(dfg: DFG, fail) -> None:
    # V6 — epilogue operands are constants
    for n in dfg.nodes:
        for e in n.epilogue:
            if e.operand is not None and not dfg.values[e.operand].is_constant:
                fail("V6", f"{n.name}: epilogue operand {e.operand} "
                           "is not a constant")


def _check_fed(dfg: DFG, fail) -> None:
    # V7 — every non-constant input is fed
    feedable = set(dfg.graph_inputs) | {n.output for n in dfg.nodes}
    for n in dfg.nodes:
        for v in n.inputs:
            if not dfg.values[v].is_constant and v not in feedable:
                fail("V7", f"{n.name}: input {v} has no producer and "
                           "is not a graph input")


def _check_shapes(dfg: DFG, fail) -> None:
    # V8 — output shape agreement (single-dim output maps only); a fused
    # pooling epilogue shrinks the mapped extents before the comparison
    for n in dfg.nodes:
        omap = n.output_map
        if not all(e.is_single_dim() for e in omap.results):
            continue
        extents = tuple(n.dim_extent(e.terms[0][0]) for e in omap.results)
        extents = n.epilogue_shape(extents)
        shape = dfg.values[n.output].shape
        if shape != extents:
            fail("V8", f"{n.name}: output {n.output} shape {shape} != "
                       f"mapped extents {extents}")


def _check_pool_windows(dfg: DFG, fail) -> None:
    # V9 — pooling epilogues divide their axes exactly (window factors
    # must tile the pre-pool extents; checked against the mapped shape)
    for n in dfg.nodes:
        omap = n.output_map
        if not all(e.is_single_dim() for e in omap.results):
            continue
        shape = tuple(n.dim_extent(e.terms[0][0]) for e in omap.results)
        for e in n.epilogue:
            if not e.window:
                continue
            if len(e.window) != len(shape):
                fail("V9", f"{n.name}: pool window rank {len(e.window)} "
                           f"!= output rank {len(shape)}")
                continue
            if any(s % f for s, f in zip(shape, e.window)):
                fail("V9", f"{n.name}: pool window {e.window} does not "
                           f"tile output extents {shape}")
            shape = tuple(s // f for s, f in zip(shape, e.window))


def _check_reorders(dfg: DFG, fail) -> None:
    # V10 — reorder ops are well-formed element-preserving moves
    for n in dfg.nodes:
        if (
            n.payload != PayloadKind.IDENTITY
            or len(n.inputs) != 1
            or any(t != IteratorType.PARALLEL for t in n.iterator_types)
        ):
            continue
        imap, omap = n.indexing_maps
        if imap.is_identity() and omap.is_identity():
            continue  # plain wire — canonicalize removes it
        spec = reorder_spec(n)
        if spec is None:
            fail("V10", f"{n.name}: IDENTITY op with non-identity "
                        "maps is not a recognizable transpose/flatten")
            continue
        if n.epilogue:
            fail("V10", f"{n.name}: reorder ops cannot carry epilogues")
        in_v, out_v = dfg.values[n.inputs[0]], dfg.values[n.output]
        if in_v.num_elements != out_v.num_elements:
            fail("V10", f"{n.name}: reorder changes element count "
                        f"({in_v.shape} -> {out_v.shape})")
        kind, arg = spec
        if kind == "transpose":
            want = tuple(in_v.shape[p] for p in arg)
        else:
            feat = 1
            for s in in_v.shape[1:]:
                feat *= s
            want = (in_v.shape[0], feat)
        if out_v.shape != want:
            fail("V10", f"{n.name}: {kind} output shape "
                        f"{out_v.shape} != expected {want}")


_CHECKS = (
    _check_names,
    _check_ssa,
    _check_boundary,
    _check_acyclic,
    _check_arity,
    _check_epilogue_consts,
    _check_fed,
    _check_shapes,
    _check_pool_windows,
    _check_reorders,
)


def verify_dfg(dfg: DFG, *, collect_all: bool = False) -> None:
    """Check every structural invariant V1–V10.

    Fail-fast by default: the first violation raises
    :class:`VerificationError` (the PassManager's mode — the offending
    pass is what matters, not an exhaustive damage report).  With
    ``collect_all=True`` all rules run, every violation is gathered,
    and one error is raised at the end listing each as a ``[Vk]`` line
    (also machine-readable on ``VerificationError.violations``).
    """
    violations: list[str] = []

    def fail(rule: str, msg: str) -> None:
        text = f"[{rule}] {msg}"
        if not collect_all:
            raise VerificationError(f"{dfg.name}: {text}", (text,))
        violations.append(text)

    for check in _CHECKS:
        try:
            check(dfg, fail)
        except VerificationError:
            raise
        except Exception:
            # A later rule crashed (KeyError on an unregistered value,
            # …) on damage an earlier rule already reported — the
            # collected violations explain it.  A crash with NO prior
            # violation is a verifier bug: surface it.
            if not violations:
                raise
    if violations:
        body = "\n  ".join(violations)
        raise VerificationError(
            f"{dfg.name}: {len(violations)} structural violation(s)\n  {body}",
            tuple(violations),
        )
