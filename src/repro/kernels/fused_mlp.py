"""Fused (gated) MLP — Pallas TPU kernel.

MING's "never materialize the intermediate" (contribution C1) applied to
the transformer MLP: the (tokens, d_ff) hidden activation — the largest
intermediate in an LM block — is *streamed* through VMEM in d_ff tiles
and consumed immediately by the down-projection, never written to HBM.
The running (tokens, d_model) accumulator in scratch plays the role of
the output stream.

Grid: (M/bm, F/bf), f innermost.  Tile sizes come from the DSE
(``repro.core.dse.plan_matmul_blocks``) under the VMEM budget — the BRAM
constraint dual.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _activate(name: str, x: jax.Array) -> jax.Array:
    if name == "silu":
        return x * jax.nn.sigmoid(x)
    if name == "gelu":
        return jax.nn.gelu(x)
    if name == "relu":
        return jnp.maximum(x, 0.0)
    if name == "squared_relu":
        r = jnp.maximum(x, 0.0)
        return r * r
    raise ValueError(name)


def _fused_mlp_kernel(
    x_ref,       # (bm, D)
    wg_ref,      # (D, bf) or None (ungated)
    wu_ref,      # (D, bf)
    wd_ref,      # (bf, D)
    o_ref,       # (bm, D)
    acc_ref,     # (bm, D) f32 scratch
    *,
    act: str,
    gated: bool,
    num_f_blocks: int,
):
    fi = pl.program_id(1)

    @pl.when(fi == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)
    wu = wu_ref[...].astype(jnp.float32)
    up = jax.lax.dot_general(
        x, wu, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                                   # (bm, bf)
    if gated:
        wg = wg_ref[...].astype(jnp.float32)
        gate = _activate(
            act,
            jax.lax.dot_general(
                x, wg, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            ),
        )
        h = gate * up
    else:
        h = _activate(act, up)

    wd = wd_ref[...].astype(jnp.float32)
    acc_ref[...] += jax.lax.dot_general(
        h, wd, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(fi == num_f_blocks - 1)
    def _finalize():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def fused_mlp_pallas(
    x: jax.Array,                   # (M, D)
    w_gate: jax.Array | None,       # (D, F) or None
    w_up: jax.Array,                # (D, F)
    w_down: jax.Array,              # (F, D)
    *,
    block_m: int,
    block_f: int,
    act: str = "silu",
    interpret: bool = False,
) -> jax.Array:
    m, d = x.shape
    f = w_up.shape[1]
    assert m % block_m == 0 and f % block_f == 0, (m, f, block_m, block_f)
    gated = w_gate is not None
    nm, nf = m // block_m, f // block_f

    kernel = functools.partial(
        _fused_mlp_kernel, act=act, gated=gated, num_f_blocks=nf
    )
    in_specs = [
        pl.BlockSpec((block_m, d), lambda mi, fi: (mi, 0)),
    ]
    operands = [x]
    if gated:
        in_specs.append(pl.BlockSpec((d, block_f), lambda mi, fi: (0, fi)))
        operands.append(w_gate)
    else:
        # keep kernel arity uniform: pass w_up twice, ignore the gate slot
        in_specs.append(pl.BlockSpec((d, block_f), lambda mi, fi: (0, fi)))
        operands.append(w_up)
    in_specs.append(pl.BlockSpec((d, block_f), lambda mi, fi: (0, fi)))
    operands.append(w_up)
    in_specs.append(pl.BlockSpec((block_f, d), lambda mi, fi: (fi, 0)))
    operands.append(w_down)

    return pl.pallas_call(
        kernel,
        grid=(nm, nf),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_m, d), lambda mi, fi: (mi, 0)),
        out_shape=jax.ShapeDtypeStruct((m, d), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, d), jnp.float32)],
        interpret=interpret,
    )(*operands)
