"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth the kernels/ops are tested
against (``tests/test_kernels.py`` sweeps shapes/dtypes and asserts
allclose).  No Pallas, no tiling — straight dense math.
"""
from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp
from jax import lax


# ---------------------------------------------------------------------------
# conv2d (+ fused ReLU) — the paper's streaming conv oracle
# ---------------------------------------------------------------------------


def conv2d(
    x: jax.Array,          # (B, H, W, C_in)
    w: jax.Array,          # (KH, KW, C_in, C_out)
    *,
    stride: int = 1,
    padding: str | tuple = "SAME",
    fuse_relu: bool = False,
    epilogue: str | None = None,
) -> jax.Array:
    """NHWC conv; int8 inputs accumulate in int32 (paper's PTQ regime).
    ``padding`` is ``"SAME"`` / ``"VALID"`` or an explicit
    ``((top, bottom), (left, right))`` pair sequence (passed straight to
    ``lax.conv_general_dilated`` — the asymmetric-pads import path).
    ``epilogue`` mirrors the kernel's fused tails (relu / squared_relu)."""
    if fuse_relu and epilogue not in (None, "relu"):
        raise ValueError(f"fuse_relu=True conflicts with epilogue={epilogue!r}")
    if jnp.issubdtype(x.dtype, jnp.integer):
        acc_dtype = jnp.int32
    else:
        acc_dtype = jnp.float32
    out = lax.conv_general_dilated(
        x.astype(acc_dtype),
        w.astype(acc_dtype),
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    if fuse_relu or epilogue == "relu":
        out = jnp.maximum(out, 0)
    elif epilogue == "squared_relu":
        r = jnp.maximum(out, 0)
        out = r * r
    elif epilogue is not None:
        raise ValueError(f"unsupported conv epilogue {epilogue!r}")
    return out


# ---------------------------------------------------------------------------
# DFG payload / epilogue primitives — shared by the DFG interpreter
# (repro.passes.interp) and the per-group Pallas lowering
# (repro.kernels.ops.lower_group), so both execute identical semantics.
# Kinds are the *string values* of repro.core.ir.PayloadKind (a str enum,
# so the enum members themselves compare equal and pass straight through).
# ---------------------------------------------------------------------------


def unary(kind: str, x: jax.Array) -> jax.Array:
    if kind == "relu":
        return jnp.maximum(x, 0)
    if kind == "squared_relu":
        r = jnp.maximum(x, 0)
        return r * r
    if kind == "identity":
        return x
    if kind == "exp":
        return jnp.exp(x.astype(jnp.float32))
    raise NotImplementedError(f"unary payload {kind}")


def binary(kind: str, a: jax.Array, b: jax.Array) -> jax.Array:
    if kind == "add":
        return a + b
    if kind == "mul":
        return a * b
    if kind == "max":
        return jnp.maximum(a, b)
    raise NotImplementedError(f"binary payload {kind}")


def _div_exact(x: jax.Array, n: int) -> jax.Array:
    """The DIV exit path shared by every avg-pool realization: floor
    division for integer accumulators (the int8 PTQ regime — identical
    semantics in the interpreter, the Pallas lowering, and the modeled
    HLS datapath), true division for floats."""
    if n == 1:
        return x
    if jnp.issubdtype(x.dtype, jnp.integer):
        return x // n
    return x / n


def pool_reduce(kind: str, x: jax.Array, window: tuple[int, ...]) -> jax.Array:
    """Non-overlapping window reduction: axis ``i`` shrinks by
    ``window[i]`` and ``kind`` combines each tile (a fused pool
    epilogue's semantics — max pool for kind="max").

    ``kind="avg"`` accumulates with ADD and takes the DIV exit path
    *once*, over the whole window product — not per axis — so integer
    floor division matches the single divider on the stream-exit
    datapath."""
    reducer = {"max": jnp.max, "add": jnp.sum, "avg": jnp.sum}.get(kind)
    if reducer is None:
        raise NotImplementedError(f"pool payload {kind}")
    count = 1
    for ax in range(x.ndim - 1, -1, -1):
        f = window[ax]
        if f <= 1:
            continue
        count *= f
        shp = x.shape
        assert shp[ax] % f == 0, (shp, window)
        x = x.reshape(shp[:ax] + (shp[ax] // f, f) + shp[ax + 1:])
        x = reducer(x, axis=ax + 1)
    if kind == "avg":
        x = _div_exact(x, count)
    return x


def maxpool2d(x: jax.Array, kh: int, kw: int, stride: int) -> jax.Array:
    """Standalone NHWC max pool (VALID padding) — the unfused oracle the
    conv+pool fusion pass is checked against."""
    if jnp.issubdtype(x.dtype, jnp.integer):
        init = jnp.iinfo(x.dtype).min
    else:
        init = -jnp.inf
    return lax.reduce_window(
        x, init, lax.max,
        window_dimensions=(1, kh, kw, 1),
        window_strides=(1, stride, stride, 1),
        padding="VALID",
    )


def avgpool2d(x: jax.Array, kh: int, kw: int, stride: int) -> jax.Array:
    """Standalone NHWC average pool (VALID padding): window ADDs in the
    accumulator dtype, then the shared DIV exit path — the unfused
    oracle the conv+avg-pool fusion is checked against."""
    if jnp.issubdtype(x.dtype, jnp.integer):
        acc = x.astype(jnp.int32)
        init = jnp.int32(0)
    else:
        acc = x.astype(jnp.float32)
        init = jnp.float32(0)
    summed = lax.reduce_window(
        acc, init, lax.add,
        window_dimensions=(1, kh, kw, 1),
        window_strides=(1, stride, stride, 1),
        padding="VALID",
    )
    return _div_exact(summed, kh * kw)


def apply_epilogue(out: jax.Array, epilogue, env) -> jax.Array:
    """Apply a chain of :class:`repro.core.ir.FusedEpilogue` entries
    (duck-typed: ``kind`` / ``operand`` / ``window`` attributes)."""
    for e in epilogue:
        window = getattr(e, "window", ())
        if window:
            out = pool_reduce(e.kind, out, window)
        elif e.operand is None:
            out = unary(e.kind, out)
        else:
            out = binary(e.kind, out, env[e.operand])
    return out


# ---------------------------------------------------------------------------
# multi-head / grouped-query attention
# ---------------------------------------------------------------------------


def attention(
    q: jax.Array,          # (B, Hq, Sq, D)
    k: jax.Array,          # (B, Hkv, Sk, D)
    v: jax.Array,          # (B, Hkv, Sk, D)
    *,
    causal: bool = True,
    scale: float | None = None,
    q_offset: int = 0,
) -> jax.Array:
    """GQA attention oracle.  Hq must be a multiple of Hkv; q_offset is the
    absolute position of q[0] (decode: q_offset = cache_len)."""
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    assert hq % hkv == 0
    g = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    qg = q.reshape(b, hkv, g, sq, d)
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        qpos = jnp.arange(sq)[:, None] + q_offset
        kpos = jnp.arange(sk)[None, :]
        mask = qpos >= kpos
        logits = jnp.where(mask[None, None, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return out.reshape(b, hq, sq, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# fused (optionally gated) MLP
# ---------------------------------------------------------------------------


def _act(name: str, x: jax.Array) -> jax.Array:
    if name == "silu":
        return jax.nn.silu(x)
    if name == "gelu":
        return jax.nn.gelu(x)
    if name == "relu":
        return jax.nn.relu(x)
    if name == "squared_relu":
        r = jnp.maximum(x, 0.0)
        return r * r
    raise ValueError(name)


def mlp(
    x: jax.Array,            # (M, D)
    w_gate: jax.Array | None,  # (D, F) or None for ungated
    w_up: jax.Array,         # (D, F)
    w_down: jax.Array,       # (F, D)
    *,
    act: str = "silu",
) -> jax.Array:
    """out = (act(x@Wg) * (x@Wu)) @ Wd, or act(x@Wu)@Wd when ungated.
    Accumulation in fp32, cast back to x.dtype."""
    xf = x.astype(jnp.float32)
    up = xf @ w_up.astype(jnp.float32)
    if w_gate is not None:
        gate = _act(act, xf @ w_gate.astype(jnp.float32))
        h = gate * up
    else:
        h = _act(act, up)
    out = h @ w_down.astype(jnp.float32)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Mamba-2 SSD (state-space duality), sequential-scan oracle
# ---------------------------------------------------------------------------


def ssd(
    x: jax.Array,        # (B, L, H, P)
    dt: jax.Array,       # (B, L, H)      softplus-activated step sizes
    a: jax.Array,        # (H,)           negative decay rates (A = -exp(a_log))
    b_mat: jax.Array,    # (B, L, N)      input gate (ngroups=1)
    c_mat: jax.Array,    # (B, L, N)      output gate (ngroups=1)
    *,
    init_state: jax.Array | None = None,   # (B, H, P, N)
) -> tuple[jax.Array, jax.Array]:
    """Exact recurrence (arXiv:2405.21060 Eq. SSD):

        S_t = exp(dt_t * a) * S_{t-1} + dt_t * x_t ⊗ b_t
        y_t = S_t @ c_t

    Returns (y (B,L,H,P), final_state (B,H,P,N)).  O(L) scan — oracle only.
    """
    bsz, l, h, p = x.shape
    n = b_mat.shape[-1]
    s0 = (
        init_state.astype(jnp.float32)
        if init_state is not None
        else jnp.zeros((bsz, h, p, n), jnp.float32)
    )
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    af = a.astype(jnp.float32)
    bf = b_mat.astype(jnp.float32)
    cf = c_mat.astype(jnp.float32)

    def step(state, t):
        dt_t = dtf[:, t]                          # (B, H)
        decay = jnp.exp(dt_t * af[None, :])       # (B, H)
        upd = jnp.einsum(
            "bhp,bn->bhpn", xf[:, t] * dt_t[..., None], bf[:, t]
        )
        state = state * decay[..., None, None] + upd
        y_t = jnp.einsum("bhpn,bn->bhp", state, cf[:, t])
        return state, y_t

    final, ys = lax.scan(step, s0, jnp.arange(l))
    y = jnp.moveaxis(ys, 0, 1)                    # (B, L, H, P)
    return y.astype(x.dtype), final


def ssd_chunked(
    x: jax.Array,
    dt: jax.Array,
    a: jax.Array,
    b_mat: jax.Array,
    c_mat: jax.Array,
    *,
    chunk: int = 16,
    init_state: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Chunked SSD (the algorithm the Pallas kernel implements): intra-chunk
    quadratic term + inter-chunk state carry.  Must match :func:`ssd`."""
    bsz, l, h, p = x.shape
    n = b_mat.shape[-1]
    assert l % chunk == 0, "oracle requires chunk | L"
    nc = l // chunk
    xf = x.astype(jnp.float32).reshape(bsz, nc, chunk, h, p)
    dtf = dt.astype(jnp.float32).reshape(bsz, nc, chunk, h)
    af = a.astype(jnp.float32)
    bf = b_mat.astype(jnp.float32).reshape(bsz, nc, chunk, n)
    cf = c_mat.astype(jnp.float32).reshape(bsz, nc, chunk, n)

    # per-position log decay within a chunk: cum_t = sum_{i<=t} dt_i * a
    da = dtf * af[None, None, None, :]                 # (B,NC,Q,H)
    cum = jnp.cumsum(da, axis=2)                       # inclusive
    s0 = (
        init_state.astype(jnp.float32)
        if init_state is not None
        else jnp.zeros((bsz, h, p, n), jnp.float32)
    )

    def chunk_step(state, ci):
        xq, dq, bq, cq = xf[:, ci], dtf[:, ci], bf[:, ci], cf[:, ci]
        cumq = cum[:, ci]                              # (B,Q,H)
        # intra-chunk: y_intra[t] = sum_{s<=t} exp(cum_t - cum_s) dt_s (c_t·b_s) x_s
        rel = cumq[:, :, None, :] - cumq[:, None, :, :]      # (B,Q,Q,H) t,s
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        gate = jnp.where(tri[None, :, :, None], jnp.exp(rel), 0.0)
        cb = jnp.einsum("btn,bsn->bts", cq, bq)              # (B,Q,Q)
        w = cb[..., None] * gate * dq[:, None, :, :]          # (B,t,s,H)
        y_intra = jnp.einsum("btsh,bshp->bthp", w, xq)
        # inter-chunk: contribution of carried state
        dec_t = jnp.exp(cumq)                                 # (B,Q,H)
        y_inter = jnp.einsum(
            "bqn,bhpn,bqh->bqhp", cq, state, dec_t
        )
        # state update: S' = exp(cum_Q) * S + sum_s exp(cum_Q - cum_s) dt_s x_s ⊗ b_s
        dec_chunk = jnp.exp(cumq[:, -1])                      # (B,H)
        carry_gate = jnp.exp(cumq[:, -1, None, :] - cumq)     # (B,Q,H)
        upd = jnp.einsum(
            "bqhp,bqn->bhpn", xq * (dq * carry_gate)[..., None], bq
        )
        state = state * dec_chunk[..., None, None] + upd
        return state, y_intra + y_inter

    final, ys = lax.scan(chunk_step, s0, jnp.arange(nc))
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, l, h, p)
    return y.astype(x.dtype), final


def ssd_decode_step(
    state: jax.Array,    # (B, H, P, N)
    x_t: jax.Array,      # (B, H, P)
    dt_t: jax.Array,     # (B, H)
    a: jax.Array,        # (H,)
    b_t: jax.Array,      # (B, N)
    c_t: jax.Array,      # (B, N)
) -> tuple[jax.Array, jax.Array]:
    """Single-token recurrent step (decode path)."""
    sf = state.astype(jnp.float32)
    decay = jnp.exp(dt_t.astype(jnp.float32) * a.astype(jnp.float32)[None])
    upd = jnp.einsum(
        "bhp,bn->bhpn",
        x_t.astype(jnp.float32) * dt_t.astype(jnp.float32)[..., None],
        b_t.astype(jnp.float32),
    )
    new = sf * decay[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", new, c_t.astype(jnp.float32))
    return y.astype(x_t.dtype), new
