"""Line-buffer streaming conv2d (+fused ReLU) — Pallas TPU kernel.

This is the TPU adaptation of MING's centerpiece (paper Sec. IV-B): a
sliding-window node that *streams* input rows instead of materializing
the input tensor on-chip.  The mapping:

  FPGA                              TPU (this kernel)
  ----------------------------      ---------------------------------
  hls::stream row arrivals          sequential grid steps (R rows each)
  (K-1)×N BRAM line buffer          VMEM scratch (KH-1, Wp, Cin),
                                    persisted across grid steps
  K×K window regs + DSP MAC tree    (R,W,Cin)×(Cin,Cout) MXU matmuls,
                                    one per (kh, kw) tap
  fused ReLU node (pure parallel)   fused max(acc, 0) before writeback

The kernel is *causal*: output row ``j`` of the padded frame is the conv
window ending at padded row ``j``.  ``ops.conv2d_stream`` pre-pads the
frame and slices ``[KH-1 : KH-1+H]``, recovering exact SAME-padding
semantics (validated against ``ref.conv2d``).

Grid: ``(B, Hp // rows_per_block)`` — the row-block count is chosen by
the DSE (``repro.core.dse.plan_conv_rows``) so the VMEM working set
(line buffer + weights + R output rows) fits the budget, the direct dual
of the paper's BRAM constraint.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _acc_dtype(dtype) -> jnp.dtype:
    return jnp.int32 if jnp.issubdtype(dtype, jnp.integer) else jnp.float32


#: fused-epilogue kinds the conv path supports — mirrors the DFG-level
#: FusedEpilogue kinds the fusion passes fold into a MAC node.  Applied
#: to the int32/f32 accumulator in VMEM before writeback, so the fused
#: activation costs zero extra HBM traffic (the TPU dual of the FPGA
#: epilogue running on the stream-exit datapath).
CONV_EPILOGUES = ("relu", "squared_relu")


def _apply_epilogue(acc, epilogue: str | None):
    if epilogue is None:
        return acc
    if epilogue == "relu":
        return jnp.maximum(acc, 0)
    if epilogue == "squared_relu":
        r = jnp.maximum(acc, 0)
        return r * r
    raise ValueError(f"unsupported conv epilogue {epilogue!r}")


def line_buffer_rows(kh: int, stride: int) -> int:
    """Rows the line buffer must carry between row blocks.

    At stride ``s`` each emitted output row advances the read window by
    ``s`` input rows, so only ``max(kh - s, 0)`` rows of the previous
    block are re-read by the next one — the stride-1 case degenerates to
    the paper's ``K-1`` rows, and ``s >= kh`` needs no carry at all
    (windows never overlap vertically)."""
    return max(kh - stride, 0)


def _conv_stream_kernel(
    x_ref,      # (1, Rin, Wp, Cin)  current row block (the "stream")
    w_ref,      # (KH, KW, Cin, Cout)
    o_ref,      # (1, Rin//s, W, Cout)
    lb_ref,     # (max(KH-s,0), Wp, Cin)  the line buffer (VMEM scratch)
    *,
    kh: int,
    kw: int,
    w_out: int,
    stride: int,
    epilogue: str | None,
):
    i = pl.program_id(1)
    acc_t = _acc_dtype(o_ref.dtype)
    carry = line_buffer_rows(kh, stride)

    @pl.when(i == 0)
    def _init():
        lb_ref[...] = jnp.zeros_like(lb_ref)

    cur = x_ref[0]                                   # (Rin, Wp, Cin)
    if carry > 0:
        window = jnp.concatenate([lb_ref[...], cur], axis=0)  # (carry+Rin, ...)
    else:
        window = cur
    r_out = cur.shape[0] // stride                   # output rows per block

    acc = jnp.zeros((r_out, w_out, o_ref.shape[-1]), acc_t)
    for dh in range(kh):
        for dw in range(kw):
            patch = window[
                dh : dh + (r_out - 1) * stride + 1 : stride,
                dw : dw + (w_out - 1) * stride + 1 : stride,
                :,
            ]                                                  # (Rout, W, Cin)
            tap = w_ref[dh, dw]                                # (Cin, Cout)
            acc = acc + jax.lax.dot_general(
                patch,
                tap,
                (((2,), (0,)), ((), ())),
                preferred_element_type=acc_t,
            )
    acc = _apply_epilogue(acc, epilogue)
    o_ref[...] = acc[None].astype(o_ref.dtype)

    if carry > 0:
        lb_ref[...] = window[-carry:]


def conv2d_stream_pallas(
    x_padded: jax.Array,     # (B, Hp, Wp, Cin) — pre-padded frame
    w: jax.Array,            # (KH, KW, Cin, Cout)
    *,
    rows_per_block: int,
    w_out: int,
    stride: int = 1,
    fuse_relu: bool = False,
    epilogue: str | None = None,
    interpret: bool = False,
) -> jax.Array:
    """Raw pallas_call; see ``ops.conv2d_stream`` for the public wrapper.

    ``rows_per_block`` counts *input* rows per grid step and must be a
    multiple of ``stride``; each step emits ``rows_per_block // stride``
    output rows (every ``stride``-th window row — the line-buffer
    discipline at stride ``s``).  ``epilogue`` generalizes ``fuse_relu``
    to any supported fused elementwise tail (``CONV_EPILOGUES``);
    ``fuse_relu=True`` is kept as sugar for ``epilogue="relu"``.
    """
    if fuse_relu:
        if epilogue not in (None, "relu"):
            raise ValueError("fuse_relu=True conflicts with epilogue="
                             f"{epilogue!r}")
        epilogue = "relu"
    b, hp, wp, cin = x_padded.shape
    kh, kw_, _, cout = w.shape
    assert hp % rows_per_block == 0, (hp, rows_per_block)
    assert rows_per_block % stride == 0, (rows_per_block, stride)
    nb = hp // rows_per_block
    rows_out = rows_per_block // stride
    acc_t = _acc_dtype(x_padded.dtype)

    kernel = functools.partial(
        _conv_stream_kernel, kh=kh, kw=kw_, w_out=w_out, stride=stride,
        epilogue=epilogue
    )
    return pl.pallas_call(
        kernel,
        grid=(b, nb),
        in_specs=[
            pl.BlockSpec(
                (1, rows_per_block, wp, cin), lambda bb, i: (bb, i, 0, 0)
            ),
            pl.BlockSpec((kh, kw_, cin, cout), lambda bb, i: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, rows_out, w_out, cout), lambda bb, i: (bb, i, 0, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((b, hp // stride, w_out, cout), acc_t),
        scratch_shapes=[pltpu.VMEM(
            (max(line_buffer_rows(kh, stride), 1), wp, cin), x_padded.dtype
        )],
        interpret=interpret,
    )(x_padded, w)
