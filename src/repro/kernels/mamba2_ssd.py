"""Mamba-2 SSD (state-space duality) chunked scan — Pallas TPU kernel.

The SSM arch is the one with the strongest affinity to the paper's idea
(DESIGN.md §4): the recurrent state S ∈ (H, P, N) *is* a line buffer over
the time axis — O(1) on-chip state instead of an O(L²) attention matrix
or an O(L) materialized history.  Chunks stream through VMEM; the carry
lives in scratch across grid steps exactly like the conv line buffer.

Per chunk (arXiv:2405.21060):
  y_intra[t] = Σ_{s≤t} exp(cum_t − cum_s) · dt_s · (c_t·b_s) · x_s
  y_inter[t] = exp(cum_t) · c_t · S_prev
  S_new      = exp(cum_Q) · S_prev + Σ_s exp(cum_Q − cum_s) dt_s x_s ⊗ b_s

Grid: (B, L/Q), chunk index innermost (sequential stream).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(
    x_ref,       # (1, Q, H, P)
    dt_ref,      # (1, Q, H)
    a_ref,       # (1, H)
    b_ref,       # (1, Q, N)
    c_ref,       # (1, Q, N)
    s0_ref,      # (1, H, P, N)  initial state (consumed at ci == 0)
    y_ref,       # (1, Q, H, P)
    sf_ref,      # (1, H, P, N)  final state (written at last chunk)
    state_ref,   # (H, P, N) f32 scratch — the time-axis line buffer
    *,
    chunk: int,
    num_chunks: int,
):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = s0_ref[0].astype(jnp.float32)

    x = x_ref[0].astype(jnp.float32)          # (Q, H, P)
    dt = dt_ref[0].astype(jnp.float32)        # (Q, H)
    a = a_ref[0].astype(jnp.float32)          # (H,)
    b = b_ref[0].astype(jnp.float32)          # (Q, N)
    c = c_ref[0].astype(jnp.float32)          # (Q, N)

    da = dt * a[None, :]                      # (Q, H)
    cum = jnp.cumsum(da, axis=0)              # (Q, H) inclusive

    # intra-chunk (quadratic in Q, like a tiny causal attention)
    rel = cum[:, None, :] - cum[None, :, :]   # (Q, Q, H): t, s
    tri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    gate = jnp.where(tri[:, :, None], jnp.exp(rel), 0.0)       # (Q, Q, H)
    cb = jax.lax.dot_general(
        c, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                           # (Q, Q): t, s
    w = cb[:, :, None] * gate * dt[None, :, :]  # (t, s, H)
    y_intra = jnp.einsum("tsh,shp->thp", w, x)

    # inter-chunk: carried state contribution
    state = state_ref[...]                      # (H, P, N)
    dec_t = jnp.exp(cum)                        # (Q, H)
    y_inter = jnp.einsum("qn,hpn,qh->qhp", c, state, dec_t)

    y_ref[...] = (y_intra + y_inter)[None].astype(y_ref.dtype)

    # state update
    dec_chunk = jnp.exp(cum[-1])                # (H,)
    carry_gate = jnp.exp(cum[-1][None, :] - cum)  # (Q, H)
    upd = jnp.einsum("qhp,qn->hpn", x * (dt * carry_gate)[:, :, None], b)
    new_state = state * dec_chunk[:, None, None] + upd
    state_ref[...] = new_state

    @pl.when(ci == num_chunks - 1)
    def _final():
        sf_ref[...] = new_state[None].astype(sf_ref.dtype)


def mamba2_ssd_pallas(
    x: jax.Array,        # (B, L, H, P)
    dt: jax.Array,       # (B, L, H)
    a: jax.Array,        # (H,)
    b_mat: jax.Array,    # (B, L, N)
    c_mat: jax.Array,    # (B, L, N)
    init_state: jax.Array,   # (B, H, P, N)
    *,
    chunk: int,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    bsz, l, h, p = x.shape
    n = b_mat.shape[-1]
    assert l % chunk == 0, (l, chunk)
    nc = l // chunk
    a2 = a[None].astype(jnp.float32)          # (1, H) — 2D for TPU layout

    kernel = functools.partial(_ssd_kernel, chunk=chunk, num_chunks=nc)
    y, sf = pl.pallas_call(
        kernel,
        grid=(bsz, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, h, p), lambda b, ci: (b, ci, 0, 0)),
            pl.BlockSpec((1, chunk, h), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, h), lambda b, ci: (0, 0)),
            pl.BlockSpec((1, chunk, n), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, h, p, n), lambda b, ci: (b, 0, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, h, p), lambda b, ci: (b, ci, 0, 0)),
            pl.BlockSpec((1, h, p, n), lambda b, ci: (b, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, l, h, p), x.dtype),
            jax.ShapeDtypeStruct((bsz, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((h, p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, a2, b_mat, c_mat, init_state)
    return y, sf
