"""Public jit'd wrappers for the Pallas kernels + the TPU consumer of
the schedule IR.

Each wrapper:
  * derives legal tile sizes from the MING DSE (``repro.core.dse``) under
    the VMEM budget — the paper's ILP with TPU-dual constraints,
  * handles padding / reshaping so callers see clean dense semantics,
  * validates in interpret mode on CPU (``interpret=None`` → auto).

The oracles live in ``ref.py``; ``tests/test_kernels.py`` sweeps
shapes/dtypes asserting allclose between the two.

``lower_group`` / ``run_compiled`` are the TPU duals of the HLS
emitter: they consume the *same*
:class:`repro.core.compile_driver.CompiledDesign` the FPGA path emits
from — each :class:`GroupSchedule` lowers to one jit-compiled fused
executable (streaming conv kernels with fused epilogues, map-driven
einsum reductions, elementwise tails), and ``run_compiled`` chains the
groups through a value environment exactly as the emitted
``host_schedule.cpp`` threads DRAM spill buffers.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import math
import threading
import time
from typing import Literal

import jax
import jax.numpy as jnp

import repro.instrument as instrument
from repro.instrument import metrics as _metrics

from repro.core.analysis import (
    KernelClass,
    classify_kernel,
    conv_spatial_pads,
    einsum_spec,
    reorder_spec,
    window_geometry,
)
from repro.core.dse import plan_attention_blocks, plan_conv_rows, plan_matmul_blocks
from repro.core.ir import PayloadKind
from . import conv2d_stream as _conv
from . import flash_attention as _flash
from . import fused_mlp as _mlp
from . import mamba2_ssd as _ssd
from . import ref as _ref


def _auto_interpret(interpret: bool | None) -> bool:
    if interpret is None:
        return jax.default_backend() == "cpu"
    return interpret


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _pick_block(size: int, target: int) -> int:
    """Largest divisor of ``size`` that is ≤ target (≥ 1)."""
    best = 1
    for d in range(1, size + 1):
        if size % d == 0 and d <= target:
            best = d
    return best


# ---------------------------------------------------------------------------
# conv2d_stream
# ---------------------------------------------------------------------------


Padding = str | tuple[tuple[int, int], tuple[int, int]]


def _conv_pads(
    h: int, w: int, kh: int, kw: int, stride: int, padding: Padding
) -> tuple[tuple[int, int], tuple[int, int]]:
    """Resolve ``padding`` to explicit ((top, bottom), (left, right)).

    ``"SAME"`` splits the deficit end-heavy (``begin = total // 2`` —
    the XLA / ONNX SAME_UPPER convention; at stride 1 with odd kernels
    this is the symmetric ``(k-1)//2`` frame), ``"VALID"`` pads nothing,
    and an explicit pair-of-pairs passes through (the importer's
    asymmetric-pads path).
    """
    if isinstance(padding, str):
        if padding == "SAME":
            def same(n: int, k: int) -> tuple[int, int]:
                out = -(-n // stride)
                total = max(0, stride * (out - 1) + k - n)
                return total // 2, total - total // 2
            return same(h, kh), same(w, kw)
        if padding == "VALID":
            if kh > h or kw > w:
                raise ValueError(
                    f"VALID conv kernel ({kh}x{kw}) exceeds input ({h}x{w})"
                )
            return (0, 0), (0, 0)
        raise ValueError(f"unsupported padding {padding!r}")
    (pt, pb), (pl, pr) = padding
    return (int(pt), int(pb)), (int(pl), int(pr))


def conv2d_stream(
    x: jax.Array,            # (B, H, W, Cin)
    w: jax.Array,            # (KH, KW, Cin, Cout)
    *,
    stride: int = 1,
    padding: Padding = "SAME",
    fuse_relu: bool = False,
    epilogue: str | None = None,
    rows_per_block: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """NHWC conv via the line-buffer streaming kernel (stride-s, SAME /
    VALID / explicit pads).

    Returns int32 accumulators for integer inputs (paper's int8 PTQ path),
    f32 otherwise — requantization is the caller's (graph's) concern.

    ``epilogue`` fuses an elementwise tail into the kernel's writeback
    (``"relu"`` | ``"squared_relu"``) — the TPU realization of the pass
    pipeline's conv+activation fusion (``repro.passes.fusion``);
    ``fuse_relu=True`` remains as sugar for ``epilogue="relu"``.

    Stride-s alignment: the kernel emits one output row per ``stride``
    input rows of the *aligned* frame, and output row ``g`` reads
    aligned rows ``[g*s - C, g*s - C + kh - 1]`` where ``C`` is the
    line-buffer carry (``line_buffer_rows``).  Prepending ``A = c*s - C``
    zero rows (``c = ceil(C/s)``) makes emitted row ``t + c`` read padded
    rows ``[t*s, t*s + kh - 1]`` — so the first ``c`` output rows are
    discarded and the valid output is ``out[:, c : c + h_out]``.  At
    stride 1 this degenerates to the original causal trick:
    ``C = c = kh - 1``, ``A = 0``, slice ``[kh-1 : kh-1+h]``.
    """
    interpret = _auto_interpret(interpret)
    b, h, ww, cin = x.shape
    kh, kw, _, cout = w.shape
    (pad_t, pad_b), (pad_l, pad_r) = _conv_pads(h, ww, kh, kw, stride, padding)
    h_out = (h + pad_t + pad_b - kh) // stride + 1
    w_out = (ww + pad_l + pad_r - kw) // stride + 1

    carry = _conv.line_buffer_rows(kh, stride)
    c_skip = -(-carry // stride)            # garbage leading output rows
    align = c_skip * stride - carry         # extra zero rows on top
    hp = align + pad_t + h + pad_b
    if rows_per_block is None:
        plan = plan_conv_rows(
            h=hp, w=ww + pad_l + pad_r, c_in=cin, c_out=cout, kh=kh, kw=kw,
            bytes_per_el=x.dtype.itemsize,
        )
        rows_per_block = _round_up(plan.blocks["rows"], stride)
    # rows_per_block must divide hp — pad the bottom if necessary
    hp_pad = _round_up(hp, rows_per_block)
    x_p = jnp.pad(
        x,
        ((0, 0), (align + pad_t, pad_b + (hp_pad - hp)),
         (pad_l, pad_r), (0, 0)),
    )
    out = _conv.conv2d_stream_pallas(
        x_p,
        w,
        rows_per_block=rows_per_block,
        w_out=w_out,
        stride=stride,
        fuse_relu=fuse_relu,
        epilogue=epilogue,
        interpret=interpret,
    )
    return out[:, c_skip : c_skip + h_out]


def conv2d_same_mm(
    x: jax.Array, w: jax.Array, *,
    stride: int = 1, padding: Padding = "SAME",
) -> jax.Array:
    """NHWC conv as KH·KW shifted channel matmuls.

    The throughput lowering the *batched* executables use for integer
    inputs: XLA's CPU path for integer ``lax.conv`` is a naive loop, an
    order of magnitude slower than its integer dot — so the conv is
    decomposed into one ``(N·H·W, Cin) @ (Cin, Cout)`` matmul per
    kernel tap, accumulated in **int32** — the same accumulator the
    streaming kernel (``conv2d_stream._acc_dtype``) and the dense
    oracle use, so sub-int32 inputs (the paper's int8 PTQ regime) get
    real int32 accumulators, not input-dtype wraparound.  Operands are
    cast to int32 *before* the matmuls: truncation mod 2³² commutes
    with integer multiply/add, so this is bit-exact with the streaming
    Pallas kernel for every integer width (including on int32
    overflow, which wraps identically everywhere).  Float inputs must
    NOT take this path — float summation order changes ulps — and keep
    the Pallas kernel.
    """
    kh, kw, cin, cout = w.shape
    if jnp.issubdtype(x.dtype, jnp.integer):
        x = x.astype(jnp.int32)
        w = w.astype(jnp.int32)
    n, h, wd, _ = x.shape
    (pad_t, pad_b), (pad_l, pad_r) = _conv_pads(h, wd, kh, kw, stride, padding)
    xp = jnp.pad(x, ((0, 0), (pad_t, pad_b), (pad_l, pad_r), (0, 0)))
    h_out = (h + pad_t + pad_b - kh) // stride + 1
    w_out = (wd + pad_l + pad_r - kw) // stride + 1
    out = None
    for dy in range(kh):
        for dx in range(kw):
            patch = xp[
                :,
                dy : dy + (h_out - 1) * stride + 1 : stride,
                dx : dx + (w_out - 1) * stride + 1 : stride,
                :,
            ]
            tap = jnp.einsum("nhwc,co->nhwo", patch, w[dy, dx])
            out = tap if out is None else out + tap
    return out


# ---------------------------------------------------------------------------
# Schedule-IR consumer: one fused executable per GroupSchedule
# ---------------------------------------------------------------------------

#: epilogue kinds the conv kernel applies *inside* the Pallas kernel
#: (on the VMEM accumulator, before writeback)
_IN_KERNEL_EPILOGUES = {
    PayloadKind.RELU: "relu",
    PayloadKind.SQUARED_RELU: "squared_relu",
}


def _split_conv_epilogue(op):
    """(in-kernel epilogue string, remaining epilogue entries) for a
    conv node: a leading unary relu/squared_relu runs on the kernel's
    accumulator; everything after (constant binops, fused pools) applies
    to the kernel's output inside the same jit unit."""
    epi = list(op.epilogue)
    if epi and epi[0].operand is None and not epi[0].window and (
        epi[0].kind in _IN_KERNEL_EPILOGUES
    ):
        return _IN_KERNEL_EPILOGUES[epi[0].kind], epi[1:]
    return None, epi


def _weight_tile_axes(op, dfg):
    """(const input name, const tensor axis, output tensor axis) for the
    *leading* weight-tileable dim of a streamed-weight node — the axis
    the DSE's ``weight_tiles`` splits the const buffer along (c_out for
    an NHWC conv, n_out for a matmul; ``NodePlan.weight_tile_dims[0]``,
    recomputed here from the maps).  ``None`` when no safe tile axis
    exists (the untiled lowering is numerically identical either way)."""
    info = classify_kernel(op)
    window = set(info.classes.window)
    cands = []  # (dim, input index, input name, const axis, output axis)
    for i, name in enumerate(op.inputs):
        if not dfg.values[name].is_constant:
            continue
        for pos, expr in enumerate(op.input_maps[i].results):
            if not expr.is_single_dim():
                continue
            (d, _), = expr.terms
            if not (op.is_parallel_dim(d) and d not in window):
                continue
            out_axis = next(
                (
                    q for q, oe in enumerate(op.output_map.results)
                    if oe.is_single_dim() and oe.terms[0][0] == d
                ),
                None,
            )
            if out_axis is not None:
                cands.append((d, i, name, pos, out_axis))
    if not cands:
        return None
    d, i, name, pos, out_axis = min(cands)  # leading dim, like plan_node
    # slicing one operand is only sound if no other input reads dim d
    for j, other in enumerate(op.inputs):
        if j == i:
            continue
        if any(d in expr.dims() for expr in op.input_maps[j].results):
            return None
    return name, pos, out_axis


def _lower_node(op, dfg, env, interpret: bool, weight_tiles: int = 1,
                fast_int_conv: bool = False):
    """Execute one GenericOp with the kernel library (jit-traceable).

    ``weight_tiles > 1`` honors the schedule's partial weight streaming:
    the const operand is processed in output-channel tiles (the TPU
    stand-in for the HLS kernel's double-buffered DRAM ``wtile`` loop)
    and the partial results concatenated — bit-exact with the resident
    lowering, but structurally the same tiled schedule the emitter
    realizes.

    ``fast_int_conv`` (the batched-executable path) lowers
    integer-dtype convs through :func:`conv2d_same_mm` instead of the
    streaming Pallas kernel — bit-exact for integers (modular addition
    is order-independent), and the difference between ~2× and ~8×
    batched throughput on CPU.  Float convs ignore the flag and keep
    the Pallas kernel so batched and per-sample runs stay bit-exact.
    """
    if weight_tiles > 1:
        tiled = _weight_tile_axes(op, dfg)
        if tiled is not None:
            cname, cax, oax = tiled
            w = env[cname]
            if w.shape[cax] % weight_tiles == 0:
                bare = dataclasses.replace(op, epilogue=())
                step = w.shape[cax] // weight_tiles
                parts = [
                    _lower_node(
                        bare, dfg,
                        {**env, cname: jax.lax.slice_in_dim(
                            w, t * step, (t + 1) * step, axis=cax)},
                        interpret, fast_int_conv=fast_int_conv,
                    )
                    for t in range(weight_tiles)
                ]
                out = jnp.concatenate(parts, axis=oax)
                return _ref.apply_epilogue(out, op.epilogue, env)
    info = classify_kernel(op)
    if info.kernel_class == KernelClass.SLIDING_WINDOW:
        if op.payload == PayloadKind.MAC:
            stream = [i for i in op.inputs if not dfg.values[i].is_constant]
            const = [i for i in op.inputs if dfg.values[i].is_constant]
            if (
                len(stream) == 1 and len(const) == 1
                and op.n_dims == 7 and info.dilation == 1
            ):
                x_in = env[stream[0]]
                # the maps determine the reach; whatever exceeds the
                # actual input extent is the zero-padding frame (SAME
                # splits end-heavy, VALID reads within bounds -> (0,0))
                pads = conv_spatial_pads(op, tuple(x_in.shape))
                padding = (pads[1], pads[2])
                if fast_int_conv and jnp.issubdtype(
                    x_in.dtype, jnp.integer
                ):
                    out = conv2d_same_mm(x_in, env[const[0]],
                                         stride=info.stride, padding=padding)
                    return _ref.apply_epilogue(out, op.epilogue, env)
                kern_epi, rest = _split_conv_epilogue(op)
                out = conv2d_stream(
                    x_in, env[const[0]],
                    stride=info.stride, padding=padding,
                    epilogue=kern_epi, interpret=interpret,
                )
                return _ref.apply_epilogue(out, rest, env)
            # keep parity with the interpreter: fail loudly rather
            # than silently computing a dilation-1 conv
            raise NotImplementedError(
                f"{op.name}: unsupported conv form in lower_group"
            )
        if (
            op.payload in (PayloadKind.MAX, PayloadKind.AVG)
            and len(op.inputs) == 1
        ):
            geo = window_geometry(op, info)
            kh, kw = geo.window_extents
            pool = (
                _ref.maxpool2d if op.payload == PayloadKind.MAX
                else _ref.avgpool2d
            )
            out = pool(env[op.inputs[0]], kh, kw, info.stride)
            return _ref.apply_epilogue(out, op.epilogue, env)
        raise NotImplementedError(f"{op.name}: unsupported sliding window")
    if info.kernel_class == KernelClass.REGULAR_REDUCTION:
        if op.payload != PayloadKind.MAC:
            raise NotImplementedError(f"{op.name}: non-MAC reduction")
        out = jnp.einsum(einsum_spec(op), *(env[i] for i in op.inputs))
        return _ref.apply_epilogue(out, op.epilogue, env)
    # PURE_PARALLEL
    if reorder_spec(op) is not None:
        from repro.passes.interp import execute_reorder

        out = execute_reorder(op, env[op.inputs[0]])
        return _ref.apply_epilogue(out, op.epilogue, env)
    args = [env[i] for i in op.inputs]
    if len(args) == 1:
        out = _ref.unary(op.payload, args[0])
    elif len(args) == 2:
        out = _ref.binary(op.payload, args[0], args[1])
    else:
        raise NotImplementedError(f"{op.name}: {len(args)}-ary elementwise")
    return _ref.apply_epilogue(out, op.epilogue, env)


#: executables per group *structure* — repeated ``run_compiled`` calls
#: (batched inference, benchmark sweeps) reuse the traced/jitted unit
#: instead of re-jitting per call (ROADMAP "lower_group jits per call").
#: A true LRU (ISSUE 7): hits refresh recency, inserts beyond the cap
#: evict the least-recently-used executable — across many signatures ×
#: batch buckets the cache stays bounded instead of growing forever.
_EXEC_CACHE: "collections.OrderedDict[tuple, object]" = \
    collections.OrderedDict()
_EXEC_CACHE_CAP = 128
#: ServeEngine worker threads hit lower_group concurrently with
#: main-thread runs; the LRU mutates on every access (move_to_end /
#: popitem), so lookup+insert+stats form one critical section.
_EXEC_CACHE_LOCK = threading.Lock()
#: observability for tests and benchmarks (evictions per ISSUE 7)
exec_cache_stats = {"hits": 0, "misses": 0, "evictions": 0}


#: the batch extents batched executables are traced at: a batched run
#: pads its batch up to the nearest bucket (and chunks above the top
#: one), so at most ``len(BATCH_BUCKETS)`` compiles happen per group
#: signature no matter what batch sizes traffic brings.
BATCH_BUCKETS = (1, 2, 4, 8, 16, 32, 64)


def batch_bucket(n: int) -> int:
    """The padded batch extent ``n`` executes at: the smallest bucket
    ≥ ``n``.  ``n`` must not exceed the top bucket (the runner chunks
    larger batches before bucketing)."""
    if n < 1:
        raise ValueError(f"batch extent must be >= 1, got {n}")
    for b in BATCH_BUCKETS:
        if n <= b:
            return b
    raise ValueError(
        f"batch extent {n} exceeds the top bucket {BATCH_BUCKETS[-1]} — "
        "chunk the batch first (run_compiled_batched does)"
    )


def _batch_chunks(batch: int):
    """Split ``batch`` into (start, n, bucket) chunks of at most the
    top bucket each, so any offered batch executes with a bounded set
    of traced shapes."""
    cap = BATCH_BUCKETS[-1]
    start = 0
    while start < batch:
        n = min(batch - start, cap)
        yield start, n, batch_bucket(n)
        start += n


def _group_signature(group, interpret: bool) -> tuple:
    """Hashable identity of everything the lowered executable depends
    on: node structure (maps, iterators, payloads, epilogues), value
    shapes/bits/names (env keys!), the group's streamed-weight tiling,
    and the interpret flag.  Constants arrive through ``env`` at call
    time, so they are deliberately *not* part of the key."""
    dfg = group.dfg
    sig: list = [interpret, tuple(dfg.graph_inputs), tuple(dfg.graph_outputs)]
    for op in dfg.topo_order():
        sig.append((
            op.name,
            op.inputs,
            op.output,
            tuple(str(m) for m in op.indexing_maps),
            tuple(t.value for t in op.iterator_types),
            op.dim_sizes,
            op.payload.value,
            op.elem_bits,
            tuple(
                (e.kind.value, e.operand, tuple(e.window) if e.window else ())
                for e in op.epilogue
            ),
            group.dse.weight_tiles.get(op.name, 1),
            tuple(
                (v, dfg.values[v].shape, dfg.values[v].elem_bits,
                 dfg.values[v].is_constant)
                for v in op.inputs + (op.output,)
            ),
        ))
    return tuple(sig)


def _build_group_fn(group, interpret: bool, jit: bool,
                    batch: int | None = None):
    """The uncached lowering — separable so tests can probe compile
    counts (the cache satellite of ISSUE 3; batched probes in ISSUE 7).

    ``batch`` (ISSUE 7) builds the *batched* executable: the per-sample
    group fn vmapped over a leading batch axis of extent ``batch`` on
    every non-constant value (graph inputs, spill values), constants
    broadcast unbatched.  Integer convs take the
    :func:`conv2d_same_mm` throughput lowering inside the vmapped unit.
    """
    dfg = group.dfg
    order = dfg.topo_order()
    tiles = dict(group.dse.weight_tiles)
    needed = set(dfg.graph_inputs) | {
        v for v, val in dfg.values.items() if val.is_constant
    }

    def run(env):
        env = dict(env)
        for op in order:
            env[op.output] = _lower_node(
                op, dfg, env, interpret,
                weight_tiles=tiles.get(op.name, 1),
                fast_int_conv=batch is not None,
            )
        return {v: env[v] for v in dfg.graph_outputs}

    if batch is not None:
        axes = ({
            k: (None if dfg.values[k].is_constant else 0) for k in needed
        },)
        run = jax.vmap(run, in_axes=axes)
    if not jit:
        return lambda env: run({k: v for k, v in env.items() if k in needed})
    jitted = jax.jit(run)
    return lambda env: jitted({k: v for k, v in env.items() if k in needed})


def lower_group(group, *, interpret: bool | None = None, jit: bool = True,
                batch: int | None = None):
    """Lower one :class:`~repro.core.compile_driver.GroupSchedule` to a
    fused executable: ``fn(env) -> {output name: array}``.

    ``env`` must bind the group's graph inputs (spill values included)
    and constants.  All nodes trace into one jit unit — the TPU analogue
    of the group's single DATAFLOW kernel: intermediates stay in
    VMEM/registers, epilogues (activations, constant binops, fused
    pools) ride the producing kernel; weight-streamed nodes run the
    tiled const-buffer schedule.  Executables are cached (LRU) per
    group signature (+ interpret flag + batch bucket), so recompiling
    or re-running the same design never re-jits.

    ``batch`` asks for the vmapped batched executable at exactly that
    (bucketed!) batch extent: non-constant env entries must carry a
    leading axis of that extent, outputs gain one.  Callers round to a
    :data:`BATCH_BUCKETS` bucket first so the cache sees a bounded key
    set (``run_compiled_batched`` handles padding/chunking).
    """
    interpret = _auto_interpret(interpret)
    if not jit:
        return _build_group_fn(group, interpret, jit=False, batch=batch)
    key = _group_signature(group, interpret) + ("batch", batch)
    with _EXEC_CACHE_LOCK:
        fn = _EXEC_CACHE.get(key)
        if fn is None:
            exec_cache_stats["misses"] += 1
            event = "miss"
            # building is cheap (jax.jit defers tracing to first call),
            # so holding the lock keeps the insert/evict atomic
            fn = _build_group_fn(group, interpret, jit=True, batch=batch)
            while len(_EXEC_CACHE) >= _EXEC_CACHE_CAP:  # LRU eviction
                _EXEC_CACHE.popitem(last=False)
                exec_cache_stats["evictions"] += 1
            _EXEC_CACHE[key] = fn
        else:
            _EXEC_CACHE.move_to_end(key)
            exec_cache_stats["hits"] += 1
            event = "hit"
        stats_snapshot = dict(exec_cache_stats)
    tracer = instrument.current()
    if tracer.enabled:
        tracer.instant("jit_cache", cat="runtime",
                       args={"group": group.name, "event": event,
                             "batch": batch})
        tracer.counter("jit_cache", stats_snapshot)
    return fn


def run_compiled(design, env, *, interpret: bool | None = None,
                 jit: bool = True, stats_out: dict | None = None) -> dict:
    """Execute a :class:`~repro.core.compile_driver.CompiledDesign` on
    the Pallas path: groups run in schedule order, chained through the
    value environment (the dict entries standing in for the DRAM spill
    buffers of ``host_schedule.cpp``).  Returns the graph outputs.

    ``stats_out`` (ISSUE 6): pass a dict to collect runtime counters —
    per-group wall time + jit-cache outcome, the exec-cache hit/miss
    delta of this call, and the modeled boundary-DMA bytes per group
    transition.  Counter collection (also active whenever a tracer is
    installed) blocks on each group's outputs so per-group wall times
    measure execution, not async dispatch; the uninstrumented path is
    untouched.
    """
    tracer = instrument.current()
    reg = _metrics.current()
    collect = stats_out is not None or tracer.enabled or reg.enabled
    env = dict(env)
    if not collect:
        for g in design.groups:
            env.update(lower_group(g, interpret=interpret, jit=jit)(env))
        return {v: env[v] for v in design.source.graph_outputs}
    m_wall = reg.histogram("run_group_wall_ms",
                           "per-group execution wall time (ms)",
                           labels=("group",))
    m_dma = reg.counter("run_dma_bytes_total",
                        "modeled boundary-DMA bytes", labels=("direction",))

    before = dict(exec_cache_stats)
    transitions = design.boundary_traffic()
    rows = []
    t_run0 = time.perf_counter()
    for idx, g in enumerate(design.groups):
        g_before = dict(exec_cache_stats)
        t0 = time.perf_counter()
        with tracer.span(f"run:{g.name}", cat="runtime") as sargs:
            out = lower_group(g, interpret=interpret, jit=jit)(env)
            out = jax.block_until_ready(out)
            env.update(out)
            row = {
                "group": g.name,
                "jit_cache": (
                    "hit" if exec_cache_stats["hits"] > g_before["hits"]
                    else "miss"
                    if exec_cache_stats["misses"] > g_before["misses"]
                    else "unjitted"
                ),
            }
            if idx < len(transitions):
                w, r = transitions[idx]
                row["dma_write_bytes"] = w
                row["dma_read_bytes"] = r
                tracer.counter("dma_bytes", {"write": w, "read": r})
                if reg.enabled:
                    m_dma.inc(w, direction="write")
                    m_dma.inc(r, direction="read")
            sargs.update(row)
        row["wall_ms"] = round((time.perf_counter() - t0) * 1e3, 3)
        if reg.enabled:
            m_wall.observe(row["wall_ms"], group=g.name)
        rows.append(row)
    if stats_out is not None:
        stats_out.update({
            "groups": rows,
            "wall_ms": round((time.perf_counter() - t_run0) * 1e3, 3),
            "exec_cache": {
                "hits": exec_cache_stats["hits"] - before["hits"],
                "misses": exec_cache_stats["misses"] - before["misses"],
            },
            "dma_write_bytes": sum(w for w, _ in transitions),
            "dma_read_bytes": sum(r for _, r in transitions),
        })
    return {v: env[v] for v in design.source.graph_outputs}


def run_compiled_batched(design, env, batch: int, *,
                         interpret: bool | None = None, jit: bool = True,
                         stats_out: dict | None = None) -> dict:
    """Execute a :class:`~repro.core.compile_driver.CompiledDesign` over
    a batch in one device dispatch per group (ISSUE 7): every
    non-constant entry of ``env`` carries a leading axis of extent
    ``batch``; constants are per-design.  Groups run in schedule order
    through vmapped+jitted executables (:func:`lower_group` with
    ``batch=``): the batch is padded up to the nearest
    :data:`BATCH_BUCKETS` bucket (zero rows, sliced off the outputs
    before return, still on device) and chunked above the top bucket,
    so each group compiles at most once per bucket.  Returns the graph
    outputs as *device* arrays with a leading batch axis — the host
    conversion happens once at the caller's boundary, never per sample.

    ``interpret=False`` is the explicit device-dispatch path (real
    Pallas kernels on an accelerator); the default auto-selects
    interpret mode on CPU exactly like :func:`run_compiled`.
    """
    interpret = _auto_interpret(interpret)
    tracer = instrument.current()
    reg = _metrics.current()
    collect = stats_out is not None or tracer.enabled or reg.enabled
    if reg.enabled:
        m_wall = reg.histogram("run_group_wall_ms",
                               "per-group execution wall time (ms)",
                               labels=("group",))
        m_dma = reg.counter("run_dma_bytes_total",
                            "modeled boundary-DMA bytes",
                            labels=("direction",))
    src = design.source
    stream = [k for k in env
              if k in src.values and not src.values[k].is_constant]
    const_env = {k: v for k, v in env.items() if k not in stream}

    before = dict(exec_cache_stats)
    transitions = design.boundary_traffic()
    group_rows: dict[str, dict] = {}
    buckets: list[int] = []
    t_run0 = time.perf_counter()
    chunks_out: list[dict] = []
    for start, n, bucket in _batch_chunks(batch):
        buckets.append(bucket)
        chunk_env = dict(const_env)
        for k in stream:
            v = jnp.asarray(env[k])[start:start + n]
            if bucket != n:
                chunk_env[k] = jnp.pad(
                    v, ((0, bucket - n),) + ((0, 0),) * (v.ndim - 1)
                )
            else:
                chunk_env[k] = v
        for idx, g in enumerate(design.groups):
            fn = lower_group(g, interpret=interpret, jit=jit, batch=bucket)
            if not collect:
                chunk_env.update(fn(chunk_env))
                continue
            g_before = dict(exec_cache_stats)
            t0 = time.perf_counter()
            with tracer.span(f"run:{g.name}", cat="runtime") as sargs:
                out = jax.block_until_ready(fn(chunk_env))
                chunk_env.update(out)
                row = group_rows.setdefault(
                    g.name, {"group": g.name, "wall_ms": 0.0, "samples": 0}
                )
                row["samples"] += n
                row["jit_cache"] = (
                    "hit" if exec_cache_stats["hits"] > g_before["hits"]
                    else "miss"
                    if exec_cache_stats["misses"] > g_before["misses"]
                    else "unjitted"
                )
                sargs.update({"group": g.name, "batch": n, "bucket": bucket,
                              "jit_cache": row["jit_cache"]})
                if idx < len(transitions):
                    w, r = transitions[idx]
                    sargs.update({"dma_write_bytes": w * n,
                                  "dma_read_bytes": r * n})
                    tracer.counter("dma_bytes",
                                   {"write": w * n, "read": r * n})
                    if reg.enabled:
                        m_dma.inc(w * n, direction="write")
                        m_dma.inc(r * n, direction="read")
            step_ms = (time.perf_counter() - t0) * 1e3
            if reg.enabled:
                m_wall.observe(step_ms, group=g.name)
            row["wall_ms"] = round(row["wall_ms"] + step_ms, 3)
        outs = {v: chunk_env[v] for v in src.graph_outputs}
        if bucket != n:  # drop padding rows, still on device
            outs = {k: v[:n] for k, v in outs.items()}
        chunks_out.append(outs)
    if len(chunks_out) == 1:
        result = chunks_out[0]
    else:
        result = {
            k: jnp.concatenate([c[k] for c in chunks_out], axis=0)
            for k in src.graph_outputs
        }
    if stats_out is not None:
        stats_out.update({
            "groups": list(group_rows.values()),
            "wall_ms": round((time.perf_counter() - t_run0) * 1e3, 3),
            "exec_cache": {
                "hits": exec_cache_stats["hits"] - before["hits"],
                "misses": exec_cache_stats["misses"] - before["misses"],
            },
            "batch_buckets": buckets,
            "dma_write_bytes": sum(w for w, _ in transitions) * batch,
            "dma_read_bytes": sum(r for _, r in transitions) * batch,
        })
    return result


# ---------------------------------------------------------------------------
# flash attention (GQA, causal, decode offset)
# ---------------------------------------------------------------------------


def flash_attention(
    q: jax.Array,        # (B, Hq, Sq, D)
    k: jax.Array,        # (B, Hkv, Sk, D)
    v: jax.Array,        # (B, Hkv, Sk, D)
    *,
    causal: bool = True,
    scale: float | None = None,
    q_offset: int = 0,
    block_q: int | None = None,
    block_k: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    interpret = _auto_interpret(interpret)
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    assert hq % hkv == 0
    group = hq // hkv
    scale = scale if scale is not None else d ** -0.5

    if block_q is None or block_k is None:
        plan = plan_attention_blocks(seq_q=max(sq, 8), seq_k=max(sk, 8), head_dim=d)
        block_q = block_q or _pick_block(sq, plan.blocks["block_q"])
        block_k = block_k or _pick_block(sk, plan.blocks["block_k"])

    qf = (q * scale).reshape(b * hq, sq, d)
    kf = k.reshape(b * hkv, sk, d)
    vf = v.reshape(b * hkv, sk, d)
    out = _flash.flash_attention_pallas(
        qf, kf, vf,
        group=group, heads_q=hq, heads_kv=hkv,
        block_q=block_q, block_k=block_k,
        causal=causal, q_offset=q_offset, interpret=interpret,
    )
    return out.reshape(b, hq, sq, d)


# ---------------------------------------------------------------------------
# fused MLP
# ---------------------------------------------------------------------------


def fused_mlp(
    x: jax.Array,                  # (..., D)
    w_gate: jax.Array | None,      # (D, F) | None
    w_up: jax.Array,               # (D, F)
    w_down: jax.Array,             # (F, D)
    *,
    act: str = "silu",
    block_m: int | None = None,
    block_f: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    interpret = _auto_interpret(interpret)
    lead = x.shape[:-1]
    d = x.shape[-1]
    f = w_up.shape[1]
    m = math.prod(lead) if lead else 1
    x2 = x.reshape(m, d)

    if block_m is None or block_f is None:
        plan = plan_matmul_blocks(m=max(m, 8), k=d, n=max(f, 8))
        block_m = block_m or _pick_block(m, plan.blocks["bm"])
        block_f = block_f or _pick_block(f, plan.blocks["bn"])

    out = _mlp.fused_mlp_pallas(
        x2, w_gate, w_up, w_down,
        block_m=block_m, block_f=block_f, act=act, interpret=interpret,
    )
    return out.reshape(*lead, d)


# ---------------------------------------------------------------------------
# Mamba-2 SSD
# ---------------------------------------------------------------------------


def mamba2_ssd(
    x: jax.Array,          # (B, L, H, P)
    dt: jax.Array,         # (B, L, H)
    a: jax.Array,          # (H,)
    b_mat: jax.Array,      # (B, L, N)
    c_mat: jax.Array,      # (B, L, N)
    *,
    init_state: jax.Array | None = None,
    chunk: int | None = None,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    interpret = _auto_interpret(interpret)
    bsz, l, h, p = x.shape
    n = b_mat.shape[-1]
    if chunk is None:
        chunk = _pick_block(l, 128)
    assert l % chunk == 0, (l, chunk)
    s0 = (
        init_state
        if init_state is not None
        else jnp.zeros((bsz, h, p, n), jnp.float32)
    )
    return _ssd.mamba2_ssd_pallas(
        x, dt, a, b_mat, c_mat, s0, chunk=chunk, interpret=interpret
    )
