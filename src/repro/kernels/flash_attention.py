"""KV-streaming flash attention — Pallas TPU kernel.

The sequence-axis analogue of MING's line buffer (DESIGN.md §2): instead
of materializing the (Sq, Sk) score matrix (the "intermediate tensor"
a naive graph would allocate), K/V tiles *stream* through VMEM while a
running (m, l, acc) triple — the "line buffer" of softmax state — is
carried in scratch across grid steps.  Supports GQA (q-head groups share
a KV head via the BlockSpec index map) and causal masking with a query
offset for decode.

Grid: (B*Hq, Sq/block_q, Sk/block_k), k innermost (sequential stream).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref,      # (1, bq, D)
    k_ref,      # (1, bk, D)
    v_ref,      # (1, bk, D)
    o_ref,      # (1, bq, D)
    m_ref,      # (bq, 1)  running max
    l_ref,      # (bq, 1)  running denominator
    acc_ref,    # (bq, D)  running numerator
    *,
    causal: bool,
    q_offset: int,
    block_q: int,
    block_k: int,
    num_k_blocks: int,
):
    ki = pl.program_id(2)
    qi = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                  # (bq, D)
    k = k_ref[0].astype(jnp.float32)                  # (bk, D)
    v = v_ref[0].astype(jnp.float32)                  # (bk, D)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                                  # (bq, bk)

    if causal:
        qpos = (
            jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
            + qi * block_q
            + q_offset
        )
        kpos = (
            jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
            + ki * block_k
        )
        mask = qpos >= kpos
        s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                                # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)          # (bq, 1)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                             # (bq, bk)
    if causal:
        p = jnp.where(mask, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)                    # (bq, 1)

    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(ki == num_k_blocks - 1)
    def _finalize():
        l = l_ref[...]
        safe_l = jnp.where(l > 0.0, l, 1.0)
        o_ref[...] = (acc_ref[...] / safe_l)[None].astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,       # (BHq, Sq, D) — pre-scaled by ops wrapper
    k: jax.Array,       # (BHkv, Sk, D)
    v: jax.Array,       # (BHkv, Sk, D)
    *,
    group: int,          # Hq // Hkv
    heads_q: int,        # Hq (per batch element) for the index arithmetic
    heads_kv: int,
    block_q: int,
    block_k: int,
    causal: bool = True,
    q_offset: int = 0,
    interpret: bool = False,
) -> jax.Array:
    bhq, sq, d = q.shape
    _, sk, _ = k.shape
    assert sq % block_q == 0 and sk % block_k == 0, (sq, sk, block_q, block_k)
    nq, nk = sq // block_q, sk // block_k

    def kv_index(bh: int, qi: int, ki: int):
        b = bh // heads_q
        h = bh % heads_q
        return (b * heads_kv + h // group, ki, 0)

    kernel = functools.partial(
        _flash_kernel,
        causal=causal,
        q_offset=q_offset,
        block_q=block_q,
        block_k=block_k,
        num_k_blocks=nk,
    )
    return pl.pallas_call(
        kernel,
        grid=(bhq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, d), kv_index),
            pl.BlockSpec((1, block_k, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bhq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
