"""Fault tolerance & straggler mitigation for the training driver.

* :class:`StragglerWatchdog` — per-step wall-time EWMA + median window;
  steps slower than ``threshold × median`` are flagged and counted.  On a
  real fleet the callback triggers re-scheduling / hot-spare swap; here
  it feeds metrics and the (tested) skip-batch policy.
* :class:`FailureInjector` — deterministic fault injection for tests and
  the resilience example: raises ``SimulatedFailure`` at chosen steps.
* :func:`run_resilient` — the restart loop: run → on failure, restore
  latest checkpoint → continue.  Used by ``repro.launch.train`` and the
  fault-tolerance tests (which assert bit-exact loss continuity across a
  mid-run crash).
"""
from __future__ import annotations

import collections
import statistics
import time
from dataclasses import dataclass, field
from typing import Callable, Optional


class SimulatedFailure(RuntimeError):
    pass


@dataclass
class FailureInjector:
    fail_at_steps: tuple[int, ...] = ()
    fired: set = field(default_factory=set)

    def check(self, step: int) -> None:
        if step in self.fail_at_steps and step not in self.fired:
            self.fired.add(step)
            raise SimulatedFailure(f"injected failure at step {step}")


class StragglerWatchdog:
    def __init__(self, window: int = 32, threshold: float = 2.5) -> None:
        self.window = window
        self.threshold = threshold
        self.times: collections.deque = collections.deque(maxlen=window)
        self.flagged: list[tuple[int, float]] = []
        self._t0: Optional[float] = None

    def start(self) -> None:
        self._t0 = time.perf_counter()

    def stop(self, step: int) -> float:
        assert self._t0 is not None
        dt = time.perf_counter() - self._t0
        self._t0 = None
        if len(self.times) >= max(4, self.window // 4):
            med = statistics.median(self.times)
            if dt > self.threshold * med:
                self.flagged.append((step, dt))
        self.times.append(dt)
        return dt

    @property
    def median(self) -> float:
        return statistics.median(self.times) if self.times else 0.0


def run_resilient(
    *,
    total_steps: int,
    make_state: Callable[[], tuple],          # () -> (step, state)
    restore_state: Callable[[], Optional[tuple]],   # () -> (step, state) | None
    run_step: Callable[[int, tuple], tuple],  # (step, state) -> (state, metrics)
    save_state: Callable[[int, tuple], None],
    checkpoint_every: int = 10,
    max_restarts: int = 8,
    on_metrics: Optional[Callable[[int, dict], None]] = None,
) -> tuple:
    """Crash-restart training loop.  Returns the final (step, state)."""
    restarts = 0
    while True:
        restored = restore_state()
        if restored is None:
            step, state = make_state()
        else:
            step, state = restored
        try:
            while step < total_steps:
                state, metrics = run_step(step, state)
                step += 1
                if on_metrics:
                    on_metrics(step, metrics)
                if step % checkpoint_every == 0 or step == total_steps:
                    save_state(step, state)
            return step, state
        except SimulatedFailure:
            restarts += 1
            if restarts > max_restarts:
                raise
            continue  # restart from the latest checkpoint
