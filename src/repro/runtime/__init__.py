"""runtime substrate."""
