"""Production mesh construction.

Kept as functions (never module-level constants) so importing this module
never touches jax device state — smoke tests must keep seeing 1 device.
"""
from __future__ import annotations

import os

import jax


def make_production_mesh(*, multi_pod: bool = False, tp: int = 0):
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod.

    Axes: ``model`` is the fast (ICI-contiguous) axis for tensor
    parallelism; ``data`` (and ``pod``) form the FSDP/batch axis group —
    ``pod`` maps to the DCN-connected slow axis in a real deployment,
    which is why gradient compression targets exactly that axis
    (repro.optim.compress).

    Test hook: ``REPRO_MESH_SHAPE`` / ``REPRO_MESH_SHAPE_MULTI`` override
    the shapes (e.g. "2,4" / "2,2,2") so the dry-run *machinery* can be
    exercised with 8 host devices in CI; the production deliverable runs
    unoverridden at 256/512.
    """
    env = os.environ.get(
        "REPRO_MESH_SHAPE_MULTI" if multi_pod else "REPRO_MESH_SHAPE"
    )
    if env:
        shape = tuple(int(x) for x in env.split(","))
    else:
        shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    assert len(shape) == len(axes), (shape, axes)
    if tp:
        # per-arch TP override: same chip count, (…, data·model/tp, tp)
        chips = shape[-1] * shape[-2]
        assert chips % tp == 0, (chips, tp)
        shape = (*shape[:-2], chips // tp, tp)
    return jax.make_mesh(shape, axes)


def make_host_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary mesh for tests (host platform devices)."""
    return jax.make_mesh(shape, axes)


def single_device_mesh():
    return jax.make_mesh((1, 1), ("data", "model"))
