"""ShapeDtypeStruct input specs for every (architecture × shape) cell.

The dry-run lowers against these — weak-type-correct, shardable, zero
device allocation.  The same specs shape the real batches produced by
``repro.data.pipeline`` (asserted in tests/test_dryrun_smoke.py), so a
dry-run-validated cell is guaranteed to accept real data.

Shape semantics (assignment + DESIGN.md §4):
  train_4k     — train_step on (global_batch, seq_len)
  prefill_32k  — prefill_step on (global_batch, seq_len)
  decode_32k   — decode_step: ONE new token against a seq_len KV cache
  long_500k    — decode_step at 524,288 (sub-quadratic archs only)

Encoder–decoder mapping: train = enc seq_len frames + seq_len/4 decoder
targets; prefill = encode seq_len frames + first token; decode = one
decoder token against a seq_len cross memory + seq_len self cache.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec, lm
from . import steps

SDS = jax.ShapeDtypeStruct

#: decoder targets per encoder frame (seamless: text tokens much shorter
#: than audio frames)
ENCDEC_DEC_FRAC = 4


def _i32(shape):
    return SDS(shape, jnp.int32)


def train_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Batch pytree for ``train_step`` (tokens or stub embeddings)."""
    b, s = shape.global_batch, shape.seq_len
    if cfg.family == "encdec":
        s_dec = max(s // ENCDEC_DEC_FRAC, 16)
        return {
            "frames": SDS((b, s, cfg.d_model), cfg.param_dtype),
            "tokens": _i32((b, s_dec)),
            "labels": _i32((b, s_dec)),
        }
    out: dict = {"labels": _i32((b, s))}
    if cfg.embeds_input:
        out["embeds"] = SDS((b, s, cfg.d_model), cfg.param_dtype)
        if cfg.mrope_sections:
            out["mrope_positions"] = _i32((3, b, s))
    else:
        out["tokens"] = _i32((b, s))
    return out


def prefill_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    b, s = shape.global_batch, shape.seq_len
    if cfg.family == "encdec":
        return {"frames": SDS((b, s, cfg.d_model), cfg.param_dtype)}
    out: dict = {}
    if cfg.embeds_input:
        out["embeds"] = SDS((b, s, cfg.d_model), cfg.param_dtype)
        if cfg.mrope_sections:
            out["mrope_positions"] = _i32((3, b, s))
    else:
        out["tokens"] = _i32((b, s))
    return out


def decode_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """{"cache", "token", "pos"} — one-token step against a seq_len cache."""
    b, s = shape.global_batch, shape.seq_len
    cache = jax.eval_shape(
        lambda: steps.model_init_cache(cfg, b, s)
    )
    if cfg.embeds_input and cfg.family != "encdec":
        token = SDS((b, 1, cfg.d_model), cfg.param_dtype)
    else:
        token = _i32((b,))
    return {"cache": cache, "token": token, "pos": SDS((), jnp.int32)}


def params_specs(cfg: ModelConfig, key=None) -> dict:
    """Abstract params pytree (no allocation)."""
    k = jax.random.key(0) if key is None else key
    return jax.eval_shape(lambda: steps.model_init(k, cfg))


def entry_for(cfg: ModelConfig, shape: ShapeConfig):
    """(kind, step_factory, input_spec_fn) for one cell."""
    if shape.kind == "train":
        return "train"
    if shape.kind == "prefill":
        return "prefill"
    return "decode"
