"""Step functions (pure, jit-able) shared by the trainer, the server and
the multi-pod dry-run.

Every entry point is a pure function of explicit state — the contract
that makes them shardable with ``jax.jit(in_shardings=..., donate=...)``
and checkpoint/restart-safe:

  ``train_step(params, opt_state, batch) -> (params, opt_state, metrics)``
  ``prefill_step(params, batch)          -> (logits, caches)``
  ``decode_step(params, cache, token, pos) -> (logits, cache)``

Model-family dispatch (decoder-only LM vs encoder–decoder) happens here,
so the launchers stay family-agnostic.  Gradient accumulation is a
``lax.scan`` over microbatches — the standard way to keep per-device
activation memory bounded at large (batch × seq) without touching the
model code (used by jamba-398B train_4k in the dry-run; see
EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import encdec, lm
from repro.optim import adamw


# ---------------------------------------------------------------------------
# family dispatch
# ---------------------------------------------------------------------------


def model_loss(params: dict, cfg: ModelConfig, batch: dict) -> jax.Array:
    if cfg.family == "encdec":
        return encdec.encdec_loss(params, cfg, batch)
    return lm.lm_loss(params, cfg, batch)


def model_init(key, cfg: ModelConfig) -> dict:
    if cfg.family == "encdec":
        return encdec.init_params(key, cfg)
    return lm.init_params(key, cfg)


def model_prefill(params: dict, cfg: ModelConfig, batch: dict):
    if cfg.family == "encdec":
        return encdec.encdec_prefill(params, cfg, batch)
    return lm.lm_prefill(params, cfg, batch)


def model_decode(params: dict, cfg: ModelConfig, cache, token, pos):
    if cfg.family == "encdec":
        return encdec.encdec_decode(params, cfg, cache, token, pos)
    return lm.lm_decode(params, cfg, cache, token, pos)


def model_init_cache(cfg: ModelConfig, batch: int, max_len: int):
    if cfg.family == "encdec":
        return encdec.init_cache(cfg, batch, mem_len=max_len, max_len=max_len)
    return lm.init_cache(cfg, batch, max_len)


# ---------------------------------------------------------------------------
# gradient accumulation helpers
# ---------------------------------------------------------------------------

#: batch leaves whose microbatch split axis is not 0
_SPLIT_AXIS = {"mrope_positions": 1}


def _split_microbatches(batch: dict, accum: int) -> dict:
    def re(path, x):
        name = str(getattr(path[-1], "key", path[-1]))
        ax = _SPLIT_AXIS.get(name, 0)
        b = x.shape[ax]
        assert b % accum == 0, (name, b, accum)
        new = x.shape[:ax] + (accum, b // accum) + x.shape[ax + 1 :]
        x = x.reshape(new)
        return jnp.moveaxis(x, ax, 0)  # accum leading for lax.scan

    return jax.tree_util.tree_map_with_path(re, batch)


# ---------------------------------------------------------------------------
# train step
# ---------------------------------------------------------------------------


def make_train_step(
    cfg: ModelConfig,
    opt_cfg: adamw.AdamWConfig,
    *,
    grad_accum: int = 1,
) -> Callable:
    """Forward + backward + AdamW update, optionally microbatched."""

    def loss_fn(params, mb):
        return model_loss(params, cfg, mb)

    def train_step(params, opt_state, batch):
        if grad_accum == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            mbs = _split_microbatches(batch, grad_accum)

            def mb_step(carry, mb):
                acc_loss, acc_g = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                acc_g = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), acc_g, g
                )
                return (acc_loss + l, acc_g), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (loss, grads), _ = lax.scan(
                mb_step, (jnp.zeros((), jnp.float32), zeros), mbs
            )
            loss = loss / grad_accum
            grads = jax.tree.map(lambda g: g / grad_accum, grads)

        params, opt_state, metrics = adamw.apply(params, grads, opt_state, opt_cfg)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


# ---------------------------------------------------------------------------
# serve steps
# ---------------------------------------------------------------------------


def make_prefill_step(cfg: ModelConfig) -> Callable:
    def prefill_step(params, batch):
        return model_prefill(params, cfg, batch)

    return prefill_step


def make_decode_step(cfg: ModelConfig) -> Callable:
    def decode_step(params, cache, token, pos):
        return model_decode(params, cfg, cache, token, pos)

    return decode_step
