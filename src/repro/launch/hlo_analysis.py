"""HLO-text analysis for the roofline (EXPERIMENTS.md §Roofline).

``compiled.cost_analysis()`` counts a ``while`` body **once**, so with
scan-over-layers every per-layer cost is undercounted by the trip count
(verified empirically in this repo: a 24-step scanned matmul reports
1/24 of the analytic FLOPs).  This module parses ``compiled.as_text()``
directly and:

  1. splits the module into computations,
  2. recovers every while loop's trip count from its condition
     computation (the ``s32[] constant(N)`` feeding the LT compare —
     the canonical lax.scan lowering),
  3. propagates multipliers through the call graph
     (while bodies ×trip, call/fusion/conditional ×1),
  4. sums trip-scaled **dot/convolution FLOPs** and trip-scaled
     **collective bytes** per collective kind.

Collective byte convention (per-device bytes moved, ring algorithms):
  all-reduce ≈ 2×size, all-gather ≈ result size, reduce-scatter ≈
  operand size, all-to-all ≈ size, collective-permute ≈ size.
"""
from __future__ import annotations

import math
import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\(.*)?\{?\s*$")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")

COLLECTIVE_KINDS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

#: opcodes treated as HBM-materialization boundaries for the memory-term
#: proxy: XLA keeps fusion-internal values in registers/VMEM; data crosses
#: HBM at fusion/dot/conv/copy/collective/cache-update boundaries.  This
#: mirrors how TPU cost models charge bytes (operands + results of
#: top-level ops); CPU fusion granularity differs from TPU — documented
#: approximation (EXPERIMENTS.md §Roofline method).
MEM_OPS = frozenset({
    "fusion", "dot", "convolution", "copy", "copy-start",
    "dynamic-update-slice", "dynamic-slice", "gather", "scatter",
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "all-reduce-start", "all-gather-start",
    "collective-permute-start", "reduce", "sort", "transpose",
    "concatenate", "slice", "pad", "reverse", "select-and-scatter",
})
# Deliberately excluded: elementwise ops (add/mul/exp/...), broadcast,
# iota, convert, reshape, bitcast — on TPU these fuse into neighbours, so
# their traffic is already charged at the producer/consumer boundaries;
# counting them separately would double-charge relative to a TPU build.

_OPCODE_RE = re.compile(r"^(?:\([^)]*\)|\S+)\s+([a-z][a-z0-9\-]*)\(")


def _opcode(rhs: str) -> str | None:
    m = _OPCODE_RE.match(rhs)
    return m.group(1) if m else None


def _operand_names(rhs: str) -> list[str]:
    """Operand tokens inside the first balanced paren group."""
    i = rhs.find("(")
    if i < 0:
        return []
    depth = 0
    j = i
    for j in range(i, len(rhs)):
        if rhs[j] == "(":
            depth += 1
        elif rhs[j] == ")":
            depth -= 1
            if depth == 0:
                break
    inner = rhs[i + 1 : j]
    return [t.lstrip("%") for t in re.findall(r"%?[\w\.\-]+", inner)]


def _result_bytes(rhs: str) -> int:
    """Bytes of the instruction's result (the type prefix of the rhs)."""
    i = rhs.find("(")
    m = _OPCODE_RE.match(rhs)
    if m:
        prefix = rhs[: m.start(1)]
    elif i >= 0:
        prefix = rhs[:i]
    else:
        prefix = rhs
    return _shape_bytes(prefix)

_COLLECTIVE_FACTOR = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def _shape_bytes(type_str: str) -> int:
    """Total bytes of all arrays in an HLO type string (handles tuples)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_dims(type_str: str) -> tuple[str, list[int]] | None:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    dtype, dims = m.groups()
    return dtype, [int(d) for d in dims.split(",") if d]


@dataclass
class Computation:
    name: str
    lines: list[str] = field(default_factory=list)
    # instruction name -> full rhs text
    instrs: dict = field(default_factory=dict)


def split_computations(hlo_text: str) -> dict[str, Computation]:
    """Header heuristic robust to the post-2024 dump format: signatures
    carry ``/*index=N*/`` comments (so '=' may precede the '{'), and the
    module prolog has FileNames/FunctionNames metadata sections whose
    numbered lines start at column 0 (they end with '}' not '{')."""
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        if not stripped:
            continue
        # computation header: "%name (params...) -> type {" or "ENTRY ..."
        if (
            not line.startswith(" ")
            and stripped.endswith("{")
            and not stripped.startswith("HloModule")
        ):
            m = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)", stripped)
            if m:
                cur = Computation(m.group(1))
                comps[cur.name] = cur
                continue
        if stripped == "}":
            cur = None
            continue
        if cur is not None:
            cur.lines.append(stripped)
            mi = _INSTR_RE.match(stripped)
            if mi:
                cur.instrs[mi.group(1)] = mi.group(2)
    return comps


def _find_trip_count(cond_name: str, comps: dict[str, Computation]) -> int:
    """Max s32 constant in the condition computation subtree (the scan
    bound).  Falls back to 1 when nothing is found."""
    seen: set[str] = set()
    stack = [cond_name]
    best = 1
    while stack:
        name = stack.pop()
        if name in seen or name not in comps:
            continue
        seen.add(name)
        comp = comps[name]
        for line in comp.lines:
            for m in re.finditer(r"s32\[\]\s+constant\((\d+)\)", line):
                best = max(best, int(m.group(1)))
            for m in re.finditer(r"(?:calls|to_apply)=%?([\w\.\-]+)", line):
                stack.append(m.group(1))
    return best


def _call_edges(comp: Computation) -> list[tuple[str, int]]:
    """(callee, multiplier) pairs for one computation."""
    edges: list[tuple[str, int]] = []
    for line in comp.lines:
        if " while(" in line:
            body = re.search(r"body=%?([\w\.\-]+)", line)
            cond = re.search(r"condition=%?([\w\.\-]+)", line)
            if body:
                edges.append((body.group(1), -1))  # -1 → resolve via cond
                if cond:
                    edges[-1] = (body.group(1), ("COND", cond.group(1)))
            continue
        for m in re.finditer(r"(?:calls|to_apply|branch_computations)=\{?%?([\w\.\-,% ]+)\}?", line):
            for callee in re.split(r"[,\s]+", m.group(1)):
                callee = callee.strip().lstrip("%")
                if callee:
                    edges.append((callee, 1))
    return edges


def computation_multipliers(comps: dict[str, Computation]) -> dict[str, float]:
    """Multiplier per computation = product of enclosing loop trip counts."""
    entry = None
    for name in comps:
        if name in ("main", "main.0") or name.startswith("main"):
            entry = name
            break
    if entry is None:  # fall back: computation not called by anyone
        called = set()
        for c in comps.values():
            for callee, _ in _call_edges(c):
                if isinstance(callee, str):
                    called.add(callee)
        roots = [n for n in comps if n not in called]
        entry = roots[0] if roots else next(iter(comps))

    mult: dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    # BFS through call graph (acyclic in HLO)
    order = [entry]
    seen = {entry}
    i = 0
    while i < len(order):
        name = order[i]
        i += 1
        comp = comps.get(name)
        if comp is None:
            continue
        for callee, kind in _call_edges(comp):
            m = mult[name]
            if isinstance(kind, tuple) and kind[0] == "COND":
                trip = _find_trip_count(kind[1], comps)
                m = m * trip
                # also mark the cond computation itself (cheap, but visit)
                if kind[1] not in seen:
                    mult[kind[1]] = max(mult[kind[1]], mult[name])
                    seen.add(kind[1])
                    order.append(kind[1])
            mult[callee] = max(mult[callee], m)
            if callee not in seen:
                seen.add(callee)
                order.append(callee)
    return dict(mult)


# ---------------------------------------------------------------------------
# FLOPs from dot / convolution instructions
# ---------------------------------------------------------------------------


def _dot_flops(rhs: str, comp: Computation) -> float:
    """2 × prod(result_dims) × prod(contracted lhs dims)."""
    res = _shape_dims(rhs)
    if res is None:
        return 0.0
    _, out_dims = res
    # lhs dims: newer HLO prints operand types inline —
    # "dot(f32[32,32]{1,0} %Arg_0.1, ...)" — read the shape directly;
    # older HLO prints bare names — "dot(%Arg_0.1, ...)" — resolve the
    # name against the computation's instructions.
    ldims: tuple[int, ...] | None = None
    mt = re.search(r"dot\(\s*[a-z0-9]+\[([\d,]*)\]", rhs)
    if mt:
        ldims = tuple(int(d) for d in mt.group(1).split(",") if d)
    else:
        m = re.search(r"dot\(\s*%?([\w\.\-]+)", rhs)
        if not m:
            return 0.0
        lhs_rhs = comp.instrs.get(m.group(1), "")
        # the instruction rhs begins with its result type, e.g.
        # "bf16[128,256]{1,0} get-tuple-element(...), index=1"
        lhs_shape = _shape_dims(lhs_rhs)
        if lhs_shape:
            _, ldims = lhs_shape
    cdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rhs)
    contracted = 1
    if ldims and cdims and cdims.group(1):
        for ci in cdims.group(1).split(","):
            ci = int(ci)
            if ci < len(ldims):
                contracted *= ldims[ci]
    out = 1
    for d in out_dims:
        out *= d
    return 2.0 * out * contracted


def _conv_flops(rhs: str) -> float:
    res = _shape_dims(rhs)
    if res is None:
        return 0.0
    _, out_dims = res
    m = re.search(r"window=\{size=([\dx]+)", rhs)
    win = 1
    if m:
        for d in m.group(1).split("x"):
            win *= int(d)
    # feature contraction dim not in text reliably; approximate with
    # operand parse
    mm = re.search(r"convolution\(\s*%?([\w\.\-]+)\s*,\s*%?([\w\.\-]+)", rhs)
    cin = 1
    return 2.0 * math.prod(out_dims) * win * cin


@dataclass
class HloStats:
    dot_flops: float = 0.0
    conv_flops: float = 0.0
    memory_bytes: float = 0.0
    collective_bytes: dict = field(default_factory=lambda: defaultdict(float))
    collective_counts: dict = field(default_factory=lambda: defaultdict(int))
    loop_trips: dict = field(default_factory=dict)
    # traffic attribution: (dtype, last-two result dims) -> bytes.  Lets
    # the roofline slice e.g. the (512, 512) f32 attention score tiles
    # that a VMEM-resident Pallas kernel would never send to HBM.
    traffic_by_shape: dict = field(default_factory=lambda: defaultdict(float))
    # collective attribution: (kind, dtype, full dims) -> bytes
    collective_by_shape: dict = field(
        default_factory=lambda: defaultdict(float)
    )

    @property
    def flops(self) -> float:
        return self.dot_flops + self.conv_flops

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())

    def summary(self) -> dict:
        return {
            "dot_flops": self.dot_flops,
            "conv_flops": self.conv_flops,
            "memory_bytes": self.memory_bytes,
            "collective_bytes": dict(self.collective_bytes),
            "collective_counts": dict(self.collective_counts),
            "total_collective_bytes": self.total_collective_bytes,
            "loop_trips": self.loop_trips,
        }


def _fusion_bodies(comps: dict[str, Computation]) -> set[str]:
    """Computations called via ``calls=`` (fusion bodies) or ``to_apply=``
    (reduce/map/collective reducers): their internal instructions are not
    HBM boundaries — only the calling op is."""
    out: set[str] = set()
    for comp in comps.values():
        for rhs in comp.instrs.values():
            for m in re.finditer(r"(?:calls|to_apply)=\{?%?([\w\.\-]+)", rhs):
                out.add(m.group(1))
    return out


def _instr_memory_bytes(op: str, rhs: str, comp: Computation) -> float:
    """HBM traffic of one boundary instruction.

    dynamic-update-slice (and fusions rooted in one) alias their big
    operand in place — XLA writes only the update region, so charging the
    full buffer would overcount by orders of magnitude.  Charge
    2 × (operands − largest operand) ≈ read update + write region.
    dynamic-slice reads the sliced region and writes the result: 2×result.
    """
    res = _result_bytes(rhs)
    operands = []
    for operand in _operand_names(rhs):
        src = comp.instrs.get(operand)
        if src is not None:
            operands.append(_result_bytes(src))
    # jax-lowered in-place cache/accumulator updates keep the marker in
    # the XLA-generated fusion name (…dynamic-update-slice_fusion.N)
    in_place = op == "dynamic-update-slice" or (
        op == "fusion" and "dynamic-update-slice" in rhs
    )
    if in_place and operands:
        small = sum(operands) - max(operands)
        return 2.0 * small
    if op == "dynamic-slice":
        return 2.0 * res
    return res + sum(operands)


def analyze_hlo(hlo_text: str) -> HloStats:
    comps = split_computations(hlo_text)
    mult = computation_multipliers(comps)
    bodies = _fusion_bodies(comps)
    stats = HloStats()
    for name, comp in comps.items():
        m = mult.get(name, 1.0)
        inside_fusion = name in bodies
        for iname, rhs in comp.instrs.items():
            op = _opcode(rhs)
            if op == "dot":
                stats.dot_flops += m * _dot_flops(rhs, comp)
            elif op == "convolution":
                stats.conv_flops += m * _conv_flops(rhs)
            else:
                for kind in COLLECTIVE_KINDS:
                    # match "all-reduce(" and "all-reduce-start("
                    if op == kind or op == f"{kind}-start":
                        prefix = rhs.split(kind)[0]
                        size = _shape_bytes(prefix)
                        b = m * size * _COLLECTIVE_FACTOR[kind]
                        stats.collective_bytes[kind] += b
                        stats.collective_counts[kind] += 1
                        sd = _shape_dims(prefix)
                        if sd is not None:
                            stats.collective_by_shape[
                                (kind, sd[0], tuple(sd[1]))
                            ] += b
                        break
            # memory-traffic proxy: operands + result of HBM-boundary ops
            if op in MEM_OPS and not op.endswith("-start") and not inside_fusion:
                b = _instr_memory_bytes(op, rhs, comp)
                stats.memory_bytes += m * b
                sd = _shape_dims(rhs)
                if sd is not None:
                    dtype, dims = sd
                    key = (dtype, tuple(dims[-2:]))
                    stats.traffic_by_shape[key] += m * b
    # record recovered trip counts for the report
    for name, comp in comps.items():
        for line in comp.lines:
            if " while(" in line:
                cond = re.search(r"condition=%?([\w\.\-]+)", line)
                if cond:
                    stats.loop_trips[cond.group(1)] = _find_trip_count(
                        cond.group(1), comps
                    )
    return stats
