"""Launch: mesh, dry-run, training and serving drivers."""
