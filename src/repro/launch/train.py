"""Production training driver.

Wires together every substrate layer:

  config registry  → model init (scan-stacked params)
  sharding rules   → jit(train_step) with in/out shardings + donation
  data pipeline    → deterministic per-host batches (restart-safe)
  checkpointing    → atomic, async, mesh-agnostic (elastic re-mesh)
  resilience       → crash-restart loop + straggler watchdog
  compression      → int8 error-feedback all-reduce on the pod axis

On this CPU container it trains the reduced (``--smoke``) configs for
real (examples/train_lm.py drives a ~100M model a few hundred steps);
on a TPU fleet the same driver runs the full configs — the dry-run
(``repro.launch.dryrun``) is the proof that those lower and fit.

Usage::

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --smoke \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import math
import os
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import ModelConfig, ShapeConfig
from repro.configs.registry import get_config
from repro.data.pipeline import DataConfig, batch_for_model
from repro.distributed import sharding as shd
from repro.distributed.ctx import activation_sharding
from repro.launch import steps as ST
from repro.launch.mesh import single_device_mesh
from repro.optim import adamw
from repro.runtime.resilience import (
    FailureInjector,
    StragglerWatchdog,
    run_resilient,
)


@dataclasses.dataclass
class TrainRun:
    """Everything a (re)start needs — built once per process."""

    cfg: ModelConfig
    shape: ShapeConfig
    opt_cfg: adamw.AdamWConfig
    mesh: object
    ckpt: Optional[CheckpointManager]
    data_cfg: DataConfig
    grad_accum: int = 1
    seed: int = 0

    def __post_init__(self):
        self.hook = shd.activation_hook(self.mesh)
        with activation_sharding(self.hook):
            params_shape = jax.eval_shape(
                lambda: ST.model_init(jax.random.key(self.seed), self.cfg)
            )
        self.p_shard = shd.make_param_shardings(self.mesh, params_shape,
                                                self.cfg)
        opt_shape = jax.eval_shape(
            lambda p: adamw.init(p, self.opt_cfg), params_shape
        )
        self.o_shard = shd.make_opt_shardings(self.mesh, opt_shape, self.p_shard)
        self._params_shape = params_shape
        self._opt_shape = opt_shape
        step_fn = ST.make_train_step(
            self.cfg, self.opt_cfg, grad_accum=self.grad_accum
        )
        self.step_jit = jax.jit(
            step_fn,
            in_shardings=(self.p_shard, self.o_shard, None),
            out_shardings=(self.p_shard, self.o_shard, None),
            donate_argnums=(0, 1),
        )

    # -- state construction / restore ---------------------------------------

    def fresh_state(self):
        with self.mesh, activation_sharding(self.hook):
            params = jax.jit(
                lambda: ST.model_init(jax.random.key(self.seed), self.cfg),
                out_shardings=self.p_shard,
            )()
            opt_state = jax.jit(
                lambda p: adamw.init(p, self.opt_cfg),
                out_shardings=self.o_shard,
            )(params)
        return 0, (params, opt_state)

    def restore_state(self):
        if self.ckpt is None:
            return None
        step = self.ckpt.latest_step()
        if step is None:
            return None
        tmpl = {"params": self._params_shape, "opt": self._opt_shape}
        shardings = {"params": self.p_shard, "opt": self.o_shard}
        tree, extra = self.ckpt.restore(step, tmpl, shardings)
        return step, (tree["params"], tree["opt"])

    def save_state(self, step: int, state):
        if self.ckpt is None:
            return
        params, opt_state = state
        self.ckpt.save_async(
            step, {"params": params, "opt": opt_state}, extra={"step": step}
        )

    # -- one step -------------------------------------------------------------

    def batch_at(self, step: int):
        b = batch_for_model(self.cfg, self.shape, self.data_cfg, step)
        b_shard = shd.make_batch_shardings(self.mesh, b)
        return jax.tree.map(
            lambda x, s: jax.device_put(x, s), b, b_shard
        )

    def run_step(self, step: int, state):
        params, opt_state = state
        batch = self.batch_at(step)
        with self.mesh, activation_sharding(self.hook):
            params, opt_state, metrics = self.step_jit(params, opt_state, batch)
        return (params, opt_state), metrics


def train(
    *,
    arch: str,
    smoke: bool,
    steps: int,
    batch: int,
    seq: int,
    ckpt_dir: Optional[str],
    ckpt_every: int = 10,
    lr: float = 3e-4,
    grad_accum: int = 1,
    fail_at: tuple[int, ...] = (),
    mesh=None,
    log_every: int = 10,
    seed: int = 0,
) -> dict:
    """Returns {"final_step", "losses", "straggler_flags", ...}."""
    cfg = get_config(arch, smoke=smoke)
    shape = ShapeConfig("train_cli", seq, batch, "train")
    mesh = mesh or single_device_mesh()
    opt_cfg = adamw.AdamWConfig(
        lr=lr, warmup_steps=max(steps // 20, 5), total_steps=steps
    )
    run = TrainRun(
        cfg=cfg,
        shape=shape,
        opt_cfg=opt_cfg,
        mesh=mesh,
        ckpt=CheckpointManager(ckpt_dir) if ckpt_dir else None,
        data_cfg=DataConfig(seed=seed, vocab_size=cfg.vocab_size,
                            seq_len=seq, global_batch=batch),
        grad_accum=grad_accum,
        seed=seed,
    )

    injector = FailureInjector(fail_at_steps=fail_at)
    watchdog = StragglerWatchdog()
    losses: list[float] = []

    def run_step(step, state):
        injector.check(step)
        watchdog.start()
        state, metrics = run.run_step(step, state)
        loss = float(metrics["loss"])
        watchdog.stop(step)
        losses.append(loss)
        if log_every and (step % log_every == 0):
            print(
                f"[train] step {step:5d} loss {loss:8.4f} "
                f"gnorm {float(metrics['grad_norm']):7.3f} "
                f"lr {float(metrics['lr']):.2e} "
                f"({watchdog.median*1e3:.0f} ms/step median)",
                flush=True,
            )
        return state, metrics

    final_step, state = run_resilient(
        total_steps=steps,
        make_state=run.fresh_state,
        restore_state=run.restore_state,
        run_step=run_step,
        save_state=run.save_state,
        checkpoint_every=ckpt_every,
    )
    if run.ckpt is not None:
        run.ckpt.wait()
    return {
        "final_step": final_step,
        "losses": losses,
        "straggler_flags": list(watchdog.flagged),
        "median_step_s": watchdog.median,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--fail-at", type=int, nargs="*", default=[])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    out = train(
        arch=args.arch, smoke=args.smoke, steps=args.steps, batch=args.batch,
        seq=args.seq, ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        lr=args.lr, grad_accum=args.grad_accum,
        fail_at=tuple(args.fail_at), seed=args.seed,
    )
    print(json.dumps({k: v for k, v in out.items() if k != "losses"}))
    print(f"[train] first loss {out['losses'][0]:.4f} "
          f"last loss {out['losses'][-1]:.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
