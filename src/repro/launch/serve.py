"""Batched serving driver (prefill + decode with bounded KV caches).

A deliberately small but real engine:

* ``ServeEngine`` holds jitted ``prefill`` / ``decode`` executables with
  sharded params and caches (same sharding rules as the dry-run lowers,
  so a dry-run-validated cell serves unchanged on hardware).
* Requests are processed in *waves* (static-batch continuous batching):
  a wave of B prompts is prefilled together, decoded lock-step to the
  per-request max; finished rows keep decoding into a scratch column
  (padding semantics) — the standard static-batch serving shape, and the
  one the assignment's decode_* cells measure (one token against a full
  cache).
* Greedy or temperature sampling; deterministic under a seed.

Usage::

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --smoke \
      --batch 4 --prompt-len 64 --max-new 32
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.configs.registry import get_config
from repro.distributed import sharding as shd
from repro.distributed.ctx import activation_sharding
from repro.launch import steps as ST
from repro.launch.mesh import single_device_mesh


@dataclasses.dataclass
class ServeStats:
    prefill_s: float
    decode_s: float
    tokens_out: int
    tokens_per_s: float


class ServeEngine:
    def __init__(
        self,
        cfg: ModelConfig,
        *,
        mesh=None,
        max_len: int = 256,
        seed: int = 0,
        int8_weights: bool = False,
    ) -> None:
        self.cfg = cfg
        self.mesh = mesh or single_device_mesh()
        self.max_len = max_len
        self.int8_weights = int8_weights
        self.hook = shd.activation_hook(self.mesh)
        with self.mesh, activation_sharding(self.hook):
            params_shape = jax.eval_shape(
                lambda: ST.model_init(jax.random.key(seed), cfg)
            )
            self.p_shard = shd.make_param_shardings(self.mesh, params_shape,
                                                    cfg)
            self.params = jax.jit(
                lambda: ST.model_init(jax.random.key(seed), cfg),
                out_shardings=self.p_shard,
            )()
        if int8_weights:
            # weight-only PTQ (the paper's int8 inference regime): weights
            # stored int8 + per-channel scales; dequantized inside the
            # jitted steps so HBM streams half the bytes
            from repro.quant import quantize_params

            self.params = jax.jit(quantize_params)(self.params)
        self._decode_jit = None
        self._prefill_jit = None

    def _model_params(self, params):
        if self.int8_weights:
            from repro.quant import dequantize_params

            return dequantize_params(params, self.cfg.param_dtype)
        return params

    # -- jitted entries --------------------------------------------------------

    def _prefill(self, batch: dict):
        if self._prefill_jit is None:
            step = ST.make_prefill_step(self.cfg)

            def run(params, b):
                return step(self._model_params(params), b)

            self._prefill_jit = jax.jit(run)
        with self.mesh, activation_sharding(self.hook):
            return self._prefill_jit(self.params, batch)

    def _decode(self, cache, token, pos):
        if self._decode_jit is None:
            step = ST.make_decode_step(self.cfg)
            c_shard = shd.make_cache_shardings(
                self.mesh, jax.eval_shape(lambda c: c, cache)
            )

            def run(params, c, t, p):
                return step(self._model_params(params), c, t, p)

            self._decode_jit = jax.jit(
                run,
                out_shardings=(None, c_shard),
                donate_argnums=(1,),
            )
        with self.mesh, activation_sharding(self.hook):
            return self._decode_jit(self.params, cache, token, pos)

    # -- wave serving -----------------------------------------------------------

    def generate(
        self,
        prompts: np.ndarray,       # (B, P) int32 token prompts
        *,
        max_new: int = 32,
        temperature: float = 0.0,
        seed: int = 0,
    ) -> tuple[np.ndarray, ServeStats]:
        cfg = self.cfg
        bsz, plen = prompts.shape
        assert plen + max_new <= self.max_len, (plen, max_new, self.max_len)

        if cfg.embeds_input:
            raise NotImplementedError(
                "stub-frontend archs serve via decode-only cells"
            )
        t0 = time.perf_counter()
        if cfg.family == "encdec":
            # prompts are encoder frames indices in the stub: use embeds
            raise NotImplementedError("use decode cells for enc-dec serving")
        batch = {"tokens": jnp.asarray(prompts, jnp.int32)}
        logits, caches = self._prefill(batch)
        # re-lay the prefill caches into the bounded decode cache
        cache = self._expand_cache(caches, bsz, plen)
        jax.block_until_ready(logits)
        t1 = time.perf_counter()

        key = jax.random.key(seed)
        out = np.zeros((bsz, max_new), np.int32)
        token = self._sample(logits, temperature, key)
        out[:, 0] = np.asarray(token)
        for i in range(1, max_new):
            pos = jnp.asarray(plen + i - 1, jnp.int32)
            logits, cache = self._decode(cache, token, pos)
            key, sub = jax.random.split(key)
            token = self._sample(logits, temperature, sub)
            out[:, i] = np.asarray(token)
        jax.block_until_ready(logits)
        t2 = time.perf_counter()
        stats = ServeStats(
            prefill_s=t1 - t0,
            decode_s=t2 - t1,
            tokens_out=bsz * max_new,
            tokens_per_s=bsz * max_new / max(t2 - t1, 1e-9),
        )
        return out, stats

    def _sample(self, logits, temperature, key):
        if temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(key, logits / temperature).astype(
            jnp.int32
        )

    def _expand_cache(self, prefill_caches, bsz: int, plen: int):
        """Prefill returns tight (…, plen, …) caches; decode needs the
        bounded max_len layout — copy into the zeroed decode cache."""
        full = ST.model_init_cache(self.cfg, bsz, self.max_len)

        def merge(path, dst):
            src = prefill_caches
            for k in path:
                src = src[getattr(k, "key", k)]
            if dst.ndim >= 2 and src.shape != dst.shape:
                # KV tensors: (L, B, H, plen, hd) -> pad seq axis
                pad = [(0, d - s) for d, s in zip(dst.shape, src.shape)]
                return jnp.pad(src.astype(dst.dtype), pad)
            return src.astype(dst.dtype)

        return jax.tree_util.tree_map_with_path(merge, full)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    engine = ServeEngine(cfg, max_len=args.prompt_len + args.max_new)
    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(
        0, cfg.vocab_size, (args.batch, args.prompt_len), dtype=np.int32
    )
    out, stats = engine.generate(
        prompts, max_new=args.max_new, temperature=args.temperature,
        seed=args.seed,
    )
    print(json.dumps(dataclasses.asdict(stats)))
    print(f"[serve] first row tokens: {out[0, :16].tolist()}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
