import os
os.environ["XLA_FLAGS"] = os.environ.get(
    "XLA_FLAGS", "--xla_force_host_platform_device_count=512"
)
# NOTE: the two lines above MUST run before any jax import — jax locks the
# device count on first init.  Tests override via REPRO_DRYRUN_DEVICES by
# exporting XLA_FLAGS themselves before spawning this module.

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture × input shape × mesh) cell this driver

  1. builds the step function (train_step / prefill_step / decode_step),
  2. derives in/out shardings from ``repro.distributed.sharding``,
  3. ``jax.jit(...).lower(**ShapeDtypeStruct specs)`` — no allocation,
  4. ``.compile()`` — GSPMD partitioning for the production mesh,
  5. records ``memory_analysis()`` (fits-per-device proof),
     ``cost_analysis()`` (XLA's FLOP/byte counts) and the trip-scaled
     HLO statistics from ``repro.launch.hlo_analysis`` (dot FLOPs +
     per-kind collective bytes — §Roofline's inputs),
  6. writes one JSON artifact per cell under ``--out``.

Meshes: ``single`` = (data=16, model=16) — one v5e pod, 256 chips;
``multi`` = (pod=2, data=16, model=16) — 512 chips; the ``pod`` axis is
the DCN-connected slow axis (gradient compression targets it).

Usage::

  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b \
      --shape train_4k --mesh single --out runs/dryrun
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import dataclasses
import json
import math
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs.base import (
    ModelConfig,
    SHAPES,
    ShapeConfig,
    count_params,
    shape_applicable,
)
from repro.configs.registry import all_archs, get_config
from repro.distributed import sharding as shd
from repro.distributed.ctx import activation_sharding
from repro.launch import specs as S
from repro.launch import steps as ST
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import make_production_mesh
from repro.optim import adamw

# TPU v5e roofline constants (per chip)
PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


# ---------------------------------------------------------------------------
# per-cell runtime knobs
# ---------------------------------------------------------------------------


def pick_grad_accum(cfg: ModelConfig, shape: ShapeConfig, dp: int,
                    budget: int = 4 << 30) -> int:
    """Microbatch count bounding per-device train memory.

    Two terms scale with the microbatch: the remat-saved layer-boundary
    activations (L × rows/ga × S × D × bf16) and the transient FFN/MoE
    working set (rows/ga × S × ff_eff × bf16 × ~6 fusion copies).  ga is
    the smallest power-of-2 divisor of the per-device rows keeping their
    sum under ``budget`` (the earlier rows-only policy left yi-9b at
    73 GiB/device — §Perf feasibility iteration)."""
    rows = max(shape.global_batch // max(dp, 1), 1)
    ff_eff = max(
        cfg.d_ff,
        2 * cfg.d_model,
        (cfg.moe.top_k * cfg.d_ff) if cfg.moe else 0,
        cfg.ssm.d_inner(cfg.d_model) * 2 if cfg.ssm else 0,
    )
    ga = 1
    while ga < rows:
        mrows = rows / ga
        saved = cfg.num_layers * mrows * shape.seq_len * cfg.d_model * 2
        work = mrows * shape.seq_len * ff_eff * 2 * 6
        if cfg.moe:
            # capacity-padded expert buffers (≈4 live copies through the
            # expert FFN + backward)
            work += (mrows * shape.seq_len * cfg.moe.top_k
                     * cfg.moe.capacity_factor * cfg.d_model * 2 * 4)
        if saved + work <= budget:
            break
        ga *= 2
    return ga


def runtime_config(cfg: ModelConfig, shape: ShapeConfig,
                   baseline: bool = False) -> ModelConfig:
    """Shape-dependent knobs for the production lowering.

    ``baseline=True`` strips the beyond-paper optimizations (per-arch TP,
    vocab padding) so §Perf can record faithful before/after pairs.
    """
    kw: dict = {}
    # blockwise attention tiles: clamp to the sequence
    kw["attn_block_q"] = min(cfg.attn_block_q, shape.seq_len)
    kw["attn_block_k"] = min(cfg.attn_block_k, shape.seq_len)
    if shape.kind != "train":
        kw["remat"] = False
    if baseline:
        kw["pad_vocab_to"] = 0
        kw["tp_preference"] = 0
    elif shape.kind == "prefill" and shape.seq_len >= 32_768:
        # §Perf iteration B2: the flash scan's (m, l, acc) carries round-
        # trip HBM once per (qi, ki) step — nq·nk ∝ 1/block_k, so a wider
        # k-tile cuts carry traffic linearly (score-tile bytes are ∝ S²
        # and unaffected).  VMEM check: plan_attention_blocks admits
        # (512, 2048) f32 tiles comfortably.
        kw["attn_block_k"] = min(2048, shape.seq_len)
    return cfg.with_(**kw)


def pick_tp(cfg: ModelConfig, shape: ShapeConfig, chips: int) -> int:
    """Shape-aware TP: start from the arch preference and widen until the
    DP group divides the global batch (a dp group larger than the batch
    replicates/pads every activation — §Perf iteration B1)."""
    tp = cfg.tp_preference or 16
    while tp < 16 and shape.global_batch % max(chips // tp, 1) != 0:
        tp *= 2
    return tp


# ---------------------------------------------------------------------------
# lowering one cell
# ---------------------------------------------------------------------------


def lower_cell(
    arch: str,
    shape_name: str,
    mesh,
    *,
    opt_overrides: dict | None = None,
    baseline: bool = False,
):
    """Returns (lowered, compiled, meta) for one (arch, shape, mesh)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        return None, None, {"skipped": True, "reason": reason}
    cfg = runtime_config(cfg, shape, baseline=baseline)

    dp = shd.axis_size(mesh, shd.dp_axes(mesh))
    hook = shd.activation_hook(mesh)

    with activation_sharding(hook):
        params_shape = S.params_specs(cfg)
        p_shard = shd.make_param_shardings(mesh, params_shape, cfg)

        if shape.kind == "train":
            ga = pick_grad_accum(cfg, shape, dp)
            overrides = dict(opt_overrides or {})
            # ≥30B params: int8 second moments (halves resident optimizer
            # bytes; jamba-398B needs it to fit beside bf16 params)
            if not baseline and count_params(cfg) > 30e9:
                overrides.setdefault("quantize_moments", True)
            opt_cfg = adamw.AdamWConfig(**overrides)
            step = ST.make_train_step(cfg, opt_cfg, grad_accum=ga)
            batch = S.train_input_specs(cfg, shape)
            b_shard = shd.make_batch_shardings(mesh, batch)
            opt_shape = jax.eval_shape(
                lambda p: adamw.init(p, opt_cfg), params_shape
            )
            o_shard = shd.make_opt_shardings(mesh, opt_shape, p_shard)
            jitted = jax.jit(
                step,
                in_shardings=(p_shard, o_shard, b_shard),
                out_shardings=(p_shard, o_shard, None),
                donate_argnums=(0, 1),
            )
            with mesh:
                lowered = jitted.lower(params_shape, opt_shape, batch)
            meta = {"entry": "train_step", "grad_accum": ga}

        elif shape.kind == "prefill":
            step = ST.make_prefill_step(cfg)
            batch = S.prefill_input_specs(cfg, shape)
            b_shard = shd.make_batch_shardings(mesh, batch)
            cache_shape = jax.eval_shape(
                lambda p, b: step(p, b), params_shape, batch
            )[1]
            c_shard = shd.make_cache_shardings(mesh, cache_shape)
            jitted = jax.jit(
                step,
                in_shardings=(p_shard, b_shard),
                out_shardings=(None, c_shard),
            )
            with mesh:
                lowered = jitted.lower(params_shape, batch)
            meta = {"entry": "prefill_step"}

        else:  # decode
            step = ST.make_decode_step(cfg)
            d = S.decode_input_specs(cfg, shape)
            c_shard = shd.make_cache_shardings(mesh, d["cache"])
            t_shard = shd.make_batch_shardings(mesh, {"token": d["token"]})[
                "token"
            ]
            jitted = jax.jit(
                step,
                in_shardings=(p_shard, c_shard, t_shard, None),
                out_shardings=(None, c_shard),
                donate_argnums=(1,),
            )
            with mesh:
                lowered = jitted.lower(
                    params_shape, d["cache"], d["token"], d["pos"]
                )
            meta = {"entry": "decode_step"}

    compiled = lowered.compile()
    return lowered, compiled, meta


# ---------------------------------------------------------------------------
# roofline terms from the compiled artifact
# ---------------------------------------------------------------------------


def roofline_report(
    arch: str, shape_name: str, compiled, meta: dict, chips: int
) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):  # older jax: list of one dict
        cost = cost[0] if cost else {}
    try:
        mem = compiled.memory_analysis()
        mem_report = {
            k: int(getattr(mem, k))
            for k in (
                "argument_size_in_bytes",
                "output_size_in_bytes",
                "temp_size_in_bytes",
                "generated_code_size_in_bytes",
            )
            if hasattr(mem, k)
        }
    except Exception as e:  # CPU backend may not implement it
        mem_report = {"error": str(e)}

    hlo = analyze_hlo(compiled.as_text())

    # --- the three roofline terms (per-device seconds) ----------------------
    # HLO FLOPs: trip-scaled dot+conv FLOPs over the whole program; that is
    # the global count, so divide by chips for per-device work (GSPMD SPMD:
    # the HLO is already per-device — dims are the sharded local sizes —
    # so NO division is applied; see EXPERIMENTS.md §Roofline method).
    flops = hlo.flops
    hbm_bytes = hlo.memory_bytes
    coll_bytes = hlo.total_collective_bytes
    compute_s = flops / PEAK_FLOPS
    memory_s = hbm_bytes / HBM_BW
    collective_s = coll_bytes / ICI_BW

    # model FLOPs (useful work): 6·N·D train / 2·N·D inference per token.
    # Enc-dec: the encoder stack sees seq_len frames but the decoder only
    # seq_len/4 targets — weight each stack by its own token count.
    n_active = count_params(cfg, active_only=cfg.moe is not None)
    mult = 6.0 if shape.kind == "train" else 2.0
    if cfg.family == "encdec":
        from repro.launch.specs import ENCDEC_DEC_FRAC

        frac = cfg.enc_layers / (cfg.enc_layers + cfg.dec_layers)
        if shape.kind == "train":
            dec_tokens = shape.global_batch * max(
                shape.seq_len // ENCDEC_DEC_FRAC, 16
            )
            enc_tokens = shape.global_batch * shape.seq_len
        elif shape.kind == "prefill":
            enc_tokens = shape.global_batch * shape.seq_len
            dec_tokens = shape.global_batch
        else:
            enc_tokens = 0
            dec_tokens = shape.global_batch
        model_flops = mult * n_active * (
            frac * enc_tokens + (1 - frac) * dec_tokens
        )
        tokens = enc_tokens + dec_tokens
    elif shape.kind in ("train", "prefill"):
        tokens = shape.global_batch * shape.seq_len
        model_flops = mult * n_active * tokens
    else:
        tokens = shape.global_batch  # one token per sequence
        model_flops = mult * n_active * tokens
    model_flops_per_chip = model_flops / chips

    cache_bytes = 0
    if shape.kind == "decode":
        cache_shape = jax.eval_shape(
            lambda: ST.model_init_cache(cfg, shape.global_batch, shape.seq_len)
        )
        cache_bytes = sum(
            math.prod(x.shape) * x.dtype.itemsize
            for x in jax.tree.leaves(cache_shape)
        )

    terms = {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
    }
    dominant = max(terms, key=terms.get)
    bound_s = max(terms.values())
    mfu = model_flops_per_chip / PEAK_FLOPS / bound_s if bound_s > 0 else 0.0

    return {
        "arch": arch,
        "shape": shape_name,
        "chips": chips,
        "entry": meta.get("entry"),
        "grad_accum": meta.get("grad_accum"),
        "params_total": count_params(cfg),
        "params_active": n_active,
        "hlo_flops_per_device": flops,
        "hlo_bytes_per_device": hbm_bytes,
        "collective_bytes_per_device": coll_bytes,
        "collective_by_kind": dict(hlo.collective_bytes),
        "collective_counts": dict(hlo.collective_counts),
        "traffic_by_shape": {
            f"{dt}[{','.join(map(str, dims))}]": b
            for (dt, dims), b in sorted(
                hlo.traffic_by_shape.items(), key=lambda kv: -kv[1]
            )[:24]
        },
        "collective_by_shape": {
            f"{kind} {dt}[{','.join(map(str, dims))}]": b
            for (kind, dt, dims), b in sorted(
                hlo.collective_by_shape.items(), key=lambda kv: -kv[1]
            )[:16]
        },
        **terms,
        "dominant": dominant,
        "bound_s": bound_s,
        "model_flops_total": model_flops,
        "model_flops_per_chip": model_flops_per_chip,
        "cache_bytes": cache_bytes,
        "useful_flops_ratio": (model_flops_per_chip / flops) if flops else 0.0,
        "roofline_mfu": mfu,
        "xla_cost_analysis": {
            k: float(v)
            for k, v in cost.items()
            if k in ("flops", "bytes accessed", "transcendentals")
        },
        "memory_analysis": mem_report,
    }


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: str,
             *, baseline: bool = False) -> dict:
    multi = mesh_kind == "multi"
    if baseline or os.environ.get("REPRO_MESH_SHAPE"):
        tp = 0  # baseline mesh / explicit test meshes
    else:
        chips = 512 if multi else 256
        tp = pick_tp(get_config(arch), SHAPES[shape_name], chips)
        tp = 0 if tp == 16 else tp
    mesh = make_production_mesh(multi_pod=multi, tp=tp)
    chips = math.prod(mesh.devices.shape)
    t0 = time.time()
    try:
        lowered, compiled, meta = lower_cell(arch, shape_name, mesh,
                                             baseline=baseline)
    except Exception as e:
        rec = {
            "arch": arch,
            "shape": shape_name,
            "mesh": mesh_kind,
            "ok": False,
            "error": f"{type(e).__name__}: {e}",
            "traceback": traceback.format_exc()[-2000:],
        }
        _write(out_dir, arch, shape_name, mesh_kind, rec)
        return rec
    if compiled is None:  # recorded skip
        rec = {
            "arch": arch,
            "shape": shape_name,
            "mesh": mesh_kind,
            "ok": True,
            **meta,
        }
        _write(out_dir, arch, shape_name, mesh_kind, rec)
        return rec
    rec = roofline_report(arch, shape_name, compiled, meta, chips)
    rec.update(
        {
            "mesh": mesh_kind,
            "mesh_shape": list(mesh.devices.shape),
            "ok": True,
            "skipped": False,
            "compile_s": time.time() - t0,
        }
    )
    _write(out_dir, arch, shape_name, mesh_kind, rec)
    return rec


def _write(out_dir: str, arch: str, shape: str, mesh_kind: str, rec: dict):
    os.makedirs(out_dir, exist_ok=True)
    safe = arch.replace(".", "_").replace("/", "_")
    path = os.path.join(out_dir, f"{safe}__{shape}__{mesh_kind}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=[*SHAPES, None])
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="runs/dryrun")
    ap.add_argument("--baseline", action="store_true",
                    help="strip beyond-paper optimizations (per-arch TP, "
                         "vocab padding) for §Perf before/after pairs")
    args = ap.parse_args(argv)

    archs = all_archs() if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    n_fail = 0
    for arch in archs:
        for shape_name in shapes:
            for mesh_kind in meshes:
                rec = run_cell(arch, shape_name, mesh_kind, args.out,
                               baseline=args.baseline)
                if rec.get("skipped"):
                    status = f"SKIP ({rec['reason'][:48]}...)"
                elif rec["ok"]:
                    status = (
                        f"ok {rec['compile_s']:6.1f}s dom={rec['dominant']}"
                        f" mfu={rec['roofline_mfu']:.3f}"
                    )
                else:
                    status = f"FAIL {rec['error'][:90]}"
                    n_fail += 1
                print(f"[dryrun] {arch:22s} {shape_name:12s} {mesh_kind:6s} {status}",
                      flush=True)
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
