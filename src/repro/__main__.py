"""``python -m repro`` — the command-line front door.

Subcommands:

* ``list``
    Named graphs (the paper suite + showcases) and device targets.
* ``compile <graph> [--target kv260] [--strategy balanced]
  [--weight-streaming auto|off] [--max-unroll N] [--no-passes]
  [--emit DIR] [--save FILE] [--run] [--quiet]``
    Build the named graph through the declarative frontend, compile it
    under one :class:`repro.api.CompileOptions`, print the
    cycles/BRAM/DSP/spill report, and optionally emit the HLS C++
    kernels, persist the artifact, or execute the Pallas path
    (interpret mode) as a numeric smoke check.

Exit status: 0 on success, 1 on an infeasible design or failed run,
2 on bad arguments (argparse convention).
"""
from __future__ import annotations

import argparse
import sys


def _cmd_list() -> int:
    from repro import api

    print("graphs:")
    for name in sorted(api.suite()):
        print(f"  {name}")
    print("targets:")
    for name, t in sorted(api.TARGETS.items()):
        print(f"  {name}  (DSP={t.d_total}, BRAM18K={t.b_total})")
    return 0


def _cmd_compile(args: argparse.Namespace) -> int:
    from repro import api

    graphs = api.suite()
    if args.graph not in graphs:
        print(f"error: unknown graph {args.graph!r} — run "
              "`python -m repro list`", file=sys.stderr)
        return 2
    options = api.CompileOptions(
        target=args.target,
        strategy=args.strategy,
        weight_streaming=args.weight_streaming,
        max_unroll=args.max_unroll,
        passes=() if args.no_passes else None,
    )
    art = api.compile_graph(graphs[args.graph](), options)
    if not args.quiet:
        print(art.report())
    if args.emit:
        for path in art.emit_hls(args.emit):
            print(f"emitted {path}")
    if args.save:
        print(f"saved {art.save(args.save)}")
    if args.run:
        out = art.run(interpret=True)
        outs = out if isinstance(out, dict) else {"output": out}
        for name, arr in outs.items():
            print(f"ran OK: {name} shape {tuple(arr.shape)} dtype {arr.dtype}")
    return 0 if art.feasible else 1


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro",
        description="MING reproduction CLI: build + compile + emit "
                    "through the public API",
    )
    sub = ap.add_subparsers(dest="cmd", required=True)
    sub.add_parser("list", help="named graphs and device targets")
    c = sub.add_parser("compile", help="compile a named graph")
    c.add_argument("graph", help="suite graph name (see `list`)")
    c.add_argument("--target", default="kv260",
                   help="device preset (kv260 | zu3eg)")
    c.add_argument("--strategy", default="balanced",
                   choices=("balanced", "greedy"))
    c.add_argument("--weight-streaming", default="auto",
                   choices=("auto", "off"))
    c.add_argument("--max-unroll", type=int, default=None)
    c.add_argument("--no-passes", action="store_true",
                   help="skip the rewrite pipeline")
    c.add_argument("--emit", metavar="DIR",
                   help="write HLS C++ kernels + host schedule here")
    c.add_argument("--save", metavar="FILE",
                   help="persist the CompiledArtifact (pickle)")
    c.add_argument("--run", action="store_true",
                   help="execute the Pallas path (interpret mode)")
    c.add_argument("--quiet", action="store_true",
                   help="suppress the report table")
    args = ap.parse_args(argv)
    if args.cmd == "list":
        return _cmd_list()
    from repro.passes import PartitionError

    try:
        return _cmd_compile(args)
    except PartitionError as e:
        # a valid command line whose design cannot be scheduled: exit 1
        # (infeasible), not 2 (bad arguments)
        print(f"infeasible: {e}", file=sys.stderr)
        return 1
    except ValueError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2


if __name__ == "__main__":
    sys.exit(main())
