"""``python -m repro`` — the command-line front door.

Subcommands:

* ``list``
    Named graphs (the paper suite + showcases + zoo) and device targets.
* ``compile <graph | model.onnx | model.json> [--target kv260]
  [--strategy balanced] [--weight-streaming auto|off] [--max-unroll N]
  [--no-passes] [--emit DIR] [--save FILE] [--run] [--trace PATH]
  [--quiet]``
    Build the named suite graph — or **import** an ONNX model / JSON
    model card (``repro.frontends``) — compile it under one
    :class:`repro.api.CompileOptions`, print the cycles/BRAM/DSP/spill
    report, and optionally emit the HLS C++ kernels, persist the
    artifact, or execute the Pallas path (interpret mode) as a numeric
    smoke check.  Imported weights ride along into ``--run``.
* ``zoo [--export DIR]``
    The bundled model zoo (LeNet-5, tiny-VGG, residual edge model);
    ``--export`` writes each model's JSON card (``examples/lenet5.json``
    is one of these).
* ``lint <graph | model.onnx | card.json> ... [--all] [--target T ...]
  [--json PATH] [--fail-on error|warning|info] [--quiet]``
    Static analysis (ISSUE 9): compile each graph (suite name or model
    file) for each target and print the ``repro.analyze`` diagnostics
    — stream-skew/deadlock, integer overflow, schedule hazards, model
    hygiene.  ``--all`` lints the whole named suite (zoo included);
    ``--json`` writes the versioned diagnostics document (the CI
    artifact); ``--fail-on`` sets the severity that makes the exit
    status 1 (default ``error``).
* ``profile <graph | model.onnx | card.json> [--target T ...]
  [--reps N] [--warmup N] [--clock-mhz F] [--threshold F]
  [--json PATH] [--no-layers] [--quiet]``
    Modeled-vs-measured profiling (ISSUE 10): compile the graph for
    each target, execute it, and print the per-group table joining the
    resource model's cycle predictions against measured wall times
    (implied clock, model-error ratio, roofline utilization), flagging
    groups whose ratio drifts past ``--threshold``× the median.
    ``--json`` writes the machine-readable document.

Exit status: 0 on success, 1 on an infeasible design, failed run, or
diagnostics at/above ``--fail-on``, 2 on bad arguments (argparse
convention).
"""
from __future__ import annotations

import argparse
import os
import sys


def _cmd_list() -> int:
    from repro import api

    print("graphs:")
    for name in sorted(api.suite()):
        print(f"  {name}")
    print("targets:")
    for name, t in sorted(api.TARGETS.items()):
        print(f"  {name}  (DSP={t.d_total}, BRAM18K={t.b_total})")
    return 0


def _cmd_zoo(args: argparse.Namespace) -> int:
    from repro.frontends import zoo

    print("zoo models (compile with `python -m repro compile <name>`):")
    for name, make in sorted(zoo.ZOO.items()):
        dfg = make()
        consts = sum(
            v.num_elements for v in dfg.values.values() if v.is_constant
        )
        print(f"  {name:<18} {len(dfg.nodes):>2} layers, "
              f"{consts / 1024:.1f} Ki params, "
              f"input {dfg.values[dfg.graph_inputs[0]].shape}")
    if args.export:
        os.makedirs(args.export, exist_ok=True)
        for name in sorted(zoo.ZOO):
            path = os.path.join(args.export, f"{name}.json")
            with open(path, "w") as f:
                f.write(zoo.card_json(name))
            print(f"exported {path}")
    return 0


def _load_graph(spec: str, quiet: bool = False):
    """(dfg, params) for a suite name or an importable model file.

    Suite names win over same-named filesystem entries (a stray
    ``lenet5/`` directory in cwd must not shadow the zoo graph);
    model files are recognized by extension or an explicit path.
    """
    from repro import api

    graphs = api.suite()
    ext = os.path.splitext(spec)[1].lower()
    if spec in graphs and ext not in (".onnx", ".json"):
        return graphs[spec](), {}
    if ext in (".onnx", ".json") or os.path.exists(spec):
        from repro import frontends

        model = frontends.import_model(spec)
        missing = model.missing_params()
        if missing and not quiet:
            print(f"# note: {len(missing)} constant(s) have no imported "
                  f"weights (random init): {', '.join(missing[:6])}"
                  f"{', …' if len(missing) > 6 else ''}")
        return model.dfg, model.params
    raise ValueError(
        f"unknown graph {spec!r} — run `python -m repro list`, or "
        "pass a .onnx / .json model file"
    )


def _cmd_compile(args: argparse.Namespace) -> int:
    from repro import api

    try:
        dfg, params = _load_graph(args.graph, quiet=args.quiet)
    except OSError as e:
        # missing file, directory-instead-of-file, unreadable path, …:
        # all bad arguments (exit 2), never a raw traceback
        print(f"error: {e}", file=sys.stderr)
        return 2
    options = api.CompileOptions(
        target=args.target,
        strategy=args.strategy,
        weight_streaming=args.weight_streaming,
        max_unroll=args.max_unroll,
        passes=() if args.no_passes else None,
        trace=args.trace if args.trace else False,
    )
    art = api.compile_graph(dfg, options)
    if not args.quiet:
        print(art.report())
    if args.emit:
        for path in art.emit_hls(args.emit):
            print(f"emitted {path}")
    if args.save:
        print(f"saved {art.save(args.save)}")
    if args.run:
        out = art.run(params=params or None, interpret=True)
        outs = out if isinstance(out, dict) else {"output": out}
        for name, arr in outs.items():
            print(f"ran OK: {name} shape {tuple(arr.shape)} dtype {arr.dtype}")
    if args.trace:
        # written last so pass/DP/DSE spans, emitter timing, and any
        # --run runtime counters all land in the one trace
        print(f"trace written {art.write_trace(args.trace)}")
    return 0 if art.feasible else 1


def _cmd_lint(args: argparse.Namespace) -> int:
    from repro import analyze, api

    specs = list(args.graphs)
    if args.all:
        specs.extend(sorted(api.suite()))
    if not specs:
        print("error: pass at least one graph/model, or --all",
              file=sys.stderr)
        return 2
    targets = args.target or ["kv260"]

    all_diags: list = []
    meta: dict = {"targets": list(targets), "graphs": []}
    for spec in specs:
        try:
            dfg, _params = _load_graph(spec, quiet=True)
        except OSError as e:
            print(f"error: {e}", file=sys.stderr)
            return 2
        for target in targets:
            options = api.CompileOptions(target=target, lint="warn")
            design = api.compile_design(dfg, options=options)
            diags = list(design.diagnostics)
            meta["graphs"].append({
                "graph": dfg.name,
                "target": target,
                "counts": analyze.severity_counts(diags),
            })
            all_diags.extend(diags)
            if not args.quiet:
                worst = analyze.max_severity(diags)
                print(f"{dfg.name} @ {target}: {len(diags)} diagnostic(s)"
                      f"{f', worst {worst.value}' if worst else ''}")
                for d in diags:
                    print(f"  {target}: {d.format()}")

    if args.json:
        import json

        doc = analyze.diagnostics_to_json(all_diags, meta=meta)
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=2)
            f.write("\n")
        print(f"diagnostics written {args.json}")

    failing = analyze.at_or_above(all_diags, args.fail_on)
    if failing:
        print(f"lint: {len(failing)} diagnostic(s) at/above "
              f"{args.fail_on!r}", file=sys.stderr)
        return 1
    return 0


def _cmd_profile(args: argparse.Namespace) -> int:
    from repro import api
    from repro.instrument import profile_artifact

    try:
        dfg, _params = _load_graph(args.graph, quiet=args.quiet)
    except OSError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    targets = args.target or ["kv260"]
    reports = []
    for target in targets:
        art = api.compile_graph(dfg, target=target)
        rep = profile_artifact(
            art, reps=args.reps, warmup=args.warmup,
            clock_mhz=args.clock_mhz, threshold=args.threshold,
        )
        reports.append(rep)
        if not args.quiet:
            print(rep.format_table(layers=not args.no_layers))
            print()
    if args.json:
        import json

        from repro.instrument import provenance

        doc = {
            "version": 1,
            "graph": dfg.name,
            "provenance": provenance(),
            "profiles": [r.to_json() for r in reports],
        }
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=2)
            f.write("\n")
        print(f"profile written {args.json}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro",
        description="MING reproduction CLI: build/import + compile + emit "
                    "through the public API",
    )
    sub = ap.add_subparsers(dest="cmd", required=True)
    sub.add_parser("list", help="named graphs and device targets")
    z = sub.add_parser("zoo", help="the bundled model zoo")
    z.add_argument("--export", metavar="DIR",
                   help="write each zoo model's JSON card here")
    c = sub.add_parser("compile",
                       help="compile a named graph or model file")
    c.add_argument("graph",
                   help="suite graph name (see `list`), or a path to a "
                        ".onnx model / .json model card")
    c.add_argument("--target", default="kv260",
                   help="device preset (kv260 | zu3eg)")
    c.add_argument("--strategy", default="balanced",
                   choices=("balanced", "greedy"))
    c.add_argument("--weight-streaming", default="auto",
                   choices=("auto", "off"))
    c.add_argument("--max-unroll", type=int, default=None)
    c.add_argument("--no-passes", action="store_true",
                   help="skip the rewrite pipeline")
    c.add_argument("--emit", metavar="DIR",
                   help="write HLS C++ kernels + host schedule here")
    c.add_argument("--save", metavar="FILE",
                   help="persist the CompiledArtifact (pickle)")
    c.add_argument("--run", action="store_true",
                   help="execute the Pallas path (interpret mode) with "
                        "imported weights when available")
    c.add_argument("--trace", metavar="PATH",
                   help="instrument the compile (and --emit/--run) and "
                        "write a Chrome trace-event JSON here "
                        "(chrome://tracing / Perfetto)")
    c.add_argument("--quiet", action="store_true",
                   help="suppress the report table")
    lt = sub.add_parser("lint",
                        help="static diagnostics for graphs / model files")
    lt.add_argument("graphs", nargs="*",
                    help="suite graph names or .onnx / .json model files")
    lt.add_argument("--all", action="store_true",
                    help="lint every named suite graph (zoo included)")
    lt.add_argument("--target", action="append", default=None,
                    help="device preset; repeatable (default: kv260)")
    lt.add_argument("--json", metavar="PATH",
                    help="write the JSON diagnostics document here")
    lt.add_argument("--fail-on", default="error",
                    choices=("error", "warning", "info"),
                    help="exit 1 when diagnostics at/above this severity "
                         "fire (default: error)")
    lt.add_argument("--quiet", action="store_true",
                    help="suppress per-diagnostic lines")
    pf = sub.add_parser("profile",
                        help="modeled-vs-measured per-group profiling")
    pf.add_argument("graph",
                    help="suite graph name (see `list`), or a path to a "
                         ".onnx model / .json model card")
    pf.add_argument("--target", action="append", default=None,
                    help="device preset; repeatable (default: kv260)")
    pf.add_argument("--reps", type=int, default=3,
                    help="measured repetitions after warmup (default 3)")
    pf.add_argument("--warmup", type=int, default=1,
                    help="discarded warmup runs (default 1)")
    pf.add_argument("--clock-mhz", type=float, default=300.0,
                    help="nominal fabric clock for modeled_ms "
                         "(default 300)")
    pf.add_argument("--threshold", type=float, default=2.0,
                    help="flag groups whose model-error ratio is this "
                         "many x off the median (default 2.0)")
    pf.add_argument("--json", metavar="PATH",
                    help="write the JSON profile document here")
    pf.add_argument("--no-layers", action="store_true",
                    help="suppress the per-layer attribution table")
    pf.add_argument("--quiet", action="store_true",
                    help="suppress the tables (useful with --json)")
    args = ap.parse_args(argv)
    if args.cmd == "list":
        return _cmd_list()
    if args.cmd == "zoo":
        return _cmd_zoo(args)
    from repro.passes import PartitionError

    try:
        if args.cmd == "lint":
            return _cmd_lint(args)
        if args.cmd == "profile":
            return _cmd_profile(args)
        return _cmd_compile(args)
    except PartitionError as e:
        # a valid command line whose design cannot be scheduled: exit 1
        # (infeasible), not 2 (bad arguments)
        print(f"infeasible: {e}", file=sys.stderr)
        return 1
    except ValueError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2


if __name__ == "__main__":
    sys.exit(main())
