"""Shared frontend types: what every importer hands the compiler.

An importer (ONNX reader, model-card loader) produces an
:class:`ImportedModel`: the builder-built DFG plus the imported weights,
keyed by the DFG's *constant value names* so they thread straight into
``CompiledArtifact.run(params=model.params)`` — the one contract that
lets ``python -m repro compile model.onnx --run`` execute imported
networks with their trained weights instead of the smoke-run random
init.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.ir import DFG


@dataclass
class ImportedModel:
    """A model pulled in from an external description.

    ``params`` binds the DFG's constant values (weights, biases) to the
    imported arrays; it may be empty (a weightless model card) — the
    run path then falls back to the deterministic random init exactly
    like a native builder graph.
    """

    name: str
    dfg: DFG
    params: dict[str, np.ndarray] = field(default_factory=dict)
    #: which importer produced this ("card" | "onnx")
    source: str = "card"

    def missing_params(self) -> list[str]:
        """Constant values the import did *not* bind (run() randomizes
        these) — surfaced by the CLI so a weightless run is explicit."""
        consts = {
            n for n, v in self.dfg.values.items() if v.is_constant
        }
        return sorted(consts - set(self.params))
