"""The bundled model zoo: real CNN topologies through the importer path.

Three classifier-shaped models exercise everything the paper suite's
synthetic kernels do not — conv→dense transitions (flatten), deep
pool pyramids, and residual trunks feeding a head:

* ``lenet5``          — the classic 5-layer LeNet (SAME-padding
                        variant: this stack's convs are 'same', so the
                        32→28→14→10→5 VALID cascade becomes
                        32→32→16→16→8), conv/pool ×2 → flatten →
                        three dense layers;
* ``tiny_vgg_32``     — a VGG-style double-conv pyramid at 32²,
                        (conv·conv·pool)×2 → flatten → dense head;
* ``edge_residual_32``— two residual blocks with an avg-pool and a
                        dense head — the skip-connection model an edge
                        deployment actually ships;
* ``resnet_mini_16``  — a ResNet-18-flavoured strided trunk: stride-1
                        stem, two stride-2 SAME downsample convs each
                        followed by an identity residual block, then a
                        global average pool and the dense head — the
                        strided streaming conv path end to end.

Every entry is a plain builder graph (so the whole pass pipeline,
partitioner, and both backends apply unchanged), is registered in the
benchmark suite (``repro.api.suite()`` → per-target BENCH_smoke rows),
and round-trips through the model-card format —
``python -m repro zoo --export DIR`` writes the cards
(``examples/lenet5.json`` is exactly ``card("lenet5")``).
"""
from __future__ import annotations

import json

from repro.api.builder import (
    AvgPool,
    Conv2D,
    Dense,
    Flatten,
    MaxPool,
    ReLU,
    Residual,
    Sequential,
)
from repro.core.ir import DFG

from .modelcard import export_card


def lenet5(n_size: int = 32, c_in: int = 1, classes: int = 10) -> DFG:
    """LeNet-5 (SAME-padding variant): C6@5×5 → pool → C16@5×5 → pool →
    flatten → 120 → 84 → ``classes``."""
    return Sequential(
        [
            Conv2D(6, kernel=5), ReLU(), MaxPool(2),
            Conv2D(16, kernel=5), ReLU(), MaxPool(2),
            Flatten(),
            Dense(120), ReLU(),
            Dense(84), ReLU(),
            Dense(classes),
        ],
        input_shape=(1, n_size, n_size, c_in),
        name="lenet5",
    ).build()


def tiny_vgg(n_size: int = 32, c_in: int = 3, classes: int = 10) -> DFG:
    """A VGG-flavoured double-conv pyramid: 16·16/pool → 32·32/pool →
    flatten → 64 → ``classes``."""
    return Sequential(
        [
            Conv2D(16), ReLU(), Conv2D(16), ReLU(), MaxPool(2),
            Conv2D(32), ReLU(), Conv2D(32), ReLU(), MaxPool(2),
            Flatten(),
            Dense(64), ReLU(),
            Dense(classes),
        ],
        input_shape=(1, n_size, n_size, c_in),
        name=f"tiny_vgg_{n_size}",
    ).build()


def edge_residual(n_size: int = 32, c: int = 16, classes: int = 10) -> DFG:
    """Residual edge model: stem conv → two residual blocks → avg-pool →
    flatten → dense head (the diamond FIFO sizing meets the classifier
    head)."""
    block = lambda: Residual([Conv2D(c), ReLU(), Conv2D(c)])  # noqa: E731
    return Sequential(
        [
            Conv2D(c), ReLU(),
            block(), ReLU(),
            block(), ReLU(),
            AvgPool(2),
            Flatten(),
            Dense(classes),
        ],
        input_shape=(1, n_size, n_size, 3),
        name=f"edge_residual_{n_size}",
    ).build()


def resnet_mini(n_size: int = 16, c: int = 8, classes: int = 10) -> DFG:
    """ResNet-18-flavoured strided trunk: stem → (stride-2 downsample
    conv → identity residual block) ×2 → global average pool → dense
    head.  Each downsample halves the map and doubles the channels; the
    global pool is an AvgPool whose window is the whole remaining map
    (the DIV exit path, floor division in the integer regime)."""
    block = lambda ch: Residual([Conv2D(ch), ReLU(), Conv2D(ch)])  # noqa: E731
    return Sequential(
        [
            Conv2D(c), ReLU(),
            Conv2D(2 * c, stride=2), ReLU(),
            block(2 * c), ReLU(),
            Conv2D(4 * c, stride=2), ReLU(),
            block(4 * c), ReLU(),
            AvgPool(n_size // 4),
            Flatten(),
            Dense(classes),
        ],
        input_shape=(1, n_size, n_size, 3),
        name=f"resnet_mini_{n_size}",
    ).build()


#: the registry the CLI (`python -m repro zoo`), the benchmark suite,
#: and the tests iterate — names match each graph's DFG name
ZOO: dict[str, object] = {
    "lenet5": lenet5,
    "tiny_vgg_32": tiny_vgg,
    "edge_residual_32": edge_residual,
    "resnet_mini_16": resnet_mini,
}


def card(name: str) -> dict:
    """The model card for a zoo entry (weightless — the run path's
    deterministic random init stands in for training)."""
    if name not in ZOO:
        raise KeyError(f"unknown zoo model {name!r} — one of {sorted(ZOO)}")
    return export_card(ZOO[name]())


def card_json(name: str) -> str:
    return json.dumps(card(name), indent=2) + "\n"
