"""ONNX importer: trained NCHW models onto the NHWC builder frontend.

Dependency-optional by construction: when the ``onnx`` package is
installed it does the parsing (``onnx.load`` + ``numpy_helper``);
otherwise a minimal vendored **protobuf wire-format decoder** reads the
node / initializer / value-info subset this importer needs directly
from the ``.onnx`` bytes — the container ships no ONNX, and a model zoo
frontend that silently required one would never run in CI.

Supported operator subset (everything the builder can express):
``Conv`` (groups=1, dilation 1, any uniform stride, SAME_UPPER / VALID
/ equivalent explicit pads), ``BatchNormalization`` (inference form,
folded into the producing Conv's weights and bias at import),
``GlobalAveragePool`` (square maps, via the AVG epilogue's DIV exit
path), ``Relu``, ``MaxPool`` / ``AveragePool`` (square VALID windows),
``Gemm`` (α=1, transA=0, β∈{0,1}), ``Add``, ``Flatten`` (axis=1).
Anything else raises :class:`OnnxImportError` naming the node and the
constraint.  Per-channel biases (Conv B, Gemm C) import as rank-1
broadcast epilogue operands — C resident elements, not the H·W·C
materialization a full-tensor constant would cost the resource model.

Padding convention: the streaming frame splits a SAME deficit
*end-heavy* (``begin = total // 2``), which is exactly ONNX
``SAME_UPPER`` — including the asymmetric split of even kernels.
``SAME_LOWER`` is only accepted where its begin-heavy split coincides
(symmetric totals); an asymmetric SAME_LOWER conv is *rejected*, never
silently mis-executed with the mirrored frame.

Layout: ONNX is NCHW, the streaming kernels are NHWC.  Every
layout-sensitive op is imported *faithfully* inside an explicit
transpose sandwich (NCHW→NHWC → op → NHWC→NCHW) so each imported value
keeps its ONNX shape; the layout-canonicalization pass
(``repro.passes.layout``) then cancels the interior pairs and folds the
final NHWC→NCHW transpose into the classifier head's flatten, leaving
only the graph-boundary transposes the external NCHW contract requires
(for a classifier, exactly one: the input bridge; a model with a
rank-4 NCHW output also keeps the output-side bridge).  Imported weights are re-laid out at import time
(OIHW→HWIO for convs, ``transB`` for Gemm) and returned as
``ImportedModel.params`` keyed by the DFG's constant value names —
``CompiledArtifact.run(params=...)`` executes the trained network.

Resource modeling note: streams are costed at the paper's int8 PTQ
width (``elem_bits=8``) regardless of the ONNX tensor dtype; numerics
at run time follow the imported arrays' dtype.
"""
from __future__ import annotations

import os
import re
import struct
from dataclasses import dataclass, field

import numpy as np

from .base import ImportedModel

NCHW2NHWC = (0, 2, 3, 1)
NHWC2NCHW = (0, 3, 1, 2)

SUPPORTED_OPS = ("Conv", "BatchNormalization", "GlobalAveragePool", "Relu",
                 "MaxPool", "AveragePool", "Gemm", "Add", "Flatten")


class OnnxImportError(ValueError):
    """The model is malformed or uses something outside the subset."""


def _fail(msg: str) -> None:
    raise OnnxImportError(msg)


# ---------------------------------------------------------------------------
# Normalized model (produced by both parsing paths)
# ---------------------------------------------------------------------------


@dataclass
class OnnxNode:
    op_type: str
    name: str
    inputs: list[str]
    outputs: list[str]
    attrs: dict[str, object] = field(default_factory=dict)


@dataclass
class OnnxGraph:
    name: str
    inputs: list[tuple[str, tuple[int, ...]]]   # non-initializer inputs
    outputs: list[str]
    nodes: list[OnnxNode]
    initializers: dict[str, np.ndarray]


# ---------------------------------------------------------------------------
# Vendored protobuf wire decoder (the no-`onnx` path)
# ---------------------------------------------------------------------------


def _varint(buf: bytes, i: int) -> tuple[int, int]:
    out = 0
    shift = 0
    while True:
        if i >= len(buf):
            _fail("truncated varint in protobuf stream")
        b = buf[i]
        i += 1
        out |= (b & 0x7F) << shift
        if not b & 0x80:
            return out, i
        shift += 7
        if shift > 70:
            _fail("varint overflow in protobuf stream")


def _signed64(v: int) -> int:
    return v - (1 << 64) if v >= (1 << 63) else v


def _fields(buf: bytes):
    """Yield ``(field_number, wire_type, value)`` triples; length-
    delimited values are bytes, varints ints, fixed32/64 raw ints."""
    i = 0
    n = len(buf)
    while i < n:
        tag, i = _varint(buf, i)
        fno, wt = tag >> 3, tag & 7
        if wt == 0:
            v, i = _varint(buf, i)
        elif wt == 1:
            if i + 8 > n:
                _fail("truncated fixed64")
            v = int.from_bytes(buf[i:i + 8], "little")
            i += 8
        elif wt == 2:
            ln, i = _varint(buf, i)
            if i + ln > n:
                _fail("truncated length-delimited field")
            v = buf[i:i + ln]
            i += ln
        elif wt == 5:
            if i + 4 > n:
                _fail("truncated fixed32")
            v = int.from_bytes(buf[i:i + 4], "little")
            i += 4
        else:
            _fail(f"unsupported protobuf wire type {wt}")
        yield fno, wt, v


def _collect(buf: bytes) -> dict[int, list[tuple[int, object]]]:
    out: dict[int, list[tuple[int, object]]] = {}
    for fno, wt, v in _fields(buf):
        out.setdefault(fno, []).append((wt, v))
    return out


def _ints(entries: list[tuple[int, object]]) -> list[int]:
    """A repeated int64 field: scalar entries or packed blocks."""
    vals: list[int] = []
    for wt, v in entries:
        if wt == 0:
            vals.append(_signed64(v))
        elif wt == 2:
            i = 0
            while i < len(v):
                x, i = _varint(v, i)
                vals.append(_signed64(x))
        else:
            _fail("unexpected wire type for repeated int field")
    return vals


def _one_int(fields: dict, fno: int, default: int = 0) -> int:
    entries = fields.get(fno)
    if not entries:
        return default
    return _ints(entries)[-1]


def _one_bytes(fields: dict, fno: int, default: bytes = b"") -> bytes:
    entries = fields.get(fno)
    if not entries:
        return default
    wt, v = entries[-1]
    if wt != 2:
        _fail(f"field {fno}: expected length-delimited, got wire type {wt}")
    return v


def _one_str(fields: dict, fno: int, default: str = "") -> str:
    b = _one_bytes(fields, fno, default.encode())
    return b.decode("utf-8", "replace")


def _one_float(fields: dict, fno: int, default: float = 0.0) -> float:
    entries = fields.get(fno)
    if not entries:
        return default
    wt, v = entries[-1]
    if wt != 5:
        _fail(f"field {fno}: expected fixed32 float, got wire type {wt}")
    return struct.unpack("<f", int(v).to_bytes(4, "little"))[0]


#: TensorProto.DataType → numpy (the subset a CNN checkpoint uses)
_DTYPES = {1: np.float32, 2: np.uint8, 3: np.int8, 6: np.int32,
           7: np.int64, 11: np.float64}


def _tensor(buf: bytes) -> tuple[str, np.ndarray]:
    f = _collect(buf)
    dims = tuple(_ints(f.get(1, [])))
    dtype_code = _one_int(f, 2, 1)
    name = _one_str(f, 8)
    np_dtype = _DTYPES.get(dtype_code)
    if np_dtype is None:
        _fail(f"initializer {name!r}: unsupported data_type {dtype_code}")
    raw = _one_bytes(f, 9)
    if raw:
        arr = np.frombuffer(raw, dtype=np.dtype(np_dtype).newbyteorder("<"))
    elif np_dtype is np.float32 and 4 in f:
        vals = []
        for wt, v in f[4]:
            if wt == 2:
                vals.extend(np.frombuffer(v, dtype="<f4").tolist())
            elif wt == 5:
                vals.append(struct.unpack(
                    "<f", int(v).to_bytes(4, "little"))[0])
        arr = np.asarray(vals, dtype=np.float32)
    elif 7 in f:
        arr = np.asarray(_ints(f[7]), dtype=np.int64)
    elif 5 in f:
        arr = np.asarray(_ints(f[5]), dtype=np.int32).astype(np_dtype)
    else:
        arr = np.zeros(0, dtype=np_dtype)
    want = int(np.prod(dims)) if dims else 1
    if arr.size != want:
        _fail(f"initializer {name!r}: {arr.size} elements for dims {dims}")
    return name, arr.reshape(dims).astype(np_dtype, copy=False)


def _value_info(buf: bytes) -> tuple[str, tuple[int, ...]]:
    f = _collect(buf)
    name = _one_str(f, 1)
    tensor_type = _collect(_one_bytes(_collect(_one_bytes(f, 2)), 1))
    shape_msg = _one_bytes(tensor_type, 2)
    dims: list[int] = []
    for wt, v in _collect(shape_msg).get(1, []):
        if wt != 2:
            continue
        d = _collect(v)  # type: ignore[arg-type]
        if 2 in d and 1 not in d:
            _fail(f"graph input {name!r}: symbolic dimension "
                  f"{_one_str(d, 2)!r} — static shapes required")
        dims.append(_one_int(d, 1))
    return name, tuple(dims)


def _value_name(buf: bytes) -> str:
    """Just a ValueInfoProto's name — graph *outputs* only need names,
    and parsing their (possibly symbolic, shape-inferred) type info
    would reject models the `onnx`-package path accepts."""
    return _one_str(_collect(buf), 1)


def _attribute(buf: bytes) -> tuple[str, object]:
    f = _collect(buf)
    name = _one_str(f, 1)
    if 8 in f:                    # ints
        return name, _ints(f[8])
    if 3 in f:                    # i
        return name, _one_int(f, 3)
    if 2 in f:                    # f
        return name, _one_float(f, 2)
    if 4 in f:                    # s
        return name, _one_bytes(f, 4).decode("utf-8", "replace")
    if 5 in f:                    # t (tensor)
        return name, _tensor(_one_bytes(f, 5))[1]
    return name, None


def _node(buf: bytes) -> OnnxNode:
    f = _collect(buf)
    return OnnxNode(
        op_type=_one_str(f, 4),
        name=_one_str(f, 3),
        inputs=[v.decode("utf-8", "replace")
                for wt, v in f.get(1, []) if wt == 2],
        outputs=[v.decode("utf-8", "replace")
                 for wt, v in f.get(2, []) if wt == 2],
        attrs=dict(_attribute(v) for wt, v in f.get(5, []) if wt == 2),
    )


def decode_wire(data: bytes) -> OnnxGraph:
    """Parse ModelProto bytes with the vendored decoder."""
    model = _collect(data)
    graph_buf = _one_bytes(model, 7)
    if not graph_buf:
        _fail("no GraphProto in the model (is this an .onnx file?)")
    g = _collect(graph_buf)
    inits = dict(_tensor(v) for wt, v in g.get(5, []) if wt == 2)
    inputs = [_value_info(v) for wt, v in g.get(11, []) if wt == 2]
    outputs = [_value_name(v) for wt, v in g.get(12, []) if wt == 2]
    nodes = [_node(v) for wt, v in g.get(1, []) if wt == 2]
    return OnnxGraph(
        name=_one_str(g, 2, "onnx_model"),
        inputs=[(n, s) for n, s in inputs if n not in inits],
        outputs=outputs,
        nodes=nodes,
        initializers=inits,
    )


# ---------------------------------------------------------------------------
# `onnx` package path (used when installed)
# ---------------------------------------------------------------------------


def _decode_with_onnx_pkg(data: bytes) -> OnnxGraph:  # pragma: no cover
    import onnx
    from onnx import numpy_helper

    model = onnx.load_model_from_string(data)
    g = model.graph
    inits = {t.name: numpy_helper.to_array(t) for t in g.initializer}
    inputs = []
    for vi in g.input:
        if vi.name in inits:
            continue
        dims = []
        for d in vi.type.tensor_type.shape.dim:
            if d.dim_param:
                _fail(f"graph input {vi.name!r}: symbolic dimension "
                      f"{d.dim_param!r} — static shapes required")
            dims.append(d.dim_value)
        inputs.append((vi.name, tuple(dims)))
    nodes = []
    for n in g.node:
        attrs: dict[str, object] = {}
        for a in n.attribute:
            if a.type == onnx.AttributeProto.INT:
                attrs[a.name] = a.i
            elif a.type == onnx.AttributeProto.INTS:
                attrs[a.name] = list(a.ints)
            elif a.type == onnx.AttributeProto.FLOAT:
                attrs[a.name] = a.f
            elif a.type == onnx.AttributeProto.STRING:
                attrs[a.name] = a.s.decode("utf-8", "replace")
            elif a.type == onnx.AttributeProto.TENSOR:
                attrs[a.name] = numpy_helper.to_array(a.t)
        nodes.append(OnnxNode(n.op_type, n.name, list(n.input),
                              list(n.output), attrs))
    return OnnxGraph(g.name or "onnx_model", inputs,
                     [o.name for o in g.output], nodes, inits)


# ---------------------------------------------------------------------------
# Mapping onto the builder
# ---------------------------------------------------------------------------


class _Names:
    """ONNX value names → unique IR-safe identifiers."""

    def __init__(self) -> None:
        self.used: set[str] = set()

    def __call__(self, onnx_name: str, fallback: str = "v") -> str:
        base = re.sub(r"[^0-9A-Za-z_]", "_", onnx_name) or fallback
        if base[0].isdigit():
            base = f"v_{base}"
        name = base
        i = 1
        while name in self.used:
            name = f"{base}_{i}"
            i += 1
        self.used.add(name)
        return name


def _square(node: OnnxNode, vals: list[int], what: str) -> int:
    if len(vals) != 2 or vals[0] != vals[1]:
        _fail(f"{node.op_type} {node.name!r}: non-square {what} {vals}")
    return vals[0]


def _uniform_stride(node: OnnxNode, default: int = 1) -> int:
    strides = node.attrs.get("strides")
    if strides is None:
        return default
    if len(set(strides)) != 1:
        _fail(f"{node.op_type} {node.name!r}: non-uniform strides {strides}")
    return int(strides[0])


def _same_pads(n: int, k: int, s: int) -> tuple[int, int]:
    """End-heavy (begin, end) SAME split for extent ``n`` — the ONNX
    SAME_UPPER convention, and the split the builder/streaming frame
    applies for ``padding="SAME"``."""
    out = -(-n // s)
    total = max(0, s * (out - 1) + k - n)
    return total // 2, total - total // 2


def _resolve_conv_padding(node: OnnxNode, kernel: int, stride: int,
                          h_in: int, w_in: int) -> str:
    """Map (auto_pad, pads, kernel, stride, input extents) onto the
    builder's ``"SAME"`` / ``"VALID"`` vocabulary, or reject by name.

    The streaming frame splits a SAME deficit end-heavy — exactly ONNX
    SAME_UPPER, *including* the asymmetric split of even kernels.
    SAME_LOWER pads begin-heavy, so it is only accepted where the two
    splits coincide (symmetric totals); anything else is rejected
    rather than silently executed with a mirrored window.  Explicit
    pads are accepted when they are all-zero (VALID) or equal the
    SAME_UPPER frame for the actual input extents.
    """
    auto = node.attrs.get("auto_pad", "NOTSET") or "NOTSET"
    pads = [int(p) for p in (node.attrs.get("pads") or [])]
    if auto not in ("NOTSET", "VALID", "SAME_UPPER", "SAME_LOWER"):
        _fail(f"Conv {node.name!r}: unknown auto_pad {auto!r}")
    if auto != "NOTSET" and any(pads):
        _fail(f"Conv {node.name!r}: auto_pad={auto!r} with explicit "
              f"pads={pads} — the ONNX spec forbids setting both")
    if auto == "VALID":
        return "VALID"
    same_h = _same_pads(h_in, kernel, stride)
    same_w = _same_pads(w_in, kernel, stride)
    if auto == "SAME_UPPER":
        return "SAME"
    if auto == "SAME_LOWER":
        if same_h[0] != same_h[1] or same_w[0] != same_w[1]:
            _fail(f"Conv {node.name!r}: auto_pad=SAME_LOWER needs a "
                  f"begin-heavy pad split, but kernel {kernel} stride "
                  f"{stride} on a {h_in}x{w_in} input pads asymmetrically "
                  f"(H {same_h}, W {same_w}) — the streaming frame is "
                  "end-heavy (SAME_UPPER); rejecting rather than "
                  "mis-placing the window")
        return "SAME"
    if not pads:
        return "VALID"
    if len(pads) != 4:
        _fail(f"Conv {node.name!r}: pads {pads} must have 4 entries "
              "(top, left, bottom, right)")
    if not any(pads):
        return "VALID"
    want = [same_h[0], same_w[0], same_h[1], same_w[1]]
    if pads == want:
        return "SAME"
    _fail(f"Conv {node.name!r}: explicit pads {pads} are neither zero "
          f"(VALID) nor the SAME_UPPER frame {want} for kernel {kernel} "
          f"stride {stride} on a {h_in}x{w_in} input — arbitrary padding "
          "does not map onto the streaming conv")
    raise AssertionError("unreachable")


def _check_no_padding(node: OnnxNode) -> None:
    auto = node.attrs.get("auto_pad", "NOTSET") or "NOTSET"
    pads = node.attrs.get("pads")
    if auto == "VALID" or auto == "NOTSET":
        if pads and any(pads):
            _fail(f"{node.op_type} {node.name!r}: padded pooling is not "
                  f"supported (pads={pads})")
        return
    _fail(f"{node.op_type} {node.name!r}: auto_pad={auto!r} pooling is "
          "not supported")


def _bn_cast_back(arr: np.ndarray, dtype: np.dtype, node: OnnxNode,
                  what: str) -> np.ndarray:
    """Return the float64 fold result ``arr`` in the Conv's parameter
    dtype.  Float dtypes just cast; integer (PTQ) dtypes require the
    fold to be *exactly* representable — anything fractional or out of
    range would need a requantization step this importer does not
    perform, so it is rejected by name instead of silently rounded."""
    dtype = np.dtype(dtype)
    if np.issubdtype(dtype, np.floating):
        return np.ascontiguousarray(arr.astype(dtype))
    r = np.rint(arr)
    info = np.iinfo(dtype)
    if (not np.array_equal(r, arr) or arr.min() < info.min
            or arr.max() > info.max):
        _fail(f"BatchNormalization {node.name!r}: folded {what} is not "
              f"exactly representable in the Conv's {dtype.name} "
              "parameters — integer (PTQ) batch-norm folding needs "
              "requantization, which is out of scope")
    return np.ascontiguousarray(r.astype(dtype))


def _fold_batchnorm(og: OnnxGraph) -> None:
    """Fold every inference-mode BatchNormalization into the Conv that
    feeds it, in place:  with ``s = scale / sqrt(var + eps)``,

        W'[o, :, :, :] = W[o, :, :, :] * s[o]
        b'             = (b - mean) * s + B

    so ``BN(conv(x, W) + b) == conv(x, W') + b'`` exactly.  The BN node
    disappears and the Conv keeps (or gains) a bias input.  A BN that
    cannot fold — not fed by a Conv, Conv output shared or a graph
    output, training-mode outputs, non-initializer statistics — raises
    :class:`OnnxImportError` naming the obstacle.
    """
    consumers: dict[str, int] = {}
    for n in og.nodes:
        for i in n.inputs:
            consumers[i] = consumers.get(i, 0) + 1
    conv_of = {n.outputs[0]: n for n in og.nodes
               if n.op_type == "Conv" and n.outputs}
    kept: list[OnnxNode] = []
    fresh = 0
    for node in og.nodes:
        if node.op_type != "BatchNormalization":
            kept.append(node)
            continue
        if len(node.outputs) != 1:
            _fail(f"BatchNormalization {node.name!r}: training-mode "
                  f"outputs {node.outputs[1:]} are unsupported")
        if node.attrs.get("training_mode", 0):
            _fail(f"BatchNormalization {node.name!r}: training_mode=1 "
                  "is unsupported")
        if node.attrs.get("spatial", 1) != 1:
            _fail(f"BatchNormalization {node.name!r}: spatial=0 (per-"
                  "element statistics) is unsupported")
        if len(node.inputs) != 5:
            _fail(f"BatchNormalization {node.name!r}: expected X, scale, "
                  "B, mean, var")
        conv = conv_of.get(node.inputs[0])
        if conv is None:
            _fail(f"BatchNormalization {node.name!r}: only folds into an "
                  f"immediately preceding Conv, but {node.inputs[0]!r} is "
                  "not a Conv output")
        if consumers.get(conv.outputs[0], 0) != 1 \
                or conv.outputs[0] in og.outputs:
            _fail(f"BatchNormalization {node.name!r}: Conv output "
                  f"{conv.outputs[0]!r} has other consumers or is a graph "
                  "output — cannot fold")
        stats = []
        for vn in node.inputs[1:]:
            arr = og.initializers.get(vn)
            if arr is None:
                _fail(f"BatchNormalization {node.name!r}: {vn!r} must be "
                      "an initializer")
            stats.append(np.asarray(arr, dtype=np.float64).reshape(-1))
        scale, shift, mean, var = stats
        w = og.initializers.get(conv.inputs[1])
        if w is None or w.ndim != 4:
            _fail(f"BatchNormalization {node.name!r}: Conv weight "
                  f"{conv.inputs[1]!r} must be a rank-4 initializer")
        cout = int(w.shape[0])
        if any(p.shape[0] != cout for p in stats):
            _fail(f"BatchNormalization {node.name!r}: statistics arity "
                  f"{[p.shape[0] for p in stats]} != Conv channels {cout}")
        eps = float(node.attrs.get("epsilon", 1e-5))
        s = scale / np.sqrt(var + eps)
        w_f = np.asarray(w, dtype=np.float64) * s[:, None, None, None]
        if len(conv.inputs) == 3:
            b_arr = og.initializers.get(conv.inputs[2])
            if b_arr is None:
                _fail(f"BatchNormalization {node.name!r}: Conv bias "
                      f"{conv.inputs[2]!r} must be an initializer")
            b0 = np.asarray(b_arr, dtype=np.float64).reshape(-1)
        else:
            b0 = np.zeros(cout, dtype=np.float64)
        b_f = (b0 - mean) * s + shift
        bias_dtype = (np.dtype(np.int32)
                      if np.issubdtype(w.dtype, np.integer) else w.dtype)
        fresh += 1
        wn = f"{conv.inputs[1]}.bnfold{fresh}"
        bn = f"{node.inputs[2]}.bnfold{fresh}"
        og.initializers[wn] = _bn_cast_back(w_f, w.dtype, node, "weight")
        og.initializers[bn] = _bn_cast_back(b_f, bias_dtype, node, "bias")
        conv.inputs = [conv.inputs[0], wn, bn]
        conv.outputs = [node.outputs[0]]
    og.nodes = kept


def _to_builder(og: OnnxGraph, model_name: str) -> ImportedModel:
    from repro.api.builder import FrontendError, Graph, TensorRef

    g = Graph(model_name)
    names = _Names()
    refs: dict[str, TensorRef] = {}
    params: dict[str, np.ndarray] = {}

    def ref(node: OnnxNode, vname: str) -> TensorRef:
        if vname not in refs:
            _fail(f"{node.op_type} {node.name!r}: input {vname!r} is "
                  "neither a graph input, an initializer-backed constant, "
                  "nor an earlier node's output")
        return refs[vname]

    def bind_const(onnx_name: str, arr: np.ndarray) -> TensorRef:
        nm = names(onnx_name, "k")
        c = g.constant(arr.shape, name=nm)
        params[nm] = np.ascontiguousarray(arr)
        return c

    def weight_name(onnx_name: str) -> str:
        return names(onnx_name, "w")

    def handle_conv(node: OnnxNode) -> None:
        if len(node.inputs) not in (2, 3):
            _fail(f"Conv {node.name!r}: expected X, W[, B]")
        xn, wn = node.inputs[:2]
        w = og.initializers.get(wn)
        if w is None:
            _fail(f"Conv {node.name!r}: weight {wn!r} must be an "
                  "initializer")
        if w.ndim != 4:
            _fail(f"Conv {node.name!r}: weight rank {w.ndim} != 4")
        if node.attrs.get("group", 1) != 1:
            _fail(f"Conv {node.name!r}: grouped convs are unsupported "
                  f"(group={node.attrs['group']})")
        dil = node.attrs.get("dilations")
        if dil and any(d != 1 for d in dil):
            _fail(f"Conv {node.name!r}: dilations {dil} are unsupported")
        kernel = _square(node, list(w.shape[2:]), "kernel")
        ks = node.attrs.get("kernel_shape")
        if ks and list(ks) != [kernel, kernel]:
            _fail(f"Conv {node.name!r}: kernel_shape {ks} != weight "
                  f"kernel {kernel}")
        stride = _uniform_stride(node)
        x = ref(node, xn)
        if x.rank != 4:
            _fail(f"Conv {node.name!r}: input rank {x.rank} != 4 (NCHW)")
        padding = _resolve_conv_padding(node, kernel, stride,
                                        int(x.shape[2]), int(x.shape[3]))
        h = g.transpose(x, NCHW2NHWC)
        wname = weight_name(wn)
        h = g.conv2d(h, int(w.shape[0]), kernel=kernel, stride=stride,
                     padding=padding, weight=wname)
        params[wname] = np.ascontiguousarray(np.transpose(w, (2, 3, 1, 0)))
        if len(node.inputs) == 3:
            b = og.initializers.get(node.inputs[2])
            if b is None:
                _fail(f"Conv {node.name!r}: bias {node.inputs[2]!r} must "
                      "be an initializer")
            if b.size != int(w.shape[0]):
                _fail(f"Conv {node.name!r}: bias has {b.size} elements, "
                      f"expected {int(w.shape[0])}")
            # rank-1 (C,) constant: the builder routes this through the
            # broadcast add, so it fuses as a C-element epilogue operand
            # instead of a materialized H*W*C tensor
            h = g.add(h, bind_const(node.inputs[2], b.reshape(-1)))
        refs[node.outputs[0]] = g.transpose(h, NHWC2NCHW)

    def handle_pool(node: OnnxNode) -> None:
        ks = node.attrs.get("kernel_shape")
        if not ks:
            _fail(f"{node.op_type} {node.name!r}: missing required "
                  "attribute 'kernel_shape'")
        window = _square(node, list(ks), "kernel_shape")
        stride = _uniform_stride(node, default=1)
        _check_no_padding(node)
        if node.attrs.get("ceil_mode", 0):
            _fail(f"{node.op_type} {node.name!r}: ceil_mode pooling is "
                  "unsupported")
        x = ref(node, node.inputs[0])
        if x.rank != 4:
            _fail(f"{node.op_type} {node.name!r}: input rank {x.rank} != 4")
        h = g.transpose(x, NCHW2NHWC)
        pool = g.max_pool if node.op_type == "MaxPool" else g.avg_pool
        h = pool(h, window, stride)
        refs[node.outputs[0]] = g.transpose(h, NHWC2NCHW)

    def handle_gemm(node: OnnxNode) -> None:
        if len(node.inputs) not in (2, 3):
            _fail(f"Gemm {node.name!r}: expected A, B[, C]")
        alpha = node.attrs.get("alpha", 1.0)
        beta = node.attrs.get("beta", 1.0)
        if abs(float(alpha) - 1.0) > 1e-6 or node.attrs.get("transA", 0):
            _fail(f"Gemm {node.name!r}: alpha={alpha} transA="
                  f"{node.attrs.get('transA', 0)} — only alpha=1, "
                  "transA=0 are supported")
        b = og.initializers.get(node.inputs[1])
        if b is None or b.ndim != 2:
            _fail(f"Gemm {node.name!r}: B must be a rank-2 initializer")
        w = b.T if node.attrs.get("transB", 0) else b
        x = ref(node, node.inputs[0])
        if x.rank != 2:
            _fail(f"Gemm {node.name!r}: input rank {x.rank} != 2 — "
                  "Flatten before the classifier head")
        wname = weight_name(node.inputs[1])
        h = g.dense(x, int(w.shape[1]), weight=wname)
        params[wname] = np.ascontiguousarray(w)
        if len(node.inputs) == 3 and abs(float(beta)) > 1e-6:
            if abs(float(beta) - 1.0) > 1e-6:
                _fail(f"Gemm {node.name!r}: beta={beta} — only 0 or 1")
            c = og.initializers.get(node.inputs[2])
            if c is None:
                _fail(f"Gemm {node.name!r}: C must be an initializer")
            if c.size != int(w.shape[1]):
                _fail(f"Gemm {node.name!r}: C has {c.size} elements — "
                      f"only a per-unit bias of {int(w.shape[1])} is "
                      "supported")
            h = g.add(h, bind_const(node.inputs[2], c.reshape(-1)))
        refs[node.outputs[0]] = h

    def handle_add(node: OnnxNode) -> None:
        a, b = node.inputs
        if a in og.initializers and b in og.initializers:
            _fail(f"Add {node.name!r}: constant-folding two initializers "
                  "is out of scope")
        if b in og.initializers or a in og.initializers:
            act, kn = (a, b) if b in og.initializers else (b, a)
            x = ref(node, act)
            arr = np.broadcast_to(og.initializers[kn], x.shape)
            refs[node.outputs[0]] = g.add(x, bind_const(kn, arr))
            return
        refs[node.outputs[0]] = g.add(ref(node, a), ref(node, b))

    def handle_global_pool(node: OnnxNode) -> None:
        x = ref(node, node.inputs[0])
        if x.rank != 4:
            _fail(f"GlobalAveragePool {node.name!r}: input rank "
                  f"{x.rank} != 4")
        hh, ww = int(x.shape[2]), int(x.shape[3])
        if hh != ww:
            _fail(f"GlobalAveragePool {node.name!r}: non-square map "
                  f"{hh}x{ww} — the square AVG window cannot cover it")
        h = g.transpose(x, NCHW2NHWC)
        h = g.avg_pool(h, hh, hh)
        refs[node.outputs[0]] = g.transpose(h, NHWC2NCHW)

    def handle_flatten(node: OnnxNode) -> None:
        if node.attrs.get("axis", 1) != 1:
            _fail(f"Flatten {node.name!r}: only axis=1 is supported "
                  f"(axis={node.attrs.get('axis')})")
        x = ref(node, node.inputs[0])
        if x.rank == 2:
            refs[node.outputs[0]] = x  # already flat — a pure alias
            return
        refs[node.outputs[0]] = g.flatten(x)

    handlers = {
        "Conv": handle_conv,
        "Relu": lambda n: refs.__setitem__(
            n.outputs[0], g.relu(ref(n, n.inputs[0]))
        ),
        "MaxPool": handle_pool,
        "AveragePool": handle_pool,
        "GlobalAveragePool": handle_global_pool,
        "Gemm": handle_gemm,
        "Add": handle_add,
        "Flatten": handle_flatten,
    }

    try:
        for vname, shape in og.inputs:
            if not shape or any(int(s) <= 0 for s in shape):
                _fail(f"graph input {vname!r}: non-static shape {shape}")
            refs[vname] = g.input(shape, name=names(vname, "x"))
        for node in og.nodes:
            handler = handlers.get(node.op_type)
            if handler is None:
                _fail(
                    f"unsupported op {node.op_type!r} (node {node.name!r}) "
                    f"— this importer speaks {SUPPORTED_OPS}"
                )
            handler(node)
        if not og.outputs:
            _fail("model has no graph outputs")
        for o in og.outputs:
            if o not in refs:
                _fail(f"graph output {o!r} is not produced by any node")
            g.output(refs[o])
        dfg = g.build()
    except FrontendError as e:
        raise OnnxImportError(f"{model_name}: {e}") from e
    except ValueError as e:
        if isinstance(e, OnnxImportError):
            raise
        raise OnnxImportError(f"{model_name}: {e}") from e
    return ImportedModel(model_name, dfg, params, source="onnx")


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------


def have_onnx_package() -> bool:
    try:  # pragma: no cover - depends on the environment
        import onnx  # noqa: F401

        return True
    except ImportError:
        return False


def load_onnx(source, *, name: str | None = None) -> ImportedModel:
    """Import an ONNX model — a path to a ``.onnx`` file or raw model
    bytes — into an :class:`ImportedModel`."""
    if isinstance(source, (bytes, bytearray)):
        data = bytes(source)
        default_name = "onnx_model"
    else:
        with open(source, "rb") as f:
            data = f.read()
        default_name = os.path.splitext(os.path.basename(source))[0]
    try:
        og = (
            _decode_with_onnx_pkg(data) if have_onnx_package()
            else decode_wire(data)
        )
    except OnnxImportError as e:
        # decode runs before the graph name exists — name the error
        # after the file (or the caller-supplied name) so a truncated /
        # corrupt protobuf points at its source
        raise OnnxImportError(f"{name or default_name}: {e}") from e
    model_name = name or re.sub(r"[^0-9A-Za-z_]", "_",
                                og.name if og.name != "onnx_model"
                                else default_name) or "onnx_model"
    try:
        _fold_batchnorm(og)
    except OnnxImportError as e:
        raise OnnxImportError(f"{model_name}: {e}") from e
    return _to_builder(og, model_name)
