"""Portable JSON "model cards": a self-contained graph interchange format.

A model card is a JSON document that round-trips any *pre-pass* builder
graph — inputs, a flat layer list (conv2d / pools / dense / relu /
activation / add / transpose / flatten / bare constants), outputs, and
optionally the weights (base64 raw bytes) — with **node-for-node
fidelity**: ``import_card(export_card(g))`` rebuilds a DFG that compares
dataclass-equal to ``g`` (``tests/test_modelcard.py`` pins this as a
property over random builder graphs).

The guarantee is enforced, not hoped for: :func:`export_card` re-imports
its own output in memory and diffs the reconstruction against the
source graph before returning, so a graph the schema cannot express
fails loudly at export time (fused epilogues, exotic maps) instead of
producing a lossy card.

Cards are the zoo's storage format (``repro.frontends.zoo``), the CLI's
``python -m repro compile model.json`` input, and the stable on-disk
form for shipping models between machines without pickling IR
internals.
"""
from __future__ import annotations

import base64
import json
import os
from typing import Mapping, Optional

import numpy as np

from repro.core.analysis import KernelClass, classify_kernel, reorder_spec
from repro.core.ir import DFG, GenericOp, PayloadKind

from .base import ImportedModel

FORMAT = "ming-modelcard"
SCHEMA_VERSION = 1

#: ops a v1 card can express (the error message vocabulary)
CARD_OPS = (
    "conv2d", "max_pool", "avg_pool", "dense", "relu", "activation",
    "add", "transpose", "flatten", "constant",
)


class ModelCardError(ValueError):
    """The card is malformed, or the graph is not expressible as one."""


def _fail(msg: str) -> None:
    raise ModelCardError(msg)


def _require(cond: bool, msg: str) -> None:
    if not cond:
        _fail(msg)


# ---------------------------------------------------------------------------
# Export: DFG -> card dict
# ---------------------------------------------------------------------------


def _node_record(dfg: DFG, op: GenericOp) -> dict:
    """One layer record for ``op`` — or a loud error naming what the
    schema cannot express."""
    if op.epilogue:
        _fail(
            f"{dfg.name}/{op.name}: fused epilogues are not expressible in "
            "a model card — export the pre-pass graph"
        )
    spec = reorder_spec(op)
    if spec is not None:
        kind, arg = spec
        if kind == "transpose":
            return {"op": "transpose", "name": op.name,
                    "input": op.inputs[0], "perm": list(arg),
                    "out": op.output}
        return {"op": "flatten", "name": op.name, "input": op.inputs[0],
                "order": list(arg), "out": op.output}
    info = classify_kernel(op)
    if info.kernel_class == KernelClass.SLIDING_WINDOW:
        if op.payload == PayloadKind.MAC and op.n_dims == 7:
            _require(info.dilation == 1,
                     f"{op.name}: dilated convs are not expressible (v1)")
            stream = [i for i in op.inputs if not dfg.values[i].is_constant]
            const = [i for i in op.inputs if dfg.values[i].is_constant]
            _require(len(stream) == 1 and len(const) == 1,
                     f"{op.name}: conv needs 1 stream + 1 const input")
            kh, kw = op.dim_sizes[4], op.dim_sizes[5]
            _require(kh == kw, f"{op.name}: non-square kernel {kh}x{kw}")
            rec = {"op": "conv2d", "name": op.name, "input": stream[0],
                   "filters": op.dim_sizes[3], "kernel": kh,
                   "stride": info.stride, "weight": const[0],
                   "out": op.output}
            # VALID convs: the output extent is the tell (SAME is always
            # ceil(h/s)); the key is omitted for SAME so older cards
            # stay byte-identical
            h_in = dfg.values[stream[0]].shape[1]
            if op.dim_sizes[1] != -(-h_in // info.stride):
                rec["padding"] = "VALID"
            return rec
        if op.payload in (PayloadKind.MAX, PayloadKind.AVG) and op.n_dims == 6:
            kh, kw = op.dim_sizes[4], op.dim_sizes[5]
            _require(kh == kw, f"{op.name}: non-square pool {kh}x{kw}")
            name = "max_pool" if op.payload == PayloadKind.MAX else "avg_pool"
            return {"op": name, "name": op.name, "input": op.inputs[0],
                    "window": kh, "stride": info.stride, "out": op.output}
        _fail(f"{op.name}: unsupported sliding-window shape")
    if info.kernel_class == KernelClass.REGULAR_REDUCTION:
        _require(
            op.payload == PayloadKind.MAC and op.n_dims == 3
            and len(op.inputs) == 2
            and dfg.values[op.inputs[1]].is_constant,
            f"{op.name}: only dense (matmul with constant rhs) reductions "
            "are expressible",
        )
        return {"op": "dense", "name": op.name, "input": op.inputs[0],
                "units": op.dim_sizes[1], "weight": op.inputs[1],
                "out": op.output}
    # PURE_PARALLEL with identity maps — or the per-channel broadcast
    # bias add (ident, last-dim, ident), whose rank-1 constant operand
    # re-derives the broadcast on import (builder ``add``)
    if not all(m.is_identity() for m in op.indexing_maps):
        is_bias = (
            len(op.inputs) == 2
            and op.payload == PayloadKind.ADD
            and op.indexing_maps[0].is_identity()
            and op.indexing_maps[2].is_identity()
            and len(op.indexing_maps[1].results) == 1
            and op.indexing_maps[1].results[0].is_single_dim()
            and op.indexing_maps[1].results[0].terms[0] == (op.n_dims - 1, 1)
            and dfg.values[op.inputs[1]].is_constant
        )
        _require(is_bias, f"{op.name}: non-identity elementwise maps")
        return {"op": "add", "name": op.name, "a": op.inputs[0],
                "b": op.inputs[1], "out": op.output}
    if len(op.inputs) == 1:
        if op.payload == PayloadKind.RELU:
            return {"op": "relu", "name": op.name, "input": op.inputs[0],
                    "out": op.output}
        _require(op.payload != PayloadKind.IDENTITY,
                 f"{op.name}: bare identity wires are not expressible — "
                 "canonicalize first")
        return {"op": "activation", "kind": op.payload.value,
                "name": op.name, "input": op.inputs[0], "out": op.output}
    if len(op.inputs) == 2 and op.payload == PayloadKind.ADD:
        return {"op": "add", "name": op.name, "a": op.inputs[0],
                "b": op.inputs[1], "out": op.output}
    _fail(f"{op.name}: {len(op.inputs)}-ary {op.payload.value} is not "
          "expressible in a model card")


def export_card(
    graph,
    *,
    params: Optional[Mapping[str, np.ndarray]] = None,
) -> dict:
    """Serialize a builder graph (DFG, or anything with ``.build()``)
    into a card dict.  ``params`` optionally embeds weights (base64) for
    the graph's constant values.

    The export is *verified*: the card is re-imported in memory and the
    reconstruction compared node-for-node against the source before the
    dict is returned.
    """
    dfg = graph.build() if hasattr(graph, "build") else graph
    if not isinstance(dfg, DFG):
        raise TypeError(
            f"export_card needs a DFG or a builder with .build(), got "
            f"{type(graph).__name__}"
        )
    layers: list[dict] = []
    # constants created implicitly by conv/dense records
    created = set()
    for op in dfg.nodes:
        rec = _node_record(dfg, op)
        if rec["op"] in ("conv2d", "dense"):
            created.add(rec["weight"])
        # any other constant operand needs an explicit record first
        for v in op.inputs:
            if dfg.values[v].is_constant and v not in created:
                cv = dfg.values[v]
                layers.append({"op": "constant", "name": v,
                               "shape": list(cv.shape),
                               "elem_bits": cv.elem_bits})
                created.add(v)
        layers.append(rec)
    card = {
        "format": FORMAT,
        "version": SCHEMA_VERSION,
        "name": dfg.name,
        "inputs": [
            {"name": n, "shape": list(dfg.values[n].shape),
             "elem_bits": dfg.values[n].elem_bits}
            for n in dfg.graph_inputs
        ],
        "layers": layers,
        "outputs": list(dfg.graph_outputs),
    }
    if params:
        consts = {n for n, v in dfg.values.items() if v.is_constant}
        blob = {}
        for name, arr in params.items():
            _require(name in consts,
                     f"params[{name!r}] is not a constant of {dfg.name} "
                     f"(constants: {sorted(consts)})")
            a = np.asarray(arr)
            _require(tuple(a.shape) == dfg.values[name].shape,
                     f"params[{name!r}] shape {tuple(a.shape)} != value "
                     f"shape {dfg.values[name].shape}")
            blob[name] = {
                "dtype": str(a.dtype),
                "shape": list(a.shape),
                "data": base64.b64encode(np.ascontiguousarray(a).tobytes())
                        .decode("ascii"),
            }
        card["params"] = blob
    # the fidelity gate: what we wrote must rebuild the graph exactly
    rebuilt = _build_dfg(card)
    if rebuilt != dfg:
        _fail(
            f"{dfg.name}: card round-trip diverged from the source graph — "
            "the graph uses structure the v1 schema cannot express"
        )
    return card


# ---------------------------------------------------------------------------
# Import: card dict (or path) -> ImportedModel
# ---------------------------------------------------------------------------


def _validated(card: dict) -> dict:
    _require(isinstance(card, dict), "card must be a JSON object")
    _require(card.get("format") == FORMAT,
             f"not a {FORMAT} document (format={card.get('format')!r})")
    _require(card.get("version") == SCHEMA_VERSION,
             f"unsupported card version {card.get('version')!r} "
             f"(this reader speaks v{SCHEMA_VERSION})")
    _require(isinstance(card.get("name"), str) and card["name"],
             "card needs a non-empty string 'name'")
    _require(isinstance(card.get("inputs"), list) and card["inputs"],
             "card needs a non-empty 'inputs' list")
    _require(isinstance(card.get("layers"), list) and card["layers"],
             "card needs a non-empty 'layers' list")
    _require(isinstance(card.get("outputs"), list) and card["outputs"],
             "card needs a non-empty 'outputs' list")
    for i, rec in enumerate(card["layers"]):
        _require(isinstance(rec, dict) and "op" in rec,
                 f"layers[{i}] is not an op record")
        _require(rec["op"] in CARD_OPS,
                 f"layers[{i}]: unknown op {rec['op']!r} — "
                 f"one of {CARD_OPS}")
    return card


def _build_dfg(card: dict) -> DFG:
    from repro.api.builder import FrontendError, Graph

    refs: dict[str, object] = {}

    def ref(rec: dict, key: str):
        name = rec.get(key)
        _require(isinstance(name, str) and name in refs,
                 f"{rec.get('name', rec['op'])}: {key}={name!r} does not "
                 "name an earlier value of the card")
        return refs[name]

    g = Graph(card["name"])
    try:
        for inp in card["inputs"]:
            refs[inp["name"]] = g.input(
                inp["shape"], name=inp["name"],
                elem_bits=inp.get("elem_bits", 8),
            )
        for rec in card["layers"]:
            op = rec["op"]
            if op == "constant":
                refs[rec["name"]] = g.constant(
                    rec["shape"], name=rec["name"],
                    elem_bits=rec.get("elem_bits", 8),
                )
            elif op == "conv2d":
                refs[rec["out"]] = g.conv2d(
                    ref(rec, "input"), rec["filters"],
                    kernel=rec.get("kernel", 3), stride=rec.get("stride", 1),
                    padding=rec.get("padding", "SAME"),
                    name=rec["name"], weight=rec["weight"], out=rec["out"],
                )
            elif op in ("max_pool", "avg_pool"):
                method = g.max_pool if op == "max_pool" else g.avg_pool
                refs[rec["out"]] = method(
                    ref(rec, "input"), rec.get("window", 2),
                    rec.get("stride"), name=rec["name"], out=rec["out"],
                )
            elif op == "dense":
                refs[rec["out"]] = g.dense(
                    ref(rec, "input"), rec["units"], name=rec["name"],
                    weight=rec["weight"], out=rec["out"],
                )
            elif op == "relu":
                refs[rec["out"]] = g.relu(
                    ref(rec, "input"), name=rec["name"], out=rec["out"],
                )
            elif op == "activation":
                try:
                    kind = PayloadKind(rec.get("kind"))
                except ValueError:
                    _fail(f"{rec['name']}: unknown activation kind "
                          f"{rec.get('kind')!r}")
                refs[rec["out"]] = g.activation(
                    ref(rec, "input"), kind, kind.value,
                    name=rec["name"], out=rec["out"],
                )
            elif op == "add":
                refs[rec["out"]] = g.add(
                    ref(rec, "a"), ref(rec, "b"),
                    name=rec["name"], out=rec["out"],
                )
            elif op == "transpose":
                refs[rec["out"]] = g.transpose(
                    ref(rec, "input"), rec["perm"],
                    name=rec["name"], out=rec["out"],
                )
            elif op == "flatten":
                refs[rec["out"]] = g.flatten(
                    ref(rec, "input"), order=rec.get("order"),
                    name=rec["name"], out=rec["out"],
                )
        for out in card["outputs"]:
            _require(out in refs,
                     f"output {out!r} does not name a value of the card")
            g.output(refs[out])
        return g.build()
    except FrontendError as e:
        raise ModelCardError(f"{card['name']}: {e}") from e
    except KeyError as e:
        raise ModelCardError(
            f"{card['name']}: layer record missing field {e}"
        ) from e


_DTYPES = {"int8", "uint8", "int16", "int32", "int64", "float32", "float64"}


def _decode_params(card: dict, dfg: DFG) -> dict[str, np.ndarray]:
    out: dict[str, np.ndarray] = {}
    for name, blob in (card.get("params") or {}).items():
        _require(name in dfg.values and dfg.values[name].is_constant,
                 f"params[{name!r}] is not a constant of the card's graph")
        _require(isinstance(blob, dict) and {"dtype", "shape", "data"}
                 <= set(blob), f"params[{name!r}]: need dtype/shape/data")
        _require(blob["dtype"] in _DTYPES,
                 f"params[{name!r}]: unsupported dtype {blob['dtype']!r}")
        raw = base64.b64decode(blob["data"])
        arr = np.frombuffer(raw, dtype=np.dtype(blob["dtype"]))
        shape = tuple(int(s) for s in blob["shape"])
        _require(arr.size == int(np.prod(shape)) if shape else arr.size == 1,
                 f"params[{name!r}]: data length does not match shape "
                 f"{shape}")
        _require(shape == dfg.values[name].shape,
                 f"params[{name!r}]: shape {shape} != value shape "
                 f"{dfg.values[name].shape}")
        out[name] = arr.reshape(shape)
    return out


def import_card(card) -> ImportedModel:
    """Load a model card — a dict, a JSON string, or a path to a
    ``.json`` file — into an :class:`ImportedModel`."""
    if isinstance(card, (str, os.PathLike)):
        looks_inline = isinstance(card, str) and card.lstrip().startswith("{")
        if looks_inline and not os.path.exists(card):
            text = card  # a JSON document passed inline
            source = "inline card"
        else:
            # a path — let open() raise the natural FileNotFoundError
            # for typos instead of mis-reporting them as invalid JSON
            with open(card) as f:
                text = f.read()
            source = os.fspath(card)
        try:
            card = json.loads(text)
        except json.JSONDecodeError as e:
            _fail(f"{source}: not valid JSON: {e}")
    card = _validated(card)
    dfg = _build_dfg(card)
    params = _decode_params(card, dfg)
    return ImportedModel(card["name"], dfg, params, source="card")
