"""Model importers: external descriptions → builder graphs (ISSUE 5).

Three pieces:

* the **ONNX reader** (:mod:`repro.frontends.onnx_reader`) — trained
  NCHW models onto the NHWC builder, transposes canonicalized away by
  ``repro.passes.layout``, weights threaded into
  ``CompiledArtifact.run``.  Uses the ``onnx`` package when installed,
  a vendored protobuf-wire decoder otherwise;
* the **model-card format** (:mod:`repro.frontends.modelcard`) — a
  self-contained JSON interchange that round-trips any builder graph
  node-for-node (``export_card`` / ``import_card``), optionally with
  embedded weights;
* the **zoo** (:mod:`repro.frontends.zoo`) — LeNet-5, a tiny-VGG
  cascade, and a residual edge model, registered in the benchmark
  suite with per-target BENCH rows.

One dispatching entry point::

    from repro.frontends import import_model
    model = import_model("lenet5.onnx")        # or a .json model card
    art = repro.compile_graph(model.dfg)
    y = art.run(x, params=model.params)

— which is exactly what ``python -m repro compile <file>`` does.
"""
from __future__ import annotations

import os

from .base import ImportedModel
from .modelcard import ModelCardError, export_card, import_card
from .onnx_reader import OnnxImportError, load_onnx
from .zoo import ZOO


def import_model(path: str) -> ImportedModel:
    """Import a model file by extension: ``.onnx`` → the ONNX reader,
    ``.json`` → the model-card loader."""
    ext = os.path.splitext(str(path))[1].lower()
    if ext == ".onnx":
        return load_onnx(path)
    if ext == ".json":
        return import_card(path)
    raise ValueError(
        f"cannot import {path!r}: unknown model extension {ext!r} "
        "(.onnx and .json model cards are supported)"
    )


__all__ = [
    "ImportedModel",
    "ModelCardError",
    "OnnxImportError",
    "ZOO",
    "export_card",
    "import_card",
    "import_model",
    "load_onnx",
]
