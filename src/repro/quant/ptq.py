"""Weight-only int8 post-training quantization (the paper's regime).

The paper evaluates int8-PTQ CNN kernels (Sec. V-A); this module applies
the same regime to the LM serving path: every ≥2-D weight matrix is
stored as int8 with a per-output-channel f32 scale (absmax), halving the
weight bytes HBM must stream at decode — the term that dominates the
decode_* roofline cells.  Activations stay bf16; matmuls dequantize on
use (XLA fuses convert·scale into the consumer on TPU, so HBM sees int8).

Norms / biases / scalar leaves stay in their original dtype (quantizing
them saves nothing and hurts accuracy).

Usage::

    qparams = quantize_params(params)                  # pytree of QTensor
    params_hat = dequantize_params(qparams)            # lazy, inside jit
    logits, cache = lm.lm_decode(params_hat, cfg, ...)

``ServeEngine(..., int8_weights=True)`` wires this in.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class QTensor(NamedTuple):
    """int8 weight + per-output-channel scale (last axis = out channels)."""

    q: jax.Array          # int8, same shape as the original
    scale: jax.Array      # f32, shape = (..., 1, out) broadcastable

    @property
    def shape(self):
        return self.q.shape

    @property
    def dtype(self):
        return jnp.int8


jax.tree_util.register_pytree_node(
    QTensor,
    lambda t: ((t.q, t.scale), None),
    lambda _, xs: QTensor(*xs),
)


def _quantize_leaf(x: jax.Array) -> QTensor | jax.Array:
    # quantize matrices only; keep vectors/scalars (norms, biases) exact
    if x.ndim < 2 or not jnp.issubdtype(x.dtype, jnp.floating):
        return x
    xf = x.astype(jnp.float32)
    # per-output-channel absmax over the contraction axis (-2)
    amax = jnp.max(jnp.abs(xf), axis=-2, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return QTensor(q, scale)


def _dequantize_leaf(x, dtype):
    if isinstance(x, QTensor):
        return (x.q.astype(jnp.float32) * x.scale).astype(dtype)
    return x


def quantize_params(params: Any) -> Any:
    """Pytree map: every ≥2-D float leaf becomes a QTensor."""
    return jax.tree.map(_quantize_leaf, params)


def dequantize_params(qparams: Any, dtype=jnp.bfloat16) -> Any:
    """Inverse map — call *inside* jit so XLA streams int8 from HBM and
    dequantizes in VMEM (weight bytes halve; the convert fuses)."""
    return jax.tree.map(
        lambda x: _dequantize_leaf(x, dtype),
        qparams,
        is_leaf=lambda x: isinstance(x, QTensor),
    )


def quantized_param_shardings(p_shard: Any, params_shape: Any) -> Any:
    """Shardings for the quantized pytree: ``q`` inherits the weight's
    sharding; the (…, 1, out) ``scale`` drops the contraction axis."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    def one(sh, leaf):
        if not (hasattr(leaf, "ndim") and leaf.ndim >= 2
                and jnp.issubdtype(leaf.dtype, jnp.floating)):
            return sh
        spec = list(sh.spec) + [None] * (leaf.ndim - len(sh.spec))
        scale_spec = list(spec)
        scale_spec[-2] = None
        return QTensor(
            sh, NamedSharding(sh.mesh, P(*scale_spec))
        )

    return jax.tree.map(one, p_shard, params_shape)


def quantization_error(params: Any, qparams: Any) -> dict:
    """Max relative weight error per quantized leaf (diagnostics)."""
    out = {}
    flat_p = jax.tree_util.tree_flatten_with_path(params)[0]
    deq = dequantize_params(qparams, jnp.float32)
    flat_d = jax.tree.leaves(deq)
    for (kp, p), d in zip(flat_p, flat_d):
        if p.ndim >= 2:
            pf = p.astype(jnp.float32)
            denom = jnp.maximum(jnp.max(jnp.abs(pf)), 1e-12)
            out[jax.tree_util.keystr(kp)] = float(
                jnp.max(jnp.abs(pf - d)) / denom
            )
    return out
