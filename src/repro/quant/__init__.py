from .ptq import dequantize_params, quantize_params  # noqa: F401
