"""Compile-time dataflow analyzer (ISSUE 9).

A rule-registry diagnostics engine over the three IR levels the
compiler already produces — the DFG, the :class:`StreamingPlan`, and
the :class:`CompiledDesign` schedule — with four analysis families:

* **stream skew / deadlock** (``SK*``, :mod:`~repro.analyze.stream_skew`)
  — reconvergent-branch FIFO depths vs the row-rate skew derived from
  the line-buffer geometry;
* **integer ranges** (``R*``, :mod:`~repro.analyze.ranges`) — interval
  propagation inferring the minimum accumulator width per
  conv/epilogue reduction (the post-PR 7 int8 wrap, statically);
* **schedule hazards** (``SH*``, :mod:`~repro.analyze.hazards`) —
  per-group budget over-commit and spill/fill read-before-write across
  overlapped DMA transitions;
* **model hygiene** (``H*``, :mod:`~repro.analyze.hygiene`) — unused
  params, dtype-inconsistent epilogue operands, dead outputs,
  narrowing streams.

Entry points: :func:`analyze_dfg` / :func:`analyze_plan` /
:func:`analyze_design`; threaded into ``compile_design`` via
``CompileOptions(lint="warn"|"error"|"off")`` and exposed as
``python -m repro lint``.  Rule catalog + JSON schema: DESIGN.md §8.
"""
from .diagnostics import (
    Diagnostic,
    LintError,
    Severity,
    at_or_above,
    diagnostics_to_json,
    max_severity,
    severity_counts,
)
from .engine import RULES, Rule, analyze_design, analyze_dfg, analyze_plan
from .hazards import analyze_schedule
from .hygiene import analyze_hygiene
from .ranges import (
    ACC_INPUT_DTYPE,
    DEFAULT_ACC_BITS,
    Interval,
    analyze_ranges,
    dtype_interval,
    overflow_safe,
    value_intervals,
)
from .stream_skew import analyze_stream_skew

__all__ = [
    "ACC_INPUT_DTYPE",
    "DEFAULT_ACC_BITS",
    "Diagnostic",
    "Interval",
    "LintError",
    "RULES",
    "Rule",
    "Severity",
    "analyze_design",
    "analyze_dfg",
    "analyze_hygiene",
    "analyze_plan",
    "analyze_ranges",
    "analyze_schedule",
    "analyze_stream_skew",
    "at_or_above",
    "diagnostics_to_json",
    "dtype_interval",
    "max_severity",
    "overflow_safe",
    "severity_counts",
    "value_intervals",
]
