"""Structured diagnostics: the data model of the static analyzer.

Every analysis family (``ranges``, ``stream_skew``, ``hazards``,
``hygiene``) reports :class:`Diagnostic` records — severity, a stable
rule id from the :data:`~repro.analyze.engine.RULES` catalog, the
graph/node/group location, a human message, and a fix hint — never
free-form strings.  The records are what every consumer shares:

* ``compile_design`` stores them on ``CompiledDesign.diagnostics`` and
  (under ``CompileOptions(lint="error")``) raises :class:`LintError`
  when any ERROR-severity record survives;
* ``Report`` telemetry and the ``python -m repro lint`` CLI format
  them (:meth:`Diagnostic.format`);
* CI serializes them (:func:`diagnostics_to_json`) as the
  ``lint_diagnostics.json`` workflow artifact.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Optional, Sequence


class Severity(str, enum.Enum):
    """Diagnostic severity, ordered INFO < WARNING < ERROR."""

    INFO = "info"
    WARNING = "warning"
    ERROR = "error"

    @property
    def rank(self) -> int:
        return _SEVERITY_RANK[self]

    @classmethod
    def parse(cls, s: "str | Severity") -> "Severity":
        if isinstance(s, Severity):
            return s
        try:
            return cls(s.lower())
        except ValueError:
            raise ValueError(
                f"unknown severity {s!r} — one of "
                f"{[m.value for m in cls]}"
            ) from None


_SEVERITY_RANK = {Severity.INFO: 0, Severity.WARNING: 1, Severity.ERROR: 2}


@dataclass(frozen=True)
class Diagnostic:
    """One finding of the static analyzer.

    ``rule`` is a stable id from the catalog (``SK1``, ``R1``, ``SH2``,
    ``H3``…); ``node`` names the offending node / stream / value when
    the finding is that local, ``group`` the :class:`GroupSchedule`
    when it is schedule-scoped.  ``hint`` says how to fix it, not just
    what is wrong.
    """

    rule: str
    severity: Severity
    message: str
    graph: str
    node: Optional[str] = None
    group: Optional[str] = None
    hint: Optional[str] = None

    @property
    def location(self) -> str:
        parts = [self.graph]
        if self.group:
            parts.append(self.group)
        if self.node:
            parts.append(self.node)
        return "/".join(parts)

    def format(self) -> str:
        """``error[R1] lenet5/conv0: message (hint: …)``"""
        s = f"{self.severity.value}[{self.rule}] {self.location}: {self.message}"
        if self.hint:
            s += f" (hint: {self.hint})"
        return s

    def to_json(self) -> dict:
        out = {
            "rule": self.rule,
            "severity": self.severity.value,
            "message": self.message,
            "graph": self.graph,
        }
        if self.node:
            out["node"] = self.node
        if self.group:
            out["group"] = self.group
        if self.hint:
            out["hint"] = self.hint
        return out


class LintError(ValueError):
    """ERROR-severity diagnostics under ``CompileOptions(lint="error")``.

    Carries the full diagnostic list on ``.diagnostics`` (every
    severity, not just the fatal ones) so callers can render the whole
    picture, not only the message string.
    """

    def __init__(self, diagnostics: Sequence[Diagnostic], graph: str = ""):
        self.diagnostics: tuple[Diagnostic, ...] = tuple(diagnostics)
        errs = [d for d in self.diagnostics if d.severity is Severity.ERROR]
        head = (f"{graph or (errs[0].graph if errs else '?')}: "
                f"{len(errs)} ERROR-severity diagnostic(s)")
        super().__init__(
            "\n".join([head] + ["  " + d.format() for d in errs])
        )


def max_severity(diags: Sequence[Diagnostic]) -> Optional[Severity]:
    """The worst severity present, or None for a clean list."""
    if not diags:
        return None
    return max((d.severity for d in diags), key=lambda s: s.rank)


def severity_counts(diags: Sequence[Diagnostic]) -> dict[str, int]:
    counts = {s.value: 0 for s in Severity}
    for d in diags:
        counts[d.severity.value] += 1
    return counts


def at_or_above(
    diags: Sequence[Diagnostic], threshold: "str | Severity"
) -> list[Diagnostic]:
    t = Severity.parse(threshold)
    return [d for d in diags if d.severity.rank >= t.rank]


def diagnostics_to_json(
    diags: Sequence[Diagnostic], *, meta: Optional[dict] = None
) -> dict:
    """The JSON diagnostic schema (DESIGN.md §8): a versioned envelope
    with per-severity counts and one record per finding."""
    out = {
        "version": 1,
        "counts": severity_counts(diags),
        "diagnostics": [d.to_json() for d in diags],
    }
    if meta:
        out["meta"] = dict(meta)
    return out
