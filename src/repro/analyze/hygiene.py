"""Model hygiene lints over the DFG.

Cheap structural smells that are legal (the verifier accepts them) but
almost always indicate an importer or rewrite bug:

* **H1 (WARNING)** — an imported constant (weights/bias) no node
  references: dead weight in the artifact, usually a mis-wired import.
* **H2 (WARNING)** — a fused epilogue operand whose dtype differs from
  the node's compute dtype: the bias/scale silently widens or
  truncates on the fused datapath.
* **H3 (WARNING)** — a dead output: a node's result is neither
  consumed nor a graph output.  DCE removes these; seeing one after
  the pipeline means a pass left garbage behind.
* **H4 (WARNING)** — a narrowing stream edge: a consumer computes at
  fewer bits than the stream it reads carries, truncating without an
  explicit requantization step.
"""
from __future__ import annotations

from repro.core.ir import DFG

from .diagnostics import Diagnostic, Severity


def analyze_hygiene(dfg: DFG) -> list[Diagnostic]:
    diags: list[Diagnostic] = []
    graph = dfg.name
    referenced = dfg.referenced_values()

    # H1 — unused imported params
    for name, v in sorted(dfg.values.items()):
        if v.is_constant and name not in referenced:
            diags.append(Diagnostic(
                rule="H1",
                severity=Severity.WARNING,
                graph=graph,
                node=name,
                message=(
                    f"constant {name!r} ({v.num_elements} elements) is "
                    "referenced by no node"
                ),
                hint="drop it from the model, or fix the importer wiring",
            ))

    for n in dfg.nodes:
        # H2 — dtype-inconsistent epilogue operands
        for e in n.epilogue:
            if e.operand is None or e.operand not in dfg.values:
                continue
            ob = dfg.values[e.operand].elem_bits
            if ob != n.elem_bits:
                diags.append(Diagnostic(
                    rule="H2",
                    severity=Severity.WARNING,
                    graph=graph,
                    node=n.name,
                    message=(
                        f"{e.kind.value} epilogue operand {e.operand!r} "
                        f"is {ob}-bit but the node computes at "
                        f"{n.elem_bits} bits"
                    ),
                    hint=(
                        "match the operand dtype to the node or fold an "
                        "explicit cast into the epilogue"
                    ),
                ))

        # H3 — dead outputs
        if (not dfg.consumers_of(n.output)
                and n.output not in dfg.graph_outputs):
            diags.append(Diagnostic(
                rule="H3",
                severity=Severity.WARNING,
                graph=graph,
                node=n.name,
                message=(
                    f"output {n.output!r} is neither consumed nor a "
                    "graph output (dead code)"
                ),
                hint="run DCE, or mark the value as a graph output",
            ))

        # H4 — narrowing stream reads
        for vname in n.inputs:
            v = dfg.values[vname]
            if not v.is_constant and n.elem_bits < v.elem_bits:
                diags.append(Diagnostic(
                    rule="H4",
                    severity=Severity.WARNING,
                    graph=graph,
                    node=n.name,
                    message=(
                        f"consumes {v.elem_bits}-bit stream {vname!r} "
                        f"but computes at {n.elem_bits} bits — implicit "
                        "truncation"
                    ),
                    hint=(
                        "insert an explicit requantization or widen the "
                        "consumer's elem_bits"
                    ),
                ))
    return diags
