"""Integer range analysis: interval propagation over quantized datapaths.

Walks the DFG in topo order carrying a conservative value interval per
stream and infers, for every reduction (conv/matmul MAC, sum, fused
AVG-pool epilogue), the **minimum accumulator width** the lowering must
provide.  The rules:

* **R1 (ERROR)** — the worst-case accumulated sum does not fit the
  accumulator the lowering provides.  This is exactly the post-PR 7
  int8 batched-conv bug class: the vmapped per-tap matmul path
  accumulated in the *input* dtype, so int8 convs wrapped silently.
  The fixed lowering (``repro.kernels.ops.conv2d_same_mm``) casts
  operands to int32 before the reduction; ``acc_bits="input"``
  reconstructs the pre-fix behaviour so the regression stays
  statically detectable.
* **R2 (INFO)** — a node's exact result range needs more bits than its
  output stream carries (``Value.elem_bits``).  In the paper's int8
  regime that is normal — a requantization step is assumed on the
  stream exit — so it is informational, but it is also precisely where
  the analysis widens back to the stream dtype to stay sound.

Soundness note: downstream intervals are always clamped to the stream
dtype (the FIFO physically carries ``elem_bits``), so the propagation
never narrows below what the hardware could observe.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Union

from repro.core.ir import DFG, FusedEpilogue, GenericOp, PayloadKind

from .diagnostics import Diagnostic, Severity

#: the fixed lowering's accumulator: conv2d_same_mm casts int operands
#: to int32 before the per-tap matmuls, so every reduction accumulates
#: in 32 bits regardless of the stream dtype
DEFAULT_ACC_BITS = 32

#: ``acc_bits`` policy reconstructing the pre-fix PR 7 lowering: the
#: accumulator is whatever dtype the node's streams carry
ACC_INPUT_DTYPE = "input"


@dataclass(frozen=True)
class Interval:
    """A closed integer interval [lo, hi]."""

    lo: int
    hi: int

    def __post_init__(self) -> None:
        if self.lo > self.hi:
            raise ValueError(f"empty interval [{self.lo}, {self.hi}]")

    # -- arithmetic ---------------------------------------------------------

    def add(self, other: "Interval") -> "Interval":
        return Interval(self.lo + other.lo, self.hi + other.hi)

    def mul(self, other: "Interval") -> "Interval":
        corners = (
            self.lo * other.lo, self.lo * other.hi,
            self.hi * other.lo, self.hi * other.hi,
        )
        return Interval(min(corners), max(corners))

    def scale(self, k: int) -> "Interval":
        """Sum of ``k`` values each drawn from this interval."""
        return Interval(self.lo * k, self.hi * k)

    def floordiv(self, k: int) -> "Interval":
        return Interval(self.lo // k, self.hi // k)

    def join_max(self, other: "Interval") -> "Interval":
        return Interval(max(self.lo, other.lo), max(self.hi, other.hi))

    def relu(self) -> "Interval":
        return Interval(max(self.lo, 0), max(self.hi, 0))

    def union(self, other: "Interval") -> "Interval":
        return Interval(min(self.lo, other.lo), max(self.hi, other.hi))

    # -- width --------------------------------------------------------------

    @property
    def bits(self) -> int:
        """Smallest signed width holding every value in the interval."""
        need_hi = self.hi.bit_length() + 1 if self.hi > 0 else 1
        need_lo = (-self.lo - 1).bit_length() + 1 if self.lo < 0 else 1
        return max(need_hi, need_lo)

    def fits(self, bits: int) -> bool:
        return self.bits <= bits

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"[{self.lo}, {self.hi}]"


def dtype_interval(bits: int) -> Interval:
    """The value range of a ``bits``-wide signed stream element."""
    return Interval(-(1 << (bits - 1)), (1 << (bits - 1)) - 1)


# ---------------------------------------------------------------------------
# Propagation
# ---------------------------------------------------------------------------


def _reduction_trip(op: GenericOp) -> int:
    return math.prod(
        (op.dim_extent(d) for d in op.reduction_dims), start=1
    )


def _resolve_acc_bits(op: GenericOp, acc_bits: Union[int, str]) -> int:
    if acc_bits == ACC_INPUT_DTYPE:
        return op.elem_bits
    return int(acc_bits)


class _RangeWalker:
    def __init__(self, dfg: DFG, acc_bits: Union[int, str]):
        self.dfg = dfg
        self.acc_bits = acc_bits
        self.env: dict[str, Interval] = {}
        self.diags: list[Diagnostic] = []

    # -- diagnostics --------------------------------------------------------

    def _overflow(self, op: GenericOp, what: str, acc: Interval,
                  trip: int) -> None:
        avail = _resolve_acc_bits(op, self.acc_bits)
        if acc.fits(avail):
            return
        self.diags.append(Diagnostic(
            rule="R1",
            severity=Severity.ERROR,
            graph=self.dfg.name,
            node=op.name,
            message=(
                f"{what}: {trip}-term {op.payload.value} reduction "
                f"accumulates into {acc} — needs a {acc.bits}-bit "
                f"accumulator but the lowering provides {avail} bits"
            ),
            hint=(
                "accumulate in int32: cast operands before the "
                "reduction as kernels/ops.conv2d_same_mm does"
            ),
        ))

    # -- per-node transfer --------------------------------------------------

    def _value(self, name: str) -> Interval:
        if name in self.env:
            return self.env[name]
        v = self.dfg.values[name]
        iv = dtype_interval(v.elem_bits)
        self.env[name] = iv
        return iv

    def _payload_result(self, op: GenericOp) -> Interval:
        ins = [self._value(n) for n in op.inputs]
        trip = _reduction_trip(op)
        kind = op.payload

        if kind == PayloadKind.MAC:
            point = ins[0].mul(ins[1]) if len(ins) >= 2 else ins[0]
            acc = point.scale(trip)
            self._overflow(op, "payload", acc, trip)
            return acc
        if kind == PayloadKind.ADD:
            point = ins[0].add(ins[1]) if len(ins) >= 2 else ins[0]
            if trip > 1:
                acc = point.scale(trip)
                self._overflow(op, "payload", acc, trip)
                return acc
            return point
        if kind == PayloadKind.MUL:
            if len(ins) >= 2 and trip == 1:
                return ins[0].mul(ins[1])
            return dtype_interval(op.elem_bits)
        if kind == PayloadKind.MAX:
            out = ins[0]
            for other in ins[1:]:
                out = out.join_max(other)
            return out
        if kind == PayloadKind.AVG:
            acc = ins[0].scale(trip)
            self._overflow(op, "payload", acc, trip)
            return acc.floordiv(trip) if trip else ins[0]
        if kind == PayloadKind.RELU:
            return ins[0].relu()
        if kind == PayloadKind.SQUARED_RELU:
            r = ins[0].relu()
            return Interval(0, r.hi * r.hi)
        if kind == PayloadKind.IDENTITY:
            return ins[0] if ins else dtype_interval(op.elem_bits)
        # EXP and anything future: no useful static bound — the stream
        # dtype is the sound fallback (the FIFO carries elem_bits)
        return dtype_interval(op.elem_bits)

    def _apply_epilogue(
        self, op: GenericOp, e: FusedEpilogue, cur: Interval
    ) -> Interval:
        if e.window and any(f > 1 for f in e.window):
            w = math.prod(e.window)
            if e.kind == PayloadKind.AVG:
                acc = cur.scale(w)
                self._overflow(op, f"{e.kind.value}-pool epilogue", acc, w)
                return acc.floordiv(w)
            # MAX (and any order-statistic pool) preserves the interval
            return cur
        operand = self._value(e.operand) if e.operand else None
        if e.kind == PayloadKind.RELU:
            return cur.relu()
        if e.kind == PayloadKind.ADD and operand:
            return cur.add(operand)
        if e.kind == PayloadKind.MUL and operand:
            return cur.mul(operand)
        if e.kind == PayloadKind.MAX and operand:
            return cur.join_max(operand)
        if e.kind == PayloadKind.SQUARED_RELU:
            r = cur.relu()
            return Interval(0, r.hi * r.hi)
        if e.kind == PayloadKind.IDENTITY:
            return cur
        return dtype_interval(op.elem_bits)

    # -- driver -------------------------------------------------------------

    def run(self) -> None:
        for op in self.dfg.topo_order():
            exact = self._payload_result(op)
            for e in op.epilogue:
                exact = self._apply_epilogue(op, e, exact)
            out_v = self.dfg.values[op.output]
            carrier = dtype_interval(out_v.elem_bits)
            if exact.lo >= carrier.lo and exact.hi <= carrier.hi:
                self.env[op.output] = exact
            else:
                # the stream physically carries elem_bits: widen back to
                # the dtype range (sound) and note the assumed requant
                self.env[op.output] = carrier
                self.diags.append(Diagnostic(
                    rule="R2",
                    severity=Severity.INFO,
                    graph=self.dfg.name,
                    node=op.name,
                    message=(
                        f"output range {exact} needs {exact.bits} bits "
                        f"but stream {op.output!r} carries "
                        f"{out_v.elem_bits} — requantization assumed at "
                        "the stream exit"
                    ),
                    hint=(
                        "widen the output Value's elem_bits or fold an "
                        "explicit requantization scale into the epilogue"
                    ),
                ))


def analyze_ranges(
    dfg: DFG, *, acc_bits: Union[int, str] = DEFAULT_ACC_BITS
) -> list[Diagnostic]:
    """Range diagnostics for ``dfg`` under an accumulator policy.

    ``acc_bits`` is the width every reduction accumulates in: the
    default 32 models the fixed int32 lowering; ``"input"``
    (:data:`ACC_INPUT_DTYPE`) models the pre-fix PR 7 lowering that
    accumulated in the stream dtype; any int models a custom datapath.
    """
    w = _RangeWalker(dfg, acc_bits)
    w.run()
    return w.diags


def value_intervals(
    dfg: DFG, *, acc_bits: Union[int, str] = DEFAULT_ACC_BITS
) -> dict[str, Interval]:
    """The propagated (stream-clamped) interval per value name."""
    w = _RangeWalker(dfg, acc_bits)
    w.run()
    return w.env


def overflow_safe(
    dfg: DFG, *, acc_bits: Union[int, str] = DEFAULT_ACC_BITS
) -> bool:
    """True when no ERROR-severity range diagnostic fires — the
    analyzer's claim that every reduction fits its accumulator."""
    return not any(
        d.severity is Severity.ERROR for d in
        analyze_ranges(dfg, acc_bits=acc_bits)
    )
