"""The analyzer engine: rule catalog + the three entry points.

* :func:`analyze_dfg` — graph-scoped families (hygiene + ranges), no
  schedule needed; what the verifier-adjacent callers use.
* :func:`analyze_plan` — plan-scoped family (stream skew) for one
  :class:`StreamingPlan`.
* :func:`analyze_design` — everything, over a ``CompiledDesign``:
  hygiene + ranges on the lowered source graph, stream skew per group
  plan, schedule hazards on the group/spill schedule.  This is what
  ``compile_design`` runs under ``CompileOptions(lint=...)``.

Each family runs under an ``analyze:<family>`` span on the ambient
PR 6 tracer (``cat="analyze"``), so lint cost shows up in the same
Chrome trace as passes, DP, and DSE.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Union

from repro import instrument
from repro.core.ir import DFG
from repro.core.streaming import StreamingPlan

from .diagnostics import Diagnostic, Severity
from .hazards import analyze_schedule
from .hygiene import analyze_hygiene
from .ranges import DEFAULT_ACC_BITS, analyze_ranges
from .stream_skew import analyze_stream_skew


@dataclass(frozen=True)
class Rule:
    """Catalog entry: stable id, default severity, scope, one-liner."""

    id: str
    severity: Severity
    scope: str  # "dfg" | "plan" | "design"
    summary: str


#: the rule catalog — ids are stable and documented in DESIGN.md §8
RULES: dict[str, Rule] = {r.id: r for r in (
    Rule("SK1", Severity.ERROR, "plan",
         "reconvergent-branch FIFO depth cannot absorb the row-rate "
         "skew (stream deadlock)"),
    Rule("SK2", Severity.INFO, "plan",
         "reconvergent join observability: skew absorbed per skip FIFO"),
    Rule("R1", Severity.ERROR, "dfg",
         "reduction accumulator narrower than the worst-case sum "
         "(integer overflow / wrap)"),
    Rule("R2", Severity.INFO, "dfg",
         "exact result range exceeds the output stream width "
         "(requantization assumed)"),
    Rule("SH1", Severity.ERROR, "design",
         "group BRAM/DSP over-commit vs the target budget"),
    Rule("SH2", Severity.ERROR, "design",
         "spill/fill read-before-write across overlapped transitions"),
    Rule("SH3", Severity.WARNING, "design",
         "transition overlap window smaller than one DRAM burst "
         "(degenerates to serial DMA)"),
    Rule("H1", Severity.WARNING, "dfg", "unused imported constant"),
    Rule("H2", Severity.WARNING, "dfg",
         "dtype-inconsistent fused epilogue operand"),
    Rule("H3", Severity.WARNING, "dfg",
         "dead output (unconsumed, not a graph output)"),
    Rule("H4", Severity.WARNING, "dfg",
         "narrowing stream edge without explicit requantization"),
)}


def analyze_dfg(
    dfg: DFG, *, acc_bits: Union[int, str] = DEFAULT_ACC_BITS
) -> list[Diagnostic]:
    """Graph-scoped diagnostics: hygiene lints + integer range analysis."""
    tracer = instrument.current()
    diags: list[Diagnostic] = []
    with tracer.span(f"analyze:hygiene:{dfg.name}", cat="analyze"):
        diags += analyze_hygiene(dfg)
    with tracer.span(f"analyze:ranges:{dfg.name}", cat="analyze"):
        diags += analyze_ranges(dfg, acc_bits=acc_bits)
    return diags


def analyze_plan(
    plan: StreamingPlan, *, group: Optional[str] = None
) -> list[Diagnostic]:
    """Plan-scoped diagnostics: stream-skew / deadlock analysis."""
    tracer = instrument.current()
    with tracer.span(f"analyze:skew:{plan.dfg.name}", cat="analyze"):
        return analyze_stream_skew(plan, group=group)


def analyze_design(
    design, *, acc_bits: Union[int, str] = DEFAULT_ACC_BITS
) -> list[Diagnostic]:
    """All four families over a ``CompiledDesign``."""
    tracer = instrument.current()
    with tracer.span(f"analyze:{design.source.name}", cat="analyze") as args:
        diags = analyze_dfg(design.source, acc_bits=acc_bits)
        for g in design.groups:
            diags += analyze_plan(g.plan, group=g.name)
        with tracer.span(
            f"analyze:hazards:{design.source.name}", cat="analyze"
        ):
            diags += analyze_schedule(design)
        args["diagnostics"] = len(diags)
        args["errors"] = sum(
            1 for d in diags if d.severity is Severity.ERROR
        )
    return diags
