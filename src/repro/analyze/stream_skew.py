"""Stream-skew / deadlock analysis over a :class:`StreamingPlan`.

For every reconvergent path (a fork whose branches re-join — residual
adds, conv+pool fusions feeding a common consumer), the branch that
produces its first element earlier must park data in its FIFO while the
long branch fills its line buffers.  :func:`repro.core.streaming.fifo_slack`
derives that row-rate skew from the line-buffer geometry; here we check
the *charged* FIFO depth actually absorbs it:

* **SK1 (ERROR)** — an internal stream's depth is smaller than the
  skew it must absorb.  In hardware this is a deadlock: the short
  branch's FIFO fills, back-pressure stalls the fork, and the long
  branch never receives the elements it needs to produce its first
  output.  ``plan_streams`` sizes these FIFOs automatically
  (``_size_diamond_fifos``), so SK1 firing means the plan was built or
  edited outside that path — exactly the class of bug FIFO sizing
  papers (FIFOAdvisor et al.) exist for.
* **SK2 (INFO)** — a reconvergent join and the skew its skip FIFO
  absorbs: observability for how much BRAM the diamond costs.
"""
from __future__ import annotations

from typing import Optional

from repro.core.streaming import StreamingPlan, fifo_slack

from .diagnostics import Diagnostic, Severity


def analyze_stream_skew(
    plan: StreamingPlan, *, group: Optional[str] = None
) -> list[Diagnostic]:
    diags: list[Diagnostic] = []
    graph = plan.dfg.name
    for name, need in sorted(fifo_slack(plan).items()):
        s = plan.streams[name]
        if s.depth < need:
            diags.append(Diagnostic(
                rule="SK1",
                severity=Severity.ERROR,
                graph=graph,
                group=group,
                node=name,
                message=(
                    f"reconvergent branch {s.producer} -> {s.consumer}: "
                    f"data is ready {need} cycles before the join's "
                    f"slowest input but the FIFO holds only {s.depth} "
                    "elements — the pipeline deadlocks once it fills"
                ),
                hint=(
                    f"deepen the skip FIFO to >= {need} (plan_streams' "
                    "_size_diamond_fifos does this automatically)"
                ),
            ))
        else:
            diags.append(Diagnostic(
                rule="SK2",
                severity=Severity.INFO,
                graph=graph,
                group=group,
                node=name,
                message=(
                    f"reconvergent join at {s.consumer}: skip FIFO "
                    f"absorbs a {need}-cycle skew (depth {s.depth}, "
                    f"{s.buffer_bits} bits)"
                ),
            ))
    return diags
