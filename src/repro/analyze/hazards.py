"""Schedule hazard checks over the :class:`CompiledDesign` schedule IR.

The host schedule (``emit_host_schedule``) runs groups back-to-back,
overlapping group *k*'s spill write DMA with group *k+1*'s fill read
(the read trails the write by one DRAM burst).  That overlap is only
legal when every filled buffer was written at the same or an earlier
transition — and the whole schedule is only realizable when every
group fits the target budget.  Three rules:

* **SH1 (ERROR)** — per-group BRAM/DSP over-commit: a group's resources
  exceed the target budget (or its DSE solution is marked infeasible).
  The emitted design cannot place and route.
* **SH2 (ERROR)** — read-before-write across spill/fill transitions: a
  group fills a value that no earlier (or same-transition) group
  spilled and that is not a graph input.  The overlapped DMA would
  read garbage from an unwritten DRAM buffer.
* **SH3 (WARNING)** — a transition whose overlap window is smaller
  than one DRAM burst: ``transition_cycles`` degenerates to the serial
  write-then-read sum, so the boundary pays full price — worth knowing
  when a partition cut was chosen for overlap it cannot get.
"""
from __future__ import annotations

from repro.core.resource_model import DRAM_BURST_BYTES

from .diagnostics import Diagnostic, Severity


def analyze_schedule(design) -> list[Diagnostic]:
    """Hazard diagnostics for a ``CompiledDesign``."""
    diags: list[Diagnostic] = []
    graph = design.source.name

    # SH1 — per-group budget over-commit
    for g in design.groups:
        over = []
        if g.bram > design.b_total:
            over.append(f"BRAM {g.bram}/{design.b_total}")
        if g.dsp > design.d_total:
            over.append(f"DSP {g.dsp}/{design.d_total}")
        if over or not g.dse.feasible:
            what = ", ".join(over) if over else "DSE marked infeasible"
            diags.append(Diagnostic(
                rule="SH1",
                severity=Severity.ERROR,
                graph=graph,
                group=g.name,
                message=f"group over target budget: {what}",
                hint=(
                    "partition further, enable weight_streaming, or "
                    "compile for a larger target"
                ),
            ))

    # SH2 — read-before-write across overlapped spill/fill transitions.
    # A fill at transition t may consume values spilled at transitions
    # <= t (same-transition is the designed trailing read: the emitter
    # issues dma_write_async before dma_read_async).  Graph inputs live
    # in DRAM from the start and are always readable.
    written: set[str] = set(design.source.graph_inputs)
    for t, (g, nxt) in enumerate(zip(design.groups, design.groups[1:])):
        written |= set(g.spill_out)
        for v in nxt.spill_in:
            if v not in written:
                diags.append(Diagnostic(
                    rule="SH2",
                    severity=Severity.ERROR,
                    graph=graph,
                    group=nxt.name,
                    node=v,
                    message=(
                        f"fill of {v!r} at transition {t} precedes its "
                        "spill — the overlapped DMA reads an unwritten "
                        "DRAM buffer"
                    ),
                    hint=(
                        "the producing group must run (and spill) no "
                        "later than the transition that fills the value"
                    ),
                ))

    # SH3 — degenerate overlap window at a transition
    for t, (w, r) in enumerate(design.boundary_traffic()):
        if w > 0 and r > 0 and min(w, r) < DRAM_BURST_BYTES:
            g, nxt = design.groups[t], design.groups[t + 1]
            diags.append(Diagnostic(
                rule="SH3",
                severity=Severity.WARNING,
                graph=graph,
                group=g.name,
                message=(
                    f"transition {g.name} -> {nxt.name} moves "
                    f"{min(w, r)} bytes on its smaller side — less than "
                    f"one DRAM burst ({DRAM_BURST_BYTES} B), so the "
                    "spill/fill overlap degenerates to the serial sum"
                ),
                hint=(
                    "a different cut point (or keeping the slice whole "
                    "with streamed weights) avoids the exposed boundary"
                ),
            ))
    return diags
