"""nemotron-4-15b [dense]: 32L d_model=6144 48H (GQA kv=8) d_ff=24576
vocab=256000 — squared-ReLU ungated MLP [arXiv:2402.16819; unverified]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b", family="dense", num_layers=32, d_model=6144,
    num_heads=48, num_kv_heads=8, d_ff=24576, vocab_size=256000,
    act="squared_relu", gated_mlp=False, rope_theta=10_000.0,
)


def smoke_config() -> ModelConfig:
    return CONFIG.with_(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
        vocab_size=256, attn_block_q=16, attn_block_k=16, loss_chunk=16,
    )
