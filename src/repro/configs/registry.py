"""Architecture registry: --arch <id> resolution."""
from __future__ import annotations

import importlib

ARCHS = {
    "llama3.2-1b": "llama3_2_1b",
    "qwen2-0.5b": "qwen2_0_5b",
    "nemotron-4-15b": "nemotron_4_15b",
    "yi-9b": "yi_9b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "mamba2-1.3b": "mamba2_1_3b",
}


def get_config(arch: str, smoke: bool = False):
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    mod = importlib.import_module(f"repro.configs.{ARCHS[arch]}")
    return mod.smoke_config() if smoke else mod.CONFIG


def all_archs() -> list[str]:
    return list(ARCHS)
