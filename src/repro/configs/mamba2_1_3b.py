"""mamba2-1.3b [ssm]: 48L d_model=2048 (attention-free) vocab=50280,
ssm_state=128 — SSD state-space duality [arXiv:2405.21060; unverified].

The arch with the strongest affinity to the paper (DESIGN.md §4): the
whole sequence mixer is a streaming line buffer (conv window + SSD
state).  Sub-quadratic → long_500k decode runs.
"""
from .base import ModelConfig, SsmConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b", family="ssm", num_layers=48, d_model=2048,
    num_heads=0, num_kv_heads=0, d_ff=0, vocab_size=50280,
    ssm=SsmConfig(state_dim=128, head_dim=64, expand=2, conv_kernel=4,
                  chunk=64),
    sub_quadratic=True,
    pad_vocab_to=256,
)


def smoke_config() -> ModelConfig:
    return CONFIG.with_(
        num_layers=2, d_model=64,
        ssm=SsmConfig(state_dim=16, head_dim=16, expand=2, conv_kernel=4,
                      chunk=8),
        vocab_size=256, loss_chunk=16,
    )
