"""seamless-m4t-medium [audio]: enc-dec 12+12L d_model=1024 16H (kv=16)
d_ff=4096 vocab=256206 [arXiv:2308.11596; hf].

Modality frontend is a stub per the assignment: input_specs provides
precomputed frame embeddings (B, T, d_model).  Enc-dec shape mapping
(DESIGN.md §4): train_4k = enc 4096 frames + dec 1024 targets;
prefill_32k = enc 32768 frames; decode_32k = one decoder token against a
32k cross memory + 32k self cache.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="encdec", num_layers=24,
    d_model=1024, num_heads=16, num_kv_heads=16, d_ff=4096,
    vocab_size=256206, enc_layers=12, dec_layers=12,
    act="gelu", gated_mlp=False, embeds_input=True, rope_theta=10_000.0,
)

#: decoder target length for train_4k (enc frames = shape seq_len)
DEC_TRAIN_FRAC = 4


def smoke_config() -> ModelConfig:
    return CONFIG.with_(
        enc_layers=2, dec_layers=2, num_layers=4, d_model=64, num_heads=4,
        num_kv_heads=4, d_ff=128, vocab_size=256, attn_block_q=16,
        attn_block_k=16, loss_chunk=16,
    )
