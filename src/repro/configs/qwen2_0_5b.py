"""qwen2-0.5b [dense]: 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151936 — GQA with QKV bias [arXiv:2407.10671; hf]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b", family="dense", num_layers=24, d_model=896,
    num_heads=14, num_kv_heads=2, d_ff=4864, vocab_size=151936,
    act="silu", gated_mlp=True, qkv_bias=True, rope_theta=1_000_000.0,
    tie_embeddings=True,
    tp_preference=2,
)


def smoke_config() -> ModelConfig:
    return CONFIG.with_(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
        vocab_size=256, attn_block_q=16, attn_block_k=16, loss_chunk=16,
    )
