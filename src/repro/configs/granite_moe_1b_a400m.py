"""granite-moe-1b-a400m [moe]: 24L d_model=1024 16H (GQA kv=8) d_ff=512
vocab=49155, MoE 32 experts top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]."""
from .base import ModelConfig, MoeConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m", family="moe", num_layers=24, d_model=1024,
    num_heads=16, num_kv_heads=8, d_ff=512, vocab_size=49155,
    act="silu", gated_mlp=True, rope_theta=10_000.0,
    moe=MoeConfig(num_experts=32, top_k=8),
    pad_vocab_to=256,
    tp_preference=8,
)


def smoke_config() -> ModelConfig:
    return CONFIG.with_(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=32,
        vocab_size=256, moe=MoeConfig(num_experts=8, top_k=2),
        attn_block_q=16, attn_block_k=16, loss_chunk=16,
    )
