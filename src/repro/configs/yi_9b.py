"""yi-9b [dense]: 48L d_model=4096 32H (GQA kv=4) d_ff=11008
vocab=64000 — llama-arch GQA [arXiv:2403.04652; hf]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="yi-9b", family="dense", num_layers=48, d_model=4096,
    num_heads=32, num_kv_heads=4, d_ff=11008, vocab_size=64000,
    act="silu", gated_mlp=True, rope_theta=10_000.0,
)


def smoke_config() -> ModelConfig:
    return CONFIG.with_(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
        vocab_size=256, attn_block_q=16, attn_block_k=16, loss_chunk=16,
    )
