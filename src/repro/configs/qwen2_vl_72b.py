"""qwen2-vl-72b [vlm]: 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064 — M-RoPE, dynamic resolution [arXiv:2409.12191; hf].

Backbone only per the assignment: the vision frontend is a stub —
input_specs supplies precomputed patch/text embeddings plus (3, B, S)
M-RoPE position streams (temporal, height, width).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b", family="vlm", num_layers=80, d_model=8192,
    num_heads=64, num_kv_heads=8, d_ff=29568, vocab_size=152064,
    act="silu", gated_mlp=True, qkv_bias=True, embeds_input=True,
    mrope_sections=(16, 24, 24), rope_theta=1_000_000.0,
)


def smoke_config() -> ModelConfig:
    return CONFIG.with_(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
        vocab_size=256, mrope_sections=(4, 2, 2), attn_block_q=16,
        attn_block_k=16, loss_chunk=16,
    )
