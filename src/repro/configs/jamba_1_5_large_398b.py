"""jamba-1.5-large-398b [hybrid]: 72L d_model=8192 64H (GQA kv=8)
d_ff=24576 vocab=65536, MoE 16e top-2, Mamba:attention 7:1 interleave
[arXiv:2403.19887; hf].

Deviation note (DESIGN.md §4): Jamba publishes Mamba-1 mixers; this repo
uses Mamba-2 (SSD) blocks as its SSM substrate for all SSM-bearing archs
— same O(1)-state streaming role, kernel shared with mamba2-1.3b.
Sub-quadratic: the 1-in-8 attention layers hold the only KV cache, so
long_500k decode is runnable (sharded 9-layer 500k cache).
"""
from .base import ModelConfig, MoeConfig, SsmConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid", num_layers=72,
    d_model=8192, num_heads=64, num_kv_heads=8, d_ff=24576,
    vocab_size=65536, act="silu", gated_mlp=True,
    moe=MoeConfig(num_experts=16, top_k=2, moe_period=2),
    ssm=SsmConfig(state_dim=128, head_dim=64, expand=2, conv_kernel=4,
                  chunk=64),
    attn_period=8, sub_quadratic=True, rope_theta=10_000.0,
)


def smoke_config() -> ModelConfig:
    return CONFIG.with_(
        num_layers=8, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
        vocab_size=256, moe=MoeConfig(num_experts=4, top_k=2, moe_period=2),
        ssm=SsmConfig(state_dim=16, head_dim=16, expand=2, conv_kernel=4,
                      chunk=8),
        attn_period=8, attn_block_q=16, attn_block_k=16, loss_chunk=16,
    )
