"""olmoe-1b-7b [moe]: 16L d_model=2048 16H (GQA kv=16) d_ff=1024
vocab=50304, MoE 64 experts top-8 [arXiv:2409.02060; hf]."""
from .base import ModelConfig, MoeConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b", family="moe", num_layers=16, d_model=2048,
    num_heads=16, num_kv_heads=16, d_ff=1024, vocab_size=50304,
    act="silu", gated_mlp=True, rope_theta=10_000.0,
    moe=MoeConfig(num_experts=64, top_k=8),
)


def smoke_config() -> ModelConfig:
    return CONFIG.with_(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, d_ff=32,
        vocab_size=256, moe=MoeConfig(num_experts=8, top_k=2),
        attn_block_q=16, attn_block_k=16, loss_chunk=16,
    )
