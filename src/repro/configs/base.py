"""Config system: model architecture + workload shape + runtime knobs.

Every assigned architecture gets a ``src/repro/configs/<id>.py`` exposing
``CONFIG`` (a :class:`ModelConfig` with the exact published numbers) and
``smoke_config()`` (a reduced same-family config for CPU smoke tests).

Workload shapes (assignment):
  train_4k      seq 4,096  global_batch 256   (train_step)
  prefill_32k   seq 32,768 global_batch 32    (serve: prefill)
  decode_32k    seq 32,768 global_batch 128   (serve: one decode step)
  long_500k     seq 524,288 global_batch 1    (decode; sub-quadratic only)
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Literal, Optional

import jax.numpy as jnp


@dataclass(frozen=True)
class MoeConfig:
    num_experts: int
    top_k: int
    capacity_factor: float = 1.25
    # every n-th layer is MoE (1 = all layers, 2 = alternate — Jamba)
    moe_period: int = 1


@dataclass(frozen=True)
class SsmConfig:
    state_dim: int = 128          # N
    head_dim: int = 64            # P
    expand: int = 2               # d_inner = expand * d_model
    conv_kernel: int = 4          # depthwise causal conv width
    chunk: int = 64               # SSD chunk length

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def num_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim

    def conv_dim(self, d_model: int) -> int:
        # conv runs over (x, B, C) channels
        return self.d_inner(d_model) + 2 * self.state_dim


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Literal["dense", "moe", "ssm", "hybrid", "encdec", "vlm", "audio"]
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                      # 0 → d_model // num_heads
    act: str = "silu"
    gated_mlp: bool = True
    qkv_bias: bool = False
    rope_theta: float = 500_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # --- modality / structure extras ---
    moe: Optional[MoeConfig] = None
    ssm: Optional[SsmConfig] = None
    attn_period: int = 0                   # hybrid: 1 attn per N layers (Jamba: 8)
    enc_layers: int = 0                    # encdec: encoder depth
    dec_layers: int = 0                    # encdec: decoder depth
    mrope_sections: tuple[int, ...] = ()   # M-RoPE (t,h,w) half-dim split
    embeds_input: bool = False             # frontend stub: inputs are embeddings
    # --- applicability (DESIGN.md §4) ---
    sub_quadratic: bool = False            # can run long_500k
    # --- runtime knobs ---
    remat: bool = True
    attn_impl: Literal["blockwise", "reference", "pallas"] = "blockwise"
    mlp_impl: Literal["dense", "streamed"] = "dense"
    loss_chunk: int = 512                  # CE computed in seq chunks
    attn_block_q: int = 512
    attn_block_k: int = 512
    # streaming backward (MING C1 at train time): recompute attention
    # score blocks / CE logit chunks in the VJP instead of stashing the
    # O(S²) / O(S·V) intermediates.  False = default scan VJP, kept for
    # the §Perf before/after measurement.
    attn_streaming_bwd: bool = True
    loss_streaming_bwd: bool = True
    # pad embed/lm_head vocab rows to a multiple (0 = off).  Unpadded
    # vocabs (50280, 49155, 256206…) cannot vocab-shard over a model=16
    # axis — padding to 256·k restores the sharding (§Perf optimization;
    # padded logit columns are masked to -inf in the loss/serve paths).
    pad_vocab_to: int = 0
    # preferred tensor-parallel width (0 = the mesh default).  Small
    # models with odd head counts (qwen2-0.5b: 14H) waste a 16-wide model
    # axis — the launcher reshapes the SAME chip count to (data·16/tp, tp)
    # (§Perf optimization A2).
    tp_preference: int = 0

    @property
    def padded_vocab(self) -> int:
        if not self.pad_vocab_to:
            return self.vocab_size
        m = self.pad_vocab_to
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        if self.num_heads == 0:        # attention-free (pure SSM)
            return 0
        return self.d_model // self.num_heads

    @property
    def param_dtype(self):
        return jnp.dtype(self.dtype)

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Assignment skip rules (recorded, not silently dropped)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, (
            "full-attention arch: 524k dense KV with O(L^2) history is the "
            "edge-infeasible case the paper targets — skipped per DESIGN.md §4"
        )
    return True, ""


# ---------------------------------------------------------------------------
# Parameter counting (for MODEL_FLOPS = 6·N·D roofline term)
# ---------------------------------------------------------------------------


def _attn_params(cfg: ModelConfig) -> int:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    q = d * cfg.num_heads * hd
    kv = 2 * d * cfg.num_kv_heads * hd
    o = cfg.num_heads * hd * d
    bias = (cfg.num_heads + 2 * cfg.num_kv_heads) * hd if cfg.qkv_bias else 0
    return q + kv + o + bias


def _mlp_params(cfg: ModelConfig) -> int:
    mult = 3 if cfg.gated_mlp else 2
    return mult * cfg.d_model * cfg.d_ff


def _moe_params(cfg: ModelConfig, active: bool) -> int:
    assert cfg.moe is not None
    e = cfg.moe.top_k if active else cfg.moe.num_experts
    mult = 3 if cfg.gated_mlp else 2
    return cfg.d_model * cfg.moe.num_experts + e * mult * cfg.d_model * cfg.d_ff


def _mamba_params(cfg: ModelConfig) -> int:
    assert cfg.ssm is not None
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    h = s.num_heads(d)
    in_p = d * (2 * di + 2 * s.state_dim + h)
    conv = s.conv_kernel * s.conv_dim(d)
    out_p = di * d
    return in_p + conv + out_p + 3 * h + di


def count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    """Total (or MoE-active) parameter count, embeddings included."""
    d = cfg.d_model
    # stub-frontend archs have no token embedding — except enc-dec, whose
    # *decoder* still embeds target tokens (only encoder frames are stubbed)
    no_embed = cfg.embeds_input and cfg.family != "encdec"
    v = cfg.padded_vocab
    embed = 0 if no_embed else v * d
    head = 0 if cfg.tie_embeddings else v * d
    norms = 0

    def dense_block() -> int:
        return _attn_params(cfg) + _mlp_params(cfg) + 2 * d

    def moe_block() -> int:
        return _attn_params(cfg) + _moe_params(cfg, active_only) + 2 * d

    def mamba_block() -> int:
        return _mamba_params(cfg) + d

    if cfg.family in ("dense", "vlm", "audio"):
        body = cfg.num_layers * dense_block()
    elif cfg.family == "moe":
        body = cfg.num_layers * moe_block()
    elif cfg.family == "ssm":
        body = cfg.num_layers * mamba_block()
    elif cfg.family == "hybrid":
        assert cfg.attn_period > 0 and cfg.moe is not None
        n_attn = cfg.num_layers // cfg.attn_period
        n_mamba = cfg.num_layers - n_attn
        n_moe = cfg.num_layers // cfg.moe.moe_period
        n_dense_mlp = cfg.num_layers - n_moe
        ffn = n_moe * _moe_params(cfg, active_only) + n_dense_mlp * _mlp_params(cfg)
        attn = n_attn * _attn_params(cfg)
        mamba = n_mamba * _mamba_params(cfg)
        body = ffn + attn + mamba + 2 * cfg.num_layers * d
    elif cfg.family == "encdec":
        enc = cfg.enc_layers * dense_block()
        # decoder: self-attn + cross-attn + mlp
        dec = cfg.dec_layers * (2 * _attn_params(cfg) + _mlp_params(cfg) + 3 * d)
        body = enc + dec + d  # two final norms (enc + dec); second added below
    else:  # pragma: no cover
        raise ValueError(cfg.family)
    return embed + head + body + norms + d  # final norm


def model_flops_per_token(cfg: ModelConfig, training: bool) -> float:
    """MODEL_FLOPS/token = 6·N (train) or 2·N (inference), N = active params."""
    n = count_params(cfg, active_only=cfg.moe is not None)
    return (6.0 if training else 2.0) * n
