"""Gradient compression for the slow (cross-pod / DCN) axis.

int8 absmax quantization with *error feedback*: the quantization residual
is carried to the next step, so compression error accumulates to zero
instead of biasing the update (Seide et al. / 1-bit-Adam lineage).

``compressed_psum`` runs inside ``shard_map`` over the pod axis: each pod
reduces its local (fast, ICI) portion in full precision via the normal
pjit path, then the cross-pod sum moves int8 — a 4× reduction of DCN
bytes at 398B-scale gradients (the collective term of the roofline).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


class ErrorFeedbackState(NamedTuple):
    err: dict     # pytree congruent with grads, fp32 residuals


def init_error_feedback(grads_template: dict) -> ErrorFeedbackState:
    return ErrorFeedbackState(
        jax.tree.map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads_template
        )
    )


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_with_feedback(
    g: jax.Array, err: jax.Array
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (q, scale, new_err)."""
    target = g.astype(jnp.float32) + err
    q, scale = quantize_int8(target)
    new_err = target - dequantize_int8(q, scale)
    return q, scale, new_err


def compressed_psum_pod(
    grads: dict,
    err_state: ErrorFeedbackState,
    mesh: Mesh,
) -> tuple[dict, ErrorFeedbackState]:
    """All-reduce grads over the 'pod' axis with int8 payload.

    Expects grads already reduced within each pod (the standard pjit
    gradient path does that); this adds the cross-pod mean.
    """
    assert "pod" in mesh.axis_names, "compressed_psum needs a pod axis"

    def one(g, err):
        def inner(g_local, err_local):
            q, scale, new_err = compress_with_feedback(g_local, err_local)
            # int8 payload over the slow axis; scales ride along in f32
            summed = lax.psum(q.astype(jnp.int32), "pod")
            scale_sum = lax.psum(scale, "pod")
            npod = lax.psum(jnp.ones((), jnp.float32), "pod")
            # each pod contributed ~q*scale; use mean scale (absmax scales
            # are near-identical across pods for i.i.d. shards)
            out = summed.astype(jnp.float32) * (scale_sum / npod) / npod
            return out.astype(g_local.dtype), new_err

        # grads are fully sharded; shard_map over every mesh axis with the
        # pod axis as the reduction axis
        spec = P(*mesh.axis_names)
        # run with replication spec on non-leading axes: treat leaf as
        # sharded over nothing except what pjit already did — simplest
        # correct contract: replicate within shard_map body.
        fn = shard_map(
            inner,
            mesh=mesh,
            in_specs=(P(), P()),
            out_specs=(P(), P()),
            check_rep=False,
        )
        return fn(g, err)

    flat_g, td = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err_state.err)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = td.unflatten([o[0] for o in outs])
    new_e = td.unflatten([o[1] for o in outs])
    return new_g, ErrorFeedbackState(new_e)
