"""optim substrate."""
