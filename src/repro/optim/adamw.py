"""AdamW with fully-sharded states, global-norm clipping and schedules.

Built from scratch (no optax in this environment).  Optimizer state is a
pytree congruent with params, so the same sharding rules apply — the
FSDP axis shards both moments (the dominant memory term at 398B params;
see EXPERIMENTS.md §Dry-run).

Optional int8 second-moment quantization (``quantize_moments=True``)
halves optimizer memory — one of the knobs that decides whether
jamba-398B training fits a single v5e pod (it does not; §Dry-run) or
needs the multi-pod mesh (it does).
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from functools import partial
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array            # () int32
    mu: dict                   # first moment  (params dtype or f32)
    nu: dict                   # second moment (f32 or int8-quantized)
    nu_scale: Optional[dict]   # per-leaf scales when quantized


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    quantize_moments: bool = False


def lr_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup → cosine decay to min_lr_frac·lr."""
    s = step.astype(jnp.float32)
    warm = s / max(cfg.warmup_steps, 1)
    prog = jnp.clip(
        (s - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog)
    )
    return cfg.lr * jnp.where(s < cfg.warmup_steps, warm, cos)


# -- int8 moment quantization (per-leaf absmax) ------------------------------


def _quant(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequant(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def init(params: dict, cfg: AdamWConfig) -> AdamWState:
    mu = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    if cfg.quantize_moments:
        nu = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.int8), params)
        scale = jax.tree.map(lambda p: jnp.zeros((), jnp.float32), params)
    else:
        nu = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
        scale = None
    return AdamWState(jnp.zeros((), jnp.int32), mu, nu, scale)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
            for x in jax.tree.leaves(tree))
    )


def apply(
    params: dict,
    grads: dict,
    state: AdamWState,
    cfg: AdamWConfig,
) -> tuple[dict, AdamWState, dict]:
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v, vs):
        g = g.astype(jnp.float32) * clip
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v_f = _dequant(v, vs) if cfg.quantize_moments else v
        v_f = cfg.b2 * v_f + (1 - cfg.b2) * g * g
        upd_ = (m / b1c) / (jnp.sqrt(v_f / b2c) + cfg.eps)
        upd_ = upd_ + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * upd_).astype(p.dtype)
        if cfg.quantize_moments:
            vq, vs_new = _quant(v_f)
            return new_p, m, vq, vs_new
        return new_p, m, v_f, jnp.zeros((), jnp.float32)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.mu)
    flat_v = jax.tree.leaves(state.nu)
    flat_vs = (
        jax.tree.leaves(state.nu_scale)
        if cfg.quantize_moments
        else [jnp.zeros((), jnp.float32)] * len(flat_p)
    )
    out = [upd(*args) for args in zip(flat_p, flat_g, flat_m, flat_v, flat_vs)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    new_vs = treedef.unflatten([o[3] for o in out]) if cfg.quantize_moments else None
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(step, new_m, new_v, new_vs), metrics
