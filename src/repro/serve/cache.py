"""Artifact LRU for the serving runtime.

A server fronting many models cannot afford a balanced-DP solve per
request; it also cannot pin every (model, target, options) artifact
forever.  :class:`ArtifactCache` is the standard answer: an LRU of
:class:`~repro.api.artifact.CompiledArtifact`\\ s keyed by the model
name plus :meth:`CompileOptions.cache_key()
<repro.core.compile_driver.CompileOptions.cache_key>` — the same
stable digest the ``REPRO_BENCH_CACHE`` disk cache uses — so two
option bundles that compile identically share an entry and two that
differ never collide.
"""
from __future__ import annotations

import collections
import threading
from typing import Optional

from repro import instrument
from repro.core.compile_driver import CompileOptions


class ArtifactCache:
    """Bounded LRU of compiled artifacts, keyed
    ``(name, options.cache_key())``.

    ``get_or_compile(name, make, options)`` returns the cached artifact
    or compiles one via :func:`repro.api.artifact.compile_graph` on
    ``make()`` (any graph/builder the front door accepts).  Thread-safe;
    hits/misses/evictions accumulate in :attr:`stats` and are mirrored
    to the ambient tracer as an ``artifact_cache`` counter series.
    """

    def __init__(self, capacity: int = 8) -> None:
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._items: "collections.OrderedDict[tuple, object]" = \
            collections.OrderedDict()
        self._lock = threading.Lock()
        self.stats = {"hits": 0, "misses": 0, "evictions": 0}

    def __len__(self) -> int:
        with self._lock:
            return len(self._items)

    def key_for(self, name: str, options: Optional[CompileOptions]) -> tuple:
        options = options or CompileOptions()
        return (name, options.cache_key())

    def get(self, name: str, options: Optional[CompileOptions] = None):
        """The cached artifact, or ``None`` — counts as hit/miss."""
        key = self.key_for(name, options)
        with self._lock:
            art = self._items.get(key)
            if art is not None:
                self._items.move_to_end(key)
                self.stats["hits"] += 1
            else:
                self.stats["misses"] += 1
            self._emit_locked()
            return art

    def put(self, name: str, options: Optional[CompileOptions],
            artifact) -> None:
        key = self.key_for(name, options)
        with self._lock:
            if key in self._items:
                self._items.move_to_end(key)
                self._items[key] = artifact
            else:
                while len(self._items) >= self.capacity:  # LRU eviction
                    self._items.popitem(last=False)
                    self.stats["evictions"] += 1
                self._items[key] = artifact
            self._emit_locked()

    def get_or_compile(self, name: str, make,
                       options: Optional[CompileOptions] = None):
        """Cached artifact for ``(name, options)``, compiling (and
        inserting) on miss.  The compile runs outside the lock — two
        racing misses may both compile, last insert wins (artifacts are
        deterministic, so either result is correct)."""
        from repro.api.artifact import compile_graph

        art = self.get(name, options)
        if art is not None:
            return art
        graph = make() if callable(make) else make
        art = compile_graph(graph, options=options or CompileOptions())
        self.put(name, options, art)
        return art

    def _emit_locked(self) -> None:
        tracer = instrument.current()
        if tracer.enabled:
            tracer.counter("artifact_cache", dict(self.stats))
