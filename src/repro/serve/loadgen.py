"""Open-loop load generator + latency report for the serve engine.

Open-loop means arrivals follow the offered schedule regardless of how
the server is doing — the methodology that actually exposes queueing
collapse (a closed loop self-throttles and flatters p99).  Arrival
times are deterministic under ``seed`` (uniform spacing at the offered
QPS); inputs are seeded small-integer tensors matching the artifact's
compiled input shapes, same value model as
:func:`repro.passes.interp.random_env`.

Saturation is data, not a crash: when admission rejects an arrival
(:class:`queue.Full`) the generator records the rejection and keeps to
its schedule — rejected arrivals are *excluded* from the latency
distribution (they have no completion) but counted in the report, so an
overloaded run reads as "p99 exploded, rejects nonzero" instead of a
stack trace.  Counters come from the engine's metrics registry when it
is enabled (the ``serve_*_total`` series), falling back to the legacy
``engine.stats`` snapshot otherwise.
"""
from __future__ import annotations

import dataclasses
import queue as queue_mod
import time
from typing import Optional

import numpy as np


def _percentile(sorted_ms: list, q: float) -> float:
    """Nearest-rank percentile on an already-sorted list."""
    if not sorted_ms:
        return 0.0
    idx = min(len(sorted_ms) - 1, max(0, int(round(
        q / 100.0 * (len(sorted_ms) - 1)))))
    return sorted_ms[idx]


@dataclasses.dataclass
class LoadReport:
    """One load level's outcome — a row of ``BENCH_serve.json``.

    ``requests`` counts *served* requests; ``rejected`` the arrivals
    admission turned away (their latencies are not in the
    distribution)."""

    offered_qps: float
    achieved_qps: float
    requests: int
    duration_s: float
    p50_ms: float
    p99_ms: float
    mean_ms: float
    mean_batch: float
    batches: int
    rejected: int

    def row(self) -> dict:
        return {
            "offered_qps": round(self.offered_qps, 3),
            "achieved_qps": round(self.achieved_qps, 3),
            "requests": self.requests,
            "duration_s": round(self.duration_s, 4),
            "p50_ms": round(self.p50_ms, 3),
            "p99_ms": round(self.p99_ms, 3),
            "mean_ms": round(self.mean_ms, 3),
            "mean_batch": round(self.mean_batch, 3),
            "batches": self.batches,
            "rejected": self.rejected,
        }


def _counter_deltas(engine):
    """Start-of-run counter baseline: registry series when enabled
    (one aggregation path, satellite of the metrics registry), legacy
    stats snapshot otherwise.  Returns a closure producing
    ``(batches, rejected)`` deltas."""
    reg = getattr(engine, "registry", None)
    if reg is not None and reg.enabled:
        c_batches = reg.counter("serve_batches_total")
        c_rejected = reg.counter("serve_rejected_total", labels=("cause",))
        b0, r0 = c_batches.value(), c_rejected.total()
        return lambda: (int(c_batches.value() - b0),
                        int(c_rejected.total() - r0))
    stats0 = engine.stats
    return lambda: (engine.stats["batches"] - stats0["batches"],
                    engine.stats["rejected"] - stats0["rejected"])


def run_load(engine, *, offered_qps: float, requests: int,
             seed: int = 0, inputs: Optional[list] = None) -> LoadReport:
    """Drive ``engine`` with ``requests`` arrivals at ``offered_qps``
    (uniform spacing, open-loop: the generator sleeps to each arrival
    time and never waits on results mid-run).  Returns the latency
    report; per-request latency is completion minus *intended* arrival,
    so generator scheduling jitter does not flatter the server.
    """
    if offered_qps <= 0:
        raise ValueError(f"offered_qps must be > 0, got {offered_qps}")
    if requests < 1:
        raise ValueError(f"requests must be >= 1, got {requests}")
    src = engine.artifact.source
    rng = np.random.default_rng(seed)
    if inputs is None:
        inputs = []
        for _ in range(min(requests, 16)):  # rotate a small input pool
            inputs.append({
                k: rng.integers(-4, 5, size=src.values[k].shape,
                                dtype=np.int32)
                for k in src.graph_inputs
            })
    gap = 1.0 / offered_qps
    deltas = _counter_deltas(engine)
    done_at: list = [None] * requests
    futures = []
    rejected_local = 0
    t_start = time.perf_counter()
    for i in range(requests):
        arrival = t_start + i * gap
        delay = arrival - time.perf_counter()
        if delay > 0:
            time.sleep(delay)
        try:
            fut = engine.submit(inputs[i % len(inputs)])
        except queue_mod.Full:
            # saturation: admission said no — record it and hold the
            # open-loop schedule (do NOT retry; that would close the loop)
            rejected_local += 1
            continue

        def _stamp(f, i=i):
            done_at[i] = time.perf_counter()

        fut.add_done_callback(_stamp)
        futures.append((arrival, i, fut))
    for _, _, fut in futures:
        fut.result()  # surface worker exceptions loudly
    t_end = time.perf_counter()
    lat_ms = sorted(
        (done_at[i] - arrival) * 1e3 for arrival, i, _ in futures
    )
    duration = t_end - t_start
    served = len(futures)
    batches, rejected_counted = deltas()
    return LoadReport(
        offered_qps=offered_qps,
        achieved_qps=served / duration if duration > 0 else 0.0,
        requests=served,
        duration_s=duration,
        p50_ms=_percentile(lat_ms, 50),
        p99_ms=_percentile(lat_ms, 99),
        mean_ms=sum(lat_ms) / len(lat_ms) if lat_ms else 0.0,
        mean_batch=served / batches if batches else 0.0,
        batches=batches,
        rejected=max(rejected_counted, rejected_local),
    )
