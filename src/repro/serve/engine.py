"""Dynamic-batching serve engine over one compiled artifact.

Requests enqueue per-sample inputs; a single worker thread drains the
queue into batches — up to :attr:`ServeConfig.max_batch` requests, or
whatever arrived before the *latency budget* measured from the first
queued request expires — and executes each batch as **one** vmapped
device dispatch per group (``CompiledArtifact.run(...,
batch_mode="vmap")``).  Under light load a request ships almost alone
(latency ≈ budget + one-sample execute); under heavy load batches fill
to ``max_batch`` and throughput rides the batched executables.  This is
the classic dynamic-batching contract (hls4ml's deployment benches,
Venieris' toolflow survey) on top of our bucketed jit cache: batch
sizes land on :data:`repro.kernels.ops.BATCH_BUCKETS`, so steady-state
traffic never recompiles.

Observability hangs off the PR 6 tracer: ``serve_batch`` /
``serve_latency_ms`` / ``serve_qps`` counter series plus a
``serve:batch`` span per dispatch, in the *same* trace as the compile
spans.  Contextvars do not cross threads, so the worker re-installs the
engine's tracer explicitly (:func:`repro.instrument.use_tracer`).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from concurrent.futures import Future
from typing import Mapping, Optional

import numpy as np

from repro import instrument


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Knobs of the dynamic batcher.

    ``max_batch`` caps the per-dispatch batch (keep it on a
    :data:`~repro.kernels.ops.BATCH_BUCKETS` bucket or the runner pads
    up to the next one); ``latency_budget_ms`` is how long the first
    request of a forming batch may wait for company; ``queue_depth``
    bounds admission — a full queue rejects instead of hiding unbounded
    latency."""

    max_batch: int = 32
    latency_budget_ms: float = 5.0
    queue_depth: int = 1024

    def __post_init__(self) -> None:
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")
        if self.latency_budget_ms < 0:
            raise ValueError("latency_budget_ms must be >= 0, got "
                             f"{self.latency_budget_ms}")
        if self.queue_depth < 1:
            raise ValueError(
                f"queue_depth must be >= 1, got {self.queue_depth}")


@dataclasses.dataclass
class _Request:
    inputs: dict
    future: Future
    t_submit: float


_STOP = object()


class ServeEngine:
    """Serve one :class:`~repro.api.artifact.CompiledArtifact`.

    Use as a context manager (or ``start()``/``stop()``)::

        with ServeEngine(artifact, ServeConfig(max_batch=32)) as eng:
            fut = eng.submit(x)          # per-sample input, no batch dim
            y = fut.result()

    ``submit`` returns a :class:`concurrent.futures.Future`;
    ``__call__`` is the blocking sugar.  ``params`` fixes the constant
    bindings (weights) for every request of this engine — serving mixes
    *inputs*, never weights.
    """

    def __init__(self, artifact, config: Optional[ServeConfig] = None, *,
                 params: Optional[Mapping] = None,
                 interpret: Optional[bool] = None, seed: int = 0) -> None:
        self.artifact = artifact
        self.config = config or ServeConfig()
        self.params = params
        self.interpret = interpret
        self.seed = seed
        self._queue: "queue.Queue" = queue.Queue(self.config.queue_depth)
        self._worker: Optional[threading.Thread] = None
        self._stopping = False
        self._tracer = None
        self.stats = {"requests": 0, "batches": 0, "rejected": 0,
                      "max_batch_seen": 0}
        self._t_start: Optional[float] = None

    # -- lifecycle -----------------------------------------------------------

    def start(self) -> "ServeEngine":
        if self._worker is not None:
            raise RuntimeError(
                f"{self.artifact.source.name}: engine already started"
            )
        # capture the tracer on the *caller's* context: the ambient one
        # if enabled (same trace as everything else this thread did),
        # else the artifact's compile-time tracer.  The worker thread
        # re-installs it — contextvars do not propagate into threads.
        ambient = instrument.current()
        self._tracer = ambient if ambient.enabled else self.artifact.tracer
        # resolve constants once: user params + seeded fill for the
        # rest — re-deriving random_env per batch would put RNG work on
        # the hot path (and is why this isn't left to artifact.run)
        from repro.passes import interp

        src = self.artifact.source
        resolved = dict(self.params or {})
        consts = {n for n, v in src.values.items() if v.is_constant}
        missing = consts - set(resolved)
        if missing:
            env = interp.random_env(src, seed=self.seed)
            resolved.update({n: env[n] for n in missing})
        self._params_resolved = resolved
        self._t_start = time.perf_counter()
        self._stopping = False
        self._worker = threading.Thread(
            target=self._serve_loop, name="repro-serve", daemon=True
        )
        self._worker.start()
        return self

    def stop(self) -> None:
        """Stop the worker, then *drain* the queue: any request still
        queued (admitted behind the stop signal, or racing shutdown)
        fails its future with :class:`RuntimeError` instead of leaving
        the caller blocked on ``fut.result()`` forever."""
        if self._worker is None:
            return
        self._stopping = True  # new submits reject from here on
        self._queue.put(_STOP)
        self._worker.join()
        self._worker = None
        while True:
            try:
                item = self._queue.get_nowait()
            except queue.Empty:
                break
            if item is _STOP:
                continue
            self.stats["rejected"] += 1
            item.future.set_exception(RuntimeError(
                f"{self.artifact.source.name}: engine stopped before the "
                "request was served"
            ))

    def __enter__(self) -> "ServeEngine":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- request path --------------------------------------------------------

    def submit(self, inputs) -> Future:
        """Enqueue one sample (bare array, or ``{name: array}`` for
        multi-input graphs — per-sample shapes, no batch dim).  Keys
        and per-sample shapes are validated *here*, at admission: a
        malformed request must reject its own caller, never poison the
        innocent requests it would have co-batched with at
        ``np.stack`` time.  Raises :class:`queue.Full` when admission
        is over ``queue_depth``."""
        if self._worker is None or self._stopping:
            raise RuntimeError(
                f"{self.artifact.source.name}: engine not started — "
                "use `with engine:`"
            )
        src = self.artifact.source
        if not isinstance(inputs, Mapping):
            if len(src.graph_inputs) != 1:
                raise ValueError(
                    f"{src.name} has {len(src.graph_inputs)} inputs "
                    f"({src.graph_inputs}); pass a dict, not a bare array"
                )
            inputs = {src.graph_inputs[0]: inputs}
        missing = set(src.graph_inputs) - set(inputs)
        unknown = set(inputs) - set(src.graph_inputs)
        if missing or unknown:
            raise ValueError(
                f"{src.name}: request must bind exactly the graph inputs "
                f"{list(src.graph_inputs)}"
                + (f" — missing {sorted(missing)}" if missing else "")
                + (f" — unknown {sorted(unknown)}" if unknown else "")
            )
        arrays = {}
        for k in src.graph_inputs:
            v = np.asarray(inputs[k])
            want = tuple(src.values[k].shape)
            if v.shape != want:
                raise ValueError(
                    f"{src.name}: input {k!r} has shape {v.shape}; "
                    f"expected the per-sample shape {want} (no batch dim)"
                )
            arrays[k] = v
        req = _Request(arrays, Future(), time.perf_counter())
        try:
            self._queue.put_nowait(req)
        except queue.Full:
            self.stats["rejected"] += 1
            raise queue.Full(
                f"{src.name}: admission queue full "
                f"(queue_depth={self.config.queue_depth})"
            ) from None
        return req.future

    def __call__(self, inputs):
        return self.submit(inputs).result()

    # -- worker --------------------------------------------------------------

    def _serve_loop(self) -> None:
        with instrument.use_tracer(self._tracer):
            tracer = instrument.current()
            while True:
                item = self._queue.get()
                if item is _STOP:
                    return
                batch = [item]
                deadline = (time.perf_counter()
                            + self.config.latency_budget_ms / 1e3)
                while len(batch) < self.config.max_batch:
                    wait = deadline - time.perf_counter()
                    if wait <= 0:
                        # budget spent: take whatever already queued,
                        # but don't wait for more
                        try:
                            nxt = self._queue.get_nowait()
                        except queue.Empty:
                            break
                    else:
                        try:
                            nxt = self._queue.get(timeout=wait)
                        except queue.Empty:
                            break
                    if nxt is _STOP:
                        self._execute(batch, tracer)
                        return
                    batch.append(nxt)
                self._execute(batch, tracer)

    def _execute(self, batch: list, tracer) -> None:
        src = self.artifact.source
        n = len(batch)
        t0 = time.perf_counter()
        try:
            stacked = {
                k: np.stack([r.inputs[k] for r in batch])
                for k in src.graph_inputs
            }
            with tracer.span("serve:batch", cat="serve",
                             args={"batch": n}):
                out = self.artifact.run(
                    stacked, self._params_resolved,
                    interpret=self.interpret, seed=self.seed,
                )
            if len(src.graph_outputs) == 1:
                rows = [out[i] for i in range(n)]
            else:
                rows = [{k: v[i] for k, v in out.items()} for i in range(n)]
        except Exception as exc:  # propagate to every caller, keep serving
            for r in batch:
                r.future.set_exception(exc)
            return
        t1 = time.perf_counter()
        self.stats["requests"] += n
        self.stats["batches"] += 1
        self.stats["max_batch_seen"] = max(self.stats["max_batch_seen"], n)
        if tracer.enabled:
            tracer.counter("serve_batch", {"size": n})
            for r in batch:
                tracer.counter(
                    "serve_latency_ms", {"ms": (t1 - r.t_submit) * 1e3}
                )
            elapsed = t1 - (self._t_start or t1)
            if elapsed > 0:
                tracer.counter(
                    "serve_qps", {"qps": self.stats["requests"] / elapsed}
                )
        for r in batch:
            r.future.set_result(rows.pop(0))
