"""Dynamic-batching serve engine over one compiled artifact.

Requests enqueue per-sample inputs; a single worker thread drains the
queue into batches — up to :attr:`ServeConfig.max_batch` requests, or
whatever arrived before the *latency budget* measured from the first
queued request expires — and executes each batch as **one** vmapped
device dispatch per group (``CompiledArtifact.run(...,
batch_mode="vmap")``).  Under light load a request ships almost alone
(latency ≈ budget + one-sample execute); under heavy load batches fill
to ``max_batch`` and throughput rides the batched executables.  This is
the classic dynamic-batching contract (hls4ml's deployment benches,
Venieris' toolflow survey) on top of our bucketed jit cache: batch
sizes land on :data:`repro.kernels.ops.BATCH_BUCKETS`, so steady-state
traffic never recompiles.

Observability is two-layered.  The PR 6 tracer still gets its post-hoc
series (``serve_batch`` / ``serve_latency_ms`` / ``serve_qps`` plus a
``serve:batch`` span per dispatch, in the *same* trace as the compile
spans).  Live aggregates go to a
:class:`repro.instrument.MetricsRegistry`: every request carries an id
and moves through four lifecycle stages — **queue-wait** (submit →
worker dequeue), **batch-form** (dequeue → batch sealed), **execute**
(stack + device dispatch), **respond** (future fan-out) — each recorded
as a ``serve_stage_ms{stage=...}`` histogram, alongside queue-depth and
in-flight gauges, a batch-occupancy histogram, and rejection counters
by cause.  A bounded flight recorder keeps the last N batch records for
post-mortems (:meth:`ServeEngine.flight_records`).  Pass
``registry=NULL_REGISTRY`` to switch all of it off; outputs are
byte-identical either way (pinned by ``tests/test_metrics.py``).
Contextvars do not cross threads, so the worker re-installs the
engine's tracer explicitly (:func:`repro.instrument.use_tracer`).
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
import queue
import threading
import time
from concurrent.futures import Future
from typing import Mapping, Optional

import numpy as np

from repro import instrument
from repro.instrument import metrics as metrics_mod


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Knobs of the dynamic batcher.

    ``max_batch`` caps the per-dispatch batch (keep it on a
    :data:`~repro.kernels.ops.BATCH_BUCKETS` bucket or the runner pads
    up to the next one); ``latency_budget_ms`` is how long the first
    request of a forming batch may wait for company; ``queue_depth``
    bounds admission — a full queue rejects instead of hiding unbounded
    latency; ``flight_records`` bounds the post-mortem ring of recent
    batch records (0 disables it)."""

    max_batch: int = 32
    latency_budget_ms: float = 5.0
    queue_depth: int = 1024
    flight_records: int = 64

    def __post_init__(self) -> None:
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")
        if self.latency_budget_ms < 0:
            raise ValueError("latency_budget_ms must be >= 0, got "
                             f"{self.latency_budget_ms}")
        if self.queue_depth < 1:
            raise ValueError(
                f"queue_depth must be >= 1, got {self.queue_depth}")
        if self.flight_records < 0:
            raise ValueError(
                f"flight_records must be >= 0, got {self.flight_records}")


@dataclasses.dataclass
class _Request:
    req_id: int
    inputs: dict
    future: Future
    t_submit: float


_STOP = object()


class ServeEngine:
    """Serve one :class:`~repro.api.artifact.CompiledArtifact`.

    Use as a context manager (or ``start()``/``stop()``)::

        with ServeEngine(artifact, ServeConfig(max_batch=32)) as eng:
            fut = eng.submit(x)          # per-sample input, no batch dim
            y = fut.result()

    ``submit`` returns a :class:`concurrent.futures.Future`;
    ``__call__`` is the blocking sugar.  ``params`` fixes the constant
    bindings (weights) for every request of this engine — serving mixes
    *inputs*, never weights.

    ``registry`` is the engine's metrics home: by default each engine
    owns a fresh :class:`~repro.instrument.MetricsRegistry` (so
    :meth:`metrics` always has something to say); pass
    :data:`~repro.instrument.NULL_REGISTRY` to disable instrumentation
    entirely, or share one registry across engines to aggregate.
    """

    def __init__(self, artifact, config: Optional[ServeConfig] = None, *,
                 params: Optional[Mapping] = None,
                 interpret: Optional[bool] = None, seed: int = 0,
                 registry=None) -> None:
        self.artifact = artifact
        self.config = config or ServeConfig()
        self.params = params
        self.interpret = interpret
        self.seed = seed
        self.registry = (metrics_mod.MetricsRegistry()
                         if registry is None else registry)
        self._queue: "queue.Queue" = queue.Queue(self.config.queue_depth)
        self._worker: Optional[threading.Thread] = None
        self._stopping = False
        self._tracer = None
        # the worker thread mutates these while callers read them (the
        # load generator diffs before/after): one lock guards the dict,
        # the public `stats` property hands out snapshots
        self._stats_lock = threading.Lock()
        self._stats = {"requests": 0, "batches": 0, "rejected": 0,
                       "max_batch_seen": 0}
        self._req_ids = itertools.count()
        self._flight: "collections.deque" = collections.deque(
            maxlen=self.config.flight_records or None
        )
        self._t_start: Optional[float] = None
        self._declare_metrics()

    def _declare_metrics(self) -> None:
        """Declare the serve series once, up front — a snapshot taken
        before any traffic still lists every family (empty families are
        how dashboards learn the schema)."""
        reg = self.registry
        self._m_requests = reg.counter(
            "serve_requests_total", "requests admitted")
        self._m_batches = reg.counter(
            "serve_batches_total", "batches dispatched")
        self._m_rejected = reg.counter(
            "serve_rejected_total", "requests rejected by cause",
            labels=("cause",))
        self._m_queue_depth = reg.gauge(
            "serve_queue_depth", "requests waiting for a batch")
        self._m_inflight = reg.gauge(
            "serve_inflight_batches", "batches currently executing")
        self._m_stage_ms = reg.histogram(
            "serve_stage_ms", "per-request lifecycle stage latency (ms)",
            labels=("stage",))
        self._m_latency_ms = reg.histogram(
            "serve_request_latency_ms",
            "submit-to-response latency (ms)")
        self._m_occupancy = reg.histogram(
            "serve_batch_occupancy", "requests per dispatched batch",
            buckets=metrics_mod.BATCH_BUCKETS_SIZES)

    # -- lifecycle -----------------------------------------------------------

    def start(self) -> "ServeEngine":
        if self._worker is not None:
            raise RuntimeError(
                f"{self.artifact.source.name}: engine already started"
            )
        # capture the tracer on the *caller's* context: the ambient one
        # if enabled (same trace as everything else this thread did),
        # else the artifact's compile-time tracer.  The worker thread
        # re-installs it — contextvars do not propagate into threads.
        ambient = instrument.current()
        self._tracer = ambient if ambient.enabled else self.artifact.tracer
        # resolve constants once: user params + seeded fill for the
        # rest — re-deriving random_env per batch would put RNG work on
        # the hot path (and is why this isn't left to artifact.run)
        from repro.passes import interp

        src = self.artifact.source
        resolved = dict(self.params or {})
        consts = {n for n, v in src.values.items() if v.is_constant}
        missing = consts - set(resolved)
        if missing:
            env = interp.random_env(src, seed=self.seed)
            resolved.update({n: env[n] for n in missing})
        self._params_resolved = resolved
        self._t_start = time.perf_counter()
        self._stopping = False
        self._worker = threading.Thread(
            target=self._serve_loop, name="repro-serve", daemon=True
        )
        self._worker.start()
        return self

    def stop(self) -> None:
        """Stop the worker, then *drain* the queue: any request still
        queued (admitted behind the stop signal, or racing shutdown)
        fails its future with :class:`RuntimeError` instead of leaving
        the caller blocked on ``fut.result()`` forever."""
        if self._worker is None:
            return
        self._stopping = True  # new submits reject from here on
        self._queue.put(_STOP)
        self._worker.join()
        self._worker = None
        while True:
            try:
                item = self._queue.get_nowait()
            except queue.Empty:
                break
            if item is _STOP:
                continue
            self._bump("rejected")
            if self.registry.enabled:
                self._m_rejected.inc(cause="shutdown")
                self._m_queue_depth.dec()
            item.future.set_exception(RuntimeError(
                f"{self.artifact.source.name}: engine stopped before the "
                "request was served"
            ))

    def __enter__(self) -> "ServeEngine":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- stats & metrics -----------------------------------------------------

    @property
    def stats(self) -> dict:
        """A point-in-time copy of the legacy counters dict
        (``requests`` / ``batches`` / ``rejected`` /
        ``max_batch_seen``).  A *copy*: the worker keeps mutating the
        backing dict under its lock, so callers never see a torn read —
        and writes to the returned dict change nothing."""
        with self._stats_lock:
            return dict(self._stats)

    def _bump(self, key: str, n: int = 1) -> None:
        with self._stats_lock:
            self._stats[key] += n

    def metrics(self) -> dict:
        """The engine registry's :meth:`snapshot` document (empty but
        schema-valid when the engine runs with ``NULL_REGISTRY``)."""
        return self.registry.snapshot()

    def flight_records(self) -> list:
        """The last N batch records, oldest first: per-batch dicts of
        ``{"batch_id", "request_ids", "n", "outcome",
        "queue_wait_ms", "batch_form_ms", "execute_ms", "respond_ms"}``
        (stage times in milliseconds; queue-wait is the mean over the
        batch's requests).  Bounded by
        :attr:`ServeConfig.flight_records`."""
        return list(self._flight)

    # -- request path --------------------------------------------------------

    def submit(self, inputs) -> Future:
        """Enqueue one sample (bare array, or ``{name: array}`` for
        multi-input graphs — per-sample shapes, no batch dim).  Keys
        and per-sample shapes are validated *here*, at admission: a
        malformed request must reject its own caller, never poison the
        innocent requests it would have co-batched with at
        ``np.stack`` time.  Raises :class:`queue.Full` when admission
        is over ``queue_depth``."""
        if self._worker is None or self._stopping:
            raise RuntimeError(
                f"{self.artifact.source.name}: engine not started — "
                "use `with engine:`"
            )
        src = self.artifact.source
        try:
            if not isinstance(inputs, Mapping):
                if len(src.graph_inputs) != 1:
                    raise ValueError(
                        f"{src.name} has {len(src.graph_inputs)} inputs "
                        f"({src.graph_inputs}); pass a dict, not a bare "
                        "array"
                    )
                inputs = {src.graph_inputs[0]: inputs}
            missing = set(src.graph_inputs) - set(inputs)
            unknown = set(inputs) - set(src.graph_inputs)
            if missing or unknown:
                raise ValueError(
                    f"{src.name}: request must bind exactly the graph "
                    f"inputs {list(src.graph_inputs)}"
                    + (f" — missing {sorted(missing)}" if missing else "")
                    + (f" — unknown {sorted(unknown)}" if unknown else "")
                )
            arrays = {}
            for k in src.graph_inputs:
                v = np.asarray(inputs[k])
                want = tuple(src.values[k].shape)
                if v.shape != want:
                    raise ValueError(
                        f"{src.name}: input {k!r} has shape {v.shape}; "
                        f"expected the per-sample shape {want} "
                        "(no batch dim)"
                    )
                arrays[k] = v
        except ValueError:
            if self.registry.enabled:
                self._m_rejected.inc(cause="invalid")
            raise
        req = _Request(next(self._req_ids), arrays, Future(),
                       time.perf_counter())
        try:
            self._queue.put_nowait(req)
        except queue.Full:
            self._bump("rejected")
            if self.registry.enabled:
                self._m_rejected.inc(cause="queue_full")
            raise queue.Full(
                f"{src.name}: admission queue full "
                f"(queue_depth={self.config.queue_depth})"
            ) from None
        if self.registry.enabled:
            self._m_queue_depth.inc()
        return req.future

    def __call__(self, inputs):
        return self.submit(inputs).result()

    # -- worker --------------------------------------------------------------

    def _serve_loop(self) -> None:
        with instrument.use_tracer(self._tracer):
            tracer = instrument.current()
            while True:
                item = self._queue.get()
                if item is _STOP:
                    return
                t_dequeue = time.perf_counter()
                batch = [item]
                deadline = t_dequeue + self.config.latency_budget_ms / 1e3
                while len(batch) < self.config.max_batch:
                    wait = deadline - time.perf_counter()
                    if wait <= 0:
                        # budget spent: take whatever already queued,
                        # but don't wait for more
                        try:
                            nxt = self._queue.get_nowait()
                        except queue.Empty:
                            break
                    else:
                        try:
                            nxt = self._queue.get(timeout=wait)
                        except queue.Empty:
                            break
                    if nxt is _STOP:
                        self._execute(batch, tracer, t_dequeue)
                        return
                    batch.append(nxt)
                self._execute(batch, tracer, t_dequeue)

    def _execute(self, batch: list, tracer, t_dequeue: float) -> None:
        src = self.artifact.source
        reg = self.registry
        n = len(batch)
        t_sealed = time.perf_counter()
        if reg.enabled:
            self._m_queue_depth.dec(n)
            self._m_inflight.inc()
            self._m_occupancy.observe(n)
        outcome = "ok"
        try:
            stacked = {
                k: np.stack([r.inputs[k] for r in batch])
                for k in src.graph_inputs
            }
            with tracer.span("serve:batch", cat="serve",
                             args={"batch": n}):
                out = self.artifact.run(
                    stacked, self._params_resolved,
                    interpret=self.interpret, seed=self.seed,
                )
            if len(src.graph_outputs) == 1:
                rows = [out[i] for i in range(n)]
            else:
                rows = [{k: v[i] for k, v in out.items()} for i in range(n)]
        except Exception as exc:  # propagate to every caller, keep serving
            outcome = f"error:{type(exc).__name__}"
            t_exec_end = time.perf_counter()
            for r in batch:
                r.future.set_exception(exc)
            self._finish_batch(batch, tracer, t_dequeue, t_sealed,
                               t_exec_end, time.perf_counter(), outcome)
            return
        t_exec_end = time.perf_counter()
        self._bump("requests", n)
        self._bump("batches")
        with self._stats_lock:
            self._stats["max_batch_seen"] = max(
                self._stats["max_batch_seen"], n)
        for r in batch:
            r.future.set_result(rows.pop(0))
        t_respond = time.perf_counter()
        if tracer.enabled:
            tracer.counter("serve_batch", {"size": n})
            for r in batch:
                tracer.counter(
                    "serve_latency_ms",
                    {"ms": (t_exec_end - r.t_submit) * 1e3}
                )
            elapsed = t_exec_end - (self._t_start or t_exec_end)
            if elapsed > 0:
                with self._stats_lock:
                    served = self._stats["requests"]
                tracer.counter("serve_qps", {"qps": served / elapsed})
        self._finish_batch(batch, tracer, t_dequeue, t_sealed,
                           t_exec_end, t_respond, outcome)

    def _finish_batch(self, batch, tracer, t_dequeue, t_sealed,
                      t_exec_end, t_respond, outcome: str) -> None:
        """Record lifecycle metrics + one flight record for a finished
        (served or failed) batch."""
        reg = self.registry
        n = len(batch)
        waits_ms = [(t_dequeue - r.t_submit) * 1e3 for r in batch]
        form_ms = (t_sealed - t_dequeue) * 1e3
        exec_ms = (t_exec_end - t_sealed) * 1e3
        respond_ms = (t_respond - t_exec_end) * 1e3
        if reg.enabled:
            self._m_inflight.dec()
            if outcome == "ok":
                self._m_requests.inc(n)
                self._m_batches.inc()
            else:
                self._m_rejected.inc(n, cause="execute_error")
            for w in waits_ms:
                self._m_stage_ms.observe(w, stage="queue_wait")
            self._m_stage_ms.observe(form_ms, stage="batch_form")
            self._m_stage_ms.observe(exec_ms, stage="execute")
            self._m_stage_ms.observe(respond_ms, stage="respond")
            if outcome == "ok":
                for r in batch:
                    self._m_latency_ms.observe(
                        (t_respond - r.t_submit) * 1e3)
        if self.config.flight_records:
            self._flight.append({
                "batch_id": self.stats["batches"],
                "request_ids": [r.req_id for r in batch],
                "n": n,
                "outcome": outcome,
                "queue_wait_ms": round(sum(waits_ms) / n, 4),
                "batch_form_ms": round(form_ms, 4),
                "execute_ms": round(exec_ms, 4),
                "respond_ms": round(respond_ms, 4),
            })
