"""Serving runtime over compiled artifacts (ISSUE 7).

The seed's LM serving driver (``repro.launch.serve``) resurrected for
the CNN compiler: a request queue with **dynamic batching** under a
configurable latency budget, executing whole batches through the
vmapped group executables of :meth:`CompiledArtifact.run
<repro.api.artifact.CompiledArtifact.run>` (``batch_mode="vmap"``), an
artifact LRU keyed ``(model, CompileOptions.cache_key())``, and an
open-loop load generator for the ``BENCH_serve.json`` trajectory.

Observability is two-layered (ISSUE 10): post-hoc traces still hang
off the PR 6 tracer — counters land in the same Chrome trace as the
compile spans — while *live* aggregates (queue depth, lifecycle-stage
latency histograms, rejection causes, batch occupancy) go to the
engine's :class:`~repro.instrument.MetricsRegistry`
(:meth:`ServeEngine.metrics` / :meth:`ServeEngine.flight_records`).
The registry is the serving layer's one aggregation path: the load
generator and ``benchmarks/serve_bench.py`` consume counter deltas and
snapshots from it rather than diffing ad-hoc stats dicts.
"""
from .cache import ArtifactCache
from .engine import ServeConfig, ServeEngine
from .loadgen import LoadReport, run_load

__all__ = [
    "ArtifactCache",
    "LoadReport",
    "ServeConfig",
    "ServeEngine",
    "run_load",
]
