"""Serving runtime over compiled artifacts (ISSUE 7).

The seed's LM serving driver (``repro.launch.serve``) resurrected for
the CNN compiler: a request queue with **dynamic batching** under a
configurable latency budget, executing whole batches through the
vmapped group executables of :meth:`CompiledArtifact.run
<repro.api.artifact.CompiledArtifact.run>` (``batch_mode="vmap"``), an
artifact LRU keyed ``(model, CompileOptions.cache_key())``, and an
open-loop load generator for the ``BENCH_serve.json`` trajectory.

All QPS/latency/batch-size observability hangs off the PR 6 tracer
(:mod:`repro.instrument`) — counters land in the same Chrome trace as
the compile spans; there is no second telemetry path.
"""
from .cache import ArtifactCache
from .engine import ServeConfig, ServeEngine
from .loadgen import LoadReport, run_load

__all__ = [
    "ArtifactCache",
    "LoadReport",
    "ServeConfig",
    "ServeEngine",
    "run_load",
]
