"""MING reproduction — top-level package.

The public surface lives in :mod:`repro.api` (layer-builder frontend,
``CompileOptions``, ``CompiledArtifact``) and is re-exported here
lazily, so ``import repro`` stays free of heavy imports (jax loads only
when a kernel path actually runs)::

    import repro

    net = repro.Sequential([repro.Conv2D(16), repro.ReLU()],
                           input_shape=(1, 32, 32, 3), name="demo")
    art = repro.compile_graph(net, repro.CompileOptions(target="kv260"))

Subsystems keep their own namespaces: ``repro.core`` (IR, analysis,
streaming, DSE, resource model, emit), ``repro.passes`` (rewrites +
partitioner), ``repro.kernels`` (Pallas kernels + oracles).
"""
from __future__ import annotations

def _api():
    import importlib

    return importlib.import_module("repro.api")


def __getattr__(name: str):
    # forward the public surface lazily (PEP 562); repro.api.__all__ is
    # the single source of truth, so new api exports appear here too
    if name == "api":
        return _api()
    api = _api()
    if name in api.__all__:
        return getattr(api, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(_api().__all__) | {"api"})
