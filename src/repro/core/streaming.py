"""MING streaming transform (paper Sec. IV-B): stream & buffer creation.

Turns a :class:`~repro.core.ir.DFG` into a :class:`StreamingPlan`:

* every inter-node tensor becomes a **stream** (FIFO channel) — the
  intermediate array is *never materialized* (contribution C1);
* sliding-window nodes get a **line buffer** of ``(K-1) lines`` plus a
  ``K×…×K`` window buffer (Sec. IV-B);
* regular-reduction nodes get a single **data-line buffer** (the current
  reduction line), no window buffer;
* pure-parallel nodes get a consume-compute-produce structure with no
  buffer at all.

The plan is consumed by three back-ends:
  1. ``resource_model`` — BRAM/DSP (FPGA) and VMEM/MXU (TPU) estimation,
  2. ``dse``            — the ILP of Eq. (1),
  3. ``emit_hls``       — Vitis-style C++ with pragmas, and
     ``kernels/ops.py`` — Pallas block-shape selection (TPU path).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Optional

from .analysis import (
    IteratorClasses,
    KernelClass,
    KernelInfo,
    classify_kernel,
    reorder_spec,
    window_geometry,
)
from .ir import DFG, GenericOp, IteratorType, Value


# ---------------------------------------------------------------------------
# Plan datatypes
# ---------------------------------------------------------------------------


@dataclass
class StreamEdge:
    """A FIFO channel between two dataflow nodes (or the host boundary).

    ``width`` (number of parallel lanes) is a *DSE variable*: the stream
    constraint of Eq. (1) forces producer and consumer widths equal.  The
    default depth of 2 realizes a double buffer; diamond-shaped graphs
    (residual blocks) get deeper skip-edge FIFOs sized from the
    first-output-cycle estimate (Sec. IV-C, last paragraph).
    """

    name: str
    producer: Optional[str]   # node name, None == host/memory boundary
    consumer: Optional[str]
    elem_bits: int
    width: int = 1
    depth: int = 2

    @property
    def buffer_bits(self) -> int:
        return self.width * self.depth * self.elem_bits


@dataclass
class LoopNest:
    """The loop structure the DSE reasons about for one node.

    ``unrollable`` marks loops eligible for an UNROLL pragma.  The paper's
    cycle estimate is ``II * ceil(total_trip / unroll) + pipeline_depth``
    with II=1 for MING's hazard-free streaming pipelines.
    """

    trip_counts: tuple[int, ...]
    unrollable: tuple[bool, ...]
    pipeline_depth: int = 4

    @property
    def total_trip(self) -> int:
        return math.prod(self.trip_counts) if self.trip_counts else 1


@dataclass
class NodePlan:
    """Streaming realization of one GenericOp."""

    op: GenericOp
    info: KernelInfo
    # -- on-chip buffers (bits) --------------------------------------------
    line_buffer_bits: int = 0       # (K-1) lines of the streamed input
    window_buffer_bits: int = 0     # current compute window (K × … × K)
    const_buffer_bits: int = 0      # weights/biases resident on-chip
    # -- streams -------------------------------------------------------------
    input_streams: list[str] = field(default_factory=list)
    output_streams: list[str] = field(default_factory=list)
    # -- loop nest for the DSE ------------------------------------------------
    loops: LoopNest = field(default_factory=lambda: LoopNest((), ()))
    # loop index whose unroll factor sets the stream width (stream constr.)
    stream_loop: int = 0
    #: loop dims in nest order (``loops.trip_counts[i]`` is the extent of
    #: dim ``loop_dims[i]``) — lets back-ends locate a specific dim
    loop_dims: tuple[int, ...] = ()
    #: parallel non-window dims that index a *constant* input (e.g. c_out
    #: for an NHWC conv's weights) — the axes partial weight streaming
    #: may tile along (``repro.core.dse`` weight_tiles knob)
    weight_tile_dims: tuple[int, ...] = ()

    @property
    def name(self) -> str:
        return self.op.name

    @property
    def kernel_class(self) -> KernelClass:
        return self.info.kernel_class

    @property
    def weight_tileable_extent(self) -> int:
        """Extent of the *leading* weight-tile dim — the one axis every
        backend splits (the emitter's ``WT`` loop divides exactly this
        dim's trip; ``kernels/ops`` slices the const tensor along it),
        so tile counts must divide it, not the product of all tileable
        dims."""
        if not self.weight_tile_dims:
            return 1
        return self.op.dim_extent(self.weight_tile_dims[0])

    def buffer_bits(self) -> int:
        return self.line_buffer_bits + self.window_buffer_bits


@dataclass
class FusionRegion:
    """A maximal producer→consumer chain executed as one pipelined unit.

    FPGA path: one DATAFLOW region (all nodes run as concurrent processes
    connected by hls::stream).  TPU path: one fused Pallas kernel / XLA
    fusion — the intermediates live in VMEM, never HBM.
    """

    name: str
    node_names: list[str]
    internal_streams: list[str]
    boundary_inputs: list[str]
    boundary_outputs: list[str]


@dataclass
class StreamingPlan:
    dfg: DFG
    nodes: dict[str, NodePlan]
    streams: dict[str, StreamEdge]
    regions: list[FusionRegion]

    def node_order(self) -> list[NodePlan]:
        return [self.nodes[n.name] for n in self.dfg.topo_order()]

    def total_buffer_bits(self) -> int:
        return sum(p.buffer_bits() for p in self.nodes.values()) + sum(
            s.buffer_bits for s in self.streams.values()
        )


# ---------------------------------------------------------------------------
# Per-node planning (Sec. IV-B)
# ---------------------------------------------------------------------------


def _streamed_input(op: GenericOp, dfg: DFG) -> tuple[int, Value] | tuple[None, None]:
    """The non-constant input that arrives as a stream (conv activations);
    constants (weights) are held in on-chip ROM/BRAM instead."""
    for i, name in enumerate(op.inputs):
        v = dfg.values[name]
        if not v.is_constant:
            return i, v
    return None, None


def plan_node(op: GenericOp, dfg: DFG) -> NodePlan:
    info = classify_kernel(op)
    plan = NodePlan(op=op, info=info)

    # constants (weights / biases) are kept on-chip for streaming reuse;
    # fused-epilogue operands (bias/scale folded in by repro.passes) live
    # alongside them
    plan.const_buffer_bits = sum(
        dfg.values[i].total_bits for i in op.inputs if dfg.values[i].is_constant
    ) + sum(
        dfg.values[e.operand].total_bits for e in op.epilogue if e.operand
    )

    if info.kernel_class == KernelClass.SLIDING_WINDOW:
        geo = window_geometry(op, info)
        idx, streamed = _streamed_input(op, dfg)
        assert streamed is not None, f"{op.name}: sliding window with no stream input"
        # channel-like reduction dims of the *streamed* input: single-dim
        # reduction subscripts in its map (e.g. c_in for NHWC conv).
        smap = op.input_maps[idx]
        chan = 1
        for expr in smap.results:
            if expr.is_single_dim():
                (d, _), = expr.terms
                if op.is_reduction_dim(d):
                    chan *= op.dim_extent(d)
        # line buffer: (K_outer - 1) lines; a line spans the *input* extent
        # of the innermost window axis times the channel depth.
        if len(geo.window_dims) >= 2:
            k_outer = geo.window_extents[0]
            line_len = geo.input_extents[-1]
            plan.line_buffer_bits = (
                max(k_outer - 1, 0) * line_len * chan * op.elem_bits
            )
        elif len(geo.window_dims) == 1:
            # 1-D sliding window: the "line" degenerates to K-1 elements
            plan.line_buffer_bits = (
                max(geo.window_extents[0] - 1, 0) * chan * op.elem_bits
            )
        # window buffer: K × … × K × chan  (the current dot-product window)
        win_elems = math.prod(geo.window_extents) * chan
        plan.window_buffer_bits = win_elems * op.elem_bits
        # loop nest: parallel dims outermost, window/reduction innermost.
        order = list(info.classes.parallel) + list(info.classes.window) + list(
            info.classes.reduction
        )
        trips = tuple(op.dim_extent(d) for d in order)
        # unrollable: everything but the sliding spatial loops (reordering
        # those breaks the streaming order — the property Sec. IV-B notes
        # polyhedral frameworks cannot preserve).
        unrollable = tuple(
            d not in info.classes.window and op.dim_extent(d) > 1 for d in order
        )
        plan.loops = LoopNest(trips, unrollable)
        plan.stream_loop = _first_unrollable(plan.loops)

    elif info.kernel_class == KernelClass.REGULAR_REDUCTION:
        # "the current data line" buffer: extent of the reduction dims of
        # the streamed input (e.g. the k-vector of a matvec row).
        idx, streamed = _streamed_input(op, dfg)
        line = 1
        if idx is not None:
            for expr in op.input_maps[idx].results:
                for d in expr.dims():
                    if op.is_reduction_dim(d):
                        line *= op.dim_extent(d)
        plan.line_buffer_bits = line * op.elem_bits
        order = list(info.classes.parallel) + list(info.classes.reduction)
        trips = tuple(op.dim_extent(d) for d in order)
        unrollable = tuple(op.dim_extent(d) > 1 for d in order)
        plan.loops = LoopNest(trips, unrollable)
        plan.stream_loop = _first_unrollable(plan.loops)

    else:  # PURE_PARALLEL: consume-compute-produce, no storage at all
        order = list(range(op.n_dims))
        trips = tuple(op.dim_extent(d) for d in order)
        plan.loops = LoopNest(trips, tuple(t > 1 for t in trips), pipeline_depth=2)
        plan.stream_loop = _first_unrollable(plan.loops)
        # a reorder op that changes the stream order (transpose, or a
        # flatten whose linearization is not the arrival order) must
        # buffer the whole tensor before the first out-of-order element
        # can leave — charge it; an in-order flatten is a pure wire.
        spec = reorder_spec(op)
        if spec is not None:
            kind, arg = spec
            in_order = (
                kind == "flatten" and arg == tuple(range(1, op.n_dims))
            )
            if not in_order:
                plan.line_buffer_bits = (
                    dfg.values[op.inputs[0]].total_bits
                )

    plan.loop_dims = tuple(order)

    # dims eligible for partial weight streaming: parallel non-window
    # subscripts of a constant input (c_out for conv weights, n_out for
    # matmul weights) — tiling them splits the const buffer cleanly.
    window = set(info.classes.window)
    tile_dims: set[int] = set()
    for i, name in enumerate(op.inputs):
        if not dfg.values[name].is_constant:
            continue
        for expr in op.input_maps[i].results:
            if expr.is_single_dim():
                (d, _), = expr.terms
                if op.is_parallel_dim(d) and d not in window:
                    tile_dims.add(d)
    plan.weight_tile_dims = tuple(sorted(tile_dims))

    # fused pooling epilogue: one partial line of pooled outputs is kept
    # while the window's leading axis fills (the 2×2 pool's row buffer)
    out_shape = dfg.values[op.output].shape
    for e in op.epilogue:
        if not e.window or not any(f > 1 for f in e.window):
            continue
        first = next(i for i, f in enumerate(e.window) if f > 1)
        line_elems = math.prod(
            out_shape[a] for a in range(first + 1, len(out_shape))
        )
        plan.line_buffer_bits += (e.window[first] - 1) * line_elems * op.elem_bits

    return plan


def _first_unrollable(loops: LoopNest) -> int:
    for i, u in enumerate(loops.unrollable):
        if u:
            return i
    return 0


# ---------------------------------------------------------------------------
# Graph-level planning: streams + fusion regions
# ---------------------------------------------------------------------------


def plan_streams(dfg: DFG) -> StreamingPlan:
    """Build the full streaming plan for a DFG (paper Fig. 4, stages
    "Stream/Buffer creation" + dfg construction)."""
    nodes = {op.name: plan_node(op, dfg) for op in dfg.nodes}
    streams: dict[str, StreamEdge] = {}

    # host boundary streams
    for gi in dfg.graph_inputs:
        v = dfg.values[gi]
        for consumer in dfg.consumers_of(gi):
            s = StreamEdge(
                name=f"s_{gi}_to_{consumer.name}",
                producer=None,
                consumer=consumer.name,
                elem_bits=v.elem_bits,
            )
            streams[s.name] = s
            nodes[consumer.name].input_streams.append(s.name)
    for go in dfg.graph_outputs:
        prod = dfg.producer_of(go)
        if prod is not None:
            v = dfg.values[go]
            s = StreamEdge(
                name=f"s_{prod.name}_to_out",
                producer=prod.name,
                consumer=None,
                elem_bits=v.elem_bits,
            )
            streams[s.name] = s
            nodes[prod.name].output_streams.append(s.name)

    # inter-node streams: one per (producer, consumer) pair — the
    # intermediate tensor itself is never allocated.
    for prod, cons, vname in dfg.edges():
        v = dfg.values[vname]
        s = StreamEdge(
            name=f"s_{prod.name}_to_{cons.name}",
            producer=prod.name,
            consumer=cons.name,
            elem_bits=v.elem_bits,
        )
        streams[s.name] = s
        nodes[prod.name].output_streams.append(s.name)
        nodes[cons.name].input_streams.append(s.name)

    regions = _form_regions(dfg, nodes, streams)
    plan = StreamingPlan(dfg=dfg, nodes=nodes, streams=streams, regions=regions)
    _size_diamond_fifos(plan)
    return plan


def _form_regions(
    dfg: DFG, nodes: dict[str, NodePlan], streams: dict[str, StreamEdge]
) -> list[FusionRegion]:
    """Connected components of the node graph = DATAFLOW regions.

    On the FPGA every component becomes one top-level DATAFLOW pipeline;
    on TPU it is the fusion unit handed to Pallas.
    """
    parent: dict[str, str] = {n: n for n in nodes}

    def find(x: str) -> str:
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    def union(a: str, b: str) -> None:
        ra, rb = find(a), find(b)
        if ra != rb:
            parent[ra] = rb

    for s in streams.values():
        if s.producer and s.consumer:
            union(s.producer, s.consumer)

    comps: dict[str, list[str]] = {}
    order = [op.name for op in dfg.topo_order()]
    for n in order:
        comps.setdefault(find(n), []).append(n)

    regions = []
    for i, (_, members) in enumerate(sorted(comps.items(), key=lambda kv: order.index(kv[1][0]))):
        member_set = set(members)
        internal, b_in, b_out = [], [], []
        for s in streams.values():
            pin = s.producer in member_set
            cin = s.consumer in member_set
            if pin and cin:
                internal.append(s.name)
            elif cin and s.producer is None:
                b_in.append(s.name)
            elif pin and s.consumer is None:
                b_out.append(s.name)
        regions.append(
            FusionRegion(
                name=f"region{i}",
                node_names=members,
                internal_streams=internal,
                boundary_inputs=b_in,
                boundary_outputs=b_out,
            )
        )
    return regions


def fifo_slack(plan: StreamingPlan) -> dict[str, int]:
    """Required skew absorption per internal stream (positive entries
    only): how many cycles earlier this edge's data is ready than the
    consumer's slowest *other* input — the depth a reconvergent skip
    FIFO must provide or the pipeline deadlocks (Sec. IV-C, final
    paragraph).  Derived from the line-buffer geometry via
    :func:`first_output_cycles`.  The one definition shared by the
    sizing pass (:func:`_size_diamond_fifos`) and the stream-skew
    analyzer (``repro.analyze.stream_skew``)."""
    dfg = plan.dfg
    order = [op.name for op in dfg.topo_order()]
    # longest path (in first-output cycles) from any graph input to node n
    dist: dict[str, int] = {n: 0 for n in order}
    for name in order:
        node = plan.nodes[name]
        preds = [
            plan.streams[s].producer
            for s in node.input_streams
            if plan.streams[s].producer is not None
        ]
        base = max((dist[p] for p in preds), default=0)
        dist[name] = base + _first_output_cycles(node)

    slack: dict[str, int] = {}
    for s in plan.streams.values():
        if s.producer is None or s.consumer is None:
            continue
        # slack between when this edge's data is ready and when the
        # consumer's *other* inputs are ready
        consumer = plan.nodes[s.consumer]
        other_ready = 0
        for other in consumer.input_streams:
            o = plan.streams[other]
            if o.name != s.name and o.producer is not None:
                other_ready = max(other_ready, dist[o.producer])
        need = other_ready - dist[s.producer]
        if need > 0:
            slack[s.name] = need
    return slack


def _size_diamond_fifos(plan: StreamingPlan) -> None:
    """FIFO sizing for diamond structures (Sec. IV-C, final paragraph).

    When two paths from a fork re-join (residual blocks), the short path's
    FIFO must absorb the long path's latency-to-first-output, or the
    pipeline deadlocks.  We size the skip FIFO to the sum of
    first-output-cycle estimates along the long path (conservative, as the
    paper notes; FIFOAdvisor-style refinement is future work there too).
    """
    for name, need in fifo_slack(plan).items():
        s = plan.streams[name]
        s.depth = max(s.depth, need)


def first_output_cycles(plan: NodePlan) -> int:
    """Cycles until the node's first output element appears (unroll=1):
    a sliding-window node must fill K−1 line buffers plus one window, a
    regular reduction its reduction trip, a buffering reorder the whole
    tensor.  Public because the stream-skew analyzer reasons about the
    same geometry."""
    return _first_output_cycles(plan)


def _first_output_cycles(plan: NodePlan) -> int:
    op = plan.op
    if plan.kernel_class == KernelClass.SLIDING_WINDOW:
        geo = window_geometry(op, plan.info)
        if len(geo.window_dims) >= 2:
            # must fill K-1 lines plus one window before first output
            fill = (geo.window_extents[0] - 1) * geo.input_extents[-1]
            return fill + math.prod(geo.window_extents)
        return geo.window_extents[0]
    if plan.kernel_class == KernelClass.REGULAR_REDUCTION:
        red = 1
        for d in plan.info.classes.reduction:
            red *= op.dim_extent(d)
        return red
    if plan.line_buffer_bits:
        # a buffering reorder (transpose) emits nothing until the whole
        # tensor has arrived
        return plan.loops.total_trip
    return 1
