"""The paper's evaluation kernel suite (Sec. V-A) as GenericOp DFGs.

Five kernels, matching Table II rows:

* ``conv_relu(N)``        — Conv3×3 + ReLU, input N×N
* ``cascade_conv(N)``     — (Conv3×3+ReLU) × 2
* ``residual_block(N)``   — Conv→ReLU→Conv → (+skip) → ReLU (diamond)
* ``linear()``            — 512×128 @ 128×256
* ``feed_forward()``      — 512×128 @ 128×256 → ReLU → @ 256×128

The paper does not publish channel counts; we fix C_in=3→C_out=16, K=3,
'same' padding — chosen so the *Vanilla* BRAM footprint reproduces the
paper's Table II values (19 blocks @32², ~707 @224²; see
benchmarks/paper_tables.py for the calibration table).  All tensors are
int8 (post-training quantization, Sec. V-A).
"""
from __future__ import annotations

from .ir import (
    DFG,
    GenericOp,
    PayloadKind,
    Value,
    make_conv2d_op,
    make_elementwise_op,
    make_matmul_op,
    make_pool2d_op,
)

INT8 = 8


def _conv(
    dfg: DFG,
    idx: int,
    in_name: str,
    n: int,
    h: int,
    w: int,
    c_in: int,
    c_out: int,
    k: int = 3,
) -> str:
    wname = f"w{idx}"
    oname = f"conv{idx}_out"
    dfg.add_value(Value(wname, (k, k, c_in, c_out), INT8, is_constant=True))
    dfg.add_value(Value(oname, (n, h, w, c_out), INT8))
    dfg.add_node(
        make_conv2d_op(
            f"conv{idx}", in_name, wname, oname,
            n=n, h_out=h, w_out=w, c_out=c_out, kh=k, kw=k, c_in=c_in,
        )
    )
    return oname


def _relu(dfg: DFG, idx: int, in_name: str, shape: tuple[int, ...]) -> str:
    oname = f"relu{idx}_out"
    dfg.add_value(Value(oname, shape, INT8))
    dfg.add_node(
        make_elementwise_op(f"relu{idx}", [in_name], oname, shape, PayloadKind.RELU)
    )
    return oname


def conv_relu(n_size: int = 32, c_in: int = 3, c_out: int = 16) -> DFG:
    dfg = DFG(f"conv_relu_{n_size}")
    shape = (1, n_size, n_size, c_in)
    dfg.add_value(Value("x", shape, INT8))
    dfg.graph_inputs.append("x")
    c1 = _conv(dfg, 0, "x", 1, n_size, n_size, c_in, c_out)
    r1 = _relu(dfg, 0, c1, (1, n_size, n_size, c_out))
    dfg.graph_outputs.append(r1)
    return dfg


def cascade_conv(n_size: int = 32, c_in: int = 3, c_mid: int = 16) -> DFG:
    dfg = DFG(f"cascade_conv_{n_size}")
    dfg.add_value(Value("x", (1, n_size, n_size, c_in), INT8))
    dfg.graph_inputs.append("x")
    c1 = _conv(dfg, 0, "x", 1, n_size, n_size, c_in, c_mid)
    r1 = _relu(dfg, 0, c1, (1, n_size, n_size, c_mid))
    c2 = _conv(dfg, 1, r1, 1, n_size, n_size, c_mid, c_mid)
    r2 = _relu(dfg, 1, c2, (1, n_size, n_size, c_mid))
    dfg.graph_outputs.append(r2)
    return dfg


def residual_block(n_size: int = 32, c: int = 16) -> DFG:
    """Diamond: x → conv0 → relu0 → conv1 → add(x) → relu1.

    Exercises the FIFO-depth sizing for diamond structures (Sec. IV-C)."""
    dfg = DFG(f"residual_block_{n_size}")
    shape = (1, n_size, n_size, c)
    dfg.add_value(Value("x", shape, INT8))
    dfg.graph_inputs.append("x")
    c1 = _conv(dfg, 0, "x", 1, n_size, n_size, c, c)
    r1 = _relu(dfg, 0, c1, shape)
    c2 = _conv(dfg, 1, r1, 1, n_size, n_size, c, c)
    dfg.add_value(Value("add_out", shape, INT8))
    dfg.add_node(
        make_elementwise_op("add_skip", [c2, "x"], "add_out", shape, PayloadKind.ADD)
    )
    r2 = _relu(dfg, 1, "add_out", shape)
    dfg.graph_outputs.append(r2)
    return dfg


def linear(batch: int = 512, d_in: int = 128, d_out: int = 256) -> DFG:
    """'Linear 512x128' (Table II): batch 512, features 128→256."""
    dfg = DFG("linear")
    dfg.add_value(Value("x", (batch, d_in), INT8))
    dfg.add_value(Value("w0", (d_in, d_out), INT8, is_constant=True))
    dfg.add_value(Value("y", (batch, d_out), INT8))
    dfg.graph_inputs.append("x")
    dfg.add_node(
        make_matmul_op("linear0", "x", "w0", "y", m=batch, k=d_in, n_out=d_out)
    )
    dfg.graph_outputs.append("y")
    return dfg


def feed_forward(batch: int = 512, d_in: int = 128, d_hidden: int = 256) -> DFG:
    """Two cascading Linear layers with ReLU (Table II 'Feed Forward')."""
    dfg = DFG("feed_forward")
    dfg.add_value(Value("x", (batch, d_in), INT8))
    dfg.add_value(Value("w0", (d_in, d_hidden), INT8, is_constant=True))
    dfg.add_value(Value("h", (batch, d_hidden), INT8))
    dfg.graph_inputs.append("x")
    dfg.add_node(
        make_matmul_op("linear0", "x", "w0", "h", m=batch, k=d_in, n_out=d_hidden)
    )
    hr = _relu(dfg, 0, "h", (batch, d_hidden))
    dfg.add_value(Value("w1", (d_hidden, d_in), INT8, is_constant=True))
    dfg.add_value(Value("y", (batch, d_in), INT8))
    dfg.add_node(
        make_matmul_op("linear1", hr, "w1", "y", m=batch, k=d_hidden, n_out=d_in)
    )
    dfg.graph_outputs.append("y")
    return dfg


def deep_cascade(n_size: int = 32, c_in: int = 3, c_mid: int = 136,
                 n_layers: int = 4) -> DFG:
    """(Conv3×3+ReLU) × 4 with wide channels — the partitioning showcase.

    ``c_mid=136`` is chosen so that at 224² the whole-graph streaming
    plan *provably* exceeds the KV260 BRAM budget even at unroll=1
    (per-conv weights ≈73 blocks + line buffer ≈27 blocks ⇒ ~3×101+3
    blocks > 288) while every conv fits comfortably on its own — the
    graph only maps via ``repro.passes.partition_layer_groups``.  At 32²
    the line buffers shrink (~5 blocks each) and the whole graph fits.
    """
    dfg = DFG(f"deep_cascade_{n_size}")
    dfg.add_value(Value("x", (1, n_size, n_size, c_in), INT8))
    dfg.graph_inputs.append("x")
    cur, c_prev = "x", c_in
    for i in range(n_layers):
        cur = _conv(dfg, i, cur, 1, n_size, n_size, c_prev, c_mid)
        cur = _relu(dfg, i, cur, (1, n_size, n_size, c_mid))
        c_prev = c_mid
    dfg.graph_outputs.append(cur)
    return dfg


def conv_pool(n_size: int = 32, c_in: int = 3, c_out: int = 16) -> DFG:
    """Conv3×3 + ReLU + MaxPool2×2 (stride 2) — the conv+pool fusion
    showcase: after the pass pipeline the pool rides the conv's epilogue
    as a windowed FusedEpilogue and its process/FIFO disappear."""
    assert n_size % 2 == 0, "pool2x2 needs even spatial extents"
    dfg = DFG(f"conv_pool_{n_size}")
    dfg.add_value(Value("x", (1, n_size, n_size, c_in), INT8))
    dfg.graph_inputs.append("x")
    c1 = _conv(dfg, 0, "x", 1, n_size, n_size, c_in, c_out)
    r1 = _relu(dfg, 0, c1, (1, n_size, n_size, c_out))
    h = n_size // 2
    dfg.add_value(Value("pool0_out", (1, h, h, c_out), INT8))
    dfg.add_node(
        make_pool2d_op(
            "pool0", r1, "pool0_out",
            n=1, h_out=h, w_out=h, c=c_out, kh=2, kw=2, stride=2,
        )
    )
    dfg.graph_outputs.append("pool0_out")
    return dfg


def fat_conv(n_size: int = 16, c: int = 288) -> DFG:
    """Single Conv3×3+ReLU whose weights alone exceed the KV260 BRAM
    budget (3·3·288·288 int8 ≈ 324 RAM18K > 288): no cut can help, so it
    is only schedulable via partial weight streaming — the graph that
    hard-failed with ``PartitionError`` before the weight-tiles knob."""
    dfg = DFG(f"fat_conv_{n_size}")
    dfg.add_value(Value("x", (1, n_size, n_size, c), INT8))
    dfg.graph_inputs.append("x")
    c1 = _conv(dfg, 0, "x", 1, n_size, n_size, c, c)
    r1 = _relu(dfg, 0, c1, (1, n_size, n_size, c))
    dfg.graph_outputs.append(r1)
    return dfg


def fat_cascade(n_size: int = 16, c: int = 288, n_layers: int = 2) -> DFG:
    """(Conv3×3+ReLU) × ``n_layers`` where *every* layer's weights alone
    exceed the KV260 BRAM budget (3·3·288·288 int8 ≈ 324 RAM18K > 288).

    No contiguous slice of this graph fits with resident weights, so the
    partitioner cannot fall back to "cut until everything fits": every
    candidate group needs streamed weight tiles, and the balanced DP
    must price spill boundaries against DRAM tile traffic — the
    cost-aware streaming showcase (ISSUE 3), unreachable through the
    PR 2 single-node rescue."""
    dfg = DFG(f"fat_cascade_{n_size}")
    dfg.add_value(Value("x", (1, n_size, n_size, c), INT8))
    dfg.graph_inputs.append("x")
    cur = "x"
    for i in range(n_layers):
        cur = _conv(dfg, i, cur, 1, n_size, n_size, c, c)
        cur = _relu(dfg, i, cur, (1, n_size, n_size, c))
    dfg.graph_outputs.append(cur)
    return dfg


PAPER_SUITE = {
    "conv_relu_32": lambda: conv_relu(32),
    "conv_relu_224": lambda: conv_relu(224),
    "cascade_conv_32": lambda: cascade_conv(32),
    "cascade_conv_224": lambda: cascade_conv(224),
    "residual_block_32": lambda: residual_block(32),
    "residual_block_224": lambda: residual_block(224),
    "linear": linear,
    "feed_forward": feed_forward,
    "deep_cascade_32": lambda: deep_cascade(32),
    "deep_cascade_224": lambda: deep_cascade(224),
}
