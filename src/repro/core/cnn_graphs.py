"""The paper's evaluation kernel suite (Sec. V-A) as GenericOp DFGs.

Five kernels, matching Table II rows:

* ``conv_relu(N)``        — Conv3×3 + ReLU, input N×N
* ``cascade_conv(N)``     — (Conv3×3+ReLU) × 2
* ``residual_block(N)``   — Conv→ReLU→Conv → (+skip) → ReLU (diamond)
* ``linear()``            — 512×128 @ 128×256
* ``feed_forward()``      — 512×128 @ 128×256 → ReLU → @ 256×128

plus the beyond-paper showcases (``deep_cascade``, ``conv_pool``,
``conv_avgpool``, ``fat_conv``, ``fat_cascade``) the partitioner,
fusion, and weight-streaming work was grown on.

The paper does not publish channel counts; we fix C_in=3→C_out=16, K=3,
'same' padding — chosen so the *Vanilla* BRAM footprint reproduces the
paper's Table II values (19 blocks @32², ~707 @224²; see
benchmarks/paper_tables.py for the calibration table).  All tensors are
int8 (post-training quantization, Sec. V-A).

Since ISSUE 4 every constructor is a thin wrapper over the declarative
layer-builder frontend (:mod:`repro.api.builder`) — the hand-assembled
``Value``/``make_*_op`` bodies are gone, and
``tests/test_frontend.py`` pins that the builder output is
node-for-node identical to the historical hand-built graphs.
"""
from __future__ import annotations

from repro.api.builder import (
    AvgPool,
    Conv2D,
    Dense,
    MaxPool,
    ReLU,
    Residual,
    Sequential,
)
from .ir import DFG

INT8 = 8


def conv_relu(n_size: int = 32, c_in: int = 3, c_out: int = 16) -> DFG:
    return Sequential(
        [Conv2D(c_out), ReLU()],
        input_shape=(1, n_size, n_size, c_in),
        name=f"conv_relu_{n_size}",
    ).build()


def cascade_conv(n_size: int = 32, c_in: int = 3, c_mid: int = 16) -> DFG:
    return Sequential(
        [Conv2D(c_mid), ReLU(), Conv2D(c_mid), ReLU()],
        input_shape=(1, n_size, n_size, c_in),
        name=f"cascade_conv_{n_size}",
    ).build()


def residual_block(n_size: int = 32, c: int = 16) -> DFG:
    """Diamond: x → conv0 → relu0 → conv1 → add(x) → relu1.

    Exercises the FIFO-depth sizing for diamond structures (Sec. IV-C)."""
    return Sequential(
        [
            Residual([Conv2D(c), ReLU(), Conv2D(c)],
                     name="add_skip", out="add_out"),
            ReLU(),
        ],
        input_shape=(1, n_size, n_size, c),
        name=f"residual_block_{n_size}",
    ).build()


def linear(batch: int = 512, d_in: int = 128, d_out: int = 256) -> DFG:
    """'Linear 512x128' (Table II): batch 512, features 128→256."""
    return Sequential(
        [Dense(d_out, out="y")],
        input_shape=(batch, d_in),
        name="linear",
    ).build()


def feed_forward(batch: int = 512, d_in: int = 128, d_hidden: int = 256) -> DFG:
    """Two cascading Linear layers with ReLU (Table II 'Feed Forward')."""
    return Sequential(
        [Dense(d_hidden, out="h"), ReLU(), Dense(d_in, out="y")],
        input_shape=(batch, d_in),
        name="feed_forward",
    ).build()


def deep_cascade(n_size: int = 32, c_in: int = 3, c_mid: int = 136,
                 n_layers: int = 4) -> DFG:
    """(Conv3×3+ReLU) × 4 with wide channels — the partitioning showcase.

    ``c_mid=136`` is chosen so that at 224² the whole-graph streaming
    plan *provably* exceeds the KV260 BRAM budget even at unroll=1
    (per-conv weights ≈73 blocks + line buffer ≈27 blocks ⇒ ~3×101+3
    blocks > 288) while every conv fits comfortably on its own — the
    graph only maps via ``repro.passes.partition_layer_groups``.  At 32²
    the line buffers shrink (~5 blocks each) and the whole graph fits.
    """
    layers = [l for _ in range(n_layers) for l in (Conv2D(c_mid), ReLU())]
    return Sequential(
        layers,
        input_shape=(1, n_size, n_size, c_in),
        name=f"deep_cascade_{n_size}",
    ).build()


def conv_pool(n_size: int = 32, c_in: int = 3, c_out: int = 16) -> DFG:
    """Conv3×3 + ReLU + MaxPool2×2 (stride 2) — the conv+pool fusion
    showcase: after the pass pipeline the pool rides the conv's epilogue
    as a windowed FusedEpilogue and its process/FIFO disappear."""
    return Sequential(
        [Conv2D(c_out), ReLU(), MaxPool(2)],
        input_shape=(1, n_size, n_size, c_in),
        name=f"conv_pool_{n_size}",
    ).build()


def conv_avgpool(n_size: int = 32, c_in: int = 3, c_out: int = 16) -> DFG:
    """Conv3×3 + ReLU + AvgPool2×2 (stride 2) — the avg-pool epilogue
    showcase (ISSUE 4 satellite): fuses like the max pool but carries
    the DIV exit path on the stream-exit datapath, which the resource
    model charges as one constant-divider DSP."""
    return Sequential(
        [Conv2D(c_out), ReLU(), AvgPool(2)],
        input_shape=(1, n_size, n_size, c_in),
        name=f"conv_avgpool_{n_size}",
    ).build()


def fat_conv(n_size: int = 16, c: int = 288) -> DFG:
    """Single Conv3×3+ReLU whose weights alone exceed the KV260 BRAM
    budget (3·3·288·288 int8 ≈ 324 RAM18K > 288): no cut can help, so it
    is only schedulable via partial weight streaming — the graph that
    hard-failed with ``PartitionError`` before the weight-tiles knob."""
    return Sequential(
        [Conv2D(c), ReLU()],
        input_shape=(1, n_size, n_size, c),
        name=f"fat_conv_{n_size}",
    ).build()


def fat_cascade(n_size: int = 16, c: int = 288, n_layers: int = 2) -> DFG:
    """(Conv3×3+ReLU) × ``n_layers`` where *every* layer's weights alone
    exceed the KV260 BRAM budget (3·3·288·288 int8 ≈ 324 RAM18K > 288).

    No contiguous slice of this graph fits with resident weights, so the
    partitioner cannot fall back to "cut until everything fits": every
    candidate group needs streamed weight tiles, and the balanced DP
    must price spill boundaries against DRAM tile traffic — the
    cost-aware streaming showcase (ISSUE 3), unreachable through the
    PR 2 single-node rescue."""
    layers = [l for _ in range(n_layers) for l in (Conv2D(c), ReLU())]
    return Sequential(
        layers,
        input_shape=(1, n_size, n_size, c),
        name=f"fat_cascade_{n_size}",
    ).build()


PAPER_SUITE = {
    "conv_relu_32": lambda: conv_relu(32),
    "conv_relu_224": lambda: conv_relu(224),
    "cascade_conv_32": lambda: cascade_conv(32),
    "cascade_conv_224": lambda: cascade_conv(224),
    "residual_block_32": lambda: residual_block(32),
    "residual_block_224": lambda: residual_block(224),
    "linear": linear,
    "feed_forward": feed_forward,
    "deep_cascade_32": lambda: deep_cascade(32),
    "deep_cascade_224": lambda: deep_cascade(224),
}
