"""MING resource & latency estimation (paper contribution C3).

Two halves:

* :class:`FpgaResourceModel` — the paper-faithful model: BRAM18K blocks,
  DSP slices with *integer-arithmetic aware* packing (the paper's claim of
  higher accuracy vs. StreamHLS comes precisely from modeling int8 DSP
  packing and BRAM18K granularity), and the cycle estimate
  ``II * ceil(trip/unroll) + depth`` summed over dataflow nodes.

* :class:`TpuResourceModel` — the TPU v5e dual used by the adapted DSE:
  BRAM→VMEM bytes, DSP→MXU/VPU lane occupancy, cycles→max(compute, HBM)
  per Pallas block.  Same ILP shape, re-derived η coefficients (DESIGN.md
  §2).

Three *execution modes* reproduce the paper's comparison frameworks:
``VANILLA`` (materialize everything, no unroll — Vitis auto baseline),
``MATERIALIZED_DATAFLOW`` (StreamHLS-like: task pipelining + unroll, but
intermediates and reorder copies materialized, WAR hazards ⇒ II=2) and
``STREAMING`` (MING: line buffers only, hazard-free II=1).
"""
from __future__ import annotations

import dataclasses
import enum
import math
from dataclasses import dataclass

from .analysis import KernelClass
from .ir import DFG, GenericOp, PAYLOAD_COSTS, PayloadKind
from .streaming import NodePlan, StreamingPlan

# ---------------------------------------------------------------------------
# FPGA constants (Kria KV260 per the paper's evaluation)
# ---------------------------------------------------------------------------

BRAM18K_BITS = 18_432          # one RAM18K block stores up to 18,432 bits
KV260_BRAM18K = 288
KV260_DSP = 1_248
#: Zynq UltraScale+ ZU3EG (Ultra96-class edge part): BRAM-richer but far
#: DSP-poorer than the KV260's K26 — 216 BRAM36 (= 432 RAM18K) vs 360
#: DSP48E2.  The multi-target sweep's second budget point: designs that
#: partition on the KV260 for BRAM often fit the ZU3EG whole but unroll
#: ~3.5× narrower.
ZU3EG_BRAM18K = 432
ZU3EG_DSP = 360
#: arrays at or below this size are mapped to LUTRAM by Vitis, not BRAM
LUTRAM_THRESHOLD_BITS = 1_024
#: DRAM bandwidth in bytes per fabric cycle (KV260 DDR4 ≈ 19 GB/s at a
#: 300 MHz fabric clock ⇒ ~64 B/cycle; derated to a conservative
#: streaming-access figure).  Charged for layer-group spills *and* for
#: partial weight streaming's tile traffic.
DRAM_BYTES_PER_CYCLE = 16
#: one AXI DMA burst: the granularity at which a group-boundary fill can
#: start trailing the previous group's spill write through DRAM.
DRAM_BURST_BYTES = 4_096


def transition_cycles(write_bytes: int, read_bytes: int) -> int:
    """Cycles for one layer-group boundary's DRAM traffic, with the
    spill write of group *k* overlapped against the fill of group *k+1*.

    The successor's read streams one DMA burst behind the predecessor's
    write, so the bus time is ``max(write, read)`` plus the *exposed
    tail* — the trailing burst the read cannot hide, capped by the
    smaller transfer (a sub-burst boundary degenerates to the serial
    sum, never worse than it).  A one-sided boundary (nothing to read
    back, or nothing written) has no overlap partner and pays its own
    transfer in full.
    """
    w = math.ceil(write_bytes / DRAM_BYTES_PER_CYCLE)
    r = math.ceil(read_bytes / DRAM_BYTES_PER_CYCLE)
    if w == 0 or r == 0:
        return w + r
    tail = math.ceil(DRAM_BURST_BYTES / DRAM_BYTES_PER_CYCLE)
    return max(w, r) + min(tail, w, r)


class ExecMode(str, enum.Enum):
    VANILLA = "vanilla"
    MATERIALIZED_DATAFLOW = "materialized_dataflow"   # StreamHLS-like
    STREAMING = "streaming"                            # MING


def dsp_per_mult(bits: int) -> float:
    """DSP48E2 cost of one multiply at a given integer width.

    int8 multiplies pack two-per-DSP when operands share a port (the
    standard INT8 packing on Xilinx DSP48E2); int16 fits one; wider needs
    cascades.  This integer-awareness is what the paper's model adds over
    StreamHLS's float-centric count.
    """
    if bits <= 8:
        return 0.5
    if bits <= 18:
        return 1.0
    if bits <= 27:
        return 2.0
    return 4.0


#: DSPs consumed by address/index arithmetic per dataflow node (empirical
#: Vitis behaviour; visible in the paper's Vanilla column: 1 MAC ⇒ 5 DSP).
ADDR_DSP_OVERHEAD = 4


def bram_blocks(bits: int, partitions: int = 1) -> int:
    """BRAM18K blocks for an array of ``bits`` split into ``partitions``.

    Each partition is a separate physical array: partitions at or below
    the LUTRAM threshold synthesize to distributed RAM (0 BRAM); larger
    ones round up to whole RAM18K blocks — the granularity loss under
    ARRAY_PARTITION is why unrolling inflates BRAM (paper Sec. V on
    StreamHLS's partition-driven BRAM growth)."""
    if bits <= 0:
        return 0
    per = math.ceil(bits / max(partitions, 1))
    if per <= LUTRAM_THRESHOLD_BITS:
        return 0
    return partitions * math.ceil(per / BRAM18K_BITS)


@dataclass
class NodeEstimate:
    name: str
    cycles: int
    dsp: int
    bram: int
    macs: int
    fill: int = 0   # cycles until first output (FIFO sizing / pipeline fill)


@dataclass
class GraphEstimate:
    mode: ExecMode
    nodes: list[NodeEstimate]
    #: BRAM18K blocks consumed by inter-process stream FIFOs (STREAMING
    #: mode only — materialized modes pass arrays, not streams)
    fifo_bram: int = 0

    @property
    def cycles(self) -> int:
        # paper Sec. IV-C: total execution cycles estimated as the sum of
        # individual node latencies (the DSE objective of Eq. (1)).
        return sum(n.cycles for n in self.nodes)

    @property
    def pipeline_cycles(self) -> int:
        """What the HLS report shows for a DATAFLOW region: concurrent
        stages, total ≈ slowest stage + downstream fill latencies.  Used
        for Table II comparisons; ``cycles`` stays the DSE objective."""
        if self.mode == ExecMode.VANILLA:
            return self.cycles  # vanilla has no task pipelining
        slowest = max(n.cycles for n in self.nodes)
        fills = sum(n.fill for n in self.nodes)
        return slowest + fills

    @property
    def dsp(self) -> int:
        return sum(n.dsp for n in self.nodes)

    @property
    def bram(self) -> int:
        return sum(n.bram for n in self.nodes) + self.fifo_bram

    @property
    def macs(self) -> int:
        return sum(n.macs for n in self.nodes)


class FpgaResourceModel:
    """Static estimator — never re-runs 'synthesis' (contribution C3)."""

    def __init__(
        self,
        *,
        war_ii: int = 2,
        vanilla_node_overhead_frac: float = 0.2,
    ) -> None:
        self.war_ii = war_ii
        self.vanilla_node_overhead_frac = vanilla_node_overhead_frac

    # -- per-node cycle/resource estimates -----------------------------------

    def node_cycles(
        self, plan: NodePlan, unroll: int, ii: int, weight_tiles: int = 1
    ) -> int:
        loops = plan.loops
        body = ii * math.ceil(loops.total_trip / max(unroll, 1))
        if (
            plan.kernel_class == KernelClass.SLIDING_WINDOW
            and plan.op.payload == PayloadKind.MAC
            and plan.info.stride > 1
        ):
            # a strided conv emits fewer windows than it ingests rows:
            # the MAC trip count (over *output* positions) undercounts
            # the cycles the node spends consuming its input stream, so
            # the node can never beat the ingest rate.  Recover the
            # streamed-input element count from the maps (the composite
            # subscripts span s*(P-1)+δ*(R-1)+1 input positions) and
            # floor the body at one element-vector per II cycles.
            op = plan.op
            smap = next(
                (m for m in op.input_maps
                 if any(not e.is_single_dim() for e in m.results)),
                None,
            )
            if smap is not None:
                in_elems = 1
                for expr in smap.results:
                    par = red = None
                    if not expr.is_single_dim() and expr.const == 0:
                        for d, c in expr.terms:
                            if op.is_parallel_dim(d):
                                par = (d, c)
                            else:
                                red = (d, c)
                    if par is not None and red is not None:
                        in_elems *= (
                            par[1] * (op.dim_extent(par[0]) - 1)
                            + red[1] * (op.dim_extent(red[0]) - 1) + 1
                        )
                    else:
                        in_elems *= op.dim_extent(expr.terms[0][0])
                body = max(body, ii * math.ceil(in_elems / max(unroll, 1)))
        cyc = body + loops.pipeline_depth
        if weight_tiles > 1:
            # partial weight streaming: the const buffer is tiled along
            # the output-channel axis and double-buffered from DRAM.
            # Charge the DRAM round-trip for the full weight set (each
            # tile crosses the bus once per inference; 2× for the
            # write/read pair, matching the spill model) plus one
            # pipeline restart per tile pass.
            const_bytes = math.ceil(plan.const_buffer_bits / 8)
            cyc += math.ceil(2 * const_bytes / DRAM_BYTES_PER_CYCLE)
            cyc += (weight_tiles - 1) * loops.pipeline_depth
        return cyc

    def node_dsp(self, plan: NodePlan, unroll: int) -> int:
        mults, adds = PAYLOAD_COSTS[plan.op.payload]
        # fused epilogue ops run once per output element on the stream-exit
        # datapath: multiplies there need DSPs (one instance, not scaled by
        # the reduction unroll), adds/compares go to LUT fabric.  An AVG
        # entry's DIV exit path counts as one multiply (Vitis lowers
        # division by a compile-time constant to multiply+shift).
        epi = sum(PAYLOAD_COSTS[e.kind][0] for e in plan.op.epilogue)
        epi_dsp = math.ceil(epi * dsp_per_mult(plan.op.elem_bits)) if epi else 0
        if plan.op.payload == PayloadKind.AVG:
            # standalone avg pool: the window accumulates are LUT adders
            # (like ADD/MAX), and the DIV exit path is ONE divider
            # instance regardless of unroll — the same single
            # constant-reciprocal multiply the fused-epilogue form is
            # charged, so fusing never changes the modeled DSP cost.
            return epi_dsp + math.ceil(dsp_per_mult(plan.op.elem_bits))
        if mults == 0:
            # pure adds/max/relu synthesize to LUT fabric — no DSP, and no
            # DSP-based address arithmetic either (paper Vanilla column:
            # Conv+ReLU shows 5 DSP ⇒ the ReLU node contributes none).
            return epi_dsp
        per_point = mults * dsp_per_mult(plan.op.elem_bits)
        return math.ceil(per_point * unroll) + ADDR_DSP_OVERHEAD + epi_dsp

    def stream_fifo_blocks(self, plan: StreamingPlan) -> int:
        """BRAM18K blocks for the inter-process FIFOs of a streaming plan.

        Like the line buffers, dataflow FIFOs are explicitly BRAM-bound
        (Vitis implements hls::stream channels between DATAFLOW processes
        as BRAM FIFOs unless forced to SRL), so every internal channel
        costs at least one RAM18K; deep diamond-absorbing FIFOs round up
        by capacity.  Host-boundary streams are AXI-stream ports, not
        on-fabric FIFOs — they are not charged.  This is the term operator
        fusion attacks: a fused consumer's FIFO disappears outright.
        """
        blocks = 0
        for s in plan.streams.values():
            if s.producer is None or s.consumer is None:
                continue
            blocks += max(1, math.ceil(s.depth * s.elem_bits / BRAM18K_BITS))
        return blocks

    def node_bram_streaming(
        self, plan: NodePlan, unroll: int, width: int = 1, weight_tiles: int = 1
    ) -> int:
        """MING: line buffer + window buffer only.

        The line buffer is partitioned by the *stream width* (lanes that
        read/write it concurrently), not the full unroll product: unrolling
        the reduction loops reads the (register-resident, fully partitioned)
        window buffer, not the line buffer.  Line buffers are explicitly
        BRAM-bound (``BIND_STORAGE impl=bram``, Sec. III-C) so each lane
        slice costs ≥1 RAM18K regardless of the LUTRAM threshold — this is
        what produces the paper's constant 16-per-conv BRAM signature.
        Window/weight buffers are completely partitioned → registers.

        ``weight_tiles > 1`` (partial weight streaming): only one
        ``1/weight_tiles`` slice of the const buffer is resident, double
        buffered (ping + pong) so the next tile's DRAM fetch overlaps the
        current tile's compute — 2× tile BRAM instead of the full set."""
        blocks = 0
        if plan.line_buffer_bits > 0:
            lanes = max(width, 1)
            per = math.ceil(plan.line_buffer_bits / lanes)
            blocks += lanes * max(1, math.ceil(per / BRAM18K_BITS))
        # window buffer: completely partitioned → registers (per-partition
        # size below the LUTRAM threshold by construction)
        blocks += bram_blocks(
            plan.window_buffer_bits, partitions=max(unroll, 1)
        )
        if weight_tiles > 1:
            tile_bits = math.ceil(plan.const_buffer_bits / weight_tiles)
            blocks += 2 * bram_blocks(tile_bits, partitions=max(unroll, 1))
        else:
            blocks += bram_blocks(plan.const_buffer_bits, partitions=max(unroll, 1))
        return blocks

    def node_bram_materialized(
        self, plan: NodePlan, dfg: DFG, unroll: int, reorder_copy: bool
    ) -> int:
        """Vanilla / StreamHLS: the node's *output tensor* is allocated in
        BRAM (plus a reorder copy for the StreamHLS-like mode, Fig. 2a)."""
        out = dfg.values[plan.op.output]
        blocks = bram_blocks(out.total_bits, partitions=max(unroll, 1))
        if reorder_copy:
            blocks *= 2
        blocks += bram_blocks(plan.const_buffer_bits, partitions=max(unroll, 1))
        return blocks

    # -- whole-graph estimates -------------------------------------------------

    def estimate(
        self,
        plan: StreamingPlan,
        mode: ExecMode,
        unrolls: dict[str, int] | None = None,
        widths: dict[str, int] | None = None,
        weight_tiles: dict[str, int] | None = None,
    ) -> GraphEstimate:
        from .streaming import _first_output_cycles  # cycle-free import

        unrolls = unrolls or {}
        widths = widths or {}
        weight_tiles = weight_tiles or {}
        dfg = plan.dfg
        nodes: list[NodeEstimate] = []
        graph_input_bits = sum(dfg.values[g].total_bits for g in dfg.graph_inputs)
        first = True
        for np_ in plan.node_order():
            u = unrolls.get(np_.name, 1)
            w = widths.get(np_.name, 1)
            fill = _first_output_cycles(np_)
            if mode == ExecMode.VANILLA:
                ii = 1
                cyc = self.node_cycles(np_, 1, ii)
                cyc = int(cyc * (1 + self.vanilla_node_overhead_frac))
                dsp = self.node_dsp(np_, 1)
                bram = self.node_bram_materialized(np_, dfg, 1, reorder_copy=False)
                if first:
                    bram += bram_blocks(graph_input_bits)  # input staged in BRAM
            elif mode == ExecMode.MATERIALIZED_DATAFLOW:
                ii = self.war_ii  # WAR hazards block II=1 (paper Sec. V)
                cyc = self.node_cycles(np_, u, ii)
                dsp = self.node_dsp(np_, u)
                bram = self.node_bram_materialized(np_, dfg, u, reorder_copy=True)
            else:  # STREAMING — MING
                ii = 1
                t = weight_tiles.get(np_.name, 1)
                cyc = self.node_cycles(np_, u, ii, weight_tiles=t)
                dsp = self.node_dsp(np_, u)
                bram = self.node_bram_streaming(np_, u, w, weight_tiles=t)
                fill = max(1, fill // max(w, 1))
            nodes.append(
                NodeEstimate(np_.name, cyc, dsp, bram, np_.op.macs(), fill)
            )
            first = False
        fifo = (
            self.stream_fifo_blocks(plan) if mode == ExecMode.STREAMING else 0
        )
        return GraphEstimate(mode, nodes, fifo_bram=fifo)


# ---------------------------------------------------------------------------
# TPU v5e dual
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TpuSpec:
    """Per-chip numbers used everywhere (roofline + DSE)."""

    peak_bf16_flops: float = 197e12       # FLOP/s
    hbm_bw: float = 819e9                 # B/s
    ici_bw: float = 50e9                  # B/s per link
    vmem_bytes: int = 16 * 1024 * 1024    # per-core Pallas-visible budget
    mxu_dim: int = 128                    # systolic array edge
    vpu_lanes: int = 8 * 128
    clock_hz: float = 0.94e9
    hbm_gib: float = 16.0


TPU_V5E = TpuSpec()


@dataclass
class TpuBlockEstimate:
    """Cycle/VMEM estimate for one Pallas block configuration."""

    cycles: float
    vmem_bytes: int
    mxu_util: float          # fraction of MXU MACs/cycle actually used
    hbm_bytes: int


class TpuResourceModel:
    """BRAM→VMEM, DSP→MXU-lanes dual of the FPGA model (DESIGN.md §2).

    Used by ``dse.plan_tpu_blocks`` to pick Pallas block shapes: the ILP's
    DSP constraint becomes "claimed MACs/cycle ≤ MXU capacity", the BRAM
    constraint becomes "double-buffered block working set ≤ VMEM"."""

    def __init__(self, spec: TpuSpec = TPU_V5E) -> None:
        self.spec = spec

    def matmul_block(
        self, bm: int, bk: int, bn: int, bytes_per_el: int = 2
    ) -> TpuBlockEstimate:
        s = self.spec
        macs = bm * bk * bn
        # MXU issues mxu_dim×mxu_dim MACs/cycle if dims are 128-aligned;
        # misaligned tiles waste lanes proportionally.
        eff_m = min(bm, s.mxu_dim) / s.mxu_dim if bm < s.mxu_dim else 1.0
        eff_n = min(bn, s.mxu_dim) / s.mxu_dim if bn < s.mxu_dim else 1.0
        util = eff_m * eff_n
        cycles = macs / (s.mxu_dim * s.mxu_dim * max(util, 1e-9))
        # double-buffered operand + accumulator tiles
        vmem = 2 * (bm * bk + bk * bn) * bytes_per_el + bm * bn * 4
        hbm = (bm * bk + bk * bn) * bytes_per_el
        return TpuBlockEstimate(cycles, vmem, util, hbm)

    def attention_blocks(
        self,
        *,
        block_q: int,
        block_k: int,
        head_dim: int,
        bytes_per_el: int = 2,
    ) -> TpuBlockEstimate:
        """One (q-tile × kv-tile) step of KV-streaming flash attention —
        the line-buffer analogue: only (block_q + block_k) rows resident."""
        s = self.spec
        macs = 2 * block_q * block_k * head_dim  # qk^T and pv
        cycles = macs / (s.mxu_dim * s.mxu_dim)
        vmem = (
            2 * (block_q * head_dim + 2 * block_k * head_dim) * bytes_per_el
            + block_q * block_k * 4          # scores tile fp32
            + 2 * block_q * 4 * 2            # running m/l accumulators
            + block_q * head_dim * 4         # output accumulator
        )
        hbm = 2 * block_k * head_dim * bytes_per_el
        return TpuBlockEstimate(cycles, vmem, 1.0, hbm)

    def roofline_time(
        self, flops: float, hbm_bytes: float, chips: int = 1
    ) -> tuple[float, float]:
        s = self.spec
        return (
            flops / (chips * s.peak_bf16_flops),
            hbm_bytes / (chips * s.hbm_bw),
        )
