"""MING lightweight DSE (paper Sec. IV-C, Eq. (1)).

The ILP::

    min   Σ_v Cycles(v)                       (objective: sum of node latencies)
    s.t.  u_ℓ | trip(ℓ)                       (unroll divisibility)
          Σ u_ℓ η_ℓd ≤ D_total                (DSP budget)
          Σ u_ℓ η_ℓb ≤ B_total                (BRAM budget)
          κ_src(s),s = κ_dst(s),s             (stream width consistency)

is solved *exactly* with branch-and-bound over divisor lattices — the
paper's point is that streaming collapses the design space enough that a
lightweight solver suffices; we lean on the same property (candidate sets
are divisor lists, typically a few dozen entries per node).

The decision variable is one unroll factor per dataflow node.  Reduction
loops unroll first (they add MACs/cycle without widening streams); once a
node's reduction trips are fully unrolled, further factors widen the
parallel (stream) loops.  The resulting *stream width* ``κ`` must agree
across every producer/consumer pair — Eq. (1)'s stream constraint.

``plan_tpu_blocks`` is the TPU dual: identical problem shape with
VMEM-bytes standing in for BRAM and MXU lane occupancy for DSPs
(DESIGN.md §2); its output drives the Pallas kernels' BlockSpecs.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Optional

from .resource_model import (
    ExecMode,
    FpgaResourceModel,
    GraphEstimate,
    KV260_BRAM18K,
    KV260_DSP,
    TPU_V5E,
    TpuResourceModel,
    TpuSpec,
)
from .streaming import NodePlan, StreamingPlan


def divisors(n: int, cap: int | None = None) -> list[int]:
    out = []
    i = 1
    while i * i <= n:
        if n % i == 0:
            out.append(i)
            if i != n // i:
                out.append(n // i)
        i += 1
    out.sort()
    if cap is not None:
        out = [d for d in out if d <= cap]
    return out


# ---------------------------------------------------------------------------
# Node-level unroll semantics
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class UnrollChoice:
    """One candidate unroll factor for a node, with derived quantities.

    ``weight_tiles > 1`` marks a partial-weight-streaming variant: the
    const buffer is split into that many output-channel tiles, double
    buffered from DRAM — less BRAM, more cycles (the DRAM round trip)."""

    unroll: int
    stream_width: int     # κ: parallel lanes on this node's streams
    dsp: int
    bram: int
    cycles: int
    weight_tiles: int = 1


def _reduction_trip(plan: NodePlan) -> int:
    op = plan.op
    r = 1
    for d in plan.info.classes.reduction:
        r *= op.dim_extent(d)
    return max(r, 1)


def _parallel_trip(plan: NodePlan) -> int:
    """Product of the *unrollable* parallel dims only.

    Sliding spatial (window) dims are never unrolled — replicating the
    sliding loops would break the streaming arrival order (Sec. IV-B's
    point about polyhedral reordering) — so the widening budget is the
    channel-like parallel dims (e.g. c_out), matching the paper's DSP
    ladder (Table II: conv unroll ≈ K·K·C_in · C_out)."""
    op = plan.op
    window = set(plan.info.classes.window)
    p = 1
    for d in op.parallel_dims:
        if d not in window:
            p *= op.dim_extent(d)
    return max(p, 1)


def node_candidates(
    plan: NodePlan,
    model: FpgaResourceModel,
    d_total: int,
    max_unroll: int = 4096,
    *,
    weight_streaming: bool = False,
) -> list[UnrollChoice]:
    """Enumerate legal unroll factors for one node (Unroll Constr.),
    STREAMING mode (II=1, line-buffer BRAM only).

    Factors are products r*p with r | reduction_trip and p | parallel_trip;
    the stream width is p (reduction unrolling does not widen streams).

    ``weight_streaming=True`` additionally enumerates partial-weight-
    streaming variants (weight_tiles > 1 along the const-indexed output
    channels, stream width pinned to 1): strictly slower than their
    resident-weight twins, but the only shapes that fit when the weights
    alone approach the BRAM budget.
    """
    red = _reduction_trip(plan)
    par = _parallel_trip(plan)
    tileable = plan.weight_tileable_extent
    tile_opts = [1]
    if weight_streaming and tileable > 1 and plan.const_buffer_bits > 0:
        tile_opts += [t for t in divisors(tileable) if t > 1]
    choices: dict[tuple[int, int], UnrollChoice] = {}
    for t in tile_opts:
        for r in divisors(red, cap=max_unroll):
            for p in divisors(par, cap=max(max_unroll // r, 1)):
                u = r * p
                if u > max_unroll:
                    continue
                # widening streams before exhausting the reduction wastes
                # DSPs feeding idle lanes — prune dominated shapes
                if p > 1 and r != red:
                    continue
                # a streamed weight tile feeds one lane; widening the
                # stream would demand concurrent tiles (defeats the point)
                if t > 1 and p > 1:
                    continue
                cyc = model.node_cycles(plan, u, ii=1, weight_tiles=t)
                dsp = model.node_dsp(plan, u)
                if dsp > d_total:
                    continue
                bram = model.node_bram_streaming(plan, u, width=p, weight_tiles=t)
                prev = choices.get((u, t))
                cand = UnrollChoice(u, p, dsp, bram, cyc, weight_tiles=t)
                if prev is None or cand.cycles < prev.cycles:
                    choices[(u, t)] = cand
    return sorted(choices.values(), key=lambda c: (c.unroll, c.weight_tiles))


# ---------------------------------------------------------------------------
# Exact branch-and-bound ILP solver
# ---------------------------------------------------------------------------


@dataclass
class DseResult:
    unrolls: dict[str, int]
    stream_widths: dict[str, int]
    estimate: GraphEstimate
    objective_cycles: int
    dsp_used: int
    bram_used: int
    feasible: bool
    explored: int = 0
    #: nodes mapped with partial weight streaming (node -> tile count > 1)
    weight_tiles: dict[str, int] = field(default_factory=dict)


def solve_ilp(
    plan: StreamingPlan,
    *,
    options=None,
    d_total: int | None = None,
    b_total: int | None = None,
    model: FpgaResourceModel | None = None,
    max_unroll: int | None = None,
    weight_streaming: bool = False,
) -> DseResult:
    """Solve Eq. (1) exactly for the STREAMING (MING) mode.

    ``options`` (a :class:`repro.core.CompileOptions`, duck-typed here
    to keep ``core.dse`` import-light) supplies the budgets, resource
    model, and unroll cap from its target — the same bundle the driver
    and the partition DP consume, so a caller never has to unpack the
    knobs positionally.  ``weight_streaming`` stays a per-solve flag:
    the partitioner flips it per slice (see below), independent of the
    bundle's policy.

    Inter-process FIFO BRAM (see
    :meth:`FpgaResourceModel.stream_fifo_blocks`) is assignment-independent
    and charged as a fixed overhead against ``b_total`` — fusing nodes
    (``repro.passes``) shrinks it before the solver ever runs.

    ``weight_streaming=True`` lets the candidate sets include partial
    weight streaming (see :func:`node_candidates`).  Off by default:
    streamed designs are strictly slower than their resident twins, so
    admitting them unconditionally would make *every* graph "feasible"
    and erase the partitioning signal.  The partitioner re-solves with
    it for any slice whose resident plan is over budget — that makes
    streamed groups a first-class choice its DP prices against cutting
    (ISSUE 3), while graphs that fit resident never pick up tiles.
    """
    if options is not None:
        if any(v is not None for v in (d_total, b_total, model, max_unroll)):
            raise ValueError(
                "pass either options=CompileOptions(...) or the loose "
                "d_total/b_total/model/max_unroll kwargs, not both"
            )
        tgt = options.target
        d_total, b_total = tgt.d_total, tgt.b_total
        model = tgt.model()
        max_unroll = options.resolved_max_unroll
    d_total = KV260_DSP if d_total is None else d_total
    b_total = KV260_BRAM18K if b_total is None else b_total
    max_unroll = 4096 if max_unroll is None else max_unroll
    model = model or FpgaResourceModel()
    nodes = plan.node_order()
    fifo_bram = model.stream_fifo_blocks(plan)
    b_nodes = b_total - fifo_bram
    cand: dict[str, list[UnrollChoice]] = {
        n.name: node_candidates(
            n, model, d_total, max_unroll, weight_streaming=weight_streaming
        )
        for n in nodes
    }

    def _infeasible(explored: int = 0) -> DseResult:
        unrolls = {n.name: 1 for n in nodes}
        est = model.estimate(plan, ExecMode.STREAMING, unrolls)
        return DseResult(unrolls, dict(unrolls), est, est.cycles,
                         est.dsp, est.bram, feasible=False, explored=explored)

    if any(not cs for cs in cand.values()) or b_nodes < 0:
        return _infeasible()

    # stream adjacency: consumer -> producers already placed (topo order)
    producers_of: dict[str, list[str]] = {n.name: [] for n in nodes}
    for s in plan.streams.values():
        if s.producer and s.consumer:
            producers_of[s.consumer].append(s.producer)

    order = [n.name for n in nodes]
    best: dict = {"cycles": math.inf, "assign": None, "explored": 0}
    # optimistic per-node lower bounds for pruning: cycles drive the
    # branch-and-bound incumbent check, bram/dsp prove infeasibility of a
    # partial assignment without enumerating its subtree (this is what
    # makes "the whole graph provably does not fit" cheap enough for the
    # layer-group partitioner to probe prefixes with).
    suffix_cycles = [0] * (len(order) + 1)
    suffix_bram = [0] * (len(order) + 1)
    suffix_dsp = [0] * (len(order) + 1)
    for i in range(len(order) - 1, -1, -1):
        cs = cand[order[i]]
        suffix_cycles[i] = suffix_cycles[i + 1] + min(c.cycles for c in cs)
        suffix_bram[i] = suffix_bram[i + 1] + min(c.bram for c in cs)
        suffix_dsp[i] = suffix_dsp[i + 1] + min(c.dsp for c in cs)

    if suffix_bram[0] > b_nodes or suffix_dsp[0] > d_total:
        return _infeasible()

    def recurse(
        i: int, assign: dict[str, UnrollChoice], dsp: int, bram: int, cycles: int
    ) -> None:
        best["explored"] += 1
        if cycles + suffix_cycles[i] >= best["cycles"]:
            return
        if i == len(order):
            best["cycles"] = cycles
            best["assign"] = dict(assign)
            return
        name = order[i]
        # stream constraint: κ must equal every already-placed producer's κ
        widths = {assign[p].stream_width for p in producers_of[name] if p in assign}
        for choice in cand[name]:
            if widths and choice.stream_width not in widths:
                continue
            if dsp + choice.dsp + suffix_dsp[i + 1] > d_total:
                continue
            if bram + choice.bram + suffix_bram[i + 1] > b_nodes:
                continue
            assign[name] = choice
            recurse(i + 1, assign, dsp + choice.dsp, bram + choice.bram,
                    cycles + choice.cycles)
            del assign[name]

    recurse(0, {}, 0, 0, 0)

    if best["assign"] is None:
        # infeasible under the budgets — report unroll=1 estimate
        return _infeasible(best["explored"])

    assign: dict[str, UnrollChoice] = best["assign"]
    unrolls = {n: c.unroll for n, c in assign.items()}
    tiles = {n: c.weight_tiles for n, c in assign.items() if c.weight_tiles > 1}
    est = model.estimate(
        plan, ExecMode.STREAMING, unrolls,
        widths={n: c.stream_width for n, c in assign.items()},
        weight_tiles=tiles,
    )
    return DseResult(
        unrolls=unrolls,
        stream_widths={n: c.stream_width for n, c in assign.items()},
        estimate=est,
        objective_cycles=sum(c.cycles for c in assign.values()),
        dsp_used=sum(c.dsp for c in assign.values()),
        bram_used=sum(c.bram for c in assign.values()) + fifo_bram,
        feasible=True,
        explored=best["explored"],
        weight_tiles=tiles,
    )


def solve_materialized(
    plan: StreamingPlan,
    *,
    d_total: int = KV260_DSP,
    b_total: int | None = None,
    model: FpgaResourceModel | None = None,
) -> DseResult:
    """StreamHLS-like DSE: unroll under the DSP budget only (the paper's
    observation: StreamHLS's DSE tracks DSPs but not BRAM, which is what
    lets its designs blow past edge BRAM limits)."""
    model = model or FpgaResourceModel()
    unrolls: dict[str, int] = {}
    widths: dict[str, int] = {}
    budget = d_total
    for np_ in plan.node_order():
        red = _reduction_trip(np_)
        # greedy: largest reduction-unroll fitting the remaining DSP budget
        u = 1
        for cand_u in divisors(red):
            dsp = model.node_dsp(np_, cand_u)
            if dsp <= max(budget, 0):
                u = cand_u
        budget -= model.node_dsp(np_, u)
        unrolls[np_.name] = u
        widths[np_.name] = 1
    est = model.estimate(plan, ExecMode.MATERIALIZED_DATAFLOW, unrolls)
    feasible = b_total is None or est.bram <= b_total
    return DseResult(unrolls, widths, est, est.cycles, est.dsp, est.bram,
                     feasible=feasible)


# ---------------------------------------------------------------------------
# TPU dual: Pallas block-shape selection under (VMEM, MXU) budgets
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TpuBlockPlan:
    """Chosen BlockSpec tile sizes for one fused kernel."""

    kind: str
    blocks: dict
    est_cycles: float
    vmem_bytes: int
    mxu_util: float


def _pow2_multiples(base: int, limit: int) -> list[int]:
    out = []
    v = base
    while v <= limit:
        out.append(v)
        v *= 2
    return out or [base]


def plan_attention_blocks(
    *,
    seq_q: int,
    seq_k: int,
    head_dim: int,
    vmem_budget: int | None = None,
    spec: TpuSpec = TPU_V5E,
    bytes_per_el: int = 2,
) -> TpuBlockPlan:
    """Pick (block_q, block_k) for KV-streaming flash attention.

    BRAM constraint → resident q/k/v tiles + accumulators ≤ VMEM;
    DSP constraint → tiles 128-aligned so MXU lanes are fully claimed;
    objective → minimize estimated cycles (favors the largest feasible
    k-tile: fewer stream iterations, better pipelining)."""
    model = TpuResourceModel(spec)
    budget = vmem_budget or spec.vmem_bytes
    best: Optional[TpuBlockPlan] = None
    for bq in _pow2_multiples(min(128, seq_q), min(seq_q, 1024)):
        for bk in _pow2_multiples(min(128, seq_k), min(seq_k, 2048)):
            if seq_q % bq or seq_k % bk:
                continue
            e = model.attention_blocks(
                block_q=bq, block_k=bk, head_dim=head_dim, bytes_per_el=bytes_per_el
            )
            if e.vmem_bytes > budget:
                continue
            steps = (seq_q // bq) * (seq_k // bk)
            total = e.cycles * steps
            if best is None or total < best.est_cycles or (
                total == best.est_cycles and e.vmem_bytes < best.vmem_bytes
            ):
                best = TpuBlockPlan(
                    "attention", {"block_q": bq, "block_k": bk},
                    total, e.vmem_bytes, e.mxu_util,
                )
    assert best is not None, "no feasible attention tiling"
    return best


def plan_matmul_blocks(
    *,
    m: int,
    k: int,
    n: int,
    vmem_budget: int | None = None,
    spec: TpuSpec = TPU_V5E,
    bytes_per_el: int = 2,
) -> TpuBlockPlan:
    """Pick (bm, bk, bn) for a streamed matmul (fused-MLP building block)."""
    model = TpuResourceModel(spec)
    budget = vmem_budget or spec.vmem_bytes
    best: Optional[TpuBlockPlan] = None
    for bm in _pow2_multiples(min(128, m), min(m, 1024)):
        for bn in _pow2_multiples(min(128, n), min(n, 1024)):
            for bk in _pow2_multiples(min(128, k), min(k, 2048)):
                if m % bm or n % bn or k % bk:
                    continue
                e = model.matmul_block(bm, bk, bn, bytes_per_el)
                if e.vmem_bytes > budget:
                    continue
                steps = (m // bm) * (n // bn) * (k // bk)
                total = e.cycles * steps
                key = (total, -e.mxu_util, e.vmem_bytes)
                if best is None or key < (best.est_cycles, -best.mxu_util,
                                          best.vmem_bytes):
                    best = TpuBlockPlan(
                        "matmul", {"bm": bm, "bk": bk, "bn": bn},
                        total, e.vmem_bytes, e.mxu_util,
                    )
    assert best is not None, "no feasible matmul tiling"
    return best


def plan_conv_rows(
    *,
    h: int,
    w: int,
    c_in: int,
    c_out: int,
    kh: int,
    kw: int,
    vmem_budget: int | None = None,
    spec: TpuSpec = TPU_V5E,
    bytes_per_el: int = 1,
) -> TpuBlockPlan:
    """Rows-per-block for the line-buffer streaming conv kernel.

    The VMEM working set is the TPU line buffer: (rows + kh - 1) input
    rows + weights + rows of output — directly mirroring the paper's
    (K-1)×N BRAM line buffer."""
    budget = vmem_budget or spec.vmem_bytes
    best: Optional[TpuBlockPlan] = None
    rows = 1
    while rows <= h:
        if h % rows == 0:
            in_rows = (rows + kh - 1) * w * c_in * bytes_per_el * 2
            w_bytes = kh * kw * c_in * c_out * bytes_per_el
            out_rows = rows * w * c_out * 4  # int32/fp32 accumulators
            vmem = in_rows + w_bytes + out_rows
            if vmem <= budget:
                macs = rows * w * c_out * kh * kw * c_in
                cycles = macs / (spec.mxu_dim * spec.mxu_dim)
                steps = h // rows
                cand = TpuBlockPlan(
                    "conv_rows", {"rows": rows}, cycles * steps, vmem, 1.0
                )
                # prefer more rows (fewer grid steps, better DMA pipelining)
                if best is None or cand.blocks["rows"] > best.blocks["rows"]:
                    best = cand
        rows *= 2
    assert best is not None, "no feasible conv row tiling"
    return best
