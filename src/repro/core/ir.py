"""MING core IR: a linalg.generic-like dataflow representation in Python.

The paper (Sec. IV-A) operates on ``linalg.generic`` operations: each op
carries *indexing maps* (affine maps from loop iterators to operand
subscripts) and *iterator types* (``parallel`` | ``reduction``).  MING's
analyses — sliding-window detection (Alg. 1) and iterator classification
(Alg. 2) — read only this structure, never the payload.  We mirror that
here: :class:`GenericOp` is the unit of analysis, :class:`DFG` is the
dataflow graph whose edges are tensors ("streams" after the transform).

This IR is deliberately tiny and dependency-free: it is the contract
between the model-graph frontends (``repro.core.cnn_graphs`` for the
paper's CNN suite, ``repro.graph`` for LM layers) and the analysis /
streaming / DSE passes.
"""
from __future__ import annotations

import dataclasses
import enum
import math
from dataclasses import dataclass, field
from typing import Callable, Iterable, Mapping, Optional, Sequence


# ---------------------------------------------------------------------------
# Affine expressions / maps (the subset MLIR's affine maps need here:
# integer-linear combinations of loop dims plus a constant).
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class AffineExpr:
    """``sum(coeff_i * d_i) + const`` over loop dimensions ``d_i``.

    ``terms`` is a sorted tuple of ``(dim_index, coefficient)`` with all
    coefficients nonzero.  A *single-dim* expression (``IS_SINGLE_DIM`` in
    Alg. 2) is one term with coefficient 1 and zero constant.
    """

    terms: tuple[tuple[int, int], ...] = ()
    const: int = 0

    @staticmethod
    def dim(d: int, coeff: int = 1) -> "AffineExpr":
        if coeff == 0:
            return AffineExpr((), 0)
        return AffineExpr(((d, coeff),), 0)

    @staticmethod
    def constant(c: int) -> "AffineExpr":
        return AffineExpr((), c)

    def __add__(self, other: "AffineExpr") -> "AffineExpr":
        acc: dict[int, int] = {}
        for d, c in self.terms + other.terms:
            acc[d] = acc.get(d, 0) + c
        terms = tuple(sorted((d, c) for d, c in acc.items() if c != 0))
        return AffineExpr(terms, self.const + other.const)

    def __mul__(self, k: int) -> "AffineExpr":
        if k == 0:
            return AffineExpr((), 0)
        return AffineExpr(tuple((d, c * k) for d, c in self.terms), self.const * k)

    # -- predicates used by the paper's algorithms --------------------------

    def is_single_dim(self) -> bool:
        """One iterator, unit coefficient, no offset (Alg. 2 IS_SINGLE_DIM)."""
        return len(self.terms) == 1 and self.terms[0][1] == 1 and self.const == 0

    def dims(self) -> tuple[int, ...]:
        return tuple(d for d, _ in self.terms)

    def coeff(self, d: int) -> int:
        for dd, c in self.terms:
            if dd == d:
                return c
        return 0

    def evaluate(self, point: Sequence[int]) -> int:
        return self.const + sum(c * point[d] for d, c in self.terms)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        parts = [
            (f"d{d}" if c == 1 else f"{c}*d{d}") for d, c in self.terms
        ]
        if self.const or not parts:
            parts.append(str(self.const))
        return " + ".join(parts)


@dataclass(frozen=True)
class AffineMap:
    """An affine map ``(d0, ..., d{n-1}) -> (E_0, ..., E_{m-1})``."""

    n_dims: int
    results: tuple[AffineExpr, ...]

    @staticmethod
    def identity(n: int) -> "AffineMap":
        return AffineMap(n, tuple(AffineExpr.dim(i) for i in range(n)))

    @staticmethod
    def of(n_dims: int, exprs: Iterable[AffineExpr]) -> "AffineMap":
        return AffineMap(n_dims, tuple(exprs))

    def is_identity(self) -> bool:
        return self.results == tuple(
            AffineExpr.dim(i) for i in range(self.n_dims)
        )

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        ds = ", ".join(f"d{i}" for i in range(self.n_dims))
        rs = ", ".join(repr(e) for e in self.results)
        return f"({ds}) -> ({rs})"


class IteratorType(str, enum.Enum):
    PARALLEL = "parallel"
    REDUCTION = "reduction"


# ---------------------------------------------------------------------------
# Values (tensors / streams) and GenericOp
# ---------------------------------------------------------------------------


@dataclass
class Value:
    """A tensor edge in the DFG.  After the streaming transform these are
    realized as streams (FIFO channels in the FPGA path, VMEM-resident
    producer→consumer handoffs in the TPU path) instead of materialized
    arrays — the core of MING contribution C1."""

    name: str
    shape: tuple[int, ...]
    elem_bits: int = 8  # paper evaluates int8 post-training quantization
    is_constant: bool = False  # weights/biases: not streamed, held on-chip

    @property
    def num_elements(self) -> int:
        return math.prod(self.shape) if self.shape else 1

    @property
    def total_bits(self) -> int:
        return self.num_elements * self.elem_bits


class PayloadKind(str, enum.Enum):
    """Semantic tag for the scalar payload region of a GenericOp.

    MING never inspects the payload for *classification* (that is purely
    structural, from indexing maps + iterator types); the payload kind is
    only used by the resource model to count multiplies/adds per iteration
    point (DSP/MXU cost) and by the emitters.
    """

    MAC = "mac"               # out += in0 * in1   (conv / matmul)
    ADD = "add"               # out = in0 + in1
    MAX = "max"               # out = max(in0, in1) (pooling)
    AVG = "avg"               # out = mean over window (avg pooling):
    #                           accumulate ADDs, divide once on the
    #                           stream-exit datapath (the DIV exit path)
    RELU = "relu"             # out = max(in0, 0)
    SQUARED_RELU = "squared_relu"
    IDENTITY = "identity"
    EXP = "exp"
    MUL = "mul"


#: multiplies, adds per iteration point, keyed by payload kind
PAYLOAD_COSTS: dict[PayloadKind, tuple[int, int]] = {
    PayloadKind.MAC: (1, 1),
    PayloadKind.ADD: (0, 1),
    PayloadKind.MAX: (0, 1),
    # avg pool: one add per window point plus the exit divide, realized
    # as a constant-reciprocal multiply (Vitis lowers /const to mul+shift)
    PayloadKind.AVG: (1, 1),
    PayloadKind.RELU: (0, 1),
    PayloadKind.SQUARED_RELU: (1, 1),
    PayloadKind.IDENTITY: (0, 0),
    PayloadKind.EXP: (4, 4),  # poly approx budget
    PayloadKind.MUL: (1, 0),
}


@dataclass(frozen=True)
class FusedEpilogue:
    """One op folded into a producer's payload by the fusion passes
    (``repro.passes.fusion``).

    Elementwise form (``window == ()``): applies ``kind`` to the
    producer's output element once per output point, *after* the main
    payload.  Binary kinds (ADD/MUL/MAX) read their second operand from
    ``operand`` — a *constant* value (bias, scale) held on-chip next to
    the weights; unary kinds leave it None.

    Pooling form (``window != ()``): a non-overlapping window reduction
    folded in by conv+pool fusion.  ``window`` has one factor per output
    axis (e.g. ``(1, 2, 2, 1)`` for an NHWC 2×2 stride-2 max pool) and
    ``kind`` is the combining op (MAX for max pool).  Unlike elementwise
    entries it *shrinks* the output: axis ``i`` divides by ``window[i]``
    — shape bookkeeping goes through :meth:`GenericOp.epilogue_shape`.
    """

    kind: PayloadKind
    operand: Optional[str] = None
    window: tuple[int, ...] = ()


@dataclass
class GenericOp:
    """A ``linalg.generic``-like op.

    ``indexing_maps`` has one entry per input followed by one for the
    output (same convention as MLIR).  ``dim_sizes`` gives the extent of
    every loop dimension (trip counts), known statically for inference
    workloads — the property MING's lightweight DSE relies on.

    ``epilogue`` is the chain of fused elementwise ops applied to each
    output element before it enters the output stream; it never changes
    the loop structure, so every analysis (Alg. 1/2) ignores it.
    """

    name: str
    inputs: tuple[str, ...]
    output: str
    indexing_maps: tuple[AffineMap, ...]
    iterator_types: tuple[IteratorType, ...]
    dim_sizes: tuple[int, ...]
    payload: PayloadKind = PayloadKind.MAC
    elem_bits: int = 8
    epilogue: tuple[FusedEpilogue, ...] = ()

    def __post_init__(self) -> None:
        if len(self.indexing_maps) != len(self.inputs) + 1:
            raise ValueError(
                f"{self.name}: need {len(self.inputs) + 1} indexing maps "
                f"(inputs + output), got {len(self.indexing_maps)}"
            )
        n = len(self.iterator_types)
        if len(self.dim_sizes) != n:
            raise ValueError(f"{self.name}: dim_sizes/iterator_types length mismatch")
        for m in self.indexing_maps:
            if m.n_dims != n:
                raise ValueError(f"{self.name}: map arity {m.n_dims} != {n}")

    # -- convenience ---------------------------------------------------------

    @property
    def input_maps(self) -> tuple[AffineMap, ...]:
        return self.indexing_maps[: len(self.inputs)]

    @property
    def output_map(self) -> AffineMap:
        return self.indexing_maps[-1]

    @property
    def n_dims(self) -> int:
        return len(self.iterator_types)

    def is_parallel_dim(self, d: int) -> bool:
        return self.iterator_types[d] == IteratorType.PARALLEL

    def is_reduction_dim(self, d: int) -> bool:
        return self.iterator_types[d] == IteratorType.REDUCTION

    @property
    def parallel_dims(self) -> tuple[int, ...]:
        return tuple(
            d for d, t in enumerate(self.iterator_types) if t == IteratorType.PARALLEL
        )

    @property
    def reduction_dims(self) -> tuple[int, ...]:
        return tuple(
            d for d, t in enumerate(self.iterator_types) if t == IteratorType.REDUCTION
        )

    @property
    def total_trip_count(self) -> int:
        return math.prod(self.dim_sizes) if self.dim_sizes else 1

    @property
    def output_elements(self) -> int:
        """Number of output points = product of output-map dim extents
        (pre-pooling: a fused pool epilogue consumes these points)."""
        dims = set()
        for expr in self.output_map.results:
            dims.update(expr.dims())
        return math.prod(self.dim_sizes[d] for d in dims) if dims else 1

    def epilogue_shape(self, shape: tuple[int, ...]) -> tuple[int, ...]:
        """Shape of the value actually produced, after any fused pooling
        epilogues shrink the mapped output extents (verifier V8 and the
        canonicalizer's shape propagation both route through this)."""
        for e in self.epilogue:
            if e.window:
                shape = tuple(
                    s // f for s, f in zip(shape, e.window)
                )
        return shape

    def macs(self) -> int:
        """Multiply-accumulate-equivalents for the whole op (epilogue
        included: one application per output element)."""
        mults, adds = PAYLOAD_COSTS[self.payload]
        total = self.total_trip_count * max(mults, adds, 1) if (mults or adds) else 0
        for ep in self.epilogue:
            m, a = PAYLOAD_COSTS[ep.kind]
            total += self.output_elements * max(m, a, 1) if (m or a) else 0
        return total

    def dim_extent(self, d: int) -> int:
        return self.dim_sizes[d]


# ---------------------------------------------------------------------------
# Dataflow graph
# ---------------------------------------------------------------------------


@dataclass
class DFG:
    """Dataflow graph over :class:`GenericOp` nodes.

    Mirrors the paper's dfg-mlir abstraction (Sec. III-B): nodes are KPN
    processes, values are FIFO channels.  ``graph_inputs`` are tensors
    arriving from host memory; ``graph_outputs`` leave the fabric.
    """

    name: str
    values: dict[str, Value] = field(default_factory=dict)
    nodes: list[GenericOp] = field(default_factory=list)
    graph_inputs: list[str] = field(default_factory=list)
    graph_outputs: list[str] = field(default_factory=list)

    # -- construction --------------------------------------------------------

    def add_value(self, value: Value) -> Value:
        if value.name in self.values:
            raise ValueError(f"duplicate value {value.name}")
        self.values[value.name] = value
        return value

    def add_node(self, node: GenericOp) -> GenericOp:
        for v in node.inputs + (node.output,):
            if v not in self.values:
                raise ValueError(f"{node.name}: unknown value {v}")
        self.nodes.append(node)
        return node

    # -- topology ------------------------------------------------------------

    def producer_of(self, value_name: str) -> Optional[GenericOp]:
        for n in self.nodes:
            if n.output == value_name:
                return n
        return None

    def consumers_of(self, value_name: str) -> list[GenericOp]:
        return [n for n in self.nodes if value_name in n.inputs]

    def node(self, name: str) -> GenericOp:
        for n in self.nodes:
            if n.name == name:
                return n
        raise KeyError(name)

    def topo_order(self) -> list[GenericOp]:
        """Kahn's algorithm over the tensor-mediated edges."""
        ready: list[GenericOp] = []
        produced = set(self.graph_inputs) | {
            v for v, val in self.values.items() if val.is_constant
        }
        pending = list(self.nodes)
        order: list[GenericOp] = []
        while pending:
            ready = [n for n in pending if all(i in produced for i in n.inputs)]
            if not ready:
                raise ValueError(f"{self.name}: cycle or missing producer in DFG")
            for n in ready:
                order.append(n)
                produced.add(n.output)
                pending.remove(n)
        return order

    def edges(self) -> list[tuple[GenericOp, GenericOp, str]]:
        """(producer, consumer, value) triples for non-constant edges."""
        out = []
        for n in self.nodes:
            for c in self.consumers_of(n.output):
                out.append((n, c, n.output))
        return out

    def intermediate_values(self) -> list[Value]:
        """Values produced and consumed inside the graph — exactly the
        tensors MING refuses to materialize (Fig. 2b)."""
        names = {n.output for n in self.nodes} - set(self.graph_outputs)
        return [self.values[v] for v in names]

    # -- rewrite hooks (used by repro.passes) --------------------------------

    def referenced_values(self) -> set[str]:
        """Every value name reachable from a node, input, or output —
        including epilogue operands (constants folded in by fusion)."""
        refs = set(self.graph_inputs) | set(self.graph_outputs)
        for n in self.nodes:
            refs.update(n.inputs)
            refs.add(n.output)
            refs.update(e.operand for e in n.epilogue if e.operand)
        return refs

    def remove_node(self, name: str) -> GenericOp:
        node = self.node(name)
        self.nodes.remove(node)
        return node

    def remove_value(self, name: str) -> Value:
        """Remove an *unreferenced* value (rewrites must detach it first)."""
        if name in self.referenced_values():
            raise ValueError(f"cannot remove {name}: still referenced")
        return self.values.pop(name)

    def replace_value_uses(self, old: str, new: str) -> int:
        """Rewire every *use* of ``old`` (node inputs, epilogue operands,
        graph outputs) to ``new``.  The producer of ``old`` is untouched."""
        if new not in self.values:
            raise ValueError(f"unknown replacement value {new}")
        n_replaced = 0
        for node in self.nodes:
            if old in node.inputs:
                node.inputs = tuple(new if i == old else i for i in node.inputs)
                n_replaced += 1
            if any(e.operand == old for e in node.epilogue):
                node.epilogue = tuple(
                    dataclasses.replace(e, operand=new) if e.operand == old else e
                    for e in node.epilogue
                )
                n_replaced += 1
        self.graph_outputs = [new if v == old else v for v in self.graph_outputs]
        self.graph_inputs = [new if v == old else v for v in self.graph_inputs]
        return n_replaced

    def clone(self, name: Optional[str] = None) -> "DFG":
        """Deep-enough copy for destructive rewrites: Value and GenericOp
        instances are duplicated; their (immutable) fields are shared."""
        out = DFG(name or self.name)
        out.values = {k: dataclasses.replace(v) for k, v in self.values.items()}
        out.nodes = [dataclasses.replace(n) for n in self.nodes]
        out.graph_inputs = list(self.graph_inputs)
        out.graph_outputs = list(self.graph_outputs)
        return out

    def subgraph(self, node_names: Sequence[str], name: Optional[str] = None) -> "DFG":
        """Extract the induced subgraph over ``node_names`` as a standalone
        DFG — the layer-group partitioner's cut primitive.

        Values consumed but not produced inside the subgraph become graph
        inputs (unless constant); values produced inside and consumed
        outside (or listed in the parent's graph_outputs) become graph
        outputs.
        """
        members = set(node_names)
        sub = DFG(name or f"{self.name}_sub")
        picked = [n for n in self.nodes if n.name in members]
        if len(picked) != len(members):
            missing = members - {n.name for n in picked}
            raise KeyError(f"unknown nodes in subgraph: {sorted(missing)}")
        produced = {n.output for n in picked}
        for n in picked:
            refs = list(n.inputs) + [n.output] + [
                e.operand for e in n.epilogue if e.operand
            ]
            for v in refs:
                if v not in sub.values:
                    sub.values[v] = dataclasses.replace(self.values[v])
        for n in picked:
            for v in n.inputs:
                if (
                    v not in produced
                    and not self.values[v].is_constant
                    and v not in sub.graph_inputs
                ):
                    sub.graph_inputs.append(v)
        for n in picked:
            v = n.output
            consumed_outside = any(
                v in c.inputs for c in self.nodes if c.name not in members
            )
            if (v in self.graph_outputs or consumed_outside) and (
                v not in sub.graph_outputs
            ):
                sub.graph_outputs.append(v)
        sub.nodes = [dataclasses.replace(n) for n in picked]
        return sub


# ---------------------------------------------------------------------------
# Builders for common NN GenericOps (used by cnn_graphs and the LM frontend)
# ---------------------------------------------------------------------------


def make_conv2d_op(
    name: str,
    input_name: str,
    weight_name: str,
    output_name: str,
    *,
    n: int,
    h_out: int,
    w_out: int,
    c_out: int,
    kh: int,
    kw: int,
    c_in: int,
    stride: int = 1,
    dilation: int = 1,
    elem_bits: int = 8,
) -> GenericOp:
    """NHWC conv2d as linalg.generic (paper Fig. 5 maps 1-3).

    dims: (d0=n, d1=h, d2=w, d3=c_out, d4=r, d5=s, d6=c_in);
    input map:  (d0, d1*stride + d4*dilation, d2*stride + d5*dilation, d6)
    weight map: (d4, d5, d6, d3)
    output map: (d0, d1, d2, d3)
    """
    d = AffineExpr.dim
    imap = AffineMap.of(
        7,
        [
            d(0),
            d(1, stride) + d(4, dilation),
            d(2, stride) + d(5, dilation),
            d(6),
        ],
    )
    wmap = AffineMap.of(7, [d(4), d(5), d(6), d(3)])
    omap = AffineMap.of(7, [d(0), d(1), d(2), d(3)])
    P, R = IteratorType.PARALLEL, IteratorType.REDUCTION
    return GenericOp(
        name=name,
        inputs=(input_name, weight_name),
        output=output_name,
        indexing_maps=(imap, wmap, omap),
        iterator_types=(P, P, P, P, R, R, R),
        dim_sizes=(n, h_out, w_out, c_out, kh, kw, c_in),
        payload=PayloadKind.MAC,
        elem_bits=elem_bits,
    )


def make_matmul_op(
    name: str,
    lhs: str,
    rhs: str,
    output: str,
    *,
    m: int,
    k: int,
    n_out: int,
    elem_bits: int = 8,
) -> GenericOp:
    """(m,k) x (k,n) -> (m,n): dims (d0=m, d1=n, d2=k)."""
    d = AffineExpr.dim
    P, R = IteratorType.PARALLEL, IteratorType.REDUCTION
    return GenericOp(
        name=name,
        inputs=(lhs, rhs),
        output=output,
        indexing_maps=(
            AffineMap.of(3, [d(0), d(2)]),
            AffineMap.of(3, [d(2), d(1)]),
            AffineMap.of(3, [d(0), d(1)]),
        ),
        iterator_types=(P, P, R),
        dim_sizes=(m, n_out, k),
        payload=PayloadKind.MAC,
        elem_bits=elem_bits,
    )


def make_elementwise_op(
    name: str,
    inputs: Sequence[str],
    output: str,
    shape: tuple[int, ...],
    payload: PayloadKind,
    elem_bits: int = 8,
) -> GenericOp:
    """Pure-parallel op: identity maps on every operand (paper map0)."""
    n = len(shape)
    ident = AffineMap.identity(n)
    return GenericOp(
        name=name,
        inputs=tuple(inputs),
        output=output,
        indexing_maps=tuple(ident for _ in range(len(inputs) + 1)),
        iterator_types=tuple(IteratorType.PARALLEL for _ in range(n)),
        dim_sizes=shape,
        payload=payload,
        elem_bits=elem_bits,
    )


def make_broadcast_binary_op(
    name: str,
    stream_input: str,
    const_input: str,
    output: str,
    shape: tuple[int, ...],
    payload: PayloadKind,
    elem_bits: int = 8,
) -> GenericOp:
    """Binary pure-parallel op whose second operand is a rank-1 constant
    broadcast along the output's *last* axis (the per-channel bias of an
    imported conv/dense).  The broadcast lives in the indexing map — the
    constant's map reads only ``d_{n-1}`` — so downstream consumers (the
    streaming planner's const-buffer charge, the HLS emitter's epilogue
    operand indexing) see a C-element buffer instead of the H·W·C
    materialization a full-tensor constant would cost.
    """
    n = len(shape)
    ident = AffineMap.identity(n)
    bcast = AffineMap.of(n, [AffineExpr.dim(n - 1)])
    return GenericOp(
        name=name,
        inputs=(stream_input, const_input),
        output=output,
        indexing_maps=(ident, bcast, ident),
        iterator_types=tuple(IteratorType.PARALLEL for _ in range(n)),
        dim_sizes=shape,
        payload=payload,
        elem_bits=elem_bits,
    )


def make_transpose_op(
    name: str,
    input_name: str,
    output_name: str,
    *,
    in_shape: Sequence[int],
    perm: Sequence[int],
    elem_bits: int = 8,
) -> GenericOp:
    """Axis permutation as a pure-parallel data-movement op.

    ``out[i0, …] = in[i_{inv[0]}, …]`` with ``out.shape[p] =
    in_shape[perm[p]]``.  Loop dims index the *output* tensor (output
    map is the identity); the input map carries the permutation, which
    is how the analyses (:func:`repro.core.analysis.reorder_spec`)
    recover it without a payload flag.  The layout-canonicalization
    pass (``repro.passes.layout``) exists to cancel these; the ones
    that survive sit at the graph boundary (ONNX's NCHW contract).
    """
    rank = len(in_shape)
    p = tuple(int(x) for x in perm)
    if sorted(p) != list(range(rank)):
        raise ValueError(f"{name}: perm {p} is not a permutation of "
                         f"0..{rank - 1}")
    inv = [0] * rank
    for pos, ax in enumerate(p):
        inv[ax] = pos
    imap = AffineMap.of(rank, [AffineExpr.dim(inv[k]) for k in range(rank)])
    omap = AffineMap.identity(rank)
    out_shape = tuple(int(in_shape[ax]) for ax in p)
    return GenericOp(
        name=name,
        inputs=(input_name,),
        output=output_name,
        indexing_maps=(imap, omap),
        iterator_types=tuple(IteratorType.PARALLEL for _ in range(rank)),
        dim_sizes=out_shape,
        payload=PayloadKind.IDENTITY,
        elem_bits=elem_bits,
    )


def make_flatten_op(
    name: str,
    input_name: str,
    output_name: str,
    *,
    in_shape: Sequence[int],
    order: Optional[Sequence[int]] = None,
    elem_bits: int = 8,
) -> GenericOp:
    """Linearize axes ``1..r-1`` into one feature axis (rank-2 output).

    ``order`` is the linearization order of the non-batch axes
    (default: ascending — row-major over the input layout).  The output
    map's second result is the affine mixed-radix expression
    ``Σ stride_ax · d_ax``.  An in-order linearization (ascending
    ``order``) is a pure wire on the stream; an out-of-order one
    buffers the tensor (``streaming.plan_node`` charges it) — the
    layout pass's transpose→flatten fold merges two data movements
    into this one node, trading a node and a stream, not the buffer.
    """
    rank = len(in_shape)
    if rank < 2:
        raise ValueError(f"{name}: flatten needs rank >= 2, got {rank}")
    o = tuple(int(x) for x in order) if order is not None \
        else tuple(range(1, rank))
    if sorted(o) != list(range(1, rank)):
        raise ValueError(f"{name}: order {o} is not a permutation of "
                         f"1..{rank - 1}")
    stride = 1
    coeffs: dict[int, int] = {}
    for ax in reversed(o):
        coeffs[ax] = stride
        stride *= int(in_shape[ax])
    expr = AffineExpr((), 0)
    for ax in o:
        expr = expr + AffineExpr.dim(ax, coeffs[ax])
    imap = AffineMap.identity(rank)
    omap = AffineMap.of(rank, [AffineExpr.dim(0), expr])
    return GenericOp(
        name=name,
        inputs=(input_name,),
        output=output_name,
        indexing_maps=(imap, omap),
        iterator_types=tuple(IteratorType.PARALLEL for _ in range(rank)),
        dim_sizes=tuple(int(s) for s in in_shape),
        payload=PayloadKind.IDENTITY,
        elem_bits=elem_bits,
    )


def make_pool2d_op(
    name: str,
    input_name: str,
    output_name: str,
    *,
    n: int,
    h_out: int,
    w_out: int,
    c: int,
    kh: int,
    kw: int,
    stride: int,
    payload: PayloadKind = PayloadKind.MAX,
    elem_bits: int = 8,
) -> GenericOp:
    """Max/avg pool: sliding window with a single (streamed) input."""
    d = AffineExpr.dim
    P, R = IteratorType.PARALLEL, IteratorType.REDUCTION
    imap = AffineMap.of(
        6, [d(0), d(1, stride) + d(4), d(2, stride) + d(5), d(3)]
    )
    omap = AffineMap.of(6, [d(0), d(1), d(2), d(3)])
    return GenericOp(
        name=name,
        inputs=(input_name,),
        output=output_name,
        indexing_maps=(imap, omap),
        iterator_types=(P, P, P, P, R, R),
        dim_sizes=(n, h_out, w_out, c, kh, kw),
        payload=payload,
        elem_bits=elem_bits,
    )
